#!/usr/bin/env bash
# One-command host benchmark: runs the three measured suites and
# overwrites the committed JSON documents in the repo root —
#
#   benches/swar_vs_scalar.rs  -> BENCH_kernels.json  (bench-kernels/v1)
#   benches/gemm_batch_sweep.rs -> BENCH_gemm.json    (bench-gemm/v1)
#   benches/serve_sweep.rs      -> BENCH_serve.json   (bench-serve/v3)
#
# The kernels suite includes the real-ISA tier (fullpack-*-avx2/-neon)
# for whatever the host CPU supports; undetected ISAs are skipped with
# a note, so the JSON only ever carries executed numbers.
#
# Usage:
#   scripts/bench_host.sh            # full sampling (minutes)
#   QUICK=1 scripts/bench_host.sh    # smoke-level sampling
#   LIVE=1 scripts/bench_host.sh     # serve sweep on the real engine
#
# Re-run after changing kernels, then commit the refreshed JSONs —
# EXPERIMENTS.md's "measured" columns are populated from them.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== suite 1/3: kernel tiers (SWAR + ISA) -> BENCH_kernels.json =="
BENCH_OUT=BENCH_kernels.json cargo bench --bench swar_vs_scalar

echo
echo "== suite 2/3: batched GEMM sweep -> BENCH_gemm.json =="
BENCH_OUT=BENCH_gemm.json cargo bench --bench gemm_batch_sweep

echo
echo "== suite 3/3: serve sweep -> BENCH_serve.json =="
OUT=BENCH_serve.json cargo bench --bench serve_sweep

echo
echo "wrote BENCH_kernels.json BENCH_gemm.json BENCH_serve.json"

"""L2 model tests: LSTM step numerics vs the f64 oracle, full-forward
shape/finite checks, and the GEMV/GEMM split (paper §4.6)."""

import numpy as np
import pytest

from compile import model as M
from compile.kernels import pack as P
from compile.kernels import ref


class TestLstmStep:
    @pytest.mark.parametrize("variant", ["w4a8", "w8a4", "w4a4", "w2a2", "w1a1"])
    def test_matches_oracle(self, variant):
        """Integer GEMV accumulators inside the LSTM must match the numpy
        oracle to f32 rounding."""
        H = 128
        wbits, abits = ref.parse_variant(variant)
        rng = np.random.default_rng(41)
        wx = M._qweights(rng, 4 * H, H, wbits)
        wh = M._qweights(rng, 4 * H, H, wbits)
        bias = rng.normal(size=4 * H).astype(np.float32) * 0.1
        alo, ahi = P.value_range(abits)
        x = rng.integers(alo, ahi + 1, size=H).astype(np.int8)
        h = rng.integers(alo, ahi + 1, size=H).astype(np.int8)
        c = rng.normal(size=H).astype(np.float32) * 0.5
        sx, sh, sw = 0.05, 0.1, 0.02

        h_ref, c_ref = ref.lstm_step_ref(x, h, c, wx, wh, bias, sx, sh, sw)

        wxp = wx if wbits == 8 else P.pack(wx, wbits)
        whp = wh if wbits == 8 else P.pack(wh, wbits)
        xp = x if abits == 8 else P.pack(x, abits)
        hp = h if abits == 8 else P.pack(h, abits)
        import jax.numpy as jnp
        _, c_got, h_f32 = M.lstm_step(
            variant, jnp.asarray(wxp), jnp.asarray(whp), jnp.asarray(bias),
            jnp.asarray(xp), jnp.asarray(hp), jnp.asarray(c),
            jnp.float32(sx), jnp.float32(sh), jnp.float32(sw))
        np.testing.assert_allclose(np.asarray(h_f32), h_ref, atol=1e-4)
        np.testing.assert_allclose(np.asarray(c_got), c_ref, atol=1e-4)

    def test_forget_gate_keeps_cell(self):
        """With saturated forget gate and zero input gate, c' ≈ c."""
        import jax.numpy as jnp
        H = 128
        wx = np.zeros((4 * H, H), np.int8)
        wh = np.zeros((4 * H, H), np.int8)
        bias = np.concatenate([np.full(H, -20.0), np.full(H, 20.0),
                               np.zeros(H), np.zeros(H)]).astype(np.float32)
        c = np.linspace(-1, 1, H).astype(np.float32)
        x = np.zeros(H, np.int8)
        _, c_next, _ = M.lstm_step(
            "w8a8", jnp.asarray(wx), jnp.asarray(wh), jnp.asarray(bias),
            jnp.asarray(x), jnp.asarray(x), jnp.asarray(c),
            jnp.float32(1), jnp.float32(1), jnp.float32(1))
        np.testing.assert_allclose(np.asarray(c_next), c, atol=1e-5)


class TestForward:
    @pytest.mark.parametrize("variant", list(ref.VARIANTS) + ["w8a8", "f32"])
    def test_shapes_and_finite(self, variant):
        rng = np.random.default_rng(43)
        x = rng.normal(size=(M.TINY.time_steps, M.TINY.n_input)).astype(np.float32)
        p = M.make_params(M.TINY, variant, seed=2)
        out = np.asarray(M.deepspeech_forward_jit(p)(x))
        assert out.shape == (M.TINY.time_steps, M.TINY.n_output)
        assert np.isfinite(out).all()

    def test_deterministic(self):
        rng = np.random.default_rng(47)
        x = rng.normal(size=(M.TINY.time_steps, M.TINY.n_input)).astype(np.float32)
        p = M.make_params(M.TINY, "w4a8", seed=2)
        f = M.deepspeech_forward_jit(p)
        np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(f(x)))

    def test_variant_changes_output(self):
        """Different LSTM bit-widths quantize differently — outputs differ
        (same seed), confirming the variant actually routes the LSTM."""
        rng = np.random.default_rng(53)
        x = rng.normal(size=(M.TINY.time_steps, M.TINY.n_input)).astype(np.float32)
        outs = {}
        for v in ("w4a8", "w1a1"):
            p = M.make_params(M.TINY, v, seed=2)
            outs[v] = np.asarray(M.deepspeech_forward_jit(p)(x))
        assert not np.array_equal(outs["w4a8"], outs["w1a1"])


class TestQuantizeHelpers:
    def test_quantize_clips(self):
        import jax.numpy as jnp
        x = jnp.asarray(np.array([-100.0, 0.0, 100.0], np.float32))
        q = np.asarray(M.quantize_jnp(x, jnp.float32(1.0), 4))
        np.testing.assert_array_equal(q, [-8, 0, 7])

    @pytest.mark.parametrize("bits", [4, 2, 1])
    def test_quantize_pack_shapes(self, bits):
        import jax.numpy as jnp
        n = P.group_size(bits)
        x = jnp.zeros((n,), jnp.float32)
        out = M.quantize_pack_jnp(x, jnp.float32(1.0), bits)
        assert out.shape == (n // P.elems_per_byte(bits),)
        assert out.dtype == jnp.uint8

    def test_pack_jnp_matches_numpy(self):
        import jax.numpy as jnp
        rng = np.random.default_rng(59)
        for bits in (4, 2, 1):
            lo, hi = P.value_range(bits)
            x = rng.integers(lo, hi + 1, size=P.group_size(bits) * 2).astype(np.int8)
            got = np.asarray(M.pack_jnp(jnp.asarray(x), bits))
            np.testing.assert_array_equal(got, P.pack(x, bits))

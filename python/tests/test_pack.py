"""Unit tests for the FullPack packing layout (pack.py) — including a
golden-vector check of the paper's Fig. 2 example layout."""

import numpy as np
import pytest

from compile.kernels import pack as P


class TestLayoutGolden:
    def test_fig2_4bit_layout(self):
        """Paper Fig. 2: 4-bit, byte j of a block holds elements j (low
        nibble) and j+16 (high nibble) of a 32-element group."""
        x = np.arange(32, dtype=np.int8) % 8  # values 0..7, in-range for 4-bit
        packed = P.pack(x, 4)
        assert packed.shape == (16,)
        for j in range(16):
            lo = packed[j] & 0xF
            hi = (packed[j] >> 4) & 0xF
            assert lo == x[j], f"byte {j} low nibble"
            assert hi == x[j + 16], f"byte {j} high nibble"

    def test_2bit_layout(self):
        x = np.arange(64, dtype=np.int8) % 2
        packed = P.pack(x, 2)
        assert packed.shape == (16,)
        for j in range(16):
            for k in range(4):
                v = (packed[j] >> (2 * k)) & 0x3
                assert v == x[j + 16 * k]

    def test_1bit_layout(self):
        rng = np.random.default_rng(3)
        x = -rng.integers(0, 2, size=128).astype(np.int8)  # {-1, 0}
        packed = P.pack(x, 1)
        assert packed.shape == (16,)
        for j in range(16):
            for k in range(8):
                bit = int((packed[j] >> k) & 1)
                assert -bit == int(x[j + 16 * k])

    def test_negative_values_two_complement(self):
        x = np.array([-8, 7, -1, 0] * 8, dtype=np.int8)
        packed = P.pack(x, 4)
        got = P.unpack(packed, 4, n=32)
        np.testing.assert_array_equal(got, x)


class TestRoundTrip:
    @pytest.mark.parametrize("bits", [4, 2, 1])
    @pytest.mark.parametrize("n", [0, 1, 15, 16, 31, 32, 100, 128, 500])
    def test_roundtrip_padded(self, bits, n):
        rng = np.random.default_rng(bits * 1000 + n)
        lo, hi = P.value_range(bits)
        x = rng.integers(lo, hi + 1, size=n).astype(np.int8)
        packed = P.pack(x, bits)
        assert packed.dtype == np.uint8
        assert packed.shape[-1] == P.padded_len(n, bits) // P.elems_per_byte(bits)
        got = P.unpack(packed, bits, n=n)
        np.testing.assert_array_equal(got, x)

    @pytest.mark.parametrize("bits", [4, 2, 1])
    def test_roundtrip_matrix(self, bits):
        rng = np.random.default_rng(17)
        lo, hi = P.value_range(bits)
        w = rng.integers(lo, hi + 1, size=(8, 192)).astype(np.int8)
        got = P.unpack(P.pack(w, bits), bits, n=192)
        np.testing.assert_array_equal(got, w)
        # rows are packed independently
        row0 = P.pack(w[0], bits)
        np.testing.assert_array_equal(P.pack(w, bits)[0], row0)

    def test_padding_is_zero(self):
        x = np.array([1, -2, 3], dtype=np.int8)
        packed = P.pack(x, 4)
        full = P.unpack(packed, 4)
        np.testing.assert_array_equal(full[3:], np.zeros(29, np.int8))


class TestValidation:
    def test_out_of_range_rejected(self):
        with pytest.raises(ValueError):
            P.pack(np.array([8], dtype=np.int8), 4)   # 4-bit max is 7
        with pytest.raises(ValueError):
            P.pack(np.array([-9], dtype=np.int8), 4)
        with pytest.raises(ValueError):
            P.pack(np.array([1], dtype=np.int8), 1)   # 1-bit domain {-1,0}

    def test_float_rejected(self):
        with pytest.raises(TypeError):
            P.pack(np.array([1.0]), 4)

    def test_bad_bits_rejected(self):
        with pytest.raises(ValueError):
            P.pack(np.array([0], dtype=np.int8), 3)
        with pytest.raises(ValueError):
            P.elems_per_byte(8)

    def test_value_range(self):
        assert P.value_range(8) == (-128, 127)
        assert P.value_range(4) == (-8, 7)
        assert P.value_range(2) == (-2, 1)
        assert P.value_range(1) == (-1, 0)


class TestNaivePacking:
    @pytest.mark.parametrize("bits", [4, 2, 1])
    def test_naive_density(self, bits):
        """Naive packing has the same density as FullPack — the difference
        is extraction cost, not footprint (paper Alg. 1 discussion)."""
        lo, hi = P.value_range(bits)
        rng = np.random.default_rng(5)
        x = rng.integers(lo, hi + 1, size=P.group_size(bits)).astype(np.int8)
        assert P.pack_naive(x, bits).nbytes == P.pack(x, bits).nbytes

    def test_naive_4bit_alg1_order(self):
        """Alg. 1: W0 = (W[i] >> 4) << 4 — first element in the high bits."""
        x = np.array([3, 5], dtype=np.int8)
        packed = P.pack_naive(x, 4)
        assert (packed[0] >> 4) & 0xF == 3
        assert packed[0] & 0xF == 5


class TestUlppackPacking:
    def test_spacer_waste(self):
        """ULPPACK wastes (16-2b)/16 of each lane — FullPack's motivating
        comparison (§1): same data, larger footprint."""
        rng = np.random.default_rng(7)
        for bits in (4, 2, 1):
            lo, hi = P.value_range(bits)
            x = rng.integers(lo, hi + 1, size=256).astype(np.int8)
            ulp = P.pack_ulppack(x, bits)
            full = P.pack(x, bits)
            assert ulp.nbytes == 256  # 2 values per 2-byte lane
            # FullPack footprint is bits/8 bytes per value:
            assert full.nbytes == 256 * bits // 8
            assert ulp.nbytes >= full.nbytes * 2

    def test_lane_values_recoverable(self):
        x = np.array([1, -2, 0, 1], dtype=np.int8)
        lanes = P.pack_ulppack(x, 2)
        assert lanes.dtype == np.uint16
        assert lanes.shape == (2,)
        assert lanes[0] & 0x3 == (1 & 0x3)
        assert (lanes[0] >> 8) & 0x3 == (-2 & 0x3)

"""AOT path smoke tests: HLO text emission is parseable-looking, manifest
metadata is consistent, and a lowered module reproduces the eager result
when run back through jax (guards the stablehlo→HLO conversion)."""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M
from compile.kernels import fullpack_gemv as fg
from compile.kernels import ref


class TestHloText:
    def test_gemv_lowering_produces_hlo(self):
        import jax
        import jax.numpy as jnp
        import functools
        fn = functools.partial(fg.gemv, variant="w4a8", row_tile=8)
        wshape, ashape = fg.packed_shapes(64, 128, "w4a8")
        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct(wshape, jnp.uint8),
            jax.ShapeDtypeStruct(ashape, jnp.int8))
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "ROOT" in text
        # the two-shift extraction must survive lowering
        assert "shift-right-arithmetic" in text
        assert "shift-left" in text

    def test_emitter_writes_manifest(self, tmp_path):
        em = aot.Emitter(str(tmp_path))
        aot.emit_gemv(em, "w4a8", 32, 128, row_tile=8)
        aot.emit_gemv(em, "w8a8", 32, 128, row_tile=8)
        em.finish()
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["vl"] == 16
        names = [a["name"] for a in manifest["artifacts"]]
        assert "gemv_w4a8_32x128" in names
        art = manifest["artifacts"][0]
        assert art["inputs"][0]["name"] == "weights"
        assert art["inputs"][0]["dtype"] == "u8"
        assert art["outputs"][0]["dtype"] == "s32"
        assert (tmp_path / art["file"]).exists()

    def test_lstm_step_manifest_shapes(self, tmp_path):
        em = aot.Emitter(str(tmp_path))
        aot.emit_lstm_step(em, "w2a2", 128, row_tile=8, tag="t")
        em.finish()
        art = json.loads((tmp_path / "manifest.json").read_text())["artifacts"][0]
        by_name = {i["name"]: i for i in art["inputs"]}
        assert by_name["wx"]["shape"] == [512, 128 // 4]  # 2-bit: 4 elems/byte
        assert by_name["x"]["shape"] == [128 // 4]
        assert by_name["c"]["dtype"] == "f32"
        # outputs: h_packed (u8), c (f32), h_f32 (f32)
        assert [o["dtype"] for o in art["outputs"]] == ["u8", "f32", "f32"]


class TestArtifactsDir:
    """Checks against the real artifacts/ tree if `make artifacts` ran."""

    ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

    @pytest.fixture(autouse=True)
    def _skip_without_artifacts(self):
        if not os.path.exists(os.path.join(self.ARTIFACTS, "manifest.json")):
            pytest.skip("artifacts/ not built (run `make artifacts`)")

    def test_manifest_files_exist(self):
        manifest = json.load(open(os.path.join(self.ARTIFACTS, "manifest.json")))
        assert len(manifest["artifacts"]) >= 30
        for art in manifest["artifacts"]:
            path = os.path.join(self.ARTIFACTS, art["file"])
            assert os.path.exists(path), art["file"]
            head = open(path).read(200)
            assert "HloModule" in head

    def test_all_gemv_variants_present(self):
        manifest = json.load(open(os.path.join(self.ARTIFACTS, "manifest.json")))
        gemv = {a["variant"] for a in manifest["artifacts"] if a["kind"] == "gemv"}
        for v in ref.VARIANTS + ref.BASELINES:
            assert v in gemv, f"missing gemv artifact for {v}"

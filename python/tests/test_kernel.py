"""Pallas kernels vs the pure-numpy oracle — the CORE correctness signal.

Every packed GEMV variant must match ``ref.gemv_ref`` on unpacked
operands bit-for-bit (integer kernels are exact; no tolerance)."""

import numpy as np
import pytest

from compile.kernels import fullpack_gemv as fg
from compile.kernels import pack as P
from compile.kernels import ref

ALL_VARIANTS = list(ref.VARIANTS)


def _padded_operands(z, k, variant, seed):
    """Random operands zero-padded to a common group-aligned depth."""
    rng = np.random.default_rng(seed)
    w, a = ref.random_operands(z, k, variant, rng)
    wbits, abits = ref.parse_variant(variant)
    kp = k
    for b in (wbits, abits):
        if b != 8:
            kp = max(kp, P.padded_len(k, b))
    wf = np.zeros((z, kp), np.int8)
    wf[:, :k] = w
    af = np.zeros((kp,), np.int8)
    af[:k] = a
    return wf, af


class TestGemvVariants:
    @pytest.mark.parametrize("variant", ALL_VARIANTS)
    def test_exact_vs_oracle(self, variant):
        z, k = 24, 160
        wf, af = _padded_operands(z, k, variant, seed=11)
        wp, ap = ref.pack_operands(wf, af, variant)
        got = np.asarray(fg.gemv(wp, ap, variant))
        np.testing.assert_array_equal(got, ref.gemv_ref(wf, af))

    @pytest.mark.parametrize("variant", ALL_VARIANTS)
    def test_extremal_values(self, variant):
        """All-min / all-max operands: worst-case accumulator magnitudes
        and the sign-extension edge (e.g. -8 for 4-bit, -1 for 1-bit)."""
        wbits, abits = ref.parse_variant(variant)
        z = 8
        k = max(P.group_size(b) for b in (wbits, abits) if b != 8)
        for wv in P.value_range(wbits):
            for av in P.value_range(abits):
                w = np.full((z, k), wv, np.int8)
                a = np.full((k,), av, np.int8)
                wp, ap = ref.pack_operands(w, a, variant)
                got = np.asarray(fg.gemv(wp, ap, variant))
                np.testing.assert_array_equal(got, ref.gemv_ref(w, a))

    @pytest.mark.parametrize("variant", ["w4a8", "w2a2", "w1a1"])
    @pytest.mark.parametrize("row_tile", [1, 4, 16])
    def test_row_tile_invariance(self, variant, row_tile):
        z, k = 32, 128
        wf, af = _padded_operands(z, k, variant, seed=13)
        wp, ap = ref.pack_operands(wf, af, variant)
        got = np.asarray(fg.gemv(wp, ap, variant, row_tile=row_tile))
        np.testing.assert_array_equal(got, ref.gemv_ref(wf, af))

    def test_bad_row_tile_rejected(self):
        wf, af = _padded_operands(8, 32, "w4a8", seed=1)
        wp, ap = ref.pack_operands(wf, af, "w4a8")
        with pytest.raises(ValueError):
            fg.gemv(wp, ap, "w4a8", row_tile=3)

    def test_depth_mismatch_rejected(self):
        wf, af = _padded_operands(8, 64, "w4a4", seed=1)
        wp, ap = ref.pack_operands(wf, af, "w4a4")
        with pytest.raises(ValueError):
            fg.gemv(wp, ap[: ap.shape[0] // 2], "w4a4")


class TestBaselines:
    def test_w8a8(self):
        rng = np.random.default_rng(19)
        w = rng.integers(-128, 128, (16, 96)).astype(np.int8)
        a = rng.integers(-128, 128, (96,)).astype(np.int8)
        got = np.asarray(fg.gemv_w8a8(w, a))
        np.testing.assert_array_equal(got, ref.gemv_ref(w, a))

    def test_f32(self):
        rng = np.random.default_rng(23)
        w = rng.normal(size=(16, 96)).astype(np.float32)
        a = rng.normal(size=(96,)).astype(np.float32)
        got = np.asarray(fg.gemv_f32(w, a))
        np.testing.assert_allclose(got, w @ a, rtol=1e-5)


class TestExtraction:
    """The two-shift extraction (Fig. 3) in isolation."""

    @pytest.mark.parametrize("bits", [4, 2, 1])
    def test_extract_matches_scalar_unpack(self, bits):
        import jax.numpy as jnp
        from jax import lax

        rng = np.random.default_rng(29)
        lo, hi = P.value_range(bits)
        x = rng.integers(lo, hi + 1, size=P.group_size(bits) * 4).astype(np.int8)
        packed = P.pack(x, bits)
        block_i8 = lax.bitcast_convert_type(jnp.asarray(packed), jnp.int8)
        got = np.asarray(fg.extract_subvectors(block_i8, bits))
        np.testing.assert_array_equal(got, P.unpack(packed, bits))

    def test_top_subvector_single_shift(self):
        """For k = E-1 the LSL amount is 0 — paper's 'only one ASR for the
        16th..32nd values' claim, kept structural in the kernel."""
        for bits in (4, 2, 1):
            e = P.elems_per_byte(bits)
            assert 8 - e * bits == 0  # dense packing ⇒ top LSL is a no-op


class TestAccumulatorSafety:
    def test_w4a8_no_overflow_at_max_depth(self):
        """Worst case |acc| = 8*128*k must stay in int32 for practical k.
        8*128*k < 2^31 ⇒ k < 2_097_152 — far above any DNN layer depth."""
        assert 8 * 128 * 2048 * 4 < 2**31

    def test_large_depth_exact(self):
        z, k = 8, 4096
        wf, af = _padded_operands(z, k, "w4a8", seed=31)
        wp, ap = ref.pack_operands(wf, af, "w4a8")
        got = np.asarray(fg.gemv(wp, ap, "w4a8"))
        np.testing.assert_array_equal(got, ref.gemv_ref(wf, af))


class TestVmemEstimate:
    def test_subbyte_smaller_than_w8a8(self):
        """The structural perf claim at L1: packed tiles move fewer bytes
        per MAC than W8A8 (DESIGN.md §8)."""
        full = fg.vmem_bytes(2048, 2048, "w4a8")
        base = fg.vmem_bytes(2048, 2048, "w8a8")
        assert full < base

    def test_monotone_in_bits(self):
        sizes = [fg.vmem_bytes(1024, 1024, v) for v in ("w1a1", "w2a2", "w4a4")]
        assert sizes == sorted(sizes)

"""Hypothesis property sweeps: shapes, values and variants for the packing
layout and the Pallas kernels vs the scalar oracle (DESIGN.md deliverable
(c): L1 property testing)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fullpack_gemv as fg
from compile.kernels import pack as P
from compile.kernels import ref

SUB_BITS = st.sampled_from([4, 2, 1])
VARIANT = st.sampled_from(list(ref.VARIANTS))


@st.composite
def packed_vector(draw, bits=None):
    b = draw(SUB_BITS) if bits is None else bits
    n = draw(st.integers(0, 400))
    lo, hi = P.value_range(b)
    x = draw(st.lists(st.integers(lo, hi), min_size=n, max_size=n))
    return b, np.array(x, dtype=np.int8)


class TestPackProperties:
    @given(packed_vector())
    @settings(max_examples=60, deadline=None)
    def test_roundtrip(self, bv):
        bits, x = bv
        got = P.unpack(P.pack(x, bits), bits, n=x.shape[-1])
        np.testing.assert_array_equal(got, x)

    @given(packed_vector())
    @settings(max_examples=60, deadline=None)
    def test_density(self, bv):
        """Zero spacer bits: footprint is exactly ceil(n/G)*G*bits/8."""
        bits, x = bv
        packed = P.pack(x, bits)
        np_ = P.padded_len(x.shape[-1], bits)
        assert packed.nbytes == np_ * bits // 8

    @given(packed_vector(), st.integers(0, 2**32 - 1))
    @settings(max_examples=40, deadline=None)
    def test_pack_is_injective_on_groups(self, bv, seed):
        """Different in-range vectors yield different packed bytes (on the
        unpadded prefix) — no information loss."""
        bits, x = bv
        if x.size == 0:
            return
        rng = np.random.default_rng(seed)
        y = x.copy()
        i = rng.integers(0, x.size)
        lo, hi = P.value_range(bits)
        alt = [v for v in range(lo, hi + 1) if v != x[i]]
        y[i] = alt[rng.integers(0, len(alt))]
        assert not np.array_equal(P.pack(x, bits), P.pack(y, bits))


class TestGemvProperties:
    @given(
        VARIANT,
        st.integers(1, 6),     # row tiles of 8
        st.integers(1, 4),     # depth in groups of 128
        st.integers(0, 2**32 - 1),
    )
    @settings(max_examples=40, deadline=None)
    def test_kernel_matches_oracle(self, variant, zt, kg, seed):
        z, k = zt * 8, kg * 128
        rng = np.random.default_rng(seed)
        w, a = ref.random_operands(z, k, variant, rng)
        wp, ap = ref.pack_operands(w, a, variant)
        got = np.asarray(fg.gemv(wp, ap, variant))
        np.testing.assert_array_equal(got, ref.gemv_ref(w, a))

    @given(VARIANT, st.integers(0, 2**32 - 1))
    @settings(max_examples=20, deadline=None)
    def test_linearity_in_activations(self, variant, seed):
        """gemv(w, a1 + a2) == gemv(w, a1) + gemv(w, a2) when the sum stays
        in range — integer GEMV is linear."""
        wbits, abits = ref.parse_variant(variant)
        z, k = 8, 128
        rng = np.random.default_rng(seed)
        w, _ = ref.random_operands(z, k, variant, rng)
        alo, ahi = P.value_range(abits)
        half_lo, half_hi = alo // 2, max(ahi // 2, 0)
        a1 = rng.integers(half_lo, half_hi + 1, size=k).astype(np.int8)
        a2 = rng.integers(half_lo, half_hi + 1, size=k).astype(np.int8)
        if abits == 1:
            a1, a2 = np.minimum(a1, 0), np.zeros_like(a2)
        wp, _ = ref.pack_operands(w, a1, variant)

        def run(a):
            _, ap = ref.pack_operands(w, a, variant)
            return np.asarray(fg.gemv(wp, ap, variant))

        s = (a1.astype(np.int32) + a2.astype(np.int32))
        if s.min() < alo or s.max() > ahi:
            return  # would saturate the packed domain; property inapplicable
        np.testing.assert_array_equal(run((a1 + a2).astype(np.int8)),
                                      run(a1) + run(a2))

    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=20, deadline=None)
    def test_zero_weights_zero_output(self, seed):
        rng = np.random.default_rng(seed)
        for variant in ("w4a8", "w2a2", "w1a1"):
            _, a = ref.random_operands(8, 128, variant, rng)
            w = np.zeros((8, 128), np.int8)
            wp, ap = ref.pack_operands(w, a, variant)
            got = np.asarray(fg.gemv(wp, ap, variant))
            np.testing.assert_array_equal(got, np.zeros(8, np.int32))

"""Pure-numpy correctness oracles for the FullPack GEMV kernels.

These deliberately avoid the vector-shift extraction path: sub-byte
operands are unpacked element-by-element (``pack.unpack`` does scalar
bit-twiddling) and the dot product is a plain int32 ``matmul``.  Every
Pallas kernel and every Rust SWAR kernel must match these bit-for-bit.
"""

from __future__ import annotations

import numpy as np

from . import pack as packmod

#: the nine paper variants (§3.2) plus the two comparison baselines.
VARIANTS = (
    "w8a4", "w4a8", "w4a4",
    "w2a8", "w8a2", "w2a2",
    "w1a8", "w8a1", "w1a1",
)
BASELINES = ("w8a8", "f32")


def parse_variant(variant: str) -> tuple[int, int]:
    """``"w4a8" -> (4, 8)`` — weight bits, activation bits."""
    v = variant.lower()
    if not (v.startswith("w") and "a" in v):
        raise ValueError(f"bad variant {variant!r}")
    wb, ab = v[1:].split("a")
    wbits, abits = int(wb), int(ab)
    for b in (wbits, abits):
        if b not in packmod.SUPPORTED_BITS:
            raise ValueError(f"unsupported bit-width {b} in {variant!r}")
    return wbits, abits


def gemv_ref(w: np.ndarray, a: np.ndarray) -> np.ndarray:
    """int32 GEMV oracle on *unpacked* operands: ``(z,k) @ (k,) -> (z,)``."""
    w = np.asarray(w, dtype=np.int32)
    a = np.asarray(a, dtype=np.int32)
    if w.ndim != 2 or a.ndim != 1 or w.shape[1] != a.shape[0]:
        raise ValueError(f"shape mismatch: w{w.shape} @ a{a.shape}")
    return (w @ a).astype(np.int32)


def gemm_ref(w: np.ndarray, a: np.ndarray) -> np.ndarray:
    """int32 GEMM oracle: ``(z,k) @ (k,b) -> (z,b)``."""
    return (np.asarray(w, np.int32) @ np.asarray(a, np.int32)).astype(np.int32)


def gemv_packed_ref(wp: np.ndarray, ap: np.ndarray, variant: str,
                    k: int, vl: int = packmod.VL) -> np.ndarray:
    """Oracle that takes *packed* operands (as the kernels do), unpacks via
    the scalar path, and reduces in int32.

    ``wp``: (z, k/Ew) uint8 if weights are sub-byte else (z, k) int8.
    ``ap``: (k/Ea,) uint8 if activations are sub-byte else (k,) int8.
    ``k``: logical depth (pre-padding length).
    """
    wbits, abits = parse_variant(variant)
    if wbits == 8:
        w = np.asarray(wp, np.int8)[:, :k]
    else:
        w = packmod.unpack(wp, wbits, n=k, vl=vl)
    if abits == 8:
        a = np.asarray(ap, np.int8)[:k]
    else:
        a = packmod.unpack(ap, abits, n=k, vl=vl)
    return gemv_ref(w, a)


def random_operands(z: int, k: int, variant: str, rng: np.random.Generator
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Random (unpacked) int8 operands with values in the variant's range."""
    wbits, abits = parse_variant(variant)
    wlo, whi = packmod.value_range(wbits)
    alo, ahi = packmod.value_range(abits)
    w = rng.integers(wlo, whi + 1, size=(z, k), dtype=np.int64).astype(np.int8)
    a = rng.integers(alo, ahi + 1, size=(k,), dtype=np.int64).astype(np.int8)
    return w, a


def pack_operands(w: np.ndarray, a: np.ndarray, variant: str,
                  vl: int = packmod.VL) -> tuple[np.ndarray, np.ndarray]:
    """Pack unpacked int8 operands per the variant (identity for 8-bit)."""
    wbits, abits = parse_variant(variant)
    wp = w.astype(np.int8) if wbits == 8 else packmod.pack(w, wbits, vl=vl)
    ap = a.astype(np.int8) if abits == 8 else packmod.pack(a, abits, vl=vl)
    return wp, ap


def lstm_step_ref(x: np.ndarray, h: np.ndarray, c: np.ndarray,
                  w_x: np.ndarray, w_h: np.ndarray, bias: np.ndarray,
                  sx: float, sh: float, sw: float,
                  ) -> tuple[np.ndarray, np.ndarray]:
    """f64-accurate oracle for the hybrid-quantized LSTM step used by the
    DeepSpeech model (gates from integer GEMV accumulators, f32
    nonlinearities).

    ``w_x``: (4H, X) int, ``w_h``: (4H, H) int, ``x``: (X,) int, ``h``: (H,) int,
    ``bias``: (4H,) f32.  ``sx, sh, sw``: activation/state/weight scales.
    Gate order: i, f, g, o (input, forget, cell, output).
    Returns (h', c') in f32.
    """
    acc = (gemv_ref(w_x, x).astype(np.float64) * (sw * sx)
           + gemv_ref(w_h, h).astype(np.float64) * (sw * sh)
           + bias.astype(np.float64))
    hdim = h.shape[0]
    i, f, g, o = (acc[0:hdim], acc[hdim:2 * hdim],
                  acc[2 * hdim:3 * hdim], acc[3 * hdim:4 * hdim])
    sig = lambda v: 1.0 / (1.0 + np.exp(-v))
    c_new = sig(f) * c.astype(np.float64) + sig(i) * np.tanh(g)
    h_new = sig(o) * np.tanh(c_new)
    return h_new.astype(np.float32), c_new.astype(np.float32)

"""L1 — FullPack GEMV as Pallas kernels (paper §3.2, Alg. 2, Fig. 3).

Hardware-Adaptation (DESIGN.md §3): the paper's NEON schedule maps onto
Pallas as

* 16×i8 NEON register        → 16-lane minor axis of a VMEM tile
                                (``VL = 16`` kept so the layout is
                                bit-identical to the Rust SWAR kernels);
* ``LD1 {v0.16b}``           → BlockSpec-scheduled HBM→VMEM tile copy —
                                dense packing means every byte moved over
                                the TPU's HBM bus is useful data, the
                                same bandwidth argument as the paper's;
* ``SSHL`` / ``SSHR`` lanes  → ``lax.shift_left`` /
                                ``shift_right_arithmetic`` on int8 —
                                the two-shift mask+sign-extend extraction
                                of Fig. 3 (LSL then ASR for the low
                                sub-vector, a single ASR for the top one);
* ``SMLAL`` accumulate       → int32 ``jnp.dot`` with
                                ``preferred_element_type=int32`` (MXU-
                                shaped on real hardware).

Kernels run ``interpret=True`` — real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see /opt/xla-example).

All kernels consume *packed* operands in the normative layout of
``pack.py`` and produce raw int32 accumulators; (re)quantization scales
are applied by the L2 model, mirroring TFLite's pipeline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .pack import VL, elems_per_byte, group_size, padded_len
from .ref import parse_variant

#: default number of output rows computed per grid step.
ROW_TILE = 8


def _bitcast_i8(x: jax.Array) -> jax.Array:
    """uint8 → int8 reinterpret (two's complement), the 'load into a signed
    vector register' step."""
    return lax.bitcast_convert_type(x, jnp.int8)


def extract_subvectors(block_i8: jax.Array, bits: int) -> jax.Array:
    """The paper's two-shift extraction, vectorized over a whole tile.

    ``block_i8``: (..., n_bytes) int8 where every VL consecutive bytes are
    one packed block.  Returns (..., n_bytes * E) int8 in original element
    order — sub-vector ``k`` of block ``g`` lands at positions
    ``g*G + k*VL .. g*G + (k+1)*VL``.

    For each ``k``: ``ASR(LSL(V, 8-(k+1)b), 8-b)`` — LSL masks away the
    higher sub-elements, ASR sign-extends.  ``k = E-1`` needs only the ASR
    (Fig. 3's "one shift for W17..W32").
    """
    e = elems_per_byte(bits)
    *lead, nbytes = block_i8.shape
    v = block_i8.reshape(*lead, nbytes // VL, VL)
    subs = []
    for k in range(e):
        lsl = 8 - (k + 1) * bits
        shifted = v if lsl == 0 else lax.shift_left(v, jnp.int8(lsl))
        subs.append(lax.shift_right_arithmetic(shifted, jnp.int8(8 - bits)))
    # (..., groups, E, VL) -> (..., n_bytes * E): original order.
    return jnp.stack(subs, axis=-2).reshape(*lead, nbytes * e)


def _unpack_operand(ref_val: jax.Array, bits: int) -> jax.Array:
    """Packed uint8 (or plain int8 when bits == 8) → int8 element stream."""
    if bits == 8:
        return ref_val
    return extract_subvectors(_bitcast_i8(ref_val), bits)


def _gemv_kernel(wp_ref, ap_ref, o_ref, *, wbits: int, abits: int):
    """One grid step: a ROW_TILE×K block of the packed weight matrix against
    the full packed activation vector (GEMV is K-bound; activations fit
    VMEM whole, weights stream — Alg. 2's loop structure with the j-loop
    vectorized away)."""
    w = _unpack_operand(wp_ref[...], wbits)          # (tile, kp) int8
    a = _unpack_operand(ap_ref[...], abits)          # (kp,) int8
    o_ref[...] = jnp.dot(w.astype(jnp.int32), a.astype(jnp.int32),
                         preferred_element_type=jnp.int32)


def packed_shapes(z: int, k: int, variant: str) -> tuple[tuple[int, int], tuple[int,]]:
    """Packed operand shapes for a z×k GEMV under ``variant``."""
    wbits, abits = parse_variant(variant)
    kp_w = k if wbits == 8 else padded_len(k, wbits) // elems_per_byte(wbits)
    kp_a = k if abits == 8 else padded_len(k, abits) // elems_per_byte(abits)
    return (z, kp_w), (kp_a,)


@functools.partial(jax.jit, static_argnames=("variant", "row_tile"))
def gemv(wp: jax.Array, ap: jax.Array, variant: str, row_tile: int = ROW_TILE
         ) -> jax.Array:
    """FullPack GEMV: packed weights (z, kbytes) × packed activations → (z,) i32.

    Requirements: ``z % row_tile == 0`` and, for sub-byte operands, the
    packed byte counts already group-aligned (``pack.pack`` guarantees
    this).  When both operands are sub-byte their *padded element* counts
    must agree (use the same ``k`` through ``pack``).
    """
    wbits, abits = parse_variant(variant)
    z, wbytes = wp.shape
    if z % row_tile != 0:
        raise ValueError(f"z={z} not a multiple of row_tile={row_tile}")
    k_w = wbytes * (elems_per_byte(wbits) if wbits != 8 else 1)
    k_a = ap.shape[0] * (elems_per_byte(abits) if abits != 8 else 1)
    if k_w != k_a:
        raise ValueError(f"padded depth mismatch: weights {k_w} vs activations {k_a}"
                         " — pad operands to a common group-aligned k first")

    kernel = functools.partial(_gemv_kernel, wbits=wbits, abits=abits)
    grid = (z // row_tile,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, wbytes), lambda i: (i, 0)),
            pl.BlockSpec((ap.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((row_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((z,), jnp.int32),
        interpret=True,
    )(wp, ap)


@functools.partial(jax.jit, static_argnames=("row_tile",))
def gemv_w8a8(w: jax.Array, a: jax.Array, row_tile: int = ROW_TILE) -> jax.Array:
    """Ruy-like W8A8 baseline GEMV as a Pallas kernel (no unpack stage)."""
    z, k = w.shape

    def kernel(w_ref, a_ref, o_ref):
        o_ref[...] = jnp.dot(w_ref[...].astype(jnp.int32),
                             a_ref[...].astype(jnp.int32),
                             preferred_element_type=jnp.int32)

    return pl.pallas_call(
        kernel,
        grid=(z // row_tile,),
        in_specs=[pl.BlockSpec((row_tile, k), lambda i: (i, 0)),
                  pl.BlockSpec((k,), lambda i: (0,))],
        out_specs=pl.BlockSpec((row_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((z,), jnp.int32),
        interpret=True,
    )(w, a)


@functools.partial(jax.jit, static_argnames=("row_tile",))
def gemv_f32(w: jax.Array, a: jax.Array, row_tile: int = ROW_TILE) -> jax.Array:
    """FP32 baseline GEMV (Eigen/Ruy-FP32 rival) as a Pallas kernel."""
    z, k = w.shape

    def kernel(w_ref, a_ref, o_ref):
        o_ref[...] = jnp.dot(w_ref[...], a_ref[...],
                             preferred_element_type=jnp.float32)

    return pl.pallas_call(
        kernel,
        grid=(z // row_tile,),
        in_specs=[pl.BlockSpec((row_tile, k), lambda i: (i, 0)),
                  pl.BlockSpec((k,), lambda i: (0,))],
        out_specs=pl.BlockSpec((row_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((z,), jnp.float32),
        interpret=True,
    )(w, a)


def vmem_bytes(z: int, k: int, variant: str, row_tile: int = ROW_TILE) -> int:
    """Static VMEM-footprint estimate per grid step (DESIGN.md §8 L1):
    weight tile + packed activations + unpacked staging + output tile.
    Used by the perf notes — interpret-mode wallclock is *not* a TPU
    proxy, the structural footprint is what we optimize."""
    wbits, abits = parse_variant(variant)
    (z_, wbytes), (abytes,) = packed_shapes(z, k, variant)
    kp_w = wbytes * (elems_per_byte(wbits) if wbits != 8 else 1)
    tile_w_packed = row_tile * wbytes
    tile_w_unpacked = row_tile * kp_w            # int8 staging post-extract
    acts = abytes + kp_w                         # packed + unpacked
    out = row_tile * 4
    return tile_w_packed + tile_w_unpacked + acts + out

"""FullPack packing scheme (paper §3.1, Fig. 2) — normative layout.

For bit-width ``b ∈ {4, 2, 1}`` and vector lane count ``VL`` (16 for the
paper's NEON target, kept at 16 here so the layout is bit-identical to the
Rust SWAR kernels):

* elements-per-byte  ``E = 8 // b``
* group size         ``G = E * VL``  (32 / 64 / 128 elements)

A vector ``x[0..n)`` (``n`` padded to a multiple of ``G``) is split into
groups of ``G`` elements.  Within group ``g``, **byte ``j`` of the
16-byte block** holds original elements ``g*G + k*VL + j`` for
``k = 0..E-1``, with sub-element ``k`` stored in bits
``[k*b, (k+1)*b)`` (k = 0 is the least-significant bits).

Extraction of sub-vector ``k`` (16 originally-consecutive elements) from a
loaded 16-byte block ``V`` is then exactly the paper's two-shift schedule::

    sub_k = ASR( LSL(V, 8 - (k+1)*b), 8 - b )

— a logical shift left to mask away higher sub-elements, then an
arithmetic shift right to sign-extend.  For the top sub-vector
(k = E-1) the LSL is a no-op, matching the paper's "only one ASR for
W17..W32" observation (Fig. 3).

Values are signed two's-complement ``b``-bit integers, range
``[-2^(b-1), 2^(b-1) - 1]`` (for b=1: {-1, 0}, the natural 1-bit
two's-complement domain that the ASR sign-extension realizes).

Matrix rows are packed independently and stored consecutively ("repeat
for all other sets of rows", §3.1).
"""

from __future__ import annotations

import numpy as np

#: vector lane count — 16 int8 lanes of a 128-bit NEON register.
VL = 16

SUPPORTED_BITS = (8, 4, 2, 1)
SUB_BYTE_BITS = (4, 2, 1)


def elems_per_byte(bits: int) -> int:
    """Number of sub-byte elements stored per packed byte."""
    if bits not in SUB_BYTE_BITS:
        raise ValueError(f"sub-byte bits must be one of {SUB_BYTE_BITS}, got {bits}")
    return 8 // bits


def group_size(bits: int, vl: int = VL) -> int:
    """Elements covered by one VL-byte packed block (G = E * VL)."""
    return elems_per_byte(bits) * vl


def value_range(bits: int) -> tuple[int, int]:
    """Inclusive [lo, hi] range of signed b-bit two's-complement values."""
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"bits must be one of {SUPPORTED_BITS}, got {bits}")
    return -(1 << (bits - 1)), (1 << (bits - 1)) - 1


def padded_len(n: int, bits: int, vl: int = VL) -> int:
    """Smallest multiple of the group size >= n."""
    g = group_size(bits, vl)
    return ((n + g - 1) // g) * g


def pack(x: np.ndarray, bits: int, vl: int = VL) -> np.ndarray:
    """Pack the last axis of ``x`` (signed b-bit values) into FullPack layout.

    ``x``: integer array, last axis length ``n``; values must lie in
    ``value_range(bits)``.  The last axis is zero-padded to a multiple of
    ``G = (8//bits) * vl``.

    Returns a ``uint8`` array with last axis ``padded_len(n) // E``.
    """
    e = elems_per_byte(bits)
    g = e * vl
    lo, hi = value_range(bits)
    x = np.asarray(x)
    if x.dtype.kind not in "iu":
        raise TypeError(f"pack expects an integer array, got {x.dtype}")
    if x.size and (x.min() < lo or x.max() > hi):
        raise ValueError(f"values out of range [{lo}, {hi}] for {bits}-bit packing")

    n = x.shape[-1]
    np_ = padded_len(n, bits, vl)
    if np_ != n:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, np_ - n)]
        x = np.pad(x, pad)

    # (..., groups, E, VL): element (g, k, j) is original index g*G + k*VL + j
    xg = x.reshape(*x.shape[:-1], np_ // g, e, vl).astype(np.int64)
    mask = (1 << bits) - 1
    out = np.zeros((*x.shape[:-1], np_ // g, vl), dtype=np.uint8)
    for k in range(e):
        out |= ((xg[..., k, :] & mask) << (k * bits)).astype(np.uint8)
    return out.reshape(*x.shape[:-1], np_ // e)


def unpack(packed: np.ndarray, bits: int, n: int | None = None, vl: int = VL) -> np.ndarray:
    """Inverse of :func:`pack`.  Scalar bit-twiddling on purpose — this is
    the *independent oracle* for the shift-based vector extraction used by
    the kernels.  Returns ``int8`` with last axis ``n`` (or the full padded
    length if ``n`` is None)."""
    e = elems_per_byte(bits)
    packed = np.asarray(packed, dtype=np.uint8)
    nbytes = packed.shape[-1]
    if nbytes % vl != 0:
        raise ValueError(f"packed length {nbytes} not a multiple of VL={vl}")
    pg = packed.reshape(*packed.shape[:-1], nbytes // vl, vl).astype(np.int64)
    subs = []
    half = 1 << (bits - 1)
    mask = (1 << bits) - 1
    for k in range(e):
        v = (pg >> (k * bits)) & mask
        v = np.where(v >= half, v - (1 << bits), v)  # sign-extend
        subs.append(v)
    # (..., groups, E, VL) -> (..., padded_n)
    full = np.stack(subs, axis=-2).reshape(*packed.shape[:-1], nbytes * e)
    out = full.astype(np.int8)
    if n is not None:
        out = out[..., :n]
    return out


def pack_naive(x: np.ndarray, bits: int) -> np.ndarray:
    """Naive adjacent packing (paper Alg. 1 strawman): consecutive elements
    share a byte, element 0 in the *high* bits as Alg. 1's ``W[i] >> 4``
    extraction implies.  Used by the naive-method baseline."""
    e = elems_per_byte(bits)
    x = np.asarray(x)
    n = x.shape[-1]
    np_ = ((n + e - 1) // e) * e
    if np_ != n:
        x = np.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, np_ - n)])
    mask = (1 << bits) - 1
    xg = x.reshape(*x.shape[:-1], np_ // e, e).astype(np.int64)
    out = np.zeros((*x.shape[:-1], np_ // e), dtype=np.uint8)
    for k in range(e):
        # element k of the byte sits in the highest remaining bits
        out |= ((xg[..., k] & mask) << ((e - 1 - k) * bits)).astype(np.uint8)
    return out


def pack_ulppack(x: np.ndarray, bits: int, lane_bits: int = 16) -> np.ndarray:
    """ULPPACK-style spacer packing (Won et al., 2022): sub-byte values are
    placed in a wider lane with guard (spacer) bits between them so that
    lane-wise multiply-accumulate cannot overflow into a neighbour.

    Two b-bit values per 16-bit lane with ``16 - 2b`` wasted bits — the
    memory-bandwidth waste FullPack eliminates.  Returned as ``uint16``
    lanes (baseline comparator only)."""
    per_lane = 2
    x = np.asarray(x)
    n = x.shape[-1]
    np_ = ((n + per_lane - 1) // per_lane) * per_lane
    if np_ != n:
        x = np.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, np_ - n)])
    mask = (1 << bits) - 1
    xg = x.reshape(*x.shape[:-1], np_ // per_lane, per_lane).astype(np.int64)
    shift = lane_bits // per_lane  # value k at bit k*8
    out = np.zeros((*x.shape[:-1], np_ // per_lane), dtype=np.uint16)
    for k in range(per_lane):
        out |= ((xg[..., k] & mask) << (k * shift)).astype(np.uint16)
    return out

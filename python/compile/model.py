"""L2 — the DeepSpeech-like model (paper Fig. 9) in jax, calling the L1
Pallas kernels.

Topology (Mozilla DeepSpeech v0.9, §4.6): three batch-16 FullyConnected
layers → one LSTM (hidden 2048) unrolled to 16 single-batch steps → two
more FC layers → logits.  Only the LSTM steps are single-batch and hence
GEMV-bound; the paper applies FullPack there and keeps the Ruy-like W8A8
path for the batch-16 GEMMs — we mirror that split exactly.

Quantization model (TFLite-hybrid-like): symmetric per-tensor scales;
integer GEMV/GEMM accumulators in int32, dequantized with ``sw * sa``;
f32 nonlinearities; activations requantized (and, for sub-byte variants,
re-packed *in-graph*) before the next integer op.  Accuracy of the
quantized network is out of scope (paper cites LSQ etc.); bit-exactness
of the integer kernels is what we verify.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .kernels import fullpack_gemv as fg
from .kernels import pack as packmod
from .kernels import ref as refmod
from .kernels.pack import VL


@dataclasses.dataclass(frozen=True)
class DeepSpeechConfig:
    """Shape configuration for the DeepSpeech-like network."""
    n_input: int = 494        # 26 MFCC x 19 context windows
    n_hidden: int = 2048
    n_output: int = 32        # 29 characters, padded to a lane multiple
    time_steps: int = 16      # LSTM unroll length (= paper's batch 16)
    fc_batch: int = 16

    @property
    def gate_dim(self) -> int:
        return 4 * self.n_hidden


#: full-size config used for artifacts; tiny config for fast tests.
FULL = DeepSpeechConfig()
# n_hidden must be a multiple of the largest group size (128 for 1-bit).
TINY = DeepSpeechConfig(n_input=64, n_hidden=128, n_output=32, time_steps=4,
                        fc_batch=4)


# --------------------------------------------------------------------------
# jnp packing (in-graph re-pack of sub-byte activations between LSTM steps)
# --------------------------------------------------------------------------

def pack_jnp(x_i8: jax.Array, bits: int) -> jax.Array:
    """jnp twin of ``pack.pack`` — last axis must already be a multiple of
    the group size G = (8/bits)*VL.  Returns uint8."""
    e = packmod.elems_per_byte(bits)
    g = e * VL
    *lead, n = x_i8.shape
    assert n % g == 0, f"pack_jnp needs n % {g} == 0, got {n}"
    xu = lax.bitcast_convert_type(x_i8, jnp.uint8)
    xg = xu.reshape(*lead, n // g, e, VL)
    mask = jnp.uint8((1 << bits) - 1)
    out = jnp.zeros((*lead, n // g, VL), jnp.uint8)
    for k in range(e):
        out = out | lax.shift_left(xg[..., k, :] & mask, jnp.uint8(k * bits))
    return out.reshape(*lead, n // e)


def quantize_jnp(x: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Symmetric quantization to signed b-bit stored in int8."""
    lo, hi = packmod.value_range(bits)
    q = jnp.clip(jnp.round(x / scale), lo, hi)
    return q.astype(jnp.int8)


def quantize_pack_jnp(x: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Quantize then (for sub-byte) pack — the per-step activation path."""
    q = quantize_jnp(x, scale, bits)
    return q if bits == 8 else pack_jnp(q, bits)


# --------------------------------------------------------------------------
# Layers
# --------------------------------------------------------------------------

def fc_w8a8(x_q: jax.Array, w_q: jax.Array, bias: jax.Array,
            s_in: jax.Array, s_w: jax.Array) -> jax.Array:
    """Batch GEMM FC, Ruy-like W8A8 path (paper keeps this for batch-16
    layers).  ``x_q``: (B, K) int8, ``w_q``: (Z, K) int8 → (B, Z) f32."""
    acc = jnp.dot(x_q.astype(jnp.int32), w_q.T.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (s_in * s_w) + bias


def relu6(x: jax.Array) -> jax.Array:
    """DeepSpeech uses clipped ReLU (min(relu(x), 20)); we keep the clip."""
    return jnp.clip(x, 0.0, 20.0)


def lstm_step(variant: str,
              wx_p: jax.Array, wh_p: jax.Array, bias: jax.Array,
              x_p: jax.Array, h_p: jax.Array, c: jax.Array,
              s_x: jax.Array, s_h: jax.Array, s_w: jax.Array,
              row_tile: int = fg.ROW_TILE,
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One single-batch LSTM step with FullPack GEMV gates (the paper's
    GEMV hot spot).

    ``wx_p``/``wh_p``: packed (4H, ·) gate weights; ``x_p``/``h_p``: packed
    activations per the variant; ``c``: (H,) f32 cell state.
    Returns ``(h_packed_next, c_next, h_f32)``.
    """
    wbits, abits = (32, 32) if variant == "f32" else refmod.parse_variant(variant)
    if variant == "f32":
        gates = wx_p @ x_p + wh_p @ h_p + bias
    else:
        if wbits == 8 and abits == 8:
            acc_x = fg.gemv_w8a8(wx_p, x_p, row_tile=row_tile)
            acc_h = fg.gemv_w8a8(wh_p, h_p, row_tile=row_tile)
        else:
            acc_x = fg.gemv(wx_p, x_p, variant, row_tile=row_tile)
            acc_h = fg.gemv(wh_p, h_p, variant, row_tile=row_tile)
        gates = (acc_x.astype(jnp.float32) * (s_w * s_x)
                 + acc_h.astype(jnp.float32) * (s_w * s_h) + bias)

    hdim = c.shape[0]
    i = jax.nn.sigmoid(gates[0 * hdim:1 * hdim])
    f = jax.nn.sigmoid(gates[1 * hdim:2 * hdim])
    g = jnp.tanh(gates[2 * hdim:3 * hdim])
    o = jax.nn.sigmoid(gates[3 * hdim:4 * hdim])
    c_next = f * c + i * g
    h_f32 = o * jnp.tanh(c_next)
    if variant == "f32":
        return h_f32, c_next, h_f32
    h_next_p = quantize_pack_jnp(h_f32, s_h, abits)
    return h_next_p, c_next, h_f32


# --------------------------------------------------------------------------
# Parameters (synthetic weights; packed per variant)
# --------------------------------------------------------------------------

def _qweights(rng: np.random.Generator, z: int, k: int, bits: int) -> np.ndarray:
    lo, hi = packmod.value_range(bits)
    return rng.integers(lo, hi + 1, size=(z, k), dtype=np.int64).astype(np.int8)


def make_params(cfg: DeepSpeechConfig, variant: str, seed: int = 0) -> dict[str, Any]:
    """Synthetic DeepSpeech parameters.

    FC layers are always W8A8 (paper §4.6: Ruy for GEMM); the LSTM gate
    weights follow ``variant`` and are FullPack-packed offline (weights
    are packed at model-load time, activations per step in-graph).
    """
    rng = np.random.default_rng(seed)
    wbits, abits = (32, 32) if variant == "f32" else refmod.parse_variant(variant)
    H, X = cfg.n_hidden, cfg.n_input
    p: dict[str, Any] = {"variant": variant, "config": cfg}

    def fc(name, z, k):
        p[f"{name}_w"] = _qweights(rng, z, k, 8)
        p[f"{name}_b"] = (rng.normal(size=(z,)) * 0.02).astype(np.float32)
        p[f"{name}_sw"] = np.float32(0.02)

    fc("fc1", H, X)
    fc("fc2", H, H)
    fc("fc3", H, H)
    fc("fc5", H, H)
    fc("fc6", cfg.n_output, H)

    if variant == "f32":
        p["lstm_wx"] = (rng.normal(size=(4 * H, H)) * 0.02).astype(np.float32)
        p["lstm_wh"] = (rng.normal(size=(4 * H, H)) * 0.02).astype(np.float32)
    else:
        wx = _qweights(rng, 4 * H, H, wbits)
        wh = _qweights(rng, 4 * H, H, wbits)
        p["lstm_wx_q"], p["lstm_wh_q"] = wx, wh  # unpacked (oracle inputs)
        if wbits == 8:
            p["lstm_wx"], p["lstm_wh"] = wx, wh
        else:
            p["lstm_wx"] = packmod.pack(wx, wbits)
            p["lstm_wh"] = packmod.pack(wh, wbits)
    p["lstm_b"] = np.concatenate([
        np.zeros(H, np.float32),                      # i
        np.ones(H, np.float32),                       # f (forget-gate bias 1)
        np.zeros(H, np.float32),                      # g
        np.zeros(H, np.float32),                      # o
    ])
    # scales chosen so int accumulators stay well inside int32
    p["s_x"] = np.float32(0.05)
    p["s_h"] = np.float32(1.0 / 127 if abits == 8 else
                          1.0 / (2 ** (abits - 1) - 1) if abits > 1 else 1.0)
    p["s_w"] = np.float32(0.02)
    return p


# --------------------------------------------------------------------------
# Full forward (Fig. 9)
# --------------------------------------------------------------------------

def deepspeech_forward(params: dict[str, Any], x: jax.Array,
                       row_tile: int = fg.ROW_TILE) -> jax.Array:
    """Full DeepSpeech-like forward: (T, n_input) f32 → (T, n_output) f32.

    The T frames run the FC front-end as one batch-T W8A8 GEMM; the LSTM
    scans over the T frames one step at a time (single-batch GEMVs —
    exactly the split in paper Fig. 10).
    """
    cfg: DeepSpeechConfig = params["config"]
    variant: str = params["variant"]
    H = cfg.n_hidden
    s_act = jnp.float32(0.05)

    def fcq(name, h_f32, s_in):
        xq = quantize_jnp(h_f32, s_in, 8)
        return fc_w8a8(xq, jnp.asarray(params[f"{name}_w"]),
                       jnp.asarray(params[f"{name}_b"]),
                       s_in, jnp.asarray(params[f"{name}_sw"]))

    h = relu6(fcq("fc1", x, s_act))
    h = relu6(fcq("fc2", h, s_act))
    h = relu6(fcq("fc3", h, s_act))          # (T, H) f32

    if variant == "f32":
        def step(carry, x_t):
            hs, cs = carry
            h_next, c_next, h_f = lstm_step(
                "f32", jnp.asarray(params["lstm_wx"]), jnp.asarray(params["lstm_wh"]),
                jnp.asarray(params["lstm_b"]), x_t, hs, cs,
                jnp.float32(1), jnp.float32(1), jnp.float32(1), row_tile)
            return (h_next, c_next), h_f
        init_h = jnp.zeros((H,), jnp.float32)
    else:
        wbits, abits = refmod.parse_variant(variant)
        s_x, s_h, s_w = (jnp.asarray(params[k]) for k in ("s_x", "s_h", "s_w"))

        def step(carry, x_t):
            hs_p, cs = carry
            x_p = quantize_pack_jnp(x_t, s_x, abits)
            h_next_p, c_next, h_f = lstm_step(
                variant, jnp.asarray(params["lstm_wx"]), jnp.asarray(params["lstm_wh"]),
                jnp.asarray(params["lstm_b"]), x_p, hs_p, cs,
                s_x, s_h, s_w, row_tile)
            return (h_next_p, c_next), h_f
        if abits == 8:
            init_h = jnp.zeros((H,), jnp.int8)
        else:
            init_h = jnp.zeros((H // packmod.elems_per_byte(abits),), jnp.uint8)

    init_c = jnp.zeros((H,), jnp.float32)
    (_, _), hs = lax.scan(step, (init_h, init_c), h)   # (T, H) f32

    h = relu6(fcq("fc5", hs, s_act))
    logits = fcq("fc6", h, s_act)
    return logits


def deepspeech_forward_jit(params: dict[str, Any], row_tile: int = fg.ROW_TILE):
    """jit-wrapped forward with params closed over (weights become
    constants — the AOT path instead passes weights as arguments, see
    ``aot.py``)."""
    return jax.jit(functools.partial(deepspeech_forward, params,
                                     row_tile=row_tile))

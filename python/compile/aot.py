"""AOT compile path: lower the L1/L2 jax functions to HLO *text* artifacts
consumed by the Rust runtime (``rust/src/runtime``).

HLO text — NOT serialized ``HloModuleProto`` — is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Outputs (under ``artifacts/``):
  * ``<name>.hlo.txt``   one per lowered function
  * ``manifest.json``    machine-readable index: per artifact the input
                         names/dtypes/shapes, output shapes, kind,
                         variant and shape metadata.  The Rust
                         ``ArtifactRegistry`` loads this.

Python runs ONCE (`make artifacts`); the rust binary is self-contained
afterwards.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import fullpack_gemv as fg
from .kernels import pack as packmod
from .kernels import ref as refmod

_DTYPE_NAMES = {
    np.dtype(np.int8): "s8", np.dtype(np.uint8): "u8",
    np.dtype(np.int32): "s32", np.dtype(np.float32): "f32",
}


def to_hlo_text(lowered) -> str:
    """jax Lowered → XLA HLO text via stablehlo (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _iospec(tree):
    out = []
    for leaf in jax.tree_util.tree_leaves(tree):
        out.append({"dtype": _DTYPE_NAMES[np.dtype(leaf.dtype)],
                    "shape": list(leaf.shape)})
    return out


class Emitter:
    def __init__(self, outdir: str):
        self.outdir = outdir
        self.manifest: list[dict] = []
        os.makedirs(outdir, exist_ok=True)

    def emit(self, name: str, fn, example_args: tuple, *, kind: str,
             variant: str, meta: dict, arg_names: list[str]) -> None:
        lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.outdir, fname), "w") as f:
            f.write(text)
        out_spec = jax.eval_shape(fn, *example_args)
        entry = {
            "name": name, "file": fname, "kind": kind, "variant": variant,
            "meta": meta,
            "inputs": [dict(n, name=an) for an, n in
                       zip(arg_names, _iospec(example_args))],
            "outputs": _iospec(out_spec),
        }
        self.manifest.append(entry)
        print(f"  wrote {fname}  ({len(text)} chars, "
              f"{len(entry['inputs'])} inputs)")

    def finish(self):
        path = os.path.join(self.outdir, "manifest.json")
        with open(path, "w") as f:
            json.dump({"version": 1, "vl": packmod.VL,
                       "artifacts": self.manifest}, f, indent=1)
        print(f"manifest: {path} ({len(self.manifest)} artifacts)")


# --------------------------------------------------------------------------
# GEMV artifacts
# --------------------------------------------------------------------------

def emit_gemv(em: Emitter, variant: str, z: int, k: int, row_tile: int):
    name = f"gemv_{variant}_{z}x{k}"
    if variant == "f32":
        fn = functools.partial(fg.gemv_f32, row_tile=row_tile)
        args = (_spec((z, k), jnp.float32), _spec((k,), jnp.float32))
    elif variant == "w8a8":
        fn = functools.partial(fg.gemv_w8a8, row_tile=row_tile)
        args = (_spec((z, k), jnp.int8), _spec((k,), jnp.int8))
    else:
        fn = functools.partial(fg.gemv, variant=variant, row_tile=row_tile)
        (wshape, ashape) = fg.packed_shapes(z, k, variant)
        wbits, abits = refmod.parse_variant(variant)
        wdt = jnp.int8 if wbits == 8 else jnp.uint8
        adt = jnp.int8 if abits == 8 else jnp.uint8
        args = (_spec(wshape, wdt), _spec(ashape, adt))
    em.emit(name, fn, args, kind="gemv", variant=variant,
            meta={"z": z, "k": k, "row_tile": row_tile},
            arg_names=["weights", "activations"])


# --------------------------------------------------------------------------
# LSTM step artifacts
# --------------------------------------------------------------------------

def _lstm_arg_specs(variant: str, hidden: int):
    h4 = 4 * hidden
    if variant == "f32":
        wx = _spec((h4, hidden), jnp.float32)
        x = _spec((hidden,), jnp.float32)
        h = _spec((hidden,), jnp.float32)
        return wx, wx, x, h
    wbits, abits = refmod.parse_variant(variant)
    if wbits == 8:
        wx = _spec((h4, hidden), jnp.int8)
    else:
        wx = _spec((h4, hidden // packmod.elems_per_byte(wbits)), jnp.uint8)
    if abits == 8:
        x = _spec((hidden,), jnp.int8)
    else:
        x = _spec((hidden // packmod.elems_per_byte(abits),), jnp.uint8)
    return wx, wx, x, x


def emit_lstm_step(em: Emitter, variant: str, hidden: int, row_tile: int,
                   tag: str):
    name = f"lstm_step_{variant}_{tag}"
    wx, wh, x, h = _lstm_arg_specs(variant, hidden)
    bias = _spec((4 * hidden,), jnp.float32)
    c = _spec((hidden,), jnp.float32)
    s = _spec((), jnp.float32)
    fn = functools.partial(M.lstm_step, variant, row_tile=row_tile)
    em.emit(name, fn, (wx, wh, bias, x, h, c, s, s, s),
            kind="lstm_step", variant=variant,
            meta={"hidden": hidden, "row_tile": row_tile},
            arg_names=["wx", "wh", "bias", "x", "h", "c", "s_x", "s_h", "s_w"])


# --------------------------------------------------------------------------
# Dense (batch GEMM) artifact — the Ruy-like W8A8 path for FC layers
# --------------------------------------------------------------------------

def emit_fc_w8a8(em: Emitter, batch: int, z: int, k: int):
    name = f"fc_w8a8_b{batch}_{z}x{k}"
    args = (_spec((batch, k), jnp.int8), _spec((z, k), jnp.int8),
            _spec((z,), jnp.float32), _spec((), jnp.float32),
            _spec((), jnp.float32))
    em.emit(name, M.fc_w8a8, args, kind="fc_w8a8", variant="w8a8",
            meta={"batch": batch, "z": z, "k": k},
            arg_names=["x", "weights", "bias", "s_in", "s_w"])


# --------------------------------------------------------------------------
# Tiny end-to-end forward (weights baked as constants) — integration check
# --------------------------------------------------------------------------

def emit_deepspeech_tiny(em: Emitter, variant: str):
    cfg = M.TINY
    params = M.make_params(cfg, variant, seed=7)
    fn = functools.partial(M.deepspeech_forward, params, row_tile=8)
    args = (_spec((cfg.time_steps, cfg.n_input), jnp.float32),)
    em.emit(f"deepspeech_tiny_{variant}", fn, args,
            kind="deepspeech", variant=variant,
            meta={"time_steps": cfg.time_steps, "n_input": cfg.n_input,
                  "n_hidden": cfg.n_hidden, "n_output": cfg.n_output,
                  "seed": 7},
            arg_names=["frames"])


# --------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory (default: ../artifacts)")
    ap.add_argument("--full", action="store_true",
                    help="also emit full-size (2048) DeepSpeech LSTM artifacts")
    args = ap.parse_args()
    em = Emitter(args.out)

    print("[1/4] GEMV kernels @ 256x256 (all variants)")
    for variant in refmod.VARIANTS + refmod.BASELINES:
        emit_gemv(em, variant, 256, 256, row_tile=8)

    print("[2/4] GEMV kernels @ 2048x2048 (perf-representative subset)")
    for variant in ("w4a8", "w4a4", "w2a2", "w1a1", "w8a8", "f32"):
        emit_gemv(em, variant, 2048, 2048, row_tile=64)

    print("[3/4] LSTM steps (tiny for integration; full with --full)")
    for variant in refmod.VARIANTS + refmod.BASELINES:
        emit_lstm_step(em, variant, M.TINY.n_hidden, row_tile=8, tag="tiny")
    if args.full:
        for variant in ("w4a8", "w4a4", "w2a2", "w1a1", "w8a8", "f32"):
            emit_lstm_step(em, variant, M.FULL.n_hidden, row_tile=64,
                           tag="full")
        emit_fc_w8a8(em, M.FULL.fc_batch, M.FULL.n_hidden, M.FULL.n_hidden)

    print("[4/4] tiny DeepSpeech end-to-end forwards")
    emit_fc_w8a8(em, M.TINY.fc_batch, M.TINY.n_hidden, M.TINY.n_hidden)
    for variant in ("w4a8", "w2a2", "w1a1", "w8a8", "f32"):
        emit_deepspeech_tiny(em, variant)

    em.finish()


if __name__ == "__main__":
    main()

//! End-to-end driver (DESIGN.md deliverable): serve batched requests of
//! the full-size DeepSpeech-like model (paper Fig. 9) through the
//! serving engine for every FullPack bit-width and the W8A8 baseline,
//! reporting per-layer breakdown (Fig. 10), end-to-end speedup (§4.6),
//! and serving latency/throughput.  Results are recorded in
//! EXPERIMENTS.md §E2E.
//!
//! ```sh
//! cargo run --release --example deepspeech_e2e            # full size
//! cargo run --release --example deepspeech_e2e -- --tiny  # CI-sized
//! ```

use fullpack::coordinator::{Engine, EngineConfig, RouterConfig, SchedulerConfig, SubmitError};
use fullpack::models::{DeepSpeech, DeepSpeechConfig};
use fullpack::pack::Variant;
use fullpack::util::error::{anyhow, Result};
use std::collections::BTreeMap;

fn main() -> Result<()> {
    let tiny = std::env::args().any(|a| a == "--tiny");
    let cfg = if tiny { DeepSpeechConfig::TINY } else { DeepSpeechConfig::FULL };
    let requests = if tiny { 8 } else { 12 };
    println!(
        "DeepSpeech end-to-end: input={} hidden={} T={} | {} requests per variant\n",
        cfg.n_input, cfg.n_hidden, cfg.time_steps, requests
    );

    let frames: Vec<f32> =
        (0..cfg.time_steps * cfg.n_input).map(|i| (i as f32 * 0.01).sin()).collect();
    let variants = ["w8a8", "w4a8", "w4a4", "w2a2", "w1a1"];
    let mut totals: BTreeMap<&str, f64> = BTreeMap::new();
    let mut layer_tables: Vec<(String, Vec<(String, f64)>)> = Vec::new();

    for v in variants {
        let variant = Variant::parse(v)?;
        let engine = Engine::new(EngineConfig {
            workers: 2,
            sched: SchedulerConfig::default(),
            router: RouterConfig::default(),
        });
        engine.register_model("deepspeech", DeepSpeech::new(cfg, variant, 7));

        // warm-up (cache + branch predictors), then measured burst
        engine.infer("deepspeech", frames.clone())?;
        let rxs: Vec<_> = (0..requests)
            .map(|_| engine.try_submit("deepspeech", frames.clone()))
            .collect::<std::result::Result<_, SubmitError>>()?;
        let mut layer_ns: BTreeMap<String, f64> = BTreeMap::new();
        let mut best_total = f64::INFINITY;
        for rx in rxs {
            let resp = rx.recv().map_err(|_| anyhow!("dropped"))??;
            let total: u128 = resp.layer_times.iter().map(|(_, t)| t).sum();
            if (total as f64) < best_total {
                best_total = total as f64;
                layer_ns =
                    resp.layer_times.iter().map(|(n, t)| (n.clone(), *t as f64)).collect();
            }
        }
        println!(
            "{v:>5}: best {:.3} ms | engine {}",
            best_total / 1e6,
            engine.metrics().summary()
        );
        totals.insert(v, best_total);
        layer_tables.push((
            v.to_string(),
            ["fc1", "fc2", "fc3", "lstm", "fc5", "fc6"]
                .iter()
                .map(|&n| (n.to_string(), layer_ns.get(n).copied().unwrap_or(0.0)))
                .collect(),
        ));
        engine.shutdown();
    }

    println!("\nper-layer breakdown (ms) — measured Fig. 10:");
    print!("{:>6}", "layer");
    for (v, _) in &layer_tables {
        print!("{v:>10}");
    }
    println!();
    for i in 0..6 {
        let name = &layer_tables[0].1[i].0;
        print!("{name:>6}");
        for (_, layers) in &layer_tables {
            print!("{:>10.3}", layers[i].1 / 1e6);
        }
        println!();
    }

    let base = totals["w8a8"];
    println!("\nend-to-end speedup vs W8A8 baseline (paper §4.6: 1.56-2.11x):");
    for (v, t) in &totals {
        println!("  {v:>5}: {:.2}x", base / t);
    }
    let lstm_share = layer_tables
        .iter()
        .find(|(v, _)| v == "w8a8")
        .map(|(_, l)| {
            let total: f64 = l.iter().map(|(_, t)| t).sum();
            l.iter().find(|(n, _)| *n == "lstm").unwrap().1 / total
        })
        .unwrap();
    println!(
        "\nFig. 1 check — LSTM share of W8A8 runtime: {:.0}% (paper: >70%)",
        lstm_share * 100.0
    );
    Ok(())
}

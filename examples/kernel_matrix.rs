//! Kernel matrix: run all nine FullPack GEMV variants (§3.2) plus the
//! baselines on one layer shape — measured wall clock, correctness
//! cross-checked against the scalar oracle, footprint reported.
//!
//! ```sh
//! cargo run --release --example kernel_matrix           # 2048x2048
//! cargo run --release --example kernel_matrix -- 512 1024
//! ```

use fullpack::figures::ondevice::measure_method;
use fullpack::kernels::{self, ActVec};
use fullpack::models::FcShape;
use fullpack::pack::{pack, PackedMatrix, Variant};
use fullpack::util::bench::Table;

fn vals(bits: fullpack::pack::BitWidth, n: usize, seed: u64) -> Vec<i8> {
    let (lo, hi) = bits.value_range();
    let span = (hi as i16 - lo as i16 + 1) as u64;
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    (0..n)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (lo as i16 + (s % span) as i16) as i8
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let args: Vec<usize> =
        std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let z = args.first().copied().unwrap_or(2048);
    let k = args.get(1).copied().unwrap_or(2048);
    println!("kernel matrix on a {z}x{k} layer (median of repeated runs)\n");

    let mut t = Table::new(vec!["kernel", "us/call", "GB/s (wts)", "footprint", "exact"]);
    let fc = FcShape { name: "custom", z, k };

    // baseline first
    let base = measure_method(&fc, "ruy-w8a8", 3, 40);
    t.row(vec![
        "ruy-w8a8 (baseline)".to_string(),
        format!("{:.1}", base.micros()),
        format!("{:.2}", (z * k) as f64 / base.median_ns),
        format!("{:.2} MB", (z * k) as f64 / 1e6),
        "-".into(),
    ]);

    for v in Variant::PAPER_VARIANTS {
        // correctness: native kernel vs oracle on this exact shape
        let kp = v.padded_depth(k);
        let mut w = vals(v.w, z * k, 3);
        let mut padded = vec![0i8; z * kp];
        for r in 0..z {
            padded[r * kp..r * kp + k].copy_from_slice(&w[r * k..(r + 1) * k]);
        }
        w = padded;
        let mut a = vals(v.a, k, 4);
        a.resize(kp, 0);
        let wp = PackedMatrix::from_i8(&w, z, kp, v.w)?;
        let ap = v.a.is_sub_byte().then(|| pack(&a, v.a).unwrap());
        let mut out = vec![0i32; z];
        let act = match &ap {
            Some(bytes) => ActVec::Packed { bytes, bits: v.a },
            None => ActVec::I8(&a),
        };
        kernels::gemv(&wp, act, &mut out)?;
        let exact = (0..z).all(|r| {
            let oracle: i32 =
                w[r * kp..(r + 1) * kp].iter().zip(&a).map(|(&x, &y)| x as i32 * y as i32).sum();
            oracle == out[r]
        });

        let m = measure_method(&fc, &v.name(), 3, 40);
        t.row(vec![
            format!("fullpack-{}", v.name()),
            format!("{:.1}", m.micros()),
            format!("{:.2}", wp.footprint() as f64 / m.median_ns),
            format!("{:.2} MB", wp.footprint() as f64 / 1e6),
            if exact { "yes".into() } else { "NO".to_string() },
        ]);
        assert!(exact, "kernel {} diverged from oracle", v);
    }

    for m in ["xnn-w8a8", "tflite-w8a8", "ruy-f32", "ulppack-w2a2", "ulppack-w1a1"] {
        let r = measure_method(&fc, m, 3, 40);
        let bytes = match m {
            "ruy-f32" => 4 * z * k,
            _ => z * k,
        };
        t.row(vec![
            m.to_string(),
            format!("{:.1}", r.micros()),
            format!("{:.2}", bytes as f64 / r.median_ns),
            format!("{:.2} MB", bytes as f64 / 1e6),
            "-".into(),
        ]);
    }
    t.print();
    println!("\nspeedups vs ruy-w8a8:");
    for v in ["w4a8", "w4a4", "w2a2", "w1a1"] {
        let m = measure_method(&fc, v, 3, 40);
        println!("  {v}: {:.2}x", base.median_ns / m.median_ns);
    }
    Ok(())
}

//! Kernel matrix: run every registered GEMV backend on one layer shape —
//! measured wall clock, correctness cross-checked against the scalar
//! oracle, footprint reported.  Fully registry-driven: add a backend to
//! `kernels::KernelRegistry` and it appears here with no edits.
//!
//! ```sh
//! cargo run --release --example kernel_matrix           # 2048x2048
//! cargo run --release --example kernel_matrix -- 512 1024
//! ```

use fullpack::figures::ondevice::measure_method;
use fullpack::kernels::testutil::{oracle_gemv, pad_rows, rngvals};
use fullpack::kernels::{GemvKernel, KernelRegistry, LayerShape, PlanBuilder, SelectPolicy};
use fullpack::models::FcShape;
use fullpack::util::bench::Table;

fn main() -> fullpack::util::error::Result<()> {
    let args: Vec<usize> =
        std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let z = args.first().copied().unwrap_or(2048);
    let k = args.get(1).copied().unwrap_or(2048);
    println!("kernel matrix on a {z}x{k} layer (median of repeated runs)\n");

    let reg = KernelRegistry::global();
    let fc = FcShape { name: "custom", z, k };
    let base = measure_method(&fc, "ruy-w8a8", 3, 40);

    let mut t = Table::new(vec!["kernel", "us/call", "wt GB/s", "footprint", "exact", "vs ruy"]);
    for kernel in reg.iter() {
        let name = kernel.name();
        let method = kernel.cost_method().expect("builtin kernels are modeled");
        let variant = method.data_variant();

        // correctness on this exact shape: plan-driven run vs oracle
        let plan = PlanBuilder::new(LayerShape { z, k, batch: 1 }, variant)
            .policy(SelectPolicy::Explicit(name.to_string()))
            .build()?;
        let w = rngvals(variant.w, z * k, 3);
        let a = rngvals(variant.a, k, 4);
        let weights = plan.prepare_weights(&w)?;
        let mut out = vec![0i32; z];
        plan.execute(&weights, &a, &mut out)?;
        let kp = weights.k_padded();
        let wp = pad_rows(&w, z, k, kp);
        let mut ap = a.clone();
        ap.resize(kp, 0);
        let oracle = oracle_gemv(&wp, &ap, z, kp);
        // integer kernels are bit-exact; f32 stand-ins round once the
        // accumulator leaves f32's 2^24 exact-integer range
        let f32_kernel = name.ends_with("-f32");
        let exact = out == oracle;
        if f32_kernel {
            let max_rel = out
                .iter()
                .zip(&oracle)
                .map(|(&x, &y)| (x as f64 - y as f64).abs() / (y as f64).abs().max(1.0))
                .fold(0.0, f64::max);
            assert!(max_rel < 1e-4, "kernel {name} relative error {max_rel}");
        } else {
            assert!(exact, "kernel {name} diverged from oracle");
        }

        let m = if name == "ruy-w8a8" { base } else { measure_method(&fc, name, 3, 40) };
        t.row(vec![
            name.to_string(),
            format!("{:.1}", m.micros()),
            format!("{:.2}", weights.footprint() as f64 / m.median_ns),
            format!("{:.2} MB", weights.footprint() as f64 / 1e6),
            if f32_kernel { "~".into() } else if exact { "yes".into() } else { "NO".to_string() },
            format!("{:.2}x", base.median_ns / m.median_ns),
        ]);
    }
    t.print();

    println!("\nspeedups vs ruy-w8a8:");
    for v in ["fullpack-w4a8", "fullpack-w4a4", "fullpack-w2a2", "fullpack-w1a1"] {
        let m = measure_method(&fc, v, 3, 40);
        println!("  {v}: {:.2}x", base.median_ns / m.median_ns);
    }
    Ok(())
}

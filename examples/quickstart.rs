//! Quickstart: quantize an f32 weight matrix to 4 bits, build an
//! execution plan from the kernel registry, run a GEMV three ways —
//! plan-selected native kernel, scalar oracle, and (with `--features
//! pjrt`) the AOT-compiled Pallas kernel via PJRT — and check all agree.
//!
//! ```sh
//! cargo run --release --example quickstart
//! make artifacts && cargo run --release --features pjrt --example quickstart
//! ```

use fullpack::kernels::{LayerShape, PlanBuilder};
use fullpack::pack::{BitWidth, Variant};
use fullpack::quant::{quantize_per_row, requantize_vec};

fn main() -> fullpack::util::error::Result<()> {
    let variant = Variant::parse("w4a8")?;
    let (z, k) = (256usize, 256usize);

    // 1. a synthetic f32 layer, quantized per-row to 4-bit weights
    let w_f32: Vec<f32> = (0..z * k).map(|i| ((i as f32) * 0.37).sin() * 0.1).collect();
    let a_f32: Vec<f32> = (0..k).map(|i| ((i as f32) * 0.11).cos()).collect();
    let (w_q, w_scales) = quantize_per_row(&w_f32, z, k, BitWidth::B4);
    let a_q: Vec<i8> = a_f32.iter().map(|&v| (v * 127.0).round() as i8).collect();

    // 2. bind a plan: shape + variant -> kernel (paper rule picks the
    //    FullPack GEMV for a single-batch sub-byte layer), then pack
    //    the weights into that kernel's layout (Fig. 2 stride-16)
    let plan = PlanBuilder::new(LayerShape { z, k, batch: 1 }, variant).build()?;
    let weights = plan.prepare_weights(&w_q)?;
    println!(
        "plan selected {} -> {} | packed {}x{} 4-bit weights: {} bytes ({}x smaller than int8)",
        variant.name(),
        plan.kernel_name(),
        z,
        k,
        weights.footprint(),
        z * k / weights.footprint()
    );

    // 3. plan-driven GEMV
    let mut acc = vec![0i32; z];
    plan.execute(&weights, &a_q, &mut acc)?;

    // 4. scalar oracle (unpack + plain dot)
    let w_back = weights.as_packed().expect("fullpack layout").unpack_all();
    let oracle: Vec<i32> = (0..z)
        .map(|r| {
            w_back[r * k..(r + 1) * k]
                .iter()
                .zip(&a_q)
                .map(|(&w, &a)| w as i32 * a as i32)
                .sum()
        })
        .collect();
    assert_eq!(acc, oracle, "native kernel == scalar oracle");
    println!("native kernel matches the scalar oracle ({} outputs)", z);

    // 5. same computation through the AOT Pallas kernel (PJRT)
    #[cfg(feature = "pjrt")]
    {
        use fullpack::runtime::{Runtime, Tensor};
        match Runtime::load("artifacts") {
            Ok(rt) => {
                let wp = weights.as_packed().expect("fullpack layout");
                let name = format!("gemv_{}_256x256", variant.name());
                let out = rt.execute(
                    &name,
                    &[
                        Tensor::u8(wp.bytes().to_vec(), vec![z, wp.bytes_per_row()]),
                        Tensor::s8(a_q.clone(), vec![k]),
                    ],
                )?;
                assert_eq!(out[0].as_s32()?, acc.as_slice(), "PJRT == native");
                println!("AOT Pallas kernel (PJRT) matches the native kernel bit-for-bit");
            }
            Err(e) => println!("skipping PJRT check (run `make artifacts`): {e}"),
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("PJRT check skipped (rebuild with --features pjrt)");

    // 6. requantize the accumulators back to f32
    let bias = vec![0.0f32; z];
    let y: Vec<f32> = requantize_vec(&acc, 1.0 / 127.0, 1.0, &bias)
        .iter()
        .zip(&w_scales)
        .map(|(v, s)| v * s)
        .collect();
    let y_ref: Vec<f32> = (0..z)
        .map(|r| w_f32[r * k..(r + 1) * k].iter().zip(&a_f32).map(|(w, a)| w * a).sum())
        .collect();
    let max_err = y.iter().zip(&y_ref).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    println!("quantized vs f32 reference: max |err| = {max_err:.4} (4-bit weights)");
    assert!(max_err < 0.5);
    println!("quickstart OK");
    Ok(())
}

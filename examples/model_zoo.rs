//! Model-zoo serving demo (DESIGN.md §10): one engine serving three
//! different topologies — DeepSpeech, the sub-byte MLP classifier and
//! the GRU keyword spotter — compiled from their `ModelGraph`s and
//! addressed by name, with per-model dispatch/latency metrics.
//!
//! ```sh
//! cargo run --release --example model_zoo            # full-size graphs
//! cargo run --release --example model_zoo -- --tiny  # CI-sized
//! ```

use fullpack::coordinator::{Engine, EngineConfig, RouterConfig, SchedulerConfig};
use fullpack::models::{CompiledModel, Model, ModelRegistry, ModelSize};
use fullpack::pack::Variant;
use fullpack::util::error::{anyhow, Result};

fn main() -> Result<()> {
    let tiny = std::env::args().any(|a| a == "--tiny");
    let size = if tiny { ModelSize::Tiny } else { ModelSize::Full };
    let requests_per_model = if tiny { 8 } else { 12 };
    let variant = Variant::parse("w4a8")?;

    let engine = Engine::new(EngineConfig {
        workers: 2,
        sched: SchedulerConfig::default(),
        router: RouterConfig::default(),
    });
    let zoo = ModelRegistry::global();
    for entry in zoo.iter() {
        let graph = (entry.build)(size, variant, 7);
        let model = CompiledModel::compile(graph).map_err(|e| anyhow!("{}: {e}", entry.name))?;
        println!(
            "registered {:<16} {} (cell kernel {})",
            entry.name,
            model.describe(),
            model.cell_kernel_name().unwrap_or("-")
        );
        engine.register_model(entry.name, model);
    }

    println!("\nserving {} requests per model...", requests_per_model);
    let mut rxs = Vec::new();
    for name in zoo.names() {
        let input_len = engine.model(name).expect("registered").input_len();
        let frames: Vec<f32> = (0..input_len).map(|i| (i as f32 * 0.01).sin()).collect();
        for _ in 0..requests_per_model {
            rxs.push(engine.try_submit(name, frames.clone())?);
        }
    }
    for rx in rxs {
        rx.recv().map_err(|_| anyhow!("engine dropped request"))??;
    }

    println!("\nengine:  {}", engine.metrics().summary());
    for (name, m) in engine.metrics().per_model_counters() {
        println!(
            "  {name:<16} batched={}/{} singleton={} mean={:.0}us",
            m.batched_requests,
            m.batched_dispatches,
            m.singleton_requests,
            m.mean_latency_us()
        );
    }
    let (gemv, gemm) = engine.router().counts();
    println!("router:  gemv(FullPack)={gemv} gemm(W8A8 tier)={gemm}");
    engine.shutdown();
    Ok(())
}

//! Cache explorer: interactively sweep the cache simulator (the gem5
//! stand-in) over layer sizes, methods and hierarchies — the tool behind
//! Figs. 6 and 7.  Shows where the "fits-in-LLC" boundary sits for each
//! bit-width and how it moves with LLC capacity.
//!
//! ```sh
//! cargo run --release --example cache_explorer
//! cargo run --release --example cache_explorer -- w2a2 l2-8m
//! ```

use fullpack::costmodel::{simulate_gemv, CoreModel, Method};
use fullpack::sim::CachePreset;
use fullpack::util::bench::Table;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let variant = args.first().map(String::as_str).unwrap_or("w4a8");
    let preset = args
        .get(1)
        .and_then(|s| CachePreset::parse(s))
        .unwrap_or(CachePreset::Gem5Ex5Big);
    let method = Method::fullpack(variant);
    let core = CoreModel::ex5_big();
    let sizes = [256, 512, 1024, 2048, 4096, 8192];

    println!("cache explorer: {} on {}\n", method.label(), preset.name());
    let mut t = Table::new(vec![
        "size (z=k)",
        "W bytes",
        "fits LLC?",
        "LLC miss% (ours)",
        "LLC miss% (ruy)",
        "speedup",
    ]);
    let llc_size = {
        let h = preset.build();
        h.level_config(h.depth() - 1).size
    };
    for s in sizes {
        let ours = simulate_gemv(method, s, s, preset, &core, 3);
        let base = simulate_gemv(Method::RuyW8A8, s, s, preset, &core, 3);
        let wbytes = s * method.weight_bytes_per_row(s);
        t.row(vec![
            format!("{s}x{s}"),
            format!("{:.1} MB", wbytes as f64 / 1e6),
            if wbytes <= llc_size { "yes".into() } else { "no".into() },
            format!("{:.1}", ours.llc.miss_rate() * 100.0),
            format!("{:.1}", base.llc.miss_rate() * 100.0),
            format!("{:.2}x", base.cycles / ours.cycles),
        ]);
    }
    t.print();
    println!(
        "\nThe speedup peaks where the packed matrix fits the {:.0} KB LLC\n\
         but the W8A8 one does not (paper §4.3.1); try other presets:\n\
         gem5 | gem5-l3 | l2-1m | l2-8m | l1-only | rpi4",
        llc_size as f64 / 1024.0
    );
}

//! # FullPack — full vector utilization for sub-byte quantized inference
//!
//! Rust + JAX + Pallas reproduction of *"FullPack: Full Vector
//! Utilization for Sub-Byte Quantized Inference on General Purpose
//! CPUs"* (Katebi, Asadi, Goudarzi; 2022).
//!
//! See `README.md` for the quickstart, `DESIGN.md` for the system
//! inventory (§3/§4 kernel API + registry, §8 the SWAR fast-path
//! tier); `EXPERIMENTS.md` logs paper-vs-measured results and the
//! `BENCH_kernels.json` perf trajectory.
//!
//! The `runtime` module's PJRT executor (AOT artifact execution) needs
//! the heavyweight `xla` bindings and is gated behind the `pjrt`
//! feature; its dependency-free parts — the artifact manifest parser
//! and the `ModelGraph`-from-manifest path — are always built.

pub mod cli;
pub mod coordinator;
pub mod costmodel;
pub mod figures;
pub mod kernels;
pub mod models;
pub mod pack;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;

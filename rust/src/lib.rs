//! # FullPack — full vector utilization for sub-byte quantized inference
//!
//! Rust + JAX + Pallas reproduction of *"FullPack: Full Vector
//! Utilization for Sub-Byte Quantized Inference on General Purpose
//! CPUs"* (Katebi, Asadi, Goudarzi; 2022).
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured experiment log.

pub mod cli;
pub mod coordinator;
pub mod figures;
pub mod costmodel;
pub mod kernels;
pub mod models;
pub mod pack;
pub mod quant;
pub mod runtime;
pub mod util;
pub mod sim;

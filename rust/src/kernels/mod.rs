//! Native GEMV/GEMM kernels — the measured hot path (DESIGN.md
//! substitution table: these are the Rust twins of the paper's ARMv8
//! NEON assembly kernels, written as 16-lane SWAR loops the compiler
//! auto-vectorizes; the layout, shift schedule and instruction mix match
//! the paper's kernels one-for-one).
//!
//! * [`fullpack`] — the nine paper variants (§3.2) over the dense layout;
//! * [`isa`]      — the real-ISA tier (DESIGN.md §15): AVX2/NEON
//!   intrinsics over the same packed layout, registered only when the
//!   host can execute them (`fullpack-*-avx2`/`-neon` entries);
//! * [`lut`]      — the table-driven LUT tier (DESIGN.md §13): same
//!   packed layout, gather-based row loops, `lut-*`/`lut-*-gemm` entries;
//! * [`baseline`] — Ruy/XNNPack/TFLite/GEMMLOWP-like i8 and f32 rivals;
//! * [`ulppack`]  — the ULPPACK spacer-lane comparator (Won et al. 2022);
//! * [`naive`]    — the Alg. 1 strawman over adjacent packing.
//!
//! Every implementation is reachable through the pluggable kernel API
//! (DESIGN.md §3): [`api::GemvKernel`] is the object-safe GEMV trait,
//! [`api::GemmKernel`] the batched-GEMM twin (DESIGN.md §9),
//! [`registry::KernelRegistry`] enumerates the built-in backends by
//! name in both namespaces, and [`plan::Plan`] binds a layer shape +
//! variant + thread budget to a selected kernel.  Call sites outside
//! this module select kernels by *name or policy*, never by concrete
//! function.

pub mod api;
pub mod baseline;
pub mod fullpack;
pub mod fullpack_gemm;
pub mod isa;
pub mod lut;
pub mod naive;
pub mod parallel;
pub mod plan;
pub mod registry;
pub mod swar;
pub mod testutil;
pub mod ulppack;

pub use api::{GemmKernel, GemvKernel, Weights};
pub use isa::{isa_kernel_name, IsaKernel, IsaKind, IsaSupport, ISA_VARIANTS};
pub use lut::{lut_gemm_kernel_name, lut_kernel_name, LutGemmKernel, LutKernel, LUT_VARIANTS};
pub use plan::{LayerShape, Plan, PlanBuilder, PlanScratch, SelectPolicy, Selection, GEMM_MIN_BATCH};
pub use registry::{
    fullpack_gemm_kernel_name, KernelRegistry, RowParallel, RowParallelGemm,
    FULLPACK_GEMM_VARIANTS,
};
pub use swar::{swar_kernel_name, SwarKernel, SWAR_MIN_DEPTH};

use crate::pack::{BitWidth, PackError, PackedMatrix, Variant};

#[derive(Debug)]
pub enum KernelError {
    Shape(String),
    Pack(PackError),
    Unsupported(String),
}

impl std::fmt::Display for KernelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelError::Shape(s) => write!(f, "operand shape mismatch: {s}"),
            KernelError::Pack(e) => write!(f, "{e}"),
            KernelError::Unsupported(v) => write!(f, "variant {v} not supported by this kernel"),
        }
    }
}

impl std::error::Error for KernelError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            KernelError::Pack(e) => Some(e),
            _ => None,
        }
    }
}

impl From<PackError> for KernelError {
    fn from(e: PackError) -> KernelError {
        KernelError::Pack(e)
    }
}

/// An activation vector for the FullPack GEMV dispatcher: plain int8 or
/// packed sub-byte bytes.
#[derive(Debug, Clone, Copy)]
pub enum ActVec<'a> {
    I8(&'a [i8]),
    Packed { bytes: &'a [u8], bits: BitWidth },
}

impl<'a> ActVec<'a> {
    /// Logical element count carried by this vector.
    pub fn elems(&self) -> usize {
        match self {
            ActVec::I8(v) => v.len(),
            ActVec::Packed { bytes, bits } => bytes.len() * bits.elems_per_byte(),
        }
    }

    pub fn bits(&self) -> BitWidth {
        match self {
            ActVec::I8(_) => BitWidth::B8,
            ActVec::Packed { bits, .. } => *bits,
        }
    }
}

/// Pack an int8 activation vector per `bits` (identity wrapper for B8).
pub fn pack_activations(a: &[i8], bits: BitWidth) -> Result<Vec<u8>, PackError> {
    debug_assert!(bits.is_sub_byte());
    crate::pack::pack(a, bits)
}

/// Dispatch a FullPack GEMV over any of the nine paper variants.
///
/// `out.len()` must equal `w.rows()`; the activation element count must
/// equal the weight matrix's padded depth (pad with zeros via
/// [`crate::pack::BitWidth::padded_len`] before packing).
pub fn gemv(w: &PackedMatrix, a: ActVec<'_>, out: &mut [i32]) -> Result<(), KernelError> {
    if out.len() != w.rows() {
        return Err(KernelError::Shape(format!(
            "out len {} != rows {}",
            out.len(),
            w.rows()
        )));
    }
    gemv_at(w, a, out, 0)
}

/// [`gemv`] over the row range `[row0, row0 + out.len())` of the weight
/// matrix — the zero-copy sharding entry used by [`parallel`].
pub fn gemv_at(
    w: &PackedMatrix,
    a: ActVec<'_>,
    out: &mut [i32],
    row0: usize,
) -> Result<(), KernelError> {
    if row0 + out.len() > w.rows() {
        return Err(KernelError::Shape(format!(
            "row range {row0}..{} exceeds rows {}",
            row0 + out.len(),
            w.rows()
        )));
    }
    let need = w.k_padded();
    let have = a.elems();
    if have < need {
        return Err(KernelError::Shape(format!(
            "activation elems {have} < padded depth {need}"
        )));
    }
    let variant = Variant::new(w.bits(), a.bits());
    match (w.bits(), a) {
        (BitWidth::B8, ActVec::I8(av)) => baseline::gemv_ruy_i8_at(w, av, out, row0),
        (BitWidth::B4, ActVec::I8(av)) => fullpack::gemv_wsub_a8_at::<4>(w, av, out, row0),
        (BitWidth::B2, ActVec::I8(av)) => fullpack::gemv_wsub_a8_at::<2>(w, av, out, row0),
        (BitWidth::B1, ActVec::I8(av)) => fullpack::gemv_wsub_a8_at::<1>(w, av, out, row0),
        (BitWidth::B8, ActVec::Packed { bytes, bits }) => match bits {
            BitWidth::B4 => fullpack::gemv_w8_asub_at::<4>(w, bytes, out, row0),
            BitWidth::B2 => fullpack::gemv_w8_asub_at::<2>(w, bytes, out, row0),
            BitWidth::B1 => fullpack::gemv_w8_asub_at::<1>(w, bytes, out, row0),
            BitWidth::B8 => unreachable!("B8 activations are ActVec::I8"),
        },
        (wb, ActVec::Packed { bytes, bits }) if wb == bits => match bits {
            BitWidth::B4 => fullpack::gemv_wsub_asub_at::<4>(w, bytes, out, row0),
            BitWidth::B2 => fullpack::gemv_wsub_asub_at::<2>(w, bytes, out, row0),
            BitWidth::B1 => fullpack::gemv_wsub_asub_at::<1>(w, bytes, out, row0),
            BitWidth::B8 => unreachable!(),
        },
        _ => return Err(KernelError::Unsupported(variant.name())),
    }
    Ok(())
}

/// GEMM (batch > 1) as repeated GEMV — the paper provides GEMV kernels
/// only and routes GEMM to Ruy; this wrapper exists for completeness and
/// for the router's fallback path.
pub fn gemm(
    w: &PackedMatrix,
    acts: &[ActVec<'_>],
    out: &mut [i32],
) -> Result<(), KernelError> {
    let z = w.rows();
    if out.len() != z * acts.len() {
        return Err(KernelError::Shape(format!(
            "out len {} != rows*batch {}",
            out.len(),
            z * acts.len()
        )));
    }
    for (b, a) in acts.iter().enumerate() {
        gemv(w, *a, &mut out[b * z..(b + 1) * z])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;
    use crate::pack::{pack, PackedMatrix, Variant};

    fn run_variant(variant: Variant, z: usize, k: usize, seed: u64) {
        let kp = variant.padded_depth(k);
        let mut w = rngvals(variant.w, z * k, seed);
        let mut a = rngvals(variant.a, k, seed + 1);
        // zero-pad to the common padded depth
        let mut wfull = vec![0i8; z * kp];
        for r in 0..z {
            wfull[r * kp..r * kp + k].copy_from_slice(&w[r * k..(r + 1) * k]);
        }
        a.resize(kp, 0);
        w = wfull;

        let wp = PackedMatrix::from_i8(&w, z, kp, variant.w).unwrap();
        let packed_a;
        let act = if variant.a.is_sub_byte() {
            packed_a = pack(&a, variant.a).unwrap();
            ActVec::Packed { bytes: &packed_a, bits: variant.a }
        } else {
            ActVec::I8(&a)
        };
        let mut out = vec![0i32; z];
        gemv(&wp, act, &mut out).unwrap();
        assert_eq!(out, oracle_gemv(&w, &a, z, kp), "{variant} z={z} k={k}");
    }

    #[test]
    fn all_nine_variants_match_oracle() {
        for (i, v) in Variant::PAPER_VARIANTS.iter().enumerate() {
            run_variant(*v, 24, 160, 1000 + i as u64);
        }
    }

    #[test]
    fn w8a8_dispatch_matches_oracle() {
        run_variant(Variant::parse("w8a8").unwrap(), 16, 96, 77);
    }

    #[test]
    fn unaligned_depths() {
        for v in ["w4a8", "w2a2", "w1a1", "w8a4"] {
            let v = Variant::parse(v).unwrap();
            for k in [1usize, 17, 33, 127, 129] {
                run_variant(v, 8, k, k as u64);
            }
        }
    }

    #[test]
    fn shape_errors() {
        let w = PackedMatrix::from_i8(&[0i8; 64], 2, 32, BitWidth::B4).unwrap();
        let a = [0i8; 32];
        let mut bad_out = vec![0i32; 3];
        assert!(gemv(&w, ActVec::I8(&a), &mut bad_out).is_err());
        let short_a = [0i8; 16];
        let mut out = vec![0i32; 2];
        assert!(gemv(&w, ActVec::I8(&short_a), &mut out).is_err());
    }

    #[test]
    fn gemm_wrapper_matches_per_column() {
        let z = 8;
        let k = 64;
        let w = rngvals(BitWidth::B4, z * k, 5);
        let wp = PackedMatrix::from_i8(&w, z, k, BitWidth::B4).unwrap();
        let a0 = rngvals(BitWidth::B8, k, 6);
        let a1 = rngvals(BitWidth::B8, k, 7);
        let mut out = vec![0i32; 2 * z];
        gemm(&wp, &[ActVec::I8(&a0), ActVec::I8(&a1)], &mut out).unwrap();
        assert_eq!(&out[..z], oracle_gemv(&w, &a0, z, k).as_slice());
        assert_eq!(&out[z..], oracle_gemv(&w, &a1, z, k).as_slice());
    }
}

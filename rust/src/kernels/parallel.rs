//! Row-parallel GEMV: intra-op parallelism for the serving hot path.
//!
//! The DeepSpeech LSTM gate matrices are 4H×H (8192×2048 full size) —
//! large enough that a single core leaves most of the socket idle while
//! a request is being served.  `gemv_parallel` splits the output rows
//! across a scoped thread pool; each shard runs the same single-thread
//! FullPack kernel on a row-contiguous sub-matrix (the packed layout is
//! row-independent by construction, §3.1), so results are bit-identical
//! to the serial kernel.

use super::{ActVec, KernelError};

use crate::pack::PackedMatrix;

/// Minimum rows per shard — below this the spawn overhead dominates.
pub const MIN_ROWS_PER_SHARD: usize = 256;

/// Balanced row spans for `z` rows over `shards` workers: the first
/// `z % shards` spans take `z/shards + 1` rows, the rest `z/shards` —
/// every pair of spans differs by at most one row, so the slowest shard
/// carries at most one extra row of work.  (The old `div_ceil` split
/// gave every shard but the last the ceiling and starved the final
/// shard — e.g. 2050 rows over 8 threads ran 7×257 + 1×251, a built-in
/// straggler imbalance; see the pinned test.)  Exact cover, in order.
pub fn shard_spans(z: usize, shards: usize) -> Vec<(usize, usize)> {
    let shards = shards.max(1).min(z.max(1));
    let base = z / shards;
    let extra = z % shards;
    let mut spans = Vec::with_capacity(shards);
    let mut lo = 0;
    for s in 0..shards {
        let hi = lo + base + usize::from(s < extra);
        spans.push((lo, hi));
        lo = hi;
    }
    spans
}

/// Shard the rows `[row0, row0 + out.len())` across up to `threads`
/// scoped workers, calling `f(chunk, abs_row0)` per shard.  The generic
/// engine behind [`gemv_parallel`] and the kernel-API `RowParallel`
/// decorator: any row-independent GEMV backend can be sharded this way.
/// Spans come from [`shard_spans`], so shard sizes differ by ≤ 1 row.
pub fn shard_rows<F>(
    out: &mut [i32],
    row0: usize,
    threads: usize,
    f: F,
) -> Result<(), KernelError>
where
    F: Fn(&mut [i32], usize) -> Result<(), KernelError> + Sync,
{
    let z = out.len();
    // clamp the *quotient*, not the constant: small outputs collapse to
    // one shard instead of multiplying by a no-op `.max(1)`
    let shards = threads.min((z / MIN_ROWS_PER_SHARD).max(1));
    if shards <= 1 {
        return f(out, row0);
    }
    let results: Vec<Result<(), KernelError>> = std::thread::scope(|scope| {
        let spans = shard_spans(z, shards);
        let mut handles = Vec::with_capacity(spans.len());
        let mut rest = &mut *out;
        let f = &f;
        for (lo, hi) in spans {
            let (chunk, tail) = rest.split_at_mut(hi - lo);
            rest = tail;
            // zero-copy: each shard borrows the shared operands and runs
            // the serial kernel over its row range
            handles.push(scope.spawn(move || f(chunk, row0 + lo)));
        }
        handles.into_iter().map(|h| h.join().expect("shard panicked")).collect()
    });
    for r in results {
        r?;
    }
    Ok(())
}

/// Shard a **batched GEMM** by output row-tiles: `out` is the
/// batch-major `z × batch` result (`out[c*z + r]`), `f(tile, lo, hi)`
/// computes rows `[lo, hi)` of every column into a tile that is
/// batch-major *over the tile* (`tile[c*(hi-lo) + (r-lo)]` — the
/// `GemmKernel::gemm_at` contract).  Each shard owns a scratch tile;
/// the main thread scatters tiles into `out` after the join, so shard
/// writes never alias.  `threads = 1` (or few rows) calls `f` directly
/// on `out` — for the full matrix the two layouts coincide.
pub fn shard_gemm_rows<F>(
    out: &mut [i32],
    z: usize,
    batch: usize,
    threads: usize,
    f: F,
) -> Result<(), KernelError>
where
    F: Fn(&mut [i32], usize, usize) -> Result<(), KernelError> + Sync,
{
    if out.len() != z * batch {
        return Err(KernelError::Shape(format!(
            "out len {} != rows*batch {}",
            out.len(),
            z * batch
        )));
    }
    let shards = threads.min((z / MIN_ROWS_PER_SHARD).max(1));
    if shards <= 1 || batch == 0 {
        return f(out, 0, z);
    }
    let results: Vec<(usize, usize, Vec<i32>, Result<(), KernelError>)> =
        std::thread::scope(|scope| {
            let f = &f;
            let handles: Vec<_> = shard_spans(z, shards)
                .into_iter()
                .map(|(lo, hi)| {
                    scope.spawn(move || {
                        let mut tile = vec![0i32; (hi - lo) * batch];
                        let r = f(&mut tile, lo, hi);
                        (lo, hi, tile, r)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("shard panicked")).collect()
        });
    for (lo, hi, tile, r) in results {
        r?;
        let rt = hi - lo;
        for c in 0..batch {
            out[c * z + lo..c * z + hi].copy_from_slice(&tile[c * rt..(c + 1) * rt]);
        }
    }
    Ok(())
}

/// Row-sharded GEMV.  `threads = 1` (or small matrices) falls back to
/// the serial kernel.  Output is bit-identical to [`super::gemv`].
pub fn gemv_parallel(
    wp: &PackedMatrix,
    a: ActVec<'_>,
    out: &mut [i32],
    threads: usize,
) -> Result<(), KernelError> {
    let z = wp.rows();
    if out.len() != z {
        return Err(KernelError::Shape(format!("out len {} != rows {z}", out.len())));
    }
    shard_rows(out, 0, threads, |chunk, lo| super::gemv_at(wp, a, chunk, lo))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testutil::{oracle_gemv, rngvals};
    use crate::kernels::{gemv, pack_activations};
    use crate::pack::{BitWidth, Variant};

    #[test]
    fn parallel_equals_serial_all_variants() {
        for v in Variant::PAPER_VARIANTS {
            let z = 1024; // enough rows to actually shard
            let k = v.padded_depth(128);
            let w = rngvals(v.w, z * k, 91);
            let a = rngvals(v.a, k, 92);
            let wp = PackedMatrix::from_i8(&w, z, k, v.w).unwrap();
            let packed_a;
            let act = if v.a.is_sub_byte() {
                packed_a = pack_activations(&a, v.a).unwrap();
                ActVec::Packed { bytes: &packed_a, bits: v.a }
            } else {
                ActVec::I8(&a)
            };
            let mut serial = vec![0i32; z];
            gemv(&wp, act, &mut serial).unwrap();
            for threads in [1, 2, 3, 4] {
                let mut par = vec![0i32; z];
                gemv_parallel(&wp, act, &mut par, threads).unwrap();
                assert_eq!(par, serial, "{v} threads={threads}");
            }
            assert_eq!(serial, oracle_gemv(&w, &a, z, k), "{v}");
        }
    }

    #[test]
    fn small_matrix_falls_back_serial() {
        let w = rngvals(BitWidth::B4, 8 * 32, 1);
        let wp = PackedMatrix::from_i8(&w, 8, 32, BitWidth::B4).unwrap();
        let a = rngvals(BitWidth::B8, 32, 2);
        let mut out = vec![0i32; 8];
        gemv_parallel(&wp, ActVec::I8(&a), &mut out, 8).unwrap();
        assert_eq!(out, oracle_gemv(&w, &a, 8, 32));
    }

    #[test]
    fn shard_spans_balance_uneven_rows() {
        // pinned (load-imbalance fix): 2050 rows over 8 shards used to
        // split 7×257 + 1×251 under the div_ceil schedule — a built-in
        // straggler.  Balanced spans differ by at most one row.
        let spans = shard_spans(2050, 8);
        assert_eq!(spans.len(), 8);
        assert_eq!(spans.first().unwrap().0, 0);
        assert_eq!(spans.last().unwrap().1, 2050);
        let sizes: Vec<usize> = spans.iter().map(|(lo, hi)| hi - lo).collect();
        assert_eq!(sizes, vec![257, 257, 256, 256, 256, 256, 256, 256]);
        assert_eq!(sizes.iter().sum::<usize>(), 2050);
        // exact in-order cover, no overlap
        for w in spans.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
        // even division stays even; degenerate cases collapse sanely
        assert!(shard_spans(2048, 8).iter().all(|(lo, hi)| hi - lo == 256));
        assert_eq!(shard_spans(3, 8), vec![(0, 1), (1, 2), (2, 3)]);
        assert_eq!(shard_spans(0, 4), vec![(0, 0)]);
    }

    #[test]
    fn uneven_rows_still_match_serial() {
        // end-to-end guard on the rebalance: a row count that is not a
        // multiple of the shard count must stay bit-identical to serial
        let v = Variant::parse("w4a8").unwrap();
        let z = 1027;
        let k = v.padded_depth(64);
        let w = rngvals(v.w, z * k, 3);
        let a = rngvals(v.a, k, 4);
        let wp = PackedMatrix::from_i8(&w, z, k, v.w).unwrap();
        let oracle = oracle_gemv(&w, &a, z, k);
        for threads in [2, 3, 4, 7] {
            let mut out = vec![0i32; z];
            gemv_parallel(&wp, ActVec::I8(&a), &mut out, threads).unwrap();
            assert_eq!(out, oracle, "threads={threads}");
        }
    }

    #[test]
    fn gemm_sharding_scatters_batch_major_tiles() {
        let (z, batch) = (1024usize, 3usize);
        // a deterministic stand-in kernel writing the gemm_at tile
        // layout: tile[c*rt + (r-lo)] for rows [lo, hi)
        let fill = |tile: &mut [i32], lo: usize, hi: usize| {
            let rt = hi - lo;
            for c in 0..batch {
                for i in 0..rt {
                    tile[c * rt + i] = ((lo + i) * 31 + c * 7) as i32;
                }
            }
            Ok(())
        };
        let mut serial = vec![0i32; z * batch];
        shard_gemm_rows(&mut serial, z, batch, 1, fill).unwrap();
        // on the full matrix the tile layout IS the batch-major result
        assert_eq!(serial[0], 0);
        assert_eq!(serial[1], 31);
        assert_eq!(serial[z], 7);
        for threads in [2, 4, 8] {
            let mut par = vec![0i32; z * batch];
            shard_gemm_rows(&mut par, z, batch, threads, fill).unwrap();
            assert_eq!(par, serial, "threads={threads}");
        }
        // wrong output length is a shape error
        let mut bad = vec![0i32; 5];
        assert!(shard_gemm_rows(&mut bad, z, batch, 2, fill).is_err());
    }

    #[test]
    fn shape_error_propagates() {
        let w = rngvals(BitWidth::B4, 8 * 32, 1);
        let wp = PackedMatrix::from_i8(&w, 8, 32, BitWidth::B4).unwrap();
        let a = rngvals(BitWidth::B8, 32, 2);
        let mut bad = vec![0i32; 5];
        assert!(gemv_parallel(&wp, ActVec::I8(&a), &mut bad, 4).is_err());
    }
}

//! Row-parallel GEMV: intra-op parallelism for the serving hot path.
//!
//! The DeepSpeech LSTM gate matrices are 4H×H (8192×2048 full size) —
//! large enough that a single core leaves most of the socket idle while
//! a request is being served.  `gemv_parallel` splits the output rows
//! across a scoped thread pool; each shard runs the same single-thread
//! FullPack kernel on a row-contiguous sub-matrix (the packed layout is
//! row-independent by construction, §3.1), so results are bit-identical
//! to the serial kernel.

use super::{ActVec, KernelError};

use crate::pack::PackedMatrix;

/// Minimum rows per shard — below this the spawn overhead dominates.
pub const MIN_ROWS_PER_SHARD: usize = 256;

/// Shard the rows `[row0, row0 + out.len())` across up to `threads`
/// scoped workers, calling `f(chunk, abs_row0)` per shard.  The generic
/// engine behind [`gemv_parallel`] and the kernel-API `RowParallel`
/// decorator: any row-independent GEMV backend can be sharded this way.
pub fn shard_rows<F>(
    out: &mut [i32],
    row0: usize,
    threads: usize,
    f: F,
) -> Result<(), KernelError>
where
    F: Fn(&mut [i32], usize) -> Result<(), KernelError> + Sync,
{
    let z = out.len();
    // clamp the *quotient*, not the constant: small outputs collapse to
    // one shard instead of multiplying by a no-op `.max(1)`
    let shards = threads.min((z / MIN_ROWS_PER_SHARD).max(1));
    if shards <= 1 {
        return f(out, row0);
    }
    let rows_per = z.div_ceil(shards);
    let results: Vec<Result<(), KernelError>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(shards);
        let mut rest = &mut *out;
        let f = &f;
        for s in 0..shards {
            let lo = s * rows_per;
            let hi = ((s + 1) * rows_per).min(z);
            if lo >= hi {
                break;
            }
            let (chunk, tail) = rest.split_at_mut(hi - lo);
            rest = tail;
            // zero-copy: each shard borrows the shared operands and runs
            // the serial kernel over its row range
            handles.push(scope.spawn(move || f(chunk, row0 + lo)));
        }
        handles.into_iter().map(|h| h.join().expect("shard panicked")).collect()
    });
    for r in results {
        r?;
    }
    Ok(())
}

/// Row-sharded GEMV.  `threads = 1` (or small matrices) falls back to
/// the serial kernel.  Output is bit-identical to [`super::gemv`].
pub fn gemv_parallel(
    wp: &PackedMatrix,
    a: ActVec<'_>,
    out: &mut [i32],
    threads: usize,
) -> Result<(), KernelError> {
    let z = wp.rows();
    if out.len() != z {
        return Err(KernelError::Shape(format!("out len {} != rows {z}", out.len())));
    }
    shard_rows(out, 0, threads, |chunk, lo| super::gemv_at(wp, a, chunk, lo))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::pack_activations;
    use crate::kernels::testutil::{oracle_gemv, rngvals};
    use crate::pack::{BitWidth, Variant};

    #[test]
    fn parallel_equals_serial_all_variants() {
        for v in Variant::PAPER_VARIANTS {
            let z = 1024; // enough rows to actually shard
            let k = v.padded_depth(128);
            let w = rngvals(v.w, z * k, 91);
            let a = rngvals(v.a, k, 92);
            let wp = PackedMatrix::from_i8(&w, z, k, v.w).unwrap();
            let packed_a;
            let act = if v.a.is_sub_byte() {
                packed_a = pack_activations(&a, v.a).unwrap();
                ActVec::Packed { bytes: &packed_a, bits: v.a }
            } else {
                ActVec::I8(&a)
            };
            let mut serial = vec![0i32; z];
            gemv(&wp, act, &mut serial).unwrap();
            for threads in [1, 2, 3, 4] {
                let mut par = vec![0i32; z];
                gemv_parallel(&wp, act, &mut par, threads).unwrap();
                assert_eq!(par, serial, "{v} threads={threads}");
            }
            assert_eq!(serial, oracle_gemv(&w, &a, z, k), "{v}");
        }
    }

    #[test]
    fn small_matrix_falls_back_serial() {
        let w = rngvals(BitWidth::B4, 8 * 32, 1);
        let wp = PackedMatrix::from_i8(&w, 8, 32, BitWidth::B4).unwrap();
        let a = rngvals(BitWidth::B8, 32, 2);
        let mut out = vec![0i32; 8];
        gemv_parallel(&wp, ActVec::I8(&a), &mut out, 8).unwrap();
        assert_eq!(out, oracle_gemv(&w, &a, 8, 32));
    }

    #[test]
    fn shape_error_propagates() {
        let w = rngvals(BitWidth::B4, 8 * 32, 1);
        let wp = PackedMatrix::from_i8(&w, 8, 32, BitWidth::B4).unwrap();
        let a = rngvals(BitWidth::B8, 32, 2);
        let mut bad = vec![0i32; 5];
        assert!(gemv_parallel(&wp, ActVec::I8(&a), &mut bad, 4).is_err());
    }
}

//! The pluggable kernel API (DESIGN.md §3) — one object-safe trait every
//! GEMV backend implements, plus the [`Weights`] container that lets
//! each backend own its storage layout.
//!
//! The paper's contribution is a *family* of kernels (nine FullPack
//! variants plus the Ruy/XNNPack/GEMMLOWP/ULPPACK rivals); this trait is
//! the single seam they all plug into, so that adding a backend (e.g. a
//! DeepGEMM-style lookup-table kernel, Ganji et al. 2023) is one
//! registry entry instead of an N-file edit.
//!
//! Dispatch flow:
//!
//! ```text
//!   caller                 kernels::plan            kernels::registry
//!   ──────                 ─────────────            ─────────────────
//!   PlanBuilder ──policy──▶ select kernel ──name──▶ KernelRegistry
//!        │                                              │
//!        ▼                                              ▼
//!   Plan::prepare_weights ────────────────────▶ GemvKernel::prepare
//!   Plan::execute ─(pad/pack acts, shard rows)─▶ GemvKernel::gemv_at
//! ```

#![warn(missing_docs)]

use super::{ActVec, KernelError};
use crate::costmodel::Method;
use crate::pack::{BitWidth, PackedMatrix, UlppackMatrix, Variant};

/// A weight matrix in one backend's own storage layout, produced by
/// [`GemvKernel::prepare`] and consumed by [`GemvKernel::gemv_at`].
#[derive(Debug, Clone)]
pub enum Weights {
    /// FullPack stride-16 layout (sub-byte widths) or plain row-major
    /// int8 (`BitWidth::B8`).
    Packed(PackedMatrix),
    /// FullPack stride-16 layout plus cached per-row weight sums — the
    /// SWAR tier's bias-correction side table (DESIGN.md §8).
    SwarPacked {
        /// the packed matrix, identical layout to [`Weights::Packed`]
        m: PackedMatrix,
        /// `Σ w` per row (padding contributes zero), used to unbias
        /// the `a + 128` accumulation in one subtract per row
        row_sums: Vec<i64>,
    },
    /// Naive adjacent packing (paper Alg. 1).
    Naive {
        /// adjacently packed row-major bytes
        bytes: Vec<u8>,
        /// output rows
        rows: usize,
        /// logical depth
        k: usize,
        /// element bit-width
        bits: BitWidth,
    },
    /// ULPPACK spacer-lane layout (two values per u16 lane).
    Ulppack(UlppackMatrix),
    /// Dequantized f32 rows (the FP32 baselines).
    F32 {
        /// row-major f32 weights
        data: Vec<f32>,
        /// output rows
        rows: usize,
        /// logical depth
        k: usize,
    },
}

impl Weights {
    /// Output rows of the stored matrix.
    pub fn rows(&self) -> usize {
        match self {
            Weights::Packed(m) | Weights::SwarPacked { m, .. } => m.rows(),
            Weights::Ulppack(m) => m.rows(),
            Weights::Naive { rows, .. } | Weights::F32 { rows, .. } => *rows,
        }
    }

    /// Logical (unpadded) depth.
    pub fn k(&self) -> usize {
        match self {
            Weights::Packed(m) | Weights::SwarPacked { m, .. } => m.k(),
            Weights::Ulppack(m) => m.k(),
            Weights::Naive { k, .. } | Weights::F32 { k, .. } => *k,
        }
    }

    /// Depth an int8 activation vector must cover for this layout
    /// (group-padded for FullPack, logical otherwise).
    pub fn k_padded(&self) -> usize {
        match self {
            Weights::Packed(m) | Weights::SwarPacked { m, .. } => m.k_padded(),
            _ => self.k(),
        }
    }

    /// Storage bytes — the paper's memory-capacity metric.
    pub fn footprint(&self) -> usize {
        match self {
            Weights::Packed(m) => m.footprint(),
            // the row-sum side table is part of the layout's cost
            Weights::SwarPacked { m, row_sums } => m.footprint() + row_sums.len() * 8,
            Weights::Ulppack(m) => m.footprint(),
            Weights::Naive { bytes, .. } => bytes.len(),
            Weights::F32 { data, .. } => data.len() * 4,
        }
    }

    /// Downcast to the FullPack/int8 container (PJRT upload, oracle
    /// unpacking).  The SWAR layout shares the packed container, so it
    /// downcasts too (the side table is derived data).
    pub fn as_packed(&self) -> Option<&PackedMatrix> {
        match self {
            Weights::Packed(m) | Weights::SwarPacked { m, .. } => Some(m),
            _ => None,
        }
    }
}

/// An object-safe GEMV backend.  Implementations are registered in
/// [`super::KernelRegistry`] under a unique name; each registry entry is
/// one (kernel family × variant) pair, e.g. `fullpack-w4a8`.
pub trait GemvKernel: Send + Sync {
    /// Unique registry name (`fullpack-w4a8`, `ruy-w8a8`, ...).
    fn name(&self) -> &'static str;

    /// Can this kernel execute a layer whose data is quantized as `v`?
    fn supports(&self, v: Variant) -> bool;

    /// Pack a row-major `rows × k` int8 matrix into this kernel's
    /// preferred layout (depth padding included where the layout needs
    /// it).
    fn prepare(&self, w: &[i8], rows: usize, k: usize) -> Result<Weights, KernelError>;

    /// GEMV over the row range `[row0, row0 + out.len())` — the
    /// zero-copy sharding entry the row-parallel decorator uses.
    fn gemv_at(
        &self,
        w: &Weights,
        a: ActVec<'_>,
        out: &mut [i32],
        row0: usize,
    ) -> Result<(), KernelError>;

    /// The analytic cost-model method this kernel is modeled as
    /// (`None` for kernels the model does not cover).  This is the
    /// bridge that keeps modeled and measured methods in one namespace.
    fn cost_method(&self) -> Option<Method>;

    /// Does this kernel consume FullPack-packed sub-byte activation
    /// bytes (`ActVec::Packed`)?  Kernels returning `false` take plain
    /// `ActVec::I8` and perform any layout conversion themselves.
    fn packs_activations(&self) -> bool {
        false
    }

    /// Batched GEMM as repeated GEMV (`out[c*z..]` per column).
    /// Backends with a real batched kernel (FullPack's GEMM extension)
    /// override this.
    fn gemm(&self, w: &Weights, cols: &[&[i8]], out: &mut [i32]) -> Result<(), KernelError> {
        let z = w.rows();
        if out.len() != z * cols.len() {
            return Err(KernelError::Shape(format!(
                "out len {} != rows*batch {}",
                out.len(),
                z * cols.len()
            )));
        }
        for (c, col) in cols.iter().enumerate() {
            self.gemv_at(w, ActVec::I8(col), &mut out[c * z..(c + 1) * z], 0)?;
        }
        Ok(())
    }
}

/// An object-safe batched-GEMM backend — the first-class tier for the
/// paper's explicit future-work gap ("FullPack does not support GEMM, so
/// we used Ruy-W8A8 for the GEMM operations", Fig. 10).  Entries are
/// registered in [`super::KernelRegistry`] under their own namespace
/// (`fullpack-w4a8-gemm`, `ruy-like-w8a8-gemm`, ...), disjoint from the
/// GEMV names by the `-gemm` suffix.
///
/// The contract mirrors [`GemvKernel`] — `prepare` owns the weight
/// layout, `gemm` consumes it — but the execution unit is one flushed
/// batch: `cols` holds `batch` int8 activation columns (each of length
/// `w.k_padded()` or more) and `out[c*rows..(c+1)*rows]` receives column
/// `c`.  The differential suite (`rust/tests/gemm_differential.rs`)
/// pins every registered backend to `repeated GEMV ≡ naive oracle`.
pub trait GemmKernel: Send + Sync {
    /// Unique registry name (`fullpack-w4a8-gemm`, ...).
    fn name(&self) -> &'static str;

    /// Can this backend execute a layer whose data is quantized as `v`?
    fn supports(&self, v: Variant) -> bool;

    /// Pack a row-major `rows × k` int8 matrix into this backend's
    /// preferred layout (depth padding included where the layout needs
    /// it).
    fn prepare(&self, w: &[i8], rows: usize, k: usize) -> Result<Weights, KernelError>;

    /// One batched GEMM over all of `cols`: `out[c][r] = Σ_k w[r][k] ·
    /// cols[c][k]`, batch-major output.
    fn gemm(&self, w: &Weights, cols: &[&[i8]], out: &mut [i32]) -> Result<(), KernelError>;

    /// Batched GEMM over the row-tile `[row0, row0 + rows_tile)` where
    /// `rows_tile = out.len() / cols.len()` — the zero-copy sharding
    /// entry the tile-parallel decorator
    /// ([`super::RowParallelGemm`]) uses.  The tile output is
    /// batch-major *over the tile*: `out[c * rows_tile + (r - row0)]`
    /// receives row `r` of column `c`.
    ///
    /// The default covers only the degenerate full-matrix tile
    /// (`row0 == 0` and `out` spanning every row) by delegating to
    /// [`GemmKernel::gemm`]; backends opt into real sharding by
    /// overriding.  All built-in backends override.
    fn gemm_at(
        &self,
        w: &Weights,
        cols: &[&[i8]],
        out: &mut [i32],
        row0: usize,
    ) -> Result<(), KernelError> {
        if row0 == 0 && out.len() == w.rows() * cols.len() {
            return self.gemm(w, cols, out);
        }
        Err(KernelError::Unsupported(format!(
            "kernel {} has no row-tile GEMM entry",
            self.name()
        )))
    }

    /// The analytic cost-model method this backend is modeled as
    /// (`None` for backends the model does not cover, e.g. the naive
    /// oracle).  FullPack GEMM entries map to `Method::FullPackGemm`;
    /// rival entries map to the GEMV method whose repeated execution
    /// they amortize (`costmodel::simulate_gemm` models them as
    /// `batch` back-to-back calls).
    fn cost_method(&self) -> Option<Method> {
        None
    }
}

/// Shared operand validation for [`GemmKernel::gemm`] implementations:
/// batch-major output length and per-column padded depth.
pub(crate) fn check_gemm_shape(
    w: &Weights,
    cols: &[&[i8]],
    out: &[i32],
) -> Result<(), KernelError> {
    let z = w.rows();
    if out.len() != z * cols.len() {
        return Err(KernelError::Shape(format!(
            "out len {} != rows*batch {}",
            out.len(),
            z * cols.len()
        )));
    }
    let kp = w.k_padded();
    for (c, col) in cols.iter().enumerate() {
        if col.len() < kp {
            return Err(KernelError::Shape(format!(
                "column {c} len {} < padded depth {kp}",
                col.len()
            )));
        }
    }
    Ok(())
}

/// Shared operand validation for [`GemmKernel::gemm_at`] row-tile
/// implementations: batch-major tile shape, row-range bounds, per-column
/// padded depth.  Returns the tile height `rt = out.len() / cols.len()`
/// (0 for an empty batch).
pub(crate) fn check_gemm_tile(
    w: &Weights,
    cols: &[&[i8]],
    out: &[i32],
    row0: usize,
) -> Result<usize, KernelError> {
    let batch = cols.len();
    if batch == 0 {
        return if out.is_empty() {
            Ok(0)
        } else {
            Err(KernelError::Shape(format!("out len {} with empty batch", out.len())))
        };
    }
    if out.len() % batch != 0 {
        return Err(KernelError::Shape(format!(
            "out len {} not a multiple of batch {batch}",
            out.len()
        )));
    }
    let rt = out.len() / batch;
    if row0 + rt > w.rows() {
        return Err(KernelError::Shape(format!(
            "row range {row0}..{} exceeds rows {}",
            row0 + rt,
            w.rows()
        )));
    }
    let kp = w.k_padded();
    for (c, col) in cols.iter().enumerate() {
        if col.len() < kp {
            return Err(KernelError::Shape(format!(
                "column {c} len {} < padded depth {kp}",
                col.len()
            )));
        }
    }
    Ok(rt)
}

/// Shared bounds check for `gemv_at` implementations.
pub(crate) fn check_rows(w: &Weights, out: &[i32], row0: usize) -> Result<(), KernelError> {
    if row0 + out.len() > w.rows() {
        return Err(KernelError::Shape(format!(
            "row range {row0}..{} exceeds rows {}",
            row0 + out.len(),
            w.rows()
        )));
    }
    Ok(())
}

/// Shared layout-mismatch error.
pub(crate) fn wrong_layout(kernel: &str, w: &Weights) -> KernelError {
    let got = match w {
        Weights::Packed(_) => "packed",
        Weights::SwarPacked { .. } => "swar-packed",
        Weights::Ulppack(_) => "ulppack",
        Weights::Naive { .. } => "naive",
        Weights::F32 { .. } => "f32",
    };
    KernelError::Shape(format!("kernel {kernel} got weights in {got} layout"))
}

//! Execution plans (DESIGN.md §3): a [`Plan`] binds one layer shape +
//! data variant + thread budget to a kernel chosen from the
//! [`KernelRegistry`], with preallocated packing scratch for the
//! activation hot path.  All kernel selection in the repo flows through
//! here — the coordinator's router, the models, the figure harnesses,
//! the benches and the CLI all build plans instead of naming kernel
//! functions.
//!
//! Three selection policies:
//!
//! * [`SelectPolicy::PaperRule`] — the paper's §4.6 split: single-batch
//!   sub-byte ops take the FullPack GEMV kernel of the data's variant;
//!   batched or 8-bit ops take the Ruy-like W8A8 path (sub-byte values
//!   widened to int8, exactly the paper's "FullPack does not support
//!   GEMM" fallback).
//! * [`SelectPolicy::Explicit`] — a registry name (`--kernel` flags,
//!   benches, ablations).
//! * [`SelectPolicy::CostModel`] — argmin of modeled cycles over every
//!   candidate backend via `costmodel::simulate_gemv`.

#![warn(missing_docs)]

use super::api::{GemvKernel, Weights};
use super::registry::{fullpack_kernel_name, KernelRegistry};
use super::swar::{swar_kernel_name, SWAR_MIN_DEPTH};
use super::{parallel, ActVec, KernelError};
use crate::costmodel::{simulate_gemv, CoreModel};
use crate::pack::{pack_into, BitWidth, Variant};
use crate::sim::CachePreset;
use std::sync::{Arc, Mutex};

const W8A8: Variant = Variant::new(BitWidth::B8, BitWidth::B8);

/// The layer shape a plan is bound to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerShape {
    /// output rows
    pub z: usize,
    /// logical input depth
    pub k: usize,
    /// columns per call (1 = GEMV)
    pub batch: usize,
}

/// How the builder picks a kernel.
#[derive(Debug, Clone)]
pub enum SelectPolicy {
    /// paper §4.6: single-batch sub-byte → FullPack; else Ruy-W8A8.
    /// With [`PlanBuilder::prefer_swar`] set, the FullPack branch takes
    /// the `-swar` tier when the variant has one and the depth clears
    /// [`SWAR_MIN_DEPTH`] (alignment is free: the packed layout is
    /// always a whole number of 8-byte chunks).
    PaperRule,
    /// a registry name, verbatim
    Explicit(String),
    /// argmin modeled cycles (`costmodel::simulate_gemv`) over all
    /// candidates; `calls` = steady-state warm-up calls for residency,
    /// `core` = the pipeline model costs are computed on (the SWAR tier
    /// wins only on cores whose `autovec_eff` marks the staged 16-lane
    /// loops as imperfectly vectorized)
    CostModel {
        /// cache hierarchy preset replayed for the stall model
        preset: CachePreset,
        /// steady-state warm-up calls before the measured call
        calls: usize,
        /// pipeline/throughput model of the target core
        core: CoreModel,
    },
}

impl SelectPolicy {
    /// Cost-model policy with the gem5 ex5_big defaults (the paper's
    /// simulated core: staged loops compile to perfect NEON).
    pub fn cost_model() -> SelectPolicy {
        SelectPolicy::CostModel {
            preset: CachePreset::Gem5Ex5Big,
            calls: 3,
            core: CoreModel::ex5_big(),
        }
    }

    /// Cost-model policy for a portable host whose auto-vectorizer
    /// cannot be trusted with the staged lane loops — the regime the
    /// SWAR tier exists for (DESIGN.md §8).
    pub fn cost_model_portable() -> SelectPolicy {
        SelectPolicy::CostModel {
            preset: CachePreset::Gem5Ex5Big,
            calls: 3,
            core: CoreModel::portable(),
        }
    }
}

/// Builder: shape + variant + knobs → [`Plan`].
///
/// ```
/// use fullpack::kernels::{LayerShape, PlanBuilder};
/// use fullpack::pack::Variant;
///
/// let shape = LayerShape { z: 8, k: 64, batch: 1 };
/// let plan = PlanBuilder::new(shape, Variant::parse("w4a8").unwrap())
///     .threads(2)
///     .build()
///     .unwrap();
/// assert_eq!(plan.kernel_name(), "fullpack-w4a8");
///
/// let w = vec![1i8; 8 * 64];
/// let a = vec![1i8; 64];
/// let weights = plan.prepare_weights(&w).unwrap();
/// let mut out = vec![0i32; 8];
/// plan.execute(&weights, &a, &mut out).unwrap();
/// assert!(out.iter().all(|&y| y == 64));
/// ```
pub struct PlanBuilder {
    shape: LayerShape,
    variant: Variant,
    threads: usize,
    policy: SelectPolicy,
    gemv_max_batch: usize,
    prefer_swar: bool,
}

impl PlanBuilder {
    /// Start a builder with the default policy ([`SelectPolicy::PaperRule`]),
    /// serial execution and the paper's batch threshold of 1.
    pub fn new(shape: LayerShape, variant: Variant) -> PlanBuilder {
        PlanBuilder {
            shape,
            variant,
            threads: 1,
            policy: SelectPolicy::PaperRule,
            gemv_max_batch: 1,
            prefer_swar: false,
        }
    }

    /// Intra-op row-parallelism budget (1 = serial).
    pub fn threads(mut self, t: usize) -> PlanBuilder {
        self.threads = t.max(1);
        self
    }

    /// Replace the selection policy (default: [`SelectPolicy::PaperRule`]).
    pub fn policy(mut self, p: SelectPolicy) -> PlanBuilder {
        self.policy = p;
        self
    }

    /// Largest batch still routed to the GEMV path under `PaperRule`
    /// (paper: 1).
    pub fn gemv_max_batch(mut self, n: usize) -> PlanBuilder {
        self.gemv_max_batch = n;
        self
    }

    /// Under `PaperRule`, take the registered `-swar` tier instead of
    /// the staged scalar kernel when the variant has one and the padded
    /// depth is at least [`SWAR_MIN_DEPTH`] (default: off, preserving
    /// the paper's kernel choice).  Only the *sub-byte* GEMV branch is
    /// affected: 8-bit ops keep the paper's Ruy path, so
    /// `fullpack-w8a8-swar` is reachable only via
    /// [`SelectPolicy::Explicit`] or [`SelectPolicy::CostModel`].
    pub fn prefer_swar(mut self, yes: bool) -> PlanBuilder {
        self.prefer_swar = yes;
        self
    }

    /// Select against the global registry.
    pub fn build(self) -> Result<Plan, KernelError> {
        self.build_in(KernelRegistry::global())
    }

    /// Select against a caller-supplied registry (custom backends).
    pub fn build_in(self, reg: &KernelRegistry) -> Result<Plan, KernelError> {
        let (shape, variant, threads) = (self.shape, self.variant, self.threads);
        let (kernel, exec_variant) = self.select_in(reg)?;
        Ok(Plan {
            shape,
            variant,
            exec_variant,
            threads,
            kernel,
            scratch: Mutex::new(PlanScratch::default()),
        })
    }

    /// Run the selection policy only (no plan construction): the chosen
    /// kernel and the variant it will execute — the cheap path for
    /// callers that just need the routing decision.
    pub fn select(self) -> Result<(Arc<dyn GemvKernel>, Variant), KernelError> {
        self.select_in(KernelRegistry::global())
    }

    /// [`PlanBuilder::select`] against a caller-supplied registry.
    pub fn select_in(
        self,
        reg: &KernelRegistry,
    ) -> Result<(Arc<dyn GemvKernel>, Variant), KernelError> {
        let LayerShape { z, k, batch } = self.shape;
        let lookup = |name: &str| -> Result<Arc<dyn GemvKernel>, KernelError> {
            reg.get(name)
                .cloned()
                .ok_or_else(|| KernelError::Unsupported(format!("unknown kernel {name:?}")))
        };
        // a kernel can run the variant natively, or run it widened to
        // int8 (the paper's Ruy fallback for sub-byte data)
        let exec_for = |kern: &Arc<dyn GemvKernel>| -> Option<Variant> {
            if kern.supports(self.variant) {
                Some(self.variant)
            } else if kern.supports(W8A8) {
                Some(W8A8)
            } else {
                None
            }
        };
        let (kernel, exec_variant) = match &self.policy {
            SelectPolicy::Explicit(name) => {
                let kern = lookup(name)?;
                let ev = exec_for(&kern).ok_or_else(|| {
                    KernelError::Unsupported(format!("{} cannot run {}", kern.name(), self.variant))
                })?;
                (kern, ev)
            }
            SelectPolicy::PaperRule => {
                let sub = self.variant.w.is_sub_byte() || self.variant.a.is_sub_byte();
                if sub && batch <= self.gemv_max_batch {
                    let mut name = fullpack_kernel_name(self.variant);
                    if self.prefer_swar && self.variant.padded_depth(k) >= SWAR_MIN_DEPTH {
                        if let Some(sw) = swar_kernel_name(self.variant) {
                            if reg.get(sw).is_some() {
                                name = sw;
                            }
                        }
                    }
                    (lookup(name)?, self.variant)
                } else {
                    (lookup("ruy-w8a8")?, W8A8)
                }
            }
            SelectPolicy::CostModel { preset, calls, core } => {
                let mut best: Option<(f64, Arc<dyn GemvKernel>, Variant)> = None;
                for kern in reg.iter() {
                    let Some(ev) = exec_for(kern) else { continue };
                    let Some(method) = kern.cost_method() else { continue };
                    let cycles = simulate_gemv(method, z, k, *preset, core, *calls).cycles;
                    let better = match &best {
                        None => true,
                        Some((c, _, _)) => cycles < *c,
                    };
                    if better {
                        best = Some((cycles, kern.clone(), ev));
                    }
                }
                let (_, kern, ev) = best.ok_or_else(|| {
                    KernelError::Unsupported(format!("no registered kernel runs {}", self.variant))
                })?;
                (kern, ev)
            }
        };
        Ok((kernel, exec_variant))
    }
}

/// Reusable activation pad/pack buffers.  Every plan owns one behind a
/// `try_lock`; hot loops that share a plan across threads (the serving
/// engine's LSTM scan) pass their own via [`Plan::execute_in`] so the
/// steady state never allocates.
#[derive(Default)]
pub struct PlanScratch {
    padded: Vec<i8>,
    packed: Vec<u8>,
}

/// A bound execution plan: shape + variant + thread budget + the chosen
/// kernel, with reusable activation-packing scratch.
pub struct Plan {
    /// the layer shape the plan is bound to
    pub shape: LayerShape,
    /// the data's quantization variant
    pub variant: Variant,
    /// what the kernel actually runs (`w8a8` when sub-byte data is
    /// widened onto the int8 fallback path)
    pub exec_variant: Variant,
    /// default intra-op thread budget for [`Plan::execute`]
    pub threads: usize,
    kernel: Arc<dyn GemvKernel>,
    scratch: Mutex<PlanScratch>,
}

impl std::fmt::Debug for Plan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Plan")
            .field("kernel", &self.kernel.name())
            .field("shape", &self.shape)
            .field("variant", &self.variant)
            .field("exec_variant", &self.exec_variant)
            .field("threads", &self.threads)
            .finish()
    }
}

impl Plan {
    /// Registry name of the chosen kernel.
    pub fn kernel_name(&self) -> &'static str {
        self.kernel.name()
    }

    /// The selected backend (e.g. to wrap in `RowParallel`).
    pub fn kernel(&self) -> &Arc<dyn GemvKernel> {
        &self.kernel
    }

    /// Did selection land on the FullPack GEMV family?
    pub fn is_fullpack(&self) -> bool {
        self.kernel.name().starts_with("fullpack-")
    }

    /// Pack a row-major `z × k` int8 weight matrix into the chosen
    /// kernel's layout.
    pub fn prepare_weights(&self, w: &[i8]) -> Result<Weights, KernelError> {
        self.kernel.prepare(w, self.shape.z, self.shape.k)
    }

    /// One GEMV with the plan's thread budget.  `a` is the logical-depth
    /// int8 activation vector; padding and sub-byte packing happen in
    /// the plan's scratch.
    pub fn execute(&self, w: &Weights, a: &[i8], out: &mut [i32]) -> Result<(), KernelError> {
        self.execute_with_threads(w, a, out, self.threads)
    }

    /// Borrow the plan's preallocated scratch, or a fresh local one
    /// when a concurrent call holds it — contenders never serialize
    /// behind each other's kernel execution.
    fn with_scratch<R>(&self, f: impl FnOnce(&mut PlanScratch) -> R) -> R {
        match self.scratch.try_lock() {
            Ok(mut guard) => f(&mut guard),
            Err(_) => f(&mut PlanScratch::default()),
        }
    }

    /// [`Plan::execute`] with an explicit thread budget (the serving
    /// engine's per-request intra-op knob).
    pub fn execute_with_threads(
        &self,
        w: &Weights,
        a: &[i8],
        out: &mut [i32],
        threads: usize,
    ) -> Result<(), KernelError> {
        self.with_scratch(|scratch| self.execute_in(w, a, out, threads, scratch))
    }

    /// [`Plan::execute`] with caller-owned scratch — the allocation-free
    /// path for hot loops that share one plan across threads (each
    /// caller keeps its own [`PlanScratch`]).
    pub fn execute_in(
        &self,
        w: &Weights,
        a: &[i8],
        out: &mut [i32],
        threads: usize,
        scratch: &mut PlanScratch,
    ) -> Result<(), KernelError> {
        if out.len() != w.rows() {
            return Err(KernelError::Shape(format!(
                "out len {} != rows {}",
                out.len(),
                w.rows()
            )));
        }
        // short activations would be silently zero-padded into a wrong
        // dot product; callers may pass pre-padded vectors (>= k)
        if a.len() < self.shape.k {
            return Err(KernelError::Shape(format!(
                "activation len {} < layer depth {}",
                a.len(),
                self.shape.k
            )));
        }
        let kp = w.k_padded();
        let act = if self.kernel.packs_activations() {
            scratch.padded.clear();
            scratch.padded.extend_from_slice(a);
            scratch.padded.resize(kp.max(a.len()), 0);
            pack_into(&scratch.padded[..kp], self.exec_variant.a, &mut scratch.packed);
            ActVec::Packed { bytes: &scratch.packed, bits: self.exec_variant.a }
        } else if kp > a.len() {
            scratch.padded.clear();
            scratch.padded.extend_from_slice(a);
            scratch.padded.resize(kp, 0);
            ActVec::I8(&scratch.padded)
        } else {
            ActVec::I8(a)
        };
        let kernel = &*self.kernel;
        if threads > 1 {
            parallel::shard_rows(out, 0, threads, |chunk, lo| kernel.gemv_at(w, act, chunk, lo))
        } else {
            kernel.gemv_at(w, act, out, 0)
        }
    }

    /// Batched execution: `a` holds `batch` row-major columns of depth
    /// `k`; `out[c*z..(c+1)*z]` receives column `c`.  FullPack kernels
    /// take their batched-GEMM extension; everything else runs repeated
    /// GEMV (the paper's protocol).
    pub fn execute_batch(
        &self,
        w: &Weights,
        a: &[i8],
        batch: usize,
        out: &mut [i32],
    ) -> Result<(), KernelError> {
        let k = self.shape.k;
        if a.len() != batch * k {
            return Err(KernelError::Shape(format!(
                "activations len {} != batch*k {}",
                a.len(),
                batch * k
            )));
        }
        let kp = w.k_padded();
        if kp > k {
            self.with_scratch(|scratch| {
                scratch.padded.clear();
                scratch.padded.resize(batch * kp, 0);
                for b in 0..batch {
                    scratch.padded[b * kp..b * kp + k].copy_from_slice(&a[b * k..(b + 1) * k]);
                }
                let padded = &scratch.padded;
                let cols: Vec<&[i8]> = (0..batch).map(|b| &padded[b * kp..(b + 1) * kp]).collect();
                self.kernel.gemm(w, &cols, out)
            })
        } else {
            let cols: Vec<&[i8]> = (0..batch).map(|b| &a[b * k..(b + 1) * k]).collect();
            self.kernel.gemm(w, &cols, out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testutil::{oracle_gemv, pad_rows, rngvals};

    fn shape(z: usize, k: usize, batch: usize) -> LayerShape {
        LayerShape { z, k, batch }
    }

    #[test]
    fn paper_rule_reproduces_router_decisions() {
        let w4a8 = Variant::parse("w4a8").unwrap();
        let w8a8 = Variant::parse("w8a8").unwrap();
        // single-batch sub-byte LSTM step -> FullPack GEMV
        let p = PlanBuilder::new(shape(2048, 2048, 1), w4a8).build().unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a8");
        assert!(p.is_fullpack());
        // batch-16 FC -> Ruy GEMM even when quantized sub-byte
        let p = PlanBuilder::new(shape(2048, 2048, 16), w4a8).build().unwrap();
        assert_eq!(p.kernel_name(), "ruy-w8a8");
        assert_eq!(p.exec_variant, W8A8);
        // 8-bit ops always take the baseline
        let p = PlanBuilder::new(shape(2048, 2048, 1), w8a8).build().unwrap();
        assert_eq!(p.kernel_name(), "ruy-w8a8");
        // raised batch threshold keeps the GEMV path
        let p = PlanBuilder::new(shape(2048, 2048, 4), w4a8).gemv_max_batch(4).build().unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a8");
    }

    #[test]
    fn cost_model_picks_fullpack_at_the_boundary() {
        // paper §4.4 regime: 2048x2048, packed weights fit the 2MB LLC,
        // W8A8 does not — the model must prefer fullpack-w4a8 over
        // ruy-w8a8 (and every other W8A8/FP32 candidate).  On the ex5
        // core the staged loops compile to perfect NEON, so the scalar
        // tier beats its own SWAR sibling too.
        let v = Variant::parse("w4a8").unwrap();
        let p = PlanBuilder::new(shape(2048, 2048, 1), v)
            .policy(SelectPolicy::cost_model())
            .build()
            .unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a8");
    }

    #[test]
    fn portable_cost_model_selects_the_swar_tier() {
        // on a core whose auto-vectorizer cannot be trusted with the
        // staged lane loops, the vectorization-independent SWAR tier
        // wins for the low bit-widths (DESIGN.md §8)
        let v = Variant::parse("w1a8").unwrap();
        let p = PlanBuilder::new(shape(2048, 2048, 1), v)
            .policy(SelectPolicy::cost_model_portable())
            .build()
            .unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w1a8-swar");
    }

    #[test]
    fn paper_rule_prefer_swar_gates_on_depth_and_tier() {
        let w4a8 = Variant::parse("w4a8").unwrap();
        // deep layer + opt-in -> the SWAR tier
        let p = PlanBuilder::new(shape(256, 2048, 1), w4a8).prefer_swar(true).build().unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a8-swar");
        assert!(p.is_fullpack());
        // below SWAR_MIN_DEPTH the flush/bias overhead dominates ->
        // stay on the staged kernel (k=1 pads to one 32-element group)
        let p = PlanBuilder::new(shape(256, 1, 1), w4a8).prefer_swar(true).build().unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a8");
        // variants without a SWAR backend keep the scalar kernel
        let w4a4 = Variant::parse("w4a4").unwrap();
        let p = PlanBuilder::new(shape(256, 2048, 1), w4a4).prefer_swar(true).build().unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a4");
        // default stays the paper's kernel choice
        let p = PlanBuilder::new(shape(256, 2048, 1), w4a8).build().unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a8");
    }

    #[test]
    fn prefer_swar_plans_execute_correctly() {
        for (vname, k) in [("w4a8", 129usize), ("w2a8", 200), ("w1a8", 501)] {
            let v = Variant::parse(vname).unwrap();
            let z = 16;
            let plan =
                PlanBuilder::new(shape(z, k, 1), v).prefer_swar(true).build().unwrap();
            assert!(plan.kernel_name().ends_with("-swar"), "{vname}");
            let w = rngvals(v.w, z * k, 41 + k as u64);
            let a = rngvals(v.a, k, 43 + k as u64);
            let wts = plan.prepare_weights(&w).unwrap();
            let mut out = vec![0i32; z];
            plan.execute(&wts, &a, &mut out).unwrap();
            let kp = v.padded_depth(k);
            let wp = pad_rows(&w, z, k, kp);
            let mut ap = a.clone();
            ap.resize(kp, 0);
            assert_eq!(out, oracle_gemv(&wp, &ap, z, kp), "{vname} k={k}");
        }
    }

    #[test]
    fn explicit_policy_and_errors() {
        let v = Variant::parse("w2a2").unwrap();
        let p = PlanBuilder::new(shape(64, 128, 1), v)
            .policy(SelectPolicy::Explicit("ulppack-w2a2".into()))
            .build()
            .unwrap();
        assert_eq!(p.kernel_name(), "ulppack-w2a2");
        assert!(PlanBuilder::new(shape(64, 128, 1), v)
            .policy(SelectPolicy::Explicit("no-such-kernel".into()))
            .build()
            .is_err());
        // naive-w4a8 cannot run w2a2 natively nor widened
        assert!(PlanBuilder::new(shape(64, 128, 1), v)
            .policy(SelectPolicy::Explicit("naive-w4a8".into()))
            .build()
            .is_err());
    }

    #[test]
    fn execute_pads_and_packs_unaligned_depths() {
        for vname in ["w4a8", "w4a4", "w2a2", "w8a4"] {
            let v = Variant::parse(vname).unwrap();
            for k in [1usize, 17, 127, 129] {
                let z = 8;
                let plan = PlanBuilder::new(shape(z, k, 1), v).build().unwrap();
                let w = rngvals(v.w, z * k, 7 + k as u64);
                let a = rngvals(v.a, k, 9 + k as u64);
                let wts = plan.prepare_weights(&w).unwrap();
                let mut out = vec![0i32; z];
                plan.execute(&wts, &a, &mut out).unwrap();
                let kp = v.padded_depth(k);
                let wp = pad_rows(&w, z, k, kp);
                let mut ap = a.clone();
                ap.resize(kp, 0);
                assert_eq!(out, oracle_gemv(&wp, &ap, z, kp), "{vname} k={k}");
            }
        }
    }

    #[test]
    fn execute_batch_matches_per_column() {
        let v = Variant::parse("w4a8").unwrap();
        let (z, k, batch) = (16usize, 64usize, 3usize);
        let plan = PlanBuilder::new(shape(z, k, 1), v).build().unwrap();
        let w = rngvals(v.w, z * k, 21);
        let a = rngvals(v.a, batch * k, 22);
        let wts = plan.prepare_weights(&w).unwrap();
        let mut out = vec![0i32; batch * z];
        plan.execute_batch(&wts, &a, batch, &mut out).unwrap();
        for b in 0..batch {
            let col = &a[b * k..(b + 1) * k];
            assert_eq!(&out[b * z..(b + 1) * z], oracle_gemv(&w, col, z, k).as_slice(), "col {b}");
        }
    }

    #[test]
    fn threads_do_not_change_results() {
        let v = Variant::parse("w2a2").unwrap();
        let (z, k) = (1024usize, 256usize);
        let plan = PlanBuilder::new(shape(z, k, 1), v).threads(4).build().unwrap();
        let w = rngvals(v.w, z * k, 31);
        let a = rngvals(v.a, k, 32);
        let wts = plan.prepare_weights(&w).unwrap();
        let mut par = vec![0i32; z];
        plan.execute(&wts, &a, &mut par).unwrap();
        let mut serial = vec![0i32; z];
        plan.execute_with_threads(&wts, &a, &mut serial, 1).unwrap();
        assert_eq!(par, serial);
    }
}

//! Execution plans (DESIGN.md §3): a [`Plan`] binds one layer shape +
//! data variant + thread budget to a kernel chosen from the
//! [`KernelRegistry`], with preallocated packing scratch for the
//! activation hot path.  All kernel selection in the repo flows through
//! here — the coordinator's router, the models, the figure harnesses,
//! the benches and the CLI all build plans instead of naming kernel
//! functions.
//!
//! Three selection policies:
//!
//! * [`SelectPolicy::PaperRule`] — the paper's §4.6 split: single-batch
//!   sub-byte ops take the FullPack GEMV kernel of the data's variant;
//!   batched or 8-bit ops take the W8A8 path — now a first-class GEMM
//!   backend (`ruy-like-w8a8-gemm`, exactly the paper's "FullPack does
//!   not support GEMM" fallback), or the native `fullpack-*-gemm` tier
//!   when [`PlanBuilder::prefer_gemm`] is set (DESIGN.md §9).
//! * [`SelectPolicy::Explicit`] — a registry name (`--kernel` flags,
//!   benches, ablations), from either the GEMV or the GEMM namespace.
//! * [`SelectPolicy::CostModel`] — argmin of modeled cycles over every
//!   candidate backend via `costmodel::simulate_gemv` (batch 1) or
//!   `costmodel::simulate_gemm` (batched plans).

#![warn(missing_docs)]

use super::api::{GemmKernel, GemvKernel, Weights};
use super::lut::lut_kernel_name;
use super::registry::{fullpack_gemm_kernel_name, fullpack_kernel_name, KernelRegistry};
use super::swar::{swar_kernel_name, SWAR_MIN_DEPTH};
use super::{parallel, ActVec, KernelError};
use crate::costmodel::{simulate_gemm, simulate_gemv, CoreModel};
use crate::pack::{pack_into, BitWidth, Variant};
use crate::sim::CachePreset;
use std::sync::{Arc, Mutex};

const W8A8: Variant = Variant::new(BitWidth::B8, BitWidth::B8);

/// Smallest flushed batch the planner promotes onto a GEMM backend:
/// below two columns there is nothing to amortize, and the modeled
/// crossover curve (`costmodel::gemm_batch_threshold`) confirms it
/// sits at two columns for every GEMM-tier variant at serving shapes.
/// Since PR 4 that curve is **memory-aware** — computed from the
/// `sim::replay_gemm`-backed `costmodel::simulate_gemm`, where the
/// batched call replays one blocked weight pass and the repeated rival
/// re-streams the matrix per column at distinct addresses — and the
/// one-weight-pass cache advantage only widens the batched side's win,
/// so the compute-only v1 threshold of 2 carries over unchanged
/// (EXPERIMENTS.md crossover table; asserted in `costmodel` tests).
pub const GEMM_MIN_BATCH: usize = 2;

/// The layer shape a plan is bound to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerShape {
    /// output rows
    pub z: usize,
    /// logical input depth
    pub k: usize,
    /// columns per call (1 = GEMV)
    pub batch: usize,
}

/// How the builder picks a kernel.
#[derive(Debug, Clone)]
pub enum SelectPolicy {
    /// paper §4.6: single-batch sub-byte → FullPack; else the W8A8
    /// path (`ruy-w8a8` for single columns, the `ruy-like-w8a8-gemm`
    /// backend for batches ≥ [`GEMM_MIN_BATCH`] — or the native
    /// `fullpack-*-gemm` tier under [`PlanBuilder::prefer_gemm`]).
    /// With [`PlanBuilder::prefer_swar`] set, the FullPack branch takes
    /// the `-swar` tier when the variant has one and the depth clears
    /// [`SWAR_MIN_DEPTH`] (alignment is free: the packed layout is
    /// always a whole number of 8-byte chunks).
    PaperRule,
    /// a registry name, verbatim
    Explicit(String),
    /// argmin modeled cycles (`costmodel::simulate_gemv`) over all
    /// candidates; `calls` = steady-state warm-up calls for residency,
    /// `core` = the pipeline model costs are computed on (the SWAR tier
    /// wins only on cores whose `autovec_eff` marks the staged 16-lane
    /// loops as imperfectly vectorized)
    CostModel {
        /// cache hierarchy preset replayed for the stall model
        preset: CachePreset,
        /// steady-state warm-up calls before the measured call
        calls: usize,
        /// pipeline/throughput model of the target core
        core: CoreModel,
    },
}

impl SelectPolicy {
    /// Cost-model policy with the gem5 ex5_big defaults (the paper's
    /// simulated core: staged loops compile to perfect NEON).
    pub fn cost_model() -> SelectPolicy {
        SelectPolicy::CostModel {
            preset: CachePreset::Gem5Ex5Big,
            calls: 3,
            core: CoreModel::ex5_big(),
        }
    }

    /// Cost-model policy for a portable host whose auto-vectorizer
    /// cannot be trusted with the staged lane loops — the regime the
    /// SWAR tier exists for (DESIGN.md §8).
    pub fn cost_model_portable() -> SelectPolicy {
        SelectPolicy::CostModel {
            preset: CachePreset::Gem5Ex5Big,
            calls: 3,
            core: CoreModel::portable(),
        }
    }
}

/// Builder: shape + variant + knobs → [`Plan`].
///
/// ```
/// use fullpack::kernels::{LayerShape, PlanBuilder};
/// use fullpack::pack::Variant;
///
/// let shape = LayerShape { z: 8, k: 64, batch: 1 };
/// let plan = PlanBuilder::new(shape, Variant::parse("w4a8").unwrap())
///     .threads(2)
///     .build()
///     .unwrap();
/// assert_eq!(plan.kernel_name(), "fullpack-w4a8");
///
/// let w = vec![1i8; 8 * 64];
/// let a = vec![1i8; 64];
/// let weights = plan.prepare_weights(&w).unwrap();
/// let mut out = vec![0i32; 8];
/// plan.execute(&weights, &a, &mut out).unwrap();
/// assert!(out.iter().all(|&y| y == 64));
/// ```
pub struct PlanBuilder {
    shape: LayerShape,
    variant: Variant,
    threads: usize,
    policy: SelectPolicy,
    gemv_max_batch: usize,
    prefer_swar: bool,
    prefer_gemm: bool,
    gemm_min_batch: usize,
}

/// What the selection policy decided for one layer: the GEMV backend,
/// the batched-GEMM backend for batched plans (`None` for pure GEMV
/// plans), and the variant the chosen backend actually executes.
pub struct Selection {
    /// the GEMV backend (for batched plans: the same-layout single-column
    /// twin, kept for metadata — execution goes through `gemm`)
    pub kernel: Arc<dyn GemvKernel>,
    /// the batched-GEMM backend, when the plan is batch-first
    pub gemm: Option<Arc<dyn GemmKernel>>,
    /// what actually runs (`w8a8` when sub-byte data is widened onto
    /// the int8 fallback path)
    pub exec_variant: Variant,
}

impl Selection {
    /// Registry name of the backend that will execute this plan — the
    /// GEMM backend for batched plans, the GEMV kernel otherwise.
    pub fn name(&self) -> &'static str {
        self.gemm.as_ref().map(|g| g.name()).unwrap_or_else(|| self.kernel.name())
    }
}

impl PlanBuilder {
    /// Start a builder with the default policy ([`SelectPolicy::PaperRule`]),
    /// serial execution and the paper's batch threshold of 1.
    pub fn new(shape: LayerShape, variant: Variant) -> PlanBuilder {
        PlanBuilder {
            shape,
            variant,
            threads: 1,
            policy: SelectPolicy::PaperRule,
            gemv_max_batch: 1,
            prefer_swar: false,
            prefer_gemm: false,
            gemm_min_batch: GEMM_MIN_BATCH,
        }
    }

    /// Intra-op row-parallelism budget (1 = serial).
    pub fn threads(mut self, t: usize) -> PlanBuilder {
        self.threads = t.max(1);
        self
    }

    /// Replace the selection policy (default: [`SelectPolicy::PaperRule`]).
    pub fn policy(mut self, p: SelectPolicy) -> PlanBuilder {
        self.policy = p;
        self
    }

    /// Largest batch still routed to the GEMV path under `PaperRule`
    /// (paper: 1).
    pub fn gemv_max_batch(mut self, n: usize) -> PlanBuilder {
        self.gemv_max_batch = n;
        self
    }

    /// Under `PaperRule`, take the registered `-swar` tier instead of
    /// the staged scalar kernel when the variant has one and the padded
    /// depth is at least [`SWAR_MIN_DEPTH`] (default: off, preserving
    /// the paper's kernel choice).  Only the *sub-byte* GEMV branch is
    /// affected: 8-bit ops keep the paper's Ruy path, so
    /// `fullpack-w8a8-swar` is reachable only via
    /// [`SelectPolicy::Explicit`] or [`SelectPolicy::CostModel`].
    pub fn prefer_swar(mut self, yes: bool) -> PlanBuilder {
        self.prefer_swar = yes;
        self
    }

    /// Under `PaperRule`, route batched sub-byte ops to the native
    /// `fullpack-*-gemm` backend instead of widening onto the Ruy-like
    /// W8A8 GEMM path (default: off, preserving the paper's protocol).
    /// Applies when the variant has a GEMM-tier entry and the batch
    /// clears [`PlanBuilder::gemm_min_batch`].
    pub fn prefer_gemm(mut self, yes: bool) -> PlanBuilder {
        self.prefer_gemm = yes;
        self
    }

    /// Smallest batch promoted onto a GEMM backend (default:
    /// [`GEMM_MIN_BATCH`]).  Batched plans below it still execute
    /// correctly — as repeated GEMV through the GEMV kernel's default
    /// `gemm` — but carry no dedicated GEMM backend.
    pub fn gemm_min_batch(mut self, n: usize) -> PlanBuilder {
        self.gemm_min_batch = n.max(1);
        self
    }

    /// Select against the global registry.
    pub fn build(self) -> Result<Plan, KernelError> {
        self.build_in(KernelRegistry::global())
    }

    /// Select against a caller-supplied registry (custom backends).
    pub fn build_in(self, reg: &KernelRegistry) -> Result<Plan, KernelError> {
        let (shape, variant, threads) = (self.shape, self.variant, self.threads);
        let sel = self.select_in(reg)?;
        Ok(Plan {
            shape,
            variant,
            exec_variant: sel.exec_variant,
            threads,
            kernel: sel.kernel,
            gemm: sel.gemm,
            scratch: Mutex::new(PlanScratch::default()),
        })
    }

    /// Run the selection policy only (no plan construction): the chosen
    /// backends and the variant they will execute — the cheap path for
    /// callers that just need the routing decision.
    pub fn select(self) -> Result<Selection, KernelError> {
        self.select_in(KernelRegistry::global())
    }

    /// For a batched selection, the same-layout GEMV twin of a GEMM
    /// backend — `fullpack-wXa8` for the `fullpack-wXa8-gemm` tier,
    /// `lut-wXaY` for the `lut-wXaY-gemm` wrappers, `ruy-w8a8` for
    /// everything int8-rowed.  Only used as plan metadata; execution
    /// goes through the GEMM backend itself.
    fn gemv_twin(
        reg: &KernelRegistry,
        gemm_name: &str,
        ev: Variant,
    ) -> Result<Arc<dyn GemvKernel>, KernelError> {
        let name = if gemm_name.starts_with("fullpack-") {
            fullpack_kernel_name(ev)
        } else if gemm_name.starts_with("lut-") {
            lut_kernel_name(ev).unwrap_or("ruy-w8a8")
        } else {
            "ruy-w8a8"
        };
        reg.get(name)
            .cloned()
            .ok_or_else(|| KernelError::Unsupported(format!("unknown kernel {name:?}")))
    }

    /// [`PlanBuilder::select`] against a caller-supplied registry.
    pub fn select_in(self, reg: &KernelRegistry) -> Result<Selection, KernelError> {
        let LayerShape { z, k, batch } = self.shape;
        let lookup = |name: &str| -> Result<Arc<dyn GemvKernel>, KernelError> {
            reg.get(name)
                .cloned()
                .ok_or_else(|| KernelError::Unsupported(format!("unknown kernel {name:?}")))
        };
        // a kernel can run the variant natively, or run it widened to
        // int8 (the paper's Ruy fallback for sub-byte data)
        let exec_for = |kern: &Arc<dyn GemvKernel>| -> Option<Variant> {
            if kern.supports(self.variant) {
                Some(self.variant)
            } else if kern.supports(W8A8) {
                Some(W8A8)
            } else {
                None
            }
        };
        let gemv_only = |kernel: Arc<dyn GemvKernel>, ev: Variant| Selection {
            kernel,
            gemm: None,
            exec_variant: ev,
        };
        let selection = match &self.policy {
            SelectPolicy::Explicit(name) => {
                // the GEMM namespace is disjoint (`-gemm` suffix); an
                // explicit GEMM name builds a batch-first plan
                if let Some(g) = reg.get_gemm(name) {
                    let g = g.clone();
                    let ev = if g.supports(self.variant) {
                        self.variant
                    } else if g.supports(W8A8) {
                        W8A8
                    } else {
                        return Err(KernelError::Unsupported(format!(
                            "{} cannot run {}",
                            g.name(),
                            self.variant
                        )));
                    };
                    let kernel = Self::gemv_twin(reg, name, ev)?;
                    Selection { kernel, gemm: Some(g), exec_variant: ev }
                } else {
                    let kern = lookup(name)?;
                    let ev = exec_for(&kern).ok_or_else(|| {
                        KernelError::Unsupported(format!(
                            "{} cannot run {}",
                            kern.name(),
                            self.variant
                        ))
                    })?;
                    gemv_only(kern, ev)
                }
            }
            SelectPolicy::PaperRule => {
                let sub = self.variant.w.is_sub_byte() || self.variant.a.is_sub_byte();
                if sub && batch <= self.gemv_max_batch {
                    let mut name = fullpack_kernel_name(self.variant);
                    if self.prefer_swar && self.variant.padded_depth(k) >= SWAR_MIN_DEPTH {
                        if let Some(sw) = swar_kernel_name(self.variant) {
                            if reg.get(sw).is_some() {
                                name = sw;
                            }
                        }
                    }
                    gemv_only(lookup(name)?, self.variant)
                } else {
                    // batched (or 8-bit) path: a first-class GEMM plan.
                    // `prefer_gemm` takes the native sub-byte tier; the
                    // default is the paper's Ruy-like W8A8 protocol.
                    if self.prefer_gemm && batch >= self.gemm_min_batch {
                        if let Some(gname) = fullpack_gemm_kernel_name(self.variant) {
                            if let Some(g) = reg.get_gemm(gname) {
                                return Ok(Selection {
                                    kernel: lookup(fullpack_kernel_name(self.variant))?,
                                    gemm: Some(g.clone()),
                                    exec_variant: self.variant,
                                });
                            }
                        }
                    }
                    let kernel = lookup("ruy-w8a8")?;
                    // single-column 8-bit ops stay pure GEMV plans; a
                    // registry without the GEMM tier degrades gracefully
                    // to the old repeated-GEMV behavior
                    let gemm = if batch >= self.gemm_min_batch {
                        reg.get_gemm("ruy-like-w8a8-gemm").cloned()
                    } else {
                        None
                    };
                    Selection { kernel, gemm, exec_variant: W8A8 }
                }
            }
            SelectPolicy::CostModel { preset, calls, core } => {
                if batch > 1 {
                    // argmin modeled cycles across BOTH tiers: every
                    // GEMM backend (one amortized call) and every GEMV
                    // candidate modeled as `batch` repeated calls
                    // (`simulate_gemm` handles both shapes) — a GEMM
                    // backend wins only when the model actually scores
                    // it below the best repeated-GEMV plan
                    let mut best_gemm: Option<(f64, Arc<dyn GemmKernel>, Variant)> = None;
                    for g in reg.gemm_iter() {
                        let ev = if g.supports(self.variant) {
                            self.variant
                        } else if g.supports(W8A8) {
                            W8A8
                        } else {
                            continue;
                        };
                        let Some(method) = g.cost_method() else { continue };
                        let cycles =
                            simulate_gemm(method, z, k, batch, *preset, core, *calls).cycles;
                        let better = match &best_gemm {
                            None => true,
                            Some((c, _, _)) => cycles < *c,
                        };
                        if better {
                            best_gemm = Some((cycles, g.clone(), ev));
                        }
                    }
                    let mut best_gemv: Option<(f64, Arc<dyn GemvKernel>, Variant)> = None;
                    for kern in reg.iter() {
                        let Some(ev) = exec_for(kern) else { continue };
                        let Some(method) = kern.cost_method() else { continue };
                        // ISA-tier methods are meaningless on cores
                        // narrower than their lanes (DESIGN.md §15)
                        if method.min_lane_bytes() > core.vec_bytes {
                            continue;
                        }
                        let cycles =
                            simulate_gemm(method, z, k, batch, *preset, core, *calls).cycles;
                        let better = match &best_gemv {
                            None => true,
                            Some((c, _, _)) => cycles < *c,
                        };
                        if better {
                            best_gemv = Some((cycles, kern.clone(), ev));
                        }
                    }
                    let gemm_wins = match (&best_gemm, &best_gemv) {
                        (Some((cg, _, _)), Some((cv, _, _))) => cg < cv,
                        (Some(_), None) => true,
                        _ => false,
                    };
                    if gemm_wins {
                        let (_, g, ev) = best_gemm.expect("gemm_wins implies a candidate");
                        let kernel = Self::gemv_twin(reg, g.name(), ev)?;
                        return Ok(Selection { kernel, gemm: Some(g), exec_variant: ev });
                    }
                    if let Some((_, kern, ev)) = best_gemv {
                        return Ok(gemv_only(kern, ev));
                    }
                    return Err(KernelError::Unsupported(format!(
                        "no registered kernel runs {}",
                        self.variant
                    )));
                }
                let mut best: Option<(f64, Arc<dyn GemvKernel>, Variant)> = None;
                for kern in reg.iter() {
                    let Some(ev) = exec_for(kern) else { continue };
                    let Some(method) = kern.cost_method() else { continue };
                    // a core cannot run ISA kernels wider than its
                    // vector registers — skip, don't mis-model
                    if method.min_lane_bytes() > core.vec_bytes {
                        continue;
                    }
                    let cycles = simulate_gemv(method, z, k, *preset, core, *calls).cycles;
                    let better = match &best {
                        None => true,
                        Some((c, _, _)) => cycles < *c,
                    };
                    if better {
                        best = Some((cycles, kern.clone(), ev));
                    }
                }
                let (_, kern, ev) = best.ok_or_else(|| {
                    KernelError::Unsupported(format!("no registered kernel runs {}", self.variant))
                })?;
                gemv_only(kern, ev)
            }
        };
        Ok(selection)
    }
}

/// Reusable activation pad/pack buffers.  Every plan owns one behind a
/// `try_lock`; hot loops that share a plan across threads (the serving
/// engine's LSTM scan) pass their own via [`Plan::execute_in`] so the
/// steady state never allocates.
#[derive(Default)]
pub struct PlanScratch {
    padded: Vec<i8>,
    packed: Vec<u8>,
}

/// A bound execution plan: shape + variant + thread budget + the chosen
/// kernel(s), with reusable activation-packing scratch.  Batched plans
/// additionally carry a [`GemmKernel`] backend; for those, every
/// execution path (including single-column [`Plan::execute`]) goes
/// through the GEMM backend, and the GEMV member is the same-layout
/// single-column twin kept for metadata.
pub struct Plan {
    /// the layer shape the plan is bound to
    pub shape: LayerShape,
    /// the data's quantization variant
    pub variant: Variant,
    /// what the kernel actually runs (`w8a8` when sub-byte data is
    /// widened onto the int8 fallback path)
    pub exec_variant: Variant,
    /// default intra-op thread budget for [`Plan::execute`]
    pub threads: usize,
    kernel: Arc<dyn GemvKernel>,
    gemm: Option<Arc<dyn GemmKernel>>,
    scratch: Mutex<PlanScratch>,
}

impl std::fmt::Debug for Plan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Plan")
            .field("kernel", &self.kernel_name())
            .field("gemm", &self.gemm_kernel_name())
            .field("shape", &self.shape)
            .field("variant", &self.variant)
            .field("exec_variant", &self.exec_variant)
            .field("threads", &self.threads)
            .finish()
    }
}

impl Plan {
    /// Registry name of the backend that executes this plan — the GEMM
    /// backend for batched plans, the GEMV kernel otherwise.
    pub fn kernel_name(&self) -> &'static str {
        self.gemm.as_ref().map(|g| g.name()).unwrap_or_else(|| self.kernel.name())
    }

    /// The selected GEMV backend (e.g. to wrap in `RowParallel`).  For
    /// batched plans this is the same-layout single-column twin.
    pub fn kernel(&self) -> &Arc<dyn GemvKernel> {
        &self.kernel
    }

    /// The selected batched-GEMM backend, for batch-first plans.
    pub fn gemm_kernel(&self) -> Option<&Arc<dyn GemmKernel>> {
        self.gemm.as_ref()
    }

    /// Registry name of the batched-GEMM backend, for batch-first plans.
    pub fn gemm_kernel_name(&self) -> Option<&'static str> {
        self.gemm.as_ref().map(|g| g.name())
    }

    /// Is this a batch-first plan (a GEMM backend executes it)?
    pub fn is_batched(&self) -> bool {
        self.gemm.is_some()
    }

    /// Did selection land on the FullPack family (GEMV or GEMM tier)?
    pub fn is_fullpack(&self) -> bool {
        self.kernel_name().starts_with("fullpack-")
    }

    /// Pack a row-major `z × k` int8 weight matrix into the chosen
    /// backend's layout (the GEMM backend's for batched plans; its
    /// layout matches the GEMV twin's by construction).
    pub fn prepare_weights(&self, w: &[i8]) -> Result<Weights, KernelError> {
        match &self.gemm {
            Some(g) => g.prepare(w, self.shape.z, self.shape.k),
            None => self.kernel.prepare(w, self.shape.z, self.shape.k),
        }
    }

    /// One GEMV with the plan's thread budget.  `a` is the logical-depth
    /// int8 activation vector; padding and sub-byte packing happen in
    /// the plan's scratch.
    pub fn execute(&self, w: &Weights, a: &[i8], out: &mut [i32]) -> Result<(), KernelError> {
        self.execute_with_threads(w, a, out, self.threads)
    }

    /// Borrow the plan's preallocated scratch, or a fresh local one
    /// when a concurrent call holds it — contenders never serialize
    /// behind each other's kernel execution.
    fn with_scratch<R>(&self, f: impl FnOnce(&mut PlanScratch) -> R) -> R {
        match self.scratch.try_lock() {
            Ok(mut guard) => f(&mut guard),
            Err(_) => f(&mut PlanScratch::default()),
        }
    }

    /// [`Plan::execute`] with an explicit thread budget (the serving
    /// engine's per-request intra-op knob).
    pub fn execute_with_threads(
        &self,
        w: &Weights,
        a: &[i8],
        out: &mut [i32],
        threads: usize,
    ) -> Result<(), KernelError> {
        self.with_scratch(|scratch| self.execute_in(w, a, out, threads, scratch))
    }

    /// [`Plan::execute`] with caller-owned scratch — the allocation-free
    /// path for hot loops that share one plan across threads (each
    /// caller keeps its own [`PlanScratch`]).
    pub fn execute_in(
        &self,
        w: &Weights,
        a: &[i8],
        out: &mut [i32],
        threads: usize,
        scratch: &mut PlanScratch,
    ) -> Result<(), KernelError> {
        if out.len() != w.rows() {
            return Err(KernelError::Shape(format!(
                "out len {} != rows {}",
                out.len(),
                w.rows()
            )));
        }
        // short activations would be silently zero-padded into a wrong
        // dot product; callers may pass pre-padded vectors (>= k)
        if a.len() < self.shape.k {
            return Err(KernelError::Shape(format!(
                "activation len {} < layer depth {}",
                a.len(),
                self.shape.k
            )));
        }
        // batch-first plans run every call — even a single column —
        // through the GEMM backend (the GEMV twin is metadata; the
        // thread budget is ignored, batching is the parallelism axis)
        if let Some(g) = &self.gemm {
            let kp = w.k_padded();
            return if a.len() < kp {
                scratch.padded.clear();
                scratch.padded.extend_from_slice(a);
                scratch.padded.resize(kp, 0);
                g.gemm(w, &[scratch.padded.as_slice()], out)
            } else {
                g.gemm(w, &[a], out)
            };
        }
        let kp = w.k_padded();
        let act = if self.kernel.packs_activations() {
            scratch.padded.clear();
            scratch.padded.extend_from_slice(a);
            scratch.padded.resize(kp.max(a.len()), 0);
            pack_into(&scratch.padded[..kp], self.exec_variant.a, &mut scratch.packed);
            ActVec::Packed { bytes: &scratch.packed, bits: self.exec_variant.a }
        } else if kp > a.len() {
            scratch.padded.clear();
            scratch.padded.extend_from_slice(a);
            scratch.padded.resize(kp, 0);
            ActVec::I8(&scratch.padded)
        } else {
            ActVec::I8(a)
        };
        let kernel = &*self.kernel;
        if threads > 1 {
            parallel::shard_rows(out, 0, threads, |chunk, lo| kernel.gemv_at(w, act, chunk, lo))
        } else {
            kernel.gemv_at(w, act, out, 0)
        }
    }

    /// Batched execution: `a` holds `batch` row-major columns of depth
    /// `k`; `out[c*z..(c+1)*z]` receives column `c`.  Batch-first plans
    /// dispatch one [`GemmKernel::gemm`] call; GEMV plans fall back to
    /// the kernel's own `gemm` (FullPack kernels take their batched
    /// extension there, everything else runs repeated GEMV — the
    /// paper's protocol).
    pub fn execute_batch(
        &self,
        w: &Weights,
        a: &[i8],
        batch: usize,
        out: &mut [i32],
    ) -> Result<(), KernelError> {
        let k = self.shape.k;
        if a.len() != batch * k {
            return Err(KernelError::Shape(format!(
                "activations len {} != batch*k {}",
                a.len(),
                batch * k
            )));
        }
        let kp = w.k_padded();
        if kp > k {
            self.with_scratch(|scratch| {
                scratch.padded.clear();
                scratch.padded.resize(batch * kp, 0);
                for b in 0..batch {
                    scratch.padded[b * kp..b * kp + k].copy_from_slice(&a[b * k..(b + 1) * k]);
                }
                let padded = &scratch.padded;
                let cols: Vec<&[i8]> = (0..batch).map(|b| &padded[b * kp..(b + 1) * kp]).collect();
                self.dispatch_gemm(w, &cols, out)
            })
        } else {
            let cols: Vec<&[i8]> = (0..batch).map(|b| &a[b * k..(b + 1) * k]).collect();
            self.dispatch_gemm(w, &cols, out)
        }
    }

    /// One batched call on whichever backend owns this plan's batches:
    /// the GEMM backend for batch-first plans, otherwise the GEMV
    /// kernel's own `gemm` default/override.  With a thread budget > 1
    /// a batch-first plan is sharded by output row-tiles
    /// (`parallel::shard_gemm_rows` → [`GemmKernel::gemm_at`]), the
    /// same intra-op axis `RowParallel` gives the GEMV tier — the
    /// serving engine's flushed batches inherit it through
    /// [`Plan::execute_batch`].
    fn dispatch_gemm(
        &self,
        w: &Weights,
        cols: &[&[i8]],
        out: &mut [i32],
    ) -> Result<(), KernelError> {
        match &self.gemm {
            Some(g) if self.threads > 1 => {
                // shape check (out == rows*batch) happens inside
                let g = &**g;
                parallel::shard_gemm_rows(
                    out,
                    w.rows(),
                    cols.len(),
                    self.threads,
                    |tile, lo, _hi| g.gemm_at(w, cols, tile, lo),
                )
            }
            Some(g) => g.gemm(w, cols, out),
            None => self.kernel.gemm(w, cols, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testutil::{oracle_gemv, pad_rows, rngvals};

    fn shape(z: usize, k: usize, batch: usize) -> LayerShape {
        LayerShape { z, k, batch }
    }

    #[test]
    fn paper_rule_reproduces_router_decisions() {
        let w4a8 = Variant::parse("w4a8").unwrap();
        let w8a8 = Variant::parse("w8a8").unwrap();
        // single-batch sub-byte LSTM step -> FullPack GEMV
        let p = PlanBuilder::new(shape(2048, 2048, 1), w4a8).build().unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a8");
        assert!(p.is_fullpack());
        assert!(!p.is_batched());
        // batch-16 FC -> the Ruy-like W8A8 GEMM backend even when
        // quantized sub-byte (the paper's protocol, now first-class)
        let p = PlanBuilder::new(shape(2048, 2048, 16), w4a8).build().unwrap();
        assert_eq!(p.kernel_name(), "ruy-like-w8a8-gemm");
        assert_eq!(p.gemm_kernel_name(), Some("ruy-like-w8a8-gemm"));
        assert_eq!(p.kernel().name(), "ruy-w8a8"); // the GEMV twin
        assert_eq!(p.exec_variant, W8A8);
        assert!(p.is_batched());
        // single-column 8-bit ops stay pure GEMV plans on the baseline
        let p = PlanBuilder::new(shape(2048, 2048, 1), w8a8).build().unwrap();
        assert_eq!(p.kernel_name(), "ruy-w8a8");
        assert!(!p.is_batched());
        // raised batch threshold keeps the GEMV path
        let p = PlanBuilder::new(shape(2048, 2048, 4), w4a8).gemv_max_batch(4).build().unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a8");
    }

    #[test]
    fn prefer_gemm_promotes_subbyte_batches_to_the_gemm_tier() {
        let w4a8 = Variant::parse("w4a8").unwrap();
        // batched sub-byte + opt-in -> the native FullPack GEMM backend
        let p = PlanBuilder::new(shape(256, 512, 16), w4a8).prefer_gemm(true).build().unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a8-gemm");
        assert_eq!(p.kernel().name(), "fullpack-w4a8"); // same-layout twin
        assert_eq!(p.exec_variant, w4a8);
        assert!(p.is_fullpack() && p.is_batched());
        // default keeps the paper's Ruy protocol
        let p = PlanBuilder::new(shape(256, 512, 16), w4a8).build().unwrap();
        assert_eq!(p.kernel_name(), "ruy-like-w8a8-gemm");
        // variants without a GEMM-tier entry fall back to the rival
        let w4a4 = Variant::parse("w4a4").unwrap();
        let p = PlanBuilder::new(shape(256, 512, 16), w4a4).prefer_gemm(true).build().unwrap();
        assert_eq!(p.kernel_name(), "ruy-like-w8a8-gemm");
        // below gemm_min_batch the opt-in does not engage
        let p = PlanBuilder::new(shape(256, 512, 16), w4a8)
            .prefer_gemm(true)
            .gemm_min_batch(32)
            .build()
            .unwrap();
        assert_eq!(p.kernel_name(), "ruy-w8a8");
        assert!(!p.is_batched());
    }

    #[test]
    fn explicit_gemm_names_build_batch_first_plans() {
        let w2a8 = Variant::parse("w2a8").unwrap();
        let p = PlanBuilder::new(shape(16, 96, 4), w2a8)
            .policy(SelectPolicy::Explicit("fullpack-w2a8-gemm".into()))
            .build()
            .unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w2a8-gemm");
        assert!(p.is_batched());
        // the oracle runs any wXa8 data
        let p = PlanBuilder::new(shape(16, 96, 4), w2a8)
            .policy(SelectPolicy::Explicit("naive-oracle-gemm".into()))
            .build()
            .unwrap();
        assert_eq!(p.kernel_name(), "naive-oracle-gemm");
        // a GEMM backend that cannot run the variant is a build error
        let w4a4 = Variant::parse("w4a4").unwrap();
        assert!(PlanBuilder::new(shape(16, 96, 4), w4a4)
            .policy(SelectPolicy::Explicit("fullpack-w2a8-gemm".into()))
            .build()
            .is_err());
    }

    #[test]
    fn explicit_lut_plans_execute_both_namespaces() {
        let v = Variant::parse("w4a8").unwrap();
        let (z, k) = (16usize, 77usize);
        let kp = v.padded_depth(k);
        let w = rngvals(v.w, z * k, 61);
        let wp = pad_rows(&w, z, k, kp);
        // GEMV namespace
        let p = PlanBuilder::new(shape(z, k, 1), v)
            .policy(SelectPolicy::Explicit("lut-w4a8".into()))
            .build()
            .unwrap();
        assert_eq!(p.kernel_name(), "lut-w4a8");
        assert!(!p.is_batched());
        let a = rngvals(v.a, k, 62);
        let wts = p.prepare_weights(&w).unwrap();
        let mut out = vec![0i32; z];
        p.execute(&wts, &a, &mut out).unwrap();
        let mut ap = a.clone();
        ap.resize(kp, 0);
        assert_eq!(out, oracle_gemv(&wp, &ap, z, kp));
        // GEMM namespace: batch-first plan with the same-layout twin
        let batch = 5;
        let p = PlanBuilder::new(shape(z, k, batch), v)
            .policy(SelectPolicy::Explicit("lut-w4a8-gemm".into()))
            .build()
            .unwrap();
        assert_eq!(p.kernel_name(), "lut-w4a8-gemm");
        assert_eq!(p.kernel().name(), "lut-w4a8");
        assert!(p.is_batched());
        let ab = rngvals(v.a, batch * k, 63);
        let wts = p.prepare_weights(&w).unwrap();
        let mut outb = vec![0i32; batch * z];
        p.execute_batch(&wts, &ab, batch, &mut outb).unwrap();
        for b in 0..batch {
            let mut col = ab[b * k..(b + 1) * k].to_vec();
            col.resize(kp, 0);
            assert_eq!(
                &outb[b * z..(b + 1) * z],
                oracle_gemv(&wp, &col, z, kp).as_slice(),
                "col {b}"
            );
        }
        // w4a4: the planner's activation-packing path feeds the LUT
        // kernel packed sub-byte activations
        let w4a4 = Variant::parse("w4a4").unwrap();
        let p = PlanBuilder::new(shape(z, k, 1), w4a4)
            .policy(SelectPolicy::Explicit("lut-w4a4".into()))
            .build()
            .unwrap();
        assert!(p.kernel().packs_activations());
        let w4 = rngvals(w4a4.w, z * k, 64);
        let a4 = rngvals(w4a4.a, k, 65);
        let wts4 = p.prepare_weights(&w4).unwrap();
        let mut out4 = vec![0i32; z];
        p.execute(&wts4, &a4, &mut out4).unwrap();
        let kp4 = w4a4.padded_depth(k);
        let wp4 = pad_rows(&w4, z, k, kp4);
        let mut ap4 = a4.clone();
        ap4.resize(kp4, 0);
        assert_eq!(out4, oracle_gemv(&wp4, &ap4, z, kp4));
    }

    #[test]
    fn cost_model_selects_the_fullpack_gemm_tier_for_batches() {
        // batched sub-byte at the LLC boundary: the amortized FullPack
        // GEMM backend must beat `batch` repeated Ruy calls
        let v = Variant::parse("w4a8").unwrap();
        let p = PlanBuilder::new(shape(2048, 2048, 16), v)
            .policy(SelectPolicy::cost_model())
            .build()
            .unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a8-gemm");
        assert!(p.is_batched());
        // a variant with no GEMM-tier entry: the cross-tier argmin
        // keeps the repeated FullPack GEMV plan — it must NOT fall onto
        // the modeled-worse widened Ruy GEMM backend
        let w4a4 = Variant::parse("w4a4").unwrap();
        let p = PlanBuilder::new(shape(2048, 2048, 16), w4a4)
            .policy(SelectPolicy::cost_model())
            .build()
            .unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a4");
        assert!(!p.is_batched());
    }

    #[test]
    fn cost_model_picks_fullpack_at_the_boundary() {
        // paper §4.4 regime: 2048x2048, packed weights fit the 2MB LLC,
        // W8A8 does not — the model must prefer fullpack-w4a8 over
        // ruy-w8a8 (and every other W8A8/FP32 candidate).  On the ex5
        // core the staged loops compile to perfect NEON, so the scalar
        // tier beats its own SWAR sibling too.
        let v = Variant::parse("w4a8").unwrap();
        let p = PlanBuilder::new(shape(2048, 2048, 1), v)
            .policy(SelectPolicy::cost_model())
            .build()
            .unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a8");
    }

    #[test]
    fn portable_cost_model_selects_the_swar_tier() {
        // on a core whose auto-vectorizer cannot be trusted with the
        // staged lane loops, the vectorization-independent SWAR tier
        // wins for the low bit-widths (DESIGN.md §8)
        let v = Variant::parse("w1a8").unwrap();
        let p = PlanBuilder::new(shape(2048, 2048, 1), v)
            .policy(SelectPolicy::cost_model_portable())
            .build()
            .unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w1a8-swar");
    }

    #[test]
    fn cost_model_prefers_the_isa_tier_on_wide_cores() {
        use crate::kernels::{isa, IsaSupport};
        use crate::sim::CachePreset;
        // force-register every ISA backend in a LOCAL registry:
        // selection is pure modeling and nothing below executes, so the
        // roster need not be runnable on the test host (the global
        // registry stays strictly detection-gated)
        let mut reg = KernelRegistry::with_builtins();
        isa::register_isa_backends(&mut reg, IsaSupport { avx2: true, neon: true });
        let v = Variant::parse("w4a8").unwrap();
        let policy = |core: CoreModel| SelectPolicy::CostModel {
            preset: CachePreset::Gem5Ex5Big,
            calls: 3,
            core,
        };
        let select = |core: CoreModel| {
            PlanBuilder::new(shape(2048, 2048, 1), v)
                .policy(policy(core))
                .select_in(&reg)
                .unwrap()
        };
        // 256-bit core: the AVX2 entry wins the w4a8 serving shape
        assert_eq!(select(CoreModel::avx2()).name(), "fullpack-w4a8-avx2");
        // 128-bit core with untrusted autovec: the NEON entry wins and
        // the 32-byte AVX2 entry is gated out by vec_bytes, not merely
        // outscored
        assert_eq!(select(CoreModel::neon()).name(), "fullpack-w4a8-neon");
        // the paper's ex5 core (perfect staged codegen): the staged
        // kernel stays ahead of the hand-written NEON tier, so the §4.4
        // calibration pins don't move when ISA entries are present
        assert_eq!(select(CoreModel::ex5_big()).name(), "fullpack-w4a8");
        // a vec_bytes = 0 portable core never models an ISA entry
        let name = select(CoreModel::portable()).name();
        assert!(
            !name.ends_with("-avx2") && !name.ends_with("-neon"),
            "portable core picked ISA entry {name}"
        );
    }

    #[test]
    fn batched_plans_shard_gemm_by_row_tiles() {
        // a batch-first plan with a thread budget: dispatch_gemm goes
        // through shard_gemm_rows/gemm_at and stays bit-identical to
        // the serial plan (rows large enough to actually spawn shards)
        let v = Variant::parse("w4a8").unwrap();
        let (z, k, batch) = (1024usize, 64usize, 4usize);
        let serial =
            PlanBuilder::new(shape(z, k, batch), v).prefer_gemm(true).build().unwrap();
        let w = rngvals(v.w, z * k, 51);
        let a = rngvals(v.a, batch * k, 52);
        let wts = serial.prepare_weights(&w).unwrap();
        let mut base = vec![0i32; batch * z];
        serial.execute_batch(&wts, &a, batch, &mut base).unwrap();
        for threads in [2usize, 4] {
            let plan = PlanBuilder::new(shape(z, k, batch), v)
                .prefer_gemm(true)
                .threads(threads)
                .build()
                .unwrap();
            assert!(plan.is_batched());
            let mut out = vec![0i32; batch * z];
            plan.execute_batch(&wts, &a, batch, &mut out).unwrap();
            assert_eq!(out, base, "threads={threads}");
        }
    }

    #[test]
    fn paper_rule_prefer_swar_gates_on_depth_and_tier() {
        let w4a8 = Variant::parse("w4a8").unwrap();
        // deep layer + opt-in -> the SWAR tier
        let p = PlanBuilder::new(shape(256, 2048, 1), w4a8).prefer_swar(true).build().unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a8-swar");
        assert!(p.is_fullpack());
        // below SWAR_MIN_DEPTH the flush/bias overhead dominates ->
        // stay on the staged kernel (k=1 pads to one 32-element group)
        let p = PlanBuilder::new(shape(256, 1, 1), w4a8).prefer_swar(true).build().unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a8");
        // variants without a SWAR backend keep the scalar kernel
        let w4a4 = Variant::parse("w4a4").unwrap();
        let p = PlanBuilder::new(shape(256, 2048, 1), w4a4).prefer_swar(true).build().unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a4");
        // default stays the paper's kernel choice
        let p = PlanBuilder::new(shape(256, 2048, 1), w4a8).build().unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a8");
    }

    #[test]
    fn prefer_swar_plans_execute_correctly() {
        for (vname, k) in [("w4a8", 129usize), ("w2a8", 200), ("w1a8", 501)] {
            let v = Variant::parse(vname).unwrap();
            let z = 16;
            let plan =
                PlanBuilder::new(shape(z, k, 1), v).prefer_swar(true).build().unwrap();
            assert!(plan.kernel_name().ends_with("-swar"), "{vname}");
            let w = rngvals(v.w, z * k, 41 + k as u64);
            let a = rngvals(v.a, k, 43 + k as u64);
            let wts = plan.prepare_weights(&w).unwrap();
            let mut out = vec![0i32; z];
            plan.execute(&wts, &a, &mut out).unwrap();
            let kp = v.padded_depth(k);
            let wp = pad_rows(&w, z, k, kp);
            let mut ap = a.clone();
            ap.resize(kp, 0);
            assert_eq!(out, oracle_gemv(&wp, &ap, z, kp), "{vname} k={k}");
        }
    }

    #[test]
    fn explicit_policy_and_errors() {
        let v = Variant::parse("w2a2").unwrap();
        let p = PlanBuilder::new(shape(64, 128, 1), v)
            .policy(SelectPolicy::Explicit("ulppack-w2a2".into()))
            .build()
            .unwrap();
        assert_eq!(p.kernel_name(), "ulppack-w2a2");
        assert!(PlanBuilder::new(shape(64, 128, 1), v)
            .policy(SelectPolicy::Explicit("no-such-kernel".into()))
            .build()
            .is_err());
        // naive-w4a8 cannot run w2a2 natively nor widened
        assert!(PlanBuilder::new(shape(64, 128, 1), v)
            .policy(SelectPolicy::Explicit("naive-w4a8".into()))
            .build()
            .is_err());
    }

    #[test]
    fn execute_pads_and_packs_unaligned_depths() {
        for vname in ["w4a8", "w4a4", "w2a2", "w8a4"] {
            let v = Variant::parse(vname).unwrap();
            for k in [1usize, 17, 127, 129] {
                let z = 8;
                let plan = PlanBuilder::new(shape(z, k, 1), v).build().unwrap();
                let w = rngvals(v.w, z * k, 7 + k as u64);
                let a = rngvals(v.a, k, 9 + k as u64);
                let wts = plan.prepare_weights(&w).unwrap();
                let mut out = vec![0i32; z];
                plan.execute(&wts, &a, &mut out).unwrap();
                let kp = v.padded_depth(k);
                let wp = pad_rows(&w, z, k, kp);
                let mut ap = a.clone();
                ap.resize(kp, 0);
                assert_eq!(out, oracle_gemv(&wp, &ap, z, kp), "{vname} k={k}");
            }
        }
    }

    #[test]
    fn execute_batch_matches_per_column() {
        let v = Variant::parse("w4a8").unwrap();
        let (z, k, batch) = (16usize, 64usize, 3usize);
        let plan = PlanBuilder::new(shape(z, k, 1), v).build().unwrap();
        let w = rngvals(v.w, z * k, 21);
        let a = rngvals(v.a, batch * k, 22);
        let wts = plan.prepare_weights(&w).unwrap();
        let mut out = vec![0i32; batch * z];
        plan.execute_batch(&wts, &a, batch, &mut out).unwrap();
        for b in 0..batch {
            let col = &a[b * k..(b + 1) * k];
            assert_eq!(&out[b * z..(b + 1) * z], oracle_gemv(&w, col, z, k).as_slice(), "col {b}");
        }
    }

    #[test]
    fn batch_first_plans_execute_both_paths() {
        // a gemm-first plan: execute_batch is one GemmKernel call, and
        // single-column execute routes through the same backend with
        // identical results (incl. an unaligned, padded depth)
        for vname in ["w4a8", "w2a8", "w1a8"] {
            let v = Variant::parse(vname).unwrap();
            let (z, k, batch) = (16usize, 77usize, 5usize);
            let plan = PlanBuilder::new(shape(z, k, batch), v).prefer_gemm(true).build().unwrap();
            assert!(plan.kernel_name().ends_with("-gemm"), "{vname}");
            let w = rngvals(v.w, z * k, 31);
            let a = rngvals(v.a, batch * k, 32);
            let wts = plan.prepare_weights(&w).unwrap();
            let mut out = vec![0i32; batch * z];
            plan.execute_batch(&wts, &a, batch, &mut out).unwrap();
            let kp = wts.k_padded();
            let wp = pad_rows(&w, z, k, kp);
            for b in 0..batch {
                let mut col = a[b * k..(b + 1) * k].to_vec();
                col.resize(kp, 0);
                assert_eq!(
                    &out[b * z..(b + 1) * z],
                    oracle_gemv(&wp, &col, z, kp).as_slice(),
                    "{vname} col {b}"
                );
                // single-column execute on the same weights
                let mut one = vec![0i32; z];
                plan.execute(&wts, &a[b * k..(b + 1) * k], &mut one).unwrap();
                assert_eq!(one.as_slice(), &out[b * z..(b + 1) * z], "{vname} col {b}");
            }
        }
    }

    #[test]
    fn threads_do_not_change_results() {
        let v = Variant::parse("w2a2").unwrap();
        let (z, k) = (1024usize, 256usize);
        let plan = PlanBuilder::new(shape(z, k, 1), v).threads(4).build().unwrap();
        let w = rngvals(v.w, z * k, 31);
        let a = rngvals(v.a, k, 32);
        let wts = plan.prepare_weights(&w).unwrap();
        let mut par = vec![0i32; z];
        plan.execute(&wts, &a, &mut par).unwrap();
        let mut serial = vec![0i32; z];
        plan.execute_with_threads(&wts, &a, &mut serial, 1).unwrap();
        assert_eq!(par, serial);
    }
}

//! The FullPack GEMV kernels (paper §3.2, Alg. 2, Fig. 3) as 16-lane
//! SWAR loops.
//!
//! Structure per 16-byte weight block (Alg. 2 lines 6–13):
//!
//! ```text
//!   V0 ← load 16 packed bytes                 (one vector load)
//!   for k in 0..E:                            (E = 8/bits sub-vectors)
//!     Vk ← ASR(LSL(V0, 8-(k+1)b), 8-b)        (2 shifts; top one: 1 ASR)
//!     ACC ← FMA(Vk, A[blk, k], ACC)           (lane MAC into i32)
//!   out[i] ← ElementWiseAdd(ACC)              (final lane reduction)
//! ```
//!
//! The shift amounts are compile-time constants through `const B`, so
//! each instantiation mirrors one of the paper's nine hand-written
//! kernels.  Lanes are fixed-size `[i8; VL]` / `[i32; VL]` arrays staged
//! with `copy_from_slice` — the shape LLVM's SLP vectorizer reliably
//! turns into the target's SIMD (the NEON analog on AArch64, AVX2 on
//! x86-64; see EXPERIMENTS.md §Perf for the before/after of this
//! choice).  The computation's *shape* (loads per useful element,
//! shifts per block, MACs per lane) is identical to the paper's
//! assembly, which is what the cost model counts.

use crate::pack::{PackedMatrix, VL};

/// Extract sub-vector element `k` from a packed byte: the two-shift
/// mask+sign-extend schedule.  `B` is the element bit-width.  Shared
/// with the SWAR tier's scalar tail fallback (`kernels::swar`).
#[inline(always)]
pub(crate) fn extract<const B: usize>(byte: i8, k: usize) -> i8 {
    let lsl = 8 - (k + 1) * B; // 0 for the top sub-vector (single ASR)
    ((byte << lsl) as i8) >> (8 - B)
}

/// Extract all E sub-vectors of one 16-byte block into `E × VL` lanes.
#[inline(always)]
fn extract_block<const B: usize>(bytes: &[u8]) -> [[i8; VL]; 8] {
    let e = 8 / B;
    let mut v = [[0i8; VL]; 8]; // only the first E rows are used
    let mut blk = [0i8; VL];
    for j in 0..VL {
        blk[j] = bytes[j] as i8;
    }
    for (k, row) in v.iter_mut().enumerate().take(e) {
        for j in 0..VL {
            row[j] = extract::<B>(blk[j], k);
        }
    }
    v
}

/// Lane-wise widening MAC: `acc[j] += w[j] * a[j]` over 16 int8 lanes.
#[inline(always)]
fn mac16(acc: &mut [i32; VL], w: &[i8; VL], a: &[i8; VL]) {
    for j in 0..VL {
        acc[j] += (w[j] as i16 * a[j] as i16) as i32;
    }
}

#[inline(always)]
fn load16(src: &[i8]) -> [i8; VL] {
    let mut v = [0i8; VL];
    v.copy_from_slice(&src[..VL]);
    v
}

/// W sub-byte (`B` bits) × A int8 — the paper's W4A8/W2A8/W1A8 kernels.
pub fn gemv_wsub_a8<const B: usize>(wp: &PackedMatrix, a: &[i8], out: &mut [i32]) {
    gemv_wsub_a8_at::<B>(wp, a, out, 0)
}

/// [`gemv_wsub_a8`] over the row range `[row0, row0 + out.len())` —
/// zero-copy sharding for `kernels::parallel`.
pub fn gemv_wsub_a8_at<const B: usize>(
    wp: &PackedMatrix,
    a: &[i8],
    out: &mut [i32],
    row0: usize,
) {
    let e = 8 / B;
    debug_assert_eq!(wp.bits().bits(), B);
    debug_assert!(a.len() >= wp.k_padded());
    // NOTE (§Perf iteration 3): a 2-block unroll with dual accumulators
    // was tried here and REVERTED — it regressed w4a8 600→682us and
    // w1a8 361→537us on the host (the single-block loop already
    // saturates the load pipe; the unroll only added register pressure).
    for (r, o) in out.iter_mut().enumerate() {
        let row = wp.row(row0 + r);
        let mut acc = [0i32; VL];
        for (blk, bytes) in row.chunks_exact(VL).enumerate() {
            let base = blk * e * VL;
            let w = extract_block::<B>(bytes);
            for (k, wk) in w.iter().enumerate().take(e) {
                let av = load16(&a[base + k * VL..]);
                mac16(&mut acc, wk, &av);
            }
        }
        *o = acc.iter().sum();
    }
}

/// W int8 × A sub-byte (`B` bits) — the W8A4/W8A2/W8A1 kernels: the
/// activation vector is unpacked in-register, weights stream as int8.
pub fn gemv_w8_asub<const B: usize>(wp: &PackedMatrix, a_packed: &[u8], out: &mut [i32]) {
    gemv_w8_asub_at::<B>(wp, a_packed, out, 0)
}

/// [`gemv_w8_asub`] over a row range (zero-copy sharding).
pub fn gemv_w8_asub_at<const B: usize>(
    wp: &PackedMatrix,
    a_packed: &[u8],
    out: &mut [i32],
    row0: usize,
) {
    let e = 8 / B;
    debug_assert!(!wp.bits().is_sub_byte());
    debug_assert!(a_packed.len() * e >= wp.k_padded());
    // unpack the activation vector once per call (it is shared by every
    // row — the in-register unpack of the paper amortizes the same way
    // across the row loop, which reuses the same extracted registers)
    let mut a_unpacked: Vec<[i8; VL]> = Vec::with_capacity(a_packed.len() / VL * e);
    for bytes in a_packed.chunks_exact(VL) {
        let v = extract_block::<B>(bytes);
        a_unpacked.extend_from_slice(&v[..e]);
    }
    for (r, o) in out.iter_mut().enumerate() {
        let row = wp.row_i8(row0 + r);
        let mut acc = [0i32; VL];
        let full = row.len() / VL;
        for (i, av) in a_unpacked.iter().enumerate().take(full) {
            let wv = load16(&row[i * VL..]);
            mac16(&mut acc, &wv, av);
        }
        let mut sum: i32 = acc.iter().sum();
        // tail: weight depth not padded to the activation group
        for i in full * VL..row.len() {
            let av = extract::<B>(a_packed[(i / (e * VL)) * VL + i % VL] as i8, (i / VL) % e);
            sum += row[i] as i32 * av as i32;
        }
        *o = sum;
    }
}

/// W and A both sub-byte with the same width — W4A4/W2A2/W1A1: weights
/// unpacked in-register per block; activations unpacked once per call
/// (shared across rows, exactly like the register reuse in the paper's
/// kernel which keeps the extracted activation vectors live).
pub fn gemv_wsub_asub<const B: usize>(wp: &PackedMatrix, a_packed: &[u8], out: &mut [i32]) {
    gemv_wsub_asub_at::<B>(wp, a_packed, out, 0)
}

/// [`gemv_wsub_asub`] over a row range (zero-copy sharding).
pub fn gemv_wsub_asub_at<const B: usize>(
    wp: &PackedMatrix,
    a_packed: &[u8],
    out: &mut [i32],
    row0: usize,
) {
    let e = 8 / B;
    debug_assert_eq!(wp.bits().bits(), B);
    debug_assert!(a_packed.len() * e >= wp.k_padded());
    let blocks = wp.bytes_per_row() / VL;
    let mut a_unpacked: Vec<[i8; VL]> = Vec::with_capacity(blocks * e);
    for bytes in a_packed.chunks_exact(VL).take(blocks) {
        let v = extract_block::<B>(bytes);
        a_unpacked.extend_from_slice(&v[..e]);
    }
    for (r, o) in out.iter_mut().enumerate() {
        let row = wp.row(row0 + r);
        let mut acc = [0i32; VL];
        for (blk, bytes) in row.chunks_exact(VL).enumerate() {
            let w = extract_block::<B>(bytes);
            for (k, wk) in w.iter().enumerate().take(e) {
                mac16(&mut acc, wk, &a_unpacked[blk * e + k]);
            }
        }
        *o = acc.iter().sum();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testutil::{oracle_gemv, rngvals};
    use crate::pack::{pack, BitWidth, PackedMatrix};

    #[test]
    fn extract_matches_scalar_signext() {
        // every byte value, every sub-position, every width
        for b in 0..=255u8 {
            let byte = b as i8;
            for k in 0..2 {
                let lo4 = extract::<4>(byte, k);
                let want = {
                    let v = (b >> (4 * k)) & 0xF;
                    ((v << 4) as i8) >> 4
                };
                assert_eq!(lo4, want);
            }
            for k in 0..4 {
                let v2 = extract::<2>(byte, k);
                let want = {
                    let v = (b >> (2 * k)) & 0x3;
                    ((v << 6) as i8) >> 6
                };
                assert_eq!(v2, want);
            }
            for k in 0..8 {
                let v1 = extract::<1>(byte, k);
                assert_eq!(v1, -(((b >> k) & 1) as i8));
            }
        }
    }

    #[test]
    fn extract_block_matches_unpack() {
        for (bits, b) in [(BitWidth::B4, 4usize), (BitWidth::B2, 2), (BitWidth::B1, 1)] {
            let x = rngvals(bits, bits.group_size(), 77);
            let packed = pack(&x, bits).unwrap();
            let v = match b {
                4 => extract_block::<4>(&packed),
                2 => extract_block::<2>(&packed),
                _ => extract_block::<1>(&packed),
            };
            let e = bits.elems_per_byte();
            for k in 0..e {
                for j in 0..VL {
                    assert_eq!(v[k][j], x[k * VL + j], "{bits:?} k={k} j={j}");
                }
            }
        }
    }

    #[test]
    fn wsub_a8_extremes() {
        // all-min weights, all-max activations: worst-case accumulators
        for (bits, b) in [(BitWidth::B4, 4usize), (BitWidth::B2, 2), (BitWidth::B1, 1)] {
            let (wlo, _) = bits.value_range();
            let g = bits.group_size();
            let z = 4;
            let w = vec![wlo; z * g];
            let a = vec![127i8; g];
            let wp = PackedMatrix::from_i8(&w, z, g, bits).unwrap();
            let mut out = vec![0i32; z];
            match b {
                4 => gemv_wsub_a8::<4>(&wp, &a, &mut out),
                2 => gemv_wsub_a8::<2>(&wp, &a, &mut out),
                _ => gemv_wsub_a8::<1>(&wp, &a, &mut out),
            }
            assert_eq!(out, oracle_gemv(&w, &a, z, g));
        }
    }

    #[test]
    fn w8_asub_weights_shorter_than_padded_acts() {
        // 8-bit weights need no padding; packed acts may be longer.
        for k in [160usize, 100, 128, 17] {
            let z = 4;
            let w = rngvals(BitWidth::B8, z * k, 3);
            let mut a = rngvals(BitWidth::B1, k, 4);
            a.resize(BitWidth::B1.padded_len(k), 0);
            let ap = pack(&a, BitWidth::B1).unwrap();
            let wp = PackedMatrix::from_i8(&w, z, k, BitWidth::B8).unwrap();
            let mut out = vec![0i32; z];
            gemv_w8_asub::<1>(&wp, &ap, &mut out);
            assert_eq!(out, oracle_gemv(&w, &a[..k], z, k), "k={k}");
        }
    }

    #[test]
    fn wsub_asub_multi_block() {
        let bits = BitWidth::B2;
        let k = bits.group_size() * 3;
        let z = 8;
        let w = rngvals(bits, z * k, 9);
        let a = rngvals(bits, k, 10);
        let wp = PackedMatrix::from_i8(&w, z, k, bits).unwrap();
        let ap = pack(&a, bits).unwrap();
        let mut out = vec![0i32; z];
        gemv_wsub_asub::<2>(&wp, &ap, &mut out);
        assert_eq!(out, oracle_gemv(&w, &a, z, k));
    }
}

//! The naive sub-byte method (paper Alg. 1): adjacent packing, per-byte
//! scalar extraction with shifts, FMA per element.  Same memory density
//! as FullPack but the extraction overhead dominates — the strawman the
//! packing/processing co-design beats.

use crate::pack::BitWidth;

/// Naive W-sub-byte × A-int8 GEMV over the adjacent (Alg. 1) layout.
/// `w_naive` holds `rows` rows of `ceil(k/E)` bytes each.
pub fn gemv_naive_wsub_a8(
    w_naive: &[u8],
    rows: usize,
    k: usize,
    bits: BitWidth,
    a: &[i8],
    out: &mut [i32],
) {
    let e = bits.elems_per_byte();
    let b = bits.bits();
    let bytes_per_row = k.div_ceil(e);
    debug_assert!(a.len() >= k);
    debug_assert_eq!(out.len(), rows);
    let shift = 8 - b;
    for (r, o) in out.iter_mut().enumerate() {
        let row = &w_naive[r * bytes_per_row..(r + 1) * bytes_per_row];
        let mut sum = 0i32;
        for (byte_idx, &byte) in row.iter().enumerate() {
            let base = byte_idx * e;
            // Alg. 1 lines 6-11: extract each element with shift pairs,
            // then FMA with the corresponding activation.
            for sub in 0..e {
                let i = base + sub;
                if i >= k {
                    break;
                }
                // element `sub` sits in the high-to-low order (Alg. 1:
                // W0 = (W >> 4) << 4 is the *high* nibble)
                let v = (byte >> ((e - 1 - sub) * b)) as u8;
                let w = ((((v & (((1u16 << b) - 1) as u8)) << shift) as i8) >> shift) as i32;
                sum += w * a[i] as i32;
            }
        }
        *o = sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testutil::{oracle_gemv, rngvals};
    use crate::pack::pack_naive;

    #[test]
    fn naive_matches_oracle_all_widths() {
        for bits in [BitWidth::B4, BitWidth::B2, BitWidth::B1] {
            let z = 8;
            let k = 100; // deliberately unaligned
            let w = rngvals(bits, z * k, 41);
            let a = rngvals(BitWidth::B8, k, 42);
            let mut packed = Vec::new();
            for r in 0..z {
                packed.extend(pack_naive(&w[r * k..(r + 1) * k], bits).unwrap());
            }
            let mut out = vec![0i32; z];
            gemv_naive_wsub_a8(&packed, z, k, bits, &a, &mut out);
            assert_eq!(out, oracle_gemv(&w, &a, z, k), "{bits:?}");
        }
    }
}

//! ULPPACK comparator kernel (Won et al., MLSys 2022) — the prior state
//! of the art FullPack improves on.
//!
//! ULPPACK packs two *unsigned* b-bit values per 16-bit lane with
//! `16 - 2b` guard (spacer) bits and multiplies packed lanes directly:
//! with weights packed low-to-high `(w0 + w1·2^8)` and activations
//! packed in *reversed* order `(a1 + a0·2^8)`, the 32-bit lane product is
//!
//! ```text
//!   w0·a1  +  (w0·a0 + w1·a1)·2^8  +  w1·a0·2^16
//! ```
//!
//! — the middle segment accumulates the two-element dot product
//! (binary segmentation, Pan 1993).  Products are accumulated *locally*
//! in the 32-bit lane for `S` steps before the middle segment is
//! extracted, where `S` is bounded by the guard bits:
//! `S · max_low_term < 2^8` with `max_low_term = (2^b - 1)^2`.
//!
//! Sign handling: operands are zero-point shifted to `[0, 2^b)`
//! (asymmetric quantization, as in the original); the signed dot product
//! is recovered with the standard zero-point correction using
//! precomputed operand sums.
//!
//! Memory cost: **1 byte per value** regardless of b — the bandwidth and
//! footprint waste (vs FullPack's `b/8` bytes) that the paper's Fig. 6
//! attributes its LLC-miss advantage to.

use crate::pack::{BitWidth, UlppackMatrix};

/// Max local-accumulation steps before the middle segment could receive
/// a carry from the low segment.
pub fn max_local_steps(bits: BitWidth) -> usize {
    let m = (1usize << bits.bits()) - 1;
    // S * m^2 must stay < 2^8 so the low segment never carries into the
    // middle; the middle itself accumulates into the upper guard bits.
    (255 / (m * m)).max(1)
}

/// Pack an unsigned activation vector in *reversed* pair order
/// (`a1 + a0·2^8`) as the binary-segmentation trick requires.
pub fn pack_acts_reversed(a_unsigned: &[u8]) -> Vec<u16> {
    let n = a_unsigned.len();
    let mut out = vec![0u16; n.div_ceil(2)];
    for (i, &v) in a_unsigned.iter().enumerate() {
        // element 0 of the pair goes to the HIGH byte
        out[i / 2] |= (v as u16) << ((1 - (i % 2)) * 8);
    }
    out
}

/// ULPPACK GEMV: unsigned packed operands, signed result via zero-point
/// correction.  `a_sum` is Σ of the unsigned activation values and
/// `a_rev` their reversed-pair lanes; `k` the logical depth.
pub fn gemv_ulppack(
    w: &UlppackMatrix,
    a_rev: &[u16],
    a_sum: i32,
    k: usize,
    out: &mut [i32],
) {
    debug_assert_eq!(out.len(), w.rows());
    gemv_ulppack_at(w, a_rev, a_sum, k, out, 0)
}

/// [`gemv_ulppack`] over the row range `[row0, row0 + out.len())` — the
/// sharding entry used by the kernel-API adapter.
pub fn gemv_ulppack_at(
    w: &UlppackMatrix,
    a_rev: &[u16],
    a_sum: i32,
    k: usize,
    out: &mut [i32],
    row0: usize,
) {
    let bits = w.bits();
    let s_max = max_local_steps(bits);
    let zp = w.zero_point as i32;
    let lanes = k.div_ceil(2);
    debug_assert!(a_rev.len() >= lanes);
    debug_assert!(row0 + out.len() <= w.rows());

    for (r, o) in out.iter_mut().enumerate() {
        let row = w.row(row0 + r);
        let mut mid_total: i64 = 0;
        let mut w_sum: i32 = 0;
        let mut lane = 0usize;
        while lane < lanes {
            let stop = (lane + s_max).min(lanes);
            let mut local: u32 = 0;
            for l in lane..stop {
                let wl = row[l] as u32;
                let al = a_rev[l] as u32;
                local = local.wrapping_add(wl.wrapping_mul(al));
                w_sum += (wl & 0xFF) as i32 + (wl >> 8) as i32;
            }
            // middle-segment extraction: bits 8.. hold Σ(w0·a0 + w1·a1)
            // plus the high terms' overflow beyond bit 16; subtracting the
            // reconstructed low/high segments is avoided by bounding S so
            // the low segment cannot carry: mid = (local >> 8) mod 2^16
            // is NOT enough once high terms overlap — instead recompute
            // exactly: local = low + mid<<8 + high<<16 with
            // low = Σ w0·a1 and high = Σ w1·a0 re-derived per block.
            let mut low: u32 = 0;
            let mut high: u32 = 0;
            for l in lane..stop {
                let wl = row[l] as u32;
                let al = a_rev[l] as u32;
                low += (wl & 0xFF) * (al & 0xFF); // w0·a1
                high += (wl >> 8) * (al >> 8); // w1·a0
            }
            let mid = (local - low - (high << 16)) >> 8;
            mid_total += mid as i64;
            lane = stop;
        }
        // zero-point correction: Σ(w-zp)(a-zp) = Σwa - zp·Σa - zp·Σw + k·zp²
        let signed =
            mid_total - (zp as i64) * (a_sum as i64) - (zp as i64) * (w_sum as i64)
                + (k as i64) * (zp as i64) * (zp as i64);
        *o = signed as i32;
    }
}

/// Convenience wrapper: signed int8 activations → unsigned domain →
/// reversed lanes + sum.
pub fn prepare_acts(a: &[i8], bits: BitWidth) -> (Vec<u16>, i32) {
    let zp = 1u8 << (bits.bits() - 1);
    let unsigned: Vec<u8> = a.iter().map(|&v| (v as i16 + zp as i16) as u8).collect();
    let sum = unsigned.iter().map(|&v| v as i32).sum();
    (pack_acts_reversed(&unsigned), sum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testutil::{oracle_gemv, rngvals};

    #[test]
    fn local_step_bounds() {
        assert_eq!(max_local_steps(BitWidth::B1), 255);
        assert_eq!(max_local_steps(BitWidth::B2), 28);
        // B4: (2^4-1)^2 = 225 > 255/2 — the middle segment must be
        // extracted after every single lane
        assert_eq!(max_local_steps(BitWidth::B4), 1);
    }

    #[test]
    fn ulppack_matches_oracle() {
        // B4 included: its max_local_steps == 1 per-block extraction
        // path (one lane per middle-segment recompute) had no oracle
        // coverage before
        for bits in [BitWidth::B1, BitWidth::B2, BitWidth::B4] {
            for k in [16usize, 33, 64, 100, 256] {
                let z = 8;
                let w = rngvals(bits, z * k, 51);
                let a = rngvals(bits, k, 52);
                let wm = UlppackMatrix::from_i8(&w, z, k, bits).unwrap();
                let (a_rev, a_sum) = prepare_acts(&a, bits);
                let mut out = vec![0i32; z];
                gemv_ulppack(&wm, &a_rev, a_sum, k, &mut out);
                assert_eq!(out, oracle_gemv(&w, &a, z, k), "{bits:?} k={k}");
            }
        }
    }

    #[test]
    fn ulppack_extremes() {
        // worst-case accumulators per width: all-min weights × all-max
        // activations, plus the all-min × all-min corner (largest
        // positive product), at an even and an odd (phantom-lane) depth
        for bits in [BitWidth::B1, BitWidth::B2, BitWidth::B4] {
            let (lo, hi) = bits.value_range();
            for k in [64usize, 65] {
                for (wv, av) in [(lo, hi.max(lo + 1)), (lo, lo), (hi, hi)] {
                    let z = 2;
                    let w = vec![wv; z * k];
                    let a = vec![av; k];
                    let wm = UlppackMatrix::from_i8(&w, z, k, bits).unwrap();
                    let (a_rev, a_sum) = prepare_acts(&a, bits);
                    let mut out = vec![0i32; z];
                    gemv_ulppack(&wm, &a_rev, a_sum, k, &mut out);
                    assert_eq!(out, oracle_gemv(&w, &a, z, k), "{bits:?} k={k} w={wv} a={av}");
                }
            }
        }
    }
}

//! Shared test/benchmark support: deterministic operand generation and
//! the scalar int32 oracle every kernel is checked against.  Public
//! (not `cfg(test)`) so the integration conformance suite, examples and
//! benches reuse one generator instead of five copies.

use crate::pack::BitWidth;

/// Deterministic xorshift values in the width's signed range (the
/// legacy weight stream, now centralized in `util::rng`).
pub fn rngvals(bits: BitWidth, n: usize, seed: u64) -> Vec<i8> {
    let (lo, hi) = bits.value_range();
    crate::util::rng::xorshift_range_vals(lo, hi, n, seed)
}

/// int32 oracle GEMV on unpacked operands.
pub fn oracle_gemv(w: &[i8], a: &[i8], z: usize, k: usize) -> Vec<i32> {
    (0..z)
        .map(|r| {
            w[r * k..(r + 1) * k]
                .iter()
                .zip(a)
                .map(|(&wv, &av)| wv as i32 * av as i32)
                .sum()
        })
        .collect()
}

/// Re-export of the layout helper tests share with production packing
/// (`pack::pad_rows`).
pub use crate::pack::pad_rows;

//! The LUT kernel tier (DESIGN.md §13): table-driven sub-byte GEMV/GEMM
//! over the FullPack packed layout — the DeepGEMM-style rival (Ganji et
//! al. 2023, arXiv 2304.09049) to shift-based extraction.
//!
//! Where the FullPack kernels spend two shifts per sub-vector to unpack
//! every weight byte, the LUT tier spends **zero extraction work in the
//! row loop**: per packed byte *position* of a row it precomputes a
//! 256-entry table of partial dot products against the activation block
//! that position multiplies, then every weight byte becomes one
//! gather-style table load + add.
//!
//! For byte position `pos = blk·VL + j` of a packed row (FullPack
//! layout: byte `j` of block `blk` holds elements `blk·E·VL + k·VL + j`
//! for sub-vectors `k < E`, `E = 8/b`):
//!
//! ```text
//!   T[pos][v] = Σ_{k<E} extract(v, k) · a[blk·E·VL + k·VL + j]   v ∈ 0..256
//!   out[r]    = Σ_pos T[pos][row_bytes[pos]]
//! ```
//!
//! The sums are exact in `i32`, so the tier is bit-identical to the
//! FullPack siblings and the scalar oracle.  The table build is
//! incremental — entry `v` extends the already-built entry with `v`'s
//! highest non-zero sub-vector field cleared (a strictly smaller index),
//! so each of the `256·wb` slots costs one add — and the build is
//! amortized across all `z` rows of the call.  The trade: the table
//! occupies `wb·1KB` of L1 (`wb` = packed bytes per row) and the row
//! loop is data-dependent gathers the SLP vectorizer cannot touch, so
//! the tier wins only where many rows amortize the build **and** the
//! table fits L1 — the crossover the cost model resolves
//! (`costmodel::Method::Lut`, EXPERIMENTS.md §LUT).
//!
//! Batched wrappers (`lut-*-gemm`) walk the packed weight bytes once per
//! [`COL_TILE`]-column tile instead of once per column, amortizing
//! weight streaming while builds still scale with the batch.
#![warn(missing_docs)]

use super::api::{check_gemm_shape, check_rows, wrong_layout, GemmKernel, GemvKernel, Weights};
use super::fullpack::extract;
use super::fullpack_gemm::COL_TILE;
use super::{ActVec, KernelError};
use crate::costmodel::Method;
use crate::pack::{pad_rows, BitWidth, PackedMatrix, Variant, VL};
use std::cell::RefCell;

/// The variants the LUT tier implements, one registry entry per tier
/// namespace (`lut-*` GEMV, `lut-*-gemm` GEMM).  Sub-byte weights are
/// required (the 256-entry table *is* the unpack); `w4a4` takes packed
/// activations on the GEMV path (SPARQLe-style sub-byte acts) and plain
/// int8 columns on the GEMM path, like its FullPack sibling.
pub const LUT_VARIANTS: [Variant; 4] = [
    Variant::new(BitWidth::B4, BitWidth::B8),
    Variant::new(BitWidth::B2, BitWidth::B8),
    Variant::new(BitWidth::B1, BitWidth::B8),
    Variant::new(BitWidth::B4, BitWidth::B4),
];

/// Registry name of the LUT GEMV kernel for a variant, if implemented.
pub fn lut_kernel_name(v: Variant) -> Option<&'static str> {
    match (v.w, v.a) {
        (BitWidth::B4, BitWidth::B8) => Some("lut-w4a8"),
        (BitWidth::B2, BitWidth::B8) => Some("lut-w2a8"),
        (BitWidth::B1, BitWidth::B8) => Some("lut-w1a8"),
        (BitWidth::B4, BitWidth::B4) => Some("lut-w4a4"),
        _ => None,
    }
}

/// Registry name of the LUT GEMM backend for a variant, if implemented.
pub fn lut_gemm_kernel_name(v: Variant) -> Option<&'static str> {
    match (v.w, v.a) {
        (BitWidth::B4, BitWidth::B8) => Some("lut-w4a8-gemm"),
        (BitWidth::B2, BitWidth::B8) => Some("lut-w2a8-gemm"),
        (BitWidth::B1, BitWidth::B8) => Some("lut-w1a8-gemm"),
        (BitWidth::B4, BitWidth::B4) => Some("lut-w4a4-gemm"),
        _ => None,
    }
}

/// Per-thread scratch: the table lives here so steady-state calls never
/// allocate (the build cost the model charges is the fill, not malloc).
#[derive(Default)]
struct LutScratch {
    table: Vec<i32>,
    acts: Vec<i8>,
}

thread_local! {
    static LUT_SCRATCH: RefCell<LutScratch> = RefCell::new(LutScratch::default());
}

/// Fill `table` (`wb · 256` slots) with the partial-dot tables for one
/// activation vector: `table[pos·256 + v]` is what packed byte value
/// `v` at row byte position `pos` contributes to a dot product with
/// `a`.  `a` must be the unpacked activation vector of at least the
/// padded depth `wb · E`.
///
/// Incremental build: entry `v` extends the entry with `v`'s highest
/// non-zero sub-vector field cleared — a strictly smaller index, so one
/// signed multiply-add per slot.
pub fn build_tables<const B: usize>(a: &[i8], wb: usize, table: &mut [i32]) {
    let e = 8 / B;
    debug_assert!(a.len() >= wb * e, "activations {} < padded depth {}", a.len(), wb * e);
    debug_assert_eq!(table.len(), wb * 256);
    for pos in 0..wb {
        let blk = pos / VL;
        let j = pos % VL;
        // the E activation elements byte position `pos` multiplies
        let mut af = [0i32; 8];
        for (k, slot) in af.iter_mut().enumerate().take(e) {
            *slot = a[blk * e * VL + k * VL + j] as i32;
        }
        let t = &mut table[pos * 256..(pos + 1) * 256];
        t[0] = 0; // every sub-vector field of byte 0 extracts to 0
        for v in 1..256usize {
            let top_bit = 31 - (v as u32).leading_zeros() as usize;
            let ks = top_bit / B;
            let lower = v & ((1usize << (ks * B)) - 1);
            t[v] = t[lower] + extract::<B>(v as u8 as i8, ks) as i32 * af[ks];
        }
    }
}

/// LUT GEMV: build the tables once, then one gather + add per packed
/// weight byte per row.  `table` is caller-owned scratch (cleared and
/// refilled here).
pub fn gemv_lut<const B: usize>(
    wp: &PackedMatrix,
    a: &[i8],
    out: &mut [i32],
    table: &mut Vec<i32>,
) {
    gemv_lut_at::<B>(wp, a, out, 0, table)
}

/// [`gemv_lut`] over the row range `[row0, row0 + out.len())` — the
/// zero-copy sharding entry (`kernels::parallel` shards rows; each
/// shard rebuilds its own table, which is why the planner's thread
/// budget is a poor fit for this tier).
pub fn gemv_lut_at<const B: usize>(
    wp: &PackedMatrix,
    a: &[i8],
    out: &mut [i32],
    row0: usize,
    table: &mut Vec<i32>,
) {
    debug_assert_eq!(wp.bits().bits(), B);
    let wb = wp.bytes_per_row();
    table.clear();
    table.resize(wb * 256, 0);
    build_tables::<B>(a, wb, table);
    for (r, o) in out.iter_mut().enumerate() {
        let row = wp.row(row0 + r);
        let mut sum = 0i32;
        for (pos, &byte) in row.iter().enumerate() {
            sum += table[pos * 256 + byte as usize];
        }
        *o = sum;
    }
}

/// Batched LUT GEMM: per [`COL_TILE`]-column tile, build one table per
/// column, then walk each packed weight row **once per tile** feeding
/// all the tile's columns — the weight stream amortizes as
/// `ceil(batch/COL_TILE)/batch` while builds stay one per column.
/// `out[c·z + r]` is batch-major like every GEMM backend.
pub fn gemm_lut<const B: usize>(
    wp: &PackedMatrix,
    cols: &[&[i8]],
    out: &mut [i32],
    tables: &mut Vec<i32>,
) {
    gemm_lut_at::<B>(wp, cols, out, 0, tables)
}

/// [`gemm_lut`] over the row-tile `[row0, row0 + rt)` where
/// `rt = out.len() / cols.len()` — the `GemmKernel::gemm_at` sharding
/// entry.  Tile output is batch-major over the tile
/// (`out[c·rt + (r - row0)]`); tables are per-column and built in full
/// per shard, so few-row shards amortize the builds poorly — the same
/// caveat as `gemv_lut_at`.
pub fn gemm_lut_at<const B: usize>(
    wp: &PackedMatrix,
    cols: &[&[i8]],
    out: &mut [i32],
    row0: usize,
    tables: &mut Vec<i32>,
) {
    let wb = wp.bytes_per_row();
    let rt = if cols.is_empty() { 0 } else { out.len() / cols.len() };
    let tb = wb * 256;
    for c0 in (0..cols.len()).step_by(COL_TILE) {
        let ct = (cols.len() - c0).min(COL_TILE);
        tables.clear();
        tables.resize(ct * tb, 0);
        for ci in 0..ct {
            build_tables::<B>(cols[c0 + ci], wb, &mut tables[ci * tb..(ci + 1) * tb]);
        }
        for r in 0..rt {
            let row = wp.row(row0 + r);
            let mut sums = [0i32; COL_TILE];
            for (pos, &byte) in row.iter().enumerate() {
                let idx = pos * 256 + byte as usize;
                for (ci, s) in sums.iter_mut().enumerate().take(ct) {
                    *s += tables[ci * tb + idx];
                }
            }
            for (ci, s) in sums.iter().enumerate().take(ct) {
                out[(c0 + ci) * rt + r] = *s;
            }
        }
    }
}

/// Width-dispatched [`gemv_lut_at`] (int8 weights have no LUT kernel:
/// a 256-entry table per byte position would just memoize one scalar
/// multiply).
pub fn gemv_lut_dyn(
    wp: &PackedMatrix,
    a: &[i8],
    out: &mut [i32],
    row0: usize,
    table: &mut Vec<i32>,
) -> Result<(), KernelError> {
    match wp.bits() {
        BitWidth::B4 => gemv_lut_at::<4>(wp, a, out, row0, table),
        BitWidth::B2 => gemv_lut_at::<2>(wp, a, out, row0, table),
        BitWidth::B1 => gemv_lut_at::<1>(wp, a, out, row0, table),
        BitWidth::B8 => {
            return Err(KernelError::Unsupported("lut tier needs sub-byte weights".into()))
        }
    }
    Ok(())
}

/// Width-dispatched [`gemm_lut`].
pub fn gemm_lut_dyn(
    wp: &PackedMatrix,
    cols: &[&[i8]],
    out: &mut [i32],
    tables: &mut Vec<i32>,
) -> Result<(), KernelError> {
    gemm_lut_dyn_at(wp, cols, out, 0, tables)
}

/// Width-dispatched [`gemm_lut_at`].
pub fn gemm_lut_dyn_at(
    wp: &PackedMatrix,
    cols: &[&[i8]],
    out: &mut [i32],
    row0: usize,
    tables: &mut Vec<i32>,
) -> Result<(), KernelError> {
    match wp.bits() {
        BitWidth::B4 => gemm_lut_at::<4>(wp, cols, out, row0, tables),
        BitWidth::B2 => gemm_lut_at::<2>(wp, cols, out, row0, tables),
        BitWidth::B1 => gemm_lut_at::<1>(wp, cols, out, row0, tables),
        BitWidth::B8 => {
            return Err(KernelError::Unsupported("lut tier needs sub-byte weights".into()))
        }
    }
    Ok(())
}

/// Unpack a FullPack-packed activation vector to plain int8 in logical
/// element order (the order [`build_tables`] indexes): group `g`, field
/// `k`, lane `j` ↦ element `g·E·VL + k·VL + j`.
fn unpack_acts<const B: usize>(bytes: &[u8], out: &mut Vec<i8>) {
    let e = 8 / B;
    out.clear();
    out.reserve(bytes.len() * e);
    for chunk in bytes.chunks_exact(VL) {
        for k in 0..e {
            for &b in chunk {
                out.push(extract::<B>(b as i8, k));
            }
        }
    }
}

fn unpack_acts_dyn(bytes: &[u8], bits: BitWidth, out: &mut Vec<i8>) {
    match bits {
        BitWidth::B4 => unpack_acts::<4>(bytes, out),
        BitWidth::B2 => unpack_acts::<2>(bytes, out),
        BitWidth::B1 => unpack_acts::<1>(bytes, out),
        BitWidth::B8 => unreachable!("B8 activations arrive as ActVec::I8"),
    }
}

/// The LUT GEMV tier as a registry backend, one entry per
/// [`LUT_VARIANTS`] variant.  Shares the FullPack tier's prepared
/// layout exactly: weights prepared by `fullpack-*` (or the `-gemm`
/// twins of either family) execute here unchanged.
pub struct LutKernel {
    variant: Variant,
    name: &'static str,
}

impl LutKernel {
    /// Backend for `variant`, if the tier implements it.
    pub fn new(variant: Variant) -> Option<LutKernel> {
        lut_kernel_name(variant).map(|name| LutKernel { variant, name })
    }
}

impl GemvKernel for LutKernel {
    fn name(&self) -> &'static str {
        self.name
    }

    fn supports(&self, v: Variant) -> bool {
        v == self.variant
    }

    fn prepare(&self, w: &[i8], rows: usize, k: usize) -> Result<Weights, KernelError> {
        // identical layout to the FullPack tier: prepared weights are
        // interchangeable across both families and both namespaces
        let kp = self.variant.padded_depth(k);
        let padded = pad_rows(w, rows, k, kp);
        Ok(Weights::Packed(PackedMatrix::from_i8(&padded, rows, kp, self.variant.w)?))
    }

    fn gemv_at(
        &self,
        w: &Weights,
        a: ActVec<'_>,
        out: &mut [i32],
        row0: usize,
    ) -> Result<(), KernelError> {
        let Weights::Packed(wp) = w else { return Err(wrong_layout(self.name, w)) };
        if !wp.bits().is_sub_byte() {
            return Err(wrong_layout(self.name, w));
        }
        check_rows(w, out, row0)?;
        let kp = wp.k_padded();
        LUT_SCRATCH.with(|s| {
            let mut s = s.borrow_mut();
            let s = &mut *s;
            match a {
                ActVec::I8(av) => {
                    if av.len() < kp {
                        return Err(KernelError::Shape(format!(
                            "activation elems {} < padded depth {kp}",
                            av.len()
                        )));
                    }
                    gemv_lut_dyn(wp, av, out, row0, &mut s.table)
                }
                ActVec::Packed { bytes, bits } if bits == self.variant.a => {
                    unpack_acts_dyn(bytes, bits, &mut s.acts);
                    if s.acts.len() < kp {
                        return Err(KernelError::Shape(format!(
                            "activation elems {} < padded depth {kp}",
                            s.acts.len()
                        )));
                    }
                    gemv_lut_dyn(wp, &s.acts, out, row0, &mut s.table)
                }
                ActVec::Packed { bits, .. } => Err(KernelError::Unsupported(format!(
                    "{}: {}-bit packed activations",
                    self.name,
                    bits.bits()
                ))),
            }
        })
    }

    fn cost_method(&self) -> Option<Method> {
        Some(Method::Lut(self.variant))
    }

    fn packs_activations(&self) -> bool {
        self.variant.a.is_sub_byte()
    }

    // NOTE: the default `gemm` (repeated per-column `gemv_at`) is kept
    // deliberately — it is exactly what `Method::Lut` models for
    // batches (b rebuilt tables, b weight streams); the amortized path
    // is the separate `lut-*-gemm` backend.
}

/// The batched LUT GEMM wrappers as first-class backends
/// (`lut-*-gemm`): same prepared layout, [`gemm_lut`] execution.
pub struct LutGemmKernel {
    variant: Variant,
    name: &'static str,
}

impl LutGemmKernel {
    /// Backend for `variant`, if the tier implements it.
    pub fn new(variant: Variant) -> Option<LutGemmKernel> {
        lut_gemm_kernel_name(variant).map(|name| LutGemmKernel { variant, name })
    }
}

impl GemmKernel for LutGemmKernel {
    fn name(&self) -> &'static str {
        self.name
    }

    fn supports(&self, v: Variant) -> bool {
        v == self.variant
    }

    fn prepare(&self, w: &[i8], rows: usize, k: usize) -> Result<Weights, KernelError> {
        let kp = self.variant.padded_depth(k);
        let padded = pad_rows(w, rows, k, kp);
        Ok(Weights::Packed(PackedMatrix::from_i8(&padded, rows, kp, self.variant.w)?))
    }

    fn gemm(&self, w: &Weights, cols: &[&[i8]], out: &mut [i32]) -> Result<(), KernelError> {
        let Weights::Packed(wp) = w else { return Err(wrong_layout(self.name, w)) };
        if !wp.bits().is_sub_byte() {
            return Err(wrong_layout(self.name, w));
        }
        check_gemm_shape(w, cols, out)?;
        // int8 columns even for w4a4: sub-byte activation values pass
        // through i8 losslessly and the table build consumes i8 anyway
        LUT_SCRATCH.with(|s| gemm_lut_dyn(wp, cols, out, &mut s.borrow_mut().table))
    }

    fn gemm_at(
        &self,
        w: &Weights,
        cols: &[&[i8]],
        out: &mut [i32],
        row0: usize,
    ) -> Result<(), KernelError> {
        let Weights::Packed(wp) = w else { return Err(wrong_layout(self.name, w)) };
        if !wp.bits().is_sub_byte() {
            return Err(wrong_layout(self.name, w));
        }
        super::api::check_gemm_tile(w, cols, out, row0)?;
        LUT_SCRATCH.with(|s| gemm_lut_dyn_at(wp, cols, out, row0, &mut s.borrow_mut().table))
    }

    fn cost_method(&self) -> Option<Method> {
        Some(Method::LutGemm(self.variant))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testutil::{oracle_gemv, rngvals};
    use crate::kernels::pack_activations;

    #[test]
    fn table_recurrence_matches_direct_computation() {
        // the incremental build must equal the definitional triple loop
        fn check<const B: usize>(seed: u64) {
            let e = 8 / B;
            let wb = 2 * VL; // two blocks
            let a = rngvals(BitWidth::B8, wb * e, seed);
            let mut table = vec![0i32; wb * 256];
            build_tables::<B>(&a, wb, &mut table);
            for pos in 0..wb {
                let (blk, j) = (pos / VL, pos % VL);
                for v in 0..256usize {
                    let direct: i32 = (0..e)
                        .map(|k| {
                            extract::<B>(v as u8 as i8, k) as i32
                                * a[blk * e * VL + k * VL + j] as i32
                        })
                        .sum();
                    assert_eq!(table[pos * 256 + v], direct, "B={B} pos={pos} v={v}");
                }
            }
        }
        check::<4>(11);
        check::<2>(12);
        check::<1>(13);
    }

    #[test]
    fn lut_gemv_matches_oracle_all_variants() {
        for (i, v) in LUT_VARIANTS.iter().enumerate() {
            let kernel = LutKernel::new(*v).unwrap();
            for k in [1usize, 33, 64, 129] {
                let z = 24;
                let w = rngvals(v.w, z * k, 500 + i as u64 + k as u64);
                let a = rngvals(v.a, k, 600 + i as u64 + k as u64);
                let wts = kernel.prepare(&w, z, k).unwrap();
                let kp = wts.k_padded();
                let mut ap = a.clone();
                ap.resize(kp, 0);
                let packed_a;
                let act = if kernel.packs_activations() {
                    packed_a = pack_activations(&ap, v.a).unwrap();
                    ActVec::Packed { bytes: &packed_a, bits: v.a }
                } else {
                    ActVec::I8(&ap)
                };
                let mut out = vec![0i32; z];
                kernel.gemv_at(&wts, act, &mut out, 0).unwrap();
                let wpad = pad_rows(&w, z, k, kp);
                assert_eq!(out, oracle_gemv(&wpad, &ap, z, kp), "{v} k={k}");
                // row-range sharding entry
                let mut shard = vec![0i32; z / 2];
                kernel.gemv_at(&wts, act, &mut shard, z / 2).unwrap();
                assert_eq!(shard.as_slice(), &out[z / 2..], "{v} k={k} shard");
            }
        }
    }

    #[test]
    fn lut_gemm_matches_oracle_across_tile_boundaries() {
        // batches around the COL_TILE boundary: partial tiles included
        for v in LUT_VARIANTS {
            let g = LutGemmKernel::new(v).unwrap();
            for batch in [1usize, 2, COL_TILE, COL_TILE + 1, 2 * COL_TILE + 3] {
                let (z, k) = (16usize, 77usize);
                let w = rngvals(v.w, z * k, 700 + batch as u64);
                let wts = g.prepare(&w, z, k).unwrap();
                let kp = wts.k_padded();
                let cols: Vec<Vec<i8>> = (0..batch)
                    .map(|c| {
                        let mut col = rngvals(v.a, k, 800 + c as u64);
                        col.resize(kp, 0);
                        col
                    })
                    .collect();
                let refs: Vec<&[i8]> = cols.iter().map(|c| c.as_slice()).collect();
                let mut out = vec![0i32; z * batch];
                g.gemm(&wts, &refs, &mut out).unwrap();
                let wpad = pad_rows(&w, z, k, kp);
                for (c, col) in cols.iter().enumerate() {
                    assert_eq!(
                        &out[c * z..(c + 1) * z],
                        oracle_gemv(&wpad, col, z, kp).as_slice(),
                        "{v} batch={batch} col {c}"
                    );
                }
            }
        }
    }

    #[test]
    fn foreign_layouts_and_widths_are_rejected() {
        let v = Variant::parse("w4a8").unwrap();
        let kernel = LutKernel::new(v).unwrap();
        let g = LutGemmKernel::new(v).unwrap();
        let a = vec![0i8; 64];
        let mut out = vec![0i32; 2];
        // int8-packed (non-sub-byte) weights
        let w8 = Weights::Packed(PackedMatrix::from_i8(&vec![0i8; 128], 2, 64, BitWidth::B8).unwrap());
        assert!(kernel.gemv_at(&w8, ActVec::I8(&a), &mut out, 0).is_err());
        assert!(g.gemm(&w8, &[a.as_slice(), a.as_slice()], &mut vec![0i32; 4]).is_err());
        // a rival family's layout entirely
        let f32w = Weights::F32 { data: vec![0.0; 128], rows: 2, k: 64 };
        assert!(kernel.gemv_at(&f32w, ActVec::I8(&a), &mut out, 0).is_err());
        assert!(g.gemm(&f32w, &[a.as_slice(), a.as_slice()], &mut vec![0i32; 4]).is_err());
        // packed activations of the wrong width
        let wts = kernel.prepare(&vec![0i8; 128], 2, 64).unwrap();
        let bytes = vec![0u8; 16];
        let bad = ActVec::Packed { bytes: &bytes, bits: BitWidth::B2 };
        assert!(kernel.gemv_at(&wts, bad, &mut out, 0).is_err());
        // short activations
        let short = vec![0i8; 63];
        assert!(kernel.gemv_at(&wts, ActVec::I8(&short), &mut out, 0).is_err());
    }

    #[test]
    fn shared_layout_with_fullpack_prepared_weights() {
        // weights prepared by the FullPack GEMV tier run on the LUT
        // tier unchanged (and vice versa) — one prepared artifact, two
        // families
        let v = Variant::parse("w2a8").unwrap();
        let reg = crate::kernels::KernelRegistry::global();
        let fp = reg.get("fullpack-w2a8").unwrap();
        let lut = reg.get("lut-w2a8").unwrap();
        let (z, k) = (8usize, 100usize);
        let w = rngvals(v.w, z * k, 41);
        let wts = fp.prepare(&w, z, k).unwrap();
        let kp = wts.k_padded();
        let mut a = rngvals(v.a, k, 42);
        a.resize(kp, 0);
        let mut via_fp = vec![0i32; z];
        fp.gemv_at(&wts, ActVec::I8(&a), &mut via_fp, 0).unwrap();
        let mut via_lut = vec![0i32; z];
        lut.gemv_at(&wts, ActVec::I8(&a), &mut via_lut, 0).unwrap();
        assert_eq!(via_fp, via_lut);
    }
}

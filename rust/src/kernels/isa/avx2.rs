//! AVX2 FullPack GEMV kernels (DESIGN.md §15): 256-bit bit-plane
//! extraction + `maddubs`-class MACs over the unchanged FullPack packed
//! layout — two 16-byte blocks per iteration.
//!
//! Extraction per sub-vector `k` of a 32-byte weight chunk: one
//! 16-bit-lane logical right shift by `k·B` plus a byte mask
//! `(1<<B)-1`.  The shift crosses byte boundaries inside each 16-bit
//! lane, but the contamination lands at bit `≥ 8 - k·B ≥ B` (since
//! `k ≤ E-1` implies `k·B ≤ 8-B`), which the mask clears — so the
//! field equals the scalar two-shift schedule exactly.  Sign extension
//! from `B` bits is the xor/sub idiom (`x ^ s) - s` with
//! `s = 1<<(B-1)`).
//!
//! MAC schedule: AVX2's byte multiplier `_mm256_maddubs_epi16` wants
//! one **unsigned** operand, so the kernel biases the int8 activations
//! by 128 (`a ^ 0x80` as unsigned = `a + 128`) and subtracts the bias
//! afterwards via a weight-sum compensation accumulator:
//!
//! ```text
//!   Σ (a+128)·w  =  Σ a·w + 128·Σ w    ⇒    Σ a·w = main − 128·comp
//! ```
//!
//! Overflow bounds (why this is exact, per weight width):
//! * `B ∈ {1,2,4}`: each `maddubs` pair is `≤ 2·255·8 = 4080 < 32767` —
//!   no i16 saturation; `madd_epi16(·, 1)` widens to i32 losslessly and
//!   the per-lane i32 accumulator is safe to depths ≫ the model sizes.
//! * `B = 8`: `maddubs` **would** saturate (`2·255·128 > 32767`), so the
//!   int8 kernel takes a widening path instead — `cvtepi8_epi16` both
//!   operands, `madd_epi16` pairs into i32 — exact at every input.
//!
//! Zero weight padding contributes zero to both accumulators, so the
//! packed tail padding stays neutral exactly like the scalar tiers.

use super::super::fullpack::extract;
use crate::pack::{PackedMatrix, VL};
use std::arch::x86_64::*;

/// Sub-byte weights (`B ∈ {1,2,4}`) × int8 activations.  Caller must
/// have verified AVX2 support via `isa::detect` (debug-asserted here).
pub fn gemv_wsub_a8<const B: usize>(wp: &PackedMatrix, a: &[i8], out: &mut [i32], row0: usize) {
    debug_assert!(std::is_x86_feature_detected!("avx2"));
    debug_assert_eq!(wp.bits().bits(), B);
    debug_assert!(a.len() >= wp.k_padded());
    unsafe { gemv_wsub_a8_impl::<B>(wp, a, out, row0) }
}

#[target_feature(enable = "avx2")]
unsafe fn gemv_wsub_a8_impl<const B: usize>(
    wp: &PackedMatrix,
    a: &[i8],
    out: &mut [i32],
    row0: usize,
) {
    let e = 8 / B;
    let mask = _mm256_set1_epi8(((1u16 << B) - 1) as u8 as i8);
    let sign = _mm256_set1_epi8(1i8 << (B - 1));
    let bias = _mm256_set1_epi8(0x80u8 as i8);
    let ones8 = _mm256_set1_epi8(1);
    let ones16 = _mm256_set1_epi16(1);
    for (r, o) in out.iter_mut().enumerate() {
        let row = wp.row(row0 + r);
        let nblk = row.len() / VL;
        let nchunk = nblk / 2;
        let mut acc = _mm256_setzero_si256();
        let mut comp = _mm256_setzero_si256();
        for c in 0..nchunk {
            let w = _mm256_loadu_si256(row.as_ptr().add(c * 2 * VL) as *const __m256i);
            for k in 0..e {
                // the two blocks' activation bases are NOT contiguous
                // (each block owns e·VL activations): merge two 128-bit
                // loads into one 256-bit register, low block low
                let lo = _mm_loadu_si128(a.as_ptr().add((c * 2 * e + k) * VL) as *const __m128i);
                let hi =
                    _mm_loadu_si128(a.as_ptr().add(((c * 2 + 1) * e + k) * VL) as *const __m128i);
                let act = _mm256_set_m128i(hi, lo);
                // extract bit-plane k: shift (variable count — the lane
                // crossings land above bit B and the mask clears them),
                // mask, sign-extend from B bits
                let count = _mm_cvtsi32_si128((k * B) as i32);
                let field = _mm256_and_si256(_mm256_srl_epi16(w, count), mask);
                let sw = _mm256_sub_epi8(_mm256_xor_si256(field, sign), sign);
                // biased maddubs MAC + weight-sum compensation
                let au = _mm256_xor_si256(act, bias);
                acc = _mm256_add_epi32(acc, _mm256_madd_epi16(_mm256_maddubs_epi16(au, sw), ones16));
                comp =
                    _mm256_add_epi32(comp, _mm256_madd_epi16(_mm256_maddubs_epi16(ones8, sw), ones16));
            }
        }
        let mut sum = hsum_epi32(acc) - 128 * hsum_epi32(comp);
        if nblk % 2 == 1 {
            // odd trailing 16-byte block: scalar two-shift tail
            let blk = nblk - 1;
            let bytes = &row[blk * VL..];
            for k in 0..e {
                let base = (blk * e + k) * VL;
                for j in 0..VL {
                    sum += extract::<B>(bytes[j] as i8, k) as i32 * a[base + j] as i32;
                }
            }
        }
        *o = sum;
    }
}

/// Int8 weights × int8 activations — the widening (`cvtepi8_epi16` +
/// `madd_epi16`) path; exact at every input (see the module docs).
pub fn gemv_w8_a8(wp: &PackedMatrix, a: &[i8], out: &mut [i32], row0: usize) {
    debug_assert!(std::is_x86_feature_detected!("avx2"));
    debug_assert!(!wp.bits().is_sub_byte());
    debug_assert!(a.len() >= wp.k_padded());
    unsafe { gemv_w8_a8_impl(wp, a, out, row0) }
}

#[target_feature(enable = "avx2")]
unsafe fn gemv_w8_a8_impl(wp: &PackedMatrix, a: &[i8], out: &mut [i32], row0: usize) {
    let k = wp.k_padded();
    let chunks = k / 32;
    for (r, o) in out.iter_mut().enumerate() {
        let row = wp.row(row0 + r);
        let mut acc = _mm256_setzero_si256();
        for c in 0..chunks {
            let w = _mm256_loadu_si256(row.as_ptr().add(c * 32) as *const __m256i);
            let av = _mm256_loadu_si256(a.as_ptr().add(c * 32) as *const __m256i);
            let wlo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(w));
            let whi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256(w, 1));
            let alo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(av));
            let ahi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256(av, 1));
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(wlo, alo));
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(whi, ahi));
        }
        let mut sum = hsum_epi32(acc);
        for i in chunks * 32..k {
            sum += row[i] as i8 as i32 * a[i] as i32;
        }
        *o = sum;
    }
}

/// Horizontal i32 sum of a 256-bit accumulator.
#[target_feature(enable = "avx2")]
unsafe fn hsum_epi32(v: __m256i) -> i32 {
    let s = _mm_add_epi32(_mm256_castsi256_si128(v), _mm256_extracti128_si256(v, 1));
    let s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b01));
    _mm_cvtsi128_si32(s)
}

//! The real-ISA kernel tier (DESIGN.md §15): FullPack GEMV kernels
//! written in actual `std::arch` intrinsics — 256-bit AVX2 on x86-64,
//! 128-bit NEON on aarch64 — behind the same registry the scalar,
//! SWAR and LUT tiers live in.
//!
//! Contract with the rest of the stack:
//!
//! * **Same layout, no repack.**  Entries prepare weights exactly like
//!   `fullpack-*` and execute on `Weights::Packed` *or*
//!   `Weights::SwarPacked` (whose packed matrix is byte-identical; its
//!   row-sum side table is simply unused) — a plan can hop tiers
//!   without touching the prepared artifact.
//! * **Detection-gated registration.**  `KernelRegistry::with_builtins`
//!   registers only the kinds [`detect::detected`] reports, so a
//!   registered name is always executable on this host.  Tests build
//!   local registries with [`register_isa_backends`] and a forced
//!   [`IsaSupport`] to exercise selection without execution.
//! * **Honest cost modeling.**  Each entry reports
//!   `Method::FullPackIsa(variant, kind)`, whose instruction mix is
//!   parameterized by [`IsaKind::lane_bytes`]; `PlanBuilder`'s
//!   cost-model policy admits an ISA candidate only when the modeled
//!   core's `vec_bytes` covers that lane width.
#![warn(missing_docs)]

pub mod detect;

#[cfg(target_arch = "x86_64")]
pub mod avx2;
#[cfg(target_arch = "aarch64")]
pub mod neon;

use super::api::{check_rows, wrong_layout, GemvKernel, Weights};
use super::{ActVec, KernelError};
use crate::costmodel::Method;
use crate::pack::{pad_rows, BitWidth, PackedMatrix, Variant};
pub use detect::{detected, probe, IsaSupport};

/// Which vector ISA a kernel (or a [`Method::FullPackIsa`] cost entry)
/// targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IsaKind {
    /// 256-bit AVX2 integer SIMD (x86-64).
    Avx2,
    /// 128-bit NEON/AdvSIMD (aarch64).
    Neon,
}

/// Every kind, widest lane first — the `PlanBuilder` preference order.
pub const ISA_KINDS: [IsaKind; 2] = [IsaKind::Avx2, IsaKind::Neon];

impl IsaKind {
    /// Vector register width in bytes (32 for AVX2, 16 for NEON) — the
    /// lane count the cost-model mixes and the `CoreModel::vec_bytes`
    /// admission gate are parameterized by.
    pub fn lane_bytes(&self) -> usize {
        match self {
            IsaKind::Avx2 => 32,
            IsaKind::Neon => 16,
        }
    }

    /// Registry-name suffix (`avx2` / `neon`).
    pub fn suffix(&self) -> &'static str {
        match self {
            IsaKind::Avx2 => "avx2",
            IsaKind::Neon => "neon",
        }
    }

    /// Figure-label fragment (`AVX2` / `NEON`).
    pub fn label(&self) -> &'static str {
        match self {
            IsaKind::Avx2 => "AVX2",
            IsaKind::Neon => "NEON",
        }
    }
}

/// The variants the ISA tier implements, one registry entry per
/// supported kind: sub-byte (and int8) weights × int8 activations —
/// the serving variants.
pub const ISA_VARIANTS: [Variant; 4] = [
    Variant::new(BitWidth::B4, BitWidth::B8),
    Variant::new(BitWidth::B2, BitWidth::B8),
    Variant::new(BitWidth::B1, BitWidth::B8),
    Variant::new(BitWidth::B8, BitWidth::B8),
];

/// Registry name of the ISA GEMV kernel for a variant × kind, if the
/// tier implements it.
pub fn isa_kernel_name(v: Variant, kind: IsaKind) -> Option<&'static str> {
    match (v.w, v.a, kind) {
        (BitWidth::B4, BitWidth::B8, IsaKind::Avx2) => Some("fullpack-w4a8-avx2"),
        (BitWidth::B2, BitWidth::B8, IsaKind::Avx2) => Some("fullpack-w2a8-avx2"),
        (BitWidth::B1, BitWidth::B8, IsaKind::Avx2) => Some("fullpack-w1a8-avx2"),
        (BitWidth::B8, BitWidth::B8, IsaKind::Avx2) => Some("fullpack-w8a8-avx2"),
        (BitWidth::B4, BitWidth::B8, IsaKind::Neon) => Some("fullpack-w4a8-neon"),
        (BitWidth::B2, BitWidth::B8, IsaKind::Neon) => Some("fullpack-w2a8-neon"),
        (BitWidth::B1, BitWidth::B8, IsaKind::Neon) => Some("fullpack-w1a8-neon"),
        (BitWidth::B8, BitWidth::B8, IsaKind::Neon) => Some("fullpack-w8a8-neon"),
        _ => None,
    }
}

/// One ISA-tier registry entry: a (variant × kind) pair.
pub struct IsaKernel {
    variant: Variant,
    kind: IsaKind,
    name: &'static str,
}

impl IsaKernel {
    /// Backend for `variant` on `kind`, if the tier implements it.
    /// Construction does NOT check host support — registration does
    /// (the selection tests rely on building kernels for foreign ISAs;
    /// executing one on an unsupported host returns `Unsupported`).
    pub fn new(variant: Variant, kind: IsaKind) -> Option<IsaKernel> {
        isa_kernel_name(variant, kind).map(|name| IsaKernel { variant, kind, name })
    }

    /// The ISA this entry targets.
    pub fn kind(&self) -> IsaKind {
        self.kind
    }
}

impl GemvKernel for IsaKernel {
    fn name(&self) -> &'static str {
        self.name
    }

    fn supports(&self, v: Variant) -> bool {
        v == self.variant
    }

    fn prepare(&self, w: &[i8], rows: usize, k: usize) -> Result<Weights, KernelError> {
        // identical layout to the FullPack tier: prepared weights are
        // interchangeable across the scalar, SWAR, LUT and ISA tiers
        let kp = self.variant.padded_depth(k);
        let padded = pad_rows(w, rows, k, kp);
        Ok(Weights::Packed(PackedMatrix::from_i8(&padded, rows, kp, self.variant.w)?))
    }

    fn gemv_at(
        &self,
        w: &Weights,
        a: ActVec<'_>,
        out: &mut [i32],
        row0: usize,
    ) -> Result<(), KernelError> {
        // the SAME packed bytes run whether they were prepared by this
        // tier, the scalar tier, or the SWAR tier (whose row-sum side
        // table is simply unused here)
        let wp = match w {
            Weights::Packed(m) => m,
            Weights::SwarPacked { m, .. } => m,
            other => return Err(wrong_layout(self.name, other)),
        };
        if wp.bits() != self.variant.w {
            return Err(wrong_layout(self.name, w));
        }
        check_rows(w, out, row0)?;
        let ActVec::I8(av) = a else {
            return Err(KernelError::Unsupported(format!("{}: packed activations", self.name)));
        };
        let kp = wp.k_padded();
        if av.len() < kp {
            return Err(KernelError::Shape(format!(
                "activation elems {} < padded depth {kp}",
                av.len()
            )));
        }
        run(self.kind, wp, av, out, row0)
    }

    fn cost_method(&self) -> Option<Method> {
        Some(Method::FullPackIsa(self.variant, self.kind))
    }
}

/// Execute on `kind`, re-verifying host support at the call site (a
/// kernel constructed for a foreign ISA — possible in selection-only
/// tests — must fail loudly, never execute intrinsics the CPU lacks).
fn run(
    kind: IsaKind,
    wp: &PackedMatrix,
    a: &[i8],
    out: &mut [i32],
    row0: usize,
) -> Result<(), KernelError> {
    match kind {
        IsaKind::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            if std::is_x86_feature_detected!("avx2") {
                match wp.bits() {
                    BitWidth::B4 => avx2::gemv_wsub_a8::<4>(wp, a, out, row0),
                    BitWidth::B2 => avx2::gemv_wsub_a8::<2>(wp, a, out, row0),
                    BitWidth::B1 => avx2::gemv_wsub_a8::<1>(wp, a, out, row0),
                    BitWidth::B8 => avx2::gemv_w8_a8(wp, a, out, row0),
                }
                return Ok(());
            }
            Err(KernelError::Unsupported("avx2 is not executable on this host".into()))
        }
        IsaKind::Neon => {
            #[cfg(target_arch = "aarch64")]
            if std::arch::is_aarch64_feature_detected!("neon") {
                match wp.bits() {
                    BitWidth::B4 => neon::gemv_wsub_a8::<4>(wp, a, out, row0),
                    BitWidth::B2 => neon::gemv_wsub_a8::<2>(wp, a, out, row0),
                    BitWidth::B1 => neon::gemv_wsub_a8::<1>(wp, a, out, row0),
                    BitWidth::B8 => neon::gemv_w8_a8(wp, a, out, row0),
                }
                return Ok(());
            }
            Err(KernelError::Unsupported("neon is not executable on this host".into()))
        }
    }
}

/// Register every ISA backend the support set covers (4 variants per
/// kind).  `with_builtins` calls this with [`detect::detected`];
/// selection tests call it with a forced [`IsaSupport`] to exercise
/// planning for ISAs the host may lack (executing such an entry
/// returns `Unsupported` — see [`IsaKernel::new`]).
pub fn register_isa_backends(reg: &mut super::KernelRegistry, support: IsaSupport) {
    for kind in ISA_KINDS {
        if !support.has(kind) {
            continue;
        }
        for v in ISA_VARIANTS {
            let kernel = IsaKernel::new(v, kind).expect("ISA_VARIANTS are implemented");
            reg.register(std::sync::Arc::new(kernel));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testutil::{oracle_gemv, rngvals};
    use crate::kernels::KernelRegistry;

    #[test]
    fn names_and_methods_share_the_registry_namespace() {
        for kind in ISA_KINDS {
            for v in ISA_VARIANTS {
                let kern = IsaKernel::new(v, kind).unwrap();
                let m = kern.cost_method().unwrap();
                assert_eq!(m.registry_name(), kern.name(), "{v} {kind:?}");
                assert!(kern.name().ends_with(kind.suffix()));
                assert!(kern.supports(v));
            }
        }
        // unimplemented pairs yield no entry
        assert!(IsaKernel::new(Variant::new(BitWidth::B4, BitWidth::B4), IsaKind::Avx2).is_none());
    }

    #[test]
    fn registration_follows_the_support_set() {
        let mut reg = KernelRegistry::empty();
        register_isa_backends(&mut reg, IsaSupport::NONE);
        assert_eq!(reg.len(), 0);
        let mut reg = KernelRegistry::empty();
        register_isa_backends(&mut reg, IsaSupport { avx2: true, neon: false });
        assert_eq!(reg.len(), ISA_VARIANTS.len());
        assert!(reg.get("fullpack-w4a8-avx2").is_some());
        assert!(reg.get("fullpack-w4a8-neon").is_none());
        let mut reg = KernelRegistry::empty();
        register_isa_backends(&mut reg, IsaSupport { avx2: true, neon: true });
        assert_eq!(reg.len(), 2 * ISA_VARIANTS.len());
    }

    #[test]
    fn foreign_isa_entries_fail_loudly_instead_of_executing() {
        // a kernel for whichever kind this host does NOT support must
        // return Unsupported from execution (selection-only tests build
        // these freely; running one would be UB without this guard)
        let host = detect::probe();
        for kind in ISA_KINDS {
            if host.has(kind) {
                continue;
            }
            let kern = IsaKernel::new(ISA_VARIANTS[0], kind).unwrap();
            let w = rngvals(BitWidth::B4, 4 * 64, 3);
            let wts = kern.prepare(&w, 4, 64).unwrap();
            let a = vec![0i8; wts.k_padded()];
            let mut out = vec![0i32; 4];
            let err = kern.gemv_at(&wts, ActVec::I8(&a), &mut out, 0);
            assert!(matches!(err, Err(KernelError::Unsupported(_))), "{kind:?}: {err:?}");
        }
    }

    #[test]
    fn supported_kinds_match_the_oracle_and_accept_swar_layout() {
        // executable check on whatever the host actually has — the full
        // depth grid lives in tests/registry_conformance.rs
        let host = detect::detected();
        for kind in host.kinds() {
            for v in ISA_VARIANTS {
                let kern = IsaKernel::new(v, kind).unwrap();
                let (z, k) = (7usize, 129usize);
                let w = rngvals(v.w, z * k, 17);
                let a0 = rngvals(v.a, k, 18);
                let wts = kern.prepare(&w, z, k).unwrap();
                let kp = wts.k_padded();
                let mut a = a0.clone();
                a.resize(kp, 0);
                let mut out = vec![0i32; z];
                kern.gemv_at(&wts, ActVec::I8(&a), &mut out, 0).unwrap();
                let wpad = crate::pack::pad_rows(&w, z, k, kp);
                assert_eq!(out, oracle_gemv(&wpad, &a, z, kp), "{v} {kind:?}");
                // row-range sharding entry
                let mut shard = vec![0i32; z - 2];
                kern.gemv_at(&wts, ActVec::I8(&a), &mut shard, 2).unwrap();
                assert_eq!(shard.as_slice(), &out[2..], "{v} {kind:?} shard");
                // the SWAR tier's prepared layout runs unchanged
                if v.w.is_sub_byte() {
                    let reg = KernelRegistry::global();
                    if let Some(swar) =
                        reg.get(crate::kernels::swar::swar_kernel_name(v).unwrap())
                    {
                        let swts = swar.prepare(&w, z, k).unwrap();
                        let mut via_swar_layout = vec![0i32; z];
                        kern.gemv_at(&swts, ActVec::I8(&a), &mut via_swar_layout, 0).unwrap();
                        assert_eq!(via_swar_layout, out, "{v} {kind:?} swar layout");
                    }
                }
            }
        }
    }
}

//! NEON/AdvSIMD FullPack GEMV kernels (DESIGN.md §15): the paper's own
//! instruction schedule (§3.2, Alg. 2) as real `std::arch::aarch64`
//! intrinsics — one 16-byte block per iteration.
//!
//! Extraction per sub-vector `k`: the two-shift schedule, `LSL` by
//! `8-(k+1)·B` then `ASR` by `8-B`, both as `vshlq_s8` (a negative
//! count is an arithmetic right shift on the signed variant).  MACs are
//! `vmull_s8` widening multiplies (low/high halves) accumulated with
//! `vpadalq_s16` into four i32 lanes — the widening chain never
//! saturates, so the kernels are exact at **every** width including
//! int8 (unlike AVX2's `maddubs`, which needs the biased schedule for
//! sub-byte and a widening path for int8; see `isa::avx2`).
//!
//! Zero weight padding extracts to zero lanes and contributes nothing,
//! so packed tail padding stays neutral exactly like the scalar tiers.

use crate::pack::{PackedMatrix, VL};
use std::arch::aarch64::*;

/// Sub-byte weights (`B ∈ {1,2,4}`) × int8 activations.  Caller must
/// have verified NEON support via `isa::detect` (debug-asserted here).
pub fn gemv_wsub_a8<const B: usize>(wp: &PackedMatrix, a: &[i8], out: &mut [i32], row0: usize) {
    debug_assert!(std::arch::is_aarch64_feature_detected!("neon"));
    debug_assert_eq!(wp.bits().bits(), B);
    debug_assert!(a.len() >= wp.k_padded());
    unsafe { gemv_wsub_a8_impl::<B>(wp, a, out, row0) }
}

#[target_feature(enable = "neon")]
unsafe fn gemv_wsub_a8_impl<const B: usize>(
    wp: &PackedMatrix,
    a: &[i8],
    out: &mut [i32],
    row0: usize,
) {
    let e = 8 / B;
    for (r, o) in out.iter_mut().enumerate() {
        let row = wp.row(row0 + r);
        let nblk = row.len() / VL;
        let mut acc = vdupq_n_s32(0);
        for blk in 0..nblk {
            let w = vld1q_s8(row.as_ptr().add(blk * VL) as *const i8);
            for k in 0..e {
                // LSL(8-(k+1)B) then ASR(8-B): Alg. 2 lines 8–9
                let lsl = vdupq_n_s8((8 - (k + 1) * B) as i8);
                let asr = vdupq_n_s8(-((8 - B) as i8));
                let sw = vshlq_s8(vshlq_s8(w, lsl), asr);
                let act = vld1q_s8(a.as_ptr().add((blk * e + k) * VL));
                acc = vpadalq_s16(acc, vmull_s8(vget_low_s8(sw), vget_low_s8(act)));
                acc = vpadalq_s16(acc, vmull_s8(vget_high_s8(sw), vget_high_s8(act)));
            }
        }
        *o = vaddvq_s32(acc);
    }
}

/// Int8 weights × int8 activations — same widening `vmull`/`vpadal`
/// chain, no extraction stage.
pub fn gemv_w8_a8(wp: &PackedMatrix, a: &[i8], out: &mut [i32], row0: usize) {
    debug_assert!(std::arch::is_aarch64_feature_detected!("neon"));
    debug_assert!(!wp.bits().is_sub_byte());
    debug_assert!(a.len() >= wp.k_padded());
    unsafe { gemv_w8_a8_impl(wp, a, out, row0) }
}

#[target_feature(enable = "neon")]
unsafe fn gemv_w8_a8_impl(wp: &PackedMatrix, a: &[i8], out: &mut [i32], row0: usize) {
    let k = wp.k_padded();
    let nblk = k / VL;
    for (r, o) in out.iter_mut().enumerate() {
        let row = wp.row(row0 + r);
        let mut acc = vdupq_n_s32(0);
        for blk in 0..nblk {
            let w = vld1q_s8(row.as_ptr().add(blk * VL) as *const i8);
            let av = vld1q_s8(a.as_ptr().add(blk * VL));
            acc = vpadalq_s16(acc, vmull_s8(vget_low_s8(w), vget_low_s8(av)));
            acc = vpadalq_s16(acc, vmull_s8(vget_high_s8(w), vget_high_s8(av)));
        }
        let mut sum = vaddvq_s32(acc);
        for i in nblk * VL..k {
            sum += row[i] as i8 as i32 * a[i] as i32;
        }
        *o = sum;
    }
}

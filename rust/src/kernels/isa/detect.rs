//! Runtime ISA capability probe (DESIGN.md §15).
//!
//! `probe()` asks the host CPU what it can execute
//! (`is_x86_feature_detected!` / `is_aarch64_feature_detected!` under
//! the matching `cfg(target_arch)` arm); [`detected`] additionally
//! applies the `FULLPACK_ISA` environment filter and caches the result
//! for the process lifetime — registration
//! (`kernels::isa::register_isa_backends`) and the conformance tests'
//! auto-skip both read this one answer.
//!
//! The env var can only **restrict**, never force-enable: executing an
//! intrinsic the CPU lacks is undefined behavior, so
//! `FULLPACK_ISA=neon` on an x86 host yields *no* ISA backends rather
//! than a crash.  Accepted values: a comma-separated subset of
//! `avx2,neon`, or `none` (or the empty string) to disable the tier —
//! the hook the tests use to exercise scalar-only registries on any
//! host.

use super::IsaKind;
use std::sync::OnceLock;

/// Which real-ISA kernel families the host can execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct IsaSupport {
    /// 256-bit AVX2 integer SIMD (x86-64).
    pub avx2: bool,
    /// 128-bit NEON/AdvSIMD (aarch64).
    pub neon: bool,
}

impl IsaSupport {
    /// No ISA tier at all — the portable baseline.
    pub const NONE: IsaSupport = IsaSupport { avx2: false, neon: false };

    /// Does the support set include `kind`?
    pub fn has(&self, kind: IsaKind) -> bool {
        match kind {
            IsaKind::Avx2 => self.avx2,
            IsaKind::Neon => self.neon,
        }
    }

    /// The supported kinds, widest lane first.
    pub fn kinds(&self) -> Vec<IsaKind> {
        let mut v = Vec::new();
        if self.avx2 {
            v.push(IsaKind::Avx2);
        }
        if self.neon {
            v.push(IsaKind::Neon);
        }
        v
    }

    /// Number of supported kinds.
    pub fn count(&self) -> usize {
        self.avx2 as usize + self.neon as usize
    }
}

/// Raw host capability check, no env filtering and no caching.
pub fn probe() -> IsaSupport {
    #[cfg(target_arch = "x86_64")]
    return IsaSupport { avx2: std::is_x86_feature_detected!("avx2"), neon: false };
    #[cfg(target_arch = "aarch64")]
    return IsaSupport {
        avx2: false,
        neon: std::arch::is_aarch64_feature_detected!("neon"),
    };
    #[allow(unreachable_code)]
    IsaSupport::NONE
}

/// [`probe`] filtered by the `FULLPACK_ISA` env var (restrict-only) and
/// cached for the process lifetime — the answer registration and the
/// test auto-skips agree on.
pub fn detected() -> IsaSupport {
    static CACHE: OnceLock<IsaSupport> = OnceLock::new();
    *CACHE.get_or_init(|| env_filter(probe(), std::env::var("FULLPACK_ISA").ok().as_deref()))
}

/// Apply the `FULLPACK_ISA` filter: unset keeps the probe verbatim; set
/// keeps only the listed kinds **that the probe already reported** —
/// the env can disable, never enable (enabling would execute intrinsics
/// the CPU lacks: UB).
pub fn env_filter(probed: IsaSupport, var: Option<&str>) -> IsaSupport {
    let Some(v) = var else { return probed };
    let mut allowed = IsaSupport::NONE;
    for tok in v.split(',').map(str::trim) {
        match tok {
            "avx2" => allowed.avx2 = true,
            "neon" => allowed.neon = true,
            _ => {} // "none", "", unknown tokens: allow nothing extra
        }
    }
    IsaSupport { avx2: probed.avx2 && allowed.avx2, neon: probed.neon && allowed.neon }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_filter_is_restrict_only() {
        let both = IsaSupport { avx2: true, neon: true };
        // unset: probe passes through
        assert_eq!(env_filter(both, None), both);
        // subset selection
        assert_eq!(env_filter(both, Some("avx2")), IsaSupport { avx2: true, neon: false });
        assert_eq!(env_filter(both, Some("neon")), IsaSupport { avx2: false, neon: true });
        assert_eq!(env_filter(both, Some("avx2,neon")), both);
        assert_eq!(env_filter(both, Some(" avx2 , neon ")), both);
        // disable entirely
        assert_eq!(env_filter(both, Some("none")), IsaSupport::NONE);
        assert_eq!(env_filter(both, Some("")), IsaSupport::NONE);
        // the env can never force-enable what the probe lacks
        assert_eq!(env_filter(IsaSupport::NONE, Some("avx2,neon")), IsaSupport::NONE);
        let only_neon = IsaSupport { avx2: false, neon: true };
        assert_eq!(env_filter(only_neon, Some("avx2")), IsaSupport::NONE);
    }

    #[test]
    fn probe_matches_the_compiled_arch() {
        let p = probe();
        // at most one family per architecture, and never a family the
        // target arch cannot express
        #[cfg(not(target_arch = "x86_64"))]
        assert!(!p.avx2);
        #[cfg(not(target_arch = "aarch64"))]
        assert!(!p.neon);
        assert!(p.count() <= 1);
    }

    #[test]
    fn detected_is_a_subset_of_probe() {
        let (d, p) = (detected(), probe());
        assert!(!d.avx2 || p.avx2);
        assert!(!d.neon || p.neon);
    }

    #[test]
    fn support_set_accessors_agree() {
        let s = IsaSupport { avx2: true, neon: false };
        assert!(s.has(IsaKind::Avx2) && !s.has(IsaKind::Neon));
        assert_eq!(s.kinds(), vec![IsaKind::Avx2]);
        assert_eq!(s.count(), 1);
        assert_eq!(IsaSupport::NONE.kinds(), Vec::<IsaKind>::new());
    }
}

//! Baseline GEMV/GEMM kernels standing in for the paper's nine rivals
//! (§4.1).  Each mirrors the *inner-loop structure* of the library it
//! represents — bytes moved per element, blocking, unrolling, extra
//! passes — which is what the figures compare (DESIGN.md substitution
//! table).

use crate::pack::{PackedMatrix, VL};

/// Ruy-like W8A8 (the paper's main baseline): row-major streaming with
/// 16-lane i32 accumulation — a well-optimized but straightforward i8
/// GEMV.
pub fn gemv_ruy_i8(wp: &PackedMatrix, a: &[i8], out: &mut [i32]) {
    gemv_ruy_i8_at(wp, a, out, 0)
}

/// [`gemv_ruy_i8`] over the row range `[row0, row0 + out.len())`.
pub fn gemv_ruy_i8_at(wp: &PackedMatrix, a: &[i8], out: &mut [i32], row0: usize) {
    debug_assert!(!wp.bits().is_sub_byte());
    for (r, o) in out.iter_mut().enumerate() {
        let row = wp.row_i8(row0 + r);
        let mut acc = [0i32; VL];
        let chunks = row.len() / VL;
        for c in 0..chunks {
            let mut wv = [0i8; VL];
            wv.copy_from_slice(&row[c * VL..(c + 1) * VL]);
            let mut av = [0i8; VL];
            av.copy_from_slice(&a[c * VL..(c + 1) * VL]);
            for j in 0..VL {
                acc[j] += (wv[j] as i16 * av[j] as i16) as i32;
            }
        }
        let mut sum: i32 = acc.iter().sum();
        for i in chunks * VL..row.len() {
            sum += row[i] as i32 * a[i] as i32;
        }
        *o = sum;
    }
}

/// XNNPack-like W8A8: 4-row micro-kernel with depth unrolled by 2×VL —
/// fewer loop-bookkeeping instructions per MAC (the paper's Fig. 12
/// shows XNNPack at ~0.68× of Ruy's instruction count).
pub fn gemv_xnn_i8(wp: &PackedMatrix, a: &[i8], out: &mut [i32]) {
    gemv_xnn_i8_at(wp, a, out, 0)
}

/// [`gemv_xnn_i8`] over the row range `[row0, row0 + out.len())`.
pub fn gemv_xnn_i8_at(wp: &PackedMatrix, a: &[i8], out: &mut [i32], row0: usize) {
    debug_assert!(!wp.bits().is_sub_byte());
    let z = out.len();
    let k = wp.k();
    let blocks = k / (2 * VL);
    let load = |src: &[i8]| -> [i8; VL] {
        let mut v = [0i8; VL];
        v.copy_from_slice(&src[..VL]);
        v
    };
    let mut r = 0;
    while r + 4 <= z {
        let rows = [
            wp.row_i8(row0 + r),
            wp.row_i8(row0 + r + 1),
            wp.row_i8(row0 + r + 2),
            wp.row_i8(row0 + r + 3),
        ];
        let mut acc = [[0i32; VL]; 4];
        for c in 0..blocks {
            let base = c * 2 * VL;
            let a0 = load(&a[base..]);
            let a1 = load(&a[base + VL..]);
            for (ri, row) in rows.iter().enumerate() {
                let w0 = load(&row[base..]);
                let w1 = load(&row[base + VL..]);
                for j in 0..VL {
                    acc[ri][j] += (w0[j] as i16 * a0[j] as i16) as i32;
                    acc[ri][j] += (w1[j] as i16 * a1[j] as i16) as i32;
                }
            }
        }
        for ri in 0..4 {
            let mut sum: i32 = acc[ri].iter().sum();
            for i in blocks * 2 * VL..k {
                sum += rows[ri][i] as i32 * a[i] as i32;
            }
            out[r + ri] = sum;
        }
        r += 4;
    }
    if r < z {
        gemv_ruy_i8_rows(wp, a, &mut out[r..], row0 + r);
    }
}

fn gemv_ruy_i8_rows(wp: &PackedMatrix, a: &[i8], out: &mut [i32], first: usize) {
    for (i, o) in out.iter_mut().enumerate() {
        let row = wp.row_i8(first + i);
        *o = row.iter().zip(a).map(|(&w, &x)| w as i32 * x as i32).sum();
    }
}

/// TFLite-default-like W8A8: plain scalar loop (C++ w/ intrinsics but no
/// hand blocking — consistently slower than Ruy in the paper's Fig. 4).
pub fn gemv_tflite_i8(wp: &PackedMatrix, a: &[i8], out: &mut [i32]) {
    gemv_tflite_i8_at(wp, a, out, 0)
}

/// [`gemv_tflite_i8`] over the row range `[row0, row0 + out.len())`.
pub fn gemv_tflite_i8_at(wp: &PackedMatrix, a: &[i8], out: &mut [i32], row0: usize) {
    debug_assert!(!wp.bits().is_sub_byte());
    for (r, o) in out.iter_mut().enumerate() {
        let row = wp.row_i8(row0 + r);
        let mut sum = 0i32;
        for i in 0..row.len() {
            sum += row[i] as i32 * a[i] as i32;
        }
        *o = sum;
    }
}

/// GEMMLOWP-like W8A8: an extra pack-to-temporary pass before the dot
/// (gemmlowp's packing stage) — same arithmetic, one more sweep over the
/// weight bytes per call.
pub fn gemv_gemmlowp_i8(wp: &PackedMatrix, a: &[i8], out: &mut [i32], scratch: &mut Vec<i8>) {
    gemv_gemmlowp_i8_at(wp, a, out, scratch, 0)
}

/// [`gemv_gemmlowp_i8`] over the row range `[row0, row0 + out.len())`.
pub fn gemv_gemmlowp_i8_at(
    wp: &PackedMatrix,
    a: &[i8],
    out: &mut [i32],
    scratch: &mut Vec<i8>,
    row0: usize,
) {
    debug_assert!(!wp.bits().is_sub_byte());
    let k = wp.k();
    scratch.clear();
    scratch.reserve(k);
    for (r, o) in out.iter_mut().enumerate() {
        // packing stage: copy the row into the packed buffer
        scratch.clear();
        scratch.extend_from_slice(wp.row_i8(row0 + r));
        let mut acc = [0i32; VL];
        let chunks = k / VL;
        for c in 0..chunks {
            for j in 0..VL {
                acc[j] += (scratch[c * VL + j] as i16 * a[c * VL + j] as i16) as i32;
            }
        }
        let mut sum: i32 = acc.iter().sum();
        for i in chunks * VL..k {
            sum += scratch[i] as i32 * a[i] as i32;
        }
        *o = sum;
    }
}

/// Ruy-like FP32 GEMV: blocked f32 with lane accumulation.
pub fn gemv_ruy_f32(w: &[f32], z: usize, k: usize, a: &[f32], out: &mut [f32]) {
    debug_assert_eq!(w.len(), z * k);
    for (r, o) in out.iter_mut().enumerate() {
        let row = &w[r * k..(r + 1) * k];
        let mut acc = [0f32; 8];
        let chunks = k / 8;
        for c in 0..chunks {
            for j in 0..8 {
                acc[j] += row[c * 8 + j] * a[c * 8 + j];
            }
        }
        let mut sum: f32 = acc.iter().sum();
        for i in chunks * 8..k {
            sum += row[i] * a[i];
        }
        *o = sum;
    }
}

/// Eigen-like FP32: 4-row blocked with 8-lane accumulators (Eigen's
/// gebp-style register blocking, simplified to GEMV).
pub fn gemv_eigen_f32(w: &[f32], z: usize, k: usize, a: &[f32], out: &mut [f32]) {
    debug_assert_eq!(w.len(), z * k);
    let mut r = 0;
    while r + 4 <= z {
        let mut acc = [[0f32; 8]; 4];
        let chunks = k / 8;
        for c in 0..chunks {
            for ri in 0..4 {
                let row = &w[(r + ri) * k..(r + ri + 1) * k];
                for j in 0..8 {
                    acc[ri][j] += row[c * 8 + j] * a[c * 8 + j];
                }
            }
        }
        for ri in 0..4 {
            let row = &w[(r + ri) * k..(r + ri + 1) * k];
            let mut sum: f32 = acc[ri].iter().sum();
            for i in chunks * 8..k {
                sum += row[i] * a[i];
            }
            out[r + ri] = sum;
        }
        r += 4;
    }
    for ri in r..z {
        let row = &w[ri * k..(ri + 1) * k];
        out[ri] = row.iter().zip(a).map(|(x, y)| x * y).sum();
    }
}

/// TFLite-default-like FP32: plain scalar loop.
pub fn gemv_tflite_f32(w: &[f32], z: usize, k: usize, a: &[f32], out: &mut [f32]) {
    debug_assert_eq!(w.len(), z * k);
    for (r, o) in out.iter_mut().enumerate() {
        *o = w[r * k..(r + 1) * k].iter().zip(a).map(|(x, y)| x * y).sum();
    }
}

/// W8A8 GEMM for the batch-16 FC layers (Ruy path in the paper's
/// end-to-end run): `out[z][b] = Σ_k w[z][k] · a[b][k]`, activations
/// row-major per batch.
pub fn gemm_ruy_i8(wp: &PackedMatrix, a: &[i8], batch: usize, out: &mut [i32]) {
    debug_assert!(!wp.bits().is_sub_byte());
    let z = wp.rows();
    let k = wp.k();
    debug_assert_eq!(a.len(), batch * k);
    debug_assert_eq!(out.len(), batch * z);
    for b in 0..batch {
        let av = &a[b * k..(b + 1) * k];
        gemv_ruy_i8(wp, av, &mut out[b * z..(b + 1) * z]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testutil::{oracle_gemv, rngvals};
    use crate::pack::{BitWidth, PackedMatrix};

    fn setup(z: usize, k: usize) -> (PackedMatrix, Vec<i8>, Vec<i8>, Vec<i32>) {
        let w = rngvals(BitWidth::B8, z * k, 21);
        let a = rngvals(BitWidth::B8, k, 22);
        let wp = PackedMatrix::from_i8(&w, z, k, BitWidth::B8).unwrap();
        let oracle = oracle_gemv(&w, &a, z, k);
        (wp, w, a, oracle)
    }

    #[test]
    fn all_i8_baselines_match_oracle() {
        for (z, k) in [(16usize, 96usize), (7, 100), (4, 15), (1, 1)] {
            let (wp, _w, a, oracle) = setup(z, k);
            let mut out = vec![0i32; z];
            gemv_ruy_i8(&wp, &a, &mut out);
            assert_eq!(out, oracle, "ruy z={z} k={k}");
            gemv_xnn_i8(&wp, &a, &mut out);
            assert_eq!(out, oracle, "xnn z={z} k={k}");
            gemv_tflite_i8(&wp, &a, &mut out);
            assert_eq!(out, oracle, "tflite z={z} k={k}");
            let mut scratch = Vec::new();
            gemv_gemmlowp_i8(&wp, &a, &mut out, &mut scratch);
            assert_eq!(out, oracle, "gemmlowp z={z} k={k}");
        }
    }

    #[test]
    fn f32_baselines_agree() {
        let z = 13;
        let k = 77;
        let w: Vec<f32> = (0..z * k).map(|i| ((i % 17) as f32 - 8.0) * 0.25).collect();
        let a: Vec<f32> = (0..k).map(|i| ((i % 11) as f32 - 5.0) * 0.5).collect();
        let mut o1 = vec![0f32; z];
        let mut o2 = vec![0f32; z];
        let mut o3 = vec![0f32; z];
        gemv_ruy_f32(&w, z, k, &a, &mut o1);
        gemv_eigen_f32(&w, z, k, &a, &mut o2);
        gemv_tflite_f32(&w, z, k, &a, &mut o3);
        for i in 0..z {
            assert!((o1[i] - o3[i]).abs() < 1e-3);
            assert!((o2[i] - o3[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn gemm_matches_stacked_gemv() {
        let z = 8;
        let k = 64;
        let batch = 3;
        let w = rngvals(BitWidth::B8, z * k, 31);
        let a = rngvals(BitWidth::B8, batch * k, 32);
        let wp = PackedMatrix::from_i8(&w, z, k, BitWidth::B8).unwrap();
        let mut out = vec![0i32; batch * z];
        gemm_ruy_i8(&wp, &a, batch, &mut out);
        for b in 0..batch {
            let oracle = oracle_gemv(&w, &a[b * k..(b + 1) * k], z, k);
            assert_eq!(&out[b * z..(b + 1) * z], oracle.as_slice());
        }
    }
}

//! FullPack batched GEMM — the paper's explicit **future-work gap**
//! ("FullPack does not support GEMM, so we used Ruy-W8A8 for the GEMM
//! operations", Fig. 10 caption) — implemented here as an extension:
//! the packed weight block is extracted *once* and the unpacked lanes
//! are reused across all batch columns, amortizing the extraction
//! overhead that makes repeated-GEMV batching wasteful.
//!
//! Cost intuition: repeated GEMV extracts each weight block `batch`
//! times (extraction : MAC ratio constant); batched GEMM extracts once
//! per `batch` MAC groups, so as batch grows the kernel converges to
//! pure-MAC throughput while still moving `b/8` bytes per weight.

use super::KernelError;
use crate::pack::{BitWidth, PackedMatrix, VL};

/// Column-tile width of the blocked loop: one weight-block extraction
/// feeds up to this many MAC streams, and the packed weight row is
/// re-walked once per tile (L1-resident by construction — a row is at
/// most a few KB).  The cost model amortizes weight loads and
/// extraction per tile, not per whole batch
/// (`costmodel::Method::instr_mix_gemm`, `sim::replay_gemm`).
pub const COL_TILE: usize = 4;

/// Extract + MAC over all batch columns: `out[c][r] = Σ_k w[r][k] · a[c][k]`.
///
/// `a_cols`: `batch` unpacked int8 activation vectors, each of length
/// `wp.k_padded()` (column-major batches, as the admission scheduler
/// seals them).  `out`: `batch * rows`, batch-major.
pub fn gemm_fullpack<const B: usize>(
    wp: &PackedMatrix,
    a_cols: &[&[i8]],
    out: &mut [i32],
) -> Result<(), KernelError> {
    let z = wp.rows();
    if out.len() != z * a_cols.len() {
        return Err(KernelError::Shape(format!(
            "out len {} != rows*batch {}",
            out.len(),
            z * a_cols.len()
        )));
    }
    gemm_fullpack_at::<B>(wp, a_cols, out, 0)
}

/// [`gemm_fullpack`] over the row-tile `[row0, row0 + rt)` where
/// `rt = out.len() / a_cols.len()` — the zero-copy sharding entry the
/// tile-parallel decorator uses.  The tile output is batch-major *over
/// the tile*: `out[c*rt + (r - row0)]` (for the full matrix this is
/// the plain batch-major result, so [`gemm_fullpack`] delegates here).
pub fn gemm_fullpack_at<const B: usize>(
    wp: &PackedMatrix,
    a_cols: &[&[i8]],
    out: &mut [i32],
    row0: usize,
) -> Result<(), KernelError> {
    let e = 8 / B;
    let batch = a_cols.len();
    if batch == 0 {
        return if out.is_empty() {
            Ok(())
        } else {
            Err(KernelError::Shape(format!("out len {} with empty batch", out.len())))
        };
    }
    if out.len() % batch != 0 {
        return Err(KernelError::Shape(format!(
            "out len {} not a multiple of batch {batch}",
            out.len()
        )));
    }
    let rt = out.len() / batch;
    if row0 + rt > wp.rows() {
        return Err(KernelError::Shape(format!(
            "row range {row0}..{} exceeds rows {}",
            row0 + rt,
            wp.rows()
        )));
    }
    for (c, col) in a_cols.iter().enumerate() {
        if col.len() < wp.k_padded() {
            return Err(KernelError::Shape(format!(
                "column {c} len {} < padded depth {}",
                col.len(),
                wp.k_padded()
            )));
        }
    }
    // column tiles of COL_TILE with stack-array accumulators: one
    // weight extraction feeds four MAC streams and the fixed shapes
    // keep the SLP vectorizer engaged (a heap `Vec` of accumulators
    // defeated it — see EXPERIMENTS.md §Perf iteration 4)
    for r in 0..rt {
        let row = wp.row(row0 + r);
        let mut c0 = 0;
        while c0 < batch {
            let ct = (batch - c0).min(COL_TILE);
            let mut accs = [[0i32; VL]; COL_TILE];
            for (blk, bytes) in row.chunks_exact(VL).enumerate() {
                let base = blk * e * VL;
                let mut blk_i8 = [0i8; VL];
                for j in 0..VL {
                    blk_i8[j] = bytes[j] as i8;
                }
                for k in 0..e {
                    let mut w = [0i8; VL];
                    let lsl = 8 - (k + 1) * B;
                    for j in 0..VL {
                        w[j] = ((blk_i8[j] << lsl) as i8) >> (8 - B);
                    }
                    for (ci, acc) in accs.iter_mut().enumerate().take(ct) {
                        let mut a = [0i8; VL];
                        a.copy_from_slice(&a_cols[c0 + ci][base + k * VL..base + (k + 1) * VL]);
                        for j in 0..VL {
                            acc[j] += (w[j] as i16 * a[j] as i16) as i32;
                        }
                    }
                }
            }
            for (ci, acc) in accs.iter().enumerate().take(ct) {
                out[(c0 + ci) * rt + r] = acc.iter().sum();
            }
            c0 += ct;
        }
    }
    Ok(())
}

/// Width-dispatched wrapper.
pub fn gemm_fullpack_dyn(
    wp: &PackedMatrix,
    a_cols: &[&[i8]],
    out: &mut [i32],
) -> Result<(), KernelError> {
    match wp.bits() {
        BitWidth::B4 => gemm_fullpack::<4>(wp, a_cols, out),
        BitWidth::B2 => gemm_fullpack::<2>(wp, a_cols, out),
        BitWidth::B1 => gemm_fullpack::<1>(wp, a_cols, out),
        BitWidth::B8 => Err(KernelError::Unsupported("w8 gemm: use baseline::gemm_ruy_i8".into())),
    }
}

/// Width-dispatched [`gemm_fullpack_at`].
pub fn gemm_fullpack_dyn_at(
    wp: &PackedMatrix,
    a_cols: &[&[i8]],
    out: &mut [i32],
    row0: usize,
) -> Result<(), KernelError> {
    match wp.bits() {
        BitWidth::B4 => gemm_fullpack_at::<4>(wp, a_cols, out, row0),
        BitWidth::B2 => gemm_fullpack_at::<2>(wp, a_cols, out, row0),
        BitWidth::B1 => gemm_fullpack_at::<1>(wp, a_cols, out, row0),
        BitWidth::B8 => Err(KernelError::Unsupported("w8 gemm: use baseline::gemm_ruy_i8".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testutil::{oracle_gemv, rngvals};

    #[test]
    fn batched_matches_per_column_oracle() {
        for bits in [BitWidth::B4, BitWidth::B2, BitWidth::B1] {
            let z = 16;
            let k = bits.group_size() * 2;
            let batch = 5;
            let w = rngvals(bits, z * k, 61);
            let wp = PackedMatrix::from_i8(&w, z, k, bits).unwrap();
            let cols: Vec<Vec<i8>> =
                (0..batch).map(|c| rngvals(BitWidth::B8, k, 62 + c as u64)).collect();
            let col_refs: Vec<&[i8]> = cols.iter().map(|c| c.as_slice()).collect();
            let mut out = vec![0i32; z * batch];
            gemm_fullpack_dyn(&wp, &col_refs, &mut out).unwrap();
            for (c, col) in cols.iter().enumerate() {
                assert_eq!(
                    &out[c * z..(c + 1) * z],
                    oracle_gemv(&w, col, z, k).as_slice(),
                    "{bits:?} col {c}"
                );
            }
        }
    }

    #[test]
    fn row_tile_matches_the_full_call() {
        let bits = BitWidth::B4;
        let z = 24;
        let k = bits.group_size() * 2;
        let batch = 3;
        let w = rngvals(bits, z * k, 71);
        let wp = PackedMatrix::from_i8(&w, z, k, bits).unwrap();
        let cols: Vec<Vec<i8>> =
            (0..batch).map(|c| rngvals(BitWidth::B8, k, 72 + c as u64)).collect();
        let refs: Vec<&[i8]> = cols.iter().map(|c| c.as_slice()).collect();
        let mut full = vec![0i32; z * batch];
        gemm_fullpack_dyn(&wp, &refs, &mut full).unwrap();
        // an interior tile is batch-major over the tile
        let (lo, hi) = (8usize, 19usize);
        let rt = hi - lo;
        let mut tile = vec![0i32; rt * batch];
        gemm_fullpack_dyn_at(&wp, &refs, &mut tile, lo).unwrap();
        for c in 0..batch {
            assert_eq!(
                &tile[c * rt..(c + 1) * rt],
                &full[c * z + lo..c * z + hi],
                "col {c}"
            );
        }
        // a tile past the last row is a shape error
        let mut bad = vec![0i32; 10 * batch];
        assert!(gemm_fullpack_dyn_at(&wp, &refs, &mut bad, z - 5).is_err());
    }

    #[test]
    fn empty_batch_is_ok() {
        let w = rngvals(BitWidth::B4, 8 * 32, 1);
        let wp = PackedMatrix::from_i8(&w, 8, 32, BitWidth::B4).unwrap();
        let mut out = vec![];
        gemm_fullpack_dyn(&wp, &[], &mut out).unwrap();
    }

    #[test]
    fn shape_errors() {
        let w = rngvals(BitWidth::B4, 8 * 32, 1);
        let wp = PackedMatrix::from_i8(&w, 8, 32, BitWidth::B4).unwrap();
        let a = vec![0i8; 32];
        let mut bad = vec![0i32; 3];
        assert!(gemm_fullpack_dyn(&wp, &[&a], &mut bad).is_err());
        let short = vec![0i8; 16];
        let mut out = vec![0i32; 8];
        assert!(gemm_fullpack_dyn(&wp, &[&short], &mut out).is_err());
        // 8-bit weights are not a FullPack GEMM case
        let w8 = PackedMatrix::from_i8(&vec![0i8; 8 * 32], 8, 32, BitWidth::B8).unwrap();
        assert!(gemm_fullpack_dyn(&w8, &[&a], &mut out).is_err());
    }
}

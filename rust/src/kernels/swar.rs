//! The SWAR fast-path kernel tier (DESIGN.md §8): u64-lane FullPack
//! GEMV inner loops that load **8 packed bytes per iteration** and
//! multiply-accumulate inside 64-bit general-purpose registers — no
//! reliance on the auto-vectorizer at all.
//!
//! The staged 16-lane loops in [`super::fullpack`] mirror the paper's
//! NEON assembly and run at full speed only when LLVM's SLP vectorizer
//! turns them into real SIMD.  This tier is the portable insurance: a
//! bit-plane decomposition that works on any 64-bit core.
//!
//! Per 8-byte chunk of a packed row (`w64 = load_le_u64`), for each
//! sub-vector `k` and bit position `p`:
//!
//! ```text
//!   m    ← (w64 >> (k·b + p)) & 0x0101..01     one 0x01 per set bit
//!   mask ← m · 0xFF                            0xFF per selected byte
//!   sel  ← (a64 ^ 0x8080..80) & mask           biased acts, selected
//!   acc  ← acc + lane-split(sel) << p          weighted u16-lane adds
//! ```
//!
//! Activations are biased to unsigned (`a + 128`) so selected bytes
//! accumulate without sign handling; the bias is removed once per row
//! with the precomputed weight row sum: `Σ(a+128)·w = Σa·w + 128·Σw`.
//! Negative-weight planes (the top bit of each two's-complement
//! sub-element) accumulate separately and subtract at the end.
//!
//! **Overflow-safe accumulator splitting**: selected bytes land in four
//! u16 lanes per u64 (even/odd byte split), and the lanes are reduced
//! into an `i64` every [`flush_period`] chunks — the largest interval
//! for which a lane provably stays below 2^16 even for all-min weights
//! against all-max activations.
//!
//! Depths that are not a multiple of the 8-byte chunk fall back to the
//! scalar two-shift extraction per byte (only reachable for the int8
//! `w8a8` rows; FullPack sub-byte rows are 16-byte multiples by
//! construction).
#![warn(missing_docs)]

use super::api::{check_gemm_shape, check_rows, wrong_layout, GemvKernel, Weights};
use super::fullpack::extract;
use super::{ActVec, KernelError};
use crate::costmodel::Method;
use crate::pack::{pad_rows, BitWidth, PackedMatrix, Variant, VL};

const ONES: u64 = 0x0101_0101_0101_0101;
const LO16: u64 = 0x00FF_00FF_00FF_00FF;
const SIGN: u64 = 0x8080_8080_8080_8080;

/// Minimum padded depth at which the planner prefers the SWAR tier:
/// below one full packed group the flush/bias bookkeeping dominates.
pub const SWAR_MIN_DEPTH: usize = 64;

/// The variants the SWAR tier implements (int8 activations only — the
/// bit-plane trick decomposes the *weights*; packed sub-byte
/// activations would need a second decomposition that costs more than
/// it saves).
pub const SWAR_VARIANTS: [Variant; 4] = [
    Variant::new(BitWidth::B4, BitWidth::B8),
    Variant::new(BitWidth::B2, BitWidth::B8),
    Variant::new(BitWidth::B1, BitWidth::B8),
    Variant::new(BitWidth::B8, BitWidth::B8),
];

/// 8-byte chunks a u16 lane can absorb before it could overflow: the
/// worst per-chunk lane gain is `E · 2^(b-1) · 255` (all-min weights ×
/// all-max biased activations on the negative plane).
const fn flush_period(b: usize) -> usize {
    65535 / ((8 / b) * (1 << (b - 1)) * 255)
}

/// Reduce four u16 lanes of a split accumulator into one integer.
#[inline(always)]
fn hsum16(x: u64) -> i64 {
    ((x & 0xFFFF) + ((x >> 16) & 0xFFFF) + ((x >> 32) & 0xFFFF) + (x >> 48)) as i64
}

/// Reinterpret an int8 slice as raw bytes (layout-identical).
#[inline(always)]
fn as_u8(a: &[i8]) -> &[u8] {
    // SAFETY: i8 and u8 have identical size/alignment.
    unsafe { std::slice::from_raw_parts(a.as_ptr() as *const u8, a.len()) }
}

#[inline(always)]
fn load_u64(bytes: &[u8]) -> u64 {
    u64::from_le_bytes(bytes[..8].try_into().expect("8-byte chunk"))
}

/// W sub-byte (`B` bits) × A int8, u64 SWAR loop — the fast-path twin
/// of [`super::fullpack::gemv_wsub_a8`].  `row_sums[r]` must hold the
/// integer sum of row `r`'s weights (padding contributes zero).
pub fn gemv_swar_wsub_a8<const B: usize>(
    wp: &PackedMatrix,
    row_sums: &[i64],
    a: &[i8],
    out: &mut [i32],
) {
    gemv_swar_wsub_a8_at::<B>(wp, row_sums, a, out, 0)
}

/// [`gemv_swar_wsub_a8`] over the row range `[row0, row0 + out.len())`
/// — the zero-copy sharding entry `RowParallel` composes over.
pub fn gemv_swar_wsub_a8_at<const B: usize>(
    wp: &PackedMatrix,
    row_sums: &[i64],
    a: &[i8],
    out: &mut [i32],
    row0: usize,
) {
    let e = 8 / B;
    debug_assert_eq!(wp.bits().bits(), B);
    debug_assert!(a.len() >= wp.k_padded());
    debug_assert!(row_sums.len() >= row0 + out.len());
    let au8 = as_u8(a);
    let flush_every = flush_period(B);
    for (r, o) in out.iter_mut().enumerate() {
        let row = wp.row(row0 + r);
        // positive-plane and negative-plane split accumulators
        // (even/odd byte lanes), flushed into i64 before u16 overflow
        let (mut pe, mut po, mut ne, mut no) = (0u64, 0u64, 0u64, 0u64);
        let (mut s_pos, mut s_neg) = (0i64, 0i64);
        let mut pending = 0usize;
        let chunks = row.chunks_exact(8);
        let tail = chunks.remainder();
        for (c, chunk) in chunks.enumerate() {
            let w64 = load_u64(chunk);
            // chunk c is half (c % 2) of packed group (c / 2)
            let base = (c / 2) * e * VL + (c % 2) * 8;
            for k in 0..e {
                let au = load_u64(&au8[base + k * VL..]) ^ SIGN;
                // positive planes: bit p contributes +2^p
                for p in 0..B - 1 {
                    let m = (w64 >> (k * B + p)) & ONES;
                    let sel = au & (m * 0xFF);
                    pe += (sel & LO16) << p;
                    po += ((sel >> 8) & LO16) << p;
                }
                // top plane: two's-complement sign bit contributes -2^(B-1)
                let m = (w64 >> (k * B + B - 1)) & ONES;
                let sel = au & (m * 0xFF);
                ne += (sel & LO16) << (B - 1);
                no += ((sel >> 8) & LO16) << (B - 1);
            }
            pending += 1;
            if pending == flush_every {
                s_pos += hsum16(pe) + hsum16(po);
                s_neg += hsum16(ne) + hsum16(no);
                (pe, po, ne, no) = (0, 0, 0, 0);
                pending = 0;
            }
        }
        if pending > 0 {
            s_pos += hsum16(pe) + hsum16(po);
            s_neg += hsum16(ne) + hsum16(no);
        }
        // scalar tail fallback (unreachable for FullPack-packed rows,
        // whose byte count is a multiple of VL = 16; kept so adopted
        // layouts with odd row strides stay correct)
        let mut tail_sum = 0i64;
        let off = row.len() - tail.len();
        for (t, &byte) in tail.iter().enumerate() {
            let i = off + t;
            let (g, j) = (i / VL, i % VL);
            for k in 0..e {
                let w = extract::<B>(byte as i8, k) as i64;
                tail_sum += w * (a[g * e * VL + k * VL + j] as i64 + 128);
            }
        }
        // unbias: Σ(a+128)·w = Σa·w + 128·Σw
        *o = ((s_pos - s_neg + tail_sum) - 128 * row_sums[row0 + r]) as i32;
    }
}

/// W int8 × A int8 with u64 loads: eight weight and eight activation
/// bytes per iteration, four interleaved accumulators, scalar tail for
/// `k % 8 != 0`.  The paper's full-utilization story is about sub-byte
/// data — int8 already fills every lane — so this entry is a load-width
/// optimization only, registered for completeness as the tier's
/// ULPPACK/Ruy-class rival.
pub fn gemv_swar_w8a8_at(wp: &PackedMatrix, a: &[i8], out: &mut [i32], row0: usize) {
    debug_assert!(!wp.bits().is_sub_byte());
    debug_assert!(a.len() >= wp.k());
    let au8 = as_u8(a);
    for (r, o) in out.iter_mut().enumerate() {
        let row = wp.row(row0 + r);
        let mut acc = [0i32; 4];
        let chunks = row.len() / 8;
        for c in 0..chunks {
            let w64 = load_u64(&row[c * 8..]);
            let a64 = load_u64(&au8[c * 8..]);
            for lane in 0..8 {
                let wv = ((w64 >> (8 * lane)) as u8) as i8 as i32;
                let av = ((a64 >> (8 * lane)) as u8) as i8 as i32;
                acc[lane & 3] += wv * av;
            }
        }
        let mut sum: i32 = acc.iter().sum();
        for i in chunks * 8..row.len() {
            sum += (row[i] as i8) as i32 * a[i] as i32;
        }
        *o = sum;
    }
}

/// Registry name of the SWAR-tier kernel for a variant, if the tier
/// implements it (see [`SWAR_VARIANTS`]).
pub fn swar_kernel_name(v: Variant) -> Option<&'static str> {
    match (v.w, v.a) {
        (BitWidth::B4, BitWidth::B8) => Some("fullpack-w4a8-swar"),
        (BitWidth::B2, BitWidth::B8) => Some("fullpack-w2a8-swar"),
        (BitWidth::B1, BitWidth::B8) => Some("fullpack-w1a8-swar"),
        (BitWidth::B8, BitWidth::B8) => Some("fullpack-w8a8-swar"),
        _ => None,
    }
}

/// The SWAR tier as a first-class registry backend: same packed layout
/// and padding contract as the scalar FullPack kernels, plus cached
/// per-row weight sums for the bias correction.
pub struct SwarKernel {
    variant: Variant,
    name: &'static str,
}

impl SwarKernel {
    /// Backend for `variant`, or `None` when the tier does not
    /// implement it (sub-byte activations).
    pub fn new(variant: Variant) -> Option<SwarKernel> {
        swar_kernel_name(variant).map(|name| SwarKernel { variant, name })
    }
}

impl GemvKernel for SwarKernel {
    fn name(&self) -> &'static str {
        self.name
    }

    fn supports(&self, v: Variant) -> bool {
        v == self.variant
    }

    fn prepare(&self, w: &[i8], rows: usize, k: usize) -> Result<Weights, KernelError> {
        let kp = self.variant.padded_depth(k);
        let padded = pad_rows(w, rows, k, kp);
        let m = PackedMatrix::from_i8(&padded, rows, kp, self.variant.w)?;
        if self.variant.w.is_sub_byte() {
            let row_sums = (0..rows)
                .map(|r| w[r * k..(r + 1) * k].iter().map(|&v| v as i64).sum())
                .collect();
            Ok(Weights::SwarPacked { m, row_sums })
        } else {
            Ok(Weights::Packed(m))
        }
    }

    fn gemv_at(
        &self,
        w: &Weights,
        a: ActVec<'_>,
        out: &mut [i32],
        row0: usize,
    ) -> Result<(), KernelError> {
        check_rows(w, out, row0)?;
        let ActVec::I8(av) = a else {
            return Err(KernelError::Unsupported(format!("{}: packed activations", self.name)));
        };
        if av.len() < w.k_padded() {
            return Err(KernelError::Shape(format!(
                "activation elems {} < padded depth {}",
                av.len(),
                w.k_padded()
            )));
        }
        match w {
            Weights::SwarPacked { m, row_sums } => match m.bits() {
                BitWidth::B4 => gemv_swar_wsub_a8_at::<4>(m, row_sums, av, out, row0),
                BitWidth::B2 => gemv_swar_wsub_a8_at::<2>(m, row_sums, av, out, row0),
                BitWidth::B1 => gemv_swar_wsub_a8_at::<1>(m, row_sums, av, out, row0),
                BitWidth::B8 => return Err(wrong_layout(self.name, w)),
            },
            // only the tier's own w8a8 entry runs plain int8 weights —
            // a sub-byte SWAR kernel handed another backend's B8 layout
            // must reject it like every other cross-kernel mismatch
            Weights::Packed(m)
                if !self.variant.w.is_sub_byte() && !m.bits().is_sub_byte() =>
            {
                gemv_swar_w8a8_at(m, av, out, row0)
            }
            other => return Err(wrong_layout(self.name, other)),
        }
        Ok(())
    }

    fn cost_method(&self) -> Option<Method> {
        Some(Method::FullPackSwar(self.variant))
    }

    /// Batched calls on the SWAR layout delegate to the FullPack GEMM
    /// extension over the shared packed matrix: extracting each weight
    /// block once and reusing it across all columns beats running the
    /// per-column bias/flush dance `batch` times (the row-sum side
    /// table is a GEMV-only artifact — the GEMM path extracts signed
    /// weights directly and needs no unbiasing).
    fn gemm(&self, w: &Weights, cols: &[&[i8]], out: &mut [i32]) -> Result<(), KernelError> {
        check_gemm_shape(w, cols, out)?;
        match w {
            Weights::SwarPacked { m, .. } if m.bits().is_sub_byte() => {
                super::fullpack_gemm::gemm_fullpack_dyn(m, cols, out)
            }
            // the tier's w8a8 entry (plain packed layout) and anything
            // else keep the repeated-GEMV default
            _ => {
                let z = w.rows();
                for (c, col) in cols.iter().enumerate() {
                    self.gemv_at(w, ActVec::I8(col), &mut out[c * z..(c + 1) * z], 0)?;
                }
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testutil::{oracle_gemv, rngvals};

    fn run_sub<const B: usize>(bits: BitWidth, z: usize, k: usize, seed: u64) {
        let kp = bits.padded_len(k);
        let mut w = rngvals(bits, z * k, seed);
        let mut a = rngvals(BitWidth::B8, k, seed + 1);
        let mut wfull = vec![0i8; z * kp];
        for r in 0..z {
            wfull[r * kp..r * kp + k].copy_from_slice(&w[r * k..(r + 1) * k]);
        }
        w = wfull;
        a.resize(kp, 0);
        let wp = PackedMatrix::from_i8(&w, z, kp, bits).unwrap();
        let sums: Vec<i64> =
            (0..z).map(|r| w[r * kp..(r + 1) * kp].iter().map(|&v| v as i64).sum()).collect();
        let mut out = vec![0i32; z];
        gemv_swar_wsub_a8::<B>(&wp, &sums, &a, &mut out);
        assert_eq!(out, oracle_gemv(&w, &a, z, kp), "b={B} z={z} k={k}");
    }

    #[test]
    fn swar_matches_oracle_across_depths() {
        for k in [1usize, 7, 8, 9, 16, 31, 63, 64, 65, 127, 129, 500, 1024] {
            run_sub::<4>(BitWidth::B4, 6, k, 100 + k as u64);
            run_sub::<2>(BitWidth::B2, 6, k, 200 + k as u64);
            run_sub::<1>(BitWidth::B1, 6, k, 300 + k as u64);
        }
    }

    #[test]
    fn swar_extremes_exercise_flush_bound() {
        // all-min weights × all-max activations for many flush periods:
        // the worst-case u16-lane gain the flush interval is sized for
        for (bits, b) in [(BitWidth::B4, 4usize), (BitWidth::B2, 2), (BitWidth::B1, 1)] {
            let k = 8192usize;
            let (wlo, whi) = bits.value_range();
            for (wv, av) in [(wlo, 127i8), (whi, -128i8), (wlo, -128), (whi, 127)] {
                let z = 2;
                let w = vec![wv; z * k];
                let a = vec![av; k];
                let wp = PackedMatrix::from_i8(&w, z, k, bits).unwrap();
                let sums = vec![(wv as i64) * k as i64; z];
                let mut out = vec![0i32; z];
                match b {
                    4 => gemv_swar_wsub_a8::<4>(&wp, &sums, &a, &mut out),
                    2 => gemv_swar_wsub_a8::<2>(&wp, &sums, &a, &mut out),
                    _ => gemv_swar_wsub_a8::<1>(&wp, &sums, &a, &mut out),
                }
                assert_eq!(out, oracle_gemv(&w, &a, z, k), "{bits:?} w={wv} a={av}");
            }
        }
    }

    #[test]
    fn swar_w8a8_tail_fallback() {
        // depths not divisible by the 8-byte chunk take the scalar tail
        for k in [1usize, 7, 9, 15, 63, 65, 127, 129] {
            let z = 5;
            let w = rngvals(BitWidth::B8, z * k, 7 + k as u64);
            let a = rngvals(BitWidth::B8, k, 8 + k as u64);
            let wp = PackedMatrix::from_i8(&w, z, k, BitWidth::B8).unwrap();
            let mut out = vec![0i32; z];
            gemv_swar_w8a8_at(&wp, &a, &mut out, 0);
            assert_eq!(out, oracle_gemv(&w, &a, z, k), "k={k}");
        }
    }

    #[test]
    fn swar_kernel_prepare_and_row_ranges() {
        let kernel = SwarKernel::new(Variant::parse("w4a8").unwrap()).unwrap();
        let (z, k) = (16usize, 100usize);
        let w = rngvals(BitWidth::B4, z * k, 21);
        let a = {
            let mut a = rngvals(BitWidth::B8, k, 22);
            a.resize(BitWidth::B4.padded_len(k), 0);
            a
        };
        let wts = kernel.prepare(&w, z, k).unwrap();
        assert_eq!(wts.rows(), z);
        assert_eq!(wts.k(), k);
        let mut full = vec![0i32; z];
        kernel.gemv_at(&wts, ActVec::I8(&a), &mut full, 0).unwrap();
        // sharded row ranges agree with the full call
        let mut lo = vec![0i32; 7];
        let mut hi = vec![0i32; 9];
        kernel.gemv_at(&wts, ActVec::I8(&a), &mut lo, 0).unwrap();
        kernel.gemv_at(&wts, ActVec::I8(&a), &mut hi, 7).unwrap();
        assert_eq!(&full[..7], lo.as_slice());
        assert_eq!(&full[7..], hi.as_slice());
    }

    #[test]
    fn sub_byte_swar_rejects_foreign_b8_layout() {
        // a w4a8 SWAR kernel handed another backend's plain int8 layout
        // must error, while the tier's own w8a8 entry accepts it
        let b8 = PackedMatrix::from_i8(&vec![1i8; 8 * 64], 8, 64, BitWidth::B8).unwrap();
        let w = Weights::Packed(b8);
        let a = vec![1i8; 64];
        let mut out = vec![0i32; 8];
        let k4 = SwarKernel::new(Variant::parse("w4a8").unwrap()).unwrap();
        assert!(k4.gemv_at(&w, ActVec::I8(&a), &mut out, 0).is_err());
        let k8 = SwarKernel::new(Variant::parse("w8a8").unwrap()).unwrap();
        k8.gemv_at(&w, ActVec::I8(&a), &mut out, 0).unwrap();
        assert!(out.iter().all(|&y| y == 64));
    }

    #[test]
    fn swar_gemm_delegates_to_the_extract_once_extension() {
        // batched calls on the SwarPacked layout match the per-column
        // SWAR GEMV bit-for-bit (both equal the oracle)
        let kernel = SwarKernel::new(Variant::parse("w2a8").unwrap()).unwrap();
        let (z, k, batch) = (8usize, 100usize, 3usize);
        let w = rngvals(BitWidth::B2, z * k, 51);
        let wts = kernel.prepare(&w, z, k).unwrap();
        let kp = wts.k_padded();
        let cols: Vec<Vec<i8>> = (0..batch)
            .map(|c| {
                let mut col = rngvals(BitWidth::B8, k, 52 + c as u64);
                col.resize(kp, 0);
                col
            })
            .collect();
        let col_refs: Vec<&[i8]> = cols.iter().map(|c| c.as_slice()).collect();
        let mut out = vec![0i32; z * batch];
        kernel.gemm(&wts, &col_refs, &mut out).unwrap();
        for (c, col) in cols.iter().enumerate() {
            let mut one = vec![0i32; z];
            kernel.gemv_at(&wts, ActVec::I8(col), &mut one, 0).unwrap();
            assert_eq!(&out[c * z..(c + 1) * z], one.as_slice(), "col {c}");
        }
        // shape rejection
        let mut bad = vec![0i32; z * batch - 1];
        assert!(kernel.gemm(&wts, &col_refs, &mut bad).is_err());
    }

    #[test]
    fn swar_names_and_variants() {
        assert_eq!(SWAR_VARIANTS.len(), 4);
        for v in SWAR_VARIANTS {
            let kernel = SwarKernel::new(v).unwrap();
            assert_eq!(Some(kernel.name()), swar_kernel_name(v));
            assert!(kernel.name().ends_with("-swar"));
            assert!(kernel.supports(v));
        }
        assert!(SwarKernel::new(Variant::parse("w4a4").unwrap()).is_none());
        assert!(swar_kernel_name(Variant::parse("w8a4").unwrap()).is_none());
    }

    #[test]
    fn flush_periods_are_overflow_safe() {
        for b in [4usize, 2, 1] {
            let e = 8 / b;
            let worst = e * (1usize << (b - 1)) * 255;
            let period = flush_period(b);
            assert!(period >= 1, "b={b}");
            assert!(period * worst <= 65535, "b={b} period={period}");
            assert!((period + 1) * worst > 65535, "b={b}: period not maximal");
        }
    }
}

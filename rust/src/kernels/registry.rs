//! The kernel registry (DESIGN.md §4): every GEMV backend in the repo,
//! registered by name behind the [`GemvKernel`] trait.  Each entry is
//! one (kernel family × variant) pair — `fullpack-w4a8`, `ruy-w8a8`,
//! `ulppack-w2a2`, ... — so selection policies, the cost model and the
//! figure harnesses all share one method namespace.
//!
//! Built-in entries:
//!
//! | name              | family   | layout    | modeled as            |
//! |-------------------|----------|-----------|-----------------------|
//! | `fullpack-wXaY`   | FullPack | stride-16 | `Method::FullPack`    |
//! | `fullpack-wXa8-swar` | SWAR tier | stride-16 + row sums | `Method::FullPackSwar` |
//! | `fullpack-wXa8-avx2`/`-neon` | ISA tier (detected) | stride-16 | `Method::FullPackIsa` |
//! | `lut-wXaY`        | LUT tier | stride-16 + per-call tables | `Method::Lut` |
//! | `naive-wXa8`      | Alg. 1   | adjacent  | `Method::Naive`       |
//! | `ulppack-wXaX`    | ULPPACK  | spacer    | `Method::Ulppack`     |
//! | `ruy-w8a8` &co.   | int8     | row-major | `Method::*W8A8`       |
//! | `ruy-f32` &co.    | FP32     | f32 rows  | `Method::*F32`        |
//!
//! GEMM-tier entries (their own namespace, `-gemm` suffix — DESIGN.md §9):
//!
//! | name                  | family       | layout    | modeled as             |
//! |-----------------------|--------------|-----------|------------------------|
//! | `fullpack-wXa8-gemm`  | FullPack     | stride-16 | `Method::FullPackGemm` |
//! | `lut-wXaY-gemm`       | LUT tier     | stride-16 | `Method::LutGemm`      |
//! | `ruy-like-w8a8-gemm`  | int8 rival   | row-major | repeated `RuyW8A8`     |
//! | `naive-oracle-gemm`   | test oracle  | unpacked  | (not modeled)          |
//!
//! [`RowParallel`] is the row-sharding decorator: it wraps any entry and
//! implements the same trait, so intra-op parallelism composes with
//! every backend.  [`RowParallelGemm`] is its GEMM-tier sibling: it
//! shards batched calls by output row-tiles through
//! [`GemmKernel::gemm_at`].
//!
//! The ISA tier (`fullpack-wXa8-avx2`, `fullpack-wXa8-neon` —
//! `kernels::isa`) is registered **only when the running host can
//! execute it** ([`super::isa::detect::detected`]), so the roster is
//! host-dependent by design: every registered entry is runnable.
#![warn(missing_docs)]

use super::api::{
    check_gemm_shape, check_gemm_tile, check_rows, wrong_layout, GemmKernel, GemvKernel, Weights,
};
use super::lut::{LutGemmKernel, LutKernel, LUT_VARIANTS};
use super::swar::{SwarKernel, SWAR_VARIANTS};
use super::{baseline, fullpack_gemm, naive, parallel, ulppack, ActVec, KernelError};
use crate::costmodel::Method;
use crate::pack::{pad_rows, BitWidth, PackedMatrix, UlppackMatrix, Variant};
use std::cell::RefCell;
use std::sync::{Arc, OnceLock};

/// Reused per-thread adapter buffers: per-call conversions (gemmlowp's
/// pack-to-temp pass, the f32 stand-ins' int8→f32 widening) must not
/// heap-allocate inside timed regions, or the measured figures would
/// charge the rivals for allocator time FullPack's path doesn't pay.
#[derive(Default)]
struct AdapterBufs {
    gemmlowp: Vec<i8>,
    f32_acts: Vec<f32>,
    f32_out: Vec<f32>,
}

thread_local! {
    static ADAPTER_BUFS: RefCell<AdapterBufs> = RefCell::new(AdapterBufs::default());
}

/// Registry name of the FullPack kernel for a variant.
pub fn fullpack_kernel_name(v: Variant) -> &'static str {
    match (v.w, v.a) {
        (BitWidth::B8, BitWidth::B4) => "fullpack-w8a4",
        (BitWidth::B4, BitWidth::B8) => "fullpack-w4a8",
        (BitWidth::B4, BitWidth::B4) => "fullpack-w4a4",
        (BitWidth::B2, BitWidth::B8) => "fullpack-w2a8",
        (BitWidth::B8, BitWidth::B2) => "fullpack-w8a2",
        (BitWidth::B2, BitWidth::B2) => "fullpack-w2a2",
        (BitWidth::B1, BitWidth::B8) => "fullpack-w1a8",
        (BitWidth::B8, BitWidth::B1) => "fullpack-w8a1",
        (BitWidth::B1, BitWidth::B1) => "fullpack-w1a1",
        (BitWidth::B8, BitWidth::B8) => "fullpack-w8a8",
        _ => "fullpack-unsupported",
    }
}

/// The nine paper FullPack variants (§3.2), one registry entry each.
struct FullPackKernel {
    variant: Variant,
}

impl GemvKernel for FullPackKernel {
    fn name(&self) -> &'static str {
        fullpack_kernel_name(self.variant)
    }

    fn supports(&self, v: Variant) -> bool {
        v == self.variant
    }

    fn prepare(&self, w: &[i8], rows: usize, k: usize) -> Result<Weights, KernelError> {
        let kp = self.variant.padded_depth(k);
        let padded = pad_rows(w, rows, k, kp);
        Ok(Weights::Packed(PackedMatrix::from_i8(&padded, rows, kp, self.variant.w)?))
    }

    fn gemv_at(
        &self,
        w: &Weights,
        a: ActVec<'_>,
        out: &mut [i32],
        row0: usize,
    ) -> Result<(), KernelError> {
        match w {
            Weights::Packed(wp) => super::gemv_at(wp, a, out, row0),
            other => Err(wrong_layout(self.name(), other)),
        }
    }

    fn cost_method(&self) -> Option<Method> {
        Some(Method::FullPack(self.variant))
    }

    fn packs_activations(&self) -> bool {
        self.variant.a.is_sub_byte()
    }

    fn gemm(&self, w: &Weights, cols: &[&[i8]], out: &mut [i32]) -> Result<(), KernelError> {
        let z = w.rows();
        if out.len() != z * cols.len() {
            return Err(KernelError::Shape(format!(
                "out len {} != rows*batch {}",
                out.len(),
                z * cols.len()
            )));
        }
        match w {
            // the batched-GEMM extension: extract each weight block once,
            // reuse across all columns
            Weights::Packed(wp) if wp.bits().is_sub_byte() => {
                fullpack_gemm::gemm_fullpack_dyn(wp, cols, out)
            }
            Weights::Packed(_) => {
                for (c, col) in cols.iter().enumerate() {
                    self.gemv_at(w, ActVec::I8(col), &mut out[c * z..(c + 1) * z], 0)?;
                }
                Ok(())
            }
            other => Err(wrong_layout(self.name(), other)),
        }
    }
}

/// Which W8A8 rival inner-loop structure an [`I8Baseline`] mirrors.
enum I8Flavor {
    Ruy,
    Xnn,
    Tflite,
    Gemmlowp,
}

struct I8Baseline {
    flavor: I8Flavor,
}

impl I8Baseline {
    fn operands<'w>(
        &self,
        w: &'w Weights,
        a: ActVec<'_>,
        out: &[i32],
        row0: usize,
    ) -> Result<&'w PackedMatrix, KernelError> {
        let Weights::Packed(wp) = w else { return Err(wrong_layout(self.name(), w)) };
        if wp.bits().is_sub_byte() {
            return Err(wrong_layout(self.name(), w));
        }
        check_rows(w, out, row0)?;
        if a.elems() < wp.k() {
            return Err(KernelError::Shape(format!(
                "activation elems {} < depth {}",
                a.elems(),
                wp.k()
            )));
        }
        Ok(wp)
    }
}

impl GemvKernel for I8Baseline {
    fn name(&self) -> &'static str {
        match self.flavor {
            I8Flavor::Ruy => "ruy-w8a8",
            I8Flavor::Xnn => "xnn-w8a8",
            I8Flavor::Tflite => "tflite-w8a8",
            I8Flavor::Gemmlowp => "gemmlowp-w8a8",
        }
    }

    fn supports(&self, v: Variant) -> bool {
        !v.w.is_sub_byte() && !v.a.is_sub_byte()
    }

    fn prepare(&self, w: &[i8], rows: usize, k: usize) -> Result<Weights, KernelError> {
        Ok(Weights::Packed(PackedMatrix::from_i8(w, rows, k, BitWidth::B8)?))
    }

    fn gemv_at(
        &self,
        w: &Weights,
        a: ActVec<'_>,
        out: &mut [i32],
        row0: usize,
    ) -> Result<(), KernelError> {
        let wp = self.operands(w, a, out, row0)?;
        let ActVec::I8(av) = a else {
            return Err(KernelError::Unsupported(format!("{}: packed activations", self.name())));
        };
        match self.flavor {
            I8Flavor::Ruy => baseline::gemv_ruy_i8_at(wp, av, out, row0),
            I8Flavor::Xnn => baseline::gemv_xnn_i8_at(wp, av, out, row0),
            I8Flavor::Tflite => baseline::gemv_tflite_i8_at(wp, av, out, row0),
            I8Flavor::Gemmlowp => ADAPTER_BUFS.with(|b| {
                // the pack-to-temp stage is gemmlowp's own extra pass;
                // its temp buffer is reused across calls
                baseline::gemv_gemmlowp_i8_at(wp, av, out, &mut b.borrow_mut().gemmlowp, row0)
            }),
        }
        Ok(())
    }

    fn cost_method(&self) -> Option<Method> {
        Some(match self.flavor {
            I8Flavor::Ruy => Method::RuyW8A8,
            I8Flavor::Xnn => Method::XnnW8A8,
            I8Flavor::Tflite => Method::TfliteW8A8,
            I8Flavor::Gemmlowp => Method::GemmlowpW8A8,
        })
    }
}

/// FP32 rival flavor.
enum F32Flavor {
    Ruy,
    Eigen,
    Tflite,
}

struct F32Baseline {
    flavor: F32Flavor,
}

impl GemvKernel for F32Baseline {
    fn name(&self) -> &'static str {
        match self.flavor {
            F32Flavor::Ruy => "ruy-f32",
            F32Flavor::Eigen => "eigen-f32",
            F32Flavor::Tflite => "tflite-f32",
        }
    }

    fn supports(&self, v: Variant) -> bool {
        // the FP32 baselines stand in for the unquantized model: int8
        // values pass through losslessly (f32 holds ±2^24 exactly)
        !v.w.is_sub_byte() && !v.a.is_sub_byte()
    }

    fn prepare(&self, w: &[i8], rows: usize, k: usize) -> Result<Weights, KernelError> {
        debug_assert_eq!(w.len(), rows * k);
        Ok(Weights::F32 { data: w.iter().map(|&v| v as f32).collect(), rows, k })
    }

    fn gemv_at(
        &self,
        w: &Weights,
        a: ActVec<'_>,
        out: &mut [i32],
        row0: usize,
    ) -> Result<(), KernelError> {
        let Weights::F32 { data, k, .. } = w else { return Err(wrong_layout(self.name(), w)) };
        check_rows(w, out, row0)?;
        let ActVec::I8(av) = a else {
            return Err(KernelError::Unsupported(format!("{}: packed activations", self.name())));
        };
        if av.len() < *k {
            return Err(KernelError::Shape(format!(
                "activation elems {} < depth {k}",
                av.len()
            )));
        }
        let z = out.len();
        let rows = &data[row0 * k..(row0 + z) * k];
        ADAPTER_BUFS.with(|b| {
            let mut b = b.borrow_mut();
            let bufs = &mut *b;
            bufs.f32_acts.clear();
            bufs.f32_acts.extend(av[..*k].iter().map(|&v| v as f32));
            bufs.f32_out.clear();
            bufs.f32_out.resize(z, 0.0);
            match self.flavor {
                F32Flavor::Ruy => baseline::gemv_ruy_f32(rows, z, *k, &bufs.f32_acts, &mut bufs.f32_out),
                F32Flavor::Eigen => {
                    baseline::gemv_eigen_f32(rows, z, *k, &bufs.f32_acts, &mut bufs.f32_out)
                }
                F32Flavor::Tflite => {
                    baseline::gemv_tflite_f32(rows, z, *k, &bufs.f32_acts, &mut bufs.f32_out)
                }
            }
            for (o, v) in out.iter_mut().zip(&bufs.f32_out) {
                *o = v.round() as i32;
            }
        });
        Ok(())
    }

    fn cost_method(&self) -> Option<Method> {
        Some(match self.flavor {
            F32Flavor::Ruy => Method::RuyF32,
            F32Flavor::Eigen => Method::EigenF32,
            F32Flavor::Tflite => Method::TfliteF32,
        })
    }
}

/// The Alg. 1 strawman: adjacent packing, scalar extraction.
struct NaiveKernel {
    bits: BitWidth,
}

impl NaiveKernel {
    fn variant(&self) -> Variant {
        Variant::new(self.bits, BitWidth::B8)
    }
}

impl GemvKernel for NaiveKernel {
    fn name(&self) -> &'static str {
        match self.bits {
            BitWidth::B4 => "naive-w4a8",
            BitWidth::B2 => "naive-w2a8",
            BitWidth::B1 => "naive-w1a8",
            BitWidth::B8 => "naive-w8a8",
        }
    }

    fn supports(&self, v: Variant) -> bool {
        v == self.variant()
    }

    fn prepare(&self, w: &[i8], rows: usize, k: usize) -> Result<Weights, KernelError> {
        debug_assert_eq!(w.len(), rows * k);
        let mut bytes = Vec::new();
        for r in 0..rows {
            bytes.extend(crate::pack::pack_naive(&w[r * k..(r + 1) * k], self.bits)?);
        }
        Ok(Weights::Naive { bytes, rows, k, bits: self.bits })
    }

    fn gemv_at(
        &self,
        w: &Weights,
        a: ActVec<'_>,
        out: &mut [i32],
        row0: usize,
    ) -> Result<(), KernelError> {
        let Weights::Naive { bytes, k, bits, .. } = w else {
            return Err(wrong_layout(self.name(), w));
        };
        check_rows(w, out, row0)?;
        let ActVec::I8(av) = a else {
            return Err(KernelError::Unsupported(format!("{}: packed activations", self.name())));
        };
        if av.len() < *k {
            return Err(KernelError::Shape(format!(
                "activation elems {} < depth {k}",
                av.len()
            )));
        }
        let bpr = k.div_ceil(bits.elems_per_byte());
        let rows = &bytes[row0 * bpr..(row0 + out.len()) * bpr];
        naive::gemv_naive_wsub_a8(rows, out.len(), *k, *bits, av, out);
        Ok(())
    }

    fn cost_method(&self) -> Option<Method> {
        Some(Method::Naive(self.variant()))
    }
}

/// The ULPPACK comparator: spacer-lane layout, local accumulation.
struct UlppackKernel {
    bits: BitWidth,
}

impl GemvKernel for UlppackKernel {
    fn name(&self) -> &'static str {
        match self.bits {
            BitWidth::B4 => "ulppack-w4a4",
            BitWidth::B2 => "ulppack-w2a2",
            BitWidth::B1 => "ulppack-w1a1",
            BitWidth::B8 => "ulppack-w8a8",
        }
    }

    fn supports(&self, v: Variant) -> bool {
        v == Variant::new(self.bits, self.bits)
    }

    fn prepare(&self, w: &[i8], rows: usize, k: usize) -> Result<Weights, KernelError> {
        Ok(Weights::Ulppack(UlppackMatrix::from_i8(w, rows, k, self.bits)?))
    }

    fn gemv_at(
        &self,
        w: &Weights,
        a: ActVec<'_>,
        out: &mut [i32],
        row0: usize,
    ) -> Result<(), KernelError> {
        let Weights::Ulppack(wm) = w else { return Err(wrong_layout(self.name(), w)) };
        check_rows(w, out, row0)?;
        let ActVec::I8(av) = a else {
            return Err(KernelError::Unsupported(format!("{}: packed activations", self.name())));
        };
        let k = wm.k();
        if av.len() < k {
            return Err(KernelError::Shape(format!(
                "activation elems {} < depth {k}",
                av.len()
            )));
        }
        // spacer-lane repack of the activations — part of the method's
        // own per-call protocol (k elements, amortized over z·k MACs)
        let (a_rev, a_sum) = ulppack::prepare_acts(&av[..k], wm.bits());
        ulppack::gemv_ulppack_at(wm, &a_rev, a_sum, k, out, row0);
        Ok(())
    }

    fn cost_method(&self) -> Option<Method> {
        Some(Method::Ulppack { bits: self.bits.bits() as u8 })
    }
}

/// Row-parallel decorator: shards output rows of *any* kernel across a
/// scoped thread pool (`kernels::parallel`), bit-identical to the serial
/// call.  Wrap any registry entry:
///
/// ```
/// use fullpack::kernels::{GemvKernel, KernelRegistry, RowParallel};
///
/// let reg = KernelRegistry::global();
/// let par = RowParallel::new(reg.get("fullpack-w4a8-swar").unwrap().clone(), 4);
/// assert_eq!(par.name(), "fullpack-w4a8-swar");
/// ```
pub struct RowParallel {
    inner: Arc<dyn GemvKernel>,
    /// shard budget handed to `parallel::shard_rows` per call
    pub threads: usize,
}

impl RowParallel {
    /// Wrap `inner` with a row-sharding budget of `threads`.
    pub fn new(inner: Arc<dyn GemvKernel>, threads: usize) -> RowParallel {
        RowParallel { inner, threads }
    }
}

impl GemvKernel for RowParallel {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn supports(&self, v: Variant) -> bool {
        self.inner.supports(v)
    }

    fn prepare(&self, w: &[i8], rows: usize, k: usize) -> Result<Weights, KernelError> {
        self.inner.prepare(w, rows, k)
    }

    fn gemv_at(
        &self,
        w: &Weights,
        a: ActVec<'_>,
        out: &mut [i32],
        row0: usize,
    ) -> Result<(), KernelError> {
        check_rows(w, out, row0)?;
        let inner = &*self.inner;
        parallel::shard_rows(out, row0, self.threads, |chunk, lo| {
            inner.gemv_at(w, a, chunk, lo)
        })
    }

    fn cost_method(&self) -> Option<Method> {
        self.inner.cost_method()
    }

    fn packs_activations(&self) -> bool {
        self.inner.packs_activations()
    }

    fn gemm(&self, w: &Weights, cols: &[&[i8]], out: &mut [i32]) -> Result<(), KernelError> {
        self.inner.gemm(w, cols, out)
    }
}

/// Tile-parallel decorator for the **GEMM tier**: shards a batched
/// forward by output row-tiles across a scoped thread pool
/// (`parallel::shard_gemm_rows`), calling the wrapped backend's
/// [`GemmKernel::gemm_at`] once per tile.  Bit-identical to the serial
/// call — every tile computes the same dot products over the same
/// shared operands, and the scatter after the join reassembles the
/// batch-major result.
///
/// ```
/// use fullpack::kernels::{GemmKernel, KernelRegistry, RowParallelGemm};
///
/// let reg = KernelRegistry::global();
/// let par = RowParallelGemm::new(reg.get_gemm("fullpack-w4a8-gemm").unwrap().clone(), 4);
/// assert_eq!(par.name(), "fullpack-w4a8-gemm");
/// ```
pub struct RowParallelGemm {
    inner: Arc<dyn GemmKernel>,
    /// shard budget handed to `parallel::shard_gemm_rows` per call
    pub threads: usize,
}

impl RowParallelGemm {
    /// Wrap `inner` with a row-tile sharding budget of `threads`.
    pub fn new(inner: Arc<dyn GemmKernel>, threads: usize) -> RowParallelGemm {
        RowParallelGemm { inner, threads }
    }
}

impl GemmKernel for RowParallelGemm {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn supports(&self, v: Variant) -> bool {
        self.inner.supports(v)
    }

    fn prepare(&self, w: &[i8], rows: usize, k: usize) -> Result<Weights, KernelError> {
        self.inner.prepare(w, rows, k)
    }

    fn gemm(&self, w: &Weights, cols: &[&[i8]], out: &mut [i32]) -> Result<(), KernelError> {
        check_gemm_shape(w, cols, out)?;
        let inner = &*self.inner;
        parallel::shard_gemm_rows(out, w.rows(), cols.len(), self.threads, |tile, lo, _hi| {
            inner.gemm_at(w, cols, tile, lo)
        })
    }

    fn gemm_at(
        &self,
        w: &Weights,
        cols: &[&[i8]],
        out: &mut [i32],
        row0: usize,
    ) -> Result<(), KernelError> {
        // tiles of tiles don't pay a second spawn: delegate directly
        self.inner.gemm_at(w, cols, out, row0)
    }

    fn cost_method(&self) -> Option<Method> {
        self.inner.cost_method()
    }
}

/// Registry name of the FullPack GEMM backend for a variant, if the
/// GEMM tier implements it (sub-byte weights × int8 activations — the
/// extract-once/MAC-many amortization needs unpacked columns).
pub fn fullpack_gemm_kernel_name(v: Variant) -> Option<&'static str> {
    match (v.w, v.a) {
        (BitWidth::B4, BitWidth::B8) => Some("fullpack-w4a8-gemm"),
        (BitWidth::B2, BitWidth::B8) => Some("fullpack-w2a8-gemm"),
        (BitWidth::B1, BitWidth::B8) => Some("fullpack-w1a8-gemm"),
        _ => None,
    }
}

/// The variants the FullPack GEMM tier implements, one registry entry
/// each (`fullpack-{w4,w2,w1}a8-gemm`).
pub const FULLPACK_GEMM_VARIANTS: [Variant; 3] = [
    Variant::new(BitWidth::B4, BitWidth::B8),
    Variant::new(BitWidth::B2, BitWidth::B8),
    Variant::new(BitWidth::B1, BitWidth::B8),
];

/// The FullPack batched-GEMM extension as a first-class backend: same
/// stride-16 packed layout as the GEMV tier, but each weight block is
/// extracted once and reused across every batch column
/// (`kernels::fullpack_gemm`).
struct FullPackGemmKernel {
    variant: Variant,
    name: &'static str,
}

impl FullPackGemmKernel {
    fn new(variant: Variant) -> Option<FullPackGemmKernel> {
        fullpack_gemm_kernel_name(variant).map(|name| FullPackGemmKernel { variant, name })
    }
}

impl GemmKernel for FullPackGemmKernel {
    fn name(&self) -> &'static str {
        self.name
    }

    fn supports(&self, v: Variant) -> bool {
        v == self.variant
    }

    fn prepare(&self, w: &[i8], rows: usize, k: usize) -> Result<Weights, KernelError> {
        // identical layout to the GEMV tier: batched and single-column
        // plans on the same variant can share prepared weights
        let kp = self.variant.padded_depth(k);
        let padded = pad_rows(w, rows, k, kp);
        Ok(Weights::Packed(PackedMatrix::from_i8(&padded, rows, kp, self.variant.w)?))
    }

    fn gemm(&self, w: &Weights, cols: &[&[i8]], out: &mut [i32]) -> Result<(), KernelError> {
        let Weights::Packed(wp) = w else { return Err(wrong_layout(self.name, w)) };
        if !wp.bits().is_sub_byte() {
            return Err(wrong_layout(self.name, w));
        }
        check_gemm_shape(w, cols, out)?;
        fullpack_gemm::gemm_fullpack_dyn(wp, cols, out)
    }

    fn gemm_at(
        &self,
        w: &Weights,
        cols: &[&[i8]],
        out: &mut [i32],
        row0: usize,
    ) -> Result<(), KernelError> {
        let Weights::Packed(wp) = w else { return Err(wrong_layout(self.name, w)) };
        if !wp.bits().is_sub_byte() {
            return Err(wrong_layout(self.name, w));
        }
        check_gemm_tile(w, cols, out, row0)?;
        fullpack_gemm::gemm_fullpack_dyn_at(wp, cols, out, row0)
    }

    fn cost_method(&self) -> Option<Method> {
        Some(Method::FullPackGemm(self.variant))
    }
}

/// The paper's GEMM protocol as a named backend: Ruy-like W8A8,
/// executed as back-to-back per-column GEMVs over a row-major int8
/// layout — the rival every FullPack GEMM entry is measured against.
struct RuyLikeGemmKernel;

impl GemmKernel for RuyLikeGemmKernel {
    fn name(&self) -> &'static str {
        "ruy-like-w8a8-gemm"
    }

    fn supports(&self, v: Variant) -> bool {
        !v.w.is_sub_byte() && !v.a.is_sub_byte()
    }

    fn prepare(&self, w: &[i8], rows: usize, k: usize) -> Result<Weights, KernelError> {
        Ok(Weights::Packed(PackedMatrix::from_i8(w, rows, k, BitWidth::B8)?))
    }

    fn gemm(&self, w: &Weights, cols: &[&[i8]], out: &mut [i32]) -> Result<(), KernelError> {
        let Weights::Packed(wp) = w else { return Err(wrong_layout(self.name(), w)) };
        if wp.bits().is_sub_byte() {
            return Err(wrong_layout(self.name(), w));
        }
        check_gemm_shape(w, cols, out)?;
        let z = wp.rows();
        for (c, col) in cols.iter().enumerate() {
            baseline::gemv_ruy_i8_at(wp, col, &mut out[c * z..(c + 1) * z], 0);
        }
        Ok(())
    }

    fn gemm_at(
        &self,
        w: &Weights,
        cols: &[&[i8]],
        out: &mut [i32],
        row0: usize,
    ) -> Result<(), KernelError> {
        let Weights::Packed(wp) = w else { return Err(wrong_layout(self.name(), w)) };
        if wp.bits().is_sub_byte() {
            return Err(wrong_layout(self.name(), w));
        }
        let rt = check_gemm_tile(w, cols, out, row0)?;
        for (c, col) in cols.iter().enumerate() {
            baseline::gemv_ruy_i8_at(wp, col, &mut out[c * rt..(c + 1) * rt], row0);
        }
        Ok(())
    }

    fn cost_method(&self) -> Option<Method> {
        // modeled as `batch` repeated Ruy GEMV calls, each re-streaming
        // the weight matrix with its column at a distinct address
        // (`costmodel::simulate_gemm` -> `sim::replay_gemm_restream`)
        Some(Method::RuyW8A8)
    }
}

/// The GEMM oracle: unpacked int8 rows, scalar triple loop.  Slow by
/// construction and excluded from cost-model selection — it exists so
/// the differential suite has a layout-independent ground truth.
struct NaiveGemmOracle;

impl GemmKernel for NaiveGemmOracle {
    fn name(&self) -> &'static str {
        "naive-oracle-gemm"
    }

    fn supports(&self, v: Variant) -> bool {
        // any weight width, int8 activation columns
        !v.a.is_sub_byte()
    }

    fn prepare(&self, w: &[i8], rows: usize, k: usize) -> Result<Weights, KernelError> {
        debug_assert_eq!(w.len(), rows * k);
        // unpacked adjacent bytes (1 B/elem regardless of quantized
        // width): the oracle trades footprint for layout transparency
        Ok(Weights::Naive {
            bytes: w.iter().map(|&v| v as u8).collect(),
            rows,
            k,
            bits: BitWidth::B8,
        })
    }

    fn gemm(&self, w: &Weights, cols: &[&[i8]], out: &mut [i32]) -> Result<(), KernelError> {
        let Weights::Naive { bytes, rows, k, .. } = w else {
            return Err(wrong_layout(self.name(), w));
        };
        let (rows, k) = (*rows, *k);
        check_gemm_shape(w, cols, out)?;
        for (c, col) in cols.iter().enumerate() {
            for r in 0..rows {
                out[c * rows + r] = bytes[r * k..(r + 1) * k]
                    .iter()
                    .zip(col.iter())
                    .map(|(&wv, &av)| (wv as i8) as i32 * av as i32)
                    .sum();
            }
        }
        Ok(())
    }

    fn gemm_at(
        &self,
        w: &Weights,
        cols: &[&[i8]],
        out: &mut [i32],
        row0: usize,
    ) -> Result<(), KernelError> {
        let Weights::Naive { bytes, k, .. } = w else {
            return Err(wrong_layout(self.name(), w));
        };
        let k = *k;
        let rt = check_gemm_tile(w, cols, out, row0)?;
        for (c, col) in cols.iter().enumerate() {
            for r in 0..rt {
                let row = row0 + r;
                out[c * rt + r] = bytes[row * k..(row + 1) * k]
                    .iter()
                    .zip(col.iter())
                    .map(|(&wv, &av)| (wv as i8) as i32 * av as i32)
                    .sum();
            }
        }
        Ok(())
    }
}

/// The kernel registry: name → backend, in two namespaces — GEMV
/// entries ([`GemvKernel`]) and batched GEMM entries ([`GemmKernel`],
/// names suffixed `-gemm`).  `global()` holds the built-in set; build a
/// local one with `with_builtins()` + `register()`/`register_gemm()` to
/// add custom backends.
pub struct KernelRegistry {
    entries: Vec<Arc<dyn GemvKernel>>,
    gemm_entries: Vec<Arc<dyn GemmKernel>>,
}

impl KernelRegistry {
    /// An empty registry (custom setups, tests).
    pub fn empty() -> KernelRegistry {
        KernelRegistry { entries: Vec::new(), gemm_entries: Vec::new() }
    }

    /// Every built-in backend: nine FullPack variants, the SWAR fast
    /// path (DESIGN.md §8), the LUT tier (DESIGN.md §13), the real-ISA
    /// tier for every vector ISA the host supports (DESIGN.md §15), the
    /// naive Alg. 1 strawman, ULPPACK, the W8A8 rivals and the FP32
    /// rivals — plus the GEMM tier (DESIGN.md §9):
    /// `fullpack-{w4,w2,w1}a8-gemm`, the `lut-*-gemm` wrappers, the
    /// Ruy-like W8A8 GEMM rival, and the naive oracle.
    pub fn with_builtins() -> KernelRegistry {
        let mut reg = KernelRegistry::empty();
        for v in Variant::PAPER_VARIANTS {
            reg.register(Arc::new(FullPackKernel { variant: v }));
        }
        for v in SWAR_VARIANTS {
            let kernel = SwarKernel::new(v).expect("SWAR_VARIANTS are implemented");
            reg.register(Arc::new(kernel));
        }
        for v in LUT_VARIANTS {
            let kernel = LutKernel::new(v).expect("LUT_VARIANTS are implemented");
            reg.register(Arc::new(kernel));
        }
        for flavor in [I8Flavor::Ruy, I8Flavor::Xnn, I8Flavor::Tflite, I8Flavor::Gemmlowp] {
            reg.register(Arc::new(I8Baseline { flavor }));
        }
        for flavor in [F32Flavor::Ruy, F32Flavor::Eigen, F32Flavor::Tflite] {
            reg.register(Arc::new(F32Baseline { flavor }));
        }
        for bits in [BitWidth::B4, BitWidth::B2, BitWidth::B1] {
            reg.register(Arc::new(NaiveKernel { bits }));
            reg.register(Arc::new(UlppackKernel { bits }));
        }
        // the real-ISA tier: registered only for ISAs the running host
        // can execute (restrictable via FULLPACK_ISA) — the roster never
        // contains an entry that would fault at dispatch
        super::isa::register_isa_backends(&mut reg, super::isa::detect::detected());
        for v in FULLPACK_GEMM_VARIANTS {
            let kernel = FullPackGemmKernel::new(v).expect("FULLPACK_GEMM_VARIANTS implemented");
            reg.register_gemm(Arc::new(kernel));
        }
        for v in LUT_VARIANTS {
            let kernel = LutGemmKernel::new(v).expect("LUT_VARIANTS are implemented");
            reg.register_gemm(Arc::new(kernel));
        }
        reg.register_gemm(Arc::new(RuyLikeGemmKernel));
        reg.register_gemm(Arc::new(NaiveGemmOracle));
        reg
    }

    /// The process-wide registry of built-ins.
    pub fn global() -> &'static KernelRegistry {
        static REG: OnceLock<KernelRegistry> = OnceLock::new();
        REG.get_or_init(KernelRegistry::with_builtins)
    }

    /// Add (or replace, by name) a backend.
    pub fn register(&mut self, kernel: Arc<dyn GemvKernel>) {
        if let Some(slot) = self.entries.iter_mut().find(|e| e.name() == kernel.name()) {
            *slot = kernel;
        } else {
            self.entries.push(kernel);
        }
    }

    /// Look a backend up by registry name.
    ///
    /// ```
    /// use fullpack::kernels::{GemvKernel, KernelRegistry};
    ///
    /// let reg = KernelRegistry::global();
    /// let kernel = reg.get("fullpack-w4a8").unwrap();
    /// assert_eq!(kernel.name(), "fullpack-w4a8");
    /// assert!(reg.get("fullpack-w4a8-swar").is_some());
    /// assert!(reg.get("no-such-backend").is_none());
    /// ```
    pub fn get(&self, name: &str) -> Option<&Arc<dyn GemvKernel>> {
        self.entries.iter().find(|e| e.name() == name)
    }

    /// Iterate every registered backend, in registration order.
    pub fn iter(&self) -> impl Iterator<Item = &Arc<dyn GemvKernel>> {
        self.entries.iter()
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name()).collect()
    }

    /// Backends that can natively execute variant `v`.
    pub fn supporting(&self, v: Variant) -> Vec<&Arc<dyn GemvKernel>> {
        self.entries.iter().filter(|e| e.supports(v)).collect()
    }

    /// Number of registered backends.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Is the registry empty (only possible for [`KernelRegistry::empty`])?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty() && self.gemm_entries.is_empty()
    }

    /// Add (or replace, by name) a batched-GEMM backend.
    pub fn register_gemm(&mut self, kernel: Arc<dyn GemmKernel>) {
        if let Some(slot) = self.gemm_entries.iter_mut().find(|e| e.name() == kernel.name()) {
            *slot = kernel;
        } else {
            self.gemm_entries.push(kernel);
        }
    }

    /// Look a GEMM backend up by registry name.
    ///
    /// ```
    /// use fullpack::kernels::KernelRegistry;
    ///
    /// let reg = KernelRegistry::global();
    /// assert!(reg.get_gemm("fullpack-w4a8-gemm").is_some());
    /// assert!(reg.get_gemm("ruy-like-w8a8-gemm").is_some());
    /// assert!(reg.get_gemm("fullpack-w4a8").is_none()); // GEMV namespace
    /// ```
    pub fn get_gemm(&self, name: &str) -> Option<&Arc<dyn GemmKernel>> {
        self.gemm_entries.iter().find(|e| e.name() == name)
    }

    /// Iterate every registered GEMM backend, in registration order.
    pub fn gemm_iter(&self) -> impl Iterator<Item = &Arc<dyn GemmKernel>> {
        self.gemm_entries.iter()
    }

    /// Registered GEMM backend names, in registration order.
    pub fn gemm_names(&self) -> Vec<&'static str> {
        self.gemm_entries.iter().map(|e| e.name()).collect()
    }

    /// GEMM backends that can natively execute variant `v`.
    pub fn gemm_supporting(&self, v: Variant) -> Vec<&Arc<dyn GemmKernel>> {
        self.gemm_entries.iter().filter(|e| e.supports(v)).collect()
    }

    /// Number of registered GEMM backends.
    pub fn gemm_len(&self) -> usize {
        self.gemm_entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testutil::{oracle_gemv, rngvals};

    #[test]
    fn builtin_roster_complete() {
        let reg = KernelRegistry::global();
        // 9 fullpack + 4 swar + 4 lut + 4 i8 + 3 f32 + 3 naive + 3 ulppack
        // + 4 ISA entries per detected vector ISA (host-dependent by
        // design: only executable backends are registered)
        let isa = crate::kernels::isa::detect::detected();
        assert_eq!(reg.len(), 30 + 4 * isa.count());
        for kind in crate::kernels::isa::ISA_KINDS {
            for v in crate::kernels::isa::ISA_VARIANTS {
                let name = crate::kernels::isa::isa_kernel_name(v, kind).unwrap();
                assert_eq!(
                    reg.get(name).is_some(),
                    isa.has(kind),
                    "{name} registration must track detection"
                );
            }
        }
        for name in [
            "fullpack-w4a8",
            "fullpack-w4a8-swar",
            "fullpack-w2a8-swar",
            "fullpack-w1a8-swar",
            "fullpack-w8a8-swar",
            "lut-w4a8",
            "lut-w2a8",
            "lut-w1a8",
            "lut-w4a4",
            "ruy-w8a8",
            "xnn-w8a8",
            "ulppack-w2a2",
            "naive-w4a8",
            "eigen-f32",
        ] {
            assert!(reg.get(name).is_some(), "{name} missing");
        }
        // names are unique
        let mut names = reg.names();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), reg.len());
        // the GEMM tier: 3 fullpack + 4 lut + ruy-like rival + naive oracle
        assert_eq!(reg.gemm_len(), 9);
        for name in [
            "fullpack-w4a8-gemm",
            "fullpack-w2a8-gemm",
            "fullpack-w1a8-gemm",
            "lut-w4a8-gemm",
            "lut-w2a8-gemm",
            "lut-w1a8-gemm",
            "lut-w4a4-gemm",
            "ruy-like-w8a8-gemm",
            "naive-oracle-gemm",
        ] {
            assert!(reg.get_gemm(name).is_some(), "{name} missing");
        }
        let mut gnames = reg.gemm_names();
        gnames.sort_unstable();
        gnames.dedup();
        assert_eq!(gnames.len(), reg.gemm_len());
        // the namespaces are disjoint
        for g in reg.gemm_names() {
            assert!(reg.get(g).is_none(), "{g} in both namespaces");
        }
    }

    #[test]
    fn gemm_tier_supports_and_replaces() {
        let reg = KernelRegistry::global();
        let w4a8 = Variant::parse("w4a8").unwrap();
        let names: Vec<_> = reg.gemm_supporting(w4a8).iter().map(|k| k.name()).collect();
        assert!(names.contains(&"fullpack-w4a8-gemm"));
        assert!(names.contains(&"naive-oracle-gemm"));
        assert!(!names.contains(&"ruy-like-w8a8-gemm"));
        let w8a8 = Variant::parse("w8a8").unwrap();
        let names8: Vec<_> = reg.gemm_supporting(w8a8).iter().map(|k| k.name()).collect();
        assert!(names8.contains(&"ruy-like-w8a8-gemm"));
        assert!(!names8.contains(&"fullpack-w4a8-gemm"));
        // register_gemm replaces by name
        let mut local = KernelRegistry::with_builtins();
        let n = local.gemm_len();
        local.register_gemm(Arc::new(RuyLikeGemmKernel));
        assert_eq!(local.gemm_len(), n);
    }

    #[test]
    fn fullpack_gemm_backend_matches_per_column_oracle() {
        let reg = KernelRegistry::global();
        for v in FULLPACK_GEMM_VARIANTS {
            let g = reg.get_gemm(fullpack_gemm_kernel_name(v).unwrap()).unwrap();
            let (z, k, batch) = (8usize, 50usize, 3usize);
            let w = rngvals(v.w, z * k, 91);
            let wts = g.prepare(&w, z, k).unwrap();
            let kp = wts.k_padded();
            assert!(kp >= k);
            let cols: Vec<Vec<i8>> = (0..batch)
                .map(|c| {
                    let mut col = rngvals(BitWidth::B8, k, 92 + c as u64);
                    col.resize(kp, 0);
                    col
                })
                .collect();
            let col_refs: Vec<&[i8]> = cols.iter().map(|c| c.as_slice()).collect();
            let mut out = vec![0i32; z * batch];
            g.gemm(&wts, &col_refs, &mut out).unwrap();
            let wp = crate::pack::pad_rows(&w, z, k, kp);
            for (c, col) in cols.iter().enumerate() {
                assert_eq!(
                    &out[c * z..(c + 1) * z],
                    oracle_gemv(&wp, col, z, kp).as_slice(),
                    "{v} col {c}"
                );
            }
            // shape rejection: wrong out length, short column
            let mut bad = vec![0i32; z * batch - 1];
            assert!(g.gemm(&wts, &col_refs, &mut bad).is_err());
            let short = vec![0i8; kp.saturating_sub(1)];
            let mut out1 = vec![0i32; z];
            assert!(g.gemm(&wts, &[short.as_slice()], &mut out1).is_err());
        }
    }

    #[test]
    fn lut_gemm_backends_match_per_column_oracle() {
        use crate::kernels::lut::lut_gemm_kernel_name;
        let reg = KernelRegistry::global();
        for v in LUT_VARIANTS {
            let g = reg.get_gemm(lut_gemm_kernel_name(v).unwrap()).unwrap();
            let (z, k, batch) = (8usize, 50usize, 5usize);
            let w = rngvals(v.w, z * k, 191);
            let wts = g.prepare(&w, z, k).unwrap();
            let kp = wts.k_padded();
            let cols: Vec<Vec<i8>> = (0..batch)
                .map(|c| {
                    let mut col = rngvals(v.a, k, 192 + c as u64);
                    col.resize(kp, 0);
                    col
                })
                .collect();
            let col_refs: Vec<&[i8]> = cols.iter().map(|c| c.as_slice()).collect();
            let mut out = vec![0i32; z * batch];
            g.gemm(&wts, &col_refs, &mut out).unwrap();
            let wp = crate::pack::pad_rows(&w, z, k, kp);
            for (c, col) in cols.iter().enumerate() {
                assert_eq!(
                    &out[c * z..(c + 1) * z],
                    oracle_gemv(&wp, col, z, kp).as_slice(),
                    "{v} col {c}"
                );
            }
            // shape rejection mirrors the FullPack GEMM tier
            let mut bad = vec![0i32; z * batch - 1];
            assert!(g.gemm(&wts, &col_refs, &mut bad).is_err());
            let short = vec![0i8; kp.saturating_sub(1)];
            let mut out1 = vec![0i32; z];
            assert!(g.gemm(&wts, &[short.as_slice()], &mut out1).is_err());
        }
    }

    #[test]
    fn supporting_partitions_variants() {
        let reg = KernelRegistry::global();
        let w4a8 = Variant::parse("w4a8").unwrap();
        let names: Vec<_> = reg.supporting(w4a8).iter().map(|k| k.name()).collect();
        assert!(names.contains(&"fullpack-w4a8"));
        assert!(names.contains(&"naive-w4a8"));
        assert!(!names.contains(&"ruy-w8a8"));
        let w8a8 = Variant::parse("w8a8").unwrap();
        let names8: Vec<_> = reg.supporting(w8a8).iter().map(|k| k.name()).collect();
        assert!(names8.contains(&"ruy-w8a8") && names8.contains(&"ruy-f32"));
    }

    #[test]
    fn register_replaces_by_name() {
        let mut reg = KernelRegistry::with_builtins();
        let n = reg.len();
        reg.register(Arc::new(I8Baseline { flavor: I8Flavor::Ruy }));
        assert_eq!(reg.len(), n);
    }

    #[test]
    fn row_parallel_decorator_is_bit_identical() {
        let reg = KernelRegistry::global();
        let base = reg.get("ruy-w8a8").unwrap();
        let (z, k) = (1024usize, 64usize);
        let w = rngvals(BitWidth::B8, z * k, 5);
        let a = rngvals(BitWidth::B8, k, 6);
        let wp = base.prepare(&w, z, k).unwrap();
        let mut serial = vec![0i32; z];
        base.gemv_at(&wp, ActVec::I8(&a), &mut serial, 0).unwrap();
        for threads in [2usize, 4] {
            let par = RowParallel::new(base.clone(), threads);
            let mut out = vec![0i32; z];
            par.gemv_at(&wp, ActVec::I8(&a), &mut out, 0).unwrap();
            assert_eq!(out, serial, "threads={threads}");
        }
        assert_eq!(serial, oracle_gemv(&w, &a, z, k));
    }

    #[test]
    fn gemm_row_tiles_match_the_full_call() {
        // every built-in GEMM backend implements the gemm_at contract:
        // an interior tile equals the matching rows of the full result,
        // batch-major over the tile
        let reg = KernelRegistry::global();
        let (z, k, batch) = (64usize, 50usize, 3usize);
        let (lo, hi) = (17usize, 41usize);
        let rt = hi - lo;
        for g in reg.gemm_iter() {
            let v = ["w4a8", "w2a8", "w1a8", "w4a4", "w8a8"]
                .iter()
                .map(|s| Variant::parse(s).unwrap())
                .find(|&v| g.supports(v))
                .unwrap_or_else(|| panic!("{}: no testable variant", g.name()));
            let w = rngvals(v.w, z * k, 131);
            let wts = g.prepare(&w, z, k).unwrap();
            let kp = wts.k_padded();
            let cols: Vec<Vec<i8>> = (0..batch)
                .map(|c| {
                    let mut col = rngvals(v.a, k, 132 + c as u64);
                    col.resize(kp, 0);
                    col
                })
                .collect();
            let refs: Vec<&[i8]> = cols.iter().map(|c| c.as_slice()).collect();
            let mut full = vec![0i32; z * batch];
            g.gemm(&wts, &refs, &mut full).unwrap();
            let mut tile = vec![0i32; rt * batch];
            g.gemm_at(&wts, &refs, &mut tile, lo).unwrap();
            for c in 0..batch {
                assert_eq!(
                    &tile[c * rt..(c + 1) * rt],
                    &full[c * z + lo..c * z + hi],
                    "{} col {c}",
                    g.name()
                );
            }
            // out-of-range tiles are shape errors
            let mut bad = vec![0i32; 10 * batch];
            assert!(g.gemm_at(&wts, &refs, &mut bad, z - 5).is_err(), "{}", g.name());
        }
    }

    #[test]
    fn tile_parallel_gemm_is_bit_identical() {
        let reg = KernelRegistry::global();
        // enough rows that shard_gemm_rows actually spawns (>= 2 shards
        // past MIN_ROWS_PER_SHARD), on both a sub-byte FullPack entry
        // and the w8a8 rival
        let (z, k, batch) = (1024usize, 64usize, 3usize);
        for (name, v) in
            [("fullpack-w4a8-gemm", "w4a8"), ("ruy-like-w8a8-gemm", "w8a8")]
        {
            let base = reg.get_gemm(name).unwrap();
            let v = Variant::parse(v).unwrap();
            let w = rngvals(v.w, z * k, 141);
            let wts = base.prepare(&w, z, k).unwrap();
            let kp = wts.k_padded();
            let cols: Vec<Vec<i8>> = (0..batch)
                .map(|c| {
                    let mut col = rngvals(v.a, k, 142 + c as u64);
                    col.resize(kp, 0);
                    col
                })
                .collect();
            let refs: Vec<&[i8]> = cols.iter().map(|c| c.as_slice()).collect();
            let mut serial = vec![0i32; z * batch];
            base.gemm(&wts, &refs, &mut serial).unwrap();
            for threads in [2usize, 4] {
                let par = RowParallelGemm::new(base.clone(), threads);
                assert_eq!(par.name(), name);
                let mut out = vec![0i32; z * batch];
                par.gemm(&wts, &refs, &mut out).unwrap();
                assert_eq!(out, serial, "{name} threads={threads}");
            }
        }
    }

    #[test]
    fn cost_methods_share_registry_namespace() {
        for kernel in KernelRegistry::global().iter() {
            let m = kernel.cost_method().expect("every builtin is modeled");
            assert_eq!(m.registry_name(), kernel.name(), "namespace drift");
        }
    }
}

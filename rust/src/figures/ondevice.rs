//! Fig. 11 — the on-device study: FullPack vs rivals on the FC layers
//! of eleven well-known CNNs, *measured* on the host with the native
//! Rust kernels (the Raspberry Pi 4 substitution, DESIGN.md §2).
//!
//! Methods are named by their `kernels::KernelRegistry` entry — the
//! same namespace the cost model uses — and every measurement runs
//! through a `Plan`, so no kernel function is named here.

use crate::kernels::testutil::rngvals;
use crate::kernels::{GemvKernel, KernelRegistry, LayerShape, PlanBuilder, SelectPolicy};
use crate::models::{FcShape, CNN_FC_ZOO};
use crate::util::bench::{bench, Measurement, Table};

/// Measured nanoseconds of one inference of `method` (a registry kernel
/// name) on one FC shape.  Methods whose protocol is a batched call per
/// inference (ULPPACK's batch-8 GEMM, §4.1) loop accordingly.
///
/// Each timed call includes that method's own per-call activation
/// handling (FullPack packs into reused scratch; ULPPACK repacks spacer
/// lanes; the f32 stand-ins widen the int8 activations into reused
/// thread-local buffers) — O(k) work against the O(z·k) kernel, and no
/// steady-state allocation except ULPPACK's per-inference repack.
/// Weights are always prepared once, outside the timed region.
pub fn measure_method(fc: &FcShape, method: &str, warmup: usize, ms: u64) -> Measurement {
    let kernel = KernelRegistry::global()
        .get(method)
        .unwrap_or_else(|| panic!("unknown registry kernel {method:?}"));
    let cost = kernel.cost_method();
    // the registry namespace tells us the data variant and the
    // calls-per-inference protocol
    let variant = cost.map(|m| m.data_variant()).unwrap_or_else(|| {
        crate::pack::Variant::new(crate::pack::BitWidth::B8, crate::pack::BitWidth::B8)
    });
    let calls = cost.map_or(1, |m| m.batch());
    let (z, k) = (fc.z, fc.k);
    let plan = PlanBuilder::new(LayerShape { z, k, batch: 1 }, variant)
        .policy(SelectPolicy::Explicit(method.to_string()))
        .build()
        .expect("plan for registry kernel");
    let w = rngvals(variant.w, z * k, 1);
    let a = rngvals(variant.a, k, 2);
    let weights = plan.prepare_weights(&w).expect("prepare weights");
    let mut out = vec![0i32; z];
    bench(
        || {
            for _ in 0..calls {
                plan.execute(&weights, &a, &mut out).unwrap();
            }
        },
        warmup,
        ms,
        100_000,
    )
}

/// Methods measured in the Fig. 11 lineup (registry names).
pub const FIG11_METHODS: [&str; 10] = [
    "ruy-w8a8",
    "fullpack-w4a4",
    "fullpack-w2a2",
    "fullpack-w1a1",
    "fullpack-w4a8",
    "xnn-w8a8",
    "tflite-w8a8",
    "ruy-f32",
    "ulppack-w2a2",
    "ulppack-w1a1",
];

/// Fig. 11: speedup of each method vs Ruy-W8A8 on each CNN's FC layer.
/// Returns the table plus per-method geomean speedups.
pub fn fig11(warmup: usize, ms: u64) -> (Table, Vec<(String, f64)>) {
    let mut headers = vec!["network (z x k)".to_string()];
    headers.extend(FIG11_METHODS.iter().skip(1).map(|m| m.to_string()));
    let mut t = Table::new(headers);
    let mut logs = vec![0.0f64; FIG11_METHODS.len() - 1];
    for fc in &CNN_FC_ZOO {
        let base = measure_method(fc, FIG11_METHODS[0], warmup, ms).median_ns;
        let mut row = vec![format!("{} ({}x{})", fc.name, fc.z, fc.k)];
        for (i, m) in FIG11_METHODS.iter().skip(1).enumerate() {
            let v = measure_method(fc, m, warmup, ms).median_ns;
            let speedup = base / v;
            logs[i] += speedup.ln();
            row.push(format!("{speedup:.2}"));
        }
        t.row(row);
    }
    let geo: Vec<(String, f64)> = FIG11_METHODS
        .iter()
        .skip(1)
        .zip(&logs)
        .map(|(m, l)| (m.to_string(), (l / CNN_FC_ZOO.len() as f64).exp()))
        .collect();
    (t, geo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::Method;

    #[test]
    fn measure_each_method_once() {
        let fc = FcShape { name: "tiny", k: 256, z: 64 };
        for m in FIG11_METHODS {
            let r = measure_method(&fc, m, 1, 1);
            assert!(r.median_ns > 0.0, "{m}");
        }
    }

    #[test]
    fn every_registry_kernel_is_measurable() {
        // the measured and modeled namespaces stay closed over the
        // registry: any registered name can be handed to measure_method
        let fc = FcShape { name: "tiny", k: 128, z: 16 };
        for name in KernelRegistry::global().names() {
            let r = measure_method(&fc, name, 0, 1);
            assert!(r.median_ns > 0.0, "{name}");
        }
    }

    #[test]
    fn ulppack_protocol_batches_per_inference() {
        assert_eq!(Method::from_registry("ulppack-w2a2").unwrap().batch(), 8);
        assert_eq!(Method::from_registry("ruy-w8a8").unwrap().batch(), 1);
    }

    #[test]
    fn fullpack_w4a8_not_catastrophically_slow() {
        // measured sanity: within 4x of the i8 baseline even on a small,
        // cache-resident shape (the compute-bound regime)
        let fc = FcShape { name: "t", k: 1024, z: 256 };
        let base = measure_method(&fc, "ruy-w8a8", 2, 10).median_ns;
        let fp = measure_method(&fc, "fullpack-w4a8", 2, 10).median_ns;
        assert!(fp < base * 4.0, "w4a8 {fp}ns vs ruy {base}ns");
    }
}

//! Fig. 11 — the on-device study: FullPack vs rivals on the FC layers
//! of eleven well-known CNNs, *measured* on the host with the native
//! Rust kernels (the Raspberry Pi 4 substitution, DESIGN.md §2).

use crate::kernels::{self, baseline, ActVec};
use crate::models::{FcShape, CNN_FC_ZOO};
use crate::pack::{pack, BitWidth, PackedMatrix, Variant};
use crate::util::bench::{bench, Measurement, Table};

fn vals(bits: BitWidth, n: usize, seed: u64) -> Vec<i8> {
    let (lo, hi) = bits.value_range();
    let span = (hi as i16 - lo as i16 + 1) as u64;
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    (0..n)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (lo as i16 + (s % span) as i16) as i8
        })
        .collect()
}

/// Measured nanoseconds of one method on one FC shape.
pub fn measure_method(fc: &FcShape, method: &str, warmup: usize, ms: u64) -> Measurement {
    let z = fc.z;
    let k = fc.k;
    match method {
        "ruy-w8a8" | "xnn-w8a8" | "tflite-w8a8" | "gemmlowp-w8a8" => {
            let w = vals(BitWidth::B8, z * k, 1);
            let a = vals(BitWidth::B8, k, 2);
            let wp = PackedMatrix::from_i8(&w, z, k, BitWidth::B8).unwrap();
            let mut out = vec![0i32; z];
            let mut scratch = Vec::new();
            bench(
                || match method {
                    "ruy-w8a8" => baseline::gemv_ruy_i8(&wp, &a, &mut out),
                    "xnn-w8a8" => baseline::gemv_xnn_i8(&wp, &a, &mut out),
                    "tflite-w8a8" => baseline::gemv_tflite_i8(&wp, &a, &mut out),
                    _ => baseline::gemv_gemmlowp_i8(&wp, &a, &mut out, &mut scratch),
                },
                warmup,
                ms,
                100_000,
            )
        }
        "ruy-f32" | "eigen-f32" | "tflite-f32" => {
            let w: Vec<f32> = vals(BitWidth::B8, z * k, 3).iter().map(|&v| v as f32).collect();
            let a: Vec<f32> = vals(BitWidth::B8, k, 4).iter().map(|&v| v as f32).collect();
            let mut out = vec![0f32; z];
            bench(
                || match method {
                    "ruy-f32" => baseline::gemv_ruy_f32(&w, z, k, &a, &mut out),
                    "eigen-f32" => baseline::gemv_eigen_f32(&w, z, k, &a, &mut out),
                    _ => baseline::gemv_tflite_f32(&w, z, k, &a, &mut out),
                },
                warmup,
                ms,
                100_000,
            )
        }
        "ulppack-w2a2" | "ulppack-w1a1" => {
            let bits = if method.ends_with("2a2") { BitWidth::B2 } else { BitWidth::B1 };
            let w = vals(bits, z * k, 5);
            let a = vals(bits, k, 6);
            let wm = crate::pack::UlppackMatrix::from_i8(&w, z, k, bits).unwrap();
            let (a_rev, a_sum) = kernels::ulppack::prepare_acts(&a, bits);
            let mut out = vec![0i32; z];
            bench(
                || {
                    // ULPPACK— protocol: batch-8 GEMM per inference (§4.1)
                    for _ in 0..8 {
                        kernels::ulppack::gemv_ulppack(&wm, &a_rev, a_sum, k, &mut out);
                    }
                },
                warmup,
                ms,
                100_000,
            )
        }
        fullpack => {
            let variant = Variant::parse(fullpack).expect("variant name like w4a8");
            let kp = variant.padded_depth(k);
            let mut w = vals(variant.w, z * k, 7);
            let mut padded = vec![0i8; z * kp];
            for r in 0..z {
                padded[r * kp..r * kp + k].copy_from_slice(&w[r * k..(r + 1) * k]);
            }
            w = padded;
            let mut a = vals(variant.a, k, 8);
            a.resize(kp, 0);
            let wp = PackedMatrix::from_i8(&w, z, kp, variant.w).unwrap();
            let ap = variant.a.is_sub_byte().then(|| pack(&a, variant.a).unwrap());
            let mut out = vec![0i32; z];
            bench(
                || {
                    let act = match &ap {
                        Some(bytes) => ActVec::Packed { bytes, bits: variant.a },
                        None => ActVec::I8(&a),
                    };
                    kernels::gemv(&wp, act, &mut out).unwrap();
                },
                warmup,
                ms,
                100_000,
            )
        }
    }
}

/// Methods measured in the Fig. 11 lineup.
pub const FIG11_METHODS: [&str; 10] = [
    "ruy-w8a8",
    "w4a4",
    "w2a2",
    "w1a1",
    "w4a8",
    "xnn-w8a8",
    "tflite-w8a8",
    "ruy-f32",
    "ulppack-w2a2",
    "ulppack-w1a1",
];

/// Fig. 11: speedup of each method vs Ruy-W8A8 on each CNN's FC layer.
/// Returns the table plus per-method geomean speedups.
pub fn fig11(warmup: usize, ms: u64) -> (Table, Vec<(String, f64)>) {
    let mut headers = vec!["network (z x k)".to_string()];
    headers.extend(FIG11_METHODS.iter().skip(1).map(|m| m.to_string()));
    let mut t = Table::new(headers);
    let mut logs = vec![0.0f64; FIG11_METHODS.len() - 1];
    for fc in &CNN_FC_ZOO {
        let base = measure_method(fc, FIG11_METHODS[0], warmup, ms).median_ns;
        let mut row = vec![format!("{} ({}x{})", fc.name, fc.z, fc.k)];
        for (i, m) in FIG11_METHODS.iter().skip(1).enumerate() {
            let v = measure_method(fc, m, warmup, ms).median_ns;
            let speedup = base / v;
            logs[i] += speedup.ln();
            row.push(format!("{speedup:.2}"));
        }
        t.row(row);
    }
    let geo: Vec<(String, f64)> = FIG11_METHODS
        .iter()
        .skip(1)
        .zip(&logs)
        .map(|(m, l)| (m.to_string(), (l / CNN_FC_ZOO.len() as f64).exp()))
        .collect();
    (t, geo)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_each_method_once() {
        let fc = FcShape { name: "tiny", k: 256, z: 64 };
        for m in FIG11_METHODS {
            let r = measure_method(&fc, m, 1, 1);
            assert!(r.median_ns > 0.0, "{m}");
        }
    }

    #[test]
    fn fullpack_w4a8_not_catastrophically_slow() {
        // measured sanity: within 4x of the i8 baseline even on a small,
        // cache-resident shape (the compute-bound regime)
        let fc = FcShape { name: "t", k: 1024, z: 256 };
        let base = measure_method(&fc, "ruy-w8a8", 2, 10).median_ns;
        let fp = measure_method(&fc, "w4a8", 2, 10).median_ns;
        assert!(fp < base * 4.0, "w4a8 {fp}ns vs ruy {base}ns");
    }
}

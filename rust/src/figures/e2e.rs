//! End-to-end DeepSpeech figures: Fig. 1 (per-layer breakdown
//! motivating the GEMV focus) and Fig. 10 (per-layer breakdown for all
//! methods) — both in simulated (gem5-stand-in) form, plus a measured
//! native-kernel run used by `examples/deepspeech_e2e`, and the
//! model-zoo generalization of the §4.6 end-to-end comparison
//! ([`fig_e2e_zoo`], built on `costmodel::simulate_model`).

use crate::costmodel::{simulate_model, simulate_model_total, CoreModel, Method};
use crate::models::{DeepSpeechConfig, ModelGraph, ModelRegistry, ModelSize};
use crate::pack::Variant;
use crate::sim::{replay_gemv_at, CachePreset, GemvTraffic};
use crate::util::bench::Table;

/// Layer names in execution order (Fig. 9 topology).
pub const LAYERS: [&str; 6] = ["fc1", "fc2", "fc3", "lstm", "fc5", "fc6"];

/// Simulated per-layer cycles of one full DeepSpeech inference.
///
/// `lstm_method` runs the 16 single-batch LSTM-step GEMVs (2 gate
/// matrices per step); `fc_method` runs the batch-16 FC GEMMs — the
/// paper's §4.6 split (FullPack rows use Ruy-W8A8 for FC).
pub fn simulate_deepspeech(
    lstm_method: Method,
    fc_method: Method,
    cfg: DeepSpeechConfig,
    preset: CachePreset,
    core: &CoreModel,
    steady_calls: usize,
) -> Vec<(&'static str, f64)> {
    let h = cfg.n_hidden;
    let fc_shapes = [
        ("fc1", h, cfg.n_input),
        ("fc2", h, h),
        ("fc3", h, h),
        ("fc5", h, h),
        ("fc6", cfg.n_output, h),
    ];
    let mut hier = preset.build();
    let mut out = Vec::new();

    // distinct address regions per layer weight matrix
    let mut wbase = 0x1000_0000u64;
    let abase = 0x9000_0000u64;
    let obase = 0xA000_0000u64;

    let mut layer_traffic: Vec<(&'static str, Vec<(GemvTraffic, u64)>, Method, usize)> = Vec::new();
    for (name, z, k) in fc_shapes {
        let t = GemvTraffic {
            z,
            w_bytes_per_row: fc_method.weight_bytes_per_row(k),
            a_bytes: fc_method.act_bytes(k),
            batch: cfg.time_steps, // batch-16 GEMM
            out_elem_bytes: 4,
        };
        let base = wbase;
        wbase += (t.weight_bytes() as u64).next_multiple_of(1 << 20);
        layer_traffic.push((name, vec![(t, base)], fc_method, 1));
    }
    // LSTM: per step two GEMVs (wx, wh) of (4H x H); weights shared
    // across the 16 steps — residency is the whole point (Fig. 1).
    let gate_t = GemvTraffic {
        z: cfg.gate_dim(),
        w_bytes_per_row: lstm_method.weight_bytes_per_row(h),
        a_bytes: lstm_method.act_bytes(h),
        batch: lstm_method.batch(),
        out_elem_bytes: 4,
    };
    let wx_base = wbase;
    let wh_base = wbase + (gate_t.weight_bytes() as u64).next_multiple_of(1 << 20);
    layer_traffic.insert(
        3,
        ("lstm", vec![(gate_t, wx_base), (gate_t, wh_base)], lstm_method, cfg.time_steps),
    );

    // steady-state warmup of the whole model
    for _ in 1..steady_calls.max(1) {
        for (_, parts, _, steps) in &layer_traffic {
            for _ in 0..*steps {
                for (t, base) in parts {
                    replay_gemv_at(&mut hier, t, *base, abase, obase);
                }
            }
        }
    }

    for (name, parts, method, steps) in &layer_traffic {
        hier.reset_stats();
        for _ in 0..*steps {
            for (t, base) in parts {
                replay_gemv_at(&mut hier, t, *base, abase, obase);
            }
        }
        // cycles = memory stalls (from the layer's replay) + compute
        // (instruction mix of every GEMV the layer issued)
        let stalls = core.stall_cycles(&hier);
        let compute = compute_for(core, *method, parts, *steps);
        out.push((*name, stalls + compute));
    }
    out
}

fn logical_depth(method: Method, t: &GemvTraffic) -> usize {
    // invert weight_bytes_per_row: find k with method.weight_bytes_per_row(k) == t.w_bytes_per_row
    // (all our models are linear in k, so scale directly)
    let probe = method.weight_bytes_per_row(1024);
    (t.w_bytes_per_row * 1024) / probe.max(1)
}

fn compute_for(
    core: &CoreModel,
    method: Method,
    parts: &[(GemvTraffic, u64)],
    steps: usize,
) -> f64 {
    let mut cycles = 0.0;
    for (t, _) in parts {
        let k = logical_depth(method, t);
        let mut mix = method.instr_mix(t.z, k);
        if t.batch > 1 && !matches!(method, Method::Ulppack { .. }) {
            mix = mix.scale(t.batch as f64);
        }
        cycles += core.compute_cycles(&mix) * steps as f64;
    }
    cycles
}

/// Fig. 10 (and Fig. 1, which is the same data for a method subset):
/// per-layer execution breakdown for every method.
pub fn fig10(cfg: DeepSpeechConfig) -> (Table, Vec<(String, f64)>) {
    let core = CoreModel::ex5_big();
    let preset = CachePreset::Gem5Ex5Big;
    let rows: Vec<(String, Method, Method)> = vec![
        ("Ruy-W8A8".into(), Method::RuyW8A8, Method::RuyW8A8),
        ("XNNPack-W8A8".into(), Method::XnnW8A8, Method::XnnW8A8),
        ("TFLite-W8A8".into(), Method::TfliteW8A8, Method::TfliteW8A8),
        ("GEMMLOWP-W8A8".into(), Method::GemmlowpW8A8, Method::GemmlowpW8A8),
        ("Ruy-FP32".into(), Method::RuyF32, Method::RuyF32),
        ("XNNPack-FP32".into(), Method::XnnF32, Method::XnnF32),
        ("TFLite-FP32".into(), Method::TfliteF32, Method::TfliteF32),
        ("Eigen-FP32".into(), Method::EigenF32, Method::EigenF32),
        ("ULPPACK-W2A2".into(), Method::Ulppack { bits: 2 }, Method::RuyW8A8),
        // FullPack rows: LSTM on FullPack, FC on Ruy (paper §4.6)
        ("FullPack-W4A4".into(), Method::fullpack("w4a4"), Method::RuyW8A8),
        ("FullPack-W2A2".into(), Method::fullpack("w2a2"), Method::RuyW8A8),
        ("FullPack-W1A1".into(), Method::fullpack("w1a1"), Method::RuyW8A8),
    ];
    let mut headers = vec!["method".to_string()];
    headers.extend(LAYERS.iter().map(|l| format!("{l} Mcyc")));
    headers.push("total Mcyc".into());
    let mut table = Table::new(headers);
    let mut totals = Vec::new();
    for (label, lstm_m, fc_m) in rows {
        let layers = simulate_deepspeech(lstm_m, fc_m, cfg, preset, &core, 2);
        let total: f64 = layers.iter().map(|(_, c)| c).sum();
        let mut row = vec![label.clone()];
        row.extend(layers.iter().map(|(_, c)| format!("{:.2}", c / 1e6)));
        row.push(format!("{:.2}", total / 1e6));
        table.row(row);
        totals.push((label, total));
    }
    (table, totals)
}

/// The FullPack method pair for a graph (now shared with the serving
/// scheduler's admission brain — the definition lives in `costmodel`).
pub use crate::costmodel::fullpack_methods_for;

/// Whole-model method comparison across the model zoo — the §4.6
/// end-to-end table generalized beyond DeepSpeech (DESIGN.md §10):
/// for every registered graph, the modeled all-Ruy baseline total vs
/// the FullPack split total (`costmodel::simulate_model`).  Returns the
/// printable table plus `(model, baseline Mcyc, fullpack Mcyc)` rows.
pub fn fig_e2e_zoo(size: ModelSize, variant: Variant) -> (Table, Vec<(String, f64, f64)>) {
    let core = CoreModel::ex5_big();
    let preset = CachePreset::Gem5Ex5Big;
    let mut table = Table::new(vec![
        "model".to_string(),
        "topology".to_string(),
        "ruy-w8a8 Mcyc".to_string(),
        "fullpack Mcyc".to_string(),
        "speedup".to_string(),
    ]);
    let mut rows = Vec::new();
    for entry in ModelRegistry::global().iter() {
        let graph = (entry.build)(size, variant, 7);
        let base =
            simulate_model_total(&graph, Method::RuyW8A8, Method::RuyW8A8, preset, &core, 2);
        let (cell_m, fc_m) = fullpack_methods_for(&graph);
        let fp = simulate_model_total(&graph, cell_m, fc_m, preset, &core, 2);
        table.row(vec![
            entry.name.to_string(),
            entry.blurb.to_string(),
            format!("{:.2}", base / 1e6),
            format!("{:.2}", fp / 1e6),
            format!("{:.2}x", base / fp),
        ]);
        rows.push((entry.name.to_string(), base, fp));
    }
    (table, rows)
}

/// Per-layer modeled breakdown of one zoo model under both method
/// assignments — the CLI's `simulate model --name X` view.
pub fn model_breakdown(
    graph: &ModelGraph,
) -> (Table, f64, f64) {
    let core = CoreModel::ex5_big();
    let preset = CachePreset::Gem5Ex5Big;
    let base = simulate_model(graph, Method::RuyW8A8, Method::RuyW8A8, preset, &core, 2);
    let (cell_m, fc_m) = fullpack_methods_for(graph);
    let fp = simulate_model(graph, cell_m, fc_m, preset, &core, 2);
    let mut table = Table::new(vec!["layer", "ruy-w8a8 Mcyc", "fullpack Mcyc", "speedup"]);
    for ((name, b), (_, f)) in base.iter().zip(&fp) {
        let s = if *f > 0.0 { format!("{:.2}x", b / f) } else { "-".to_string() };
        table.row(vec![
            name.clone(),
            format!("{:.2}", b / 1e6),
            format!("{:.2}", f / 1e6),
            s,
        ]);
    }
    let bt: f64 = base.iter().map(|(_, c)| c).sum();
    let ft: f64 = fp.iter().map(|(_, c)| c).sum();
    (table, bt, ft)
}

/// Fig. 1 headline: LSTM share of total time for a given method pair.
pub fn lstm_share(lstm_m: Method, fc_m: Method, cfg: DeepSpeechConfig) -> f64 {
    let core = CoreModel::ex5_big();
    let layers = simulate_deepspeech(lstm_m, fc_m, cfg, CachePreset::Gem5Ex5Big, &core, 2);
    let total: f64 = layers.iter().map(|(_, c)| c).sum();
    let lstm: f64 = layers.iter().filter(|(n, _)| *n == "lstm").map(|(_, c)| c).sum();
    lstm / total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lstm_dominates_baseline_runtime() {
        // paper Fig. 1: the LSTM layer is >70% of DeepSpeech inference
        let share = lstm_share(Method::RuyW8A8, Method::RuyW8A8, DeepSpeechConfig::FULL);
        assert!(share > 0.55, "lstm share {share}");
    }

    #[test]
    fn fullpack_end_to_end_speedup() {
        // paper §4.6: 1.56-2.11x end-to-end vs Ruy-W8A8
        let (_, totals) = fig10(DeepSpeechConfig::FULL);
        let get = |name: &str| {
            totals.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap()
        };
        let base = get("Ruy-W8A8");
        for v in ["FullPack-W4A4", "FullPack-W2A2", "FullPack-W1A1"] {
            let s = base / get(v);
            assert!(s > 1.2, "{v} e2e speedup {s}");
        }
        // FullPack beats every rival end to end (paper: "outperforms all")
        let best_fullpack = ["FullPack-W4A4", "FullPack-W2A2", "FullPack-W1A1"]
            .iter()
            .map(|v| get(v))
            .fold(f64::INFINITY, f64::min);
        for (name, total) in &totals {
            if !name.starts_with("FullPack") {
                assert!(*total > best_fullpack * 0.99, "{name} unexpectedly faster");
            }
        }
    }

    #[test]
    fn zoo_e2e_fullpack_wins_on_every_model() {
        // the §4.6 comparison generalized: every zoo graph models a
        // FullPack end-to-end win over the all-Ruy baseline
        let (table, rows) = fig_e2e_zoo(ModelSize::Full, Variant::parse("w4a8").unwrap());
        assert_eq!(rows.len(), ModelRegistry::global().len());
        for (name, base, fp) in &rows {
            assert!(base / fp > 1.0, "{name}: e2e speedup {}", base / fp);
        }
        let rendered = table.render();
        assert!(rendered.contains("keyword-spotter"));
        assert!(rendered.contains("mlp"));
    }

    #[test]
    fn model_breakdown_sums_match_totals() {
        let g = crate::models::deepspeech_graph(
            DeepSpeechConfig::FULL,
            Variant::parse("w4a8").unwrap(),
            7,
        );
        let (table, base, fp) = model_breakdown(&g);
        assert!(base > fp, "fullpack split must win on deepspeech");
        assert!(table.render().contains("lstm"));
        let total = simulate_model_total(
            &g,
            Method::RuyW8A8,
            Method::RuyW8A8,
            CachePreset::Gem5Ex5Big,
            &CoreModel::ex5_big(),
            2,
        );
        assert!((total - base).abs() < 1e-6 * base.max(1.0));
    }

    #[test]
    fn fp32_dwarfed_by_quantized() {
        let (_, totals) = fig10(DeepSpeechConfig::FULL);
        let get = |name: &str| {
            totals.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap()
        };
        assert!(get("TFLite-FP32") > get("Ruy-W8A8") * 2.0);
    }
}

//! Serve-sweep figures (`fig-serve` family): tail latency and dispatch
//! mix across a set of workload-mix reports — the serving analogue of
//! the kernel sweep tables.  Rows come from `workload::report`
//! ([`MixReport`]), one per mix, in sweep order, and carry the
//! admission scheduler's policy signals (typed sheds, cost-model
//! budget flushes, queue occupancy, EDF inversions).

use crate::util::bench::Table;
use crate::workload::report::MixReport;

/// Latency/throughput table: one row per mix with exact nearest-rank
/// tail percentiles and the typed shed split (the backpressure and
/// admission-control signals).
pub fn fig_serve_latency(reports: &[MixReport]) -> Table {
    let mut table = Table::new(vec![
        "mix".to_string(),
        "mode".to_string(),
        "arrival".to_string(),
        "clients".to_string(),
        "issued".to_string(),
        "shed full/budget/cold".to_string(),
        "p50 us".to_string(),
        "p95 us".to_string(),
        "p99 us".to_string(),
        "max us".to_string(),
        "mean us".to_string(),
        "rps".to_string(),
    ]);
    for r in reports {
        table.row(vec![
            r.mix.clone(),
            r.mode.clone(),
            r.arrival.clone(),
            r.clients.to_string(),
            r.issued.to_string(),
            format!("{}/{}/{}", r.shed_queue_full, r.shed_over_budget, r.shed_cold_model),
            r.p50_us.to_string(),
            r.p95_us.to_string(),
            r.p99_us.to_string(),
            r.max_us.to_string(),
            format!("{:.1}", r.mean_us),
            format!("{:.1}", r.throughput_rps),
        ]);
    }
    table
}

/// Dispatch-mix table: how each mix's traffic split across batched vs
/// singleton dispatches, what sealed the batches (including the cost
/// model's marginal-latency `budget` seals), and the sharded worker
/// pool's EDF behavior — the scheduling policy's side of the
/// tail-latency story.
pub fn fig_serve_dispatch(reports: &[MixReport]) -> Table {
    let mut table = Table::new(vec![
        "mix".to_string(),
        "completed".to_string(),
        "errors".to_string(),
        "batched".to_string(),
        "singleton".to_string(),
        "dispatches".to_string(),
        "flush full".to_string(),
        "flush budget".to_string(),
        "flush deadline".to_string(),
        "flush drained".to_string(),
        "qdepth max".to_string(),
        "edf inv".to_string(),
        "stolen".to_string(),
        "store l/e/s".to_string(),
        "models".to_string(),
    ]);
    for r in reports {
        let models: Vec<String> = r
            .per_model
            .iter()
            .map(|m| format!("{}:{}b/{}s", m.name, m.batched_requests, m.singleton_requests))
            .collect();
        table.row(vec![
            r.mix.clone(),
            r.completed.to_string(),
            r.errors.to_string(),
            r.batched_requests.to_string(),
            r.singleton_requests.to_string(),
            r.batched_dispatches.to_string(),
            r.flushes.0.to_string(),
            r.flushes.1.to_string(),
            r.flushes.2.to_string(),
            r.flushes.3.to_string(),
            r.max_queue_depth.to_string(),
            r.edf_inversions.to_string(),
            r.stolen_dispatches.to_string(),
            format!("{}/{}/{}", r.store_loads, r.store_evictions, r.store_swaps),
            models.join(" "),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::loadgen::run_virtual;
    use crate::workload::mix::MixSpace;
    use crate::workload::report::build_report;

    #[test]
    fn tables_render_one_row_per_mix() {
        let mut space = MixSpace::default_space();
        space.clients = (1, 1);
        space.requests_per_client = (4, 4);
        let reports: Vec<MixReport> = space
            .sample_all(13, 2)
            .iter()
            .map(|mix| build_report(mix, &run_virtual(mix).unwrap()).unwrap())
            .collect();
        let lat = fig_serve_latency(&reports).render();
        let disp = fig_serve_dispatch(&reports).render();
        for name in ["mix_000", "mix_001"] {
            assert!(lat.contains(name), "{lat}");
            assert!(disp.contains(name), "{disp}");
        }
        assert!(lat.contains("p99 us"));
        assert!(lat.contains("shed full/budget/cold"));
        assert!(disp.contains("flush deadline"));
        assert!(disp.contains("flush budget"));
        assert!(disp.contains("edf inv"));
        assert!(disp.contains("store l/e/s"));
    }
}

//! Figure/table harnesses: one function per figure of the paper's
//! evaluation (§4), each returning printable tables with the same
//! rows/series the paper reports.  DESIGN.md §5 maps figure → harness.
//!
//! Simulated figures (4–8, 12, 13) run on the cache simulator + cost
//! model (the gem5 stand-in); measured figures (11, and the measured
//! variant of 10) run the native kernels under the wall clock.

pub mod e2e;
pub mod ondevice;
pub mod serve;
pub mod sweeps;

use crate::costmodel::{simulate_gemv, CoreModel, Method, SimResult};
use crate::sim::CachePreset;
use crate::util::bench::Table;

/// Default IO-size grid of the Fig. 4/5/6/12/13 sweeps.
pub const SIZES: [usize; 6] = [128, 256, 512, 1024, 2048, 4096];

/// Reduced grid for `--quick` runs and tests.
pub const SIZES_QUICK: [usize; 3] = [256, 1024, 4096];

/// Steady-state warmup calls before measuring (weights resident if they
/// fit the LLC — the regime the paper's inference benchmarks measure).
pub const STEADY_CALLS: usize = 3;

/// One simulated sweep cell.
#[derive(Debug, Clone, Copy)]
pub struct Cell {
    pub z: usize,
    pub k: usize,
    pub result: SimResult,
}

/// Run `method` over a `sizes × sizes` grid.
pub fn sweep(method: Method, sizes: &[usize], preset: CachePreset, core: &CoreModel) -> Vec<Cell> {
    let mut cells = Vec::with_capacity(sizes.len() * sizes.len());
    for &z in sizes {
        for &k in sizes {
            cells.push(Cell { z, k, result: simulate_gemv(method, z, k, preset, core, STEADY_CALLS) });
        }
    }
    cells
}

/// Render a per-method grid of `value(cell, baseline_cell)` as a table
/// with `k` columns and `z` rows (the paper's heatmap layout).
pub fn grid_table(
    title: &str,
    sizes: &[usize],
    cells: &[Cell],
    base: &[Cell],
    value: impl Fn(&SimResult, &SimResult) -> f64,
) -> Table {
    let mut headers = vec![format!("{title} z\\k")];
    headers.extend(sizes.iter().map(|k| k.to_string()));
    let mut t = Table::new(headers);
    for (zi, &z) in sizes.iter().enumerate() {
        let mut row = vec![z.to_string()];
        for ki in 0..sizes.len() {
            let c = &cells[zi * sizes.len() + ki];
            let b = &base[zi * sizes.len() + ki];
            row.push(format!("{:.2}", value(&c.result, &b.result)));
        }
        t.row(row);
    }
    t
}

/// Geometric mean of a grid metric (the paper quotes average speedups).
pub fn geomean(cells: &[Cell], base: &[Cell], value: impl Fn(&SimResult, &SimResult) -> f64) -> f64 {
    let logs: f64 = cells
        .iter()
        .zip(base)
        .map(|(c, b)| value(&c.result, &b.result).max(1e-12).ln())
        .sum();
    (logs / cells.len() as f64).exp()
}

/// speedup = T_baseline / T_case (paper Fig. 4 caption).
pub fn speedup(case: &SimResult, base: &SimResult) -> f64 {
    base.cycles / case.cycles
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_and_geomean() {
        let core = CoreModel::ex5_big();
        let base = sweep(Method::RuyW8A8, &SIZES_QUICK, CachePreset::Gem5Ex5Big, &core);
        let full = sweep(Method::fullpack("w4a8"), &SIZES_QUICK, CachePreset::Gem5Ex5Big, &core);
        assert_eq!(base.len(), 9);
        let g = geomean(&base, &full, speedup); // baseline vs fullpack < 1
        let g_inv = geomean(&full, &base, speedup);
        assert!(g_inv > 1.0, "FullPack-W4A8 mean speedup {g_inv}");
        assert!((g * g_inv - 1.0).abs() < 1e-9);
        let t = grid_table("w4a8", &SIZES_QUICK, &full, &base, speedup);
        let s = t.render();
        assert!(s.contains("4096"));
    }
}

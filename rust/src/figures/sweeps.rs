//! The simulated sweep figures: Fig. 4 (speedup heatmaps for all
//! methods), Fig. 5 (what to quantize), Fig. 6 (LLC metrics), Fig. 7
//! (LLC size/hierarchy sweep), Fig. 8 (narrower bit-widths), Fig. 12
//! (instruction counts), Fig. 13 (IPC) — plus the repo's own
//! GEMM batch×size sweep ([`fig_gemm_batch`], not a paper figure: the
//! paper routes GEMM to Ruy; DESIGN.md §9).

use super::{geomean, grid_table, speedup, sweep, STEADY_CALLS};
use crate::costmodel::{gemm_batch_threshold, simulate_gemm, simulate_gemv, CoreModel, Method};
use crate::kernels::isa::IsaKind;
use crate::pack::Variant;
use crate::sim::CachePreset;
use crate::util::bench::Table;

/// A figure's rendered output: named tables + headline numbers.
pub struct FigureReport {
    pub id: &'static str,
    pub tables: Vec<(String, Table)>,
    pub headlines: Vec<(String, f64)>,
}

impl FigureReport {
    pub fn print(&self) {
        println!("=== {} ===", self.id);
        for (name, t) in &self.tables {
            println!("\n-- {name}");
            t.print();
        }
        for (name, v) in &self.headlines {
            println!("{name}: {v:.3}");
        }
        println!();
    }
}

fn core() -> CoreModel {
    CoreModel::ex5_big()
}

/// Fig. 4: speedup of every method vs Ruy-W8A8 over the IO-size grid.
pub fn fig4(sizes: &[usize]) -> FigureReport {
    let c = core();
    let base = sweep(Method::RuyW8A8, sizes, CachePreset::Gem5Ex5Big, &c);
    let mut tables = Vec::new();
    let mut headlines = Vec::new();
    for m in Method::fig4_lineup().into_iter().skip(1) {
        let cells = sweep(m, sizes, CachePreset::Gem5Ex5Big, &c);
        let g = geomean(&cells, &base, speedup);
        tables.push((
            format!("{} speedup vs Ruy-W8A8", m.label()),
            grid_table(&m.label(), sizes, &cells, &base, speedup),
        ));
        headlines.push((format!("{} geomean speedup", m.label()), g));
    }
    FigureReport { id: "fig4", tables, headlines }
}

/// Fig. 5: W4A8 vs W8A4 vs W4A4 — what to quantize.
pub fn fig5(sizes: &[usize]) -> FigureReport {
    let c = core();
    let base = sweep(Method::RuyW8A8, sizes, CachePreset::Gem5Ex5Big, &c);
    let mut tables = Vec::new();
    let mut headlines = Vec::new();
    for v in ["w4a8", "w8a4", "w4a4"] {
        let m = Method::fullpack(v);
        let cells = sweep(m, sizes, CachePreset::Gem5Ex5Big, &c);
        headlines.push((format!("{} geomean speedup", m.label()), geomean(&cells, &base, speedup)));
        tables.push((
            format!("{} speedup vs Ruy-W8A8", m.label()),
            grid_table(v, sizes, &cells, &base, speedup),
        ));
    }
    FigureReport { id: "fig5", tables, headlines }
}

/// Fig. 6: LLC access / miss / miss-rate / miss-latency ratios
/// (M_case / M_baseline) for W4A8, W8A4, W4A4.
pub fn fig6(sizes: &[usize]) -> FigureReport {
    let c = core();
    let base = sweep(Method::RuyW8A8, sizes, CachePreset::Gem5Ex5Big, &c);
    let mut tables = Vec::new();
    let mut headlines = Vec::new();
    let metrics: [(&str, fn(&super::SimResult, &super::SimResult) -> f64); 4] = [
        ("LLC accesses", |a, b| a.llc.accesses as f64 / b.llc.accesses.max(1) as f64),
        ("LLC misses", |a, b| a.llc.misses as f64 / b.llc.misses.max(1) as f64),
        ("LLC miss rate", |a, b| a.llc.miss_rate() / b.llc.miss_rate().max(1e-12)),
        ("LLC miss latency", |a, b| {
            a.llc.miss_latency_total as f64 / b.llc.miss_latency_total.max(1) as f64
        }),
    ];
    for v in ["w4a8", "w8a4", "w4a4"] {
        let m = Method::fullpack(v);
        let cells = sweep(m, sizes, CachePreset::Gem5Ex5Big, &c);
        for (name, f) in metrics {
            tables.push((
                format!("{} {name} ratio vs baseline", m.label()),
                grid_table(v, sizes, &cells, &base, f),
            ));
        }
        // headline: access reduction at the largest size (paper: ~0.5)
        let last = cells.len() - 1;
        headlines.push((
            format!("{} largest-size access ratio", m.label()),
            cells[last].result.llc.accesses as f64 / base[last].result.llc.accesses.max(1) as f64,
        ));
    }
    FigureReport { id: "fig6", tables, headlines }
}

/// Fig. 7: FullPack-W4A4 speedup under different LLC sizes/hierarchies.
pub fn fig7(sizes: &[usize]) -> FigureReport {
    let c = core();
    let m = Method::fullpack("w4a4");
    let mut tables = Vec::new();
    let mut headlines = Vec::new();
    for preset in [
        CachePreset::L21M,
        CachePreset::Gem5Ex5Big,
        CachePreset::L28M,
        CachePreset::Gem5Ex5BigL3,
        CachePreset::L1Only,
    ] {
        let base = sweep(Method::RuyW8A8, sizes, preset, &c);
        let cells = sweep(m, sizes, preset, &c);
        headlines.push((
            format!("W4A4 geomean speedup [{}]", preset.name()),
            geomean(&cells, &base, speedup),
        ));
        tables.push((
            format!("W4A4 speedup vs Ruy-W8A8 [{}]", preset.name()),
            grid_table("w4a4", sizes, &cells, &base, speedup),
        ));
    }
    FigureReport { id: "fig7", tables, headlines }
}

/// Fig. 8: W2A2 / W1A1 speedup and instruction count **relative to
/// W4A4** (T_w4a4/T_case, I_case/I_w4a4).
pub fn fig8(sizes: &[usize]) -> FigureReport {
    let c = core();
    let w4a4 = sweep(Method::fullpack("w4a4"), sizes, CachePreset::Gem5Ex5Big, &c);
    let mut tables = Vec::new();
    let mut headlines = Vec::new();
    for v in ["w2a2", "w1a1"] {
        let m = Method::fullpack(v);
        let cells = sweep(m, sizes, CachePreset::Gem5Ex5Big, &c);
        tables.push((
            format!("{} speedup vs W4A4", m.label()),
            grid_table(v, sizes, &cells, &w4a4, speedup),
        ));
        tables.push((
            format!("{} instruction ratio vs W4A4", m.label()),
            grid_table(v, sizes, &cells, &w4a4, |a, b| a.instrs / b.instrs),
        ));
        headlines.push((format!("{} geomean speedup vs W4A4", m.label()), geomean(&cells, &w4a4, speedup)));
        headlines.push((
            format!("{} instr ratio vs W4A4", m.label()),
            geomean(&cells, &w4a4, |a, b| a.instrs / b.instrs),
        ));
    }
    FigureReport { id: "fig8", tables, headlines }
}

/// Fig. 12: instruction-count ratio (I_case / I_baseline) per method.
pub fn fig12(sizes: &[usize]) -> FigureReport {
    let c = core();
    let base = sweep(Method::RuyW8A8, sizes, CachePreset::Gem5Ex5Big, &c);
    let mut tables = Vec::new();
    let mut headlines = Vec::new();
    let lineup: Vec<Method> = Method::fig4_lineup()
        .into_iter()
        .skip(1)
        .chain([Method::fullpack("w8a4"), Method::fullpack("w4a4")])
        .collect();
    for m in lineup {
        let cells = sweep(m, sizes, CachePreset::Gem5Ex5Big, &c);
        headlines.push((
            format!("{} instr ratio", m.label()),
            geomean(&cells, &base, |a, b| a.instrs / b.instrs),
        ));
        tables.push((
            format!("{} instruction ratio vs Ruy-W8A8", m.label()),
            grid_table(&m.label(), sizes, &cells, &base, |a, b| a.instrs / b.instrs),
        ));
    }
    FigureReport { id: "fig12", tables, headlines }
}

/// Fig. 13: IPC ratio (IPC_case / IPC_baseline) per method.
pub fn fig13(sizes: &[usize]) -> FigureReport {
    let c = core();
    let base = sweep(Method::RuyW8A8, sizes, CachePreset::Gem5Ex5Big, &c);
    let mut tables = Vec::new();
    let mut headlines = Vec::new();
    for m in [
        Method::fullpack("w4a8"),
        Method::fullpack("w8a4"),
        Method::fullpack("w4a4"),
        Method::XnnW8A8,
    ] {
        let cells = sweep(m, sizes, CachePreset::Gem5Ex5Big, &c);
        headlines.push((
            format!("{} IPC ratio", m.label()),
            geomean(&cells, &base, |a, b| a.ipc() / b.ipc()),
        ));
        tables.push((
            format!("{} IPC ratio vs Ruy-W8A8", m.label()),
            grid_table(&m.label(), sizes, &cells, &base, |a, b| a.ipc() / b.ipc()),
        ));
    }
    FigureReport { id: "fig13", tables, headlines }
}

/// Batch columns of the [`fig_gemm_batch`] sweep rows.
pub const GEMM_SWEEP_BATCHES: [usize; 5] = [1, 2, 4, 8, 16];

/// The GEMM tier's batch×size sweep (EXPERIMENTS.md crossover table;
/// DESIGN.md §9): memory-aware gain of **one** batched
/// `FullPack-GEMM` call over `batch` repeated FullPack GEMVs on the
/// same `n × n` weights (`T_repeated / T_gemm`, both through
/// `costmodel::simulate_gemm` — the batched side replays a single
/// blocked weight pass, the repeated side re-streams the matrix per
/// column).  One table per GEMM-tier variant, rows = batch, columns =
/// size; headlines report the modeled crossover batch per variant at
/// the largest swept size (`costmodel::gemm_batch_threshold`, the
/// number behind `kernels::GEMM_MIN_BATCH`).
pub fn fig_gemm_batch(sizes: &[usize]) -> FigureReport {
    let c = core();
    let preset = CachePreset::Gem5Ex5Big;
    let mut tables = Vec::new();
    let mut headlines = Vec::new();
    for vname in ["w4a8", "w2a8", "w1a8"] {
        let gemm = Method::fullpack_gemm(vname);
        let repeated = Method::fullpack(vname);
        let mut headers = vec![format!("{vname} gain b\\n")];
        headers.extend(sizes.iter().map(|n| n.to_string()));
        let mut t = Table::new(headers);
        for &batch in &GEMM_SWEEP_BATCHES {
            let mut row = vec![batch.to_string()];
            for &n in sizes {
                let g = simulate_gemm(gemm, n, n, batch, preset, &c, STEADY_CALLS);
                let r = simulate_gemm(repeated, n, n, batch, preset, &c, STEADY_CALLS);
                row.push(format!("{:.2}", r.cycles / g.cycles));
            }
            t.row(row);
        }
        tables.push((format!("FullPack-GEMM-{} gain vs repeated GEMV", vname.to_uppercase()), t));
        let n = *sizes.last().expect("non-empty size grid");
        let v = Variant::parse(vname).expect("gemm-tier variant");
        let th = gemm_batch_threshold(v, n, n, preset, &c, 16);
        headlines.push((
            format!("{vname} crossover batch @ {n}x{n}"),
            th.map(|b| b as f64).unwrap_or(f64::INFINITY),
        ));
    }
    FigureReport { id: "gemm-batch", tables, headlines }
}

/// Depth columns of the [`fig_lut_crossover`] sweep: the LUT tier's
/// table is `wb · 1KB` (`wb` = packed bytes per row), so the swept
/// depths straddle the 128KB L1 — 128 (64KB table at w4a8, fits), 512
/// (256KB, spills), 2048 (1MB, thrashes).
pub const LUT_SWEEP_DEPTHS: [usize; 3] = [128, 512, 2048];

/// The LUT tier's crossover sweep (EXPERIMENTS.md §LUT; DESIGN.md §13,
/// not a paper figure): modeled gain of one `lut-*` GEMV call over its
/// FullPack sibling (and, for `w4a4`, over ULPPACK) on the **portable**
/// core — the regime the tier exists for, where the staged lane loops
/// are charged for imperfect vectorization while the LUT's scalar
/// gathers cost what they cost everywhere.  Rows sweep `z` (more rows
/// amortize the per-call table build), columns sweep `k`
/// ([`LUT_SWEEP_DEPTHS`] — the table-vs-L1 axis).  Headlines pin the
/// four crossover cells the cost-model tests assert: LUT wins only at
/// many-rows × L1-resident-table on the portable core.
pub fn fig_lut_crossover(zs: &[usize]) -> FigureReport {
    let preset = CachePreset::Gem5Ex5Big;
    let port = CoreModel::portable();
    let mut tables = Vec::new();
    let mut headlines = Vec::new();
    let lineup: [(&str, Method, &str); 5] = [
        ("w4a8", Method::fullpack("w4a8"), "FullPack-W4A8"),
        ("w2a8", Method::fullpack("w2a8"), "FullPack-W2A8"),
        ("w1a8", Method::fullpack("w1a8"), "FullPack-W1A8"),
        ("w4a4", Method::fullpack("w4a4"), "FullPack-W4A4"),
        ("w4a4", Method::Ulppack { bits: 4 }, "ULPPACK-W4A4"),
    ];
    for (vname, rival, rival_label) in lineup {
        let lut = Method::lut(vname);
        let mut headers = vec![format!("{vname} gain z\\k")];
        headers.extend(LUT_SWEEP_DEPTHS.iter().map(|k| k.to_string()));
        let mut t = Table::new(headers);
        for &z in zs {
            let mut row = vec![z.to_string()];
            for &k in &LUT_SWEEP_DEPTHS {
                let l = simulate_gemv(lut, z, k, preset, &port, STEADY_CALLS);
                let r = simulate_gemv(rival, z, k, preset, &port, STEADY_CALLS);
                row.push(format!("{:.2}", r.cycles / l.cycles));
            }
            t.row(row);
        }
        tables.push((
            format!("LUT-{} gain vs {rival_label} [portable core]", vname.to_uppercase()),
            t,
        ));
    }
    let cell = |core: &CoreModel, z: usize, k: usize| {
        let l = simulate_gemv(Method::lut("w4a8"), z, k, preset, core, STEADY_CALLS);
        let r = simulate_gemv(Method::fullpack("w4a8"), z, k, preset, core, STEADY_CALLS);
        r.cycles / l.cycles
    };
    headlines.push(("w4a8 gain @ z=2048 k=128 [portable]".into(), cell(&port, 2048, 128)));
    headlines.push(("w4a8 gain @ z=128 k=128 [portable]".into(), cell(&port, 128, 128)));
    headlines.push(("w4a8 gain @ z=2048 k=2048 [portable]".into(), cell(&port, 2048, 2048)));
    let neon = CoreModel::ex5_big();
    headlines.push(("w4a8 gain @ z=2048 k=128 [ex5-big]".into(), cell(&neon, 2048, 128)));
    FigureReport { id: "lut-crossover", tables, headlines }
}

/// The real-ISA tier's crossover sweep (EXPERIMENTS.md §ISA;
/// DESIGN.md §15, not a paper figure): modeled gain of the
/// `fullpack-*-avx2` / `-neon` intrinsic kernels over the staged scalar
/// kernel **and** the SWAR tier, each ISA evaluated on its matching
/// wide core ([`CoreModel::avx2`] / [`CoreModel::neon`] — real SIMD
/// issue, but the staged lane loops charged the portable autovec
/// discount they actually suffer there).  Rows sweep the square size
/// `n`; the two gain columns are the tier's rivals.  Headlines pin the
/// cells the plan-selection test asserts
/// (`kernels::plan::tests::cost_model_prefers_the_isa_tier_on_wide_cores`):
/// the ISA tier wins on the wide cores, while on `ex5_big` — where the
/// model trusts the compiler to vectorize the staged loops perfectly —
/// staged keeps winning, which is why detection alone never forces the
/// tier on.
pub fn fig_isa_crossover(sizes: &[usize]) -> FigureReport {
    let preset = CachePreset::Gem5Ex5Big;
    let mut tables = Vec::new();
    let mut headlines = Vec::new();
    let lineup: [(IsaKind, CoreModel, &str); 2] = [
        (IsaKind::Avx2, CoreModel::avx2(), "avx2-core"),
        (IsaKind::Neon, CoreModel::neon(), "neon-core"),
    ];
    for (kind, core, core_label) in &lineup {
        for vname in ["w4a8", "w2a8", "w1a8", "w8a8"] {
            let isa = Method::fullpack_isa(vname, *kind);
            // staged rival: the scalar FullPack sibling for sub-byte,
            // the Ruy-style baseline for w8a8 (no staged w8a8 kernel)
            let staged =
                if vname == "w8a8" { Method::RuyW8A8 } else { Method::fullpack(vname) };
            let swar = Method::fullpack_swar(vname);
            let mut t = Table::new(vec![
                format!("{vname} gain n"),
                "vs staged".to_string(),
                "vs swar".to_string(),
            ]);
            for &n in sizes {
                let i = simulate_gemv(isa, n, n, preset, core, STEADY_CALLS);
                let s = simulate_gemv(staged, n, n, preset, core, STEADY_CALLS);
                let w = simulate_gemv(swar, n, n, preset, core, STEADY_CALLS);
                t.row(vec![
                    n.to_string(),
                    format!("{:.2}", s.cycles / i.cycles),
                    format!("{:.2}", w.cycles / i.cycles),
                ]);
            }
            tables.push((format!("{} gain [{core_label}]", isa.label()), t));
        }
    }
    let cell = |m: Method, core: &CoreModel, n: usize| {
        simulate_gemv(m, n, n, preset, core, STEADY_CALLS).cycles
    };
    let avx = CoreModel::avx2();
    let neon = CoreModel::neon();
    let ex5 = CoreModel::ex5_big();
    let w4_avx = Method::fullpack_isa("w4a8", IsaKind::Avx2);
    let w4_neon = Method::fullpack_isa("w4a8", IsaKind::Neon);
    headlines.push((
        "w4a8 avx2 gain vs swar @ 2048 [avx2-core]".into(),
        cell(Method::fullpack_swar("w4a8"), &avx, 2048) / cell(w4_avx, &avx, 2048),
    ));
    headlines.push((
        "w4a8 avx2 gain vs staged @ 2048 [avx2-core]".into(),
        cell(Method::fullpack("w4a8"), &avx, 2048) / cell(w4_avx, &avx, 2048),
    ));
    headlines.push((
        "w4a8 neon gain vs staged @ 2048 [neon-core]".into(),
        cell(Method::fullpack("w4a8"), &neon, 2048) / cell(w4_neon, &neon, 2048),
    ));
    headlines.push((
        "w4a8 neon gain vs staged @ 2048 [ex5-big]".into(),
        cell(Method::fullpack("w4a8"), &ex5, 2048) / cell(w4_neon, &ex5, 2048),
    ));
    FigureReport { id: "isa-crossover", tables, headlines }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::SIZES_QUICK;

    #[test]
    fn fig4_shape_holds() {
        let r = fig4(&SIZES_QUICK);
        let hl: std::collections::HashMap<&str, f64> =
            r.headlines.iter().map(|(n, v)| (n.as_str(), *v)).collect();
        // who wins: FullPack-W4A8 > 1, FP32 methods < 1, ULPPACK << 1
        assert!(hl["FullPack-W4A8 geomean speedup"] > 1.0);
        assert!(hl["TFLite-FP32 geomean speedup"] < 0.5);
        assert!(hl["ULPPACK-W2A2 geomean speedup"] < 0.5);
        // XNNPack beats baseline on average (paper: 2.4x overall)
        assert!(hl["XNNPack-W8A8 geomean speedup"] > 1.0);
    }

    #[test]
    fn fig5_weight_quant_dominates() {
        let r = fig5(&SIZES_QUICK);
        let hl: std::collections::HashMap<&str, f64> =
            r.headlines.iter().map(|(n, v)| (n.as_str(), *v)).collect();
        let w = hl["FullPack-W4A8 geomean speedup"];
        let a = hl["FullPack-W8A4 geomean speedup"];
        let both = hl["FullPack-W4A4 geomean speedup"];
        assert!(w > a, "weights {w} vs acts {a}");
        // paper: W4A4 ≈ 1.02x of W4A8 — near parity.  Our instruction
        // model charges W4A4's extra extraction shifts slightly more
        // than gem5 measured, so allow a 15% band around parity.
        assert!(both >= w * 0.85, "both {both} vs weights {w}");
    }

    #[test]
    fn fig6_access_halving() {
        let r = fig6(&SIZES_QUICK);
        let hl: std::collections::HashMap<&str, f64> =
            r.headlines.iter().map(|(n, v)| (n.as_str(), *v)).collect();
        let ratio = hl["FullPack-W4A8 largest-size access ratio"];
        assert!((0.4..0.7).contains(&ratio), "access ratio {ratio}");
    }

    #[test]
    fn fig7_reports_all_hierarchies() {
        let r = fig7(&SIZES_QUICK);
        assert_eq!(r.tables.len(), 5);
        for (_, v) in &r.headlines {
            assert!(*v > 0.5, "speedup {v}");
        }
    }

    #[test]
    fn fig8_narrow_bits_help_at_scale() {
        let r = fig8(&SIZES_QUICK);
        let hl: std::collections::HashMap<&str, f64> =
            r.headlines.iter().map(|(n, v)| (n.as_str(), *v)).collect();
        assert!(hl["FullPack-W2A2 geomean speedup vs W4A4"] > 0.9);
        // instruction ratios stay near 1 (paper: 1.03x / 0.8x)
        let i1 = hl["FullPack-W1A1 instr ratio vs W4A4"];
        assert!((0.5..1.5).contains(&i1), "w1a1 instr ratio {i1}");
    }

    #[test]
    fn gemm_batch_sweep_amortizes() {
        // small grid to keep the replay volume test-sized
        let r = fig_gemm_batch(&[256, 1024]);
        assert_eq!(r.tables.len(), 3);
        for (vi, vname) in ["w4a8", "w2a8", "w1a8"].iter().enumerate() {
            let t = &r.tables[vi].1;
            let rendered = t.render();
            assert!(rendered.contains("1024"), "{vname}");
            // the memory-aware crossover at the largest swept size sits
            // at batch 2 — the number GEMM_MIN_BATCH encodes
            let (name, th) = &r.headlines[vi];
            assert!(name.contains(vname));
            assert_eq!(*th, 2.0, "{vname} crossover {th}");
        }
    }

    #[test]
    fn lut_crossover_sweep_shows_both_regimes() {
        let r = fig_lut_crossover(&[128, 2048]);
        // one gain table per FullPack sibling plus the ULPPACK rival
        assert_eq!(r.tables.len(), 5);
        let hl: std::collections::HashMap<&str, f64> =
            r.headlines.iter().map(|(n, v)| (n.as_str(), *v)).collect();
        // the four pinned crossover cells (mirrors costmodel::tests::
        // lut_crossover_amortized_build_vs_l1_pressure): LUT wins only
        // when the table is L1-resident, the build is amortized over
        // many rows, and the core pays the portable autovec penalty
        assert!(hl["w4a8 gain @ z=2048 k=128 [portable]"] > 1.0);
        assert!(hl["w4a8 gain @ z=128 k=128 [portable]"] < 1.0);
        assert!(hl["w4a8 gain @ z=2048 k=2048 [portable]"] < 1.0);
        assert!(hl["w4a8 gain @ z=2048 k=128 [ex5-big]"] < 1.0);
    }

    #[test]
    fn isa_crossover_pins_the_wide_core_wins() {
        let r = fig_isa_crossover(&SIZES_QUICK);
        // 2 ISAs x 4 variants, one gain table each
        assert_eq!(r.tables.len(), 8);
        let hl: std::collections::HashMap<&str, f64> =
            r.headlines.iter().map(|(n, v)| (n.as_str(), *v)).collect();
        // mirrors kernels::plan::tests::cost_model_prefers_the_isa_tier_
        // on_wide_cores: the ISA tier wins on its matching wide core...
        assert!(hl["w4a8 avx2 gain vs swar @ 2048 [avx2-core]"] > 1.0);
        assert!(hl["w4a8 avx2 gain vs staged @ 2048 [avx2-core]"] > 1.0);
        assert!(hl["w4a8 neon gain vs staged @ 2048 [neon-core]"] > 1.0);
        // ...but on ex5-big, where the model trusts the autovectorizer,
        // the staged kernel keeps its §4.4 crown
        assert!(hl["w4a8 neon gain vs staged @ 2048 [ex5-big]"] < 1.0);
    }

    #[test]
    fn fig12_fig13_render() {
        let r12 = fig12(&SIZES_QUICK);
        assert!(!r12.tables.is_empty());
        let r13 = fig13(&SIZES_QUICK);
        let hl: std::collections::HashMap<&str, f64> =
            r13.headlines.iter().map(|(n, v)| (n.as_str(), *v)).collect();
        // FullPack has better IPC than the baseline (paper Fig. 13)
        assert!(hl["FullPack-W4A8 IPC ratio"] > 0.9);
    }
}

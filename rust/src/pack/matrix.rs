//! Packed matrix containers: row-major matrices whose rows are packed
//! independently (paper §3.1 — "repeated again for all other sets of
//! rows"), plus the ULPPACK comparison container.

use super::{pack, pack_ulppack, unpack, BitWidth, PackError, VL};

/// A `rows × k` matrix of signed `bits`-wide values in FullPack layout
/// (or plain int8 for `BitWidth::B8`).  Rows are packed independently so
/// the GEMV kernels can stream one row at a time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedMatrix {
    data: Vec<u8>,
    rows: usize,
    /// logical (unpadded) depth
    k: usize,
    /// group-padded depth
    k_padded: usize,
    bits: BitWidth,
    bytes_per_row: usize,
}

impl PackedMatrix {
    /// Pack from a row-major `rows × k` signed int8 matrix.
    pub fn from_i8(w: &[i8], rows: usize, k: usize, bits: BitWidth) -> Result<Self, PackError> {
        assert_eq!(w.len(), rows * k, "matrix data length mismatch");
        if bits.is_sub_byte() {
            let bytes_per_row = bits.packed_bytes(k);
            let mut data = Vec::with_capacity(rows * bytes_per_row);
            for r in 0..rows {
                data.extend(pack(&w[r * k..(r + 1) * k], bits)?);
            }
            Ok(PackedMatrix {
                data,
                rows,
                k,
                k_padded: bits.padded_len(k),
                bits,
                bytes_per_row,
            })
        } else {
            Ok(PackedMatrix {
                data: w.iter().map(|&v| v as u8).collect(),
                rows,
                k,
                k_padded: k,
                bits,
                bytes_per_row: k,
            })
        }
    }

    /// Adopt pre-packed bytes (e.g. read from disk or produced by the
    /// Python pack twin).  Validates the byte count.
    pub fn from_packed(
        data: Vec<u8>,
        rows: usize,
        k: usize,
        bits: BitWidth,
    ) -> Result<Self, PackError> {
        let bytes_per_row = bits.packed_bytes(k);
        if bits.is_sub_byte() && bytes_per_row % VL != 0 {
            return Err(PackError::BadPackedLen(bytes_per_row));
        }
        assert_eq!(data.len(), rows * bytes_per_row, "packed data length mismatch");
        Ok(PackedMatrix {
            data,
            rows,
            k,
            k_padded: bits.padded_len(k),
            bits,
            bytes_per_row,
        })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    #[inline]
    pub fn k_padded(&self) -> usize {
        self.k_padded
    }

    #[inline]
    pub fn bits(&self) -> BitWidth {
        self.bits
    }

    #[inline]
    pub fn bytes_per_row(&self) -> usize {
        self.bytes_per_row
    }

    /// Packed bytes of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[u8] {
        &self.data[r * self.bytes_per_row..(r + 1) * self.bytes_per_row]
    }

    /// Row `r` as signed int8 (only valid for `B8` matrices).
    #[inline]
    pub fn row_i8(&self, r: usize) -> &[i8] {
        debug_assert!(!self.bits.is_sub_byte());
        let row = self.row(r);
        // SAFETY: i8 and u8 have identical layout.
        unsafe { std::slice::from_raw_parts(row.as_ptr() as *const i8, row.len()) }
    }

    /// Whole packed buffer (for PJRT literal upload / serialization).
    #[inline]
    pub fn bytes(&self) -> &[u8] {
        &self.data
    }

    /// Total footprint in bytes — the paper's memory-capacity metric.
    #[inline]
    pub fn footprint(&self) -> usize {
        self.data.len()
    }

    /// Unpack row `r` to int8 (oracle/debug path).
    pub fn unpack_row(&self, r: usize) -> Vec<i8> {
        if self.bits.is_sub_byte() {
            unpack(self.row(r), self.bits, self.k).expect("valid packed row")
        } else {
            self.row_i8(r).to_vec()
        }
    }

    /// Unpack the whole matrix to row-major int8 (oracle/debug path).
    pub fn unpack_all(&self) -> Vec<i8> {
        let mut out = Vec::with_capacity(self.rows * self.k);
        for r in 0..self.rows {
            out.extend(self.unpack_row(r));
        }
        out
    }
}

/// ULPPACK-layout matrix: unsigned values with zero point, two per u16
/// lane (baseline comparator; see `pack_ulppack`).
#[derive(Debug, Clone)]
pub struct UlppackMatrix {
    data: Vec<u16>,
    rows: usize,
    k: usize,
    bits: BitWidth,
    lanes_per_row: usize,
    /// zero point added when converting from the signed domain.
    pub zero_point: u8,
}

impl UlppackMatrix {
    /// Pack from signed int8 by shifting to the unsigned domain
    /// (`zero_point = 2^(b-1)`).
    pub fn from_i8(w: &[i8], rows: usize, k: usize, bits: BitWidth) -> Result<Self, PackError> {
        assert_eq!(w.len(), rows * k);
        let zp = 1u8 << (bits.bits() - 1);
        let lanes_per_row = k.div_ceil(2);
        let mut data = Vec::with_capacity(rows * lanes_per_row);
        for r in 0..rows {
            let row: Vec<u8> = w[r * k..(r + 1) * k]
                .iter()
                .map(|&v| (v as i16 + zp as i16) as u8)
                .collect();
            data.extend(pack_ulppack(&row, bits)?);
        }
        Ok(UlppackMatrix {
            data,
            rows,
            k,
            bits,
            lanes_per_row,
            zero_point: zp,
        })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    #[inline]
    pub fn bits(&self) -> BitWidth {
        self.bits
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[u16] {
        &self.data[r * self.lanes_per_row..(r + 1) * self.lanes_per_row]
    }

    /// Footprint in bytes — 2 bytes per 2 values regardless of b: the
    /// spacer waste FullPack eliminates.
    #[inline]
    pub fn footprint(&self) -> usize {
        self.data.len() * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_roundtrip() {
        let rows = 4;
        let k = 40; // unaligned: pads to 64 for 4-bit
        let w: Vec<i8> = (0..rows * k).map(|i| ((i % 15) as i8) - 7).collect();
        let m = PackedMatrix::from_i8(&w, rows, k, BitWidth::B4).unwrap();
        assert_eq!(m.k_padded(), 64);
        assert_eq!(m.bytes_per_row(), 32);
        assert_eq!(m.unpack_all(), w);
    }

    #[test]
    fn matrix_b8_passthrough() {
        let w: Vec<i8> = vec![-128, 0, 127, 5];
        let m = PackedMatrix::from_i8(&w, 2, 2, BitWidth::B8).unwrap();
        assert_eq!(m.row_i8(0), &[-128, 0]);
        assert_eq!(m.unpack_all(), w);
        assert_eq!(m.footprint(), 4);
    }

    #[test]
    fn footprint_ratios_match_bits() {
        // The paper's capacity claim: footprint scales with b/8.
        let k = 256;
        let w: Vec<i8> = vec![0; 8 * k];
        let f8 = PackedMatrix::from_i8(&w, 8, k, BitWidth::B8).unwrap().footprint();
        let f4 = PackedMatrix::from_i8(&w, 8, k, BitWidth::B4).unwrap().footprint();
        let f2 = PackedMatrix::from_i8(&w, 8, k, BitWidth::B2).unwrap().footprint();
        let f1 = PackedMatrix::from_i8(&w, 8, k, BitWidth::B1).unwrap().footprint();
        assert_eq!(f4 * 2, f8);
        assert_eq!(f2 * 4, f8);
        assert_eq!(f1 * 8, f8);
    }

    #[test]
    fn ulppack_footprint_vs_fullpack() {
        let k = 256;
        let w: Vec<i8> = vec![1; 4 * k];
        let ulp = UlppackMatrix::from_i8(&w, 4, k, BitWidth::B2).unwrap();
        let full = PackedMatrix::from_i8(&w, 4, k, BitWidth::B2).unwrap();
        assert_eq!(ulp.footprint(), 4 * k); // 1 byte/value
        assert_eq!(full.footprint(), 4 * k / 4); // 0.25 byte/value
        assert_eq!(ulp.zero_point, 2);
    }

    #[test]
    fn from_packed_validates_length() {
        let ok = PackedMatrix::from_packed(vec![0u8; 2 * 16], 2, 32, BitWidth::B4);
        assert!(ok.is_ok());
    }
}

//! Packed matrix containers: row-major matrices whose rows are packed
//! independently (paper §3.1 — "repeated again for all other sets of
//! rows"), plus the ULPPACK comparison container.

use super::{pack, pack_ulppack, unpack, BitWidth, PackError, VL};
use std::sync::Arc;

/// Reference-counted byte storage for packed weights: a window into an
/// owner buffer shared across any number of views.  The owner is either
/// a plain heap `Vec<u8>` (one matrix, one allocation — the historical
/// layout) or a whole multi-tensor FPCK image (`serialize::WeightsImage`,
/// possibly an `mmap`ed file), in which case every tensor's bytes alias
/// the single image allocation — the zero-copy multi-tenant path.
#[derive(Clone)]
pub struct SharedBytes {
    owner: Arc<dyn AsRef<[u8]> + Send + Sync>,
    off: usize,
    len: usize,
}

impl SharedBytes {
    /// Own a heap buffer outright (the single-tensor path).
    pub fn from_vec(data: Vec<u8>) -> Self {
        let len = data.len();
        SharedBytes { owner: Arc::new(data), off: 0, len }
    }

    /// A `[off, off+len)` window into a shared owner buffer.  Panics if
    /// the window falls outside the owner (caller bugs, not wire data —
    /// wire offsets are validated by the image parser first).
    pub fn view(owner: Arc<dyn AsRef<[u8]> + Send + Sync>, off: usize, len: usize) -> Self {
        let total = (*owner).as_ref().len();
        assert!(
            off.checked_add(len).is_some_and(|end| end <= total),
            "SharedBytes window {off}+{len} outside owner of {total} bytes"
        );
        SharedBytes { owner, off, len }
    }

    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        &(*self.owner).as_ref()[self.off..self.off + self.len]
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Offset of this window within its owner (0 for `from_vec`).
    #[inline]
    pub fn offset(&self) -> usize {
        self.off
    }

    /// Does this view borrow from `owner` (same allocation), rather than
    /// holding its own copy?  The zero-copy test hook.
    pub fn is_view_of(&self, owner: &Arc<dyn AsRef<[u8]> + Send + Sync>) -> bool {
        Arc::ptr_eq(&self.owner, owner)
    }
}

impl std::ops::Deref for SharedBytes {
    type Target = [u8];
    #[inline]
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl PartialEq for SharedBytes {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for SharedBytes {}

impl std::fmt::Debug for SharedBytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SharedBytes")
            .field("off", &self.off)
            .field("len", &self.len)
            .finish()
    }
}

/// A `rows × k` matrix of signed `bits`-wide values in FullPack layout
/// (or plain int8 for `BitWidth::B8`).  Rows are packed independently so
/// the GEMV kernels can stream one row at a time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedMatrix {
    data: SharedBytes,
    rows: usize,
    /// logical (unpadded) depth
    k: usize,
    /// group-padded depth
    k_padded: usize,
    bits: BitWidth,
    bytes_per_row: usize,
}

impl PackedMatrix {
    /// Pack from a row-major `rows × k` signed int8 matrix.
    pub fn from_i8(w: &[i8], rows: usize, k: usize, bits: BitWidth) -> Result<Self, PackError> {
        assert_eq!(w.len(), rows * k, "matrix data length mismatch");
        if bits.is_sub_byte() {
            let bytes_per_row = bits.packed_bytes(k);
            let mut data = Vec::with_capacity(rows * bytes_per_row);
            for r in 0..rows {
                data.extend(pack(&w[r * k..(r + 1) * k], bits)?);
            }
            Ok(PackedMatrix {
                data: SharedBytes::from_vec(data),
                rows,
                k,
                k_padded: bits.padded_len(k),
                bits,
                bytes_per_row,
            })
        } else {
            Ok(PackedMatrix {
                data: SharedBytes::from_vec(w.iter().map(|&v| v as u8).collect()),
                rows,
                k,
                k_padded: k,
                bits,
                bytes_per_row: k,
            })
        }
    }

    /// Adopt pre-packed bytes (e.g. read from disk or produced by the
    /// Python pack twin).  Validates the byte count.
    pub fn from_packed(
        data: Vec<u8>,
        rows: usize,
        k: usize,
        bits: BitWidth,
    ) -> Result<Self, PackError> {
        Self::from_shared(SharedBytes::from_vec(data), rows, k, bits)
    }

    /// Adopt pre-packed bytes that alias a shared owner buffer — the
    /// zero-copy path used by `serialize::WeightsImage`: every tensor of
    /// a loaded image borrows the one image allocation.
    pub fn from_shared(
        data: SharedBytes,
        rows: usize,
        k: usize,
        bits: BitWidth,
    ) -> Result<Self, PackError> {
        let bytes_per_row = bits.packed_bytes(k);
        if bits.is_sub_byte() && bytes_per_row % VL != 0 {
            return Err(PackError::BadPackedLen(bytes_per_row));
        }
        assert_eq!(data.len(), rows * bytes_per_row, "packed data length mismatch");
        Ok(PackedMatrix {
            data,
            rows,
            k,
            k_padded: bits.padded_len(k),
            bits,
            bytes_per_row,
        })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    #[inline]
    pub fn k_padded(&self) -> usize {
        self.k_padded
    }

    #[inline]
    pub fn bits(&self) -> BitWidth {
        self.bits
    }

    #[inline]
    pub fn bytes_per_row(&self) -> usize {
        self.bytes_per_row
    }

    /// Packed bytes of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[u8] {
        &self.data[r * self.bytes_per_row..(r + 1) * self.bytes_per_row]
    }

    /// Row `r` as signed int8 (only valid for `B8` matrices).
    #[inline]
    pub fn row_i8(&self, r: usize) -> &[i8] {
        debug_assert!(!self.bits.is_sub_byte());
        let row = self.row(r);
        // SAFETY: i8 and u8 have identical layout.
        unsafe { std::slice::from_raw_parts(row.as_ptr() as *const i8, row.len()) }
    }

    /// Whole packed buffer (for PJRT literal upload / serialization).
    #[inline]
    pub fn bytes(&self) -> &[u8] {
        self.data.as_slice()
    }

    /// The shared storage behind this matrix (zero-copy introspection:
    /// `SharedBytes::is_view_of` tells whether it aliases an image).
    #[inline]
    pub fn shared(&self) -> &SharedBytes {
        &self.data
    }

    /// Total footprint in bytes — the paper's memory-capacity metric.
    #[inline]
    pub fn footprint(&self) -> usize {
        self.data.len()
    }

    /// Unpack row `r` to int8 (oracle/debug path).
    pub fn unpack_row(&self, r: usize) -> Vec<i8> {
        if self.bits.is_sub_byte() {
            unpack(self.row(r), self.bits, self.k).expect("valid packed row")
        } else {
            self.row_i8(r).to_vec()
        }
    }

    /// Unpack the whole matrix to row-major int8 (oracle/debug path).
    pub fn unpack_all(&self) -> Vec<i8> {
        let mut out = Vec::with_capacity(self.rows * self.k);
        for r in 0..self.rows {
            out.extend(self.unpack_row(r));
        }
        out
    }
}

/// ULPPACK-layout matrix: unsigned values with zero point, two per u16
/// lane (baseline comparator; see `pack_ulppack`).
#[derive(Debug, Clone)]
pub struct UlppackMatrix {
    data: Vec<u16>,
    rows: usize,
    k: usize,
    bits: BitWidth,
    lanes_per_row: usize,
    /// zero point added when converting from the signed domain.
    pub zero_point: u8,
}

impl UlppackMatrix {
    /// Pack from signed int8 by shifting to the unsigned domain
    /// (`zero_point = 2^(b-1)`).
    pub fn from_i8(w: &[i8], rows: usize, k: usize, bits: BitWidth) -> Result<Self, PackError> {
        assert_eq!(w.len(), rows * k);
        let zp = 1u8 << (bits.bits() - 1);
        let lanes_per_row = k.div_ceil(2);
        let mut data = Vec::with_capacity(rows * lanes_per_row);
        for r in 0..rows {
            let row: Vec<u8> = w[r * k..(r + 1) * k]
                .iter()
                .map(|&v| (v as i16 + zp as i16) as u8)
                .collect();
            data.extend(pack_ulppack(&row, bits)?);
        }
        Ok(UlppackMatrix {
            data,
            rows,
            k,
            bits,
            lanes_per_row,
            zero_point: zp,
        })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    #[inline]
    pub fn bits(&self) -> BitWidth {
        self.bits
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[u16] {
        &self.data[r * self.lanes_per_row..(r + 1) * self.lanes_per_row]
    }

    /// Footprint in bytes — 2 bytes per 2 values regardless of b: the
    /// spacer waste FullPack eliminates.
    #[inline]
    pub fn footprint(&self) -> usize {
        self.data.len() * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_roundtrip() {
        let rows = 4;
        let k = 40; // unaligned: pads to 64 for 4-bit
        let w: Vec<i8> = (0..rows * k).map(|i| ((i % 15) as i8) - 7).collect();
        let m = PackedMatrix::from_i8(&w, rows, k, BitWidth::B4).unwrap();
        assert_eq!(m.k_padded(), 64);
        assert_eq!(m.bytes_per_row(), 32);
        assert_eq!(m.unpack_all(), w);
    }

    #[test]
    fn matrix_b8_passthrough() {
        let w: Vec<i8> = vec![-128, 0, 127, 5];
        let m = PackedMatrix::from_i8(&w, 2, 2, BitWidth::B8).unwrap();
        assert_eq!(m.row_i8(0), &[-128, 0]);
        assert_eq!(m.unpack_all(), w);
        assert_eq!(m.footprint(), 4);
    }

    #[test]
    fn footprint_ratios_match_bits() {
        // The paper's capacity claim: footprint scales with b/8.
        let k = 256;
        let w: Vec<i8> = vec![0; 8 * k];
        let f8 = PackedMatrix::from_i8(&w, 8, k, BitWidth::B8).unwrap().footprint();
        let f4 = PackedMatrix::from_i8(&w, 8, k, BitWidth::B4).unwrap().footprint();
        let f2 = PackedMatrix::from_i8(&w, 8, k, BitWidth::B2).unwrap().footprint();
        let f1 = PackedMatrix::from_i8(&w, 8, k, BitWidth::B1).unwrap().footprint();
        assert_eq!(f4 * 2, f8);
        assert_eq!(f2 * 4, f8);
        assert_eq!(f1 * 8, f8);
    }

    #[test]
    fn ulppack_footprint_vs_fullpack() {
        let k = 256;
        let w: Vec<i8> = vec![1; 4 * k];
        let ulp = UlppackMatrix::from_i8(&w, 4, k, BitWidth::B2).unwrap();
        let full = PackedMatrix::from_i8(&w, 4, k, BitWidth::B2).unwrap();
        assert_eq!(ulp.footprint(), 4 * k); // 1 byte/value
        assert_eq!(full.footprint(), 4 * k / 4); // 0.25 byte/value
        assert_eq!(ulp.zero_point, 2);
    }

    #[test]
    fn from_packed_validates_length() {
        let ok = PackedMatrix::from_packed(vec![0u8; 2 * 16], 2, 32, BitWidth::B4);
        assert!(ok.is_ok());
    }

    #[test]
    fn shared_views_alias_one_owner_without_copying() {
        use std::sync::Arc;
        // two matrices carved out of one owner buffer: same allocation,
        // disjoint windows, equal to their standalone twins
        let w: Vec<i8> = (0..2 * 32).map(|i| ((i % 15) as i8) - 7).collect();
        let standalone = PackedMatrix::from_i8(&w, 2, 32, BitWidth::B4).unwrap();
        let mut buf = vec![0xAAu8; 8]; // leading bytes the views must skip
        buf.extend_from_slice(standalone.bytes());
        buf.extend_from_slice(standalone.bytes());
        let owner: Arc<dyn AsRef<[u8]> + Send + Sync> = Arc::new(buf);
        let n = standalone.bytes().len();
        let a = PackedMatrix::from_shared(
            SharedBytes::view(owner.clone(), 8, n),
            2,
            32,
            BitWidth::B4,
        )
        .unwrap();
        let b = PackedMatrix::from_shared(
            SharedBytes::view(owner.clone(), 8 + n, n),
            2,
            32,
            BitWidth::B4,
        )
        .unwrap();
        assert_eq!(a, standalone);
        assert_eq!(b, standalone);
        assert_eq!(a.unpack_all(), w);
        // zero-copy: both views alias the owner allocation...
        assert!(a.shared().is_view_of(&owner));
        assert!(b.shared().is_view_of(&owner));
        let base = (*owner).as_ref().as_ptr() as usize;
        assert_eq!(a.bytes().as_ptr() as usize, base + 8);
        assert_eq!(b.bytes().as_ptr() as usize, base + 8 + n);
        // ...while from_i8/from_packed matrices own their bytes
        assert!(!standalone.shared().is_view_of(&owner));
        // a clone shares too (Arc bump, no byte copy)
        let c = a.clone();
        assert_eq!(c.bytes().as_ptr(), a.bytes().as_ptr());
    }

    #[test]
    #[should_panic(expected = "outside owner")]
    fn shared_view_bounds_checked() {
        use std::sync::Arc;
        let owner: Arc<dyn AsRef<[u8]> + Send + Sync> = Arc::new(vec![0u8; 16]);
        let _ = SharedBytes::view(owner, 8, 9);
    }
}

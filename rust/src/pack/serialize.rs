//! Packed-weight serialization: store a [`PackedMatrix`] (or a whole
//! kernel-layout [`Weights`] value) to disk and load it back — the
//! deployment path (pack once offline, ship the packed blob, the
//! server never touches unpacked weights).
//!
//! Two wire formats, both little-endian with magic `FPCK`:
//!
//! * **v1** (`write_packed`/`read_packed`): version u32 = 1, bits u32,
//!   rows u64, k u64, packed bytes — a bare [`PackedMatrix`].
//! * **v2** (`write_weights`/`read_weights`): version u32 = 2, kind
//!   u32, then the v1 body, then kind-specific side tables.  Kind 0 is
//!   [`Weights::Packed`]; kind 1 is [`Weights::SwarPacked`] and appends
//!   `rows` i64 row sums — the SWAR tier's bias-correction side table
//!   (DESIGN.md §8), so compiled models whose plans selected a `-swar`
//!   backend survive save/load without re-deriving anything.
//!   `read_weights` also accepts v1 files (as kind 0).

use super::{BitWidth, PackedMatrix};
use crate::kernels::Weights;
use std::io::{self, Read, Write};

const MAGIC: &[u8; 4] = b"FPCK";
const VERSION: u32 = 1;
const WEIGHTS_VERSION: u32 = 2;

const KIND_PACKED: u32 = 0;
const KIND_SWAR_PACKED: u32 = 1;

/// Serialize to any writer (v1: a bare [`PackedMatrix`]).
pub fn write_packed<W: Write>(m: &PackedMatrix, w: &mut W) -> io::Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    write_matrix_body(m, w)
}

/// Deserialize from any reader (v1 files only — [`read_weights`]
/// accepts both formats).
pub fn read_packed<R: Read>(r: &mut R) -> io::Result<PackedMatrix> {
    let version = read_header(r)?;
    if version != VERSION {
        return Err(invalid(format!("unsupported FPCK version {version}")));
    }
    read_matrix_body(r)
}

/// Magic check + version read, shared by both formats.
fn read_header<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(invalid("bad magic (not a FPCK file)"));
    }
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    Ok(u32::from_le_bytes(b4))
}

/// File convenience wrappers.
pub fn save(m: &PackedMatrix, path: impl AsRef<std::path::Path>) -> io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_packed(m, &mut f)
}

pub fn load(path: impl AsRef<std::path::Path>) -> io::Result<PackedMatrix> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    read_packed(&mut f)
}

fn invalid(msg: impl std::fmt::Display) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

fn write_matrix_body<W: Write>(m: &PackedMatrix, w: &mut W) -> io::Result<()> {
    w.write_all(&(m.bits().bits() as u32).to_le_bytes())?;
    w.write_all(&(m.rows() as u64).to_le_bytes())?;
    w.write_all(&(m.k() as u64).to_le_bytes())?;
    w.write_all(m.bytes())
}

fn read_matrix_body<R: Read>(r: &mut R) -> io::Result<PackedMatrix> {
    // header fields are untrusted: bound them before any size
    // arithmetic (padded_len/packed_bytes would overflow on absurd
    // depths) and never preallocate from a declared size — read up to
    // the declared length and require it was all actually there, so a
    // lying ~24-byte header cannot demand gigabytes
    const DIM_CAP: u64 = 1 << 32;
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let bits = BitWidth::from_u8(u32::from_le_bytes(b4) as u8).map_err(invalid)?;
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let rows = u64::from_le_bytes(b8);
    r.read_exact(&mut b8)?;
    let k = u64::from_le_bytes(b8);
    if rows > DIM_CAP || k > DIM_CAP {
        return Err(invalid(format!("implausible FPCK dims {rows}x{k}")));
    }
    let (rows, k) = (rows as usize, k as usize);
    let expect = rows
        .checked_mul(bits.packed_bytes(k))
        .ok_or_else(|| invalid(format!("implausible FPCK payload for {rows}x{k}")))?;
    let mut data = Vec::new();
    r.take(expect as u64).read_to_end(&mut data)?;
    if data.len() != expect {
        return Err(invalid(format!(
            "truncated FPCK payload: {} of {expect} bytes",
            data.len()
        )));
    }
    PackedMatrix::from_packed(data, rows, k, bits).map_err(invalid)
}

/// Serialize a kernel-layout [`Weights`] value (v2 format).  Supports
/// the packed layouts ([`Weights::Packed`], [`Weights::SwarPacked`]
/// with its `row_sums` side table); other layouts are cheap to rebuild
/// from int8 sources and are rejected with `InvalidInput`.
pub fn write_weights<W: Write>(weights: &Weights, w: &mut W) -> io::Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&WEIGHTS_VERSION.to_le_bytes())?;
    match weights {
        Weights::Packed(m) => {
            w.write_all(&KIND_PACKED.to_le_bytes())?;
            write_matrix_body(m, w)
        }
        Weights::SwarPacked { m, row_sums } => {
            if row_sums.len() != m.rows() {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("{} row sums for a {}-row matrix", row_sums.len(), m.rows()),
                ));
            }
            w.write_all(&KIND_SWAR_PACKED.to_le_bytes())?;
            write_matrix_body(m, w)?;
            for s in row_sums {
                w.write_all(&s.to_le_bytes())?;
            }
            Ok(())
        }
        other => {
            let layout = match other {
                Weights::Ulppack(_) => "ulppack",
                Weights::Naive { .. } => "naive",
                Weights::F32 { .. } => "f32",
                Weights::Packed(_) | Weights::SwarPacked { .. } => unreachable!(),
            };
            Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("unsupported weights layout for serialization: {layout}"),
            ))
        }
    }
}

/// Deserialize a [`Weights`] value: v2 kind-tagged files, plus v1
/// bare-matrix files (read as [`Weights::Packed`]).
pub fn read_weights<R: Read>(r: &mut R) -> io::Result<Weights> {
    match read_header(r)? {
        VERSION => Ok(Weights::Packed(read_matrix_body(r)?)),
        WEIGHTS_VERSION => {
            let mut b4 = [0u8; 4];
            r.read_exact(&mut b4)?;
            match u32::from_le_bytes(b4) {
                KIND_PACKED => Ok(Weights::Packed(read_matrix_body(r)?)),
                KIND_SWAR_PACKED => {
                    let m = read_matrix_body(r)?;
                    let mut row_sums = Vec::with_capacity(m.rows());
                    let mut b8 = [0u8; 8];
                    for _ in 0..m.rows() {
                        r.read_exact(&mut b8)?;
                        row_sums.push(i64::from_le_bytes(b8));
                    }
                    Ok(Weights::SwarPacked { m, row_sums })
                }
                other => Err(invalid(format!("unknown FPCK weights kind {other}"))),
            }
        }
        v => Err(invalid(format!("unsupported FPCK version {v}"))),
    }
}

/// File convenience wrappers for [`Weights`] values.
pub fn save_weights(w: &Weights, path: impl AsRef<std::path::Path>) -> io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_weights(w, &mut f)
}

/// Load a [`Weights`] value saved by [`save_weights`] (or a v1 file).
pub fn load_weights(path: impl AsRef<std::path::Path>) -> io::Result<Weights> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    read_weights(&mut f)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(bits: BitWidth) -> PackedMatrix {
        let (lo, hi) = bits.value_range();
        let k = 100;
        let rows = 7;
        let vals: Vec<i8> = (0..rows * k)
            .map(|i| (lo as i32 + (i as i32 % (hi as i32 - lo as i32 + 1))) as i8)
            .collect();
        PackedMatrix::from_i8(&vals, rows, k, bits).unwrap()
    }

    #[test]
    fn roundtrip_every_width() {
        for bits in [BitWidth::B8, BitWidth::B4, BitWidth::B2, BitWidth::B1] {
            let m = sample(bits);
            let mut buf = Vec::new();
            write_packed(&m, &mut buf).unwrap();
            let back = read_packed(&mut buf.as_slice()).unwrap();
            assert_eq!(back, m, "{bits:?}");
            assert_eq!(back.unpack_all(), m.unpack_all());
        }
    }

    #[test]
    fn file_roundtrip() {
        let m = sample(BitWidth::B4);
        let path = std::env::temp_dir().join("fullpack_test_weights.fpck");
        save(&m, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, m);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn weights_roundtrip_packed_and_swar_every_width() {
        use crate::kernels::{GemvKernel, KernelRegistry, Weights};
        for bits in [BitWidth::B4, BitWidth::B2, BitWidth::B1] {
            // the real SWAR layout, produced by the registered kernel
            // (packed matrix + derived row_sums side table)
            let kern = KernelRegistry::global()
                .get(&format!("fullpack-w{}a8-swar", bits.bits()))
                .expect("swar tier registered");
            let (lo, hi) = bits.value_range();
            let (rows, k) = (7usize, 100usize);
            let vals: Vec<i8> = (0..rows * k)
                .map(|i| (lo as i32 + (i as i32 % (hi as i32 - lo as i32 + 1))) as i8)
                .collect();
            let w = kern.prepare(&vals, rows, k).unwrap();
            let Weights::SwarPacked { m, row_sums } = &w else {
                panic!("swar prepare must produce SwarPacked");
            };
            let mut buf = Vec::new();
            write_weights(&w, &mut buf).unwrap();
            let back = read_weights(&mut buf.as_slice()).unwrap();
            let Weights::SwarPacked { m: m2, row_sums: rs2 } = &back else {
                panic!("{bits:?}: roundtrip lost the SWAR side table");
            };
            assert_eq!(m2, m, "{bits:?}");
            assert_eq!(rs2, row_sums, "{bits:?} row sums must survive exactly");
            // the plain packed kind too
            let p = Weights::Packed(sample(bits));
            let mut buf = Vec::new();
            write_weights(&p, &mut buf).unwrap();
            match (read_weights(&mut buf.as_slice()).unwrap(), &p) {
                (Weights::Packed(a), Weights::Packed(b)) => assert_eq!(&a, b),
                _ => panic!("packed kind changed shape"),
            }
        }
    }

    #[test]
    fn loaded_swar_weights_execute_identically() {
        // save/load then run the SWAR kernel on the loaded weights:
        // bit-identical GEMV output (the side table is live, not
        // re-derived)
        use crate::kernels::{ActVec, GemvKernel, KernelRegistry, Weights};
        let kern = KernelRegistry::global().get("fullpack-w4a8-swar").unwrap();
        let (rows, k) = (5usize, 129usize);
        let vals: Vec<i8> = (0..rows * k).map(|i| ((i % 15) as i8) - 7).collect();
        let w = kern.prepare(&vals, rows, k).unwrap();
        let path = std::env::temp_dir().join("fullpack_test_swar.fpck");
        save_weights(&w, &path).unwrap();
        let loaded = load_weights(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let kp = w.k_padded();
        let a: Vec<i8> = (0..kp).map(|i| ((i % 11) as i8) - 5).collect();
        let mut out_orig = vec![0i32; rows];
        let mut out_loaded = vec![0i32; rows];
        kern.gemv_at(&w, ActVec::I8(&a), &mut out_orig, 0).unwrap();
        kern.gemv_at(&loaded, ActVec::I8(&a), &mut out_loaded, 0).unwrap();
        assert_eq!(out_orig, out_loaded);
        // a v1 file still loads (as the packed kind)
        let m = sample(BitWidth::B4);
        let mut buf = Vec::new();
        write_packed(&m, &mut buf).unwrap();
        assert!(matches!(read_weights(&mut buf.as_slice()).unwrap(), Weights::Packed(_)));
        // non-packable layouts are a loud error
        let f32w = Weights::F32 { data: vec![0.0; 4], rows: 2, k: 2 };
        assert!(write_weights(&f32w, &mut Vec::new()).is_err());
    }

    #[test]
    fn corrupt_weights_rejected() {
        use crate::kernels::Weights;
        let w = Weights::SwarPacked {
            m: sample(BitWidth::B2),
            row_sums: vec![3; sample(BitWidth::B2).rows()],
        };
        let mut buf = Vec::new();
        write_weights(&w, &mut buf).unwrap();
        // truncated side table
        assert!(read_weights(&mut &buf[..buf.len() - 4]).is_err());
        // unknown kind
        let mut bad = buf.clone();
        bad[8] = 9;
        assert!(read_weights(&mut bad.as_slice()).is_err());
        // bad version
        let mut bad = buf.clone();
        bad[4] = 7;
        assert!(read_weights(&mut bad.as_slice()).is_err());
        // mismatched side-table length is rejected at write time
        let short = Weights::SwarPacked { m: sample(BitWidth::B2), row_sums: vec![1] };
        assert!(write_weights(&short, &mut Vec::new()).is_err());
        // a lying header (absurd dims on a tiny file) errors cleanly
        // instead of attempting a giant allocation
        let mut lying = Vec::new();
        lying.extend_from_slice(b"FPCK");
        lying.extend_from_slice(&1u32.to_le_bytes()); // v1
        lying.extend_from_slice(&8u32.to_le_bytes()); // bits
        lying.extend_from_slice(&(1u64 << 40).to_le_bytes()); // rows
        lying.extend_from_slice(&(1u64 << 20).to_le_bytes()); // k
        assert!(read_packed(&mut lying.as_slice()).is_err());
        assert!(read_weights(&mut lying.as_slice()).is_err());
        // plausible dims but a short payload: truncation error, not a
        // zero-filled matrix
        let mut short_payload = Vec::new();
        short_payload.extend_from_slice(b"FPCK");
        short_payload.extend_from_slice(&1u32.to_le_bytes());
        short_payload.extend_from_slice(&8u32.to_le_bytes());
        short_payload.extend_from_slice(&4u64.to_le_bytes());
        short_payload.extend_from_slice(&4u64.to_le_bytes());
        short_payload.extend_from_slice(&[1, 2, 3]); // 3 of 16 bytes
        assert!(read_packed(&mut short_payload.as_slice()).is_err());
    }

    #[test]
    fn corrupt_inputs_rejected() {
        assert!(read_packed(&mut &b"XXXX"[..]).is_err());
        let m = sample(BitWidth::B2);
        let mut buf = Vec::new();
        write_packed(&m, &mut buf).unwrap();
        // truncated payload
        let cut = buf.len() - 5;
        assert!(read_packed(&mut &buf[..cut]).is_err());
        // wrong version
        buf[4] = 9;
        assert!(read_packed(&mut buf.as_slice()).is_err());
    }
}

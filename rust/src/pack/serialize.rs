//! Packed-weight serialization: store a [`PackedMatrix`] (or a whole
//! kernel-layout [`Weights`] value) to disk and load it back — the
//! deployment path (pack once offline, ship the packed blob, the
//! server never touches unpacked weights).
//!
//! Two wire formats, both little-endian with magic `FPCK`:
//!
//! * **v1** (`write_packed`/`read_packed`): version u32 = 1, bits u32,
//!   rows u64, k u64, packed bytes — a bare [`PackedMatrix`].
//! * **v2** (`write_weights`/`read_weights`): version u32 = 2, kind
//!   u32, then the v1 body, then kind-specific side tables.  Kind 0 is
//!   [`Weights::Packed`]; kind 1 is [`Weights::SwarPacked`] and appends
//!   `rows` i64 row sums — the SWAR tier's bias-correction side table
//!   (DESIGN.md §8), so compiled models whose plans selected a `-swar`
//!   backend survive save/load without re-deriving anything.
//!   `read_weights` also accepts v1 files (as kind 0).

use super::{BitWidth, PackedMatrix, SharedBytes};
use crate::kernels::Weights;
use std::io::{self, Read, Write};
use std::sync::Arc;

const MAGIC: &[u8; 4] = b"FPCK";
const VERSION: u32 = 1;
const WEIGHTS_VERSION: u32 = 2;
const IMAGE_VERSION: u32 = 3;

const KIND_PACKED: u32 = 0;
const KIND_SWAR_PACKED: u32 = 1;

/// Header fields are untrusted: dimensions beyond this are rejected
/// before any size arithmetic (padded_len/packed_bytes would overflow
/// on absurd depths).
const DIM_CAP: u64 = 1 << 32;
/// Tensor-count / name-length sanity caps for v3 images.
const COUNT_CAP: u32 = 1 << 20;
const NAME_CAP: u32 = 4096;

/// Serialize to any writer (v1: a bare [`PackedMatrix`]).
pub fn write_packed<W: Write>(m: &PackedMatrix, w: &mut W) -> io::Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    write_matrix_body(m, w)
}

/// Deserialize from any reader (v1 files only — [`read_weights`]
/// accepts both formats).
pub fn read_packed<R: Read>(r: &mut R) -> io::Result<PackedMatrix> {
    let version = read_header(r)?;
    if version != VERSION {
        return Err(invalid(format!("unsupported FPCK version {version}")));
    }
    read_matrix_body(r)
}

/// Magic check + version read, shared by both formats.
fn read_header<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(invalid("bad magic (not a FPCK file)"));
    }
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    Ok(u32::from_le_bytes(b4))
}

/// File convenience wrappers.
pub fn save(m: &PackedMatrix, path: impl AsRef<std::path::Path>) -> io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_packed(m, &mut f)
}

pub fn load(path: impl AsRef<std::path::Path>) -> io::Result<PackedMatrix> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let m = read_packed(&mut f)?;
    require_eof(&mut f)?;
    Ok(m)
}

/// A file must end exactly where its payload does.  The stream readers
/// (`read_packed`/`read_weights`) deliberately stop at the payload edge
/// so records can be concatenated in one stream, but a *file* with
/// bytes past the payload is corrupt (doubled payload, bad re-pack) and
/// loading its prefix would silently serve wrong-provenance weights.
fn require_eof<R: Read>(r: &mut R) -> io::Result<()> {
    let mut probe = [0u8; 1];
    if r.read(&mut probe)? != 0 {
        return Err(invalid("trailing bytes after FPCK payload"));
    }
    Ok(())
}

fn invalid(msg: impl std::fmt::Display) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

fn write_matrix_body<W: Write>(m: &PackedMatrix, w: &mut W) -> io::Result<()> {
    w.write_all(&(m.bits().bits() as u32).to_le_bytes())?;
    w.write_all(&(m.rows() as u64).to_le_bytes())?;
    w.write_all(&(m.k() as u64).to_le_bytes())?;
    w.write_all(m.bytes())
}

fn read_matrix_body<R: Read>(r: &mut R) -> io::Result<PackedMatrix> {
    // never preallocate from a declared size — read up to the declared
    // length and require it was all actually there, so a lying ~24-byte
    // header cannot demand gigabytes
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let bits = BitWidth::from_u8(u32::from_le_bytes(b4) as u8).map_err(invalid)?;
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let rows = u64::from_le_bytes(b8);
    r.read_exact(&mut b8)?;
    let k = u64::from_le_bytes(b8);
    if rows > DIM_CAP || k > DIM_CAP {
        return Err(invalid(format!("implausible FPCK dims {rows}x{k}")));
    }
    let (rows, k) = (rows as usize, k as usize);
    let expect = rows
        .checked_mul(bits.packed_bytes(k))
        .ok_or_else(|| invalid(format!("implausible FPCK payload for {rows}x{k}")))?;
    let mut data = Vec::new();
    r.take(expect as u64).read_to_end(&mut data)?;
    if data.len() != expect {
        return Err(invalid(format!(
            "truncated FPCK payload: {} of {expect} bytes",
            data.len()
        )));
    }
    PackedMatrix::from_packed(data, rows, k, bits).map_err(invalid)
}

/// Serialize a kernel-layout [`Weights`] value (v2 format).  Supports
/// the packed layouts ([`Weights::Packed`], [`Weights::SwarPacked`]
/// with its `row_sums` side table); other layouts are cheap to rebuild
/// from int8 sources and are rejected with `InvalidInput`.
pub fn write_weights<W: Write>(weights: &Weights, w: &mut W) -> io::Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&WEIGHTS_VERSION.to_le_bytes())?;
    match weights {
        Weights::Packed(m) => {
            w.write_all(&KIND_PACKED.to_le_bytes())?;
            write_matrix_body(m, w)
        }
        Weights::SwarPacked { m, row_sums } => {
            if row_sums.len() != m.rows() {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("{} row sums for a {}-row matrix", row_sums.len(), m.rows()),
                ));
            }
            w.write_all(&KIND_SWAR_PACKED.to_le_bytes())?;
            write_matrix_body(m, w)?;
            for s in row_sums {
                w.write_all(&s.to_le_bytes())?;
            }
            Ok(())
        }
        other => {
            let layout = match other {
                Weights::Ulppack(_) => "ulppack",
                Weights::Naive { .. } => "naive",
                Weights::F32 { .. } => "f32",
                Weights::Packed(_) | Weights::SwarPacked { .. } => unreachable!(),
            };
            Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("unsupported weights layout for serialization: {layout}"),
            ))
        }
    }
}

/// Deserialize a [`Weights`] value: v2 kind-tagged files, plus v1
/// bare-matrix files (read as [`Weights::Packed`]).
pub fn read_weights<R: Read>(r: &mut R) -> io::Result<Weights> {
    match read_header(r)? {
        VERSION => Ok(Weights::Packed(read_matrix_body(r)?)),
        WEIGHTS_VERSION => {
            let mut b4 = [0u8; 4];
            r.read_exact(&mut b4)?;
            match u32::from_le_bytes(b4) {
                KIND_PACKED => Ok(Weights::Packed(read_matrix_body(r)?)),
                KIND_SWAR_PACKED => {
                    let m = read_matrix_body(r)?;
                    let mut row_sums = Vec::with_capacity(m.rows());
                    let mut b8 = [0u8; 8];
                    for _ in 0..m.rows() {
                        r.read_exact(&mut b8)?;
                        row_sums.push(i64::from_le_bytes(b8));
                    }
                    Ok(Weights::SwarPacked { m, row_sums })
                }
                other => Err(invalid(format!("unknown FPCK weights kind {other}"))),
            }
        }
        v => Err(invalid(format!("unsupported FPCK version {v}"))),
    }
}

/// File convenience wrappers for [`Weights`] values.
pub fn save_weights(w: &Weights, path: impl AsRef<std::path::Path>) -> io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_weights(w, &mut f)
}

/// Load a [`Weights`] value saved by [`save_weights`] (or a v1 file).
pub fn load_weights(path: impl AsRef<std::path::Path>) -> io::Result<Weights> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let w = read_weights(&mut f)?;
    require_eof(&mut f)?;
    Ok(w)
}

// ---------------------------------------------------------------------------
// v3: multi-tensor weight images (the zero-copy model-store path)
// ---------------------------------------------------------------------------

/// One named tensor inside a [`WeightsImage`]: its header fields plus
/// byte ranges into the shared image buffer (validated at parse time).
#[derive(Debug, Clone)]
struct ImageEntry {
    name: String,
    kind: u32,
    bits: BitWidth,
    rows: usize,
    k: usize,
    payload_off: usize,
    payload_len: usize,
    /// byte offset of the `rows × i64` row-sum side table (SWAR kind).
    sums_off: usize,
}

/// A whole model's weights in one buffer, shared zero-copy.
///
/// v3 wire format: magic `FPCK`, version u32 = 3, count u32, then per
/// tensor: name_len u32, utf-8 name, kind u32, bits u32, rows u64,
/// k u64, the packed payload, and (kind 1) `rows` i64 row sums.  The
/// parser walks the buffer once, validates every range, and requires
/// exact EOF by construction; [`WeightsImage::get`] then hands out
/// [`Weights`] whose [`PackedMatrix`] *borrows* the image allocation
/// through [`SharedBytes`] — loading a model copies its weight bytes
/// zero times (the SWAR side table, `rows × 8` bytes, is decoded per
/// `get` because i64 alignment forbids aliasing it in place).
///
/// The owner is a heap buffer ([`WeightsImage::open`]/`from_bytes`) or,
/// with the zero-dependency `mmap` feature on Linux, a read-only
/// private file mapping — residency then costs page-cache, not heap.
pub struct WeightsImage {
    owner: Arc<dyn AsRef<[u8]> + Send + Sync>,
    entries: Vec<ImageEntry>,
}

impl WeightsImage {
    /// Parse an image from an owned heap buffer.
    pub fn from_bytes(buf: Vec<u8>) -> io::Result<Self> {
        Self::from_owner(Arc::new(buf))
    }

    /// Load an image file.  With the `mmap` feature on Linux the file
    /// is mapped read-only (falling back to a heap read on any mmap
    /// failure); otherwise it is read into a heap buffer.
    pub fn open(path: impl AsRef<std::path::Path>) -> io::Result<Self> {
        #[cfg(all(feature = "mmap", target_os = "linux"))]
        if let Ok(m) = mapped::MappedFile::open(path.as_ref()) {
            return Self::from_owner(Arc::new(m));
        }
        Self::from_bytes(std::fs::read(path)?)
    }

    /// Parse from any shared owner buffer (heap, mmap, test double).
    pub fn from_owner(owner: Arc<dyn AsRef<[u8]> + Send + Sync>) -> io::Result<Self> {
        let mut cur = Cursor { buf: (*owner).as_ref(), pos: 0 };
        if cur.take(4)? != MAGIC {
            return Err(invalid("bad magic (not a FPCK file)"));
        }
        let version = cur.u32()?;
        if version != IMAGE_VERSION {
            return Err(invalid(format!(
                "unsupported FPCK image version {version} (expected {IMAGE_VERSION})"
            )));
        }
        let count = cur.u32()?;
        if count > COUNT_CAP {
            return Err(invalid(format!("implausible FPCK image tensor count {count}")));
        }
        let mut entries: Vec<ImageEntry> = Vec::with_capacity(count.min(1024) as usize);
        for _ in 0..count {
            let name_len = cur.u32()?;
            if name_len == 0 || name_len > NAME_CAP {
                return Err(invalid(format!("implausible FPCK tensor name length {name_len}")));
            }
            let name = std::str::from_utf8(cur.take(name_len as usize)?)
                .map_err(|_| invalid("FPCK tensor name is not utf-8"))?
                .to_string();
            if entries.iter().any(|e| e.name == name) {
                return Err(invalid(format!("duplicate FPCK tensor name {name:?}")));
            }
            let kind = cur.u32()?;
            if kind != KIND_PACKED && kind != KIND_SWAR_PACKED {
                return Err(invalid(format!("unknown FPCK weights kind {kind}")));
            }
            let bits = BitWidth::from_u8(cur.u32()? as u8).map_err(invalid)?;
            let rows = cur.u64()?;
            let k = cur.u64()?;
            if rows > DIM_CAP || k > DIM_CAP {
                return Err(invalid(format!("implausible FPCK dims {rows}x{k}")));
            }
            let (rows, k) = (rows as usize, k as usize);
            let payload_len = rows
                .checked_mul(bits.packed_bytes(k))
                .ok_or_else(|| invalid(format!("implausible FPCK payload for {rows}x{k}")))?;
            let payload_off = cur.pos;
            cur.take(payload_len)?;
            let sums_off = cur.pos;
            if kind == KIND_SWAR_PACKED {
                cur.take(rows.checked_mul(8).ok_or_else(|| invalid("row-sum overflow"))?)?;
            }
            entries.push(ImageEntry { name, kind, bits, rows, k, payload_off, payload_len, sums_off });
        }
        if cur.pos != cur.buf.len() {
            return Err(invalid(format!(
                "trailing bytes after FPCK image payload: {} of {} consumed",
                cur.pos,
                cur.buf.len()
            )));
        }
        drop(cur);
        Ok(WeightsImage { owner, entries })
    }

    /// Resolve one tensor by name as kernel-layout [`Weights`] whose
    /// matrix bytes alias the image buffer (no payload copy).
    pub fn get(&self, name: &str) -> Option<Weights> {
        let e = self.entries.iter().find(|e| e.name == name)?;
        let m = PackedMatrix::from_shared(
            SharedBytes::view(self.owner.clone(), e.payload_off, e.payload_len),
            e.rows,
            e.k,
            e.bits,
        )
        .expect("image entry validated at parse time");
        Some(if e.kind == KIND_PACKED {
            Weights::Packed(m)
        } else {
            let buf: &[u8] = (*self.owner).as_ref();
            let row_sums = (0..e.rows)
                .map(|i| {
                    let off = e.sums_off + i * 8;
                    i64::from_le_bytes(buf[off..off + 8].try_into().unwrap())
                })
                .collect();
            Weights::SwarPacked { m, row_sums }
        })
    }

    /// Tensor names in file order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Size of the whole image buffer in bytes — what residency costs.
    pub fn total_bytes(&self) -> usize {
        (*self.owner).as_ref().len()
    }

    /// The shared buffer behind every tensor view (zero-copy test hook:
    /// pair with [`SharedBytes::is_view_of`]).
    pub fn owner(&self) -> &Arc<dyn AsRef<[u8]> + Send + Sync> {
        &self.owner
    }

    /// `(offset, len)` of a tensor's packed payload within the image.
    pub fn payload_range(&self, name: &str) -> Option<(usize, usize)> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .map(|e| (e.payload_off, e.payload_len))
    }
}

/// Serialize named tensors as one v3 image.  Same layout support as
/// [`write_weights`]: the packed kinds round-trip (including the SWAR
/// row-sum side table); other layouts are rejected with `InvalidInput`.
pub fn write_image<W: Write>(tensors: &[(&str, &Weights)], w: &mut W) -> io::Result<()> {
    if tensors.len() as u64 > COUNT_CAP as u64 {
        return Err(io::Error::new(io::ErrorKind::InvalidInput, "too many tensors for one image"));
    }
    w.write_all(MAGIC)?;
    w.write_all(&IMAGE_VERSION.to_le_bytes())?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    let mut seen: Vec<&str> = Vec::with_capacity(tensors.len());
    for (name, weights) in tensors {
        if name.is_empty() || name.len() as u32 > NAME_CAP {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("bad tensor name length {}", name.len()),
            ));
        }
        if seen.contains(name) {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("duplicate tensor name {name:?}"),
            ));
        }
        seen.push(name);
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        match weights {
            Weights::Packed(m) => {
                w.write_all(&KIND_PACKED.to_le_bytes())?;
                write_matrix_body(m, w)?;
            }
            Weights::SwarPacked { m, row_sums } => {
                if row_sums.len() != m.rows() {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidInput,
                        format!("{} row sums for a {}-row matrix", row_sums.len(), m.rows()),
                    ));
                }
                w.write_all(&KIND_SWAR_PACKED.to_le_bytes())?;
                write_matrix_body(m, w)?;
                for s in row_sums {
                    w.write_all(&s.to_le_bytes())?;
                }
            }
            other => {
                let layout = match other {
                    Weights::Ulppack(_) => "ulppack",
                    Weights::Naive { .. } => "naive",
                    Weights::F32 { .. } => "f32",
                    Weights::Packed(_) | Weights::SwarPacked { .. } => unreachable!(),
                };
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("unsupported weights layout for serialization: {layout}"),
                ));
            }
        }
    }
    Ok(())
}

/// File convenience wrapper for [`write_image`].
pub fn save_image(tensors: &[(&str, &Weights)], path: impl AsRef<std::path::Path>) -> io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_image(tensors, &mut f)?;
    f.flush()
}

/// Bounds-checked walk over an image buffer.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| invalid("truncated FPCK image"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Read-only private file mappings for the `mmap` feature: hand-rolled
/// libc FFI so the default build stays dependency-free.  Linux-only;
/// [`WeightsImage::open`] falls back to a heap read everywhere else.
#[cfg(all(feature = "mmap", target_os = "linux"))]
mod mapped {
    use std::ffi::c_void;
    use std::io;
    use std::os::unix::io::AsRawFd;

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> i32;
    }

    pub struct MappedFile {
        ptr: *mut c_void,
        len: usize,
    }

    // SAFETY: the mapping is PROT_READ and exclusively owned; the only
    // access is through the shared `&[u8]` below.
    unsafe impl Send for MappedFile {}
    unsafe impl Sync for MappedFile {}

    impl MappedFile {
        pub fn open(path: &std::path::Path) -> io::Result<Self> {
            let f = std::fs::File::open(path)?;
            let len = f.metadata()?.len() as usize;
            if len == 0 {
                // mmap(len=0) is EINVAL; an empty file cannot be an image
                return Err(io::Error::new(io::ErrorKind::InvalidData, "empty FPCK image"));
            }
            let ptr = unsafe {
                mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, f.as_raw_fd(), 0)
            };
            if ptr as isize == -1 {
                return Err(io::Error::last_os_error());
            }
            Ok(MappedFile { ptr, len })
        }
    }

    impl AsRef<[u8]> for MappedFile {
        fn as_ref(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
    }

    impl Drop for MappedFile {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(bits: BitWidth) -> PackedMatrix {
        let (lo, hi) = bits.value_range();
        let k = 100;
        let rows = 7;
        let vals: Vec<i8> = (0..rows * k)
            .map(|i| (lo as i32 + (i as i32 % (hi as i32 - lo as i32 + 1))) as i8)
            .collect();
        PackedMatrix::from_i8(&vals, rows, k, bits).unwrap()
    }

    #[test]
    fn roundtrip_every_width() {
        for bits in [BitWidth::B8, BitWidth::B4, BitWidth::B2, BitWidth::B1] {
            let m = sample(bits);
            let mut buf = Vec::new();
            write_packed(&m, &mut buf).unwrap();
            let back = read_packed(&mut buf.as_slice()).unwrap();
            assert_eq!(back, m, "{bits:?}");
            assert_eq!(back.unpack_all(), m.unpack_all());
        }
    }

    #[test]
    fn file_roundtrip() {
        let m = sample(BitWidth::B4);
        let path = std::env::temp_dir().join("fullpack_test_weights.fpck");
        save(&m, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, m);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn weights_roundtrip_packed_and_swar_every_width() {
        use crate::kernels::{GemvKernel, KernelRegistry, Weights};
        for bits in [BitWidth::B4, BitWidth::B2, BitWidth::B1] {
            // the real SWAR layout, produced by the registered kernel
            // (packed matrix + derived row_sums side table)
            let kern = KernelRegistry::global()
                .get(&format!("fullpack-w{}a8-swar", bits.bits()))
                .expect("swar tier registered");
            let (lo, hi) = bits.value_range();
            let (rows, k) = (7usize, 100usize);
            let vals: Vec<i8> = (0..rows * k)
                .map(|i| (lo as i32 + (i as i32 % (hi as i32 - lo as i32 + 1))) as i8)
                .collect();
            let w = kern.prepare(&vals, rows, k).unwrap();
            let Weights::SwarPacked { m, row_sums } = &w else {
                panic!("swar prepare must produce SwarPacked");
            };
            let mut buf = Vec::new();
            write_weights(&w, &mut buf).unwrap();
            let back = read_weights(&mut buf.as_slice()).unwrap();
            let Weights::SwarPacked { m: m2, row_sums: rs2 } = &back else {
                panic!("{bits:?}: roundtrip lost the SWAR side table");
            };
            assert_eq!(m2, m, "{bits:?}");
            assert_eq!(rs2, row_sums, "{bits:?} row sums must survive exactly");
            // the plain packed kind too
            let p = Weights::Packed(sample(bits));
            let mut buf = Vec::new();
            write_weights(&p, &mut buf).unwrap();
            match (read_weights(&mut buf.as_slice()).unwrap(), &p) {
                (Weights::Packed(a), Weights::Packed(b)) => assert_eq!(&a, b),
                _ => panic!("packed kind changed shape"),
            }
        }
    }

    #[test]
    fn loaded_swar_weights_execute_identically() {
        // save/load then run the SWAR kernel on the loaded weights:
        // bit-identical GEMV output (the side table is live, not
        // re-derived)
        use crate::kernels::{ActVec, GemvKernel, KernelRegistry, Weights};
        let kern = KernelRegistry::global().get("fullpack-w4a8-swar").unwrap();
        let (rows, k) = (5usize, 129usize);
        let vals: Vec<i8> = (0..rows * k).map(|i| ((i % 15) as i8) - 7).collect();
        let w = kern.prepare(&vals, rows, k).unwrap();
        let path = std::env::temp_dir().join("fullpack_test_swar.fpck");
        save_weights(&w, &path).unwrap();
        let loaded = load_weights(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let kp = w.k_padded();
        let a: Vec<i8> = (0..kp).map(|i| ((i % 11) as i8) - 5).collect();
        let mut out_orig = vec![0i32; rows];
        let mut out_loaded = vec![0i32; rows];
        kern.gemv_at(&w, ActVec::I8(&a), &mut out_orig, 0).unwrap();
        kern.gemv_at(&loaded, ActVec::I8(&a), &mut out_loaded, 0).unwrap();
        assert_eq!(out_orig, out_loaded);
        // a v1 file still loads (as the packed kind)
        let m = sample(BitWidth::B4);
        let mut buf = Vec::new();
        write_packed(&m, &mut buf).unwrap();
        assert!(matches!(read_weights(&mut buf.as_slice()).unwrap(), Weights::Packed(_)));
        // non-packable layouts are a loud error
        let f32w = Weights::F32 { data: vec![0.0; 4], rows: 2, k: 2 };
        assert!(write_weights(&f32w, &mut Vec::new()).is_err());
    }

    #[test]
    fn corrupt_weights_rejected() {
        use crate::kernels::Weights;
        let w = Weights::SwarPacked {
            m: sample(BitWidth::B2),
            row_sums: vec![3; sample(BitWidth::B2).rows()],
        };
        let mut buf = Vec::new();
        write_weights(&w, &mut buf).unwrap();
        // truncated side table
        assert!(read_weights(&mut &buf[..buf.len() - 4]).is_err());
        // unknown kind
        let mut bad = buf.clone();
        bad[8] = 9;
        assert!(read_weights(&mut bad.as_slice()).is_err());
        // bad version
        let mut bad = buf.clone();
        bad[4] = 7;
        assert!(read_weights(&mut bad.as_slice()).is_err());
        // mismatched side-table length is rejected at write time
        let short = Weights::SwarPacked { m: sample(BitWidth::B2), row_sums: vec![1] };
        assert!(write_weights(&short, &mut Vec::new()).is_err());
        // a lying header (absurd dims on a tiny file) errors cleanly
        // instead of attempting a giant allocation
        let mut lying = Vec::new();
        lying.extend_from_slice(b"FPCK");
        lying.extend_from_slice(&1u32.to_le_bytes()); // v1
        lying.extend_from_slice(&8u32.to_le_bytes()); // bits
        lying.extend_from_slice(&(1u64 << 40).to_le_bytes()); // rows
        lying.extend_from_slice(&(1u64 << 20).to_le_bytes()); // k
        assert!(read_packed(&mut lying.as_slice()).is_err());
        assert!(read_weights(&mut lying.as_slice()).is_err());
        // plausible dims but a short payload: truncation error, not a
        // zero-filled matrix
        let mut short_payload = Vec::new();
        short_payload.extend_from_slice(b"FPCK");
        short_payload.extend_from_slice(&1u32.to_le_bytes());
        short_payload.extend_from_slice(&8u32.to_le_bytes());
        short_payload.extend_from_slice(&4u64.to_le_bytes());
        short_payload.extend_from_slice(&4u64.to_le_bytes());
        short_payload.extend_from_slice(&[1, 2, 3]); // 3 of 16 bytes
        assert!(read_packed(&mut short_payload.as_slice()).is_err());
    }

    #[test]
    fn corrupt_inputs_rejected() {
        assert!(read_packed(&mut &b"XXXX"[..]).is_err());
        let m = sample(BitWidth::B2);
        let mut buf = Vec::new();
        write_packed(&m, &mut buf).unwrap();
        // truncated payload
        let cut = buf.len() - 5;
        assert!(read_packed(&mut &buf[..cut]).is_err());
        // wrong version
        buf[4] = 9;
        assert!(read_packed(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn file_loaders_reject_trailing_garbage() {
        // corruption table for the strict-EOF check on the file
        // variants: (suffix appended to a valid file, loader).  The
        // stream readers stay lenient (concatenated records), but a
        // file must end exactly at the payload.
        let dir = std::env::temp_dir();
        let m = sample(BitWidth::B4);
        let mut v1 = Vec::new();
        write_packed(&m, &mut v1).unwrap();
        let w = Weights::Packed(sample(BitWidth::B2));
        let mut v2 = Vec::new();
        write_weights(&w, &mut v2).unwrap();
        let cases: [(&str, Vec<u8>); 4] = [
            ("one trailing byte", vec![0u8]),
            ("trailing run", vec![0xAB; 64]),
            ("doubled payload (v1)", v1.clone()),
            ("doubled payload (v2)", v2.clone()),
        ];
        for (what, suffix) in &cases {
            let p1 = dir.join(format!("fullpack_eof_v1_{}.fpck", what.len()));
            let mut bytes = v1.clone();
            bytes.extend_from_slice(suffix);
            std::fs::write(&p1, &bytes).unwrap();
            assert!(load(&p1).is_err(), "load must reject: {what}");
            let p2 = dir.join(format!("fullpack_eof_v2_{}.fpck", what.len()));
            let mut bytes = v2.clone();
            bytes.extend_from_slice(suffix);
            std::fs::write(&p2, &bytes).unwrap();
            assert!(load_weights(&p2).is_err(), "load_weights must reject: {what}");
            let _ = std::fs::remove_file(p1);
            let _ = std::fs::remove_file(p2);
        }
        // the exact files still load
        let p = dir.join("fullpack_eof_clean.fpck");
        std::fs::write(&p, &v1).unwrap();
        assert_eq!(load(&p).unwrap(), m);
        std::fs::write(&p, &v2).unwrap();
        assert!(load_weights(&p).is_ok());
        let _ = std::fs::remove_file(p);
    }

    fn swar_sample(bits: BitWidth, rows: usize, k: usize) -> Weights {
        use crate::kernels::{GemvKernel, KernelRegistry};
        let kern = KernelRegistry::global()
            .get(&format!("fullpack-w{}a8-swar", bits.bits()))
            .expect("swar tier registered");
        let (lo, hi) = bits.value_range();
        let vals: Vec<i8> = (0..rows * k)
            .map(|i| (lo as i32 + (i as i32 % (hi as i32 - lo as i32 + 1))) as i8)
            .collect();
        kern.prepare(&vals, rows, k).unwrap()
    }

    #[test]
    fn image_roundtrip_is_zero_copy() {
        let fc = Weights::Packed(sample(BitWidth::B4));
        let swar = swar_sample(BitWidth::B2, 5, 129);
        let b8 = Weights::Packed(sample(BitWidth::B8));
        let mut buf = Vec::new();
        write_image(&[("fc0", &fc), ("cell0.wx", &swar), ("out", &b8)], &mut buf).unwrap();
        let img = WeightsImage::from_bytes(buf).unwrap();
        assert_eq!(img.names(), vec!["fc0", "cell0.wx", "out"]);
        assert_eq!(img.len(), 3);
        // every tensor round-trips bit-exactly...
        let (Some(Weights::Packed(m_fc)), Weights::Packed(m0)) = (img.get("fc0"), &fc) else {
            panic!("fc0 kind changed")
        };
        assert_eq!(&m_fc, m0);
        let (Some(Weights::SwarPacked { m, row_sums }), Weights::SwarPacked { m: m1, row_sums: rs1 }) =
            (img.get("cell0.wx"), &swar)
        else {
            panic!("cell0.wx lost the SWAR side table")
        };
        assert_eq!(&m, m1);
        assert_eq!(&row_sums, rs1);
        // ...and borrows the image allocation: payload bytes alias the
        // one buffer, at the parser's recorded offsets (zero copies)
        let base = (**img.owner()).as_ref().as_ptr() as usize;
        for name in ["fc0", "cell0.wx", "out"] {
            let (off, len) = img.payload_range(name).unwrap();
            let w = img.get(name).unwrap();
            let m = match &w {
                Weights::Packed(m) => m,
                Weights::SwarPacked { m, .. } => m,
                _ => unreachable!(),
            };
            assert!(m.shared().is_view_of(img.owner()), "{name} must alias the image");
            assert_eq!(m.bytes().as_ptr() as usize, base + off, "{name} offset");
            assert_eq!(m.bytes().len(), len, "{name} length");
        }
        assert!(img.get("missing").is_none());
    }

    #[test]
    fn image_file_roundtrip_and_corruption_table() {
        let fc = Weights::Packed(sample(BitWidth::B4));
        let swar = swar_sample(BitWidth::B4, 7, 100);
        let path = std::env::temp_dir().join("fullpack_test_image.fpck");
        save_image(&[("a", &fc), ("b", &swar)], &path).unwrap();
        let img = WeightsImage::open(&path).unwrap();
        assert_eq!(img.names(), vec!["a", "b"]);
        assert!(img.total_bytes() > 0);
        let mut good = std::fs::read(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        // trailing byte → strict-EOF error (exact-consumption parse)
        let mut bad = good.clone();
        bad.push(0);
        assert!(WeightsImage::from_bytes(bad).is_err());
        // truncation anywhere → error
        let cut = good.len() - 3;
        assert!(WeightsImage::from_bytes(good[..cut].to_vec()).is_err());
        // wrong version (a v2 single-weights file is not an image)
        let mut single = Vec::new();
        write_weights(&fc, &mut single).unwrap();
        assert!(WeightsImage::from_bytes(single).is_err());
        // unknown kind: corrupt the first entry's kind field
        // (offset: magic 4 + version 4 + count 4 + name_len 4 + "a" 1)
        let kind_off = 17;
        good[kind_off] = 9;
        assert!(WeightsImage::from_bytes(good).is_err());
        // duplicate names are rejected at write time
        assert!(write_image(&[("a", &fc), ("a", &fc)], &mut Vec::new()).is_err());
        // non-packable layouts too
        let f32w = Weights::F32 { data: vec![0.0; 4], rows: 2, k: 2 };
        assert!(write_image(&[("x", &f32w)], &mut Vec::new()).is_err());
    }

    #[test]
    fn image_swar_weights_execute_identically() {
        use crate::kernels::{ActVec, GemvKernel, KernelRegistry};
        let kern = KernelRegistry::global().get("fullpack-w4a8-swar").unwrap();
        let w = swar_sample(BitWidth::B4, 5, 129);
        let mut buf = Vec::new();
        write_image(&[("m", &w)], &mut buf).unwrap();
        let img = WeightsImage::from_bytes(buf).unwrap();
        let loaded = img.get("m").unwrap();
        let kp = w.k_padded();
        let a: Vec<i8> = (0..kp).map(|i| ((i % 11) as i8) - 5).collect();
        let (mut out_orig, mut out_loaded) = (vec![0i32; 5], vec![0i32; 5]);
        kern.gemv_at(&w, ActVec::I8(&a), &mut out_orig, 0).unwrap();
        kern.gemv_at(&loaded, ActVec::I8(&a), &mut out_loaded, 0).unwrap();
        assert_eq!(out_orig, out_loaded);
    }
}

//! Packed-weight serialization: store a [`PackedMatrix`] to disk and
//! load it back — the deployment path (pack once offline, ship the
//! packed blob, the server never touches unpacked weights).
//!
//! Format (little-endian): magic `FPCK`, version u32, bits u32,
//! rows u64, k u64, then the packed bytes.

use super::{BitWidth, PackedMatrix};
use std::io::{self, Read, Write};

const MAGIC: &[u8; 4] = b"FPCK";
const VERSION: u32 = 1;

/// Serialize to any writer.
pub fn write_packed<W: Write>(m: &PackedMatrix, w: &mut W) -> io::Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(m.bits().bits() as u32).to_le_bytes())?;
    w.write_all(&(m.rows() as u64).to_le_bytes())?;
    w.write_all(&(m.k() as u64).to_le_bytes())?;
    w.write_all(m.bytes())
}

/// Deserialize from any reader.
pub fn read_packed<R: Read>(r: &mut R) -> io::Result<PackedMatrix> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic (not a FPCK file)"));
    }
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let version = u32::from_le_bytes(b4);
    if version != VERSION {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("unsupported FPCK version {version}"),
        ));
    }
    r.read_exact(&mut b4)?;
    let bits = BitWidth::from_u8(u32::from_le_bytes(b4) as u8)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?;
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let rows = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let k = u64::from_le_bytes(b8) as usize;
    let expect = rows * bits.packed_bytes(k);
    let mut data = vec![0u8; expect];
    r.read_exact(&mut data)?;
    PackedMatrix::from_packed(data, rows, k, bits)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))
}

/// File convenience wrappers.
pub fn save(m: &PackedMatrix, path: impl AsRef<std::path::Path>) -> io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_packed(m, &mut f)
}

pub fn load(path: impl AsRef<std::path::Path>) -> io::Result<PackedMatrix> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    read_packed(&mut f)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(bits: BitWidth) -> PackedMatrix {
        let (lo, hi) = bits.value_range();
        let k = 100;
        let rows = 7;
        let vals: Vec<i8> = (0..rows * k)
            .map(|i| (lo as i32 + (i as i32 % (hi as i32 - lo as i32 + 1))) as i8)
            .collect();
        PackedMatrix::from_i8(&vals, rows, k, bits).unwrap()
    }

    #[test]
    fn roundtrip_every_width() {
        for bits in [BitWidth::B8, BitWidth::B4, BitWidth::B2, BitWidth::B1] {
            let m = sample(bits);
            let mut buf = Vec::new();
            write_packed(&m, &mut buf).unwrap();
            let back = read_packed(&mut buf.as_slice()).unwrap();
            assert_eq!(back, m, "{bits:?}");
            assert_eq!(back.unpack_all(), m.unpack_all());
        }
    }

    #[test]
    fn file_roundtrip() {
        let m = sample(BitWidth::B4);
        let path = std::env::temp_dir().join("fullpack_test_weights.fpck");
        save(&m, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, m);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn corrupt_inputs_rejected() {
        assert!(read_packed(&mut &b"XXXX"[..]).is_err());
        let m = sample(BitWidth::B2);
        let mut buf = Vec::new();
        write_packed(&m, &mut buf).unwrap();
        // truncated payload
        let cut = buf.len() - 5;
        assert!(read_packed(&mut &buf[..cut]).is_err());
        // wrong version
        buf[4] = 9;
        assert!(read_packed(&mut buf.as_slice()).is_err());
    }
}

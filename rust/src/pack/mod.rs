//! FullPack packing scheme (paper §3.1, Fig. 2) — the Rust twin of
//! `python/compile/kernels/pack.py`, bit-identical by construction.
//!
//! Layout (normative, DESIGN.md §6): for bit-width `b ∈ {4,2,1}`, lane
//! count `VL = 16`, elements-per-byte `E = 8/b`, group `G = E·VL`:
//! byte `j` of group `g`'s 16-byte block holds original elements
//! `g·G + k·VL + j` for `k = 0..E`, sub-element `k` in bits
//! `[k·b, (k+1)·b)`.  Extraction of sub-vector `k` is the paper's
//! two-shift schedule `ASR(LSL(V, 8-(k+1)b), 8-b)`.
//!
//! Also provides the two comparison layouts: the naive adjacent packing
//! of Alg. 1 and the ULPPACK spacer-lane packing (Won et al., 2022).

mod matrix;
pub mod serialize;
pub use matrix::{PackedMatrix, SharedBytes, UlppackMatrix};

/// Vector lane count: 16 int8 lanes of a 128-bit NEON register.  Kept at
/// 16 on every target so layouts are interchangeable with the Pallas
/// kernels and the AOT artifacts.
pub const VL: usize = 16;

#[derive(Debug, PartialEq, Eq)]
pub enum PackError {
    OutOfRange(i8, i8, i8, u8),
    BadBits(u8),
    BadPackedLen(usize),
}

impl std::fmt::Display for PackError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PackError::OutOfRange(v, lo, hi, b) => {
                write!(f, "value {v} out of range [{lo}, {hi}] for {b}-bit packing")
            }
            PackError::BadBits(b) => write!(f, "unsupported bit-width {b} (expected 8, 4, 2 or 1)"),
            PackError::BadPackedLen(n) => write!(f, "packed length {n} is not a multiple of VL={VL}"),
        }
    }
}

impl std::error::Error for PackError {}

/// Supported element bit-widths.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BitWidth {
    B1 = 1,
    B2 = 2,
    B4 = 4,
    B8 = 8,
}

impl BitWidth {
    pub fn from_u8(b: u8) -> Result<Self, PackError> {
        match b {
            1 => Ok(BitWidth::B1),
            2 => Ok(BitWidth::B2),
            4 => Ok(BitWidth::B4),
            8 => Ok(BitWidth::B8),
            other => Err(PackError::BadBits(other)),
        }
    }

    #[inline]
    pub fn bits(self) -> usize {
        self as usize
    }

    /// Is this a sub-byte width (needs packing)?
    #[inline]
    pub fn is_sub_byte(self) -> bool {
        !matches!(self, BitWidth::B8)
    }

    /// Elements stored per packed byte (1 for 8-bit).
    #[inline]
    pub fn elems_per_byte(self) -> usize {
        8 / self.bits()
    }

    /// Elements covered by one VL-byte packed block (G = E·VL).
    #[inline]
    pub fn group_size(self) -> usize {
        self.elems_per_byte() * VL
    }

    /// Inclusive signed two's-complement value range.
    #[inline]
    pub fn value_range(self) -> (i8, i8) {
        let half = 1i16 << (self.bits() - 1);
        ((-half) as i8, (half - 1) as i8)
    }

    /// Smallest group-aligned length >= n (identity for 8-bit).
    #[inline]
    pub fn padded_len(self, n: usize) -> usize {
        if !self.is_sub_byte() {
            return n;
        }
        let g = self.group_size();
        n.div_ceil(g) * g
    }

    /// Bytes needed to store `n` elements in this width (after padding).
    #[inline]
    pub fn packed_bytes(self, n: usize) -> usize {
        if self.is_sub_byte() {
            self.padded_len(n) / self.elems_per_byte()
        } else {
            n
        }
    }
}

/// A weight/activation datatype pair, e.g. `W4A8` (paper §3.2 kernels).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Variant {
    pub w: BitWidth,
    pub a: BitWidth,
}

impl Variant {
    pub const fn new(w: BitWidth, a: BitWidth) -> Self {
        Variant { w, a }
    }

    /// Parse `"w4a8"` → W4A8.  Case-insensitive.
    pub fn parse(s: &str) -> Result<Self, PackError> {
        let s = s.to_ascii_lowercase();
        let rest = s.strip_prefix('w').ok_or(PackError::BadBits(0))?;
        let (wb, ab) = rest.split_once('a').ok_or(PackError::BadBits(0))?;
        let w = BitWidth::from_u8(wb.parse().map_err(|_| PackError::BadBits(0))?)?;
        let a = BitWidth::from_u8(ab.parse().map_err(|_| PackError::BadBits(0))?)?;
        Ok(Variant::new(w, a))
    }

    /// `"w4a8"`-style lowercase name.
    pub fn name(&self) -> String {
        format!("w{}a{}", self.w.bits(), self.a.bits())
    }

    /// Common padded depth for a logical GEMV depth `k`: both operands
    /// padded to the larger group alignment.
    pub fn padded_depth(&self, k: usize) -> usize {
        self.w.padded_len(k).max(self.a.padded_len(k))
    }

    /// The nine paper kernel variants (§3.2).
    pub const PAPER_VARIANTS: [Variant; 9] = [
        Variant::new(BitWidth::B8, BitWidth::B4),
        Variant::new(BitWidth::B4, BitWidth::B8),
        Variant::new(BitWidth::B4, BitWidth::B4),
        Variant::new(BitWidth::B2, BitWidth::B8),
        Variant::new(BitWidth::B8, BitWidth::B2),
        Variant::new(BitWidth::B2, BitWidth::B2),
        Variant::new(BitWidth::B1, BitWidth::B8),
        Variant::new(BitWidth::B8, BitWidth::B1),
        Variant::new(BitWidth::B1, BitWidth::B1),
    ];
}

impl std::fmt::Display for Variant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

fn check_range(x: &[i8], bits: BitWidth) -> Result<(), PackError> {
    let (lo, hi) = bits.value_range();
    for &v in x {
        if v < lo || v > hi {
            return Err(PackError::OutOfRange(v, lo, hi, bits.bits() as u8));
        }
    }
    Ok(())
}

/// Pack a vector of signed `bits`-wide values into the FullPack layout.
/// Input is zero-padded to a group multiple.  `bits` must be sub-byte.
pub fn pack(x: &[i8], bits: BitWidth) -> Result<Vec<u8>, PackError> {
    if !bits.is_sub_byte() {
        return Err(PackError::BadBits(8));
    }
    check_range(x, bits)?;
    Ok(pack_unchecked(x, bits))
}

/// `pack` without the range check — values are masked; caller guarantees
/// range (the kernels' internal path).
pub fn pack_unchecked(x: &[i8], bits: BitWidth) -> Vec<u8> {
    let mut out = Vec::new();
    pack_into(x, bits, &mut out);
    out
}

/// [`pack_unchecked`] into a caller-owned buffer (cleared and resized) —
/// the allocation-free path for per-call activation packing in the
/// serving hot loop (`kernels::Plan` scratch).
pub fn pack_into(x: &[i8], bits: BitWidth, out: &mut Vec<u8>) {
    let b = bits.bits();
    let e = bits.elems_per_byte();
    let g = bits.group_size();
    let np = bits.padded_len(x.len());
    let mask = ((1u16 << b) - 1) as u8;
    out.clear();
    out.resize(np / e, 0);
    for (i, &v) in x.iter().enumerate() {
        let grp = i / g;
        let within = i % g;
        let k = within / VL;
        let j = within % VL;
        out[grp * VL + j] |= ((v as u8) & mask) << (k * b);
    }
}

/// Zero-pad each row of a row-major `rows × k` matrix to depth `kp` —
/// the layout step before packing a matrix whose depth is not
/// group-aligned (see [`Variant::padded_depth`]).
pub fn pad_rows(w: &[i8], rows: usize, k: usize, kp: usize) -> Vec<i8> {
    debug_assert_eq!(w.len(), rows * k);
    if kp == k {
        return w.to_vec();
    }
    let mut out = vec![0i8; rows * kp];
    for r in 0..rows {
        out[r * kp..r * kp + k].copy_from_slice(&w[r * k..(r + 1) * k]);
    }
    out
}

/// Inverse of [`pack`]: scalar bit-twiddling (the oracle path — kernels
/// use the two-shift vector extraction instead).  Returns `n` elements.
pub fn unpack(packed: &[u8], bits: BitWidth, n: usize) -> Result<Vec<i8>, PackError> {
    if !bits.is_sub_byte() {
        return Err(PackError::BadBits(8));
    }
    if packed.len() % VL != 0 {
        return Err(PackError::BadPackedLen(packed.len()));
    }
    let b = bits.bits();
    let e = bits.elems_per_byte();
    let g = bits.group_size();
    let total = packed.len() * e;
    let mut out = vec![0i8; total];
    for (i, slot) in out.iter_mut().enumerate() {
        let grp = i / g;
        let within = i % g;
        let k = within / VL;
        let j = within % VL;
        let byte = packed[grp * VL + j];
        let v = (byte >> (k * b)) & (((1u16 << b) - 1) as u8);
        // sign extend b-bit value
        let shift = 8 - b;
        *slot = (((v << shift) as i8) >> shift) as i8;
    }
    out.truncate(n.min(total));
    Ok(out)
}

/// Naive adjacent packing (paper Alg. 1): consecutive elements share a
/// byte, first element in the *high* bits.  Same density as FullPack,
/// worse extraction cost — the strawman baseline.
pub fn pack_naive(x: &[i8], bits: BitWidth) -> Result<Vec<u8>, PackError> {
    if !bits.is_sub_byte() {
        return Err(PackError::BadBits(8));
    }
    check_range(x, bits)?;
    let b = bits.bits();
    let e = bits.elems_per_byte();
    let np = x.len().div_ceil(e) * e;
    let mask = ((1u16 << b) - 1) as u8;
    let mut out = vec![0u8; np / e];
    for (i, &v) in x.iter().enumerate() {
        let byte = i / e;
        let k = i % e;
        out[byte] |= ((v as u8) & mask) << ((e - 1 - k) * b);
    }
    Ok(out)
}

/// Unpack the naive layout (for the naive-method baseline kernel tests).
pub fn unpack_naive(packed: &[u8], bits: BitWidth, n: usize) -> Vec<i8> {
    let b = bits.bits();
    let e = bits.elems_per_byte();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let byte = packed[i / e];
        let k = i % e;
        let v = (byte >> ((e - 1 - k) * b)) & (((1u16 << b) - 1) as u8);
        let shift = 8 - b;
        out.push((((v << shift) as i8) >> shift) as i8);
    }
    out
}

/// ULPPACK spacer-lane packing (Won et al., 2022): two *unsigned*
/// (zero-point shifted) b-bit values per 16-bit lane, value 0 at bit 0
/// and value 1 at bit 8, leaving `16 - 2b` guard bits so lane-wise
/// multiply-accumulate cannot overflow into a neighbour.  This is the
/// memory/bandwidth waste FullPack removes: 16 bits carry only `2b`
/// useful bits.
///
/// Values here are the *unsigned* quantized domain `[0, 2^b)` (ULPPACK
/// uses asymmetric quantization with a zero point).
pub fn pack_ulppack(x_unsigned: &[u8], bits: BitWidth) -> Result<Vec<u16>, PackError> {
    if !bits.is_sub_byte() {
        return Err(PackError::BadBits(8));
    }
    let b = bits.bits();
    let limit = 1u16 << b;
    let np = x_unsigned.len().div_ceil(2) * 2;
    let mut out = vec![0u16; np / 2];
    for (i, &v) in x_unsigned.iter().enumerate() {
        if (v as u16) >= limit {
            return Err(PackError::OutOfRange(v as i8, 0, (limit - 1) as i8, b as u8));
        }
        out[i / 2] |= (v as u16) << ((i % 2) * 8);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rngvals(bits: BitWidth, n: usize, seed: u64) -> Vec<i8> {
        let (lo, hi) = bits.value_range();
        crate::util::rng::xorshift_range_vals(lo, hi, n, seed)
    }

    #[test]
    fn fig2_4bit_layout_golden() {
        // Paper Fig. 2: byte j holds elements j (low nibble) and j+16 (high).
        let x: Vec<i8> = (0..32).map(|i| (i % 8) as i8).collect();
        let p = pack(&x, BitWidth::B4).unwrap();
        assert_eq!(p.len(), 16);
        for j in 0..16 {
            assert_eq!((p[j] & 0xF) as i8, x[j], "low nibble {j}");
            assert_eq!((p[j] >> 4) as i8, x[j + 16], "high nibble {j}");
        }
    }

    #[test]
    fn layout_2bit_stride16() {
        let x: Vec<i8> = (0..64).map(|i| (i % 2) as i8).collect();
        let p = pack(&x, BitWidth::B2).unwrap();
        for j in 0..16 {
            for k in 0..4 {
                assert_eq!(((p[j] >> (2 * k)) & 0x3) as i8, x[j + 16 * k]);
            }
        }
    }

    #[test]
    fn layout_1bit_stride16() {
        let x = rngvals(BitWidth::B1, 128, 3);
        let p = pack(&x, BitWidth::B1).unwrap();
        for j in 0..16 {
            for k in 0..8 {
                let bit = (p[j] >> k) & 1;
                assert_eq!(-(bit as i8), x[j + 16 * k]);
            }
        }
    }

    #[test]
    fn roundtrip_all_widths_and_lengths() {
        for bits in [BitWidth::B4, BitWidth::B2, BitWidth::B1] {
            for n in [0usize, 1, 15, 16, 31, 32, 100, 128, 500] {
                let x = rngvals(bits, n, (n as u64) * 7 + bits.bits() as u64);
                let p = pack(&x, bits).unwrap();
                assert_eq!(p.len(), bits.packed_bytes(n));
                let u = unpack(&p, bits, n).unwrap();
                assert_eq!(u, x, "bits={bits:?} n={n}");
            }
        }
    }

    #[test]
    fn out_of_range_rejected() {
        assert!(pack(&[8], BitWidth::B4).is_err());
        assert!(pack(&[-9], BitWidth::B4).is_err());
        assert!(pack(&[1], BitWidth::B1).is_err());
        assert!(pack(&[0], BitWidth::B8).is_err()); // 8-bit never packs
    }

    #[test]
    fn value_ranges() {
        assert_eq!(BitWidth::B8.value_range(), (-128, 127));
        assert_eq!(BitWidth::B4.value_range(), (-8, 7));
        assert_eq!(BitWidth::B2.value_range(), (-2, 1));
        assert_eq!(BitWidth::B1.value_range(), (-1, 0));
    }

    #[test]
    fn variant_parse_and_name() {
        let v = Variant::parse("W4A8").unwrap();
        assert_eq!(v.w, BitWidth::B4);
        assert_eq!(v.a, BitWidth::B8);
        assert_eq!(v.name(), "w4a8");
        assert!(Variant::parse("w3a3").is_err());
        assert!(Variant::parse("x4a8").is_err());
        assert_eq!(Variant::PAPER_VARIANTS.len(), 9);
    }

    #[test]
    fn naive_same_density_different_layout() {
        let x = rngvals(BitWidth::B4, 64, 11);
        let full = pack(&x, BitWidth::B4).unwrap();
        let naive = pack_naive(&x, BitWidth::B4).unwrap();
        assert_eq!(full.len(), naive.len());
        assert_ne!(full, naive);
        assert_eq!(unpack_naive(&naive, BitWidth::B4, 64), x);
    }

    #[test]
    fn naive_alg1_msb_first() {
        // Alg. 1: W0 = (W[i] >> 4) << 4 — element 0 in the high nibble.
        let p = pack_naive(&[3, 5], BitWidth::B4).unwrap();
        assert_eq!(p[0] >> 4, 3);
        assert_eq!(p[0] & 0xF, 5);
    }

    #[test]
    fn ulppack_wastes_spacer_bits() {
        let x: Vec<u8> = (0..64).map(|i| (i % 4) as u8).collect();
        let ulp = pack_ulppack(&x, BitWidth::B2).unwrap();
        // 2 values per u16 lane: 64 bytes for 64 values...
        assert_eq!(ulp.len() * 2, 64);
        // ...vs FullPack's 16 bytes for the same 64 2-bit values.
        let signed: Vec<i8> = x.iter().map(|&v| (v as i8) - 2).collect();
        assert_eq!(pack(&signed, BitWidth::B2).unwrap().len(), 16);
        assert!(pack_ulppack(&[4], BitWidth::B2).is_err());
    }

    #[test]
    fn padding_is_zero() {
        let p = pack(&[1, -2, 3], BitWidth::B4).unwrap();
        let full = unpack(&p, BitWidth::B4, 32).unwrap();
        assert_eq!(&full[..3], &[1, -2, 3]);
        assert!(full[3..].iter().all(|&v| v == 0));
    }
}

//! Admission-controlled scheduler: per-model admission queues, a
//! cost-model flush policy, EDF dequeue, and typed load shedding —
//! the continuous-batching core that replaced the single global
//! deadline batcher (DESIGN.md §12).
//!
//! The scheduler is a **pure state machine**: every method takes an
//! explicit `now_ns` timestamp instead of reading a clock.  The live
//! [`super::Engine`] drives it with `Instant`-derived nanoseconds; the
//! workload harness's virtual discrete-event loop
//! (`workload::loadgen::run_virtual`) drives the *same code* with
//! virtual-clock nanoseconds — which is what makes the DES a bit-exact
//! mirror of the live admission policy by construction, not by
//! reimplementation.
//!
//! Policy, per model queue:
//!
//! * **admission** — a request joins its model's *forming* batch.  The
//!   batch **seals** (becomes dispatchable) as soon as one of:
//!   - `Full`: the forming batch reached `max_batch`;
//!   - `Budget`: the cost model says one more column no longer fits
//!     the front request's remaining deadline budget — i.e.
//!     `svc(n+1) > slo − waited(front)` (the marginal-latency rule;
//!     `svc` is the modeled batched-dispatch service time, the same
//!     `costmodel` curve behind `gemm_batch_threshold`);
//!   - `Deadline`: the forming batch's front waited `max_wait`
//!     (the legacy flush deadline, now a backstop);
//!   - `Drained`: shutdown seals whatever is forming.
//! * **shedding** — `submit` rejects with a typed [`Rejected`] carrying
//!   a **modeled retry-after** instead of silently dropping: `QueueFull`
//!   when the queue (forming + sealed) is at `max_queue`, `OverBudget`
//!   when the modeled backlog already exceeds the request's SLO budget.
//! * **dequeue** — EDF: among sealed batches the earliest front
//!   deadline (`enq + slo`) dispatches first.  A multi-worker engine
//!   shards models across workers (`model_id % workers`); a worker
//!   prefers its home shard and steals the global EDF batch only when
//!   its shard is empty (work conservation).  Shard-affinity dispatches
//!   that overtake an earlier-deadline batch elsewhere are surfaced as
//!   **EDF inversions** in [`super::Metrics`].

use std::collections::{HashMap, VecDeque};
use std::time::Duration;

use super::request::{Rejected, ShedReason};

/// Scheduling policy knobs (the `"scheduler"` section of engine JSON).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedulerConfig {
    /// seal a forming batch as soon as this many requests joined it
    pub max_batch: usize,
    /// seal a non-empty forming batch after this long (backstop)
    pub max_wait: Duration,
    /// per-model admission bound (forming + sealed, not yet dispatched);
    /// beyond it `submit` sheds with [`ShedReason::QueueFull`]
    pub max_queue: usize,
    /// per-request latency budget: the EDF deadline (`enq + slo`), the
    /// remaining-budget term of the marginal-latency seal rule, and the
    /// over-budget shed threshold
    pub slo: Duration,
    /// enable the cost-model marginal-latency seal (`Budget` flushes);
    /// off, the scheduler degrades to full/deadline batching
    pub cost_flush: bool,
    /// enable admission-control shedding when the modeled backlog
    /// already exceeds `slo` ([`ShedReason::OverBudget`])
    pub shed_over_budget: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            max_queue: 1024,
            slo: Duration::from_millis(50),
            cost_flush: true,
            shed_over_budget: true,
        }
    }
}

/// Why a batch sealed (for metrics and tests).
#[derive(Debug, PartialEq, Eq, Clone, Copy)]
pub enum FlushReason {
    /// the forming batch reached `max_batch`
    Full,
    /// the cost model said one more column no longer fits the front
    /// request's remaining deadline budget (marginal-latency rule)
    Budget,
    /// the forming batch's front waited past `max_wait`
    Deadline,
    /// a forced drain (shutdown)
    Drained,
}

/// Fault-injection plan for the scheduler test battery and the
/// workload harness (`rust/tests/scheduler_invariants.rs`): the engine
/// honors `worker_stall` and `slow_models`; `poison_reply_every` is a
/// *client-side* fault (the submitting harness drops every k-th reply
/// receiver, proving the engine never blocks on a dead channel).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// each worker sleeps this long once before its first dispatch
    pub worker_stall: Duration,
    /// extra per-dispatch latency injected for the named models
    pub slow_models: Vec<(String, Duration)>,
    /// harness-side: drop the reply receiver of every k-th request
    pub poison_reply_every: Option<u64>,
}

impl FaultPlan {
    /// Injected extra latency for `model`, if any.
    pub fn slow_for(&self, model: &str) -> Option<Duration> {
        self.slow_models.iter().find(|(n, _)| n == model).map(|(_, d)| *d)
    }

    /// True when the plan injects nothing.
    pub fn is_noop(&self) -> bool {
        self.worker_stall.is_zero()
            && self.slow_models.is_empty()
            && self.poison_reply_every.is_none()
    }
}

/// Modeled service time (ns) of one batched dispatch of `group`
/// requests of a named model — the scheduler's admission brain.
/// Memoized per `(model, group)` inside the scheduler.
pub type CostFn = Box<dyn Fn(&str, usize) -> u64 + Send>;

/// Outcome of a successful [`Scheduler::submit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Admitted {
    /// queue depth (forming + sealed) after admission — the
    /// backpressure/occupancy signal surfaced in `Metrics`
    pub depth: usize,
    /// the admission sealed a batch (workers should be woken broadly)
    pub sealed: bool,
}

/// One dispatchable batch handed to a worker by [`Scheduler::pop`].
#[derive(Debug)]
pub struct Dispatch<T> {
    /// queue index of the model (registration order)
    pub model: usize,
    /// registered model name
    pub name: String,
    /// `(item, enq_ns)` in admission order
    pub entries: Vec<(T, u64)>,
    /// what sealed the batch
    pub reason: FlushReason,
    /// EDF key: the front entry's deadline (`enq + slo`)
    pub front_deadline_ns: u64,
    /// the dispatching worker's home shard was empty and it took the
    /// global EDF batch instead
    pub stolen: bool,
    /// shard affinity dispatched this batch past a strictly
    /// earlier-deadline sealed batch waiting elsewhere
    pub inversion: bool,
}

#[derive(Debug)]
struct Entry<T> {
    item: T,
    enq_ns: u64,
}

#[derive(Debug)]
struct SealedBatch<T> {
    entries: Vec<Entry<T>>,
    reason: FlushReason,
    svc_ns: u64,
    seq: u64,
}

#[derive(Debug)]
struct ModelQueue<T> {
    name: String,
    forming: VecDeque<Entry<T>>,
    sealed: VecDeque<SealedBatch<T>>,
    /// requests inside `sealed` (kept explicit; depth checks are hot)
    sealed_items: usize,
    /// summed modeled service of `sealed` (the backlog estimate)
    sealed_svc_ns: u64,
}

impl<T> ModelQueue<T> {
    fn new(name: &str) -> Self {
        ModelQueue {
            name: name.to_string(),
            forming: VecDeque::new(),
            sealed: VecDeque::new(),
            sealed_items: 0,
            sealed_svc_ns: 0,
        }
    }

    fn depth(&self) -> usize {
        self.forming.len() + self.sealed_items
    }
}

/// The admission scheduler (single consumer lock; callers hold it).
/// Generic over the queued payload so the test battery can drive it
/// with plain values and synthetic clocks/cost curves.
pub struct Scheduler<T> {
    cfg: SchedulerConfig,
    queues: Vec<ModelQueue<T>>,
    index: HashMap<String, usize>,
    cost: CostFn,
    /// per-model `group -> ns` memo of the cost function
    memo: Vec<HashMap<usize, u64>>,
    seal_seq: u64,
}

impl<T> std::fmt::Debug for Scheduler<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scheduler")
            .field("cfg", &self.cfg)
            .field("queues", &self.queues.len())
            .finish()
    }
}

impl<T> Scheduler<T> {
    /// An empty scheduler with the given policy and cost model.
    pub fn new(cfg: SchedulerConfig, cost: CostFn) -> Self {
        Scheduler {
            cfg,
            queues: Vec::new(),
            index: HashMap::new(),
            cost,
            memo: Vec::new(),
            seal_seq: 0,
        }
    }

    /// The policy in force.
    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    /// Register (or re-register) a model queue; returns its id.
    /// Re-registration keeps the queue but invalidates the cost memo
    /// (hot-swapped weights may change the service curve).
    pub fn register(&mut self, name: &str) -> usize {
        if let Some(&i) = self.index.get(name) {
            self.memo[i].clear();
            return i;
        }
        let i = self.queues.len();
        self.queues.push(ModelQueue::new(name));
        self.memo.push(HashMap::new());
        self.index.insert(name.to_string(), i);
        i
    }

    /// Queue id of a registered model.
    pub fn model_id(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    /// Memoized modeled service time of one dispatch of `n` requests —
    /// the same curve the admission decisions consult.  The virtual
    /// workload DES reads it for dispatch service times, so live and
    /// virtual replays share one cost source.
    pub fn modeled_cost_ns(&mut self, model: usize, n: usize) -> u64 {
        self.cost_ns(model, n)
    }

    /// Memoized modeled service time of one dispatch of `n` requests.
    fn cost_ns(&mut self, model: usize, n: usize) -> u64 {
        if let Some(&v) = self.memo[model].get(&n) {
            return v;
        }
        let v = (self.cost)(&self.queues[model].name, n).max(1);
        self.memo[model].insert(n, v);
        v
    }

    fn slo_ns(&self) -> u64 {
        self.cfg.slo.as_nanos() as u64
    }

    fn max_wait_ns(&self) -> u64 {
        self.cfg.max_wait.as_nanos() as u64
    }

    /// Modeled time to drain `depth` queued requests of `model` — the
    /// retry-after hint a `QueueFull` shed carries: the queue drains in
    /// `⌈depth / max_batch⌉` dispatches of modeled service
    /// `svc(min(depth, max_batch))` each.
    fn drain_estimate_us(&mut self, model: usize, depth: usize) -> u64 {
        let per = self.cost_ns(model, depth.min(self.cfg.max_batch).max(1));
        let flushes = depth.div_ceil(self.cfg.max_batch.max(1)) as u64;
        (flushes.saturating_mul(per) / 1_000).max(1)
    }

    /// Seal the forming batch of `model` (no-op when empty).
    fn seal(&mut self, model: usize, reason: FlushReason) {
        let n = self.queues[model].forming.len();
        if n == 0 {
            return;
        }
        let svc = self.cost_ns(model, n);
        self.seal_seq += 1;
        let seq = self.seal_seq;
        let q = &mut self.queues[model];
        let entries: Vec<Entry<T>> = q.forming.drain(..).collect();
        q.sealed_items += n;
        q.sealed_svc_ns += svc;
        q.sealed.push_back(SealedBatch { entries, reason, svc_ns: svc, seq });
    }

    /// Admit one request into its model's forming batch at `now_ns`,
    /// or shed it with a typed, retry-hinted [`Rejected`].
    pub fn submit(&mut self, model: usize, item: T, now_ns: u64) -> Result<Admitted, Rejected> {
        let depth = self.queues[model].depth();
        if depth >= self.cfg.max_queue {
            let retry_after_us = self.drain_estimate_us(model, depth);
            return Err(Rejected {
                model: self.queues[model].name.clone(),
                reason: ShedReason::QueueFull,
                depth,
                retry_after_us,
            });
        }
        if self.cfg.shed_over_budget {
            // modeled completion if admitted: the sealed backlog plus
            // this request's own batch — beyond the SLO it can only
            // miss its deadline, so shed it now with the overshoot as
            // the retry hint
            let own = self.cost_ns(model, self.queues[model].forming.len() + 1);
            let backlog = self.queues[model].sealed_svc_ns.saturating_add(own);
            let slo = self.slo_ns();
            if backlog > slo {
                return Err(Rejected {
                    model: self.queues[model].name.clone(),
                    reason: ShedReason::OverBudget,
                    depth,
                    retry_after_us: ((backlog - slo) / 1_000).max(1),
                });
            }
        }
        self.queues[model].forming.push_back(Entry { item, enq_ns: now_ns });
        let n = self.queues[model].forming.len();
        let sealed = if n >= self.cfg.max_batch {
            self.seal(model, FlushReason::Full);
            true
        } else if self.cfg.cost_flush {
            // the marginal-latency rule: keep the batch open only while
            // one more column still fits the front's remaining budget
            let front_enq = self.queues[model].forming.front().map(|e| e.enq_ns).unwrap_or(now_ns);
            let remaining = self.slo_ns().saturating_sub(now_ns.saturating_sub(front_enq));
            if self.cost_ns(model, n + 1) > remaining {
                self.seal(model, FlushReason::Budget);
                true
            } else {
                false
            }
        } else {
            false
        };
        Ok(Admitted { depth: depth + 1, sealed })
    }

    /// Seal-eligibility time of `model`'s forming batch: the earlier of
    /// its `max_wait` deadline and the instant the marginal-latency
    /// rule expires (`enq + slo − svc(n+1)`, exclusive).
    fn seal_time(&mut self, model: usize) -> Option<u64> {
        let front_enq = self.queues[model].forming.front().map(|e| e.enq_ns)?;
        let n = self.queues[model].forming.len();
        let deadline_t = front_enq.saturating_add(self.max_wait_ns());
        let budget_t = if self.cfg.cost_flush {
            let c = self.cost_ns(model, n + 1);
            front_enq
                .saturating_add(self.slo_ns().saturating_sub(c))
                .saturating_add(1)
        } else {
            u64::MAX
        };
        Some(deadline_t.min(budget_t))
    }

    /// Seal every forming batch whose deadline or budget expired by
    /// `now_ns` (workers call this on every wake-up; the virtual DES on
    /// every event).  `Deadline` takes precedence when both expired.
    pub fn on_tick(&mut self, now_ns: u64) {
        for m in 0..self.queues.len() {
            let Some(front_enq) = self.queues[m].forming.front().map(|e| e.enq_ns) else {
                continue;
            };
            let Some(t) = self.seal_time(m) else { continue };
            if now_ns >= t {
                let reason = if now_ns >= front_enq.saturating_add(self.max_wait_ns()) {
                    FlushReason::Deadline
                } else {
                    FlushReason::Budget
                };
                self.seal(m, reason);
            }
        }
    }

    /// Earliest future seal-eligibility instant over all forming
    /// batches (what a worker may sleep until), `None` when nothing is
    /// forming.  Call after [`Scheduler::on_tick`]: already-due batches
    /// are sealed, so the returned instant is strictly after `now_ns`.
    pub fn next_wakeup(&mut self, now_ns: u64) -> Option<u64> {
        (0..self.queues.len())
            .filter_map(|m| self.seal_time(m))
            .min()
            .map(|t| t.max(now_ns + 1))
    }

    /// Seal every forming batch as `Drained` (shutdown path).
    pub fn seal_all_drained(&mut self) {
        for m in 0..self.queues.len() {
            self.seal(m, FlushReason::Drained);
        }
    }

    /// EDF dequeue: dispatch the sealed batch whose front deadline
    /// (`enq + slo`) is earliest.  With `worker = Some((w, n))` the
    /// worker prefers its home shard (`model % n == w`) and steals the
    /// global EDF batch only when the shard has nothing sealed; an
    /// affinity dispatch past a strictly earlier deadline elsewhere is
    /// flagged as an EDF inversion.
    pub fn pop(&mut self, _now_ns: u64, worker: Option<(usize, usize)>) -> Option<Dispatch<T>> {
        let slo = self.slo_ns();
        let key = |q: &ModelQueue<T>| -> Option<(u64, u64)> {
            q.sealed
                .front()
                .map(|s| (s.entries[0].enq_ns.saturating_add(slo), s.seq))
        };
        let global = (0..self.queues.len())
            .filter_map(|m| key(&self.queues[m]).map(|k| (k, m)))
            .min()?;
        let (chosen, stolen) = match worker {
            Some((w, n)) if n > 1 => {
                let home = (0..self.queues.len())
                    .filter(|m| m % n.max(1) == w % n.max(1))
                    .filter_map(|m| key(&self.queues[m]).map(|k| (k, m)))
                    .min();
                match home {
                    Some(h) => (h, false),
                    None => (global, true),
                }
            }
            _ => (global, false),
        };
        let ((front_deadline_ns, _), model) = chosen;
        let inversion = chosen.0 > global.0;
        let q = &mut self.queues[model];
        let batch = q.sealed.pop_front().expect("chosen queue has a sealed batch");
        q.sealed_items -= batch.entries.len();
        q.sealed_svc_ns = q.sealed_svc_ns.saturating_sub(batch.svc_ns);
        Some(Dispatch {
            model,
            name: q.name.clone(),
            entries: batch.entries.into_iter().map(|e| (e.item, e.enq_ns)).collect(),
            reason: batch.reason,
            front_deadline_ns,
            stolen,
            inversion,
        })
    }

    /// Any sealed batch waiting for a worker?
    pub fn has_sealed(&self) -> bool {
        self.queues.iter().any(|q| !q.sealed.is_empty())
    }

    /// Any forming (unsealed) batch?
    pub fn has_forming(&self) -> bool {
        self.queues.iter().any(|q| !q.forming.is_empty())
    }

    /// Earliest front deadline over sealed batches (test/EDF oracle).
    pub fn min_sealed_deadline(&self) -> Option<u64> {
        let slo = self.slo_ns();
        self.queues
            .iter()
            .filter_map(|q| q.sealed.front().map(|s| s.entries[0].enq_ns.saturating_add(slo)))
            .min()
    }

    /// Per-queue occupancy: `(name, forming, sealed_items)`.
    pub fn depths(&self) -> Vec<(String, usize, usize)> {
        self.queues
            .iter()
            .map(|q| (q.name.clone(), q.forming.len(), q.sealed_items))
            .collect()
    }

    /// Total queued (forming + sealed) requests across all models.
    pub fn len(&self) -> usize {
        self.queues.iter().map(|q| q.depth()).sum()
    }

    /// No queued requests anywhere?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MS: u64 = 1_000_000;

    /// Scheduler with a flat synthetic cost curve: `svc(n) = n · step`.
    fn sched(cfg: SchedulerConfig, step: u64) -> Scheduler<u32> {
        Scheduler::new(cfg, Box::new(move |_, n| n as u64 * step))
    }

    fn cfg(max_batch: usize, wait_ms: u64, max_queue: usize, slo_ms: u64) -> SchedulerConfig {
        SchedulerConfig {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
            max_queue,
            slo: Duration::from_millis(slo_ms),
            cost_flush: true,
            shed_over_budget: false,
        }
    }

    fn drain_items(s: &mut Scheduler<u32>) -> Vec<u32> {
        let mut out = Vec::new();
        s.seal_all_drained();
        while let Some(d) = s.pop(0, None) {
            out.extend(d.entries.into_iter().map(|(i, _)| i));
        }
        out
    }

    #[test]
    fn full_seal_pops_fifo() {
        // svc tiny vs slo: the budget rule never fires; Full does
        let mut s = sched(cfg(4, 1_000, 100, 1_000), 1);
        let m = s.register("ds");
        for i in 0..4u32 {
            let a = s.submit(m, i, 0).unwrap();
            assert_eq!(a.depth as u32, i + 1);
            assert_eq!(a.sealed, i == 3);
        }
        let d = s.pop(0, None).unwrap();
        assert_eq!(d.reason, FlushReason::Full);
        assert_eq!(d.entries.iter().map(|&(i, _)| i).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert!(s.is_empty());
    }

    #[test]
    fn partial_not_dispatchable_before_deadline() {
        let mut s = sched(cfg(4, 10, 100, 1_000), 1);
        let m = s.register("ds");
        s.submit(m, 1, 0).unwrap();
        s.on_tick(5 * MS);
        assert!(s.pop(5 * MS, None).is_none());
        // the wake-up targets the 10ms max-wait backstop (svc is ns-
        // scale, so the budget instant sits just before slo = 1s)
        assert_eq!(s.next_wakeup(5 * MS).unwrap(), 10 * MS);
        s.on_tick(10 * MS);
        let d = s.pop(10 * MS, None).unwrap();
        assert_eq!(d.reason, FlushReason::Deadline);
        assert_eq!(d.entries.len(), 1);
    }

    #[test]
    fn budget_seal_at_admission_matches_cost_curve() {
        // svc(n) = n·2ms, slo = 5ms: svc(n+1) > 5ms first at n = 2
        // (svc(3) = 6ms) — the marginal-latency rule seals exactly
        // there, long before the 1s max-wait backstop
        let mut s = sched(cfg(16, 1_000, 100, 5), 2 * MS);
        let m = s.register("ds");
        assert!(!s.submit(m, 0, 0).unwrap().sealed, "svc(2)=4ms fits the 5ms budget");
        assert!(s.submit(m, 1, 0).unwrap().sealed, "svc(3)=6ms does not");
        let d = s.pop(0, None).unwrap();
        assert_eq!(d.reason, FlushReason::Budget);
        assert_eq!(d.entries.len(), 2);
    }

    #[test]
    fn budget_seal_when_remaining_budget_decays() {
        // svc(2) = 2ms, slo = 5ms: at t=0 one request waits (2 < 5);
        // once 3ms+ elapse the remaining budget drops below svc(2) and
        // the tick seals with Budget, ahead of the 100ms deadline
        let mut s = sched(cfg(16, 100, 100, 5), MS);
        let m = s.register("ds");
        s.submit(m, 7, 0).unwrap();
        let wake = s.next_wakeup(0).unwrap();
        assert_eq!(wake, 3 * MS + 1, "budget expiry: slo − svc(2) = 3ms, exclusive");
        s.on_tick(wake - 1);
        assert!(s.pop(wake - 1, None).is_none());
        s.on_tick(wake);
        let d = s.pop(wake, None).unwrap();
        assert_eq!(d.reason, FlushReason::Budget);
    }

    #[test]
    fn deadline_takes_precedence_when_both_expired() {
        let mut s = sched(cfg(16, 1, 100, 5), MS);
        let m = s.register("ds");
        s.submit(m, 1, 0).unwrap();
        // 10ms later both the 1ms deadline and the budget have expired
        s.on_tick(10 * MS);
        assert_eq!(s.pop(10 * MS, None).unwrap().reason, FlushReason::Deadline);
    }

    #[test]
    fn queue_full_shed_carries_modeled_retry_after() {
        // max_queue 2, max_batch 4, svc(n) = n·1ms: at depth 2 the
        // drain estimate is one flush of svc(2) = 2ms → 2000µs
        let mut s = sched(cfg(4, 1_000, 2, 1_000), MS);
        let m = s.register("ds");
        s.submit(m, 1, 0).unwrap();
        s.submit(m, 2, 0).unwrap();
        let rej = s.submit(m, 3, 0).unwrap_err();
        assert_eq!(rej.reason, ShedReason::QueueFull);
        assert_eq!(rej.depth, 2);
        assert_eq!(rej.retry_after_us, 2_000, "⌈2/4⌉ flush × svc(2)=2ms");
        assert_eq!(rej.model, "ds");
        // the queue is intact and drains in order
        assert_eq!(drain_items(&mut s), vec![1, 2]);
    }

    #[test]
    fn queue_full_retry_after_spans_multiple_flushes() {
        // depth 5, max_batch 2 → ⌈5/2⌉ = 3 flushes × svc(2) = 2ms
        let mut c = cfg(2, 1_000, 5, 1_000);
        c.cost_flush = false; // keep all 5 queued without budget seals
        let mut s = sched(c, MS);
        let m = s.register("ds");
        for i in 0..5 {
            s.submit(m, i, 0).unwrap();
        }
        let rej = s.submit(m, 9, 0).unwrap_err();
        assert_eq!(rej.reason, ShedReason::QueueFull);
        assert_eq!(rej.retry_after_us, 6_000);
    }

    #[test]
    fn over_budget_shed_is_typed_with_overshoot_hint() {
        // svc(1) = 10ms > slo 5ms: the queue can never meet the SLO,
        // admission control sheds up front with the 5ms overshoot
        let mut c = cfg(16, 1_000, 100, 5);
        c.shed_over_budget = true;
        let mut s = sched(c, 10 * MS);
        let m = s.register("ds");
        let rej = s.submit(m, 1, 0).unwrap_err();
        assert_eq!(rej.reason, ShedReason::OverBudget);
        assert_eq!(rej.depth, 0);
        assert_eq!(rej.retry_after_us, 5_000, "modeled overshoot: 10ms − 5ms");
        assert!(s.is_empty());
    }

    #[test]
    fn backpressure_recovers_after_pop() {
        let mut s = sched(cfg(2, 1_000, 2, 1_000), 1);
        let m = s.register("ds");
        s.submit(m, 1, 0).unwrap();
        s.submit(m, 2, 0).unwrap(); // Full seal
        assert!(s.submit(m, 3, 0).is_err());
        s.pop(0, None).unwrap();
        assert!(s.submit(m, 4, 0).is_ok());
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn oversize_burst_seals_in_chunks() {
        let mut s = sched(cfg(2, 1_000, 100, 1_000), 1);
        let m = s.register("ds");
        for i in 0..5u32 {
            s.submit(m, i, 0).unwrap();
        }
        assert_eq!(s.pop(0, None).unwrap().entries.len(), 2);
        assert_eq!(s.pop(0, None).unwrap().entries.len(), 2);
        assert!(s.pop(0, None).is_none(), "remainder still forming");
        s.seal_all_drained();
        let d = s.pop(0, None).unwrap();
        assert_eq!(d.reason, FlushReason::Drained);
        assert_eq!(d.entries.iter().map(|&(i, _)| i).collect::<Vec<_>>(), vec![4]);
    }

    #[test]
    fn edf_orders_across_model_queues() {
        // model "b"'s batch sealed later but its front enqueued earlier
        // → earlier deadline → dispatched first
        let mut s = sched(cfg(2, 1_000, 100, 10), 1);
        let a = s.register("a");
        let b = s.register("b");
        s.submit(b, 100, 0).unwrap();
        s.submit(a, 200, 1 * MS).unwrap();
        s.submit(a, 201, 1 * MS).unwrap(); // seals a (Full)
        s.submit(b, 101, 2 * MS).unwrap(); // seals b (Full)
        let d1 = s.pop(2 * MS, None).unwrap();
        assert_eq!(d1.name, "b", "front deadline 0+slo beats 1ms+slo");
        assert!(!d1.inversion && !d1.stolen);
        assert_eq!(s.pop(2 * MS, None).unwrap().name, "a");
    }

    #[test]
    fn shard_affinity_steals_and_flags_inversions() {
        let mut s = sched(cfg(1, 1_000, 100, 10), 1);
        let a = s.register("a"); // home of worker 0 (a % 2 == 0)
        let b = s.register("b"); // home of worker 1
        s.submit(b, 1, 0).unwrap(); // sealed (max_batch 1), deadline 0+slo
        s.submit(a, 2, 1 * MS).unwrap(); // sealed, deadline 1ms+slo
        // worker 0's home has a sealed batch, but the global EDF batch
        // is b's — dispatching a's is an EDF inversion
        let d = s.pop(1 * MS, Some((0, 2))).unwrap();
        assert_eq!(d.name, "a");
        assert!(d.inversion && !d.stolen);
        // worker 0's home is now empty: it steals b's batch
        let d = s.pop(1 * MS, Some((0, 2))).unwrap();
        assert_eq!(d.name, "b");
        assert!(d.stolen && !d.inversion);
        // a single-worker topology is pure EDF: never inverted
        s.submit(b, 3, 2 * MS).unwrap();
        s.submit(a, 4, 3 * MS).unwrap();
        let d = s.pop(3 * MS, Some((0, 1))).unwrap();
        assert_eq!(d.name, "b");
        assert!(!d.inversion && !d.stolen);
    }

    #[test]
    fn reregistration_clears_cost_memo() {
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Arc;
        let calls = Arc::new(AtomicU64::new(0));
        let c = calls.clone();
        let mut s: Scheduler<u32> = Scheduler::new(
            cfg(4, 1_000, 100, 1_000),
            Box::new(move |_, n| {
                c.fetch_add(1, Ordering::Relaxed);
                n as u64
            }),
        );
        let m = s.register("ds");
        s.submit(m, 1, 0).unwrap();
        s.submit(m, 2, 0).unwrap();
        let before = calls.load(Ordering::Relaxed);
        assert!(before > 0);
        s.submit(m, 3, 0).unwrap(); // memoized lookahead: no new calls
        assert_eq!(calls.load(Ordering::Relaxed), before);
        assert_eq!(s.register("ds"), m, "same queue id");
        s.submit(m, 4, 0).unwrap();
        assert!(calls.load(Ordering::Relaxed) > before, "memo invalidated");
    }

    #[test]
    fn depths_and_occupancy_views() {
        let mut s = sched(cfg(2, 1_000, 100, 1_000), 1);
        let a = s.register("a");
        let b = s.register("b");
        s.submit(a, 1, 0).unwrap();
        s.submit(a, 2, 0).unwrap(); // sealed
        s.submit(a, 3, 0).unwrap(); // forming
        s.submit(b, 4, 0).unwrap(); // forming
        assert_eq!(
            s.depths(),
            vec![("a".to_string(), 1, 2), ("b".to_string(), 1, 0)]
        );
        assert!(s.has_sealed() && s.has_forming());
        assert_eq!(s.len(), 4);
        assert_eq!(s.min_sealed_deadline(), Some(1_000 * MS));
    }
}

//! Serving metrics: engine-wide counters, a fixed-bucket latency
//! histogram, and per-model dispatch/latency counters (the engine
//! serves many registered models; capacity planning needs the split).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;
use std::time::Instant;

/// Log-spaced latency buckets in microseconds (upper bounds).
const BUCKETS_US: [u64; 17] = [
    50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 250_000, 500_000,
    1_000_000, 2_500_000, 10_000_000, u64::MAX,
];

/// Engine-wide metrics; cheap to update from worker threads.
#[derive(Debug)]
pub struct Metrics {
    /// requests accepted
    pub requests: AtomicU64,
    /// requests served to completion
    pub completed: AtomicU64,
    /// requests that failed
    pub errors: AtomicU64,
    /// multi-request GEMM dispatches: flushed batches of ≥2 same-model
    /// requests executed as one batched forward (single
    /// `GemmKernel::gemm` call per FC layer)
    pub batched_dispatches: AtomicU64,
    /// requests served through a multi-request GEMM dispatch
    pub batched_requests: AtomicU64,
    /// requests served individually (singleton flushes, per-request
    /// errors); `batched_requests + singleton_requests` equals the
    /// total requests handed to workers
    pub singleton_requests: AtomicU64,
    latency_buckets: [AtomicU64; 17],
    latency_sum_us: AtomicU64,
    started: Mutex<Option<Instant>>,
    /// per-model counters, keyed by registered model name
    per_model: Mutex<BTreeMap<String, ModelCounters>>,
}

/// Dispatch/latency counters for one registered model.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ModelCounters {
    /// requests served through a multi-request GEMM dispatch
    pub batched_requests: u64,
    /// multi-request batched dispatches (flushes of ≥2 requests)
    pub batched_dispatches: u64,
    /// requests served individually (singleton flushes, errors)
    pub singleton_requests: u64,
    /// requests that failed
    pub errors: u64,
    /// requests served to completion
    pub completed: u64,
    /// summed end-to-end latency of completed requests
    pub latency_sum_us: u64,
}

impl ModelCounters {
    /// Mean end-to-end latency over this model's completed requests.
    pub fn mean_latency_us(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.latency_sum_us as f64 / self.completed as f64
        }
    }

    /// `(batched_requests, singleton_requests)` for this model.
    pub fn dispatch_counts(&self) -> (u64, u64) {
        (self.batched_requests, self.singleton_requests)
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            batched_dispatches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            singleton_requests: AtomicU64::new(0),
            latency_buckets: Default::default(),
            latency_sum_us: AtomicU64::new(0),
            started: Mutex::new(None),
            per_model: Mutex::new(BTreeMap::new()),
        }
    }
}

impl Metrics {
    /// Record the first-request timestamp (throughput denominator).
    pub fn mark_started(&self) {
        let mut s = self.started.lock().unwrap();
        if s.is_none() {
            *s = Some(Instant::now());
        }
    }

    /// Count one completed request with its end-to-end latency.
    pub fn observe_latency_us(&self, us: u64) {
        self.completed.fetch_add(1, Relaxed);
        self.latency_sum_us.fetch_add(us, Relaxed);
        let idx = BUCKETS_US.iter().position(|&b| us <= b).unwrap_or(BUCKETS_US.len() - 1);
        self.latency_buckets[idx].fetch_add(1, Relaxed);
    }

    fn with_model(&self, model: &str, f: impl FnOnce(&mut ModelCounters)) {
        let mut map = self.per_model.lock().unwrap();
        // steady state takes the allocation-free lookup; the
        // to_string() only happens on a model's first-ever counter
        match map.get_mut(model) {
            Some(m) => f(m),
            None => f(map.entry(model.to_string()).or_default()),
        }
    }

    /// [`Metrics::observe_latency_us`] attributed to a model: updates
    /// the engine-wide histogram *and* the model's completion/latency
    /// counters.
    pub fn observe_latency_for(&self, model: &str, us: u64) {
        self.observe_latency_us(us);
        self.with_model(model, |m| {
            m.completed += 1;
            m.latency_sum_us += us;
        });
    }

    /// Count `n` requests of `model` served individually (engine-wide
    /// and per-model singleton counters).
    pub fn record_singleton(&self, model: &str, n: u64) {
        self.singleton_requests.fetch_add(n, Relaxed);
        self.with_model(model, |m| m.singleton_requests += n);
    }

    /// Count one multi-request batched dispatch of `model` covering
    /// `requests` requests.
    pub fn record_batched_dispatch(&self, model: &str, requests: u64) {
        self.batched_dispatches.fetch_add(1, Relaxed);
        self.batched_requests.fetch_add(requests, Relaxed);
        self.with_model(model, |m| {
            m.batched_dispatches += 1;
            m.batched_requests += requests;
        });
    }

    /// Count `n` failed requests of `model`.
    pub fn record_errors(&self, model: &str, n: u64) {
        self.errors.fetch_add(n, Relaxed);
        self.with_model(model, |m| m.errors += n);
    }

    /// Snapshot of one model's counters (`None` if the engine never
    /// dispatched for that name).
    pub fn model_counters(&self, model: &str) -> Option<ModelCounters> {
        self.per_model.lock().unwrap().get(model).copied()
    }

    /// `(batched_requests, singleton_requests)` for one model.
    pub fn model_dispatch_counts(&self, model: &str) -> (u64, u64) {
        self.model_counters(model).unwrap_or_default().dispatch_counts()
    }

    /// Snapshot of every model's counters, sorted by name.
    pub fn per_model_counters(&self) -> Vec<(String, ModelCounters)> {
        self.per_model
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    }

    /// Approximate quantile from the histogram (upper bound of the
    /// bucket containing the q-th observation).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let total: u64 = self.latency_buckets.iter().map(|b| b.load(Relaxed)).sum();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.latency_buckets.iter().enumerate() {
            seen += b.load(Relaxed);
            if seen >= target {
                return BUCKETS_US[i];
            }
        }
        BUCKETS_US[BUCKETS_US.len() - 1]
    }

    /// Mean end-to-end latency over completed requests.
    pub fn mean_latency_us(&self) -> f64 {
        let n = self.completed.load(Relaxed);
        if n == 0 {
            0.0
        } else {
            self.latency_sum_us.load(Relaxed) as f64 / n as f64
        }
    }

    /// Completed requests per second since the first request.
    pub fn throughput_rps(&self) -> f64 {
        let started = self.started.lock().unwrap();
        match *started {
            Some(t0) => {
                let secs = t0.elapsed().as_secs_f64();
                if secs > 0.0 {
                    self.completed.load(Relaxed) as f64 / secs
                } else {
                    0.0
                }
            }
            None => 0.0,
        }
    }

    /// One-line human-readable snapshot of every counter.
    pub fn summary(&self) -> String {
        let q = |v: u64| {
            if v == u64::MAX {
                ">10s".to_string()
            } else if v >= 1_000_000 {
                format!("{:.1}s", v as f64 / 1e6)
            } else {
                format!("{}us", v)
            }
        };
        let mut s = format!(
            "requests={} completed={} errors={} batched={}/{} singleton={} \
             mean={:.0}us p50<={} p95<={} rps={:.1}",
            self.requests.load(Relaxed),
            self.completed.load(Relaxed),
            self.errors.load(Relaxed),
            self.batched_requests.load(Relaxed),
            self.batched_dispatches.load(Relaxed),
            self.singleton_requests.load(Relaxed),
            self.mean_latency_us(),
            q(self.latency_quantile_us(0.5)),
            q(self.latency_quantile_us(0.95)),
            self.throughput_rps(),
        );
        for (name, m) in self.per_model_counters() {
            s.push_str(&format!(
                " | {name}: batched={}/{} singleton={} errors={} mean={:.0}us",
                m.batched_requests,
                m.batched_dispatches,
                m.singleton_requests,
                m.errors,
                m.mean_latency_us(),
            ));
        }
        s
    }

    /// `(batched_requests, singleton_requests)` — the dispatch-path
    /// split; their sum equals the requests handed to workers.
    pub fn dispatch_counts(&self) -> (u64, u64) {
        (self.batched_requests.load(Relaxed), self.singleton_requests.load(Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_from_histogram() {
        let m = Metrics::default();
        for us in [40, 60, 90, 200, 400, 900, 2_000, 6_000, 20_000, 90_000] {
            m.observe_latency_us(us);
        }
        assert_eq!(m.completed.load(Relaxed), 10);
        let p50 = m.latency_quantile_us(0.5);
        assert!(p50 <= 1_000, "p50 {p50}");
        let p95 = m.latency_quantile_us(0.95);
        assert!(p95 >= 25_000, "p95 {p95}");
        assert!(m.mean_latency_us() > 0.0);
    }

    #[test]
    fn empty_metrics() {
        let m = Metrics::default();
        assert_eq!(m.latency_quantile_us(0.99), 0);
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.throughput_rps(), 0.0);
    }

    #[test]
    fn dispatch_counts_and_summary() {
        let m = Metrics::default();
        m.batched_dispatches.fetch_add(1, Relaxed);
        m.batched_requests.fetch_add(3, Relaxed);
        m.singleton_requests.fetch_add(2, Relaxed);
        assert_eq!(m.dispatch_counts(), (3, 2));
        let s = m.summary();
        assert!(s.contains("batched=3/1"), "{s}");
        assert!(s.contains("singleton=2"), "{s}");
    }

    #[test]
    fn per_model_counters_split_by_name() {
        let m = Metrics::default();
        m.record_batched_dispatch("ds", 3);
        m.record_singleton("ds", 1);
        m.record_singleton("mlp", 2);
        m.record_errors("mlp", 1);
        m.observe_latency_for("ds", 100);
        m.observe_latency_for("ds", 300);
        m.observe_latency_for("mlp", 50);
        // per-model views
        let ds = m.model_counters("ds").unwrap();
        assert_eq!(ds.dispatch_counts(), (3, 1));
        assert_eq!(ds.batched_dispatches, 1);
        assert_eq!(ds.completed, 2);
        assert_eq!(ds.mean_latency_us(), 200.0);
        assert_eq!(m.model_dispatch_counts("mlp"), (0, 2));
        assert_eq!(m.model_counters("mlp").unwrap().errors, 1);
        assert!(m.model_counters("ghost").is_none());
        assert_eq!(m.model_dispatch_counts("ghost"), (0, 0));
        // engine-wide counters aggregate the per-model ones
        assert_eq!(m.dispatch_counts(), (3, 3));
        assert_eq!(m.errors.load(Relaxed), 1);
        assert_eq!(m.completed.load(Relaxed), 3);
        // both models surface in the summary
        let s = m.summary();
        assert!(s.contains("ds: batched=3/1 singleton=1"), "{s}");
        assert!(s.contains("mlp: batched=0/0 singleton=2 errors=1"), "{s}");
        // sorted snapshot
        let names: Vec<String> = m.per_model_counters().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["ds".to_string(), "mlp".to_string()]);
    }

    #[test]
    fn huge_latency_lands_in_last_bucket() {
        let m = Metrics::default();
        m.observe_latency_us(u64::MAX / 2);
        assert_eq!(m.latency_quantile_us(1.0), u64::MAX);
    }
}

//! Serving metrics: engine-wide counters, a fixed-bucket latency
//! histogram, and per-model dispatch/latency counters (the engine
//! serves many registered models; capacity planning needs the split).
//! The admission scheduler (DESIGN.md §12) surfaces its policy here
//! too: flush reasons (including cost-model `Budget` seals), typed
//! shed counts, queue-occupancy high-water marks, dispatch batch
//! sizes, and EDF inversions/steals from the sharded worker pool — all
//! of it reconciled exactly by `workload::report::build_report`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;
use std::time::Instant;

use super::request::ShedReason;
use super::scheduler::FlushReason;

/// Log-spaced latency buckets in microseconds (upper bounds).
pub const BUCKETS_US: [u64; 17] = [
    50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 250_000, 500_000,
    1_000_000, 2_500_000, 10_000_000, u64::MAX,
];

/// Bucket index a latency observation lands in.
fn bucket_index(us: u64) -> usize {
    BUCKETS_US.iter().position(|&b| us <= b).unwrap_or(BUCKETS_US.len() - 1)
}

/// Nearest-rank quantile over bucket counts: the upper bound of the
/// bucket containing the ⌈total·q⌉-th observation.  Resolution is the
/// bucket spacing; reports that need exact percentiles keep the raw
/// samples (`workload::report`) — this is the cheap always-on view.
fn quantile_from_buckets(buckets: &[u64; 17], q: f64) -> u64 {
    let total: u64 = buckets.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = ((total as f64) * q).ceil() as u64;
    let mut seen = 0;
    for (i, &b) in buckets.iter().enumerate() {
        seen += b;
        if seen >= target {
            return BUCKETS_US[i];
        }
    }
    BUCKETS_US[BUCKETS_US.len() - 1]
}

/// Fixed-bucket log-spaced latency histogram ([`BUCKETS_US`]) with
/// count/sum and nearest-rank p50/p95/p99 extraction.  `Copy` so it
/// can live inside the by-value [`ModelCounters`] snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LatencyHistogram {
    buckets: [u64; 17],
    count: u64,
    sum_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: [0; 17], count: 0, sum_us: 0 }
    }
}

impl LatencyHistogram {
    /// Record one latency observation.
    pub fn observe(&mut self, us: u64) {
        self.buckets[bucket_index(us)] += 1;
        self.count += 1;
        self.sum_us += us;
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of recorded latencies (µs).
    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    /// Mean latency over recorded observations (0 when empty).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Nearest-rank quantile (upper bound of the containing bucket).
    pub fn quantile_us(&self, q: f64) -> u64 {
        quantile_from_buckets(&self.buckets, q)
    }

    /// Raw per-bucket counts (aligned with [`BUCKETS_US`]).
    pub fn bucket_counts(&self) -> [u64; 17] {
        self.buckets
    }
}

/// Engine-wide metrics; cheap to update from worker threads.
#[derive(Debug)]
pub struct Metrics {
    /// requests accepted
    pub requests: AtomicU64,
    /// requests served to completion
    pub completed: AtomicU64,
    /// requests that failed
    pub errors: AtomicU64,
    /// multi-request GEMM dispatches: flushed batches of ≥2 same-model
    /// requests executed as one batched forward (single
    /// `GemmKernel::gemm` call per FC layer)
    pub batched_dispatches: AtomicU64,
    /// requests served through a multi-request GEMM dispatch
    pub batched_requests: AtomicU64,
    /// requests served individually (singleton flushes, per-request
    /// errors); `batched_requests + singleton_requests` equals the
    /// total requests handed to workers
    pub singleton_requests: AtomicU64,
    latency_buckets: [AtomicU64; 17],
    latency_sum_us: AtomicU64,
    /// batch flushes whose trigger was the batch filling up
    pub flushes_full: AtomicU64,
    /// batch flushes sealed by the cost model's marginal-latency rule
    /// (one more column would no longer fit the front request's
    /// remaining SLO budget)
    pub flushes_budget: AtomicU64,
    /// batch flushes whose trigger was the max-wait deadline
    pub flushes_deadline: AtomicU64,
    /// forced early flushes (shutdown drain)
    pub flushes_drained: AtomicU64,
    /// requests shed because a model queue was at `max_queue`
    pub sheds_queue_full: AtomicU64,
    /// requests shed because the modeled backlog exceeded the SLO
    pub sheds_over_budget: AtomicU64,
    /// requests shed because the model was registered but not resident
    /// (the store started the load; retry priced at modeled load time)
    pub sheds_cold_model: AtomicU64,
    /// model-store weight loads (cold admissions + pins + swaps)
    pub model_loads: AtomicU64,
    /// model-store LRU evictions under the residency budget
    pub model_evictions: AtomicU64,
    /// model-store atomic hot-swaps (version flips)
    pub model_swaps: AtomicU64,
    /// shard-affinity dispatches that overtook a strictly
    /// earlier-deadline sealed batch waiting on another queue
    pub edf_inversions: AtomicU64,
    /// dispatches a worker took from outside its home shard (its own
    /// shard had nothing sealed)
    pub stolen_dispatches: AtomicU64,
    /// high-water mark of per-model queue depth observed at admission
    pub max_queue_depth: AtomicU64,
    /// dispatch batch-size histogram: `size -> dispatches`
    dispatch_sizes: Mutex<BTreeMap<u64, u64>>,
    started: Mutex<Option<Instant>>,
    /// per-model counters, keyed by registered model name
    per_model: Mutex<BTreeMap<String, ModelCounters>>,
}

/// Dispatch/latency counters for one registered model.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ModelCounters {
    /// requests served through a multi-request GEMM dispatch
    pub batched_requests: u64,
    /// multi-request batched dispatches (flushes of ≥2 requests)
    pub batched_dispatches: u64,
    /// requests served individually (singleton flushes, errors)
    pub singleton_requests: u64,
    /// requests that failed
    pub errors: u64,
    /// requests served to completion
    pub completed: u64,
    /// summed end-to-end latency of completed requests
    pub latency_sum_us: u64,
    /// per-model latency histogram (p50/p95/p99 via
    /// [`LatencyHistogram::quantile_us`])
    pub latency: LatencyHistogram,
    /// requests shed from this model's queue at `max_queue`
    pub sheds_queue_full: u64,
    /// requests shed because this model's modeled backlog broke SLO
    pub sheds_over_budget: u64,
    /// requests shed because this model was cold (not resident)
    pub sheds_cold_model: u64,
    /// high-water queue depth observed at admission
    pub max_queue_depth: u64,
    /// times the store loaded this model's weights into residency
    pub loads: u64,
    /// times the store evicted this model under the byte budget
    pub evictions: u64,
    /// store version of this model's weights (0 = never swapped or
    /// not store-managed; starts at 1 on registration, +1 per swap)
    pub version: u64,
}

impl ModelCounters {
    /// Mean end-to-end latency over this model's completed requests.
    pub fn mean_latency_us(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.latency_sum_us as f64 / self.completed as f64
        }
    }

    /// `(batched_requests, singleton_requests)` for this model.
    pub fn dispatch_counts(&self) -> (u64, u64) {
        (self.batched_requests, self.singleton_requests)
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            batched_dispatches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            singleton_requests: AtomicU64::new(0),
            latency_buckets: Default::default(),
            latency_sum_us: AtomicU64::new(0),
            flushes_full: AtomicU64::new(0),
            flushes_budget: AtomicU64::new(0),
            flushes_deadline: AtomicU64::new(0),
            flushes_drained: AtomicU64::new(0),
            sheds_queue_full: AtomicU64::new(0),
            sheds_over_budget: AtomicU64::new(0),
            sheds_cold_model: AtomicU64::new(0),
            model_loads: AtomicU64::new(0),
            model_evictions: AtomicU64::new(0),
            model_swaps: AtomicU64::new(0),
            edf_inversions: AtomicU64::new(0),
            stolen_dispatches: AtomicU64::new(0),
            max_queue_depth: AtomicU64::new(0),
            dispatch_sizes: Mutex::new(BTreeMap::new()),
            started: Mutex::new(None),
            per_model: Mutex::new(BTreeMap::new()),
        }
    }
}

impl Metrics {
    /// Record the first-request timestamp (throughput denominator).
    pub fn mark_started(&self) {
        let mut s = self.started.lock().unwrap();
        if s.is_none() {
            *s = Some(Instant::now());
        }
    }

    /// Count one completed request with its end-to-end latency
    /// (engine-wide histogram; the canonical observation entry point).
    pub fn observe_latency(&self, us: u64) {
        self.completed.fetch_add(1, Relaxed);
        self.latency_sum_us.fetch_add(us, Relaxed);
        self.latency_buckets[bucket_index(us)].fetch_add(1, Relaxed);
    }

    /// Alias of [`Metrics::observe_latency`] kept for older call sites.
    pub fn observe_latency_us(&self, us: u64) {
        self.observe_latency(us);
    }

    fn with_model(&self, model: &str, f: impl FnOnce(&mut ModelCounters)) {
        let mut map = self.per_model.lock().unwrap();
        // steady state takes the allocation-free lookup; the
        // to_string() only happens on a model's first-ever counter
        match map.get_mut(model) {
            Some(m) => f(m),
            None => f(map.entry(model.to_string()).or_default()),
        }
    }

    /// [`Metrics::observe_latency`] attributed to a model: updates
    /// the engine-wide histogram *and* the model's completion/latency
    /// counters plus its per-model histogram.
    pub fn observe_latency_for(&self, model: &str, us: u64) {
        self.observe_latency(us);
        self.with_model(model, |m| {
            m.completed += 1;
            m.latency_sum_us += us;
            m.latency.observe(us);
        });
    }

    /// Count one batch flush by its trigger ([`FlushReason`]): loadgen
    /// reports attribute tail latency to batching policy with these.
    pub fn record_flush(&self, reason: FlushReason) {
        match reason {
            FlushReason::Full => &self.flushes_full,
            FlushReason::Budget => &self.flushes_budget,
            FlushReason::Deadline => &self.flushes_deadline,
            FlushReason::Drained => &self.flushes_drained,
        }
        .fetch_add(1, Relaxed);
    }

    /// `(full, budget, deadline, drained)` flush counts.
    pub fn flush_counts(&self) -> (u64, u64, u64, u64) {
        (
            self.flushes_full.load(Relaxed),
            self.flushes_budget.load(Relaxed),
            self.flushes_deadline.load(Relaxed),
            self.flushes_drained.load(Relaxed),
        )
    }

    /// Count one typed load shed against `model`.
    pub fn record_shed(&self, model: &str, reason: ShedReason) {
        match reason {
            ShedReason::QueueFull => &self.sheds_queue_full,
            ShedReason::OverBudget => &self.sheds_over_budget,
            ShedReason::ColdModel => &self.sheds_cold_model,
        }
        .fetch_add(1, Relaxed);
        self.with_model(model, |m| match reason {
            ShedReason::QueueFull => m.sheds_queue_full += 1,
            ShedReason::OverBudget => m.sheds_over_budget += 1,
            ShedReason::ColdModel => m.sheds_cold_model += 1,
        });
    }

    /// `(queue_full, over_budget, cold_model)` shed counts.
    pub fn shed_counts(&self) -> (u64, u64, u64) {
        (
            self.sheds_queue_full.load(Relaxed),
            self.sheds_over_budget.load(Relaxed),
            self.sheds_cold_model.load(Relaxed),
        )
    }

    /// Count one model-store weight load of `model` (cold admission,
    /// pin, or swap bringing bytes into residency).
    pub fn record_model_load(&self, model: &str) {
        self.model_loads.fetch_add(1, Relaxed);
        self.with_model(model, |m| m.loads += 1);
    }

    /// Count one LRU eviction of `model` under the residency budget.
    pub fn record_model_eviction(&self, model: &str) {
        self.model_evictions.fetch_add(1, Relaxed);
        self.with_model(model, |m| m.evictions += 1);
    }

    /// Record an atomic hot-swap of `model` to store `version`.
    pub fn record_model_swap(&self, model: &str, version: u64) {
        self.model_swaps.fetch_add(1, Relaxed);
        self.with_model(model, |m| m.version = version);
    }

    /// Surface a model's current store version without counting a swap
    /// (set at registration so reports can reconcile versions even for
    /// never-swapped models).
    pub fn set_model_version(&self, model: &str, version: u64) {
        self.with_model(model, |m| m.version = version);
    }

    /// `(loads, evictions, swaps)` model-store counts.
    pub fn model_store_counts(&self) -> (u64, u64, u64) {
        (
            self.model_loads.load(Relaxed),
            self.model_evictions.load(Relaxed),
            self.model_swaps.load(Relaxed),
        )
    }

    /// Record the queue depth observed when a request of `model` was
    /// admitted (engine-wide and per-model high-water marks — the
    /// backpressure/occupancy signal).
    pub fn observe_queue_depth(&self, model: &str, depth: u64) {
        self.max_queue_depth.fetch_max(depth, Relaxed);
        self.with_model(model, |m| m.max_queue_depth = m.max_queue_depth.max(depth));
    }

    /// Count one dispatch of `size` requests in the batch-size
    /// histogram.
    pub fn record_batch_size(&self, size: u64) {
        *self.dispatch_sizes.lock().unwrap().entry(size).or_insert(0) += 1;
    }

    /// Snapshot of the dispatch batch-size histogram, sorted by size.
    pub fn batch_size_counts(&self) -> Vec<(u64, u64)> {
        self.dispatch_sizes.lock().unwrap().iter().map(|(&k, &v)| (k, v)).collect()
    }

    /// Count `n` requests of `model` served individually (engine-wide
    /// and per-model singleton counters).
    pub fn record_singleton(&self, model: &str, n: u64) {
        self.singleton_requests.fetch_add(n, Relaxed);
        self.with_model(model, |m| m.singleton_requests += n);
    }

    /// Count one multi-request batched dispatch of `model` covering
    /// `requests` requests.
    pub fn record_batched_dispatch(&self, model: &str, requests: u64) {
        self.batched_dispatches.fetch_add(1, Relaxed);
        self.batched_requests.fetch_add(requests, Relaxed);
        self.with_model(model, |m| {
            m.batched_dispatches += 1;
            m.batched_requests += requests;
        });
    }

    /// Count `n` failed requests of `model`.
    pub fn record_errors(&self, model: &str, n: u64) {
        self.errors.fetch_add(n, Relaxed);
        self.with_model(model, |m| m.errors += n);
    }

    /// Snapshot of one model's counters (`None` if the engine never
    /// dispatched for that name).
    pub fn model_counters(&self, model: &str) -> Option<ModelCounters> {
        self.per_model.lock().unwrap().get(model).copied()
    }

    /// `(batched_requests, singleton_requests)` for one model.
    pub fn model_dispatch_counts(&self, model: &str) -> (u64, u64) {
        self.model_counters(model).unwrap_or_default().dispatch_counts()
    }

    /// Snapshot of every model's counters, sorted by name.
    pub fn per_model_counters(&self) -> Vec<(String, ModelCounters)> {
        self.per_model
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    }

    /// Approximate quantile from the engine-wide histogram (upper
    /// bound of the bucket containing the q-th observation).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let mut snap = [0u64; 17];
        for (s, b) in snap.iter_mut().zip(&self.latency_buckets) {
            *s = b.load(Relaxed);
        }
        quantile_from_buckets(&snap, q)
    }

    /// Mean end-to-end latency over completed requests.
    pub fn mean_latency_us(&self) -> f64 {
        let n = self.completed.load(Relaxed);
        if n == 0 {
            0.0
        } else {
            self.latency_sum_us.load(Relaxed) as f64 / n as f64
        }
    }

    /// Completed requests per second since the first request.
    pub fn throughput_rps(&self) -> f64 {
        let started = self.started.lock().unwrap();
        match *started {
            Some(t0) => {
                let secs = t0.elapsed().as_secs_f64();
                if secs > 0.0 {
                    self.completed.load(Relaxed) as f64 / secs
                } else {
                    0.0
                }
            }
            None => 0.0,
        }
    }

    /// One-line human-readable snapshot of every counter.
    pub fn summary(&self) -> String {
        let q = |v: u64| {
            if v == u64::MAX {
                ">10s".to_string()
            } else if v >= 1_000_000 {
                format!("{:.1}s", v as f64 / 1e6)
            } else {
                format!("{}us", v)
            }
        };
        let (ff, fb, fd, fs) = self.flush_counts();
        let (sq, sb, sc) = self.shed_counts();
        let (ml, me, ms) = self.model_store_counts();
        let mut s = format!(
            "requests={} completed={} errors={} batched={}/{} singleton={} \
             flushes=full:{ff}/budget:{fb}/deadline:{fd}/drained:{fs} \
             shed=queue-full:{sq}/over-budget:{sb}/cold-model:{sc} \
             store=loads:{ml}/evictions:{me}/swaps:{ms} \
             qdepth-max={} edf-inv={} stolen={} \
             mean={:.0}us p50<={} p95<={} p99<={} rps={:.1}",
            self.requests.load(Relaxed),
            self.completed.load(Relaxed),
            self.errors.load(Relaxed),
            self.batched_requests.load(Relaxed),
            self.batched_dispatches.load(Relaxed),
            self.singleton_requests.load(Relaxed),
            self.max_queue_depth.load(Relaxed),
            self.edf_inversions.load(Relaxed),
            self.stolen_dispatches.load(Relaxed),
            self.mean_latency_us(),
            q(self.latency_quantile_us(0.5)),
            q(self.latency_quantile_us(0.95)),
            q(self.latency_quantile_us(0.99)),
            self.throughput_rps(),
        );
        for (name, m) in self.per_model_counters() {
            s.push_str(&format!(
                " | {name}: batched={}/{} singleton={} errors={} mean={:.0}us p50<={} p99<={}",
                m.batched_requests,
                m.batched_dispatches,
                m.singleton_requests,
                m.errors,
                m.mean_latency_us(),
                q(m.latency.quantile_us(0.5)),
                q(m.latency.quantile_us(0.99)),
            ));
        }
        s
    }

    /// `(batched_requests, singleton_requests)` — the dispatch-path
    /// split; their sum equals the requests handed to workers.
    pub fn dispatch_counts(&self) -> (u64, u64) {
        (self.batched_requests.load(Relaxed), self.singleton_requests.load(Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_from_histogram() {
        let m = Metrics::default();
        for us in [40, 60, 90, 200, 400, 900, 2_000, 6_000, 20_000, 90_000] {
            m.observe_latency_us(us);
        }
        assert_eq!(m.completed.load(Relaxed), 10);
        let p50 = m.latency_quantile_us(0.5);
        assert!(p50 <= 1_000, "p50 {p50}");
        let p95 = m.latency_quantile_us(0.95);
        assert!(p95 >= 25_000, "p95 {p95}");
        assert!(m.mean_latency_us() > 0.0);
    }

    #[test]
    fn empty_metrics() {
        let m = Metrics::default();
        assert_eq!(m.latency_quantile_us(0.99), 0);
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.throughput_rps(), 0.0);
    }

    #[test]
    fn dispatch_counts_and_summary() {
        let m = Metrics::default();
        m.batched_dispatches.fetch_add(1, Relaxed);
        m.batched_requests.fetch_add(3, Relaxed);
        m.singleton_requests.fetch_add(2, Relaxed);
        assert_eq!(m.dispatch_counts(), (3, 2));
        let s = m.summary();
        assert!(s.contains("batched=3/1"), "{s}");
        assert!(s.contains("singleton=2"), "{s}");
    }

    #[test]
    fn per_model_counters_split_by_name() {
        let m = Metrics::default();
        m.record_batched_dispatch("ds", 3);
        m.record_singleton("ds", 1);
        m.record_singleton("mlp", 2);
        m.record_errors("mlp", 1);
        m.observe_latency_for("ds", 100);
        m.observe_latency_for("ds", 300);
        m.observe_latency_for("mlp", 50);
        // per-model views
        let ds = m.model_counters("ds").unwrap();
        assert_eq!(ds.dispatch_counts(), (3, 1));
        assert_eq!(ds.batched_dispatches, 1);
        assert_eq!(ds.completed, 2);
        assert_eq!(ds.mean_latency_us(), 200.0);
        assert_eq!(m.model_dispatch_counts("mlp"), (0, 2));
        assert_eq!(m.model_counters("mlp").unwrap().errors, 1);
        assert!(m.model_counters("ghost").is_none());
        assert_eq!(m.model_dispatch_counts("ghost"), (0, 0));
        // engine-wide counters aggregate the per-model ones
        assert_eq!(m.dispatch_counts(), (3, 3));
        assert_eq!(m.errors.load(Relaxed), 1);
        assert_eq!(m.completed.load(Relaxed), 3);
        // both models surface in the summary
        let s = m.summary();
        assert!(s.contains("ds: batched=3/1 singleton=1"), "{s}");
        assert!(s.contains("mlp: batched=0/0 singleton=2 errors=1"), "{s}");
        // sorted snapshot
        let names: Vec<String> = m.per_model_counters().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["ds".to_string(), "mlp".to_string()]);
    }

    #[test]
    fn huge_latency_lands_in_last_bucket() {
        let m = Metrics::default();
        m.observe_latency_us(u64::MAX / 2);
        assert_eq!(m.latency_quantile_us(1.0), u64::MAX);
    }

    #[test]
    fn histogram_known_distribution_quantiles() {
        // 100 observations: 50 at 80us, 45 at 2ms, 5 at 80ms.  The
        // nearest-rank quantiles land in known buckets: p50 → the 50th
        // obs (80us → bucket ≤100us), p95 → the 95th (2ms → ≤2.5ms),
        // p99 → the 99th (80ms → ≤100ms).
        let mut h = LatencyHistogram::default();
        for _ in 0..50 {
            h.observe(80);
        }
        for _ in 0..45 {
            h.observe(2_000);
        }
        for _ in 0..5 {
            h.observe(80_000);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.quantile_us(0.50), 100);
        assert_eq!(h.quantile_us(0.95), 2_500);
        assert_eq!(h.quantile_us(0.99), 100_000);
        assert_eq!(h.quantile_us(1.0), 100_000);
        let mean = h.mean_us();
        let expect = (50.0 * 80.0 + 45.0 * 2_000.0 + 5.0 * 80_000.0) / 100.0;
        assert!((mean - expect).abs() < 1e-9, "mean {mean}");
        // empty histogram yields zeros
        assert_eq!(LatencyHistogram::default().quantile_us(0.99), 0);
        assert_eq!(LatencyHistogram::default().mean_us(), 0.0);
    }

    #[test]
    fn per_model_histograms_track_quantiles() {
        let m = Metrics::default();
        for us in [100, 100, 100, 9_000] {
            m.observe_latency_for("ds", us);
        }
        m.observe_latency_for("mlp", 40);
        let ds = m.model_counters("ds").unwrap();
        assert_eq!(ds.latency.count(), 4);
        assert_eq!(ds.latency.quantile_us(0.5), 100);
        assert_eq!(ds.latency.quantile_us(0.99), 10_000);
        let mlp = m.model_counters("mlp").unwrap();
        assert_eq!(mlp.latency.quantile_us(0.99), 50);
        // the global histogram aggregates both models
        assert_eq!(m.latency_quantile_us(1.0), 10_000);
        // per-model sums reconcile with the histogram's own view
        assert_eq!(ds.latency.sum_us(), ds.latency_sum_us);
        assert_eq!(ds.latency.count(), ds.completed);
    }

    #[test]
    fn flush_counts_by_reason() {
        let m = Metrics::default();
        m.record_flush(FlushReason::Full);
        m.record_flush(FlushReason::Full);
        m.record_flush(FlushReason::Budget);
        m.record_flush(FlushReason::Deadline);
        m.record_flush(FlushReason::Drained);
        assert_eq!(m.flush_counts(), (2, 1, 1, 1));
        let s = m.summary();
        assert!(s.contains("flushes=full:2/budget:1/deadline:1/drained:1"), "{s}");
    }

    #[test]
    fn typed_sheds_and_occupancy_counters() {
        let m = Metrics::default();
        m.record_shed("ds", ShedReason::QueueFull);
        m.record_shed("ds", ShedReason::QueueFull);
        m.record_shed("mlp", ShedReason::OverBudget);
        m.record_shed("kws", ShedReason::ColdModel);
        m.record_shed("kws", ShedReason::ColdModel);
        m.record_shed("kws", ShedReason::ColdModel);
        assert_eq!(m.shed_counts(), (2, 1, 3));
        let ds = m.model_counters("ds").unwrap();
        assert_eq!((ds.sheds_queue_full, ds.sheds_over_budget), (2, 0));
        let mlp = m.model_counters("mlp").unwrap();
        assert_eq!((mlp.sheds_queue_full, mlp.sheds_over_budget), (0, 1));
        assert_eq!(m.model_counters("kws").unwrap().sheds_cold_model, 3);
        // occupancy keeps the high-water mark, globally and per model
        m.observe_queue_depth("ds", 3);
        m.observe_queue_depth("ds", 7);
        m.observe_queue_depth("ds", 5);
        m.observe_queue_depth("mlp", 2);
        assert_eq!(m.max_queue_depth.load(Relaxed), 7);
        assert_eq!(m.model_counters("ds").unwrap().max_queue_depth, 7);
        assert_eq!(m.model_counters("mlp").unwrap().max_queue_depth, 2);
        let s = m.summary();
        assert!(s.contains("shed=queue-full:2/over-budget:1/cold-model:3"), "{s}");
        assert!(s.contains("qdepth-max=7"), "{s}");
    }

    #[test]
    fn model_store_counters_and_versions() {
        let m = Metrics::default();
        m.set_model_version("ds", 1);
        m.record_model_load("ds");
        m.record_model_load("ds");
        m.record_model_eviction("ds");
        m.record_model_swap("ds", 2);
        m.record_model_load("mlp");
        assert_eq!(m.model_store_counts(), (3, 1, 1));
        let ds = m.model_counters("ds").unwrap();
        assert_eq!((ds.loads, ds.evictions, ds.version), (2, 1, 2));
        let mlp = m.model_counters("mlp").unwrap();
        assert_eq!((mlp.loads, mlp.evictions, mlp.version), (1, 0, 0));
        let s = m.summary();
        assert!(s.contains("store=loads:3/evictions:1/swaps:1"), "{s}");
    }

    #[test]
    fn batch_size_histogram_counts_dispatches() {
        let m = Metrics::default();
        m.record_batch_size(1);
        m.record_batch_size(4);
        m.record_batch_size(4);
        m.record_batch_size(2);
        assert_eq!(m.batch_size_counts(), vec![(1, 1), (2, 1), (4, 2)]);
    }
}

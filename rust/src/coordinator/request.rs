//! Request/response types for the serving engine.

use std::time::Instant;

/// Unique request id.
pub type RequestId = u64;

/// An inference request: a window of audio feature frames for a named
/// model (the DeepSpeech-style workload of §4.6).
#[derive(Debug, Clone)]
pub struct Request {
    /// engine-assigned unique id
    pub id: RequestId,
    /// registered model to run
    pub model: String,
    /// `time_steps × n_input` row-major f32 feature frames
    pub frames: Vec<f32>,
    /// enqueue timestamp (set by the engine)
    pub arrived: Instant,
}

/// Per-layer timing entry: (layer name, nanoseconds).  The name is an
/// owned `String` so runtime-assembled models (graphs parsed from
/// manifests) can report their layers without interning into leaked
/// statics.
pub type LayerTiming = (String, u128);

/// The response: logits plus the per-layer breakdown (paper Fig. 10).
#[derive(Debug, Clone)]
pub struct Response {
    /// id of the request this answers
    pub id: RequestId,
    /// `time_steps × n_output` logits
    pub logits: Vec<f32>,
    /// per-layer timing breakdown (paper Fig. 10)
    pub layer_times: Vec<LayerTiming>,
    /// queueing delay before a worker picked the request up
    pub queue_ns: u128,
    /// total service time (queue + compute)
    pub total_ns: u128,
}

/// What kind of linear-algebra call a layer needs — the router's input
/// (paper §4.6: GEMV single-batch vs GEMM multi-batch).  The router
/// turns one of these into an executable `kernels::Plan`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpDesc {
    /// columns per call (1 = GEMV)
    pub batch: usize,
    /// output rows
    pub z: usize,
    /// input depth
    pub k: usize,
    /// weight/activation quantization of the layer's data
    pub variant: crate::pack::Variant,
}

//! Request/response types for the serving engine.

use std::time::Instant;

/// Unique request id.
pub type RequestId = u64;

/// An inference request: a window of audio feature frames for a named
/// model (the DeepSpeech-style workload of §4.6).
#[derive(Debug, Clone)]
pub struct Request {
    /// engine-assigned unique id
    pub id: RequestId,
    /// registered model to run
    pub model: String,
    /// `time_steps × n_input` row-major f32 feature frames
    pub frames: Vec<f32>,
    /// enqueue timestamp (set by the engine)
    pub arrived: Instant,
}

/// Per-layer timing entry: (layer name, nanoseconds).  The name is an
/// owned `String` so runtime-assembled models (graphs parsed from
/// manifests) can report their layers without interning into leaked
/// statics.
pub type LayerTiming = (String, u128);

/// The response: logits plus the per-layer breakdown (paper Fig. 10).
#[derive(Debug, Clone)]
pub struct Response {
    /// id of the request this answers
    pub id: RequestId,
    /// `time_steps × n_output` logits
    pub logits: Vec<f32>,
    /// per-layer timing breakdown (paper Fig. 10)
    pub layer_times: Vec<LayerTiming>,
    /// queueing delay before a worker picked the request up
    pub queue_ns: u128,
    /// total service time (queue + compute)
    pub total_ns: u128,
}

/// Why the admission scheduler shed a request (DESIGN.md §12, §14).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// the model's queue (forming + sealed) is at `max_queue`
    QueueFull,
    /// the modeled backlog already exceeds the request's SLO budget —
    /// admitting it could only produce a deadline miss
    OverBudget,
    /// the model is registered but not resident — the store started
    /// bringing it in and priced the retry at the modeled load time
    /// (`costmodel::cold_retry_us`, DESIGN.md §14)
    ColdModel,
}

impl ShedReason {
    /// Stable lowercase label (metrics, JSON reports).
    pub fn name(self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue-full",
            ShedReason::OverBudget => "over-budget",
            ShedReason::ColdModel => "cold-model",
        }
    }
}

/// A typed load-shed reply: why the request was rejected, how deep the
/// queue was, and the cost model's estimate of when retrying could
/// succeed (`retry_after_us`) — derived from the same service-time
/// curve that drives batching, so clients get a budget hint instead of
/// a bare "queue full" string.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Rejected {
    /// the model whose queue shed the request
    pub model: String,
    /// why it was shed
    pub reason: ShedReason,
    /// queue depth (forming + sealed) observed at the shed
    pub depth: usize,
    /// modeled microseconds until a retry could be admitted (≥ 1)
    pub retry_after_us: u64,
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "request shed ({}): model {:?} at depth {}, retry after ~{}us",
            self.reason.name(),
            self.model,
            self.depth,
            self.retry_after_us
        )
    }
}

/// Why `Engine::try_submit` refused a request at the front door.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// no model registered under this name
    UnknownModel(String),
    /// the admission scheduler shed it (typed, with a retry hint)
    Rejected(Rejected),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::UnknownModel(name) => write!(f, "unknown model {name:?}"),
            SubmitError::Rejected(r) => write!(f, "{r}"),
        }
    }
}

// std error impls so refusals can travel through `anyhow::Error` (e.g.
// `Engine::infer`) without losing their type: callers recover the shed
// reason and `retry_after_us` via `downcast_ref` instead of string
// matching — the stringly `Engine::submit` path this replaced.
impl std::error::Error for SubmitError {}

impl std::error::Error for Rejected {}

/// What kind of linear-algebra call a layer needs — the router's input
/// (paper §4.6: GEMV single-batch vs GEMM multi-batch).  The router
/// turns one of these into an executable `kernels::Plan`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpDesc {
    /// columns per call (1 = GEMV)
    pub batch: usize,
    /// output rows
    pub z: usize,
    /// input depth
    pub k: usize,
    /// weight/activation quantization of the layer's data
    pub variant: crate::pack::Variant,
}

//! Engine configuration files (JSON) — the deployment-facing config
//! system: workers, admission/scheduling policy, routing policy and
//! the model roster are declared in one file and loaded by `fullpack
//! serve --config engine.json`.
//!
//! Roster entries select model *graphs* by zoo registry name
//! (`models::ModelRegistry` — DESIGN.md §10), so one config can serve a
//! mixed fleet of topologies:
//!
//! ```json
//! {
//!   "workers": 4,
//!   "scheduler": { "max_batch": 16, "max_wait_ms": 2, "max_queue": 1024,
//!                  "slo_ms": 50, "cost_flush": true, "shed_over_budget": true },
//!   "router":  { "gemv_max_batch": 1, "disable_fullpack": false, "prefer_gemm": false },
//!   "models": [
//!     { "name": "deepspeech", "model": "deepspeech", "variant": "w4a8", "size": "full", "seed": 7 },
//!     { "name": "kws", "model": "keyword-spotter", "variant": "w2a8", "size": "tiny" }
//!   ]
//! }
//! ```
//!
//! The pre-scheduler `"batcher"` key (`max_batch`/`max_wait_ms`/
//! `max_queue` only) is still read as a fallback so existing config
//! and mix files keep loading.

use super::{EngineConfig, RouterConfig, SchedulerConfig, StoreConfig};
use crate::models::ModelSize;
use crate::pack::Variant;
use crate::util::error::{anyhow, Result};
use crate::util::json::Json;
use std::time::Duration;

/// One model roster entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// the name requests address the model by
    pub name: String,
    /// zoo registry name of the graph to compile (defaults to the
    /// request name when omitted)
    pub model: String,
    /// weight/activation quantization of the model's layers
    pub variant: Variant,
    /// topology preset (`full` or `tiny`)
    pub size: ModelSize,
    /// deterministic weight-generation seed
    pub seed: u64,
    /// pin this model resident in the store (loaded eagerly, never
    /// evicted under the residency budget — DESIGN.md §14)
    pub pin: bool,
}

/// Parsed config file: engine knobs + model roster.
#[derive(Debug, Clone)]
pub struct FileConfig {
    /// worker/batcher/router knobs
    pub engine: EngineConfig,
    /// models to register at startup
    pub models: Vec<ModelSpec>,
}

/// Engine knobs (`workers`/`scheduler`/`router` keys, with the legacy
/// `batcher` key accepted for the scheduler section) from a parsed
/// JSON node, falling back to [`EngineConfig::default`] per field.
/// Shared by [`FileConfig::parse`] and the workload-mix parser
/// (`workload::mix`), so a mix file embeds the exact same engine
/// schema a `serve --config` file uses.
pub fn engine_from_json(j: &Json) -> EngineConfig {
    let usize_at = |node: &Json, key: &str, default: usize| -> usize {
        node.get(key).and_then(Json::as_usize).unwrap_or(default)
    };
    let bool_at = |node: &Json, key: &str, default: bool| -> bool {
        match node.get(key) {
            Some(Json::Bool(b)) => *b,
            _ => default,
        }
    };
    let defaults = EngineConfig::default();
    let mut engine = EngineConfig {
        workers: usize_at(j, "workers", defaults.workers),
        ..defaults
    };
    if let Some(b) = j.get("scheduler").or_else(|| j.get("batcher")) {
        engine.sched = SchedulerConfig {
            max_batch: usize_at(b, "max_batch", defaults.sched.max_batch),
            max_wait: Duration::from_millis(
                usize_at(b, "max_wait_ms", defaults.sched.max_wait.as_millis() as usize) as u64,
            ),
            max_queue: usize_at(b, "max_queue", defaults.sched.max_queue),
            slo: Duration::from_millis(
                usize_at(b, "slo_ms", defaults.sched.slo.as_millis() as usize) as u64,
            ),
            cost_flush: bool_at(b, "cost_flush", defaults.sched.cost_flush),
            shed_over_budget: bool_at(b, "shed_over_budget", defaults.sched.shed_over_budget),
        };
    }
    if let Some(r) = j.get("router") {
        engine.router = RouterConfig {
            gemv_max_batch: usize_at(r, "gemv_max_batch", defaults.router.gemv_max_batch),
            disable_fullpack: matches!(r.get("disable_fullpack"), Some(Json::Bool(true))),
            prefer_swar: matches!(r.get("prefer_swar"), Some(Json::Bool(true))),
            prefer_gemm: matches!(r.get("prefer_gemm"), Some(Json::Bool(true))),
        };
    }
    if let Some(s) = j.get("store") {
        engine.store = StoreConfig {
            budget_bytes: s.get("budget_bytes").and_then(Json::as_usize).map(|b| b as u64),
        };
    }
    engine
}

/// Serialize engine knobs back to the same JSON schema
/// [`engine_from_json`] reads (deterministic key order — byte-stable
/// output for seeded mix files).
pub fn engine_to_json(e: &EngineConfig) -> String {
    // `store` serializes `{}` for the unbounded default so configs
    // written before the model store parse back to the identical value
    let store = match e.store.budget_bytes {
        Some(b) => format!("{{\"budget_bytes\": {b}}}"),
        None => "{}".to_string(),
    };
    format!(
        "{{\"workers\": {}, \"scheduler\": {{\"max_batch\": {}, \"max_wait_ms\": {}, \"max_queue\": {}, \
         \"slo_ms\": {}, \"cost_flush\": {}, \"shed_over_budget\": {}}}, \
         \"router\": {{\"gemv_max_batch\": {}, \"disable_fullpack\": {}, \"prefer_swar\": {}, \"prefer_gemm\": {}}}, \
         \"store\": {store}}}",
        e.workers,
        e.sched.max_batch,
        e.sched.max_wait.as_millis(),
        e.sched.max_queue,
        e.sched.slo.as_millis(),
        e.sched.cost_flush,
        e.sched.shed_over_budget,
        e.router.gemv_max_batch,
        e.router.disable_fullpack,
        e.router.prefer_swar,
        e.router.prefer_gemm,
    )
}

/// One roster entry from a parsed JSON node (`i` is its index, for
/// error messages).  Shared by [`FileConfig::parse`] and the
/// workload-mix parser.
pub fn model_spec_from_json(m: &Json, i: usize) -> Result<ModelSpec> {
    let name = m
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("models[{i}] missing name"))?
        .to_string();
    let model = m.get("model").and_then(Json::as_str).unwrap_or(&name).to_string();
    let variant = Variant::parse(m.get("variant").and_then(Json::as_str).unwrap_or("w4a8"))
        .map_err(|e| anyhow!("models[{i}] variant: {e}"))?;
    let size_str = m.get("size").and_then(Json::as_str).unwrap_or("full");
    let size = ModelSize::parse(size_str)
        .ok_or_else(|| anyhow!("models[{i}] size {size_str:?} (expected full|tiny)"))?;
    let seed = m.get("seed").and_then(Json::as_usize).unwrap_or(7) as u64;
    let pin = matches!(m.get("pin"), Some(Json::Bool(true)));
    Ok(ModelSpec { name, model, variant, size, seed, pin })
}

/// Serialize one roster entry back to the schema
/// [`model_spec_from_json`] reads (deterministic key order).
pub fn model_spec_to_json(s: &ModelSpec) -> String {
    // `pin` is emitted only when set, keeping pre-store mix files
    // byte-stable through a write/parse/write cycle
    let pin = if s.pin { ", \"pin\": true" } else { "" };
    format!(
        "{{\"name\": \"{}\", \"model\": \"{}\", \"variant\": \"{}\", \"size\": \"{}\", \"seed\": {}{pin}}}",
        s.name,
        s.model,
        s.variant.name(),
        s.size.name(),
        s.seed,
    )
}

impl FileConfig {
    /// Parse a config document (see the module example for the schema).
    pub fn parse(text: &str) -> Result<FileConfig> {
        let j = Json::parse(text).map_err(|e| anyhow!("config JSON: {e}"))?;
        let engine = engine_from_json(&j);
        let mut models = Vec::new();
        if let Some(arr) = j.get("models").and_then(Json::as_arr) {
            for (i, m) in arr.iter().enumerate() {
                models.push(model_spec_from_json(m, i)?);
            }
        }
        Ok(FileConfig { engine, models })
    }

    /// Read and [`FileConfig::parse`] a config file.
    pub fn load(path: &str) -> Result<FileConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading config {path:?}: {e}"))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config_roundtrip() {
        let cfg = FileConfig::parse(
            r#"{
              "workers": 4,
              "scheduler": {"max_batch": 8, "max_wait_ms": 5, "max_queue": 32,
                            "slo_ms": 20, "cost_flush": false, "shed_over_budget": false},
              "router": {"gemv_max_batch": 2, "disable_fullpack": true, "prefer_swar": true,
                         "prefer_gemm": true},
              "store": {"budget_bytes": 8388608},
              "models": [
                {"name": "ds", "model": "deepspeech", "variant": "w2a2", "size": "tiny", "seed": 3, "pin": true},
                {"name": "ds-full", "variant": "w4a8"},
                {"name": "kws", "model": "keyword-spotter", "size": "tiny"}
              ]
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.engine.workers, 4);
        assert_eq!(cfg.engine.sched.max_batch, 8);
        assert_eq!(cfg.engine.sched.max_wait, Duration::from_millis(5));
        assert_eq!(cfg.engine.sched.slo, Duration::from_millis(20));
        assert!(!cfg.engine.sched.cost_flush);
        assert!(!cfg.engine.sched.shed_over_budget);
        assert_eq!(cfg.engine.router.gemv_max_batch, 2);
        assert!(cfg.engine.router.disable_fullpack);
        assert!(cfg.engine.router.prefer_swar);
        assert!(cfg.engine.router.prefer_gemm);
        assert_eq!(cfg.engine.store.budget_bytes, Some(8 << 20));
        assert_eq!(cfg.models.len(), 3);
        assert!(cfg.models[0].pin);
        assert!(!cfg.models[1].pin, "pin defaults to false");
        assert_eq!(cfg.models[0].variant, Variant::parse("w2a2").unwrap());
        assert_eq!(cfg.models[0].size, ModelSize::Tiny);
        assert_eq!(cfg.models[0].model, "deepspeech");
        // omitted `model` defaults to the request name
        assert_eq!(cfg.models[1].model, "ds-full");
        assert_eq!(cfg.models[1].size, ModelSize::Full);
        assert_eq!(cfg.models[1].seed, 7);
        // a non-DeepSpeech zoo graph in the same roster
        assert_eq!(cfg.models[2].model, "keyword-spotter");
    }

    #[test]
    fn defaults_when_sections_missing() {
        let cfg = FileConfig::parse("{}").unwrap();
        assert_eq!(cfg.engine.workers, EngineConfig::default().workers);
        assert_eq!(cfg.engine.sched, SchedulerConfig::default());
        assert!(cfg.models.is_empty());
    }

    #[test]
    fn legacy_batcher_key_still_parses() {
        // pre-scheduler config files name the section "batcher" and
        // carry no SLO knobs: the three shared fields are honored and
        // the new policy knobs take their defaults
        let cfg = FileConfig::parse(
            r#"{"workers": 2, "batcher": {"max_batch": 4, "max_wait_ms": 1, "max_queue": 64}}"#,
        )
        .unwrap();
        assert_eq!(cfg.engine.sched.max_batch, 4);
        assert_eq!(cfg.engine.sched.max_wait, Duration::from_millis(1));
        assert_eq!(cfg.engine.sched.max_queue, 64);
        assert_eq!(cfg.engine.sched.slo, SchedulerConfig::default().slo);
        assert!(cfg.engine.sched.cost_flush);
    }

    #[test]
    fn engine_json_roundtrips_through_parser() {
        let mut e = EngineConfig::default();
        e.workers = 3;
        e.sched.max_batch = 6;
        e.sched.slo = Duration::from_millis(9);
        e.sched.shed_over_budget = false;
        let text = engine_to_json(&e);
        let back = engine_from_json(&Json::parse(&text).unwrap());
        assert_eq!(back, e, "engine_to_json -> engine_from_json is the identity");
        // identity holds with a residency budget set, too
        e.store.budget_bytes = Some(16 << 20);
        let text = engine_to_json(&e);
        let back = engine_from_json(&Json::parse(&text).unwrap());
        assert_eq!(back, e, "store budget survives the round trip");
        // pinned model specs round-trip; unpinned stay byte-stable
        let spec = ModelSpec {
            name: "ds".into(),
            model: "deepspeech".into(),
            variant: Variant::parse("w2a2").unwrap(),
            size: ModelSize::Tiny,
            seed: 3,
            pin: true,
        };
        let back = model_spec_from_json(&Json::parse(&model_spec_to_json(&spec)).unwrap(), 0)
            .unwrap();
        assert_eq!(back, spec);
        let unpinned = ModelSpec { pin: false, ..spec };
        assert!(!model_spec_to_json(&unpinned).contains("pin"));
    }

    #[test]
    fn bad_inputs_rejected() {
        assert!(FileConfig::parse("not json").is_err());
        assert!(FileConfig::parse(r#"{"models": [{"variant": "w4a8"}]}"#).is_err());
        assert!(FileConfig::parse(r#"{"models": [{"name": "x", "size": "huge"}]}"#).is_err());
        assert!(FileConfig::load("/no/such/file.json").is_err());
    }
}

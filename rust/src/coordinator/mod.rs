//! L3 serving engine — the coordinator: per-model admission queues →
//! cost-model-driven continuous batching → EDF dispatch across a
//! sharded worker pool → per-layer routed execution (FullPack GEMV for
//! single-batch scan cells, GEMM-tier backends for the batched FC
//! stacks), with metrics, typed load shedding and graceful shutdown
//! (DESIGN.md §12).
//!
//! The engine is generic over the [`crate::models::Model`] trait
//! (DESIGN.md §10): any registered model — a `CompiledModel` over a
//! zoo graph, the legacy `DeepSpeech` struct — is served by name
//! through the same admission, routing-stats and metrics machinery.
//!
//! Admission and dequeue live in [`Scheduler`], a pure state machine
//! driven here with wall-clock nanoseconds and by the workload
//! harness's virtual DES with simulated ones — one policy
//! implementation, two clocks.  A request is admitted into its model's
//! forming batch while the cost model says one more column still fits
//! the front request's remaining SLO budget; otherwise the batch seals
//! and the next one forms.  Overload is shed at the front door with a
//! typed [`Rejected`] carrying a modeled retry-after instead of a bare
//! error string.  Workers prefer their home shard of model queues
//! (`model_id % workers`) and steal the globally earliest-deadline
//! batch when their shard is idle.
//!
//! When a sealed batch holds ≥2 requests for a model, the worker
//! executes them as **one** batched forward — each FC layer becomes a
//! single `GemmKernel::gemm` call over `n · time_steps` columns, and
//! per-request outputs are scattered back to their reply channels
//! (DESIGN.md §9).  [`Metrics`] records the batched-vs-singleton
//! dispatch split, flush reasons, shed counts, queue occupancy and EDF
//! inversions, engine-wide and per model.
//!
//! Python never appears here: models execute on the native Rust kernels
//! or through AOT-compiled PJRT artifacts (`crate::runtime`).
#![warn(missing_docs)]

pub mod config;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;

pub use config::{FileConfig, ModelSpec};
pub use metrics::{LatencyHistogram, Metrics, ModelCounters, BUCKETS_US};
pub use request::{
    LayerTiming, OpDesc, Rejected, Request, RequestId, Response, ShedReason, SubmitError,
};
pub use router::{Router, RouterConfig};
pub use scheduler::{
    Admitted, CostFn, Dispatch, FaultPlan, FlushReason, Scheduler, SchedulerConfig,
};

use crate::models::{Model, ModelBuilder, ModelStore, StoreError};
use crate::util::error::{anyhow, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Model-store residency policy (DESIGN.md §14).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StoreConfig {
    /// modeled resident-weights byte budget; `None` = unbounded
    /// (nothing is ever evicted)
    pub budget_bytes: Option<u64>,
}

/// Engine configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineConfig {
    /// worker threads draining the scheduler (model queues shard
    /// across them by `model_id % workers`)
    pub workers: usize,
    /// admission / batching / shedding policy
    pub sched: SchedulerConfig,
    /// per-layer kernel routing policy
    pub router: RouterConfig,
    /// model residency / eviction policy
    pub store: StoreConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 2,
            sched: SchedulerConfig::default(),
            router: RouterConfig::default(),
            store: StoreConfig::default(),
        }
    }
}

type Reply = mpsc::Sender<Result<Response>>;

struct Shared {
    sched: Mutex<Scheduler<(Request, Reply)>>,
    cv: Condvar,
    shutdown: AtomicBool,
    store: Arc<ModelStore>,
    metrics: Arc<Metrics>,
    router: Router,
    epoch: Instant,
    faults: FaultPlan,
}

impl Shared {
    /// Monotonic nanoseconds since engine start — the scheduler clock.
    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }
}

/// The serving engine.
pub struct Engine {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
}

/// Modeled dispatch cost for models that carry no cost model of their
/// own ([`Model::dispatch_cost_ns`] returned `None`): classify the
/// dispatch's routed ops and simulate each on the analytic cost model
/// (scan cells as FullPack GEMVs, widened FC nodes on the Ruy-W8A8
/// GEMM protocol).  Coarser than `costmodel::serving_dispatch_ns` but
/// monotone in the group size, which is all the admission rule needs.
fn fallback_dispatch_ns(model: &dyn Model, group: usize) -> u64 {
    use crate::costmodel::{simulate_gemm, simulate_gemv, CoreModel, Method};
    use crate::sim::CachePreset;
    let core = CoreModel::ex5_big();
    let preset = CachePreset::Gem5Ex5Big;
    let mut cycles = 0.0;
    for op in model.route_ops(group.max(1)) {
        cycles += if op.batch > 1 {
            simulate_gemm(Method::RuyW8A8, op.z, op.k, op.batch, preset, &core, 2).cycles
        } else {
            simulate_gemv(Method::FullPack(op.variant), op.z, op.k, preset, &core, 2).cycles
        };
    }
    ((cycles / core.freq_ghz) as u64).max(1)
}

impl Engine {
    /// Start an engine: spawns the worker pool immediately.
    pub fn new(config: EngineConfig) -> Engine {
        Engine::new_with_faults(config, FaultPlan::default())
    }

    /// Start an engine with an injected [`FaultPlan`] (the scheduler
    /// test battery's graceful-degradation hook: worker stalls and
    /// slow models are honored here; poisoned reply channels are a
    /// client-side fault the reply path already tolerates).
    pub fn new_with_faults(config: EngineConfig, faults: FaultPlan) -> Engine {
        let metrics = Arc::new(Metrics::default());
        let store = Arc::new(ModelStore::new(config.store.budget_bytes.map(|b| b as usize)));
        store.attach_metrics(metrics.clone());
        let cost_store = store.clone();
        let cost: CostFn = Box::new(move |name, n| {
            // pure peek: probing a cost must never touch LRU order or
            // trigger a load, or live and virtual admission would skew
            match cost_store.peek(name) {
                Some(m) => m
                    .dispatch_cost_ns(n)
                    .unwrap_or_else(|| fallback_dispatch_ns(m.as_ref(), n)),
                // cold or unknown (unknown models are refused at the
                // front door) — a safe floor, not a policy
                None => 1_000,
            }
        });
        let nworkers = config.workers.max(1);
        let shared = Arc::new(Shared {
            sched: Mutex::new(Scheduler::new(config.sched, cost)),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            store,
            metrics,
            router: Router::new(config.router),
            epoch: Instant::now(),
            faults,
        });
        let workers = (0..nworkers)
            .map(|i| {
                let s = shared.clone();
                std::thread::Builder::new()
                    .name(format!("fullpack-worker-{i}"))
                    .spawn(move || worker_loop(s, i, nworkers))
                    .expect("spawn worker")
            })
            .collect();
        Engine { shared, workers, next_id: AtomicU64::new(1) }
    }

    /// Register a model under a name — anything implementing [`Model`]
    /// (a `CompiledModel` over a zoo graph, the legacy `DeepSpeech`,
    /// ...).  Registration creates the model's admission queue.
    /// Re-registering an existing name is refused with a typed
    /// [`StoreError::AlreadyRegistered`]: replacing a live model must
    /// go through the explicit versioned [`Engine::swap_model`], so a
    /// config typo can never silently clobber a serving model.
    pub fn register_model(
        &self,
        name: &str,
        model: impl Model + 'static,
    ) -> std::result::Result<(), StoreError> {
        self.shared.store.register(name, Arc::new(model))?;
        self.shared.sched.lock().unwrap().register(name);
        self.shared.cv.notify_all();
        Ok(())
    }

    /// Register a lazily-built model: cold (non-resident) until first
    /// admission, evictable back to `builder` under the store budget.
    pub fn register_model_lazy(
        &self,
        name: &str,
        bytes_hint: usize,
        builder: ModelBuilder,
    ) -> std::result::Result<(), StoreError> {
        self.shared.store.register_lazy(name, bytes_hint, builder)?;
        self.shared.sched.lock().unwrap().register(name);
        self.shared.cv.notify_all();
        Ok(())
    }

    /// Pin a registered model: loaded eagerly, never evicted.
    pub fn pin_model(&self, name: &str) -> std::result::Result<(), StoreError> {
        self.shared.store.pin(name)
    }

    /// Atomically hot-swap a registered model to new weights: the
    /// store's per-model version counter bumps, new admissions see the
    /// new model, and in-flight sealed batches finish on the old
    /// weights their dispatch guards hold.  Replacement invalidates
    /// the model's cost memo.  Returns the new version.
    pub fn swap_model(
        &self,
        name: &str,
        model: impl Model + 'static,
        builder: Option<ModelBuilder>,
    ) -> std::result::Result<u64, StoreError> {
        let version = self.shared.store.swap(name, Arc::new(model), builder)?;
        // scheduler re-registration of an existing name keeps its
        // queue and id but drops the memoized cost curve
        self.shared.sched.lock().unwrap().register(name);
        self.shared.cv.notify_all();
        Ok(version)
    }

    /// The engine's model store (residency stats, versions, pins).
    pub fn store(&self) -> &Arc<ModelStore> {
        &self.shared.store
    }

    /// Look up a registered model by name, loading it if cold.
    pub fn model(&self, name: &str) -> Option<Arc<dyn Model>> {
        self.shared.store.fetch(name).ok()
    }

    /// Names of every registered model, sorted.
    pub fn model_names(&self) -> Vec<String> {
        self.shared.store.per_entry().into_iter().map(|e| e.name).collect()
    }

    /// Submit asynchronously with typed refusals: an unknown model or
    /// a load shed is reported at the front door as a [`SubmitError`]
    /// (sheds carry the modeled retry-after).  The receiver yields the
    /// response.
    pub fn try_submit(
        &self,
        model: &str,
        frames: Vec<f32>,
    ) -> std::result::Result<mpsc::Receiver<Result<Response>>, SubmitError> {
        self.shared.metrics.mark_started();
        self.shared.metrics.requests.fetch_add(1, Relaxed);
        // residency gate (DESIGN.md §14): a cold model starts loading
        // *now* (synchronously, so the retry hits a warm entry) but
        // the triggering request is shed with the modeled load time as
        // its retry hint.  The virtual DES mirrors this exact order:
        // count the request, then the cold check, then admission.
        match self.shared.store.admit(model) {
            Ok(_) => {}
            Err(StoreError::Cold(cold)) => {
                self.shared.metrics.record_shed(model, ShedReason::ColdModel);
                return Err(SubmitError::Rejected(Rejected {
                    model: model.to_string(),
                    reason: ShedReason::ColdModel,
                    depth: 0,
                    retry_after_us: cold.retry_after_us,
                }));
            }
            Err(e) => {
                // unknown name, or a builder failure (the model is
                // unservable either way).  Global counter only:
                // per-model entries are keyed by *registered* names,
                // so bogus client-supplied names cannot grow the map.
                self.shared.metrics.errors.fetch_add(1, Relaxed);
                let name = match e {
                    StoreError::Unknown(n) => n,
                    _ => model.to_string(),
                };
                return Err(SubmitError::UnknownModel(name));
            }
        }
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Relaxed),
            model: model.to_string(),
            frames,
            arrived: Instant::now(),
        };
        let admitted = {
            let mut sched = self.shared.sched.lock().unwrap();
            let Some(mid) = sched.model_id(model) else {
                drop(sched);
                // global counter only: per-model entries are keyed by
                // *registered* names, so a stream of bogus
                // client-supplied names cannot grow the metrics map
                self.shared.metrics.errors.fetch_add(1, Relaxed);
                return Err(SubmitError::UnknownModel(model.to_string()));
            };
            sched.submit(mid, (req, tx), self.shared.now_ns())
        };
        match admitted {
            Ok(a) => {
                self.shared.metrics.observe_queue_depth(model, a.depth as u64);
                if a.sealed {
                    self.shared.cv.notify_all();
                } else {
                    self.shared.cv.notify_one();
                }
                Ok(rx)
            }
            Err(rej) => {
                self.shared.metrics.record_shed(model, rej.reason);
                Err(SubmitError::Rejected(rej))
            }
        }
    }

    /// Synchronous convenience wrapper over [`Engine::try_submit`].
    /// Refusals stay typed: the returned error wraps the original
    /// [`SubmitError`], so callers can `downcast_ref::<SubmitError>()`
    /// to recover `QueueFull`/`OverBudget`/`UnknownModel` and the
    /// modeled `retry_after_us` instead of parsing a message.
    pub fn infer(&self, model: &str, frames: Vec<f32>) -> Result<Response> {
        self.try_submit(model, frames)
            .map_err(crate::util::error::Error::new)?
            .recv()
            .map_err(|_| anyhow!("engine dropped request"))?
    }

    /// Engine-wide counters and latency histogram.
    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    /// The per-layer routing policy (and its path counters).
    pub fn router(&self) -> &Router {
        &self.shared.router
    }

    /// Per-queue occupancy snapshot: `(model, forming, sealed)`.
    pub fn queue_depths(&self) -> Vec<(String, usize, usize)> {
        self.shared.sched.lock().unwrap().depths()
    }

    /// Drain and stop the workers.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Relaxed);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Relaxed);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Worker `w` of `nworkers`: tick the scheduler, dispatch its shard's
/// earliest-deadline sealed batch (stealing globally when the shard is
/// idle), or sleep until the next seal-eligibility instant.
fn worker_loop(s: Arc<Shared>, w: usize, nworkers: usize) {
    if !s.faults.worker_stall.is_zero() {
        std::thread::sleep(s.faults.worker_stall);
    }
    loop {
        let dispatch = {
            let mut sched = s.sched.lock().unwrap();
            loop {
                let now = s.now_ns();
                sched.on_tick(now);
                if let Some(d) = sched.pop(now, Some((w, nworkers))) {
                    break Some(d);
                }
                if s.shutdown.load(Relaxed) {
                    // drain: seal whatever is forming and serve it; the
                    // worker exits only when nothing sealed remains
                    // anywhere (shard affinity is ignored on the way out
                    // so no batch is orphaned)
                    sched.seal_all_drained();
                    break sched.pop(s.now_ns(), None);
                }
                let wait = match sched.next_wakeup(now) {
                    Some(t) => Duration::from_nanos(t.saturating_sub(now)),
                    None => Duration::from_millis(50),
                }
                .clamp(Duration::from_micros(100), Duration::from_millis(50));
                let (guard, _timeout) = s.cv.wait_timeout(sched, wait).unwrap();
                sched = guard;
            }
        };
        let Some(d) = dispatch else { return };
        s.metrics.record_flush(d.reason);
        s.metrics.record_batch_size(d.entries.len() as u64);
        if d.stolen {
            s.metrics.stolen_dispatches.fetch_add(1, Relaxed);
        }
        if d.inversion {
            s.metrics.edf_inversions.fetch_add(1, Relaxed);
        }
        dispatch_batch(&s, d);
    }
}

/// Serve one sealed batch (single-model by construction): ≥2 valid
/// requests execute as a single batched forward (one
/// `GemmKernel::gemm` call per FC layer — the scheduler's throughput
/// win); everything else takes the per-request path.  Every dispatched
/// request is counted exactly once as batched or singleton, engine-wide
/// and under its model's name.
fn dispatch_batch(s: &Arc<Shared>, d: Dispatch<(Request, Reply)>) {
    let name = d.name;
    let items: Vec<(Request, Reply)> = d.entries.into_iter().map(|(item, _)| item).collect();
    if let Some(extra) = s.faults.slow_for(&name) {
        std::thread::sleep(extra);
    }
    // the dispatch guard pins this batch's model version for the whole
    // forward: a concurrent hot-swap flips the registry entry but this
    // batch finishes on the weights it captured, and the LRU can never
    // evict an entry with a live guard.  A model evicted between
    // admission and dispatch is transparently reloaded (no shed — the
    // request was already admitted).
    let guard = match s.store.begin_dispatch(&name) {
        Ok(g) => g,
        Err(e) => {
            // defensive: queues exist only for registered models, and
            // entries are never removed — but a reply beats a panic
            s.metrics.record_singleton(&name, items.len() as u64);
            s.metrics.record_errors(&name, items.len() as u64);
            let msg = e.to_string();
            for (_, reply) in items {
                let _ = reply.send(Err(anyhow!("{msg}")));
            }
            return;
        }
    };
    let model = guard.model().clone();
    // shape-validate up front; invalid requests error individually
    // and never poison the group's GEMM
    let expected = model.input_len();
    let (valid, invalid): (Vec<_>, Vec<_>) =
        items.into_iter().partition(|(req, _)| req.frames.len() == expected);
    if !invalid.is_empty() {
        s.metrics.record_singleton(&name, invalid.len() as u64);
        s.metrics.record_errors(&name, invalid.len() as u64);
        for (req, reply) in invalid {
            let _ = reply.send(Err(anyhow!(
                "frames len {} != model input len {expected}",
                req.frames.len()
            )));
        }
    }
    if valid.len() >= 2 {
        process_group(s, model.as_ref(), &name, valid);
    } else {
        for (req, reply) in valid {
            s.metrics.record_singleton(&name, 1);
            let result = process_one(s, model.as_ref(), &name, &req);
            if result.is_err() {
                s.metrics.record_errors(&name, 1);
            }
            let _ = reply.send(result);
        }
    }
}

/// Route-classify every linear-algebra op of one dispatch (stats — the
/// model's own plans apply the identical policy, mirroring the paper's
/// §4.6 split); a routing failure is a real error, not a silently
/// skipped counter.  `group` is the number of requests sharing the
/// dispatch: the model's [`Model::route_ops`] widens batched nodes to
/// the flushed column count and repeats scan cells per request.
fn classify_ops(s: &Shared, model: &dyn Model, group: usize) -> Result<()> {
    for op in model.route_ops(group) {
        s.router
            .classify(&op)
            .map_err(|e| anyhow!("routing {}x{} op (batch {}): {e}", op.z, op.k, op.batch))?;
    }
    Ok(())
}

/// The per-request path (model already resolved and shape-validated).
fn process_one(s: &Shared, model: &dyn Model, name: &str, req: &Request) -> Result<Response> {
    let queue_ns = req.arrived.elapsed().as_nanos();
    classify_ops(s, model, 1)?;
    let t0 = Instant::now();
    let (logits, layer_times) = model.forward_timed(&req.frames);
    let total_ns = queue_ns + t0.elapsed().as_nanos();
    s.metrics.observe_latency_for(name, (total_ns / 1_000) as u64);
    Ok(Response { id: req.id, logits, layer_times, queue_ns, total_ns })
}

/// The multi-request path: one batched forward for the whole group,
/// per-request outputs scattered back to their reply channels.
fn process_group(s: &Shared, model: &dyn Model, name: &str, items: Vec<(Request, Reply)>) {
    let n = items.len();
    if let Err(e) = classify_ops(s, model, n) {
        // no GEMM was dispatched: these count as per-request errors on
        // the singleton side, keeping batched_requests true to its
        // "served through a batched dispatch" meaning
        let msg = e.to_string();
        s.metrics.record_singleton(name, n as u64);
        s.metrics.record_errors(name, n as u64);
        for (_, reply) in items {
            let _ = reply.send(Err(anyhow!("{msg}")));
        }
        return;
    }
    let queue_ns: Vec<u128> = items.iter().map(|(r, _)| r.arrived.elapsed().as_nanos()).collect();
    let t0 = Instant::now();
    let results = {
        let frame_refs: Vec<&[f32]> = items.iter().map(|(r, _)| r.frames.as_slice()).collect();
        model.forward_batch(&frame_refs)
    };
    let compute_ns = t0.elapsed().as_nanos();
    s.metrics.record_batched_dispatch(name, n as u64);
    for (((req, reply), (logits, layer_times)), q) in
        items.into_iter().zip(results).zip(queue_ns)
    {
        let total_ns = q + compute_ns;
        s.metrics.observe_latency_for(name, (total_ns / 1_000) as u64);
        let _ = reply.send(Ok(Response { id: req.id, logits, layer_times, queue_ns: q, total_ns }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{DeepSpeech, DeepSpeechConfig};
    use crate::pack::Variant;

    fn tiny_engine(variant: &str) -> Engine {
        let e = Engine::new(EngineConfig {
            workers: 2,
            sched: SchedulerConfig {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(1),
                max_queue: 64,
                ..SchedulerConfig::default()
            },
            router: RouterConfig::default(),
            store: StoreConfig::default(),
        });
        let m = DeepSpeech::new(DeepSpeechConfig::TINY, Variant::parse(variant).unwrap(), 5);
        e.register_model("deepspeech", m).unwrap();
        e
    }

    fn frames() -> Vec<f32> {
        let cfg = DeepSpeechConfig::TINY;
        (0..cfg.time_steps * cfg.n_input).map(|i| (i as f32 * 0.01).sin()).collect()
    }

    #[test]
    fn infer_roundtrip() {
        let e = tiny_engine("w4a8");
        let r = e.infer("deepspeech", frames()).unwrap();
        let cfg = DeepSpeechConfig::TINY;
        assert_eq!(r.logits.len(), cfg.time_steps * cfg.n_output);
        assert_eq!(r.layer_times.len(), 6);
        assert!(r.total_ns > 0);
        assert_eq!(e.metrics().completed.load(Relaxed), 1);
        let (gemv, gemm) = e.router().counts();
        assert_eq!(gemv, 1); // the LSTM layer
        assert_eq!(gemm, 5); // the five FC layers
        // a lone request is a singleton dispatch, engine-wide and
        // under the model's own name
        assert_eq!(e.metrics().dispatch_counts(), (0, 1));
        assert_eq!(e.metrics().model_dispatch_counts("deepspeech"), (0, 1));
        assert_eq!(e.model_names(), vec!["deepspeech".to_string()]);
    }

    #[test]
    fn unknown_model_is_refused_at_the_front_door() {
        let e = tiny_engine("w4a8");
        let err = e.try_submit("nope", frames()).unwrap_err();
        assert!(matches!(err, SubmitError::UnknownModel(ref n) if n == "nope"));
        // the sync wrapper keeps the refusal typed behind anyhow
        let ierr = e.infer("nope", frames()).unwrap_err();
        assert!(matches!(
            ierr.downcast_ref::<SubmitError>(),
            Some(SubmitError::UnknownModel(n)) if n == "nope"
        ));
        assert_eq!(e.metrics().errors.load(Relaxed), 2);
    }

    #[test]
    fn re_registration_is_refused_until_explicitly_swapped() {
        // the silent-replacement bug: register_model used to blindly
        // insert, so a duplicate name clobbered a live model with no
        // trace.  Now the duplicate is a typed refusal and replacement
        // is an explicit versioned swap.
        let e = tiny_engine("w4a8");
        let dup = DeepSpeech::new(DeepSpeechConfig::TINY, Variant::parse("w4a8").unwrap(), 6);
        let err = e.register_model("deepspeech", dup).unwrap_err();
        assert!(matches!(err, StoreError::AlreadyRegistered(ref n) if n == "deepspeech"));
        // the original model (seed 5) is still the one serving
        let before = e.infer("deepspeech", frames()).unwrap().logits;
        assert_eq!(e.store().version("deepspeech"), Some(1));
        // the explicit path: swap bumps the version and changes weights
        let next = DeepSpeech::new(DeepSpeechConfig::TINY, Variant::parse("w4a8").unwrap(), 6);
        let v = e.swap_model("deepspeech", next, None).unwrap();
        assert_eq!(v, 2);
        assert_eq!(e.store().version("deepspeech"), Some(2));
        let after = e.infer("deepspeech", frames()).unwrap().logits;
        assert_ne!(before, after, "swap must actually change the serving weights");
        assert_eq!(e.metrics().model_store_counts().2, 1);
        // swapping a never-registered name is a typed error too
        assert!(matches!(
            e.swap_model("ghost", DeepSpeech::new(DeepSpeechConfig::TINY, Variant::parse("w4a8").unwrap(), 5), None),
            Err(StoreError::Unknown(_))
        ));
    }

    #[test]
    fn cold_model_is_shed_with_modeled_retry_then_served() {
        let e = tiny_engine("w4a8");
        e.register_model_lazy(
            "lazy-ds",
            1 << 20,
            Box::new(|| {
                Ok(Arc::new(DeepSpeech::new(
                    DeepSpeechConfig::TINY,
                    Variant::parse("w4a8").unwrap(),
                    9,
                )))
            }),
        )
        .unwrap();
        assert!(!e.store().resident("lazy-ds"));
        let err = e.try_submit("lazy-ds", frames()).unwrap_err();
        match err {
            SubmitError::Rejected(r) => {
                assert_eq!(r.reason, ShedReason::ColdModel);
                assert_eq!(r.model, "lazy-ds");
                assert!(r.retry_after_us >= 1);
            }
            other => panic!("expected a cold-model shed, got {other:?}"),
        }
        assert_eq!(e.metrics().shed_counts().2, 1);
        // the shed performed the load: the retry is admitted and served
        assert!(e.store().resident("lazy-ds"));
        let r = e.infer("lazy-ds", frames()).unwrap();
        assert!(r.logits.iter().all(|x| x.is_finite()));
        assert_eq!(e.metrics().shed_counts().2, 1, "warm retry must not shed");
    }

    #[test]
    fn bad_frame_len_is_error() {
        let e = tiny_engine("w4a8");
        assert!(e.infer("deepspeech", vec![0.0; 3]).is_err());
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let e = tiny_engine("w2a2");
        let rxs: Vec<_> =
            (0..16).map(|_| e.try_submit("deepspeech", frames()).unwrap()).collect();
        let mut ok = 0;
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert!(r.logits.iter().all(|x| x.is_finite()));
            ok += 1;
        }
        assert_eq!(ok, 16);
        assert_eq!(e.metrics().completed.load(Relaxed), 16);
        assert!(e.metrics().throughput_rps() > 0.0);
        // every request dispatched exactly once, batched or singleton
        let (batched, singleton) = e.metrics().dispatch_counts();
        assert_eq!(batched + singleton, 16);
        // occupancy was observed on every admission
        assert!(e.metrics().max_queue_depth.load(Relaxed) >= 1);
    }

    #[test]
    fn shutdown_drains() {
        let e = tiny_engine("w1a1");
        let rx = e.try_submit("deepspeech", frames()).unwrap();
        e.shutdown();
        // the queued request was served before exit
        assert!(rx.recv().unwrap().is_ok());
    }

    #[test]
    fn deterministic_across_engines() {
        let a = tiny_engine("w4a8").infer("deepspeech", frames()).unwrap().logits;
        let b = tiny_engine("w4a8").infer("deepspeech", frames()).unwrap().logits;
        assert_eq!(a, b);
    }

    #[test]
    fn queue_full_shed_is_typed_with_retry_hint() {
        // one worker stalled long enough that nothing drains while we
        // flood a depth-2 queue: the third submit must shed with a
        // typed QueueFull carrying a modeled retry-after
        let e = Engine::new_with_faults(
            EngineConfig {
                workers: 1,
                sched: SchedulerConfig {
                    max_batch: 4,
                    max_queue: 2,
                    max_wait: std::time::Duration::from_millis(200),
                    ..SchedulerConfig::default()
                },
                router: RouterConfig::default(),
                store: StoreConfig::default(),
            },
            FaultPlan {
                worker_stall: std::time::Duration::from_millis(300),
                ..FaultPlan::default()
            },
        );
        let m = DeepSpeech::new(DeepSpeechConfig::TINY, Variant::parse("w4a8").unwrap(), 5);
        e.register_model("deepspeech", m).unwrap();
        let _rx1 = e.try_submit("deepspeech", frames()).unwrap();
        let _rx2 = e.try_submit("deepspeech", frames()).unwrap();
        let err = e.try_submit("deepspeech", frames()).unwrap_err();
        match err {
            SubmitError::Rejected(r) => {
                assert_eq!(r.reason, ShedReason::QueueFull);
                assert_eq!(r.depth, 2);
                assert!(r.retry_after_us >= 1, "modeled retry hint present");
                assert_eq!(r.model, "deepspeech");
            }
            other => panic!("expected a typed shed, got {other:?}"),
        }
        assert_eq!(e.metrics().sheds_queue_full.load(Relaxed), 1);
        // the queued requests still complete after the stall
        assert!(_rx1.recv().unwrap().is_ok());
        assert!(_rx2.recv().unwrap().is_ok());
    }
}

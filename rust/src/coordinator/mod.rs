//! L3 serving engine — the coordinator: request queue → dynamic batcher
//! → worker pool → per-layer routed execution (FullPack GEMV for
//! single-batch LSTM steps, Ruy-like GEMM for the batched FC stack),
//! with metrics and graceful shutdown.
//!
//! Python never appears here: models execute on the native Rust kernels
//! or through AOT-compiled PJRT artifacts (`crate::runtime`).
#![warn(missing_docs)]

pub mod batcher;
pub mod config;
pub mod metrics;
pub mod request;
pub mod router;

pub use batcher::{Batcher, BatcherConfig, FlushReason};
pub use config::{FileConfig, ModelSpec};
pub use metrics::Metrics;
pub use request::{OpDesc, Request, RequestId, Response};
pub use router::{Router, RouterConfig};

use crate::models::DeepSpeech;
use crate::util::error::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{mpsc, Arc, Condvar, Mutex, RwLock};
use std::time::Instant;

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// worker threads draining the batcher
    pub workers: usize,
    /// dynamic-batching policy
    pub batcher: BatcherConfig,
    /// per-layer kernel routing policy
    pub router: RouterConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 2,
            batcher: BatcherConfig::default(),
            router: RouterConfig::default(),
        }
    }
}

type Reply = mpsc::Sender<Result<Response>>;

struct Shared {
    batcher: Mutex<Batcher<(Request, Reply)>>,
    cv: Condvar,
    shutdown: AtomicBool,
    models: RwLock<HashMap<String, Arc<DeepSpeech>>>,
    metrics: Metrics,
    router: Router,
}

/// The serving engine.
pub struct Engine {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Engine {
    /// Start an engine: spawns the worker pool immediately.
    pub fn new(config: EngineConfig) -> Engine {
        let shared = Arc::new(Shared {
            batcher: Mutex::new(Batcher::new(config.batcher)),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            models: RwLock::new(HashMap::new()),
            metrics: Metrics::default(),
            router: Router::new(config.router),
        });
        let workers = (0..config.workers.max(1))
            .map(|i| {
                let s = shared.clone();
                std::thread::Builder::new()
                    .name(format!("fullpack-worker-{i}"))
                    .spawn(move || worker_loop(s))
                    .expect("spawn worker")
            })
            .collect();
        Engine { shared, workers, next_id: AtomicU64::new(1) }
    }

    /// Register (or replace) a model under a name.
    pub fn register_model(&self, name: &str, model: DeepSpeech) {
        self.shared
            .models
            .write()
            .unwrap()
            .insert(name.to_string(), Arc::new(model));
    }

    /// Look up a registered model by name.
    pub fn model(&self, name: &str) -> Option<Arc<DeepSpeech>> {
        self.shared.models.read().unwrap().get(name).cloned()
    }

    /// Submit asynchronously; the receiver yields the response.
    pub fn submit(&self, model: &str, frames: Vec<f32>) -> Result<mpsc::Receiver<Result<Response>>> {
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Relaxed),
            model: model.to_string(),
            frames,
            arrived: Instant::now(),
        };
        self.shared.metrics.mark_started();
        self.shared.metrics.requests.fetch_add(1, Relaxed);
        {
            let mut b = self.shared.batcher.lock().unwrap();
            b.push((req, tx)).map_err(|_| anyhow!("queue full (backpressure)"))?;
        }
        self.shared.cv.notify_one();
        Ok(rx)
    }

    /// Synchronous convenience wrapper.
    pub fn infer(&self, model: &str, frames: Vec<f32>) -> Result<Response> {
        self.submit(model, frames)?
            .recv()
            .map_err(|_| anyhow!("engine dropped request"))?
    }

    /// Engine-wide counters and latency histogram.
    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    /// The per-layer routing policy (and its path counters).
    pub fn router(&self) -> &Router {
        &self.shared.router
    }

    /// Drain and stop the workers.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Relaxed);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Relaxed);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(s: Arc<Shared>) {
    loop {
        let batch = {
            let mut b = s.batcher.lock().unwrap();
            loop {
                if let Some((batch, _reason)) = b.pop_batch(s.shutdown.load(Relaxed)) {
                    break Some(batch);
                }
                if s.shutdown.load(Relaxed) {
                    break None;
                }
                let wait = b
                    .time_to_deadline()
                    .unwrap_or(std::time::Duration::from_millis(50))
                    .max(std::time::Duration::from_micros(100));
                let (guard, _timeout) = s.cv.wait_timeout(b, wait).unwrap();
                b = guard;
            }
        };
        let Some(batch) = batch else { return };
        for (req, reply) in batch {
            let result = process(&s, &req);
            if result.is_err() {
                s.metrics.errors.fetch_add(1, Relaxed);
            }
            let _ = reply.send(result);
        }
    }
}

fn process(s: &Shared, req: &Request) -> Result<Response> {
    let model = s
        .models
        .read()
        .unwrap()
        .get(&req.model)
        .cloned()
        .ok_or_else(|| anyhow!("unknown model {:?}", req.model))?;
    let queue_ns = req.arrived.elapsed().as_nanos();
    let expected = model.config.time_steps * model.config.n_input;
    if req.frames.len() != expected {
        return Err(anyhow!(
            "frames len {} != time_steps*n_input {}",
            req.frames.len(),
            expected
        ));
    }
    // route per layer (stats — the model's own plans apply the identical
    // policy, mirroring the paper's §4.6 split); a routing failure is a
    // real error, not a silently skipped counter
    for layer in &model.layers {
        let batch = match layer.kind {
            crate::models::LayerKind::FcBatch => model.config.time_steps,
            crate::models::LayerKind::LstmStep => 1,
        };
        s.router
            .classify(&OpDesc { batch, z: layer.z, k: layer.k, variant: model.variant })
            .map_err(|e| anyhow!("routing layer {}: {e}", layer.name))?;
    }
    let t0 = Instant::now();
    let (logits, layer_times) = model.forward_timed(&req.frames);
    let total_ns = queue_ns + t0.elapsed().as_nanos();
    s.metrics.observe_latency_us((total_ns / 1_000) as u64);
    Ok(Response { id: req.id, logits, layer_times, queue_ns, total_ns })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::DeepSpeechConfig;
    use crate::pack::Variant;

    fn tiny_engine(variant: &str) -> Engine {
        let e = Engine::new(EngineConfig {
            workers: 2,
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(1),
                max_queue: 64,
            },
            router: RouterConfig::default(),
        });
        let m = DeepSpeech::new(DeepSpeechConfig::TINY, Variant::parse(variant).unwrap(), 5);
        e.register_model("deepspeech", m);
        e
    }

    fn frames() -> Vec<f32> {
        let cfg = DeepSpeechConfig::TINY;
        (0..cfg.time_steps * cfg.n_input).map(|i| (i as f32 * 0.01).sin()).collect()
    }

    #[test]
    fn infer_roundtrip() {
        let e = tiny_engine("w4a8");
        let r = e.infer("deepspeech", frames()).unwrap();
        let cfg = DeepSpeechConfig::TINY;
        assert_eq!(r.logits.len(), cfg.time_steps * cfg.n_output);
        assert_eq!(r.layer_times.len(), 6);
        assert!(r.total_ns > 0);
        assert_eq!(e.metrics().completed.load(Relaxed), 1);
        let (gemv, gemm) = e.router().counts();
        assert_eq!(gemv, 1); // the LSTM layer
        assert_eq!(gemm, 5); // the five FC layers
    }

    #[test]
    fn unknown_model_is_error() {
        let e = tiny_engine("w4a8");
        assert!(e.infer("nope", frames()).is_err());
        assert_eq!(e.metrics().errors.load(Relaxed), 1);
    }

    #[test]
    fn bad_frame_len_is_error() {
        let e = tiny_engine("w4a8");
        assert!(e.infer("deepspeech", vec![0.0; 3]).is_err());
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let e = tiny_engine("w2a2");
        let rxs: Vec<_> = (0..16).map(|_| e.submit("deepspeech", frames()).unwrap()).collect();
        let mut ok = 0;
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert!(r.logits.iter().all(|x| x.is_finite()));
            ok += 1;
        }
        assert_eq!(ok, 16);
        assert_eq!(e.metrics().completed.load(Relaxed), 16);
        assert!(e.metrics().throughput_rps() > 0.0);
    }

    #[test]
    fn shutdown_drains() {
        let e = tiny_engine("w1a1");
        let rx = e.submit("deepspeech", frames()).unwrap();
        e.shutdown();
        // the queued request was served before exit
        assert!(rx.recv().unwrap().is_ok());
    }

    #[test]
    fn deterministic_across_engines() {
        let a = tiny_engine("w4a8").infer("deepspeech", frames()).unwrap().logits;
        let b = tiny_engine("w4a8").infer("deepspeech", frames()).unwrap().logits;
        assert_eq!(a, b);
    }
}

//! L3 serving engine — the coordinator: request queue → dynamic batcher
//! → worker pool → per-layer routed execution (FullPack GEMV for
//! single-batch scan cells, GEMM-tier backends for the batched FC
//! stacks), with metrics and graceful shutdown.
//!
//! The engine is generic over the [`crate::models::Model`] trait
//! (DESIGN.md §10): any registered model — a `CompiledModel` over a
//! zoo graph, the legacy `DeepSpeech` struct — is served by name
//! through the same batching, routing-stats and metrics machinery.
//!
//! When the batcher flushes ≥2 requests for the same model, the worker
//! executes them as **one** batched forward — each FC layer becomes a
//! single `GemmKernel::gemm` call over `n · time_steps` columns, and
//! per-request outputs are scattered back to their reply channels
//! (DESIGN.md §9).  [`Metrics`] records the batched-vs-singleton
//! dispatch split, engine-wide and per model.
//!
//! Python never appears here: models execute on the native Rust kernels
//! or through AOT-compiled PJRT artifacts (`crate::runtime`).
#![warn(missing_docs)]

pub mod batcher;
pub mod config;
pub mod metrics;
pub mod request;
pub mod router;

pub use batcher::{Batcher, BatcherConfig, FlushReason};
pub use config::{FileConfig, ModelSpec};
pub use metrics::{LatencyHistogram, Metrics, ModelCounters, BUCKETS_US};
pub use request::{LayerTiming, OpDesc, Request, RequestId, Response};
pub use router::{Router, RouterConfig};

use crate::models::Model;
use crate::util::error::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{mpsc, Arc, Condvar, Mutex, RwLock};
use std::time::Instant;

/// Engine configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineConfig {
    /// worker threads draining the batcher
    pub workers: usize,
    /// dynamic-batching policy
    pub batcher: BatcherConfig,
    /// per-layer kernel routing policy
    pub router: RouterConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 2,
            batcher: BatcherConfig::default(),
            router: RouterConfig::default(),
        }
    }
}

type Reply = mpsc::Sender<Result<Response>>;

struct Shared {
    batcher: Mutex<Batcher<(Request, Reply)>>,
    cv: Condvar,
    shutdown: AtomicBool,
    models: RwLock<HashMap<String, Arc<dyn Model>>>,
    metrics: Metrics,
    router: Router,
}

/// The serving engine.
pub struct Engine {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Engine {
    /// Start an engine: spawns the worker pool immediately.
    pub fn new(config: EngineConfig) -> Engine {
        let shared = Arc::new(Shared {
            batcher: Mutex::new(Batcher::new(config.batcher)),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            models: RwLock::new(HashMap::new()),
            metrics: Metrics::default(),
            router: Router::new(config.router),
        });
        let workers = (0..config.workers.max(1))
            .map(|i| {
                let s = shared.clone();
                std::thread::Builder::new()
                    .name(format!("fullpack-worker-{i}"))
                    .spawn(move || worker_loop(s))
                    .expect("spawn worker")
            })
            .collect();
        Engine { shared, workers, next_id: AtomicU64::new(1) }
    }

    /// Register (or replace) a model under a name — anything
    /// implementing [`Model`] (a `CompiledModel` over a zoo graph, the
    /// legacy `DeepSpeech`, ...).
    pub fn register_model(&self, name: &str, model: impl Model + 'static) {
        self.shared
            .models
            .write()
            .unwrap()
            .insert(name.to_string(), Arc::new(model));
    }

    /// Look up a registered model by name.
    pub fn model(&self, name: &str) -> Option<Arc<dyn Model>> {
        self.shared.models.read().unwrap().get(name).cloned()
    }

    /// Names of every registered model, sorted.
    pub fn model_names(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.shared.models.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    /// Submit asynchronously; the receiver yields the response.
    pub fn submit(&self, model: &str, frames: Vec<f32>) -> Result<mpsc::Receiver<Result<Response>>> {
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Relaxed),
            model: model.to_string(),
            frames,
            arrived: Instant::now(),
        };
        self.shared.metrics.mark_started();
        self.shared.metrics.requests.fetch_add(1, Relaxed);
        {
            let mut b = self.shared.batcher.lock().unwrap();
            b.push((req, tx)).map_err(|_| anyhow!("queue full (backpressure)"))?;
        }
        self.shared.cv.notify_one();
        Ok(rx)
    }

    /// Synchronous convenience wrapper.
    pub fn infer(&self, model: &str, frames: Vec<f32>) -> Result<Response> {
        self.submit(model, frames)?
            .recv()
            .map_err(|_| anyhow!("engine dropped request"))?
    }

    /// Engine-wide counters and latency histogram.
    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    /// The per-layer routing policy (and its path counters).
    pub fn router(&self) -> &Router {
        &self.shared.router
    }

    /// Drain and stop the workers.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Relaxed);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Relaxed);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(s: Arc<Shared>) {
    loop {
        let batch = {
            let mut b = s.batcher.lock().unwrap();
            loop {
                if let Some((batch, reason)) = b.pop_batch(s.shutdown.load(Relaxed)) {
                    s.metrics.record_flush(reason);
                    break Some(batch);
                }
                if s.shutdown.load(Relaxed) {
                    break None;
                }
                let wait = b
                    .time_to_deadline()
                    .unwrap_or(std::time::Duration::from_millis(50))
                    .max(std::time::Duration::from_micros(100));
                let (guard, _timeout) = s.cv.wait_timeout(b, wait).unwrap();
                b = guard;
            }
        };
        let Some(batch) = batch else { return };
        dispatch_flush(&s, batch);
    }
}

/// Serve one flushed batch: same-model runs of ≥2 valid requests are
/// executed as a single batched forward (one `GemmKernel::gemm` call
/// per FC layer — the batcher's throughput win); everything else takes
/// the per-request path.  Every request is counted exactly once as
/// batched or singleton, engine-wide and under its model's name.
fn dispatch_flush(s: &Arc<Shared>, batch: Vec<(Request, Reply)>) {
    // group by model, preserving arrival order within each group
    let mut groups: Vec<(String, Vec<(Request, Reply)>)> = Vec::new();
    for (req, reply) in batch {
        match groups.iter_mut().find(|(m, _)| *m == req.model) {
            Some((_, v)) => v.push((req, reply)),
            None => groups.push((req.model.clone(), vec![(req, reply)])),
        }
    }
    for (name, items) in groups {
        let model = s.models.read().unwrap().get(&name).cloned();
        let Some(model) = model else {
            // global counters only: per-model entries are keyed by
            // *registered* names, so a stream of bogus client-supplied
            // names cannot grow the metrics map (or the summary line)
            // without bound
            s.metrics.singleton_requests.fetch_add(items.len() as u64, Relaxed);
            s.metrics.errors.fetch_add(items.len() as u64, Relaxed);
            for (req, reply) in items {
                let _ = reply.send(Err(anyhow!("unknown model {:?}", req.model)));
            }
            continue;
        };
        // shape-validate up front; invalid requests error individually
        // and never poison the group's GEMM
        let expected = model.input_len();
        let (valid, invalid): (Vec<_>, Vec<_>) =
            items.into_iter().partition(|(req, _)| req.frames.len() == expected);
        if !invalid.is_empty() {
            s.metrics.record_singleton(&name, invalid.len() as u64);
            s.metrics.record_errors(&name, invalid.len() as u64);
            for (req, reply) in invalid {
                let _ = reply.send(Err(anyhow!(
                    "frames len {} != model input len {expected}",
                    req.frames.len()
                )));
            }
        }
        if valid.len() >= 2 {
            process_group(s, model.as_ref(), &name, valid);
        } else {
            for (req, reply) in valid {
                s.metrics.record_singleton(&name, 1);
                let result = process_one(s, model.as_ref(), &name, &req);
                if result.is_err() {
                    s.metrics.record_errors(&name, 1);
                }
                let _ = reply.send(result);
            }
        }
    }
}

/// Route-classify every linear-algebra op of one dispatch (stats — the
/// model's own plans apply the identical policy, mirroring the paper's
/// §4.6 split); a routing failure is a real error, not a silently
/// skipped counter.  `group` is the number of requests sharing the
/// dispatch: the model's [`Model::route_ops`] widens batched nodes to
/// the flushed column count and repeats scan cells per request.
fn classify_ops(s: &Shared, model: &dyn Model, group: usize) -> Result<()> {
    for op in model.route_ops(group) {
        s.router
            .classify(&op)
            .map_err(|e| anyhow!("routing {}x{} op (batch {}): {e}", op.z, op.k, op.batch))?;
    }
    Ok(())
}

/// The per-request path (model already resolved and shape-validated).
fn process_one(s: &Shared, model: &dyn Model, name: &str, req: &Request) -> Result<Response> {
    let queue_ns = req.arrived.elapsed().as_nanos();
    classify_ops(s, model, 1)?;
    let t0 = Instant::now();
    let (logits, layer_times) = model.forward_timed(&req.frames);
    let total_ns = queue_ns + t0.elapsed().as_nanos();
    s.metrics.observe_latency_for(name, (total_ns / 1_000) as u64);
    Ok(Response { id: req.id, logits, layer_times, queue_ns, total_ns })
}

/// The multi-request path: one batched forward for the whole group,
/// per-request outputs scattered back to their reply channels.
fn process_group(s: &Shared, model: &dyn Model, name: &str, items: Vec<(Request, Reply)>) {
    let n = items.len();
    if let Err(e) = classify_ops(s, model, n) {
        // no GEMM was dispatched: these count as per-request errors on
        // the singleton side, keeping batched_requests true to its
        // "served through a batched dispatch" meaning
        let msg = e.to_string();
        s.metrics.record_singleton(name, n as u64);
        s.metrics.record_errors(name, n as u64);
        for (_, reply) in items {
            let _ = reply.send(Err(anyhow!("{msg}")));
        }
        return;
    }
    let queue_ns: Vec<u128> = items.iter().map(|(r, _)| r.arrived.elapsed().as_nanos()).collect();
    let t0 = Instant::now();
    let results = {
        let frame_refs: Vec<&[f32]> = items.iter().map(|(r, _)| r.frames.as_slice()).collect();
        model.forward_batch(&frame_refs)
    };
    let compute_ns = t0.elapsed().as_nanos();
    s.metrics.record_batched_dispatch(name, n as u64);
    for (((req, reply), (logits, layer_times)), q) in
        items.into_iter().zip(results).zip(queue_ns)
    {
        let total_ns = q + compute_ns;
        s.metrics.observe_latency_for(name, (total_ns / 1_000) as u64);
        let _ = reply.send(Ok(Response { id: req.id, logits, layer_times, queue_ns: q, total_ns }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::DeepSpeechConfig;
    use crate::pack::Variant;

    fn tiny_engine(variant: &str) -> Engine {
        let e = Engine::new(EngineConfig {
            workers: 2,
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(1),
                max_queue: 64,
            },
            router: RouterConfig::default(),
        });
        let m = DeepSpeech::new(DeepSpeechConfig::TINY, Variant::parse(variant).unwrap(), 5);
        e.register_model("deepspeech", m);
        e
    }

    fn frames() -> Vec<f32> {
        let cfg = DeepSpeechConfig::TINY;
        (0..cfg.time_steps * cfg.n_input).map(|i| (i as f32 * 0.01).sin()).collect()
    }

    #[test]
    fn infer_roundtrip() {
        let e = tiny_engine("w4a8");
        let r = e.infer("deepspeech", frames()).unwrap();
        let cfg = DeepSpeechConfig::TINY;
        assert_eq!(r.logits.len(), cfg.time_steps * cfg.n_output);
        assert_eq!(r.layer_times.len(), 6);
        assert!(r.total_ns > 0);
        assert_eq!(e.metrics().completed.load(Relaxed), 1);
        let (gemv, gemm) = e.router().counts();
        assert_eq!(gemv, 1); // the LSTM layer
        assert_eq!(gemm, 5); // the five FC layers
        // a lone request is a singleton dispatch, engine-wide and
        // under the model's own name
        assert_eq!(e.metrics().dispatch_counts(), (0, 1));
        assert_eq!(e.metrics().model_dispatch_counts("deepspeech"), (0, 1));
        assert_eq!(e.model_names(), vec!["deepspeech".to_string()]);
    }

    #[test]
    fn unknown_model_is_error() {
        let e = tiny_engine("w4a8");
        assert!(e.infer("nope", frames()).is_err());
        assert_eq!(e.metrics().errors.load(Relaxed), 1);
    }

    #[test]
    fn bad_frame_len_is_error() {
        let e = tiny_engine("w4a8");
        assert!(e.infer("deepspeech", vec![0.0; 3]).is_err());
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let e = tiny_engine("w2a2");
        let rxs: Vec<_> = (0..16).map(|_| e.submit("deepspeech", frames()).unwrap()).collect();
        let mut ok = 0;
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert!(r.logits.iter().all(|x| x.is_finite()));
            ok += 1;
        }
        assert_eq!(ok, 16);
        assert_eq!(e.metrics().completed.load(Relaxed), 16);
        assert!(e.metrics().throughput_rps() > 0.0);
        // every request dispatched exactly once, batched or singleton
        let (batched, singleton) = e.metrics().dispatch_counts();
        assert_eq!(batched + singleton, 16);
    }

    #[test]
    fn shutdown_drains() {
        let e = tiny_engine("w1a1");
        let rx = e.submit("deepspeech", frames()).unwrap();
        e.shutdown();
        // the queued request was served before exit
        assert!(rx.recv().unwrap().is_ok());
    }

    #[test]
    fn deterministic_across_engines() {
        let a = tiny_engine("w4a8").infer("deepspeech", frames()).unwrap().logits;
        let b = tiny_engine("w4a8").infer("deepspeech", frames()).unwrap().logits;
        assert_eq!(a, b);
    }
}

//! Path router — the paper's §4.6 execution policy as a first-class
//! component: single-batch sub-byte ops take the FullPack GEMV kernels;
//! multi-batch ops take the Ruy-like W8A8 GEMM path ("FullPack does not
//! support GEMM, so we used Ruy-W8A8 for the GEMM operations"); pure
//! f32 models fall through to the FP32 kernels.

use super::request::{OpDesc, Path};

/// Routing policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// largest batch still routed to the GEMV path (paper: 1)
    pub gemv_max_batch: usize,
    /// force everything onto the baseline path (ablation switch)
    pub disable_fullpack: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { gemv_max_batch: 1, disable_fullpack: false }
    }
}

/// Stateless router (kept as a struct for config + stats).
#[derive(Debug, Default)]
pub struct Router {
    pub config: RouterConfig,
    pub gemv_routed: std::sync::atomic::AtomicU64,
    pub gemm_routed: std::sync::atomic::AtomicU64,
}

impl Router {
    pub fn new(config: RouterConfig) -> Self {
        Router { config, ..Default::default() }
    }

    /// Choose the execution path for one op.
    pub fn route(&self, op: &OpDesc) -> Path {
        use std::sync::atomic::Ordering::Relaxed;
        if !op.sub_byte {
            self.gemm_routed.fetch_add(1, Relaxed);
            return Path::RuyGemm;
        }
        if self.config.disable_fullpack || op.batch > self.config.gemv_max_batch {
            self.gemm_routed.fetch_add(1, Relaxed);
            Path::RuyGemm
        } else {
            self.gemv_routed.fetch_add(1, Relaxed);
            Path::FullPackGemv
        }
    }

    pub fn counts(&self) -> (u64, u64) {
        use std::sync::atomic::Ordering::Relaxed;
        (self.gemv_routed.load(Relaxed), self.gemm_routed.load(Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(batch: usize, sub_byte: bool) -> OpDesc {
        OpDesc { batch, z: 2048, k: 2048, sub_byte }
    }

    #[test]
    fn paper_policy() {
        let r = Router::default();
        // single-batch sub-byte LSTM step -> FullPack
        assert_eq!(r.route(&op(1, true)), Path::FullPackGemv);
        // batch-16 FC -> Ruy GEMM even when quantized sub-byte
        assert_eq!(r.route(&op(16, true)), Path::RuyGemm);
        // 8-bit ops always take the baseline
        assert_eq!(r.route(&op(1, false)), Path::RuyGemm);
        let (gemv, gemm) = r.counts();
        assert_eq!((gemv, gemm), (1, 2));
    }

    #[test]
    fn ablation_switch() {
        let r = Router::new(RouterConfig { disable_fullpack: true, ..Default::default() });
        assert_eq!(r.route(&op(1, true)), Path::RuyGemm);
    }

    #[test]
    fn batch_threshold() {
        let r = Router::new(RouterConfig { gemv_max_batch: 4, ..Default::default() });
        assert_eq!(r.route(&op(4, true)), Path::FullPackGemv);
        assert_eq!(r.route(&op(5, true)), Path::RuyGemm);
    }
}

//! Plan router — the paper's §4.6 execution policy as a first-class
//! component: single-batch sub-byte ops take the FullPack GEMV kernels;
//! multi-batch ops take the Ruy-like W8A8 GEMM path ("FullPack does not
//! support GEMM, so we used Ruy-W8A8 for the GEMM operations").
//!
//! The router no longer names paths or kernels itself: it binds the
//! policy knobs to a `kernels::PlanBuilder` and emits executable
//! [`Plan`]s, so every backend decision flows through the
//! `KernelRegistry` (DESIGN.md §3).

use super::request::OpDesc;
use crate::kernels::{KernelError, LayerShape, Plan, PlanBuilder, SelectPolicy};

/// Routing policy knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouterConfig {
    /// largest batch still routed to the GEMV path (paper: 1)
    pub gemv_max_batch: usize,
    /// force everything onto the baseline path (ablation switch)
    pub disable_fullpack: bool,
    /// route *sub-byte* GEMV ops to the `-swar` kernel tier when the
    /// variant has one and the depth permits (hosts without trustworthy
    /// auto-vectorization, DESIGN.md §8).  8-bit ops keep the paper's
    /// Ruy path regardless — `fullpack-w8a8-swar` is reachable only via
    /// `SelectPolicy::Explicit` or `CostModel`.
    pub prefer_swar: bool,
    /// route batched *sub-byte* ops to the native `fullpack-*-gemm`
    /// backend instead of widening onto the Ruy-like W8A8 GEMM rival
    /// (DESIGN.md §9).  Off by default, preserving the paper's "route
    /// GEMM to Ruy" protocol.  Note the stock DeepSpeech model's FC
    /// stack holds W8A8 weights by construction (and is classified as
    /// such), so this knob changes execution only for sub-byte batched
    /// ops planned through the router — not the built-in model's FC
    /// layers.
    pub prefer_gemm: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            gemv_max_batch: 1,
            disable_fullpack: false,
            prefer_swar: false,
            prefer_gemm: false,
        }
    }
}

/// Stateless router (kept as a struct for config + stats).
#[derive(Debug, Default)]
pub struct Router {
    /// the policy knobs this router binds to every plan
    pub config: RouterConfig,
    /// ops routed to the FullPack GEMV path (incl. the SWAR tier)
    pub gemv_routed: std::sync::atomic::AtomicU64,
    /// ops routed to the baseline GEMM path
    pub gemm_routed: std::sync::atomic::AtomicU64,
}

impl Router {
    /// A router with the given policy knobs and zeroed counters.
    pub fn new(config: RouterConfig) -> Self {
        Router { config, ..Default::default() }
    }

    fn builder(&self, op: &OpDesc) -> PlanBuilder {
        let policy = if self.config.disable_fullpack {
            SelectPolicy::Explicit("ruy-w8a8".into())
        } else {
            SelectPolicy::PaperRule
        };
        PlanBuilder::new(LayerShape { z: op.z, k: op.k, batch: op.batch }, op.variant)
            .gemv_max_batch(self.config.gemv_max_batch)
            .prefer_swar(self.config.prefer_swar)
            .prefer_gemm(self.config.prefer_gemm)
            .policy(policy)
    }

    fn count(&self, kernel_name: &str) {
        use std::sync::atomic::Ordering::Relaxed;
        // the GEMM tier (any `-gemm` backend, incl. fullpack-*-gemm)
        // counts as the batched path; FullPack GEMV/SWAR as the GEMV
        // path; everything else is the baseline GEMM fallback
        if kernel_name.ends_with("-gemm") || !kernel_name.starts_with("fullpack-") {
            self.gemm_routed.fetch_add(1, Relaxed);
        } else {
            self.gemv_routed.fetch_add(1, Relaxed);
        }
    }

    /// Bind the §4.6 policy to one op: emit an executable plan.
    pub fn plan(&self, op: &OpDesc) -> Result<Plan, KernelError> {
        let plan = self.builder(op).build()?;
        self.count(plan.kernel_name());
        Ok(plan)
    }

    /// Policy decision only: the registry kernel name this op routes to
    /// (the GEMM backend's for batched ops), with counters updated but
    /// no plan (scratch, Arc) constructed — the cheap per-request stats
    /// path.
    pub fn classify(&self, op: &OpDesc) -> Result<&'static str, KernelError> {
        let sel = self.builder(op).select()?;
        let name = sel.name();
        self.count(name);
        Ok(name)
    }

    /// `(gemv_routed, gemm_routed)` counter snapshot.
    pub fn counts(&self) -> (u64, u64) {
        use std::sync::atomic::Ordering::Relaxed;
        (self.gemv_routed.load(Relaxed), self.gemm_routed.load(Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pack::Variant;

    fn op(batch: usize, variant: &str) -> OpDesc {
        OpDesc { batch, z: 2048, k: 2048, variant: Variant::parse(variant).unwrap() }
    }

    #[test]
    fn paper_policy() {
        let r = Router::default();
        // single-batch sub-byte LSTM step -> FullPack
        assert_eq!(r.plan(&op(1, "w4a8")).unwrap().kernel_name(), "fullpack-w4a8");
        // batch-16 FC -> the Ruy-like GEMM backend even when quantized
        // sub-byte (the paper's protocol as a first-class GEMM plan)
        let p = r.plan(&op(16, "w4a8")).unwrap();
        assert_eq!(p.kernel_name(), "ruy-like-w8a8-gemm");
        assert!(p.is_batched());
        // 8-bit single-column ops take the baseline GEMV
        assert_eq!(r.plan(&op(1, "w8a8")).unwrap().kernel_name(), "ruy-w8a8");
        let (gemv, gemm) = r.counts();
        assert_eq!((gemv, gemm), (1, 2));
    }

    #[test]
    fn prefer_gemm_promotes_flushed_subbyte_batches() {
        let r = Router::new(RouterConfig { prefer_gemm: true, ..Default::default() });
        // a flushed multi-request batch on sub-byte data -> native GEMM
        let p = r.plan(&op(16, "w4a8")).unwrap();
        assert_eq!(p.kernel_name(), "fullpack-w4a8-gemm");
        assert!(p.is_batched() && p.is_fullpack());
        // counted as the batched path
        assert_eq!(r.counts().1, 1);
        // single-column ops are untouched by the knob
        assert_eq!(r.plan(&op(1, "w4a8")).unwrap().kernel_name(), "fullpack-w4a8");
        // variants without a GEMM-tier entry keep the Ruy-like rival
        assert_eq!(r.plan(&op(16, "w4a4")).unwrap().kernel_name(), "ruy-like-w8a8-gemm");
    }

    #[test]
    fn ablation_switch() {
        let r = Router::new(RouterConfig { disable_fullpack: true, ..Default::default() });
        assert_eq!(r.plan(&op(1, "w4a8")).unwrap().kernel_name(), "ruy-w8a8");
    }

    #[test]
    fn prefer_swar_routes_gemv_to_the_tier() {
        let r = Router::new(RouterConfig { prefer_swar: true, ..Default::default() });
        // deep single-batch sub-byte op with a SWAR backend -> the tier
        assert_eq!(r.plan(&op(1, "w4a8")).unwrap().kernel_name(), "fullpack-w4a8-swar");
        // still counted as the GEMV path
        assert_eq!(r.counts().0, 1);
        // variants without a SWAR backend keep the staged kernel
        assert_eq!(r.plan(&op(1, "w2a2")).unwrap().kernel_name(), "fullpack-w2a2");
        // batches still take the baseline GEMM path
        assert_eq!(r.plan(&op(16, "w4a8")).unwrap().kernel_name(), "ruy-like-w8a8-gemm");
    }

    #[test]
    fn batch_threshold() {
        let r = Router::new(RouterConfig { gemv_max_batch: 4, ..Default::default() });
        assert_eq!(r.plan(&op(4, "w2a2")).unwrap().kernel_name(), "fullpack-w2a2");
        assert_eq!(r.plan(&op(5, "w2a2")).unwrap().kernel_name(), "ruy-like-w8a8-gemm");
    }

    #[test]
    fn classify_matches_plan() {
        let r = Router::default();
        assert_eq!(r.classify(&op(1, "w4a8")).unwrap(), "fullpack-w4a8");
        assert_eq!(r.classify(&op(16, "w4a8")).unwrap(), "ruy-like-w8a8-gemm");
        let (gemv, gemm) = r.counts();
        assert_eq!((gemv, gemm), (1, 1));
    }
}

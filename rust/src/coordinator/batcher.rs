//! Dynamic batcher: coalesces independent requests into a batch before
//! dispatch, bounded by a max batch size and a flush deadline.  The
//! DeepSpeech FC front-end is a batch-16 GEMM in the paper; the batcher
//! is how a serving deployment reaches that batch from independent
//! arrivals while bounding added latency (backpressure: `push` reports
//! a full queue instead of growing unboundedly).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// flush as soon as this many requests are waiting
    pub max_batch: usize,
    /// flush a non-empty partial batch after this long
    pub max_wait: Duration,
    /// reject new work beyond this queue depth (backpressure)
    pub max_queue: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            max_queue: 1024,
        }
    }
}

/// A queued item plus its arrival time.
#[derive(Debug)]
struct Entry<T> {
    item: T,
    arrived: Instant,
}

/// Deadline-based dynamic batcher (single consumer; callers lock it).
#[derive(Debug)]
pub struct Batcher<T> {
    cfg: BatcherConfig,
    queue: VecDeque<Entry<T>>,
}

/// Why `pop_batch` returned a batch (for tests/metrics).
#[derive(Debug, PartialEq, Eq, Clone, Copy)]
pub enum FlushReason {
    /// the batch reached `max_batch`
    Full,
    /// the oldest entry waited past `max_wait`
    Deadline,
    /// a forced drain (shutdown)
    Drained,
}

impl<T> Batcher<T> {
    /// An empty batcher with the given policy.
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher { cfg, queue: VecDeque::new() }
    }

    /// Enqueue; `Err(item)` when the queue is full (backpressure).
    pub fn push(&mut self, item: T) -> Result<(), T> {
        if self.queue.len() >= self.cfg.max_queue {
            return Err(item);
        }
        self.queue.push_back(Entry { item, arrived: Instant::now() });
        Ok(())
    }

    /// Queued (not yet popped) items.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Is a batch ready (full, or the oldest entry has waited past the
    /// deadline)?
    pub fn ready(&self) -> bool {
        if self.queue.len() >= self.cfg.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(e) => e.arrived.elapsed() >= self.cfg.max_wait,
            None => false,
        }
    }

    /// Time until the current partial batch must flush (consumers can
    /// sleep this long), `None` when empty.
    pub fn time_to_deadline(&self) -> Option<Duration> {
        self.queue.front().map(|e| self.cfg.max_wait.saturating_sub(e.arrived.elapsed()))
    }

    /// Take up to `max_batch` items if ready (or `force`).
    pub fn pop_batch(&mut self, force: bool) -> Option<(Vec<T>, FlushReason)> {
        if self.queue.is_empty() {
            return None;
        }
        let full = self.queue.len() >= self.cfg.max_batch;
        let due = self
            .queue
            .front()
            .map(|e| e.arrived.elapsed() >= self.cfg.max_wait)
            .unwrap_or(false);
        if !(full || due || force) {
            return None;
        }
        let n = self.queue.len().min(self.cfg.max_batch);
        let batch: Vec<T> = self.queue.drain(..n).map(|e| e.item).collect();
        let reason = if full {
            FlushReason::Full
        } else if due {
            FlushReason::Deadline
        } else {
            FlushReason::Drained
        };
        Some((batch, reason))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_batch: usize, wait_ms: u64, max_queue: usize) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
            max_queue,
        }
    }

    #[test]
    fn flushes_when_full() {
        let mut b = Batcher::new(cfg(4, 1000, 100));
        for i in 0..4 {
            b.push(i).unwrap();
        }
        assert!(b.ready());
        let (batch, reason) = b.pop_batch(false).unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert_eq!(reason, FlushReason::Full);
        assert!(b.is_empty());
    }

    #[test]
    fn partial_not_ready_before_deadline() {
        let mut b = Batcher::new(cfg(4, 1000, 100));
        b.push(1).unwrap();
        assert!(!b.ready());
        assert!(b.pop_batch(false).is_none());
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn deadline_flush() {
        let mut b = Batcher::new(cfg(16, 1, 100));
        b.push(7).unwrap();
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.ready());
        let (batch, reason) = b.pop_batch(false).unwrap();
        assert_eq!(batch, vec![7]);
        assert_eq!(reason, FlushReason::Deadline);
    }

    #[test]
    fn force_drain() {
        let mut b = Batcher::new(cfg(16, 10_000, 100));
        b.push(1).unwrap();
        b.push(2).unwrap();
        let (batch, reason) = b.pop_batch(true).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(reason, FlushReason::Drained);
    }

    #[test]
    fn backpressure() {
        let mut b = Batcher::new(cfg(4, 1000, 2));
        b.push(1).unwrap();
        b.push(2).unwrap();
        assert_eq!(b.push(3), Err(3));
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn oversize_queue_flushes_in_chunks() {
        let mut b = Batcher::new(cfg(2, 1000, 100));
        for i in 0..5 {
            b.push(i).unwrap();
        }
        assert_eq!(b.pop_batch(false).unwrap().0, vec![0, 1]);
        assert_eq!(b.pop_batch(false).unwrap().0, vec![2, 3]);
        assert_eq!(b.pop_batch(true).unwrap().0, vec![4]);
        assert!(b.pop_batch(true).is_none());
    }

    #[test]
    fn time_to_deadline_decreases() {
        let mut b = Batcher::new(cfg(4, 50, 10));
        assert!(b.time_to_deadline().is_none());
        b.push(0).unwrap();
        let d = b.time_to_deadline().unwrap();
        assert!(d <= Duration::from_millis(50));
    }
}

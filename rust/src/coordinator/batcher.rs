//! Dynamic batcher: coalesces independent requests into a batch before
//! dispatch, bounded by a max batch size and a flush deadline.  The
//! DeepSpeech FC front-end is a batch-16 GEMM in the paper; the batcher
//! is how a serving deployment reaches that batch from independent
//! arrivals while bounding added latency (backpressure: `push` reports
//! a full queue instead of growing unboundedly).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatcherConfig {
    /// flush as soon as this many requests are waiting
    pub max_batch: usize,
    /// flush a non-empty partial batch after this long
    pub max_wait: Duration,
    /// reject new work beyond this queue depth (backpressure)
    pub max_queue: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            max_queue: 1024,
        }
    }
}

/// A queued item plus its arrival time.
#[derive(Debug)]
struct Entry<T> {
    item: T,
    arrived: Instant,
}

/// Deadline-based dynamic batcher (single consumer; callers lock it).
#[derive(Debug)]
pub struct Batcher<T> {
    cfg: BatcherConfig,
    queue: VecDeque<Entry<T>>,
}

/// Why `pop_batch` returned a batch (for tests/metrics).
#[derive(Debug, PartialEq, Eq, Clone, Copy)]
pub enum FlushReason {
    /// the batch reached `max_batch`
    Full,
    /// the oldest entry waited past `max_wait`
    Deadline,
    /// a forced drain (shutdown)
    Drained,
}

impl<T> Batcher<T> {
    /// An empty batcher with the given policy.
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher { cfg, queue: VecDeque::new() }
    }

    /// Enqueue; `Err(item)` when the queue is full (backpressure).
    pub fn push(&mut self, item: T) -> Result<(), T> {
        if self.queue.len() >= self.cfg.max_queue {
            return Err(item);
        }
        self.queue.push_back(Entry { item, arrived: Instant::now() });
        Ok(())
    }

    /// Queued (not yet popped) items.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Is a batch ready (full, or the oldest entry has waited past the
    /// deadline)?
    pub fn ready(&self) -> bool {
        if self.queue.len() >= self.cfg.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(e) => e.arrived.elapsed() >= self.cfg.max_wait,
            None => false,
        }
    }

    /// Time until the current partial batch must flush (consumers can
    /// sleep this long), `None` when empty.
    pub fn time_to_deadline(&self) -> Option<Duration> {
        self.queue.front().map(|e| self.cfg.max_wait.saturating_sub(e.arrived.elapsed()))
    }

    /// Take up to `max_batch` items if ready (or `force`).
    pub fn pop_batch(&mut self, force: bool) -> Option<(Vec<T>, FlushReason)> {
        if self.queue.is_empty() {
            return None;
        }
        let full = self.queue.len() >= self.cfg.max_batch;
        let due = self
            .queue
            .front()
            .map(|e| e.arrived.elapsed() >= self.cfg.max_wait)
            .unwrap_or(false);
        if !(full || due || force) {
            return None;
        }
        let n = self.queue.len().min(self.cfg.max_batch);
        let batch: Vec<T> = self.queue.drain(..n).map(|e| e.item).collect();
        let reason = if full {
            FlushReason::Full
        } else if due {
            FlushReason::Deadline
        } else {
            FlushReason::Drained
        };
        Some((batch, reason))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_batch: usize, wait_ms: u64, max_queue: usize) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
            max_queue,
        }
    }

    #[test]
    fn flushes_when_full() {
        let mut b = Batcher::new(cfg(4, 1000, 100));
        for i in 0..4 {
            b.push(i).unwrap();
        }
        assert!(b.ready());
        let (batch, reason) = b.pop_batch(false).unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert_eq!(reason, FlushReason::Full);
        assert!(b.is_empty());
    }

    #[test]
    fn partial_not_ready_before_deadline() {
        let mut b = Batcher::new(cfg(4, 1000, 100));
        b.push(1).unwrap();
        assert!(!b.ready());
        assert!(b.pop_batch(false).is_none());
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn deadline_flush() {
        let mut b = Batcher::new(cfg(16, 1, 100));
        b.push(7).unwrap();
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.ready());
        let (batch, reason) = b.pop_batch(false).unwrap();
        assert_eq!(batch, vec![7]);
        assert_eq!(reason, FlushReason::Deadline);
    }

    #[test]
    fn force_drain() {
        let mut b = Batcher::new(cfg(16, 10_000, 100));
        b.push(1).unwrap();
        b.push(2).unwrap();
        let (batch, reason) = b.pop_batch(true).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(reason, FlushReason::Drained);
    }

    #[test]
    fn backpressure() {
        let mut b = Batcher::new(cfg(4, 1000, 2));
        b.push(1).unwrap();
        b.push(2).unwrap();
        assert_eq!(b.push(3), Err(3));
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn oversize_queue_flushes_in_chunks() {
        let mut b = Batcher::new(cfg(2, 1000, 100));
        for i in 0..5 {
            b.push(i).unwrap();
        }
        assert_eq!(b.pop_batch(false).unwrap().0, vec![0, 1]);
        assert_eq!(b.pop_batch(false).unwrap().0, vec![2, 3]);
        assert_eq!(b.pop_batch(true).unwrap().0, vec![4]);
        assert!(b.pop_batch(true).is_none());
    }

    #[test]
    fn time_to_deadline_decreases() {
        let mut b = Batcher::new(cfg(4, 50, 10));
        assert!(b.time_to_deadline().is_none());
        b.push(0).unwrap();
        let d = b.time_to_deadline().unwrap();
        assert!(d <= Duration::from_millis(50));
    }

    #[test]
    fn full_takes_precedence_over_deadline() {
        // a batch that is both full AND past its deadline reports Full —
        // metrics must attribute the flush to capacity, not latency
        let m = crate::coordinator::Metrics::default();
        let mut b = Batcher::new(cfg(2, 1, 100));
        b.push(1).unwrap();
        b.push(2).unwrap();
        std::thread::sleep(Duration::from_millis(3));
        let (_, reason) = b.pop_batch(false).unwrap();
        assert_eq!(reason, FlushReason::Full);
        m.record_flush(reason);
        assert_eq!(m.flush_counts(), (1, 0, 0));
    }

    #[test]
    fn drained_reported_only_for_forced_early_flushes() {
        // force=true on a partial, non-expired batch -> Drained; the
        // same force on an expired batch still reports Deadline
        let m = crate::coordinator::Metrics::default();
        let mut b = Batcher::new(cfg(16, 10_000, 100));
        b.push(1).unwrap();
        let (_, r1) = b.pop_batch(true).unwrap();
        assert_eq!(r1, FlushReason::Drained);
        m.record_flush(r1);
        let mut b = Batcher::new(cfg(16, 1, 100));
        b.push(1).unwrap();
        std::thread::sleep(Duration::from_millis(3));
        let (_, r2) = b.pop_batch(true).unwrap();
        assert_eq!(r2, FlushReason::Deadline);
        m.record_flush(r2);
        assert_eq!(m.flush_counts(), (0, 1, 1));
    }

    #[test]
    fn shutdown_drain_empties_in_order_across_flushes() {
        // the worker's shutdown path: repeated forced pops drain the
        // whole queue FIFO in max_batch-sized chunks, then yield None
        let m = crate::coordinator::Metrics::default();
        let mut b = Batcher::new(cfg(3, 10_000, 100));
        for i in 0..7 {
            b.push(i).unwrap();
        }
        let mut drained = Vec::new();
        while let Some((batch, reason)) = b.pop_batch(true) {
            assert!(batch.len() <= 3);
            assert!(matches!(reason, FlushReason::Full | FlushReason::Drained));
            m.record_flush(reason);
            drained.extend(batch);
        }
        assert_eq!(drained, (0..7).collect::<Vec<_>>());
        assert!(b.is_empty());
        assert!(b.pop_batch(true).is_none());
        // 7 items in max_batch=3 chunks: two Full flushes (3, 3) and
        // one forced Drained flush for the remainder (1)
        assert_eq!(m.flush_counts(), (2, 0, 1));
    }

    #[test]
    fn backpressure_recovers_after_drain() {
        // a rejected push leaves the queue intact; capacity freed by a
        // flush is immediately reusable
        let mut b = Batcher::new(cfg(2, 10_000, 2));
        b.push(1).unwrap();
        b.push(2).unwrap();
        assert_eq!(b.push(3), Err(3));
        assert_eq!(b.len(), 2);
        let (batch, _) = b.pop_batch(false).unwrap(); // full -> flushes
        assert_eq!(batch, vec![1, 2]);
        b.push(4).unwrap();
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn expired_deadline_saturates_to_zero() {
        let mut b = Batcher::new(cfg(16, 1, 10));
        b.push(0).unwrap();
        std::thread::sleep(Duration::from_millis(3));
        // saturating_sub: an expired deadline reports zero, not a panic
        assert_eq!(b.time_to_deadline().unwrap(), Duration::ZERO);
        assert!(b.ready());
    }
}

//! Analytic CPU cost model — the cycle/instruction half of the gem5
//! stand-in (the memory half is `crate::sim`).
//!
//! For every method the paper compares (§4.1), [`Method`] gives
//!
//! * the **memory traffic** of one GEMV call ([`Method::traffic`]) —
//!   replayed through the cache simulator for the Fig. 6/7 metrics, and
//! * the **instruction mix** ([`Method::instr_mix`]) — closed-form
//!   counts of vector loads, MACs, shift/ALU ops, and scalar
//!   bookkeeping per call, derived from each kernel's inner-loop
//!   structure (ours from `crate::kernels`, rivals from their published
//!   micro-kernels).
//!
//! [`CoreModel`] folds both into cycles for an ex5_big-class (3-wide
//! OoO, dual NEON pipe) core: `cycles = compute + stalls`, with
//! `compute = max(load-pipe, SIMD-pipe) + scalar-pipe` and stalls from
//! the simulated per-level miss counts discounted by an
//! overlap factor (OoO cores hide part of each miss under other work).
//! Absolute cycles are a model; the paper-facing outputs are *ratios*
//! between methods, which the figures compare (DESIGN.md §2).

pub mod methods;

pub use methods::{InstrMix, Method};

use crate::sim::{
    replay_gemm, replay_gemm_lut, replay_gemm_restream, replay_gemv, replay_gemv_lut,
    replay_gemv_lut_restream, CachePreset, CacheStats, GemmTraffic, Hierarchy, ReplayStats,
};

/// Pipeline/throughput description of the modeled core.
#[derive(Debug, Clone, Copy)]
pub struct CoreModel {
    /// 16-byte vector loads per cycle
    pub load_tp: f64,
    /// widening MACs per cycle (NEON smlal class) — dual SIMD pipes
    pub mac_tp: f64,
    /// vector ALU ops (shifts, adds) per cycle (shares the SIMD pipes)
    pub alu_tp: f64,
    /// scalar/bookkeeping instructions per cycle
    pub scalar_tp: f64,
    /// fraction of an L2-hit latency hidden by the OoO window
    pub l2_overlap: f64,
    /// fraction of a DRAM miss latency hidden by the OoO window
    pub mem_overlap: f64,
    /// how much of the staged 16-lane loops the compiler actually turns
    /// into SIMD, in `[0, 1]`: 1.0 = the paper's hand-written NEON
    /// (every lane op is one instruction), 0.0 = fully serialized
    /// lane-by-lane scalar code.  The SWAR tier (`Method::FullPackSwar`)
    /// is immune to this knob — that is its reason to exist
    /// (DESIGN.md §8).
    pub autovec_eff: f64,
    /// core frequency in GHz (for reporting only; ratios are unitless)
    pub freq_ghz: f64,
    /// SIMD register width in bytes this model is calibrated for — the
    /// gate `Method::min_lane_bytes` is compared against, so the
    /// CostModel policy only considers real-ISA kernels on cores whose
    /// vector unit the model actually describes (DESIGN.md §15).
    /// 0.0 = no calibrated ISA tier ([`CoreModel::portable`]).
    pub vec_bytes: f64,
    /// SIMD pipes that can issue per cycle — the width the real-ISA
    /// throughput numbers (`mac_tp`, `alu_tp`) are derived from in the
    /// per-ISA constructors ([`CoreModel::avx2`], [`CoreModel::neon`]).
    pub simd_issue: f64,
}

impl CoreModel {
    /// gem5 Table 1: modified ex5_big @ 2.45 GHz.
    pub fn ex5_big() -> Self {
        CoreModel {
            load_tp: 1.0,
            mac_tp: 2.0,
            // simple vector shifts dual-issue on both SIMD pipes and are
            // half the cost of a widening MAC pair
            alu_tp: 4.0,
            scalar_tp: 2.0,
            l2_overlap: 0.7,
            mem_overlap: 0.4,
            autovec_eff: 1.0,
            freq_ghz: 2.45,
            // 128-bit NEON, dual issue — the widths behind the two
            // throughput lines above
            vec_bytes: 16.0,
            simd_issue: 2.0,
        }
    }

    /// Table 2: Cortex-A72 (RPi 4) @ 1.5 GHz — narrower OoO window.
    pub fn cortex_a72() -> Self {
        CoreModel {
            load_tp: 1.0,
            mac_tp: 2.0,
            alu_tp: 4.0,
            scalar_tp: 2.0,
            l2_overlap: 0.6,
            mem_overlap: 0.3,
            autovec_eff: 1.0,
            freq_ghz: 1.5,
            vec_bytes: 16.0,
            simd_issue: 2.0,
        }
    }

    /// A portable 64-bit host whose auto-vectorizer cannot be trusted
    /// with the staged lane loops (`autovec_eff = 0.25`): the selection
    /// regime the SWAR kernel tier targets.  `vec_bytes = 0` — this
    /// profile describes no particular vector unit, so the real-ISA
    /// tier is never selected under it even when the host registered
    /// ISA kernels.  Everything else matches ex5_big so SWAR-vs-staged
    /// comparisons isolate the one knob.
    pub fn portable() -> Self {
        CoreModel { autovec_eff: 0.25, freq_ghz: 3.0, vec_bytes: 0.0, ..CoreModel::ex5_big() }
    }

    /// An AVX2-class x86-64 core (256-bit integer SIMD, dual issue):
    /// the calibration the `fullpack-*-avx2` kernels are costed on.
    /// The staged-lane knob stays pessimistic (`autovec_eff = 0.25`,
    /// like [`CoreModel::portable`]) — on such hosts the portable tiers
    /// lean on a vectorizer, but the real-ISA tier does not, which is
    /// exactly the regime where it wins (DESIGN.md §15).
    pub fn avx2() -> Self {
        let simd_issue = 2.0;
        CoreModel {
            // two load ports feed the 32-byte lanes
            load_tp: 2.0,
            // maddubs/madd chains issue one per SIMD pipe
            mac_tp: simd_issue,
            // simple vector ALU ops dual-issue per pipe
            alu_tp: 2.0 * simd_issue,
            scalar_tp: 2.0,
            l2_overlap: 0.7,
            mem_overlap: 0.4,
            autovec_eff: 0.25,
            freq_ghz: 3.0,
            vec_bytes: 32.0,
            simd_issue,
        }
    }

    /// A NEON aarch64 core with an untrusted auto-vectorizer — ex5_big
    /// pipes, but staged tiers degrade while the `fullpack-*-neon`
    /// intrinsic kernels run at full modeled throughput.  (On the
    /// paper's own hand-tuned-NEON calibration, [`CoreModel::ex5_big`],
    /// the staged kernels already model the NEON assembly — there the
    /// ISA tier ties rather than wins.)
    pub fn neon() -> Self {
        CoreModel { autovec_eff: 0.25, ..CoreModel::ex5_big() }
    }

    /// Degrade a lane-staged instruction mix by the core's
    /// auto-vectorization effectiveness: each vector-class op count is
    /// scaled by `f + (1 - f) · VL` (one instruction per lane when the
    /// vectorizer gives up entirely).
    pub fn degrade_staged(&self, m: InstrMix) -> InstrMix {
        let f = self.autovec_eff.clamp(0.0, 1.0);
        if f >= 1.0 {
            return m;
        }
        let lanes = crate::pack::VL as f64;
        let scale = f + (1.0 - f) * lanes;
        InstrMix {
            loads: m.loads * scale,
            stores: m.stores,
            macs: m.macs * scale,
            alus: m.alus * scale,
            scalar: m.scalar,
        }
    }

    /// Cycles spent on computation alone (no memory stalls).
    pub fn compute_cycles(&self, m: &InstrMix) -> f64 {
        let load = m.loads / self.load_tp;
        let simd = m.macs / self.mac_tp + m.alus / self.alu_tp;
        let scalar = (m.scalar + m.stores) / self.scalar_tp;
        load.max(simd) + scalar
    }

    /// Stall cycles from the hierarchy's per-level stats.
    ///
    /// Every level-`i` miss that hits level `i+1` pays that level's hit
    /// latency (discounted by `l2_overlap`); LLC misses pay DRAM
    /// latency (discounted by `mem_overlap`).
    pub fn stall_cycles(&self, h: &Hierarchy) -> f64 {
        let mut stalls = 0.0;
        let depth = h.depth();
        for i in 0..depth {
            let st = h.level_stats(i);
            if i + 1 < depth {
                let next = h.level_config(i + 1);
                let hits_below = st.misses - h.level_stats(i + 1).misses.min(st.misses);
                stalls += hits_below as f64 * next.hit_latency as f64 * (1.0 - self.l2_overlap);
            } else {
                stalls += st.misses as f64 * h.mem_latency as f64 * (1.0 - self.mem_overlap);
            }
        }
        stalls
    }
}

/// Modeled execution of one GEMV (or one ULPPACK— batch-8 GEMM).
#[derive(Debug, Clone, Copy)]
pub struct SimResult {
    pub cycles: f64,
    pub instrs: f64,
    pub compute_cycles: f64,
    pub stall_cycles: f64,
    pub llc: CacheStats,
    pub l1: CacheStats,
}

impl SimResult {
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0.0 {
            0.0
        } else {
            self.instrs / self.cycles
        }
    }

    /// Wall-clock estimate in microseconds at the core's frequency.
    pub fn micros(&self, core: &CoreModel) -> f64 {
        self.cycles / (core.freq_ghz * 1000.0)
    }
}

/// Simulate `calls` consecutive GEMV invocations of `method` on a
/// `z × k` layer through a fresh `preset` hierarchy.
///
/// `calls > 1` models steady-state inference (weights that fit the LLC
/// stay resident between calls — the effect behind the paper's Fig. 6
/// diagonal boundary).  Stats are taken over the *last* call.
pub fn simulate_gemv(
    method: Method,
    z: usize,
    k: usize,
    preset: CachePreset,
    core: &CoreModel,
    calls: usize,
) -> SimResult {
    let mut h = preset.build();
    let t = method.traffic(z, k);
    // the LUT tier replays its table-build + gather stream; everything
    // else streams weights and activations directly
    let replay = |h: &mut Hierarchy| match method {
        Method::Lut(_) => {
            replay_gemv_lut(h, &t);
        }
        _ => {
            replay_gemv(h, &t);
        }
    };
    // warm-up calls: populate the hierarchy
    for _ in 1..calls.max(1) {
        replay(&mut h);
    }
    h.reset_stats();
    replay(&mut h);
    finish(method, z, k, &h, core)
}

/// Combine a replayed hierarchy with the instruction model.  The mix is
/// taken through [`Method::instr_mix_on`], so cores with
/// `autovec_eff < 1` charge lane-staged methods for imperfect
/// vectorization while the SWAR tier keeps its flat cost.
pub fn finish(method: Method, z: usize, k: usize, h: &Hierarchy, core: &CoreModel) -> SimResult {
    combine(method.instr_mix_on(z, k, core), h, core)
}

/// Fold an instruction mix and a replayed hierarchy into a result.
fn combine(mix: InstrMix, h: &Hierarchy, core: &CoreModel) -> SimResult {
    let compute = core.compute_cycles(&mix);
    let stalls = core.stall_cycles(h);
    SimResult {
        cycles: compute + stalls,
        instrs: mix.total(),
        compute_cycles: compute,
        stall_cycles: stalls,
        llc: h.llc_stats(),
        l1: h.level_stats(0),
    }
}

/// Simulate one **batched** execution of `method` over `batch` columns
/// of a `z × k` layer, on the GEMM memory-trace tier (`sim::trace`):
///
/// * a [`Method::FullPackGemm`] call replays **one** blocked weight
///   pass feeding the whole activation panel
///   ([`crate::sim::replay_gemm`] — the extract-once/MAC-many loop of
///   `kernels::gemm_fullpack`), while
/// * every other method replays back-to-back re-streams of the weight
///   matrix ([`crate::sim::replay_gemm_restream`]) — the paper's
///   "route GEMM to Ruy" protocol — one whole call per
///   `Method::batch()` columns (`batch` re-streams for the
///   single-column rivals, `⌈batch/8⌉` for ULPPACK's batch-8
///   protocol), with each column's activations and outputs at
///   **distinct** addresses (the old approximation replayed every
///   column at one aliased activation base, overstating rival
///   locality).
///
/// `calls` warm-up batched executions model steady-state residency;
/// stats cover the last one.  [`simulate_gemm_traced`] additionally
/// returns the per-operand access/LLC-miss split of the measured call.
pub fn simulate_gemm(
    method: Method,
    z: usize,
    k: usize,
    batch: usize,
    preset: CachePreset,
    core: &CoreModel,
    calls: usize,
) -> SimResult {
    simulate_gemm_traced(method, z, k, batch, preset, core, calls).0
}

/// [`simulate_gemm`] returning the measured call's per-operand
/// [`ReplayStats`] alongside the folded result — the view that makes
/// the one-weight-pass advantage visible per operand (weight LLC
/// misses flat in batch for the GEMM tier, growing linearly for the
/// re-streamed rivals).
pub fn simulate_gemm_traced(
    method: Method,
    z: usize,
    k: usize,
    batch: usize,
    preset: CachePreset,
    core: &CoreModel,
    calls: usize,
) -> (SimResult, ReplayStats) {
    let b = batch.max(1);
    let mut h = preset.build();
    let t = method.traffic(z, k);
    let replay = |h: &mut Hierarchy| -> ReplayStats {
        match method {
            Method::FullPackGemm(_) => replay_gemm(h, &GemmTraffic::from_gemv(&t, b)),
            // the LUT GEMM tier: one weight pass per COL_TILE-column
            // tile, per-column table builds (`sim::replay_gemm_lut`)
            Method::LutGemm(_) => replay_gemm_lut(h, &GemmTraffic::from_gemv(&t, b)),
            // the LUT GEMV kernel as a batched rival: b back-to-back
            // calls, each rebuilding the table and re-streaming the
            // weights — the protocol its `-gemm` wrapper amortizes
            Method::Lut(_) => replay_gemv_lut_restream(h, &t, b),
            // rivals re-stream the weights once per whole call of
            // their own per-call width: `b` single-column calls for
            // the GEMV protocols, ⌈b/8⌉ batch-8 calls for ULPPACK
            _ => replay_gemm_restream(h, &t, b.div_ceil(t.batch.max(1))),
        }
    };
    for _ in 1..calls.max(1) {
        replay(&mut h);
    }
    h.reset_stats();
    let stats = replay(&mut h);
    (combine(method.instr_mix_gemm_on(z, k, b, core), &h, core), stats)
}

/// Modeled whole-model execution of a [`crate::models::ModelGraph`]:
/// the per-layer `simulate_gemv`/`simulate_gemm` sum (DESIGN.md §10).
///
/// * [`crate::models::Op::FullyConnected`] nodes are one batched call
///   over `time_steps` columns (`simulate_gemm` — the engine flushes
///   them as one GEMM), or a single `simulate_gemv` when
///   `time_steps == 1`;
/// * scan cells issue two GEMVs per step (input + recurrent matrix),
///   scaled by `time_steps`, with steady-state warm-up so the gate
///   weights are resident across the scan (the Fig. 1 regime);
/// * weightless elementwise nodes are free at this model's granularity.
///
/// `cell_method` runs the scan cells, `fc_method` the FC nodes — the
/// paper's §4.6 split is `(FullPack, RuyW8A8)`; an all-baseline run is
/// `(RuyW8A8, RuyW8A8)`.  Returns `(layer name, cycles)` per node;
/// [`simulate_model_total`] folds the sum.
pub fn simulate_model(
    graph: &crate::models::ModelGraph,
    cell_method: Method,
    fc_method: Method,
    preset: CachePreset,
    core: &CoreModel,
    calls: usize,
) -> Vec<(String, f64)> {
    use crate::models::Op;
    let mut out = Vec::with_capacity(graph.nodes.len());
    for node in &graph.nodes {
        let cycles = match node.op {
            Op::FullyConnected { .. } => {
                if graph.time_steps > 1 {
                    simulate_gemm(fc_method, node.z, node.k, graph.time_steps, preset, core, calls)
                        .cycles
                } else {
                    simulate_gemv(fc_method, node.z, node.k, preset, core, calls).cycles
                }
            }
            Op::LstmCell | Op::GruCell => {
                let h = node.hidden().unwrap_or(0);
                // per step: wx (z × k) + wh (z × h); the scan keeps the
                // gate matrices resident, so warm at least one call
                let steady = calls.max(2);
                let wx = simulate_gemv(cell_method, node.z, node.k, preset, core, steady).cycles;
                let wh = simulate_gemv(cell_method, node.z, h, preset, core, steady).cycles;
                (wx + wh) * graph.time_steps as f64
            }
            Op::Relu { .. } => 0.0,
        };
        out.push((node.name.clone(), cycles));
    }
    out
}

/// Total modeled cycles of [`simulate_model`].
pub fn simulate_model_total(
    graph: &crate::models::ModelGraph,
    cell_method: Method,
    fc_method: Method,
    preset: CachePreset,
    core: &CoreModel,
    calls: usize,
) -> f64 {
    simulate_model(graph, cell_method, fc_method, preset, core, calls)
        .iter()
        .map(|(_, c)| c)
        .sum()
}

/// The modeled GEMM-vs-repeated-GEMV crossover: the smallest batch (in
/// `2..=max_batch`) at which the amortized [`Method::FullPackGemm`]
/// call beats `batch` repeated [`Method::FullPack`] GEMVs on variant
/// `v`, or `None` when repeated GEMV stays ahead across the whole
/// range.  Since PR 4 both sides are **memory-aware**: the GEMM side
/// replays one blocked weight pass (`sim::replay_gemm`), the repeated
/// side re-streams the weights per column at distinct activation
/// addresses (`sim::replay_gemm_restream`), so the crossover sees the
/// one-weight-pass cache advantage, not just the amortized extraction.
/// This is the curve behind the router's batch policy
/// (`kernels::GEMM_MIN_BATCH`) and the EXPERIMENTS.md crossover table.
pub fn gemm_batch_threshold(
    v: crate::pack::Variant,
    z: usize,
    k: usize,
    preset: CachePreset,
    core: &CoreModel,
    max_batch: usize,
) -> Option<usize> {
    const STEADY: usize = 3;
    (2..=max_batch).find(|&b| {
        let gemm = simulate_gemm(Method::FullPackGemm(v), z, k, b, preset, core, STEADY);
        let repeated = simulate_gemm(Method::FullPack(v), z, k, b, preset, core, STEADY);
        gemm.cycles < repeated.cycles
    })
}

/// The FullPack method pair for a graph: scan cells always take
/// `Method::FullPack(variant)`; FC nodes take FullPack only when the
/// graph quantizes them on the model variant (the MLP), otherwise the
/// paper's Ruy-W8A8 GEMM protocol (DeepSpeech, the KWS head).
pub fn fullpack_methods_for(graph: &crate::models::ModelGraph) -> (Method, Method) {
    let cell = Method::FullPack(graph.variant);
    let fc = if graph.has_model_variant_fc() {
        Method::FullPack(graph.variant)
    } else {
        Method::RuyW8A8
    };
    (cell, fc)
}

/// Modeled wall-clock nanoseconds of **one batched serving dispatch**
/// of `group` requests of `graph` — the admission scheduler's brain
/// (DESIGN.md §12) and the workload DES's service-time source.
///
/// Batching `group` requests widens every layer to `group ×
/// time_steps` columns, which is exactly `simulate_model_total` over a
/// graph with `time_steps` scaled by the group (the same construction
/// the serving figures use): FC stacks amortize one weight pass over
/// all columns (the paper's GEMM win), scan cells repeat per request.
/// Cycles are converted at the modeled ex5_big frequency; the absolute
/// number is a cost-model estimate, but admission decisions only
/// compare these against each other and the SLO, so the *shape* of the
/// curve (marginal cost of one more column) is what matters.
pub fn serving_dispatch_ns(graph: &crate::models::ModelGraph, group: usize) -> u64 {
    let core = CoreModel::ex5_big();
    let mut g = graph.clone();
    g.time_steps *= group.max(1);
    let (cell_m, fc_m) = fullpack_methods_for(&g);
    let cycles = simulate_model_total(&g, cell_m, fc_m, CachePreset::Gem5Ex5Big, &core, 2);
    (cycles / core.freq_ghz) as u64
}

/// Modeled wall-clock nanoseconds to bring `bytes` of packed weights
/// resident — the model store's cold-load price (DESIGN.md §14) and the
/// source of `ColdModel` retry-after hints.
///
/// Streaming a cold image is DRAM-bound: one 16-byte vector load per
/// cycle (`load_tp`), discounted by the OoO window's miss-hiding
/// fraction (`mem_overlap`).  At the ex5_big core that is ≈ 15.7 GB/s —
/// and the cost scales with the *packed* byte count, so a w4 model
/// loads in half the time a w8 twin would: FullPack's footprint
/// advantage priced directly into residency churn.  Pure and
/// deterministic, so the virtual DES mirrors live cold-load pricing
/// bit-exactly.
pub fn weight_load_ns(bytes: usize) -> u64 {
    let core = CoreModel::ex5_big();
    let cycles = (bytes as f64 / 16.0) / core.load_tp / core.mem_overlap;
    (cycles / core.freq_ghz).ceil() as u64
}

/// [`weight_load_ns`] as the microsecond retry-after hint carried by a
/// `ColdModel` shed (floored at 1µs so a hint is never "retry now").
pub fn cold_retry_us(bytes: usize) -> u64 {
    (weight_load_ns(bytes) / 1_000).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pack::Variant;

    const STEADY: usize = 3;

    fn speedup(a: Method, b: Method, z: usize, k: usize) -> f64 {
        let core = CoreModel::ex5_big();
        let ra = simulate_gemv(a, z, k, CachePreset::Gem5Ex5Big, &core, STEADY);
        let rb = simulate_gemv(b, z, k, CachePreset::Gem5Ex5Big, &core, STEADY);
        ra.cycles / rb.cycles
    }

    #[test]
    fn w4a8_beats_baseline_at_large_sizes() {
        // paper §4.2: 1.2–6.7x for mid/large sizes
        let s = speedup(Method::RuyW8A8, Method::fullpack("w4a8"), 4096, 4096);
        assert!(s > 1.2, "large-size speedup {s}");
    }

    #[test]
    fn boundary_cells_peak() {
        // 2048x2048: packed fits 2MB L2, W8A8 does not — the Fig. 4
        // diagonal where speedup peaks.
        let s = speedup(Method::RuyW8A8, Method::fullpack("w4a8"), 2048, 2048);
        assert!(s > 1.8, "boundary speedup {s}");
    }

    #[test]
    fn small_sizes_near_parity() {
        // paper: 0.96–2.1x for small sizes (compute-bound region)
        let s = speedup(Method::RuyW8A8, Method::fullpack("w4a8"), 128, 128);
        assert!((0.7..2.5).contains(&s), "small-size speedup {s}");
    }

    #[test]
    fn fp32_an_order_slower() {
        // paper §1: FP32 methods slower than Ruy-W8A8 by 1–2 orders
        let s = speedup(Method::TfliteF32, Method::RuyW8A8, 2048, 2048);
        assert!(s > 3.0, "fp32 slowdown {s}");
    }

    #[test]
    fn ulppack_slower_than_baseline() {
        // ULPPACK— runs a batch-8 GEMM per inference (§4.1)
        let s = speedup(Method::Ulppack { bits: 2 }, Method::RuyW8A8, 1024, 1024);
        assert!(s > 2.0, "ulppack slowdown {s}");
    }

    #[test]
    fn xnn_fewer_instructions_than_ruy() {
        // paper Fig. 12: XNNPack ≈ 0.68x of Ruy's instruction count
        let xm = Method::XnnW8A8.instr_mix(1024, 1024).total();
        let rm = Method::RuyW8A8.instr_mix(1024, 1024).total();
        let ratio = xm / rm;
        assert!((0.5..0.9).contains(&ratio), "instr ratio {ratio}");
    }

    #[test]
    fn subbyte_activation_only_less_effective() {
        // paper §4.3: W8A4 gains less than W4A8 (weights dominate traffic)
        let s_w = speedup(Method::RuyW8A8, Method::fullpack("w4a8"), 2048, 2048);
        let s_a = speedup(Method::RuyW8A8, Method::fullpack("w8a4"), 2048, 2048);
        assert!(s_w > s_a, "w4a8 {s_w} vs w8a4 {s_a}");
    }

    #[test]
    fn ipc_positive_and_sane() {
        let core = CoreModel::ex5_big();
        for m in [Method::RuyW8A8, Method::fullpack("w4a8"), Method::RuyF32] {
            let r = simulate_gemv(m, 512, 512, CachePreset::Gem5Ex5Big, &core, STEADY);
            let ipc = r.ipc();
            assert!(ipc > 0.05 && ipc < 6.0, "{m:?} ipc {ipc}");
        }
    }

    #[test]
    fn simulate_model_reproduces_the_paper_split_win() {
        // whole-model comparison over the DeepSpeech graph: FullPack on
        // the LSTM scan (FC kept on Ruy, the §4.6 protocol) must beat
        // the all-Ruy baseline end to end
        use crate::models::{deepspeech_graph, DeepSpeechConfig};
        let core = CoreModel::ex5_big();
        let v = Variant::parse("w4a8").unwrap();
        let g = deepspeech_graph(DeepSpeechConfig::FULL, v, 7);
        let layers = simulate_model(
            &g,
            Method::FullPack(v),
            Method::RuyW8A8,
            CachePreset::Gem5Ex5Big,
            &core,
            STEADY,
        );
        assert_eq!(layers.len(), 6);
        assert_eq!(layers[3].0, "lstm");
        assert!(layers.iter().all(|(_, c)| *c >= 0.0));
        let fp = simulate_model_total(
            &g,
            Method::FullPack(v),
            Method::RuyW8A8,
            CachePreset::Gem5Ex5Big,
            &core,
            STEADY,
        );
        let base = simulate_model_total(
            &g,
            Method::RuyW8A8,
            Method::RuyW8A8,
            CachePreset::Gem5Ex5Big,
            &core,
            STEADY,
        );
        assert!(base / fp > 1.2, "e2e speedup {}", base / fp);
        // totals are the per-layer sum
        let sum: f64 = layers.iter().map(|(_, c)| c).sum();
        assert!((sum - fp).abs() < 1e-6 * fp.max(1.0));
    }

    #[test]
    fn simulate_model_covers_feedforward_and_gru_graphs() {
        use crate::models::{mlp_graph, keyword_spotter_graph, ModelSize};
        let core = CoreModel::ex5_big();
        let v = Variant::parse("w4a8").unwrap();
        // MLP: all-FC at batch 1 — FullPack FC beats Ruy FC
        let g = mlp_graph(ModelSize::Full, v, 7);
        let fp = simulate_model_total(
            &g,
            Method::FullPack(v),
            Method::FullPack(v),
            CachePreset::Gem5Ex5Big,
            &core,
            STEADY,
        );
        let base = simulate_model_total(
            &g,
            Method::RuyW8A8,
            Method::RuyW8A8,
            CachePreset::Gem5Ex5Big,
            &core,
            STEADY,
        );
        assert!(base / fp > 1.0, "mlp speedup {}", base / fp);
        // weightless relu nodes are free at this granularity
        let layers =
            simulate_model(&g, Method::RuyW8A8, Method::RuyW8A8, CachePreset::Gem5Ex5Big, &core, STEADY);
        assert_eq!(layers.iter().filter(|(_, c)| *c == 0.0).count(), 2);
        // keyword spotter: the GRU scan dominates and FullPack wins it
        let g = keyword_spotter_graph(ModelSize::Full, v, 7);
        let fp = simulate_model_total(
            &g,
            Method::FullPack(v),
            Method::RuyW8A8,
            CachePreset::Gem5Ex5Big,
            &core,
            STEADY,
        );
        let base = simulate_model_total(
            &g,
            Method::RuyW8A8,
            Method::RuyW8A8,
            CachePreset::Gem5Ex5Big,
            &core,
            STEADY,
        );
        assert!(base / fp > 1.0, "kws speedup {}", base / fp);
    }

    #[test]
    fn variant_helper() {
        assert_eq!(
            Method::fullpack("w2a2"),
            Method::FullPack(Variant::parse("w2a2").unwrap())
        );
    }

    #[test]
    fn portable_core_prefers_swar_only_at_low_bits() {
        // DESIGN.md §8: the SWAR tier's bit-plane cost is ~8 planes per
        // 8 packed bytes regardless of width, so its win over the
        // staged kernels grows as the bit-width shrinks
        let preset = CachePreset::Gem5Ex5Big;
        let port = CoreModel::portable();
        let cyc = |m: Method| simulate_gemv(m, 2048, 2048, preset, &port, STEADY).cycles;
        assert!(cyc(Method::fullpack_swar("w1a8")) < cyc(Method::fullpack("w1a8")));
        assert!(cyc(Method::fullpack_swar("w2a8")) < cyc(Method::fullpack("w2a8")));
        // honest: at 4 bits the staged kernel stays ahead even with the
        // vectorizer degraded — recorded as such in EXPERIMENTS.md
        assert!(cyc(Method::fullpack_swar("w4a8")) > cyc(Method::fullpack("w4a8")));
        // on the paper's NEON core the staged kernels win everywhere
        let neon = CoreModel::ex5_big();
        let n = |m: Method| simulate_gemv(m, 2048, 2048, preset, &neon, STEADY).cycles;
        assert!(n(Method::fullpack("w1a8")) < n(Method::fullpack_swar("w1a8")));
        assert!(n(Method::fullpack("w4a8")) < n(Method::fullpack_swar("w4a8")));
    }

    #[test]
    fn lut_crossover_amortized_build_vs_l1_pressure() {
        // DESIGN.md §13: the LUT tier wins only where (a) the scalar
        // gather row loop beats *degraded* staged extraction — a
        // portable core, not the paper's NEON core — and (b) the table
        // (`wb` KB) fits L1 so the gathers hit.  k=128 w4a8 → wb=64 →
        // a 64KB table, half the 128KB L1.
        let preset = CachePreset::Gem5Ex5Big;
        let port = CoreModel::portable();
        let cyc =
            |m: Method, z: usize, k: usize| simulate_gemv(m, z, k, preset, &port, STEADY).cycles;
        // many rows amortize the per-call table build: LUT wins
        assert!(
            cyc(Method::lut("w4a8"), 2048, 128) < cyc(Method::fullpack("w4a8"), 2048, 128),
            "lut should win at z=2048 k=128 on the portable core"
        );
        // few rows: the build dominates and the staged kernel wins
        assert!(
            cyc(Method::lut("w4a8"), 128, 128) > cyc(Method::fullpack("w4a8"), 128, 128),
            "fullpack should win at z=128 k=128"
        );
        // deep layers: the table outgrows L1 (k=2048 → 1MB) and the
        // gathers stall — FullPack wins even with many rows
        assert!(
            cyc(Method::lut("w4a8"), 2048, 2048) > cyc(Method::fullpack("w4a8"), 2048, 2048),
            "fullpack should win at k=2048 (table thrashes L1)"
        );
        // on the paper's NEON core the staged kernels win everywhere
        let neon = CoreModel::ex5_big();
        let n = |m: Method| simulate_gemv(m, 2048, 128, preset, &neon, STEADY).cycles;
        assert!(n(Method::lut("w4a8")) > n(Method::fullpack("w4a8")));
    }

    #[test]
    fn lut_gemm_wrapper_trades_weight_stream_for_table_pressure() {
        // Compute side, the -gemm wrapper is a strict improvement: it
        // walks the packed weights once per COL_TILE tile instead of
        // once per column (fewer loads), while the table-build scalar
        // work scales with the batch either way (identical scalar).
        let (z, k) = (1024usize, 128usize);
        let g_mix = Method::lut_gemm("w4a8").instr_mix_gemm(z, k, 16);
        let r_mix = Method::lut("w4a8").instr_mix_gemm(z, k, 16);
        assert!(g_mix.loads < r_mix.loads, "{} !< {}", g_mix.loads, r_mix.loads);
        assert_eq!(g_mix.scalar, r_mix.scalar, "builds scale with batch either way");
        // Memory side, the trade goes the other way: the wrapper keeps
        // COL_TILE live tables at a `wb`KB stride — at k=128 (wb=64)
        // that stride is exactly the L1 way size, so same-position
        // lines of the four tables alias into one 2-way set and the
        // gathers thrash, while the repeated-GEMV rival rebuilds ONE
        // table in place and gathers straight from L1.  The model
        // scores that honestly: the wrapper's stall bill dwarfs the
        // restreamed calls' and costs it the matchup — among LUT
        // plans repeated calls win, and full-registry batched
        // selection stays on the FullPack GEMM tier
        // (plan::tests::cost_model_selects_the_fullpack_gemm_tier...).
        let core = CoreModel::portable();
        let preset = CachePreset::Gem5Ex5Big;
        for batch in [4usize, 16] {
            let g = simulate_gemm(Method::lut_gemm("w4a8"), z, k, batch, preset, &core, STEADY);
            let r = simulate_gemm(Method::lut("w4a8"), z, k, batch, preset, &core, STEADY);
            assert!(
                g.stall_cycles > 10.0 * r.stall_cycles,
                "batch {batch}: wrapper stalls {} !> 10x restream stalls {}",
                g.stall_cycles,
                r.stall_cycles
            );
            assert!(g.cycles > r.cycles, "batch {batch}: {} !> {}", g.cycles, r.cycles);
        }
    }

    #[test]
    fn gemm_amortization_curve_decreases_per_column() {
        // DESIGN.md §9: per-column cycles of the batched FullPack GEMM
        // fall strictly while batch grows toward the kernel's
        // COL_TILE=4 (extraction amortizes inside a tile); beyond the
        // tile width the compute side is flat by construction (the
        // kernel re-extracts per tile), so the curve may only improve
        // via the memory side — never regress past rounding
        let core = CoreModel::ex5_big();
        for v in ["w4a8", "w2a8", "w1a8"] {
            let m = Method::fullpack_gemm(v);
            let per_col = |b: usize| {
                simulate_gemm(m, 1024, 1024, b, CachePreset::Gem5Ex5Big, &core, STEADY).cycles
                    / b as f64
            };
            let (c1, c2, c4, c16) = (per_col(1), per_col(2), per_col(4), per_col(16));
            assert!(c2 < c1 && c4 < c2, "{v}: {c1} {c2} {c4}");
            assert!(c16 <= c4 * 1.001, "{v}: post-tile regression {c4} -> {c16}");
        }
        // beyond COL_TILE the remaining lever is the single weight
        // pass: at an LLC-spilling size (4096x4096 w4a8 = 8MB) the
        // amortized stall term keeps per-column cost falling strictly
        let m = Method::fullpack_gemm("w4a8");
        let per_col = |b: usize| {
            simulate_gemm(m, 4096, 4096, b, CachePreset::Gem5Ex5Big, &core, STEADY).cycles
                / b as f64
        };
        assert!(per_col(16) < per_col(4), "spilling-size memory amortization");
    }

    #[test]
    fn gemm_beats_repeated_gemv_above_the_threshold() {
        let core = CoreModel::ex5_big();
        let preset = CachePreset::Gem5Ex5Big;
        for vname in ["w4a8", "w2a8", "w1a8"] {
            let v = Variant::parse(vname).unwrap();
            // the memory-aware crossover sits at batch 2 at serving
            // shapes — the number GEMM_MIN_BATCH and the EXPERIMENTS.md
            // "threshold shift: none" note encode
            let th = gemm_batch_threshold(v, 2048, 2048, preset, &core, 16);
            assert_eq!(th, Some(2), "{vname}: threshold {th:?}");
            // and the batch-16 flush is a clear win
            let gemm =
                simulate_gemm(Method::FullPackGemm(v), 2048, 2048, 16, preset, &core, STEADY);
            let repeated =
                simulate_gemm(Method::FullPack(v), 2048, 2048, 16, preset, &core, STEADY);
            assert!(
                gemm.cycles < repeated.cycles,
                "{vname}: gemm {} vs repeated {}",
                gemm.cycles,
                repeated.cycles
            );
        }
    }

    #[test]
    fn gemm_also_beats_the_ruy_protocol_on_subbyte_data() {
        // the router's prefer_gemm promotion: amortized sub-byte GEMM
        // vs the paper's widened repeated-Ruy fallback at the flush size
        let core = CoreModel::ex5_big();
        let preset = CachePreset::Gem5Ex5Big;
        let gemm = simulate_gemm(
            Method::fullpack_gemm("w4a8"),
            2048,
            2048,
            16,
            preset,
            &core,
            STEADY,
        );
        let ruy = simulate_gemm(Method::RuyW8A8, 2048, 2048, 16, preset, &core, STEADY);
        assert!(gemm.cycles < ruy.cycles, "gemm {} vs ruy {}", gemm.cycles, ruy.cycles);
    }

    #[test]
    fn gemm_one_weight_pass_visible_in_cache_stats() {
        // acceptance (PR 4): at a size where the packed weights spill
        // the LLC (4096x4096 w4a8 = 8MB vs the 2MB L2), the modeled
        // one-weight-pass advantage must show up in the per-level cache
        // stats — the repeated protocol re-streams the matrix per
        // column, the GEMM tier reads it once
        let core = CoreModel::ex5_big();
        let preset = CachePreset::Gem5Ex5Big;
        let (z, k, batch) = (4096, 4096, 8);
        let (g, gs) =
            simulate_gemm_traced(Method::fullpack_gemm("w4a8"), z, k, batch, preset, &core, STEADY);
        let (r, rs) =
            simulate_gemm_traced(Method::fullpack("w4a8"), z, k, batch, preset, &core, STEADY);
        // per-operand: the rival pays ~batch x the weight misses
        assert!(
            gs.weights.llc_misses * 4 < rs.weights.llc_misses,
            "gemm weight misses {} vs repeated {}",
            gs.weights.llc_misses,
            rs.weights.llc_misses
        );
        // per-level: visible in the aggregate LLC stats and in cycles
        assert!(g.llc.misses * 2 < r.llc.misses, "llc {} vs {}", g.llc.misses, r.llc.misses);
        assert!(g.cycles < r.cycles);
    }

    #[test]
    fn rival_columns_no_longer_alias() {
        // bugfix pin (PR 4): the rival path used to replay every batch
        // column at the same activation base, so its modeled locality
        // was one column's.  Post-fix, rival LLC accesses grow with
        // batch while the FullPack-GEMM weight misses stay flat.
        let core = CoreModel::ex5_big();
        let preset = CachePreset::Gem5Ex5Big;
        let (z, k) = (4096, 4096);
        let rival = |b| {
            simulate_gemm_traced(Method::RuyW8A8, z, k, b, preset, &core, STEADY).0.llc.accesses
        };
        let (r1, r8) = (rival(1), rival(8));
        assert!(r8 > r1 * 4, "rival LLC accesses must grow with batch: {r1} -> {r8}");
        let gemm_wmiss = |b| {
            simulate_gemm_traced(Method::fullpack_gemm("w4a8"), z, k, b, preset, &core, STEADY)
                .1
                .weights
                .llc_misses
        };
        let (g1, g8) = (gemm_wmiss(1), gemm_wmiss(8));
        assert!(
            g8 <= g1 + g1 / 4,
            "one weight pass: misses must not grow with batch ({g1} -> {g8})"
        );
    }

    #[test]
    fn weight_load_cost_scales_with_packed_bytes() {
        // the residency price is linear in *packed* bytes: a w4 model
        // costs exactly half its w8 twin's load time (FullPack's
        // capacity claim priced into churn), and the retry hint is the
        // same number in µs, floored at 1
        let mb = 1 << 20;
        let w8 = weight_load_ns(2 * mb);
        let w4 = weight_load_ns(mb);
        assert!(w8 > 0 && w4 > 0);
        assert!((w8 as i64 - 2 * w4 as i64).abs() <= 2, "w8 {w8} vs 2x w4 {w4}");
        // ≈ 15.7 GB/s modeled bandwidth: 1 MiB in the 50–100 µs decade
        assert!((10_000..1_000_000).contains(&w4), "1 MiB load {w4} ns");
        assert_eq!(cold_retry_us(mb), weight_load_ns(mb) / 1_000);
        assert_eq!(cold_retry_us(0), 1);
        // deterministic (the DES mirrors this bit-exactly)
        assert_eq!(weight_load_ns(12345), weight_load_ns(12345));
    }

    #[test]
    fn avx2_core_prefers_the_real_isa_tier_at_serving_shapes() {
        // acceptance (DESIGN.md §15): on the AVX2 calibration the
        // intrinsic tier must beat every portable tier at the w4a8
        // serving shape — it runs real 256-bit lanes while the staged
        // kernels degrade behind the untrusted vectorizer and the SWAR
        // tier grinds 64-bit planes.  Pure simulation: holds on any
        // build host.
        use crate::kernels::IsaKind;
        let core = CoreModel::avx2();
        let preset = CachePreset::Gem5Ex5Big;
        let cyc = |m: Method| simulate_gemv(m, 2048, 2048, preset, &core, STEADY).cycles;
        let isa = cyc(Method::fullpack_isa("w4a8", IsaKind::Avx2));
        assert!(isa < cyc(Method::fullpack_swar("w4a8")), "isa vs swar");
        assert!(isa < cyc(Method::fullpack("w4a8")), "isa vs staged");
        assert!(isa < cyc(Method::RuyW8A8), "isa vs ruy");
        // the 256-bit schedule also beats its own 128-bit sibling
        assert!(isa < cyc(Method::fullpack_isa("w4a8", IsaKind::Neon)), "avx2 vs neon width");
    }

    #[test]
    fn paper_neon_calibration_keeps_the_staged_kernels_ahead() {
        // guard for the existing boundary pins: on ex5_big
        // (autovec_eff = 1 — the staged mix IS the paper's hand-written
        // NEON) the intrinsic tier's extra per-lane sign-extend ops
        // cost it the matchup, so registering NEON kernels on an
        // aarch64 host cannot drift boundary_cells_peak and friends.
        use crate::kernels::IsaKind;
        let preset = CachePreset::Gem5Ex5Big;
        let paper = CoreModel::ex5_big();
        let p = |m: Method| simulate_gemv(m, 2048, 2048, preset, &paper, STEADY).cycles;
        assert!(p(Method::fullpack("w4a8")) < p(Method::fullpack_isa("w4a8", IsaKind::Neon)));
        // ...but on the neon() profile (same pipes, untrusted
        // vectorizer) the intrinsic tier is the clear winner
        let neon = CoreModel::neon();
        let n = |m: Method| simulate_gemv(m, 2048, 2048, preset, &neon, STEADY).cycles;
        let isa = n(Method::fullpack_isa("w4a8", IsaKind::Neon));
        assert!(isa < n(Method::fullpack("w4a8")), "isa vs degraded staged");
        assert!(isa < n(Method::fullpack_swar("w4a8")), "isa vs swar");
    }

    #[test]
    fn degrade_staged_is_identity_on_perfect_cores() {
        let neon = CoreModel::ex5_big();
        let m = Method::fullpack("w4a8");
        let a = m.instr_mix(512, 512);
        let b = m.instr_mix_on(512, 512, &neon);
        assert_eq!(a, b);
        // and inflates lane ops on the portable profile
        let port = CoreModel::portable();
        let c = m.instr_mix_on(512, 512, &port);
        assert!(c.macs > a.macs && c.loads > a.loads);
        // ...but never touches the SWAR tier's mix
        let s = Method::fullpack_swar("w4a8");
        assert_eq!(s.instr_mix(512, 512), s.instr_mix_on(512, 512, &port));
    }
}

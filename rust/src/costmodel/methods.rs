//! Per-method traffic and instruction-mix models for every method the
//! paper compares (§4.1), plus the naive Alg. 1 strawman as an ablation.
//!
//! Modeled and measured methods share **one namespace**: every variant
//! of [`Method`] names the registry kernel it models
//! ([`Method::registry_name`]), and a registry name resolves back to a
//! `Method` through the kernel's own `cost_method`
//! ([`Method::from_registry`]).

use crate::kernels::isa::IsaKind;
use crate::pack::{BitWidth, Variant};
use crate::sim::GemvTraffic;

/// One of the compared execution methods.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// our kernels, any of the nine W/A variants
    FullPack(Variant),
    /// the u64 SWAR fast-path tier over the same layout (DESIGN.md §8):
    /// vectorizer-independent bit-plane inner loops, `wXa8` variants
    FullPackSwar(Variant),
    /// the real-ISA tier (DESIGN.md §15): AVX2/NEON intrinsic kernels
    /// over the unchanged packed layout, registered only on hosts whose
    /// CPU can execute them (`fullpack-wXa8-avx2`/`-neon`).  The mix is
    /// parameterized by the ISA's lane width — a 256-bit AVX2 lane
    /// covers two 16-byte blocks per weight load, halving weight-stream
    /// and bookkeeping ops relative to the 128-bit NEON schedule
    FullPackIsa(Variant, IsaKind),
    /// the batched FullPack GEMM extension (DESIGN.md §9): each packed
    /// weight block is extracted once and its lanes feed every batch
    /// column, so extraction cost amortizes as `1/batch` — the DeepGEMM
    /// (arXiv 2304.09049) argument.  `wXa8` sub-byte variants; batch is
    /// supplied per call ([`Method::instr_mix_gemm`],
    /// `costmodel::simulate_gemm`)
    FullPackGemm(Variant),
    /// the LUT tier (DeepGEMM, Ganji et al. 2023, arXiv 2304.09049, as
    /// the *opposite* trade to bit-plane extraction): per packed weight
    /// byte slot, a 256-entry table of partial dots against the current
    /// activation block is built once per call, then every weight byte
    /// becomes one gather-style table load + add — no extraction at
    /// all.  Scalar by construction (gathers defeat the SLP
    /// vectorizer), so the mix is dominated by the table build and
    /// gather loads; the table stresses L1 instead of bandwidth
    /// (`sim::replay_gemv_lut`).  Same packed layout as [`Method::FullPack`]
    Lut(Variant),
    /// batched LUT GEMM wrapper (`lut-*-gemm`): per-column tables, but
    /// the packed weight bytes are walked once per
    /// `kernels::fullpack_gemm::COL_TILE`-column tile instead of once
    /// per column — amortizing weight streaming, not table builds
    LutGemm(Variant),
    /// Alg. 1 adjacent packing with scalar extraction (ablation)
    Naive(Variant),
    /// ULPPACK— (Won et al. 2022): spacer-lane GEMM, batch 8 per the
    /// paper's evaluation protocol; `bits` ∈ {1, 2, 3}
    Ulppack { bits: u8 },
    RuyW8A8,
    XnnW8A8,
    TfliteW8A8,
    GemmlowpW8A8,
    RuyF32,
    XnnF32,
    TfliteF32,
    EigenF32,
}

impl Method {
    /// Convenience constructor: `Method::fullpack("w4a8")`.
    pub fn fullpack(v: &str) -> Method {
        Method::FullPack(Variant::parse(v).expect("valid variant"))
    }

    /// Convenience constructor: `Method::fullpack_swar("w4a8")`.
    pub fn fullpack_swar(v: &str) -> Method {
        Method::FullPackSwar(Variant::parse(v).expect("valid variant"))
    }

    /// Convenience constructor: `Method::fullpack_isa("w4a8", IsaKind::Avx2)`.
    pub fn fullpack_isa(v: &str, kind: IsaKind) -> Method {
        Method::FullPackIsa(Variant::parse(v).expect("valid variant"), kind)
    }

    /// Convenience constructor: `Method::fullpack_gemm("w4a8")`.
    pub fn fullpack_gemm(v: &str) -> Method {
        Method::FullPackGemm(Variant::parse(v).expect("valid variant"))
    }

    /// Convenience constructor: `Method::lut("w4a8")`.
    pub fn lut(v: &str) -> Method {
        Method::Lut(Variant::parse(v).expect("valid variant"))
    }

    /// Convenience constructor: `Method::lut_gemm("w4a8")`.
    pub fn lut_gemm(v: &str) -> Method {
        Method::LutGemm(Variant::parse(v).expect("valid variant"))
    }

    /// Display name matching the paper's legend.
    pub fn label(&self) -> String {
        match self {
            Method::FullPack(v) => format!("FullPack-{}", v.name().to_uppercase()),
            Method::FullPackSwar(v) => format!("FullPack-SWAR-{}", v.name().to_uppercase()),
            Method::FullPackIsa(v, kind) => {
                format!("FullPack-{}-{}", kind.label(), v.name().to_uppercase())
            }
            Method::FullPackGemm(v) => format!("FullPack-GEMM-{}", v.name().to_uppercase()),
            Method::Lut(v) => format!("LUT-{}", v.name().to_uppercase()),
            Method::LutGemm(v) => format!("LUT-GEMM-{}", v.name().to_uppercase()),
            Method::Naive(v) => format!("Naive-{}", v.name().to_uppercase()),
            Method::Ulppack { bits } => format!("ULPPACK-W{bits}A{bits}"),
            Method::RuyW8A8 => "Ruy-W8A8".into(),
            Method::XnnW8A8 => "XNNPack-W8A8".into(),
            Method::TfliteW8A8 => "TFLite-W8A8".into(),
            Method::GemmlowpW8A8 => "GEMMLOWP-W8A8".into(),
            Method::RuyF32 => "Ruy-FP32".into(),
            Method::XnnF32 => "XNNPack-FP32".into(),
            Method::TfliteF32 => "TFLite-FP32".into(),
            Method::EigenF32 => "Eigen-FP32".into(),
        }
    }

    /// The `kernels::KernelRegistry` name this method models — the
    /// shared modeled/measured namespace.
    pub fn registry_name(&self) -> String {
        match self {
            Method::FullPack(v) => format!("fullpack-{}", v.name()),
            Method::FullPackSwar(v) => format!("fullpack-{}-swar", v.name()),
            Method::FullPackIsa(v, kind) => format!("fullpack-{}-{}", v.name(), kind.suffix()),
            Method::FullPackGemm(v) => format!("fullpack-{}-gemm", v.name()),
            Method::Lut(v) => format!("lut-{}", v.name()),
            Method::LutGemm(v) => format!("lut-{}-gemm", v.name()),
            Method::Naive(v) => format!("naive-{}", v.name()),
            Method::Ulppack { bits } => format!("ulppack-w{bits}a{bits}"),
            Method::RuyW8A8 => "ruy-w8a8".into(),
            Method::XnnW8A8 => "xnn-w8a8".into(),
            Method::TfliteW8A8 => "tflite-w8a8".into(),
            Method::GemmlowpW8A8 => "gemmlowp-w8a8".into(),
            Method::RuyF32 => "ruy-f32".into(),
            Method::XnnF32 => "xnn-f32".into(),
            Method::TfliteF32 => "tflite-f32".into(),
            Method::EigenF32 => "eigen-f32".into(),
        }
    }

    /// Resolve a registry kernel name to its modeled method, via the
    /// registered kernel's own `cost_method` (i.e. *derived from the
    /// registry*, not a second hard-coded table).  Checks the GEMV
    /// namespace first, then the GEMM tier.
    pub fn from_registry(name: &str) -> Option<Method> {
        let reg = crate::kernels::KernelRegistry::global();
        reg.get(name)
            .and_then(|k| k.cost_method())
            .or_else(|| reg.get_gemm(name).and_then(|g| g.cost_method()))
    }

    /// The quantization variant of the data this method consumes (int8
    /// for the W8A8 and FP32 stand-ins, which take int8-valued inputs).
    pub fn data_variant(&self) -> Variant {
        match self {
            Method::FullPack(v)
            | Method::FullPackSwar(v)
            | Method::FullPackIsa(v, _)
            | Method::FullPackGemm(v)
            | Method::Lut(v)
            | Method::LutGemm(v)
            | Method::Naive(v) => *v,
            Method::Ulppack { bits } => {
                let b = BitWidth::from_u8(*bits).unwrap_or(BitWidth::B8);
                Variant::new(b, b)
            }
            _ => Variant::new(BitWidth::B8, BitWidth::B8),
        }
    }

    /// The ten methods of Fig. 4 (baseline first), using the paper's
    /// ULPPACK bit-widths.
    pub fn fig4_lineup() -> Vec<Method> {
        vec![
            Method::RuyW8A8,
            Method::fullpack("w4a8"),
            Method::XnnW8A8,
            Method::TfliteW8A8,
            Method::GemmlowpW8A8,
            Method::RuyF32,
            Method::XnnF32,
            Method::TfliteF32,
            Method::EigenF32,
            Method::Ulppack { bits: 1 },
            Method::Ulppack { bits: 2 },
            Method::Ulppack { bits: 3 },
        ]
    }

    /// Bytes of weight storage per row of a depth-`k` layer.
    pub fn weight_bytes_per_row(&self, k: usize) -> usize {
        match self {
            // the GEMM, LUT and real-ISA tiers share the GEMV tier's
            // packed layout exactly (the LUT kernels index tables *by*
            // the packed bytes, the ISA kernels extract bit-planes from
            // them in-register — no re-layout)
            Method::FullPack(v)
            | Method::FullPackIsa(v, _)
            | Method::FullPackGemm(v)
            | Method::Lut(v)
            | Method::LutGemm(v)
            | Method::Naive(v) => v.w.packed_bytes(v.padded_depth(k)),
            // the SWAR tier also streams its 8-byte per-row weight-sum
            // side table (Weights::SwarPacked, DESIGN.md §8)
            Method::FullPackSwar(v) => {
                v.w.packed_bytes(v.padded_depth(k)) + if v.w.is_sub_byte() { 8 } else { 0 }
            }
            Method::Ulppack { .. } => k, // 1 byte/value in a u16 half-lane
            Method::RuyW8A8 | Method::XnnW8A8 | Method::TfliteW8A8 | Method::GemmlowpW8A8 => k,
            Method::RuyF32 | Method::XnnF32 | Method::TfliteF32 | Method::EigenF32 => 4 * k,
        }
    }

    /// Bytes of one activation vector of logical depth `k`.
    pub fn act_bytes(&self, k: usize) -> usize {
        match self {
            Method::FullPack(v)
            | Method::FullPackSwar(v)
            | Method::FullPackIsa(v, _)
            | Method::FullPackGemm(v)
            | Method::Lut(v)
            | Method::LutGemm(v)
            | Method::Naive(v) => v.a.packed_bytes(v.padded_depth(k)),
            Method::Ulppack { .. } => k,
            Method::RuyW8A8 | Method::XnnW8A8 | Method::TfliteW8A8 | Method::GemmlowpW8A8 => k,
            Method::RuyF32 | Method::XnnF32 | Method::TfliteF32 | Method::EigenF32 => 4 * k,
        }
    }

    /// Batch columns per weight pass (1 except ULPPACK—'s batch-8 GEMM).
    pub fn batch(&self) -> usize {
        match self {
            Method::Ulppack { .. } => 8,
            _ => 1,
        }
    }

    /// Memory traffic of one inference call on a `z × k` layer.
    pub fn traffic(&self, z: usize, k: usize) -> GemvTraffic {
        GemvTraffic {
            z,
            w_bytes_per_row: self.weight_bytes_per_row(k),
            a_bytes: self.act_bytes(k),
            batch: self.batch(),
            out_elem_bytes: 4,
        }
    }

    /// Instruction mix of one inference call on a `z × k` layer.
    pub fn instr_mix(&self, z: usize, k: usize) -> InstrMix {
        // the GEMM tiers' single-column degenerate case (a GEMV with
        // per-column bookkeeping); batched calls use `instr_mix_gemm`
        if matches!(self, Method::FullPackGemm(_) | Method::LutGemm(_)) {
            return self.instr_mix_gemm(z, k, 1);
        }
        // the LUT tier is not the per-row × z shape below: the table
        // build is a whole-call cost that amortizes across rows
        if let Method::Lut(v) = self {
            return lut_call_mix(*v, z, k, 1);
        }
        let zf = z as f64;
        let kf = k as f64;
        // per-row fixed overhead: accumulator setup, 16-lane reduction,
        // result store, loop bookkeeping
        let row_overhead = InstrMix { loads: 0.0, stores: 1.0, macs: 0.0, alus: 4.0, scalar: 6.0 };
        let per_row: InstrMix = match self {
            Method::FullPack(v) => {
                let kp = v.padded_depth(k) as f64;
                match (v.w.is_sub_byte(), v.a.is_sub_byte()) {
                    (true, false) => {
                        // W-sub × A8: per block of G = 16·E elements:
                        // 1 weight load + E act loads, 2E-1 shifts, 2E
                        // widening MACs, 2 bookkeeping
                        let e = v.w.elems_per_byte() as f64;
                        let blocks = kp / (16.0 * e);
                        InstrMix {
                            loads: blocks * (1.0 + e),
                            stores: 0.0,
                            macs: blocks * 2.0 * e,
                            alus: blocks * (2.0 * e - 1.0),
                            scalar: blocks * 2.0,
                        }
                    }
                    (false, true) => {
                        let e = v.a.elems_per_byte() as f64;
                        let blocks = kp / (16.0 * e);
                        InstrMix {
                            loads: blocks * (e + 1.0),
                            stores: 0.0,
                            macs: blocks * 2.0 * e,
                            alus: blocks * (2.0 * e - 1.0),
                            scalar: blocks * 2.0,
                        }
                    }
                    (true, true) => {
                        let e = v.w.elems_per_byte() as f64;
                        let blocks = kp / (16.0 * e);
                        InstrMix {
                            loads: blocks * 2.0,
                            stores: 0.0,
                            macs: blocks * 2.0 * e,
                            alus: blocks * 2.0 * (2.0 * e - 1.0),
                            scalar: blocks * 2.0,
                        }
                    }
                    (false, false) => per16(kf, 2.0, 2.0, 0.0, 0.75), // = Ruy
                }
            }
            Method::FullPackSwar(v) => {
                if v.w.is_sub_byte() {
                    // per 8-byte chunk (8·E elements): one u64 weight
                    // load + E u64 activation loads (counted as half a
                    // 16-byte vector load each), one mask-expand
                    // multiply per bit-plane (B·E = 8 planes), ~9
                    // shift/and/select/accumulate ops per plane, E
                    // bias XORs, chunk bookkeeping + amortized flush
                    let e = v.w.elems_per_byte() as f64;
                    let kp = v.padded_depth(k) as f64;
                    let chunks = kp / (8.0 * e);
                    InstrMix {
                        loads: chunks * 0.5 * (1.0 + e),
                        stores: 0.0,
                        macs: chunks * 8.0,
                        alus: chunks * (8.0 * 9.0 + e),
                        scalar: chunks * 3.0,
                    }
                } else {
                    // w8a8: u64 loads of both operands, 8 scalar
                    // extract+MAC pairs per chunk, interleaved acc
                    let chunks = kf / 8.0;
                    InstrMix {
                        loads: chunks,
                        stores: 0.0,
                        macs: chunks * 8.0,
                        alus: chunks * 16.0,
                        scalar: chunks * 4.0,
                    }
                }
            }
            Method::FullPackIsa(v, kind) => {
                // real intrinsics, parameterized by lane width: with
                // r = lane_bytes/16 packed blocks per vector register,
                // the weight load and loop bookkeeping are paid once
                // per r blocks while per-element work is lane-count
                // invariant (wider lanes do r blocks per op)
                let r = kind.lane_bytes() as f64 / 16.0;
                if v.w.is_sub_byte() {
                    // per 16-byte block (16·E elements): 1/r weight
                    // loads + E act loads; per sub-vector one
                    // shift+mask+sign-extend+bias (4 ALU) and one
                    // MAC+widen pair (2 MAC-class); 2/r bookkeeping
                    let e = v.w.elems_per_byte() as f64;
                    let kp = v.padded_depth(k) as f64;
                    let blocks = kp / (16.0 * e);
                    InstrMix {
                        loads: blocks * (1.0 / r + e),
                        stores: 0.0,
                        macs: blocks * 2.0 * e,
                        alus: blocks * 4.0 * e,
                        scalar: blocks * 2.0 / r,
                    }
                } else {
                    // w8a8 widening path, per 16 elements: both operand
                    // loads and the multiply chain scale with 1/r, but
                    // AVX2 pays 2 extra widen/shuffle ops per 32-byte
                    // chunk (cvtepi8_epi16 of each half)
                    let units = kf / 16.0;
                    InstrMix {
                        loads: units * 2.0 / r,
                        stores: 0.0,
                        macs: units * 2.0 / r,
                        alus: units * (2.0 / r + (r - 1.0) * 2.0),
                        scalar: units / r,
                    }
                }
            }
            Method::Naive(v) => {
                // Alg. 1: scalar extraction — per element ~1.5 shift, 1
                // scalar MAC, 1.5 loads amortized, heavy bookkeeping
                let e = v.w.elems_per_byte().max(v.a.elems_per_byte()) as f64;
                let _ = e;
                InstrMix {
                    loads: kf * 1.5,
                    stores: 0.0,
                    macs: kf,
                    alus: kf * 2.0,
                    scalar: kf,
                }
            }
            // ULPPACK: per 16 values (8 u16 lanes): 2 loads, 2 lane
            // MAC/acc ops, extraction every S lanes (~6 ALU per event),
            // per-batch-column; zero-point correction folded into
            // row_overhead scale below.
            Method::Ulppack { bits } => {
                let s = (255usize / ((((1usize << bits) - 1).pow(2)).max(1))).max(1) as f64;
                let per_col = InstrMix {
                    loads: kf / 16.0 * 2.0,
                    stores: 0.0,
                    macs: kf / 16.0 * 2.0,
                    alus: (kf / 2.0 / s) * 6.0,
                    scalar: kf / 16.0,
                };
                per_col.scale(self.batch() as f64)
            }
            Method::RuyW8A8 => per16(kf, 2.0, 2.0, 0.0, 0.75),
            Method::XnnW8A8 => per16(kf, 1.25, 2.0, 0.0, 0.125),
            Method::TfliteW8A8 => per16(kf, 2.0, 2.0, 2.0, 4.0),
            Method::GemmlowpW8A8 => {
                // Ruy + the pack-to-temp pass (1 extra load+store/16B)
                let mut m = per16(kf, 3.0, 2.0, 0.0, 1.25);
                m.stores += kf / 16.0;
                m
            }
            Method::RuyF32 => per16(kf, 8.0, 4.0, 0.0, 1.0),
            Method::XnnF32 => per16(kf, 5.0, 4.0, 0.0, 0.5),
            Method::EigenF32 => per16(kf, 5.25, 4.0, 0.0, 1.0),
            Method::TfliteF32 => per16(kf, 8.0, 4.0, 4.0, 6.0),
            Method::FullPackGemm(_) | Method::Lut(_) | Method::LutGemm(_) => {
                unreachable!("handled above")
            }
        };
        let overhead_scale = self.batch() as f64;
        per_row.add(&row_overhead.scale(overhead_scale)).scale(zf)
    }

    /// Instruction mix of one **batched GEMM** call (`batch` columns)
    /// on a `z × k` layer — the extraction-amortization curve.
    ///
    /// For [`Method::FullPackGemm`], per packed block of `G = 16·E`
    /// elements the weight load and the `2E−1` extraction shifts are
    /// paid once per `kernels::fullpack_gemm::COL_TILE`-column tile
    /// (the kernel re-extracts per tile of 4, so amortization caps at
    /// `COL_TILE` — charging one extraction per whole batch would
    /// overstate large-batch gains), while the `E` activation loads
    /// and `2E` widening MACs are paid per column — so per-column cost
    /// falls toward the tile-amortized MAC floor as batch grows.
    /// Every other method models the
    /// paper's protocol: back-to-back whole calls of the method's own
    /// per-call width — `batch` single-column calls for the GEMV
    /// rivals, `⌈batch/8⌉` batch-8 calls for ULPPACK (charging it one
    /// full call per column would overstate its cost ~8×).
    pub fn instr_mix_gemm(&self, z: usize, k: usize, batch: usize) -> InstrMix {
        let b = batch.max(1) as f64;
        if let Method::FullPackGemm(v) = self {
            let e = v.w.elems_per_byte() as f64;
            let kp = v.padded_depth(k) as f64;
            let blocks = kp / (16.0 * e);
            let tiles =
                batch.max(1).div_ceil(crate::kernels::fullpack_gemm::COL_TILE) as f64;
            // amortized once per COL_TILE-column tile: 1 weight load,
            // 2E−1 shifts, 2 bookkeeping; per column: E act loads, 2E
            // MACs, 1 accumulator-tile op, 1 column step
            let per_row = InstrMix {
                loads: blocks * (tiles + b * e),
                stores: 0.0,
                macs: blocks * b * 2.0 * e,
                alus: blocks * (tiles * (2.0 * e - 1.0) + b),
                scalar: blocks * (2.0 * tiles + b),
            };
            let row_overhead =
                InstrMix { loads: 0.0, stores: 1.0, macs: 0.0, alus: 4.0, scalar: 6.0 };
            return per_row.add(&row_overhead.scale(b)).scale(z as f64);
        }
        // LUT GEMM: per-column tables (builds scale with batch — table
        // construction is NOT amortizable, each column's activations
        // differ), but the packed weight bytes stream once per
        // COL_TILE-column tile instead of once per column.  Note the
        // contrast with the repeated-call fallback used for
        // [`Method::Lut`]: b separate GEMV calls also pay b builds,
        // so the GEMM tier's whole gain is the weight-stream reuse
        if let Method::LutGemm(v) = self {
            return lut_call_mix(*v, z, k, batch);
        }
        // whole calls of the method's own per-call width
        let calls = batch.max(1).div_ceil(self.batch());
        self.instr_mix(z, k).scale(calls as f64)
    }

    /// [`Method::instr_mix_gemm`] adjusted for the core's
    /// auto-vectorization effectiveness (see [`Method::instr_mix_on`]).
    pub fn instr_mix_gemm_on(
        &self,
        z: usize,
        k: usize,
        batch: usize,
        core: &crate::costmodel::CoreModel,
    ) -> InstrMix {
        let mix = self.instr_mix_gemm(z, k, batch);
        if self.simd_staged() {
            core.degrade_staged(mix)
        } else {
            mix
        }
    }

    /// Does this method's inner loop depend on the compiler turning
    /// staged 16-lane array code into real SIMD?  The SWAR tier (plain
    /// 64-bit register ops), the naive strawman (scalar by
    /// construction), the LUT tier (data-dependent table gathers —
    /// scalar on any core, which is exactly why it wins on weak
    /// vectorizers) and the real-ISA tier (hand-written intrinsics, no
    /// vectorizer in the loop) run at their modeled cost everywhere;
    /// everything else degrades by `CoreModel::autovec_eff`
    /// (DESIGN.md §8).
    pub fn simd_staged(&self) -> bool {
        !matches!(
            self,
            Method::FullPackSwar(_)
                | Method::FullPackIsa(..)
                | Method::Naive(_)
                | Method::Lut(_)
                | Method::LutGemm(_)
        )
    }

    /// The narrowest SIMD register width (bytes) this method needs the
    /// executing core to have — 0 for everything outside the real-ISA
    /// tier.  `PlanBuilder`'s CostModel policy skips methods whose
    /// requirement exceeds `CoreModel::vec_bytes`, so a portable core
    /// model never selects an ISA kernel it cannot reason about.
    pub fn min_lane_bytes(&self) -> f64 {
        match self {
            Method::FullPackIsa(_, kind) => kind.lane_bytes() as f64,
            _ => 0.0,
        }
    }

    /// [`Method::instr_mix`] adjusted for the core's auto-vectorization
    /// effectiveness: on `autovec_eff = 1` cores (the paper's NEON
    /// assembly regime) this is the plain mix; below 1, lane-staged
    /// methods pay up to the full 16-lane serialization.
    pub fn instr_mix_on(&self, z: usize, k: usize, core: &crate::costmodel::CoreModel) -> InstrMix {
        let mix = self.instr_mix(z, k);
        if self.simd_staged() {
            core.degrade_staged(mix)
        } else {
            mix
        }
    }
}

/// One LUT-tier call on a `z × k` layer with `batch` columns
/// ([`Method::Lut`] is the `batch = 1` case).
///
/// Per column, the build fills 256 entries per packed weight byte slot
/// via the incremental recurrence (clear the top field, load the
/// smaller entry, add the new field's contribution): ~3 scalar ops per
/// entry, plus one streaming pass over that column's activations.  Per
/// output row, the packed weight bytes stream once per
/// `kernels::fullpack_gemm::COL_TILE`-column tile (vector loads), and
/// every weight byte costs one gather-style table load + add *per
/// column* — scalar, because the data-dependent indices defeat the
/// vectorizer (which is also why [`Method::simd_staged`] is false).
fn lut_call_mix(v: Variant, z: usize, k: usize, batch: usize) -> InstrMix {
    let b = batch.max(1) as f64;
    let wb = v.w.packed_bytes(v.padded_depth(k)) as f64;
    let tiles = batch.max(1).div_ceil(crate::kernels::fullpack_gemm::COL_TILE) as f64;
    let build = InstrMix {
        loads: b * v.a.packed_bytes(v.padded_depth(k)) as f64 / 16.0,
        stores: 0.0,
        macs: 0.0,
        alus: 0.0,
        scalar: b * 3.0 * 256.0 * wb,
    };
    let per_row = InstrMix {
        loads: tiles * wb / 16.0,
        stores: 0.0,
        macs: 0.0,
        alus: 0.0,
        scalar: b * 2.0 * wb,
    };
    let row_overhead = InstrMix { loads: 0.0, stores: 1.0, macs: 0.0, alus: 4.0, scalar: 6.0 };
    per_row.add(&row_overhead.scale(b)).scale(z as f64).add(&build)
}

/// Helper: a mix expressed per 16 logical elements.
fn per16(k: f64, loads: f64, macs: f64, alus: f64, scalar: f64) -> InstrMix {
    let u = k / 16.0;
    InstrMix { loads: u * loads, stores: 0.0, macs: u * macs, alus: u * alus, scalar: u * scalar }
}

/// Instruction counts by pipeline class, for one GEMV call.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct InstrMix {
    /// 16-byte vector loads
    pub loads: f64,
    /// stores
    pub stores: f64,
    /// widening multiply-accumulate ops (NEON smlal class)
    pub macs: f64,
    /// vector ALU ops: shifts, adds, reductions
    pub alus: f64,
    /// scalar bookkeeping: address increments, branches, moves
    pub scalar: f64,
}

impl InstrMix {
    pub fn total(&self) -> f64 {
        self.loads + self.stores + self.macs + self.alus + self.scalar
    }

    pub fn scale(&self, f: f64) -> InstrMix {
        InstrMix {
            loads: self.loads * f,
            stores: self.stores * f,
            macs: self.macs * f,
            alus: self.alus * f,
            scalar: self.scalar * f,
        }
    }

    pub fn add(&self, o: &InstrMix) -> InstrMix {
        InstrMix {
            loads: self.loads + o.loads,
            stores: self.stores + o.stores,
            macs: self.macs + o.macs,
            alus: self.alus + o.alus,
            scalar: self.scalar + o.scalar,
        }
    }
}

/// All FullPack variants + key rivals, used by several figure harnesses.
pub fn all_methods() -> Vec<Method> {
    let mut v: Vec<Method> = Variant::PAPER_VARIANTS.iter().copied().map(Method::FullPack).collect();
    v.extend(Method::fig4_lineup());
    v
}

/// The weight footprint in bytes of a `z × k` layer under this method —
/// the quantity behind the Fig. 6 "fits in LLC" boundary.
pub fn weight_footprint(method: Method, z: usize, k: usize) -> usize {
    z * method.weight_bytes_per_row(k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_match_paper_legend() {
        assert_eq!(Method::RuyW8A8.label(), "Ruy-W8A8");
        assert_eq!(Method::fullpack("w4a8").label(), "FullPack-W4A8");
        assert_eq!(Method::Ulppack { bits: 3 }.label(), "ULPPACK-W3A3");
    }

    #[test]
    fn traffic_scales_with_bits() {
        let k = 2048;
        let w8 = Method::RuyW8A8.weight_bytes_per_row(k);
        assert_eq!(Method::fullpack("w4a8").weight_bytes_per_row(k), w8 / 2);
        assert_eq!(Method::fullpack("w2a2").weight_bytes_per_row(k), w8 / 4);
        assert_eq!(Method::fullpack("w1a1").weight_bytes_per_row(k), w8 / 8);
        assert_eq!(Method::RuyF32.weight_bytes_per_row(k), w8 * 4);
        // ULPPACK stores 1 byte per value despite sub-byte data
        assert_eq!(Method::Ulppack { bits: 2 }.weight_bytes_per_row(k), w8);
    }

    #[test]
    fn instr_count_monotone_in_size() {
        let m = Method::fullpack("w4a8");
        let a = m.instr_mix(256, 256).total();
        let b = m.instr_mix(512, 512).total();
        assert!(b > 3.0 * a);
    }

    #[test]
    fn fullpack_w8a8_degenerates_to_ruy() {
        let f = Method::fullpack("w8a8").instr_mix(128, 256);
        let r = Method::RuyW8A8.instr_mix(128, 256);
        assert_eq!(f, r);
    }

    #[test]
    fn subbyte_variants_fewer_loads_more_alus() {
        let k = 2048;
        let z = 128;
        let full = Method::fullpack("w4a8").instr_mix(z, k);
        let ruy = Method::RuyW8A8.instr_mix(z, k);
        assert!(full.loads < ruy.loads, "packed loads fewer");
        assert!(full.alus > ruy.alus, "extraction shifts extra");
    }

    #[test]
    fn w1a1_vs_w4a4_instruction_ratio() {
        // paper §4.5 discussion: W1A1's extraction overhead keeps its
        // instruction count near W4A4's despite 4x fewer bytes.
        let a = Method::fullpack("w1a1").instr_mix(2048, 2048).total();
        let b = Method::fullpack("w4a4").instr_mix(2048, 2048).total();
        let r = a / b;
        assert!((0.6..1.3).contains(&r), "ratio {r}");
    }

    #[test]
    fn ulppack_batch8() {
        assert_eq!(Method::Ulppack { bits: 2 }.batch(), 8);
        assert_eq!(Method::RuyW8A8.batch(), 1);
        let t = Method::Ulppack { bits: 2 }.traffic(64, 64);
        assert_eq!(t.batch, 8);
    }

    #[test]
    fn footprint_boundary() {
        // 2048x2048: 4MB at W8A8 (spills 2MB L2), 2MB at W4A8 (fits-ish)
        assert_eq!(weight_footprint(Method::RuyW8A8, 2048, 2048), 4 << 20);
        assert_eq!(weight_footprint(Method::fullpack("w4a8"), 2048, 2048), 2 << 20);
    }

    #[test]
    fn registry_namespace_roundtrip() {
        for m in all_methods() {
            let name = m.registry_name();
            if let Some(back) = Method::from_registry(&name) {
                assert_eq!(back, m, "{name} resolved to a different method");
            } else {
                // the only modeled methods without a registered kernel
                assert!(matches!(m, Method::XnnF32 | Method::Ulppack { bits: 3 }), "{name}");
            }
        }
        assert_eq!(Method::from_registry("fullpack-w4a8"), Some(Method::fullpack("w4a8")));
        assert_eq!(Method::from_registry("nope"), None);
        assert_eq!(Method::fullpack("w2a2").data_variant(), Variant::parse("w2a2").unwrap());
        assert_eq!(Method::RuyW8A8.data_variant(), Variant::parse("w8a8").unwrap());
    }

    #[test]
    fn lineup_has_all_rivals() {
        let lineup = Method::fig4_lineup();
        assert_eq!(lineup.len(), 12);
        assert_eq!(lineup[0], Method::RuyW8A8);
    }

    #[test]
    fn swar_methods_share_registry_namespace() {
        for v in ["w4a8", "w2a8", "w1a8", "w8a8"] {
            let m = Method::fullpack_swar(v);
            let name = m.registry_name();
            assert_eq!(Method::from_registry(&name), Some(m), "{name}");
            assert_eq!(m.data_variant(), Variant::parse(v).unwrap());
            assert_eq!(m.batch(), 1);
        }
        assert_eq!(Method::fullpack_swar("w4a8").label(), "FullPack-SWAR-W4A8");
        assert_eq!(Method::fullpack_swar("w1a8").registry_name(), "fullpack-w1a8-swar");
    }

    #[test]
    fn gemm_methods_share_registry_namespace_and_layout() {
        for v in ["w4a8", "w2a8", "w1a8"] {
            let m = Method::fullpack_gemm(v);
            let name = m.registry_name();
            assert_eq!(name, format!("fullpack-{v}-gemm"));
            // resolves through the GEMM tier of the registry
            assert_eq!(Method::from_registry(&name), Some(m), "{name}");
            assert_eq!(m.data_variant(), Variant::parse(v).unwrap());
            // identical packed layout to the GEMV tier
            assert_eq!(
                m.weight_bytes_per_row(2048),
                Method::fullpack(v).weight_bytes_per_row(2048)
            );
            assert_eq!(m.act_bytes(2048), Method::fullpack(v).act_bytes(2048));
            // staged 16-lane code, like the GEMV tier
            assert!(m.simd_staged());
        }
        assert_eq!(Method::fullpack_gemm("w4a8").label(), "FullPack-GEMM-W4A8");
        // the rival GEMM backend is modeled as repeated Ruy
        assert_eq!(Method::from_registry("ruy-like-w8a8-gemm"), Some(Method::RuyW8A8));
        // the oracle is deliberately unmodeled
        assert_eq!(Method::from_registry("naive-oracle-gemm"), None);
    }

    #[test]
    fn gemm_mix_amortizes_extraction_only() {
        let (z, k) = (256usize, 2048usize);
        let m = Method::fullpack_gemm("w4a8");
        let gemv = Method::fullpack("w4a8");
        // single column: the GEMM mix is the GEMV mix plus per-column
        // bookkeeping — never cheaper
        let g1 = m.instr_mix_gemm(z, k, 1);
        assert!(g1.total() >= gemv.instr_mix(z, k).total());
        assert_eq!(m.instr_mix(z, k), g1, "instr_mix degenerates to batch 1");
        // batch b: MACs scale with b exactly (no MAC is amortizable)...
        let g8 = m.instr_mix_gemm(z, k, 8);
        assert!((g8.macs - 8.0 * g1.macs).abs() < 1e-6);
        // ...but loads and shifts do not (weight loads + extraction are
        // paid once per block), so total grows sublinearly
        assert!(g8.loads < 8.0 * g1.loads);
        assert!(g8.alus < 8.0 * g1.alus);
        assert!(g8.total() < 8.0 * g1.total());
        // repeated-GEMV modeling for non-GEMM methods is exactly b calls
        let r = Method::RuyW8A8;
        assert_eq!(r.instr_mix_gemm(z, k, 5), r.instr_mix(z, k).scale(5.0));
    }

    #[test]
    fn lut_methods_share_registry_namespace_and_layout() {
        for v in ["w4a8", "w2a8", "w1a8", "w4a4"] {
            let m = Method::lut(v);
            let g = Method::lut_gemm(v);
            assert_eq!(m.registry_name(), format!("lut-{v}"));
            assert_eq!(g.registry_name(), format!("lut-{v}-gemm"));
            // both tiers resolve through the registry's own cost_method
            assert_eq!(Method::from_registry(&m.registry_name()), Some(m));
            assert_eq!(Method::from_registry(&g.registry_name()), Some(g));
            // identical packed layout to the FullPack GEMV tier: the
            // tables are indexed *by* the packed bytes, no re-layout
            assert_eq!(
                m.weight_bytes_per_row(2048),
                Method::fullpack(v).weight_bytes_per_row(2048)
            );
            assert_eq!(m.act_bytes(2048), Method::fullpack(v).act_bytes(2048));
            assert_eq!(m.data_variant(), Variant::parse(v).unwrap());
            // table gathers are scalar on every core
            assert!(!m.simd_staged());
            assert!(!g.simd_staged());
        }
        assert_eq!(Method::lut("w4a8").label(), "LUT-W4A8");
        assert_eq!(Method::lut_gemm("w2a8").label(), "LUT-GEMM-W2A8");
    }

    #[test]
    fn lut_build_amortizes_across_rows_and_gemm_amortizes_weight_stream() {
        let k = 2048;
        let m = Method::lut("w4a8");
        // the table build is a whole-call cost: doubling the rows less
        // than doubles the total
        let a = m.instr_mix(64, k).total();
        let b2 = m.instr_mix(128, k).total();
        assert!(b2 < 2.0 * a, "build amortizes across rows: {b2} vs 2×{a}");
        let g = Method::lut_gemm("w4a8");
        let g1 = g.instr_mix_gemm(256, k, 1);
        assert_eq!(m.instr_mix(256, k), g1, "batch 1 degenerates to the GEMV tier");
        // batch b: builds and gathers scale with b exactly (per-column
        // tables are not amortizable)...
        let g8 = g.instr_mix_gemm(256, k, 8);
        assert!((g8.scalar - 8.0 * g1.scalar).abs() < 1e-6);
        // ...but the packed weight stream is paid once per COL_TILE
        // tile, so the GEMM tier beats b repeated GEMV calls (which is
        // what `instr_mix_gemm` charges Method::Lut)
        assert!(g8.loads < 8.0 * g1.loads);
        assert!(g8.total() < m.instr_mix_gemm(256, k, 8).total());
    }

    #[test]
    fn ulppack_batched_cost_counts_whole_calls() {
        // ULPPACK's protocol serves 8 columns per call: a 16-column
        // batch is TWO batch-8 calls, not sixteen (charging a full
        // call per column would overstate its cost ~8x and rig the
        // batched CostModel argmin against it)
        let m = Method::Ulppack { bits: 2 };
        let one = m.instr_mix(256, 256);
        assert_eq!(m.instr_mix_gemm(256, 256, 8), one.scale(1.0));
        assert_eq!(m.instr_mix_gemm(256, 256, 9), one.scale(2.0));
        assert_eq!(m.instr_mix_gemm(256, 256, 16), one.scale(2.0));
        assert_eq!(m.instr_mix_gemm(256, 256, 17), one.scale(3.0));
    }

    #[test]
    fn isa_methods_share_registry_namespace_and_layout() {
        use crate::kernels::isa::{detected, ISA_KINDS};
        for kind in ISA_KINDS {
            for v in ["w4a8", "w2a8", "w1a8", "w8a8"] {
                let m = Method::fullpack_isa(v, kind);
                let name = m.registry_name();
                assert_eq!(name, format!("fullpack-{v}-{}", kind.suffix()));
                // identical packed layout to the GEMV tier — the ISA
                // kernels consume Weights::Packed verbatim, no side
                // table and no re-layout
                assert_eq!(
                    m.weight_bytes_per_row(2048),
                    Method::fullpack(v).weight_bytes_per_row(2048)
                );
                assert_eq!(m.act_bytes(2048), Method::fullpack(v).act_bytes(2048));
                assert_eq!(m.data_variant(), Variant::parse(v).unwrap());
                assert_eq!(m.batch(), 1);
                // hand-written intrinsics: vectorizer-independent
                assert!(!m.simd_staged());
                assert_eq!(m.min_lane_bytes(), kind.lane_bytes() as f64);
                // the registry carries an ISA entry iff the host can
                // execute it — from_registry resolves exactly then
                if detected().has(kind) {
                    assert_eq!(Method::from_registry(&name), Some(m), "{name}");
                } else {
                    assert_eq!(Method::from_registry(&name), None, "{name} must not register");
                }
            }
        }
        assert_eq!(Method::fullpack_isa("w4a8", IsaKind::Avx2).label(), "FullPack-AVX2-W4A8");
        assert_eq!(Method::fullpack_isa("w2a8", IsaKind::Neon).label(), "FullPack-NEON-W2A8");
        assert_eq!(Method::fullpack("w4a8").min_lane_bytes(), 0.0);
    }

    #[test]
    fn wider_isa_lanes_amortize_the_weight_stream() {
        let (z, k) = (256, 2048);
        for v in ["w4a8", "w1a8", "w8a8"] {
            let avx = Method::fullpack_isa(v, IsaKind::Avx2).instr_mix(z, k);
            let neon = Method::fullpack_isa(v, IsaKind::Neon).instr_mix(z, k);
            // 256-bit lanes halve the per-block weight loads and
            // bookkeeping; per-element MAC work is lane-invariant for
            // sub-byte (and strictly cheaper per op at w8a8)
            assert!(avx.loads < neon.loads, "{v}");
            assert!(avx.scalar < neon.scalar, "{v}");
        }
        // the ISA tier beats the staged FullPack mix at its own game:
        // same MAC count, no 16-lane staging risk, fewer shift ops
        let isa = Method::fullpack_isa("w4a8", IsaKind::Neon).instr_mix(z, k);
        let staged = Method::fullpack("w4a8").instr_mix(z, k);
        assert!((isa.macs - staged.macs).abs() < 1e-6, "same widening MAC schedule");
    }

    #[test]
    fn swar_shares_layout_traffic_but_not_staging() {
        // same packed layout plus the 8-byte per-row weight-sum side
        // table (Weights::SwarPacked carries it; the kernel reads one
        // i64 per row)
        for v in ["w4a8", "w2a8", "w1a8"] {
            assert_eq!(
                Method::fullpack_swar(v).weight_bytes_per_row(2048),
                Method::fullpack(v).weight_bytes_per_row(2048) + 8,
                "{v}"
            );
            assert_eq!(
                Method::fullpack_swar(v).act_bytes(2048),
                Method::fullpack(v).act_bytes(2048),
                "{v}"
            );
        }
        // the w8a8 entry reuses plain Weights::Packed — no side table
        assert_eq!(
            Method::fullpack_swar("w8a8").weight_bytes_per_row(2048),
            Method::RuyW8A8.weight_bytes_per_row(2048)
        );
        // the tier is vectorizer-independent; everything staged is not
        assert!(!Method::fullpack_swar("w4a8").simd_staged());
        assert!(!Method::Naive(Variant::parse("w4a8").unwrap()).simd_staged());
        assert!(Method::fullpack("w4a8").simd_staged());
        assert!(Method::RuyW8A8.simd_staged());
        assert!(Method::Ulppack { bits: 2 }.simd_staged());
    }
}

//! The model zoo (DESIGN.md §10): named [`ModelGraph`] constructors
//! registered in a [`ModelRegistry`], so the engine, the CLI and the
//! cost model all select models by *name* — the model-level twin of the
//! kernel registry.
//!
//! Built-in graphs:
//!
//! | name              | topology                              | scenario |
//! |-------------------|---------------------------------------|----------|
//! | `deepspeech`      | 3×FC → LSTM → 2×FC (paper Fig. 9)     | §4.6 end-to-end (GEMV+GEMM split) |
//! | `mlp`             | FC → ReLU → FC → ReLU → FC            | pure-FC sub-byte classifier (all-GEMV at batch 1) |
//! | `keyword-spotter` | GRU → FFN(FC+ReLU) → FC               | streaming KWS: recurrent scan + batched W8A8 head |
//!
//! `deepspeech` reproduces the legacy `DeepSpeech` struct exactly —
//! same shapes, same weight seeds, same §4.6 variant split — so
//! `CompiledModel` over it is bit-identical to the legacy forward
//! (pinned by `rust/tests/model_graph.rs`).

#![warn(missing_docs)]

use super::graph::ModelGraph;
use super::DeepSpeechConfig;
use crate::pack::{BitWidth, Variant};
use crate::util::error::{anyhow, Error};
use std::sync::OnceLock;

const W8A8: Variant = Variant::new(BitWidth::B8, BitWidth::B8);

/// Topology preset: the paper-sized graph or the CI-sized twin.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelSize {
    /// paper-scale shapes (DeepSpeech v0.9 &c.)
    Full,
    /// CI-sized shapes (seconds, not minutes, under `cargo test`)
    Tiny,
}

impl ModelSize {
    /// Parse `"full"` / `"tiny"`.
    pub fn parse(s: &str) -> Option<ModelSize> {
        match s {
            "full" => Some(ModelSize::Full),
            "tiny" => Some(ModelSize::Tiny),
            _ => None,
        }
    }

    /// Lowercase preset name.
    pub fn name(self) -> &'static str {
        match self {
            ModelSize::Full => "full",
            ModelSize::Tiny => "tiny",
        }
    }
}

/// The DeepSpeech-like Fig. 9 graph — the legacy model as a graph
/// constructor: W8A8 FC stack (paper: GEMM routed to Ruy), model-variant
/// LSTM gate GEMVs (the FullPack path), legacy weight seeds.
pub fn deepspeech_graph(cfg: DeepSpeechConfig, variant: Variant, seed: u64) -> ModelGraph {
    let h = cfg.n_hidden;
    ModelGraph::new("deepspeech", variant, cfg.n_input, cfg.time_steps, seed)
        .fc_fixed("fc1", h, true, W8A8)
        .fc_fixed("fc2", h, true, W8A8)
        .fc_fixed("fc3", h, true, W8A8)
        .lstm("lstm", h)
        .fc_fixed("fc5", h, true, W8A8)
        .fc_fixed("fc6", cfg.n_output, false, W8A8)
}

fn build_deepspeech(size: ModelSize, variant: Variant, seed: u64) -> ModelGraph {
    let cfg = match size {
        ModelSize::Full => DeepSpeechConfig::FULL,
        ModelSize::Tiny => DeepSpeechConfig::TINY,
    };
    deepspeech_graph(cfg, variant, seed)
}

/// Pure-FC MLP classifier: every layer quantizes on the model variant,
/// so at serving batch 1 the whole network runs the FullPack GEMV path
/// (standalone [`super::graph::Op::Relu`] nodes between layers).
pub fn mlp_graph(size: ModelSize, variant: Variant, seed: u64) -> ModelGraph {
    let (input, h1, h2, classes) = match size {
        ModelSize::Full => (784, 1024, 512, 10),
        ModelSize::Tiny => (64, 128, 64, 10),
    };
    ModelGraph::new("mlp", variant, input, 1, seed)
        .fc("fc1", h1, false)
        .relu("relu1", 20.0)
        .fc("fc2", h2, false)
        .relu("relu2", 20.0)
        .fc("out", classes, false)
}

/// GRU/FFN keyword spotter: a model-variant GRU scan over the MFCC
/// stream (the FullPack GEMV regime) feeding a batched W8A8 FFN head
/// (the GEMM regime) — both paper paths in one non-DeepSpeech topology.
pub fn keyword_spotter_graph(size: ModelSize, variant: Variant, seed: u64) -> ModelGraph {
    let (input, hidden, t, ffn, classes) = match size {
        ModelSize::Full => (40, 256, 16, 128, 12),
        ModelSize::Tiny => (40, 64, 4, 32, 12),
    };
    ModelGraph::new("keyword-spotter", variant, input, t, seed)
        .gru("gru", hidden)
        .fc_fixed("ffn", ffn, true, W8A8)
        .fc_fixed("out", classes, false, W8A8)
}

/// Synthetic N-model roster for residency and eviction tests: cycles
/// the built-in zoo with per-index seeds and zero-padded unique names
/// (`mlp-017`), without growing the registry itself.  Names sort in
/// roster order only within a topology, so LRU victim selection over a
/// roster exercises the `(last_used, name)` tie-break across topologies.
pub fn synthetic_roster(
    n: usize,
    size: ModelSize,
    variant: Variant,
    seed: u64,
) -> Vec<(String, ModelGraph)> {
    let reg = ModelRegistry::global();
    let names = reg.names();
    (0..n)
        .map(|i| {
            let base = names[i % names.len()];
            let graph = (reg.get(base).expect("builtin").build)(size, variant, seed + i as u64);
            (format!("{base}-{i:03}"), graph)
        })
        .collect()
}

/// One zoo entry: a named graph constructor.
pub struct ZooEntry {
    /// registry name (`deepspeech`, `mlp`, `keyword-spotter`)
    pub name: &'static str,
    /// one-line topology description
    pub blurb: &'static str,
    /// the graph constructor
    pub build: fn(ModelSize, Variant, u64) -> ModelGraph,
}

/// Named model-graph registry — the model-level twin of
/// `kernels::KernelRegistry`.
pub struct ModelRegistry {
    entries: Vec<ZooEntry>,
}

impl ModelRegistry {
    /// The built-in zoo.
    pub fn builtin() -> ModelRegistry {
        ModelRegistry {
            entries: vec![
                ZooEntry {
                    name: "deepspeech",
                    blurb: "3xFC -> LSTM -> 2xFC (paper Fig. 9, §4.6 split)",
                    build: build_deepspeech,
                },
                ZooEntry {
                    name: "mlp",
                    blurb: "pure-FC sub-byte classifier (FC/ReLU stack)",
                    build: mlp_graph,
                },
                ZooEntry {
                    name: "keyword-spotter",
                    blurb: "GRU scan -> batched W8A8 FFN head",
                    build: keyword_spotter_graph,
                },
            ],
        }
    }

    /// The process-wide registry of built-in graphs.
    pub fn global() -> &'static ModelRegistry {
        static REG: OnceLock<ModelRegistry> = OnceLock::new();
        REG.get_or_init(ModelRegistry::builtin)
    }

    /// Entry by name.
    pub fn get(&self, name: &str) -> Option<&ZooEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All registered names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name).collect()
    }

    /// Iterate the entries.
    pub fn iter(&self) -> impl Iterator<Item = &ZooEntry> {
        self.entries.iter()
    }

    /// Registered entry count.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Is the registry empty?  (Never, for the built-in set.)
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Build a named graph, or an error listing the registered zoo.
    pub fn build(
        &self,
        name: &str,
        size: ModelSize,
        variant: Variant,
        seed: u64,
    ) -> Result<ModelGraph, Error> {
        match self.get(name) {
            Some(e) => Ok((e.build)(size, variant, seed)),
            None => Err(anyhow!(
                "unknown model {name:?} (zoo: {})",
                self.names().join(", ")
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &str) -> Variant {
        Variant::parse(s).unwrap()
    }

    #[test]
    fn registry_serves_three_models() {
        let reg = ModelRegistry::global();
        assert!(reg.len() >= 3);
        assert!(!reg.is_empty());
        assert_eq!(reg.names(), vec!["deepspeech", "mlp", "keyword-spotter"]);
        for name in reg.names() {
            for size in [ModelSize::Full, ModelSize::Tiny] {
                let g = reg.build(name, size, v("w4a8"), 7).unwrap();
                assert!(g.validate().is_ok(), "{name} {:?}", size);
                assert_eq!(g.name, name);
            }
        }
        assert!(reg.build("nope", ModelSize::Tiny, v("w4a8"), 7).is_err());
    }

    #[test]
    fn deepspeech_graph_matches_legacy_shapes() {
        let cfg = DeepSpeechConfig::TINY;
        let g = deepspeech_graph(cfg, v("w4a8"), 7);
        assert_eq!(g.nodes.len(), 6);
        assert_eq!(g.nodes[3].z, cfg.gate_dim());
        assert_eq!(g.nodes[3].k, cfg.n_hidden);
        assert_eq!(g.input_len(), cfg.time_steps * cfg.n_input);
        assert_eq!(g.output_len(), cfg.time_steps * cfg.n_output);
        // legacy weight seeds: fc1..3 at 0..2, the cell at 100, fc5/6 at 4/5
        let offs: Vec<u64> = g.nodes.iter().map(|n| n.seed_offset).collect();
        assert_eq!(offs, vec![0, 1, 2, 100, 4, 5]);
    }

    #[test]
    fn synthetic_roster_names_are_unique_and_graphs_valid() {
        let roster = synthetic_roster(7, ModelSize::Tiny, v("w4a8"), 42);
        assert_eq!(roster.len(), 7);
        let mut names: Vec<&str> = roster.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names[0], "deepspeech-000");
        assert_eq!(names[1], "mlp-001");
        assert_eq!(names[3], "deepspeech-003");
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 7, "roster names collide");
        for (_, g) in &roster {
            g.validate().unwrap();
        }
        // the registry itself is untouched
        assert_eq!(
            ModelRegistry::global().names(),
            vec!["deepspeech", "mlp", "keyword-spotter"]
        );
    }

    #[test]
    fn size_parse_roundtrip() {
        for s in [ModelSize::Full, ModelSize::Tiny] {
            assert_eq!(ModelSize::parse(s.name()), Some(s));
        }
        assert_eq!(ModelSize::parse("huge"), None);
    }
}

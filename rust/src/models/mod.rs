//! Model definitions: the DeepSpeech-like network of the paper's
//! end-to-end evaluation (Fig. 9) and the CNN FC-layer zoo of the
//! on-device study (Fig. 11).

pub mod deepspeech;

pub use deepspeech::{DeepSpeech, DeepSpeechConfig, Layer, LayerKind};

/// One FullyConnected layer shape: `z` outputs from `k` inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FcShape {
    pub name: &'static str,
    pub k: usize,
    pub z: usize,
}

/// Final-classifier FC layers of the eleven CNNs in the paper's §4.7
/// Raspberry Pi study (feature dim → 1000 ImageNet classes; VGG19 also
/// carries its two 4096-wide FC layers, we use the classifier head as
/// the paper's figure does).
pub const CNN_FC_ZOO: [FcShape; 11] = [
    FcShape { name: "DenseNet201", k: 1920, z: 1000 },
    FcShape { name: "EfficientNetV2L", k: 1280, z: 1000 },
    FcShape { name: "InceptionV3", k: 2048, z: 1000 },
    FcShape { name: "InceptionResNetV2", k: 1536, z: 1000 },
    FcShape { name: "MobileNetV2", k: 1280, z: 1000 },
    FcShape { name: "NASNetLarge", k: 4032, z: 1000 },
    FcShape { name: "RegNetY320", k: 3712, z: 1000 },
    FcShape { name: "ResNet152", k: 2048, z: 1000 },
    FcShape { name: "ResNet152V2", k: 2048, z: 1000 },
    FcShape { name: "VGG19", k: 4096, z: 1000 },
    FcShape { name: "Xception", k: 2048, z: 1000 },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_has_eleven_networks() {
        assert_eq!(CNN_FC_ZOO.len(), 11);
        for fc in CNN_FC_ZOO {
            assert!(fc.k >= 1000 && fc.z == 1000, "{}", fc.name);
        }
    }
}

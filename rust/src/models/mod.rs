//! Model layer (DESIGN.md §10): the [`ModelGraph`] IR, the
//! [`CompiledModel`] executor, the named model [`zoo`], and the legacy
//! [`DeepSpeech`] struct (kept as the bit-exact oracle the graph
//! executor is pinned against), plus the CNN FC-layer zoo of the
//! on-device study (Fig. 11).
//!
//! The serving engine is generic over the [`Model`] trait: anything
//! that can forward frames (singly or batched), report its request
//! shape, and describe its layer ops for routing stats can be
//! registered and served by name.

pub mod compiled;
pub mod deepspeech;
pub mod graph;
pub mod store;
pub mod zoo;

pub use compiled::CompiledModel;
pub use deepspeech::{DeepSpeech, DeepSpeechConfig, Layer, LayerKind};
pub use graph::{BatchRole, ModelGraph, Node, NodeVariant, Op};
pub use store::{
    ColdLoad, DispatchGuard, ModelBuilder, ModelStore, StoreEntryStats, StoreError, StoreStats,
};
pub use zoo::{
    deepspeech_graph, keyword_spotter_graph, mlp_graph, synthetic_roster, ModelRegistry,
    ModelSize, ZooEntry,
};

use crate::coordinator::request::{LayerTiming, OpDesc};
use crate::pack::BitWidth;

/// A servable model: the engine's only view of the things it registers.
/// Implemented by [`CompiledModel`] (any [`ModelGraph`]) and by the
/// legacy [`DeepSpeech`] struct.
pub trait Model: Send + Sync {
    /// f32 values per request (`time_steps × input_dim`); the engine
    /// shape-validates incoming frames against this.
    fn input_len(&self) -> usize;

    /// f32 values per reply.
    fn output_len(&self) -> usize;

    /// Forward one request: `(outputs, per-layer elapsed ns)`.
    fn forward_timed(&self, frames: &[f32]) -> (Vec<f32>, Vec<LayerTiming>);

    /// Forward a flushed group of requests as one batched dispatch
    /// (bit-identical to per-request forwards); one result per request.
    fn forward_batch(&self, frames: &[&[f32]]) -> Vec<(Vec<f32>, Vec<LayerTiming>)>;

    /// The linear-algebra ops one dispatch of `group` requests issues —
    /// the router classifies these for the per-path stats (batched FC
    /// nodes widen to `group · time_steps` columns; scan cells repeat
    /// per request).
    fn route_ops(&self, group: usize) -> Vec<OpDesc>;

    /// Modeled service time (ns) of one batched dispatch of `group`
    /// requests — the admission scheduler's marginal-latency brain
    /// (DESIGN.md §12).  `None` means the model carries no cost model;
    /// the engine falls back to a [`Model::route_ops`]-derived
    /// estimate.  Graph-backed models return
    /// `costmodel::serving_dispatch_ns`, the same curve the virtual
    /// workload DES replays, which is what keeps live and virtual
    /// admission decisions bit-identical.
    fn dispatch_cost_ns(&self, group: usize) -> Option<u64> {
        let _ = group;
        None
    }

    /// Bytes this model costs to keep resident, packed-width-aware —
    /// the [`ModelStore`] budget currency (DESIGN.md §14).  The default
    /// `0` means "free": models with no sizing never trigger eviction
    /// and are effectively always-resident.
    fn resident_bytes(&self) -> usize {
        0
    }

    /// One-line description for logs and the CLI.
    fn describe(&self) -> String;
}

impl Model for CompiledModel {
    fn input_len(&self) -> usize {
        self.graph().input_len()
    }

    fn output_len(&self) -> usize {
        self.graph().output_len()
    }

    fn forward_timed(&self, frames: &[f32]) -> (Vec<f32>, Vec<LayerTiming>) {
        CompiledModel::forward_timed(self, frames)
    }

    fn forward_batch(&self, frames: &[&[f32]]) -> Vec<(Vec<f32>, Vec<LayerTiming>)> {
        CompiledModel::forward_batch(self, frames)
    }

    fn route_ops(&self, group: usize) -> Vec<OpDesc> {
        self.route_op_descs(group)
    }

    fn dispatch_cost_ns(&self, group: usize) -> Option<u64> {
        Some(crate::costmodel::serving_dispatch_ns(self.graph(), group))
    }

    fn resident_bytes(&self) -> usize {
        CompiledModel::resident_bytes(self)
    }

    fn describe(&self) -> String {
        self.graph().describe()
    }
}

/// Deterministic synthetic weight values in a bit-width's signed range
/// (the DESIGN.md substitution table: end-to-end timing depends on
/// shapes, not weight values).  Shared by the legacy [`DeepSpeech`]
/// constructor and [`CompiledModel`] so the two generate identical
/// matrices from identical seeds.
pub(crate) fn xorshift_vals(bits: BitWidth, n: usize, seed: u64) -> Vec<i8> {
    let (lo, hi) = bits.value_range();
    crate::util::rng::xorshift_range_vals(lo, hi, n, seed)
}

/// One FullyConnected layer shape: `z` outputs from `k` inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FcShape {
    pub name: &'static str,
    pub k: usize,
    pub z: usize,
}

/// Final-classifier FC layers of the eleven CNNs in the paper's §4.7
/// Raspberry Pi study (feature dim → 1000 ImageNet classes; VGG19 also
/// carries its two 4096-wide FC layers, we use the classifier head as
/// the paper's figure does).
pub const CNN_FC_ZOO: [FcShape; 11] = [
    FcShape { name: "DenseNet201", k: 1920, z: 1000 },
    FcShape { name: "EfficientNetV2L", k: 1280, z: 1000 },
    FcShape { name: "InceptionV3", k: 2048, z: 1000 },
    FcShape { name: "InceptionResNetV2", k: 1536, z: 1000 },
    FcShape { name: "MobileNetV2", k: 1280, z: 1000 },
    FcShape { name: "NASNetLarge", k: 4032, z: 1000 },
    FcShape { name: "RegNetY320", k: 3712, z: 1000 },
    FcShape { name: "ResNet152", k: 2048, z: 1000 },
    FcShape { name: "ResNet152V2", k: 2048, z: 1000 },
    FcShape { name: "VGG19", k: 4096, z: 1000 },
    FcShape { name: "Xception", k: 2048, z: 1000 },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_has_eleven_networks() {
        assert_eq!(CNN_FC_ZOO.len(), 11);
        for fc in CNN_FC_ZOO {
            assert!(fc.k >= 1000 && fc.z == 1000, "{}", fc.name);
        }
    }

    #[test]
    fn mlp_route_ops_stay_on_the_compiled_fullpack_path() {
        use crate::pack::Variant;
        let v = Variant::parse("w4a8").unwrap();
        let m = CompiledModel::compile(mlp_graph(ModelSize::Tiny, v, 7)).unwrap();
        // a multi-request flush still executes the compiled batch-1
        // FullPack GEMV plans (GemvKernel::gemm fallback) — the
        // classification must not widen onto the W8A8 GEMM rival the
        // plans never run
        let ops = Model::route_ops(&m, 3);
        assert_eq!(ops.len(), 3); // the three FC nodes; relus weightless
        for op in ops {
            assert_eq!(op.batch, 1);
            assert_eq!(op.variant, v);
        }
    }

    #[test]
    fn compiled_route_ops_match_legacy_classification() {
        use crate::pack::Variant;
        let cfg = DeepSpeechConfig::TINY;
        let v = Variant::parse("w4a8").unwrap();
        let legacy = DeepSpeech::new(cfg, v, 7);
        let compiled =
            CompiledModel::compile(deepspeech_graph(cfg, v, 7)).unwrap();
        for group in [1usize, 3] {
            assert_eq!(
                Model::route_ops(&legacy, group),
                Model::route_ops(&compiled, group),
                "group {group}"
            );
        }
        // 5 FC descriptors + group LSTM descriptors
        assert_eq!(Model::route_ops(&compiled, 3).len(), 5 + 3);
    }
}

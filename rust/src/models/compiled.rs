//! `CompiledModel` — lower a [`ModelGraph`] onto the per-layer
//! `kernels::Plan` machinery and execute it (DESIGN.md §10).
//!
//! Compilation quantizes/packs every weighted node into its selected
//! backend's layout (one `Plan` per layer via the existing `PlanBuilder`
//! policies: batched FC nodes land on the GEMM tier, scan cells on the
//! FullPack GEMV tier — exactly the paper's §4.6 split), and
//! preallocates the execution scratch so steady-state forwards do not
//! allocate per call (`ScratchPool`).
//!
//! The executor is the generalization of the legacy `DeepSpeech`
//! forward: over the DeepSpeech graph it is **bit-identical** to
//! `DeepSpeech::forward`/`forward_batch` (pinned by
//! `rust/tests/model_graph.rs`) — same quantization points, same
//! requantization order, same gate math.

#![warn(missing_docs)]

use super::graph::{ModelGraph, Node, Op};
use super::xorshift_vals;
use crate::coordinator::request::OpDesc;
use crate::kernels::{
    KernelError, LayerShape, Plan, PlanBuilder, PlanScratch, SelectPolicy, Weights,
};
use crate::pack::serialize::WeightsImage;
use crate::pack::{BitWidth, Variant};
use crate::quant::requantize;
use std::sync::Mutex;
use std::time::Instant;

/// One compiled, executable layer.
enum CompiledLayer {
    Fc {
        name: String,
        /// resolved data variant (what the weights were quantized as)
        variant: Variant,
        plan: Plan,
        weights: Weights,
        bias: Vec<f32>,
        relu: bool,
    },
    Cell {
        name: String,
        kind: CellKind,
        hidden: usize,
        /// gate rows (`4·hidden` LSTM, `3·hidden` GRU)
        gate_dim: usize,
        wx_plan: Plan,
        wh_plan: Plan,
        wx: Weights,
        wh: Weights,
        bias: Vec<f32>,
    },
    Relu {
        name: String,
        max: f32,
    },
}

impl CompiledLayer {
    fn name(&self) -> &str {
        match self {
            CompiledLayer::Fc { name, .. }
            | CompiledLayer::Cell { name, .. }
            | CompiledLayer::Relu { name, .. } => name,
        }
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum CellKind {
    Lstm,
    Gru,
}

/// Reusable per-forward buffers (quantized activations, accumulators,
/// cell state, plan pack scratch).  Pooled so concurrent forwards on
/// the same model each check one out instead of allocating — including
/// the scan-cell hot loop, which runs `n · time_steps` steps per
/// forward without touching the allocator in steady state.
#[derive(Default)]
struct ExecScratch {
    qact: Vec<i8>,
    acc: Vec<i32>,
    // scan-cell step buffers
    x_q: Vec<i8>,
    h_q: Vec<i8>,
    acc_x: Vec<i32>,
    acc_h: Vec<i32>,
    h_new: Vec<f32>,
    c: Vec<f32>,
    c_new: Vec<f32>,
    pack: PlanScratch,
}

/// Bounded pool of [`ExecScratch`] — steady-state forwards reuse
/// buffers; bursts beyond the pool allocate and the extras are dropped
/// on return.
struct ScratchPool {
    pool: Mutex<Vec<ExecScratch>>,
}

const SCRATCH_POOL_CAP: usize = 8;

impl ScratchPool {
    fn new() -> ScratchPool {
        ScratchPool { pool: Mutex::new(Vec::new()) }
    }

    fn take(&self) -> ExecScratch {
        self.pool.lock().unwrap().pop().unwrap_or_default()
    }

    fn put(&self, s: ExecScratch) {
        let mut p = self.pool.lock().unwrap();
        if p.len() < SCRATCH_POOL_CAP {
            p.push(s);
        }
    }
}

/// A [`ModelGraph`] lowered onto executable plans: packed weights, one
/// plan per layer, preallocated scratch.
pub struct CompiledModel {
    graph: ModelGraph,
    layers: Vec<CompiledLayer>,
    /// hidden-state quantization scale (`1 / a_max` of the graph
    /// variant — the legacy `DeepSpeech::s_h`)
    s_h: f32,
    /// intra-op row-parallelism for the scan-cell GEMVs (1 = serial;
    /// results are bit-identical either way)
    pub intra_op_threads: usize,
    scratch: ScratchPool,
}

impl CompiledModel {
    /// Compile a validated graph: quantize + pack weights per node and
    /// bind one plan per layer under the default (`PaperRule`) policy.
    pub fn compile(graph: ModelGraph) -> Result<CompiledModel, KernelError> {
        Self::compile_from(graph, None)
    }

    /// Compile a graph resolving every weight tensor from a loaded
    /// [`WeightsImage`] instead of regenerating and re-packing it — the
    /// model store's warm path: the layers *borrow* the shared image
    /// allocation (zero payload copies; see `pack::serialize`).  Tensor
    /// names are the node names, with scan cells contributing
    /// `"<name>.wx"`/`"<name>.wh"` (the [`CompiledModel::weight_entries`]
    /// convention).  Plan selection is deterministic from the graph, so
    /// an image saved from a compiled model always re-binds onto the
    /// same kernels; dimension/width mismatches are a typed error.
    pub fn compile_with_image(
        graph: ModelGraph,
        image: &WeightsImage,
    ) -> Result<CompiledModel, KernelError> {
        Self::compile_from(graph, Some(image))
    }

    fn compile_from(
        graph: ModelGraph,
        image: Option<&WeightsImage>,
    ) -> Result<CompiledModel, KernelError> {
        graph.validate()?;
        let mut layers = Vec::with_capacity(graph.nodes.len());
        for node in &graph.nodes {
            layers.push(Self::compile_node(&graph, node, None, image)?);
        }
        let (_, ahi) = graph.variant.a.value_range();
        Ok(CompiledModel {
            s_h: if ahi > 0 { 1.0 / ahi as f32 } else { 1.0 },
            graph,
            layers,
            intra_op_threads: 1,
            scratch: ScratchPool::new(),
        })
    }

    /// Pull tensor `entry` out of an image and require it to match the
    /// shape/width the plan was built for.
    fn image_weights(
        image: &WeightsImage,
        entry: &str,
        rows: usize,
        k: usize,
        wbits: BitWidth,
    ) -> Result<Weights, KernelError> {
        let w = image.get(entry).ok_or_else(|| {
            KernelError::Shape(format!(
                "weights image has no tensor {entry:?} (image has {:?})",
                image.names()
            ))
        })?;
        let m = w.as_packed().expect("images only carry packed kinds");
        if m.rows() != rows || m.k() != k || m.bits() != wbits {
            return Err(KernelError::Shape(format!(
                "image tensor {entry:?} is {}x{} w{}, the model wants {}x{} w{}",
                m.rows(),
                m.k(),
                m.bits().bits(),
                rows,
                k,
                wbits.bits()
            )));
        }
        Ok(w)
    }

    fn compile_node(
        graph: &ModelGraph,
        node: &Node,
        cell_kernel: Option<&str>,
        image: Option<&WeightsImage>,
    ) -> Result<CompiledLayer, KernelError> {
        let variant = node.variant.resolve(graph.variant);
        match node.op {
            Op::FullyConnected { relu, bias } => {
                // batched over the request's columns: PaperRule lands
                // sub-byte single-column stacks on FullPack GEMV and
                // multi-column / 8-bit stacks on the GEMM tier
                let plan = PlanBuilder::new(
                    LayerShape { z: node.z, k: node.k, batch: graph.time_steps },
                    variant,
                )
                .build()?;
                let weights = match image {
                    Some(img) => {
                        Self::image_weights(img, &node.name, node.z, node.k, variant.w)?
                    }
                    None => {
                        let w = xorshift_vals(
                            variant.w,
                            node.z * node.k,
                            graph.seed + node.seed_offset,
                        );
                        plan.prepare_weights(&w)?
                    }
                };
                Ok(CompiledLayer::Fc {
                    name: node.name.clone(),
                    variant,
                    plan,
                    weights,
                    bias: vec![bias; node.z],
                    relu,
                })
            }
            Op::LstmCell | Op::GruCell => {
                let kind = if node.op == Op::LstmCell { CellKind::Lstm } else { CellKind::Gru };
                let hidden = node.hidden().expect("cell node");
                let gate_dim = node.z;
                // kernel re-binding recompiles from the node, so the
                // seeds need not be retained past this call
                let wx_seed = graph.seed + node.seed_offset;
                let wh_seed = graph.seed + node.seed_offset + 1;
                let build = |k: usize| -> Result<Plan, KernelError> {
                    let b = PlanBuilder::new(
                        LayerShape { z: gate_dim, k, batch: 1 },
                        graph.variant,
                    );
                    match cell_kernel {
                        Some(name) => b.policy(SelectPolicy::Explicit(name.to_string())).build(),
                        None => b.build(),
                    }
                };
                let wx_plan = build(node.k)?;
                let wh_plan = build(hidden)?;
                let (wx, wh) = match image {
                    Some(img) => (
                        Self::image_weights(
                            img,
                            &format!("{}.wx", node.name),
                            gate_dim,
                            node.k,
                            graph.variant.w,
                        )?,
                        Self::image_weights(
                            img,
                            &format!("{}.wh", node.name),
                            gate_dim,
                            hidden,
                            graph.variant.w,
                        )?,
                    ),
                    None => (
                        wx_plan.prepare_weights(&xorshift_vals(
                            graph.variant.w,
                            gate_dim * node.k,
                            wx_seed,
                        ))?,
                        wh_plan.prepare_weights(&xorshift_vals(
                            graph.variant.w,
                            gate_dim * hidden,
                            wh_seed,
                        ))?,
                    ),
                };
                let mut bias = vec![0.0f32; gate_dim];
                if kind == CellKind::Lstm {
                    bias[hidden..2 * hidden].fill(1.0); // forget-gate bias 1
                }
                Ok(CompiledLayer::Cell {
                    name: node.name.clone(),
                    kind,
                    hidden,
                    gate_dim,
                    wx_plan,
                    wh_plan,
                    wx,
                    wh,
                    bias,
                })
            }
            Op::Relu { max } => Ok(CompiledLayer::Relu { name: node.name.clone(), max }),
        }
    }

    /// Re-bind every scan cell's GEMVs to an explicit registry kernel
    /// (CLI `--kernel`): rebuilds the plans and re-packs the gate
    /// weights into the new backend's layout.  A graph with no scan
    /// cells is an error — an explicit kernel choice must never be
    /// silently ignored.
    pub fn with_cell_kernel(mut self, name: &str) -> Result<CompiledModel, KernelError> {
        let mut rebound = 0;
        for (i, node) in self.graph.nodes.iter().enumerate() {
            if matches!(node.op, Op::LstmCell | Op::GruCell) {
                self.layers[i] = Self::compile_node(&self.graph, node, Some(name), None)?;
                rebound += 1;
            }
        }
        if rebound == 0 {
            return Err(KernelError::Shape(format!(
                "model {:?} has no scan cells to re-bind onto {name:?} \
                 (--kernel applies to LSTM/GRU gate plans)",
                self.graph.name
            )));
        }
        Ok(self)
    }

    /// The compiled graph.
    pub fn graph(&self) -> &ModelGraph {
        &self.graph
    }

    /// Registry name of the kernel serving the first scan cell's GEMVs
    /// (`None` for pure feed-forward graphs).
    pub fn cell_kernel_name(&self) -> Option<&'static str> {
        self.layers.iter().find_map(|l| match l {
            CompiledLayer::Cell { wx_plan, .. } => Some(wx_plan.kernel_name()),
            _ => None,
        })
    }

    /// `(layer name, backend registry name)` per weighted layer.
    pub fn plan_names(&self) -> Vec<(String, &'static str)> {
        self.layers
            .iter()
            .filter_map(|l| match l {
                CompiledLayer::Fc { name, plan, .. } => Some((name.clone(), plan.kernel_name())),
                CompiledLayer::Cell { name, wx_plan, .. } => {
                    Some((name.clone(), wx_plan.kernel_name()))
                }
                CompiledLayer::Relu { .. } => None,
            })
            .collect()
    }

    /// The linear-algebra ops one dispatch of `group` requests issues,
    /// described as what the **compiled plans** actually execute (the
    /// legacy invariant: routing stats can never advertise a backend
    /// the model's own plans did not run).  FC nodes whose plan carries
    /// a GEMM backend widen to the flushed `group · time_steps` column
    /// count; FC nodes compiled onto a GEMV plan (e.g. a sub-byte
    /// single-column stack — the MLP) stay at their compiled batch, so
    /// a multi-request flush is still classified onto the FullPack
    /// path its `GemvKernel::gemm` fallback really takes.  Scan cells
    /// repeat per request.
    pub(crate) fn route_op_descs(&self, group: usize) -> Vec<OpDesc> {
        let g = &self.graph;
        let mut ops = Vec::new();
        for (node, layer) in g.nodes.iter().zip(&self.layers) {
            match layer {
                CompiledLayer::Fc { variant, plan, .. } => {
                    let batch = if plan.is_batched() {
                        group * g.time_steps
                    } else {
                        g.time_steps
                    };
                    ops.push(OpDesc { batch, z: node.z, k: node.k, variant: *variant });
                }
                CompiledLayer::Cell { hidden, .. } => {
                    // the cell's two matrices (input + recurrent) fold
                    // into one per-request descriptor, legacy-style
                    let op = OpDesc {
                        batch: 1,
                        z: node.z,
                        k: node.k + hidden,
                        variant: g.variant,
                    };
                    ops.extend(std::iter::repeat(op).take(group));
                }
                CompiledLayer::Relu { .. } => {}
            }
        }
        ops
    }

    /// Total packed-weight bytes (the paper's capacity metric).
    pub fn weight_footprint(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                CompiledLayer::Fc { weights, .. } => weights.footprint(),
                CompiledLayer::Cell { wx, wh, .. } => wx.footprint() + wh.footprint(),
                CompiledLayer::Relu { .. } => 0,
            })
            .sum()
    }

    /// Bytes this model costs to keep resident — the model store's
    /// budget currency.  Packed-width-aware by construction: the packed
    /// weight footprint (so a w4 model charges half its w8 twin, the
    /// paper's capacity claim) plus the f32 bias vectors.
    pub fn resident_bytes(&self) -> usize {
        let bias: usize = self
            .layers
            .iter()
            .map(|l| match l {
                CompiledLayer::Fc { bias, .. } | CompiledLayer::Cell { bias, .. } => bias.len() * 4,
                CompiledLayer::Relu { .. } => 0,
            })
            .sum();
        self.weight_footprint() + bias
    }

    /// Every weight tensor by its image-entry name: FC nodes under the
    /// node name, scan cells as `"<name>.wx"`/`"<name>.wh"` — the
    /// naming contract shared with [`CompiledModel::compile_with_image`]
    /// and `pack::serialize::write_image`.
    pub fn weight_entries(&self) -> Vec<(String, &Weights)> {
        let mut out = Vec::new();
        for l in &self.layers {
            match l {
                CompiledLayer::Fc { name, weights, .. } => out.push((name.clone(), weights)),
                CompiledLayer::Cell { name, wx, wh, .. } => {
                    out.push((format!("{name}.wx"), wx));
                    out.push((format!("{name}.wh"), wh));
                }
                CompiledLayer::Relu { .. } => {}
            }
        }
        out
    }

    /// Quantize an f32 vector at `scale` into `bits`' signed range, into
    /// a reused buffer (the legacy `DeepSpeech::quant_act`, minus the
    /// per-call allocation).
    fn quant_into(x: &[f32], scale: f32, bits: crate::pack::BitWidth, out: &mut Vec<i8>) {
        let (lo, hi) = bits.value_range();
        out.clear();
        out.extend(x.iter().map(|&v| (v / scale).round().clamp(lo as f32, hi as f32) as i8));
    }

    /// Full forward over one request's frames (`time_steps × input_dim`
    /// row-major f32).  Returns `(outputs, per-layer elapsed ns)`.
    pub fn forward_timed(&self, frames: &[f32]) -> (Vec<f32>, Vec<(String, u128)>) {
        self.forward_batch(&[frames]).pop().expect("one request in, one result out")
    }

    /// Batched forward over `n` independent requests — the serving
    /// engine's multi-request dispatch: all requests' columns stack so
    /// every [`Op::FullyConnected`] node executes as **one** batched
    /// call over `n · time_steps` columns, while scan cells stay
    /// per-request single-column GEMV streams (a recurrence cannot
    /// batch across time).  Per-request results are bit-identical to
    /// `n` separate [`CompiledModel::forward_timed`] calls.
    pub fn forward_batch(&self, frames: &[&[f32]]) -> Vec<(Vec<f32>, Vec<(String, u128)>)> {
        let t = self.graph.time_steps;
        let n = frames.len();
        if n == 0 {
            return Vec::new();
        }
        let input_len = self.graph.input_len();
        for f in frames {
            assert_eq!(f.len(), input_len, "bad frame window");
        }
        let cols = n * t;
        let mut times: Vec<(String, u128)> = Vec::with_capacity(self.layers.len());
        let mut scratch = self.scratch.take();

        let mut cur: Vec<f32> = Vec::with_capacity(cols * self.graph.input_dim);
        for f in frames {
            cur.extend_from_slice(f);
        }
        let mut dim = self.graph.input_dim;
        for layer in &self.layers {
            let start = Instant::now();
            match layer {
                CompiledLayer::Fc { .. } => {
                    cur = self.fc_forward(layer, &cur, cols, dim, &mut scratch);
                }
                CompiledLayer::Cell { .. } => {
                    cur = self.scan_forward(layer, &cur, n, dim, &mut scratch);
                }
                CompiledLayer::Relu { max, .. } => {
                    for v in &mut cur {
                        *v = v.clamp(0.0, *max);
                    }
                }
            }
            dim = cur.len() / cols;
            times.push((layer.name().to_string(), start.elapsed().as_nanos()));
        }
        self.scratch.put(scratch);

        let per = t * dim;
        (0..n).map(|r| (cur[r * per..(r + 1) * per].to_vec(), times.clone())).collect()
    }

    /// One batched FC node over all columns — the legacy
    /// `DeepSpeech::fc_forward` generalized to the node's variant.
    fn fc_forward(
        &self,
        layer: &CompiledLayer,
        x: &[f32],
        cols: usize,
        k: usize,
        scratch: &mut ExecScratch,
    ) -> Vec<f32> {
        let CompiledLayer::Fc { variant, plan, weights, bias, relu, .. } = layer else {
            unreachable!("fc_forward on a non-FC layer");
        };
        let z = weights.rows();
        debug_assert_eq!(weights.k(), k);
        let (lo, hi) = variant.a.value_range();
        let (lo, hi) = (lo as f32, hi as f32);
        scratch.qact.clear();
        scratch
            .qact
            .extend(x.iter().map(|&v| (v / self.graph.s_act).round().clamp(lo, hi) as i8));
        scratch.acc.clear();
        scratch.acc.resize(cols * z, 0);
        plan.execute_batch(weights, &scratch.qact, cols, &mut scratch.acc).expect("fc gemm");
        let mut out = vec![0.0f32; cols * z];
        for (ocol, acol) in out.chunks_exact_mut(z).zip(scratch.acc.chunks_exact(z)) {
            for ((y, &a), &bi) in ocol.iter_mut().zip(acol).zip(bias) {
                *y = requantize(a, self.graph.s_w, self.graph.s_act, bi);
            }
        }
        if *relu {
            for v in &mut out {
                *v = v.clamp(0.0, 20.0);
            }
        }
        out
    }

    /// One scan cell over every request's column stream — the legacy
    /// LSTM scan generalized (LSTM and GRU gate math).  All step-local
    /// state lives in the pooled scratch; the only allocation is the
    /// output stream.
    fn scan_forward(
        &self,
        layer: &CompiledLayer,
        cur: &[f32],
        n: usize,
        dim: usize,
        scratch: &mut ExecScratch,
    ) -> Vec<f32> {
        let CompiledLayer::Cell { hidden, .. } = layer else {
            unreachable!("scan_forward on a non-cell layer");
        };
        let t = self.graph.time_steps;
        let hidden = *hidden;
        let a_bits = self.graph.variant.a;
        let mut hs = vec![0.0f32; n * t * hidden];
        for r in 0..n {
            scratch.h_q.clear();
            scratch.h_q.resize(hidden, 0);
            scratch.c.clear();
            scratch.c.resize(hidden, 0.0);
            for step in 0..t {
                let col = r * t + step;
                let x = &cur[col * dim..(col + 1) * dim];
                Self::quant_into(x, self.graph.s_act, a_bits, &mut scratch.x_q);
                self.cell_step_in(layer, scratch);
                hs[col * hidden..(col + 1) * hidden].copy_from_slice(&scratch.h_new);
                Self::quant_into(&scratch.h_new, self.s_h, a_bits, &mut scratch.h_q);
                std::mem::swap(&mut scratch.c, &mut scratch.c_new);
            }
        }
        hs
    }

    /// One cell step over the plan-selected kernels: two gate GEMVs
    /// (`wx·scratch.x_q`, `wh·scratch.h_q`) then the cell's gate math,
    /// writing `scratch.h_new`/`scratch.c_new` from `scratch.c`.
    /// Bit-for-bit the legacy `DeepSpeech::lstm_step` for
    /// [`CellKind::Lstm`] (same per-element requantize/gate
    /// expressions, no reassociation).
    fn cell_step_in(&self, layer: &CompiledLayer, scratch: &mut ExecScratch) {
        let CompiledLayer::Cell { kind, hidden, gate_dim, wx_plan, wh_plan, wx, wh, bias, .. } =
            layer
        else {
            unreachable!("cell_step_in on a non-cell layer");
        };
        let (hidden, gd) = (*hidden, *gate_dim);
        let threads = self.intra_op_threads.max(1);
        scratch.acc_x.resize(gd, 0);
        scratch.acc_h.resize(gd, 0);
        wx_plan
            .execute_in(wx, &scratch.x_q, &mut scratch.acc_x, threads, &mut scratch.pack)
            .expect("cell gemv");
        wh_plan
            .execute_in(wh, &scratch.h_q, &mut scratch.acc_h, threads, &mut scratch.pack)
            .expect("cell gemv");

        scratch.h_new.clear();
        scratch.h_new.resize(hidden, 0.0);
        scratch.c_new.clear();
        scratch.c_new.resize(hidden, 0.0);
        // per-lane views of the two accumulators, same expressions the
        // legacy requantize_vec/g_h pair computed (edition-2021 closures
        // capture the individual fields, so the writes below coexist)
        let g_x =
            |lane: usize| requantize(scratch.acc_x[lane], self.graph.s_w, self.graph.s_act, bias[lane]);
        let g_h = |lane: usize| scratch.acc_h[lane] as f32 * (self.graph.s_w * self.s_h);
        let sig = |v: f32| 1.0 / (1.0 + (-v).exp());
        match kind {
            CellKind::Lstm => {
                for j in 0..hidden {
                    let i = sig(g_x(j) + g_h(j));
                    let f = sig(g_x(hidden + j) + g_h(hidden + j));
                    let g = (g_x(2 * hidden + j) + g_h(2 * hidden + j)).tanh();
                    let o = sig(g_x(3 * hidden + j) + g_h(3 * hidden + j));
                    scratch.c_new[j] = f * scratch.c[j] + i * g;
                    scratch.h_new[j] = o * scratch.c_new[j].tanh();
                }
            }
            CellKind::Gru => {
                // gates [reset, update, candidate]; `scratch.c` carries
                // the f32 previous hidden state
                for j in 0..hidden {
                    let rg = sig(g_x(j) + g_h(j));
                    let zg = sig(g_x(hidden + j) + g_h(hidden + j));
                    let ng = (g_x(2 * hidden + j) + rg * g_h(2 * hidden + j)).tanh();
                    scratch.h_new[j] = (1.0 - zg) * ng + zg * scratch.c[j];
                    scratch.c_new[j] = scratch.h_new[j];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::models::DeepSpeechConfig;

    fn v(s: &str) -> Variant {
        Variant::parse(s).unwrap()
    }

    fn tiny_frames(g: &ModelGraph) -> Vec<f32> {
        (0..g.input_len()).map(|i| (i as f32 * 0.013).sin()).collect()
    }

    #[test]
    fn compile_rejects_invalid_graphs() {
        let g = ModelGraph::new("empty", v("w4a8"), 8, 1, 7);
        assert!(CompiledModel::compile(g).is_err());
    }

    #[test]
    fn deepspeech_graph_compiles_with_paper_plans() {
        let g = zoo::deepspeech_graph(DeepSpeechConfig::TINY, v("w4a8"), 7);
        let m = CompiledModel::compile(g).unwrap();
        assert_eq!(m.cell_kernel_name(), Some("fullpack-w4a8"));
        let names = m.plan_names();
        assert_eq!(names.len(), 6);
        // FC stack on the Ruy-like GEMM tier (paper §4.6 protocol)
        assert_eq!(names[0].1, "ruy-like-w8a8-gemm");
        assert_eq!(names[3].1, "fullpack-w4a8");
        assert!(m.weight_footprint() > 0);
    }

    #[test]
    fn forward_shapes_and_determinism() {
        for name in ["mlp", "keyword-spotter"] {
            let g = zoo::ModelRegistry::global()
                .build(name, zoo::ModelSize::Tiny, v("w4a8"), 7)
                .unwrap();
            let frames = tiny_frames(&g);
            let out_len = g.output_len();
            let layers = g.nodes.len();
            let m = CompiledModel::compile(g.clone()).unwrap();
            let (a, times) = m.forward_timed(&frames);
            assert_eq!(a.len(), out_len, "{name}");
            assert!(a.iter().all(|x| x.is_finite()), "{name}");
            assert_eq!(times.len(), layers, "{name}");
            let m2 = CompiledModel::compile(g).unwrap();
            assert_eq!(m2.forward_timed(&frames).0, a, "{name} determinism");
        }
    }

    #[test]
    fn forward_batch_is_bit_identical_to_per_request() {
        let g = zoo::ModelRegistry::global()
            .build("keyword-spotter", zoo::ModelSize::Tiny, v("w2a8"), 9)
            .unwrap();
        let m = CompiledModel::compile(g.clone()).unwrap();
        let reqs: Vec<Vec<f32>> = (0..3)
            .map(|r| {
                (0..g.input_len()).map(|i| ((i + r * 37) as f32 * 0.011).sin()).collect()
            })
            .collect();
        let refs: Vec<&[f32]> = reqs.iter().map(|f| f.as_slice()).collect();
        let batched = m.forward_batch(&refs);
        assert_eq!(batched.len(), 3);
        for (r, f) in reqs.iter().enumerate() {
            assert_eq!(batched[r].0, m.forward_timed(f).0, "request {r}");
        }
        assert!(m.forward_batch(&[]).is_empty());
    }

    #[test]
    fn explicit_cell_kernel_is_bit_identical() {
        let g = zoo::deepspeech_graph(DeepSpeechConfig::TINY, v("w4a8"), 7);
        let frames = tiny_frames(&g);
        let base = CompiledModel::compile(g.clone()).unwrap().forward_timed(&frames).0;
        let naive = CompiledModel::compile(g.clone())
            .unwrap()
            .with_cell_kernel("naive-w4a8")
            .unwrap();
        assert_eq!(naive.cell_kernel_name(), Some("naive-w4a8"));
        assert_eq!(naive.forward_timed(&frames).0, base);
        // a kernel that cannot run the variant is a re-bind error
        assert!(CompiledModel::compile(g)
            .unwrap()
            .with_cell_kernel("ulppack-w2a2")
            .is_err());
        // a graph with no scan cells must refuse the knob rather than
        // silently ignore an explicit kernel choice
        let mlp = zoo::ModelRegistry::global()
            .build("mlp", zoo::ModelSize::Tiny, v("w4a8"), 7)
            .unwrap();
        assert!(CompiledModel::compile(mlp)
            .unwrap()
            .with_cell_kernel("fullpack-w4a8-swar")
            .is_err());
    }

    #[test]
    fn image_compiled_model_is_bit_identical_and_zero_copy() {
        use crate::pack::serialize::{write_image, WeightsImage};
        // export a compiled model's tensors to one image, re-compile
        // from the image, and require bit-identical forwards with every
        // weight tensor aliasing the image allocation
        let g = zoo::deepspeech_graph(DeepSpeechConfig::TINY, v("w4a8"), 7);
        let frames = tiny_frames(&g);
        let base = CompiledModel::compile(g.clone()).unwrap();
        let entries = base.weight_entries();
        assert!(entries.len() >= 6, "deepspeech has FC + cell tensors");
        let named: Vec<(&str, &Weights)> =
            entries.iter().map(|(n, w)| (n.as_str(), *w)).collect();
        let mut buf = Vec::new();
        write_image(&named, &mut buf).unwrap();
        let img = WeightsImage::from_bytes(buf).unwrap();
        let from_img = CompiledModel::compile_with_image(g.clone(), &img).unwrap();
        assert_eq!(from_img.forward_timed(&frames).0, base.forward_timed(&frames).0);
        assert_eq!(from_img.resident_bytes(), base.resident_bytes());
        // zero-copy: every tensor of the image-compiled model borrows
        // the one image buffer
        for (name, w) in from_img.weight_entries() {
            let m = w.as_packed().expect("packed kinds only");
            assert!(m.shared().is_view_of(img.owner()), "{name} must alias the image");
        }
        // ...while the freshly compiled model owns its bytes
        for (_, w) in base.weight_entries() {
            assert!(!w.as_packed().unwrap().shared().is_view_of(img.owner()));
        }
        // a mismatched graph is a typed error, not silent garbage: same
        // shapes, different weight width (the cell tensors are w2, the
        // image holds w4)
        let other = zoo::deepspeech_graph(DeepSpeechConfig::TINY, v("w2a8"), 7);
        assert!(CompiledModel::compile_with_image(other, &img).is_err());
    }

    #[test]
    fn resident_bytes_scale_with_packed_width() {
        // the capacity claim the store banks on: a w4 zoo model buys
        // roughly twice the residency of its w8 twin
        let g4 = zoo::deepspeech_graph(DeepSpeechConfig::TINY, v("w4a8"), 7);
        let g8 = zoo::deepspeech_graph(DeepSpeechConfig::TINY, v("w8a8"), 7);
        let m4 = CompiledModel::compile(g4).unwrap().resident_bytes();
        let m8 = CompiledModel::compile(g8).unwrap().resident_bytes();
        assert!(m4 > 0 && m8 > m4, "w8 {m8} must outweigh w4 {m4}");
    }

    #[test]
    fn gru_state_carries_across_steps() {
        // feeding the same frame at every step must still move the
        // hidden state (the recurrence is live): step outputs differ
        let g = zoo::ModelRegistry::global()
            .build("keyword-spotter", zoo::ModelSize::Tiny, v("w4a8"), 3)
            .unwrap();
        let t = g.time_steps;
        let per = g.output_dim();
        let one: Vec<f32> = (0..g.input_dim).map(|i| (i as f32 * 0.05).sin()).collect();
        let frames: Vec<f32> = one.iter().copied().cycle().take(t * g.input_dim).collect();
        let m = CompiledModel::compile(g).unwrap();
        let (out, _) = m.forward_timed(&frames);
        assert_ne!(out[..per], out[(t - 1) * per..], "recurrence had no effect");
    }
}

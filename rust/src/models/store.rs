//! Multi-tenant model store (DESIGN.md §14): the engine's single
//! source of truth for which models exist, which are *resident*
//! (weights materialized in memory), and which version of each is
//! live.  Three jobs:
//!
//! 1. **Residency budget** — every resident model charges its
//!    packed-width-aware [`Model::resident_bytes`] against a modeled
//!    byte budget.  When the budget overflows, the least-recently-used
//!    unpinned idle model is evicted back to its builder (a closure
//!    that can re-materialize it, typically from an FPCK
//!    [`WeightsImage`](crate::pack::serialize::WeightsImage) on disk).
//! 2. **Cold admission** — admitting a non-resident model loads it
//!    *and* sheds the triggering request with a typed
//!    [`ColdLoad`] whose `retry_after_us` is priced by
//!    [`costmodel::cold_retry_us`](crate::costmodel::cold_retry_us)
//!    (bytes over modeled load bandwidth).  The retry hits a warm
//!    entry.  Because pricing is pure in the byte count, the virtual
//!    workload DES replays cold sheds bit-exactly.
//! 3. **Atomic hot-swap** — [`ModelStore::swap`] flips the registry
//!    entry to new weights under a per-model version counter while
//!    in-flight dispatches keep the old `Arc` alive until their
//!    [`DispatchGuard`]s drop: v1 batches finish on v1 weights, v2
//!    admissions see v2, and nothing ever observes a torn model.
//!
//! All bookkeeping lives behind one mutex; model forwards never hold
//! it — dispatch clones the `Arc` out under the guard and computes
//! outside.  Determinism rules (the DES mirrors these): LRU victim is
//! the minimum `(last_used, name)` over evictable entries, and
//! [`ModelStore::resident`] is a pure peek that never touches LRU
//! order (the scheduler's cost closure may probe it freely).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::coordinator::metrics::Metrics;
use crate::costmodel::cold_retry_us;
use crate::models::Model;

/// Builder closure that re-materializes an evicted model's weights.
pub type ModelBuilder = Box<dyn Fn() -> Result<Arc<dyn Model>, String> + Send + Sync>;

/// Typed cold-admission shed: the store started bringing the model
/// into residency and prices the retry at the modeled weight-load
/// time — clients get a budget hint, not a bare "try later".
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ColdLoad {
    /// the model that was cold
    pub name: String,
    /// resident bytes the load brings in
    pub bytes: usize,
    /// modeled microseconds until a retry hits the warm entry (≥ 1)
    pub retry_after_us: u64,
}

impl std::fmt::Display for ColdLoad {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "model {:?} cold: loading {} bytes, retry after ~{}us",
            self.name, self.bytes, self.retry_after_us
        )
    }
}

/// Why a store operation failed.
#[derive(Debug)]
pub enum StoreError {
    /// `register` on a name that already exists — re-registration must
    /// be an explicit versioned [`ModelStore::swap`], never a silent
    /// replacement
    AlreadyRegistered(String),
    /// no entry under this name
    Unknown(String),
    /// the model was registered but not resident; the load has been
    /// started and the request should be shed with this retry hint
    Cold(ColdLoad),
    /// the entry's builder failed to re-materialize the model
    Build {
        /// entry whose builder failed
        name: String,
        /// builder's error message
        reason: String,
    },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::AlreadyRegistered(n) => {
                write!(f, "model {n:?} already registered (use swap to replace)")
            }
            StoreError::Unknown(n) => write!(f, "no model registered under {n:?}"),
            StoreError::Cold(c) => write!(f, "{c}"),
            StoreError::Build { name, reason } => {
                write!(f, "building model {name:?} failed: {reason}")
            }
        }
    }
}

impl std::error::Error for StoreError {}

/// One registry entry.
struct Entry {
    /// the live model, when resident
    resident: Option<Arc<dyn Model>>,
    /// re-materializer; `None` for bare registered instances, which
    /// therefore can never be evicted (nothing could bring them back)
    builder: Option<ModelBuilder>,
    /// pinned entries are never evicted and are loaded eagerly
    pinned: bool,
    /// weights version: 1 at registration, +1 per swap
    version: u64,
    /// times this entry's weights were brought into residency
    loads: u64,
    /// times this entry was evicted under the budget
    evictions: u64,
    /// logical LRU clock value of the last admission/fetch
    last_used: u64,
    /// dispatches currently holding this entry's model
    in_flight: usize,
    /// resident byte charge (actual when resident, hint when cold)
    bytes: usize,
}

struct Inner {
    entries: HashMap<String, Entry>,
    /// logical LRU clock; bumped on every touch
    tick: u64,
    /// modeled residency budget; `None` = unbounded
    budget: Option<usize>,
    /// sum of `bytes` over resident entries
    resident_bytes: usize,
    total_loads: u64,
    total_evictions: u64,
    metrics: Option<Arc<Metrics>>,
}

/// Store-wide counters ([`ModelStore::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoreStats {
    /// registered entries
    pub models: usize,
    /// entries currently resident
    pub resident_models: usize,
    /// bytes charged by resident entries
    pub resident_bytes: usize,
    /// the modeled budget (`None` = unbounded)
    pub budget_bytes: Option<usize>,
    /// weight loads performed
    pub loads: u64,
    /// evictions performed
    pub evictions: u64,
}

/// Per-entry counters ([`ModelStore::entry_stats`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreEntryStats {
    /// entry name
    pub name: String,
    /// currently resident
    pub resident: bool,
    /// pinned (never evicted)
    pub pinned: bool,
    /// weights version (1 = as registered)
    pub version: u64,
    /// times loaded into residency
    pub loads: u64,
    /// times evicted
    pub evictions: u64,
    /// resident byte charge
    pub bytes: usize,
    /// dispatches currently holding the model
    pub in_flight: usize,
}

/// The multi-tenant model store.  See the module docs for the
/// residency/admission/hot-swap contract.
pub struct ModelStore {
    inner: Mutex<Inner>,
}

impl std::fmt::Debug for ModelStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("ModelStore")
            .field("models", &s.models)
            .field("resident_models", &s.resident_models)
            .field("resident_bytes", &s.resident_bytes)
            .field("budget_bytes", &s.budget_bytes)
            .finish()
    }
}

impl ModelStore {
    /// Empty store with a modeled residency budget (`None` =
    /// unbounded: nothing is ever evicted).
    pub fn new(budget_bytes: Option<usize>) -> Self {
        ModelStore {
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                tick: 0,
                budget: budget_bytes,
                resident_bytes: 0,
                total_loads: 0,
                total_evictions: 0,
                metrics: None,
            }),
        }
    }

    /// Mirror load/eviction/swap/version events into the engine's
    /// [`Metrics`] so reports reconcile store activity.
    pub fn attach_metrics(&self, metrics: Arc<Metrics>) {
        self.inner.lock().unwrap().metrics = Some(metrics);
    }

    /// Register a bare model instance.  It is resident immediately and
    /// — having no builder to re-materialize it — never evicted.
    /// Fails with [`StoreError::AlreadyRegistered`] on a duplicate
    /// name: replacing a live model must be an explicit versioned
    /// [`ModelStore::swap`].
    pub fn register(&self, name: &str, model: Arc<dyn Model>) -> Result<(), StoreError> {
        let mut g = self.inner.lock().unwrap();
        if g.entries.contains_key(name) {
            return Err(StoreError::AlreadyRegistered(name.to_string()));
        }
        let bytes = model.resident_bytes();
        g.tick += 1;
        let tick = g.tick;
        g.entries.insert(
            name.to_string(),
            Entry {
                resident: Some(model),
                builder: None,
                pinned: false,
                version: 1,
                loads: 1,
                evictions: 0,
                last_used: tick,
                in_flight: 0,
                bytes,
            },
        );
        g.resident_bytes += bytes;
        g.total_loads += 1;
        if let Some(m) = &g.metrics {
            m.record_model_load(name);
            m.set_model_version(name, 1);
        }
        Self::evict_to_fit(&mut g, Some(name));
        Ok(())
    }

    /// Register a lazily-built model: cold until first admission,
    /// evictable back to `builder` thereafter.  `bytes_hint` is the
    /// charge used while cold (replaced by the model's actual
    /// [`Model::resident_bytes`] on load).
    pub fn register_lazy(
        &self,
        name: &str,
        bytes_hint: usize,
        builder: ModelBuilder,
    ) -> Result<(), StoreError> {
        let mut g = self.inner.lock().unwrap();
        if g.entries.contains_key(name) {
            return Err(StoreError::AlreadyRegistered(name.to_string()));
        }
        g.entries.insert(
            name.to_string(),
            Entry {
                resident: None,
                builder: Some(builder),
                pinned: false,
                version: 1,
                loads: 0,
                evictions: 0,
                last_used: 0,
                in_flight: 0,
                bytes: bytes_hint,
            },
        );
        if let Some(m) = &g.metrics {
            m.set_model_version(name, 1);
        }
        Ok(())
    }

    /// Pin an entry: loaded eagerly (if cold) and never evicted.
    pub fn pin(&self, name: &str) -> Result<(), StoreError> {
        let mut g = self.inner.lock().unwrap();
        if !g.entries.contains_key(name) {
            return Err(StoreError::Unknown(name.to_string()));
        }
        if g.entries.get(name).unwrap().resident.is_none() {
            Self::make_resident(&mut g, name)?;
        }
        g.entries.get_mut(name).unwrap().pinned = true;
        Ok(())
    }

    /// Admit a request for `name`.  Warm → LRU touch and the model.
    /// Cold → the load happens *now* (synchronously, so the very next
    /// admission is warm), but the triggering request is shed with a
    /// typed [`ColdLoad`] pricing the retry at the modeled load time.
    pub fn admit(&self, name: &str) -> Result<Arc<dyn Model>, StoreError> {
        let mut g = self.inner.lock().unwrap();
        let warm = match g.entries.get(name) {
            None => return Err(StoreError::Unknown(name.to_string())),
            Some(e) => e.resident.is_some(),
        };
        if warm {
            g.tick += 1;
            let tick = g.tick;
            let e = g.entries.get_mut(name).unwrap();
            e.last_used = tick;
            Ok(e.resident.as_ref().unwrap().clone())
        } else {
            Self::make_resident(&mut g, name)?;
            let bytes = g.entries.get(name).unwrap().bytes;
            Err(StoreError::Cold(ColdLoad {
                name: name.to_string(),
                bytes,
                retry_after_us: cold_retry_us(bytes),
            }))
        }
    }

    /// Warm-or-load without the cold shed: the model, loading it first
    /// if needed.  The synchronous path for `infer` and the CLI, where
    /// there is no admission queue to protect.
    pub fn fetch(&self, name: &str) -> Result<Arc<dyn Model>, StoreError> {
        let mut g = self.inner.lock().unwrap();
        if !g.entries.contains_key(name) {
            return Err(StoreError::Unknown(name.to_string()));
        }
        if g.entries.get(name).unwrap().resident.is_none() {
            Self::make_resident(&mut g, name)?;
        }
        g.tick += 1;
        let tick = g.tick;
        let e = g.entries.get_mut(name).unwrap();
        e.last_used = tick;
        Ok(e.resident.as_ref().unwrap().clone())
    }

    /// Pure model peek: the resident model if any, with no LRU touch
    /// and no load.  The scheduler's cost closure probes this;
    /// keeping it side-effect-free is what lets the virtual DES
    /// replay admissions bit-exactly.
    pub fn peek(&self, name: &str) -> Option<Arc<dyn Model>> {
        self.inner.lock().unwrap().entries.get(name).and_then(|e| e.resident.clone())
    }

    /// Pure residency peek: no LRU touch, no load.
    pub fn resident(&self, name: &str) -> bool {
        self.inner
            .lock()
            .unwrap()
            .entries
            .get(name)
            .is_some_and(|e| e.resident.is_some())
    }

    /// Take a dispatch hold on `name`: the returned guard keeps the
    /// entry's *current* model alive and un-evictable until dropped.
    /// If the entry was evicted between admission and dispatch the
    /// weights are transparently reloaded (no shed — the request was
    /// already admitted).
    pub fn begin_dispatch(self: &Arc<Self>, name: &str) -> Result<DispatchGuard, StoreError> {
        let mut g = self.inner.lock().unwrap();
        if !g.entries.contains_key(name) {
            return Err(StoreError::Unknown(name.to_string()));
        }
        if g.entries.get(name).unwrap().resident.is_none() {
            Self::make_resident(&mut g, name)?;
        }
        let e = g.entries.get_mut(name).unwrap();
        e.in_flight += 1;
        let model = e.resident.as_ref().unwrap().clone();
        drop(g);
        Ok(DispatchGuard { store: Arc::clone(self), name: name.to_string(), model })
    }

    fn end_dispatch(&self, name: &str) {
        let mut g = self.inner.lock().unwrap();
        if let Some(e) = g.entries.get_mut(name) {
            e.in_flight = e.in_flight.saturating_sub(1);
        }
        // a hold ending may free the victim the budget was waiting on
        Self::evict_to_fit(&mut g, None);
    }

    /// Atomically hot-swap `name` to new weights: the version counter
    /// bumps, new admissions see the new model, and in-flight
    /// dispatches finish on the old `Arc` their guards hold — the
    /// drain protocol is the guard lifetime itself.  `builder`, when
    /// given, replaces the re-materializer so future cold loads build
    /// the *new* version.  Returns the new version.
    pub fn swap(
        &self,
        name: &str,
        model: Arc<dyn Model>,
        builder: Option<ModelBuilder>,
    ) -> Result<u64, StoreError> {
        let mut g = self.inner.lock().unwrap();
        if !g.entries.contains_key(name) {
            return Err(StoreError::Unknown(name.to_string()));
        }
        let bytes = model.resident_bytes();
        g.tick += 1;
        let tick = g.tick;
        let e = g.entries.get_mut(name).unwrap();
        let was_resident = e.resident.is_some();
        let old_bytes = e.bytes;
        e.resident = Some(model);
        e.bytes = bytes;
        e.version += 1;
        e.loads += 1;
        e.last_used = tick;
        if let Some(b) = builder {
            e.builder = Some(b);
        }
        let version = e.version;
        if was_resident {
            g.resident_bytes = g.resident_bytes - old_bytes + bytes;
        } else {
            g.resident_bytes += bytes;
        }
        g.total_loads += 1;
        if let Some(m) = &g.metrics {
            m.record_model_load(name);
            m.record_model_swap(name, version);
        }
        Self::evict_to_fit(&mut g, Some(name));
        Ok(version)
    }

    /// Current version of an entry (1 = as registered).
    pub fn version(&self, name: &str) -> Option<u64> {
        self.inner.lock().unwrap().entries.get(name).map(|e| e.version)
    }

    /// Store-wide counters.
    pub fn stats(&self) -> StoreStats {
        let g = self.inner.lock().unwrap();
        StoreStats {
            models: g.entries.len(),
            resident_models: g.entries.values().filter(|e| e.resident.is_some()).count(),
            resident_bytes: g.resident_bytes,
            budget_bytes: g.budget,
            loads: g.total_loads,
            evictions: g.total_evictions,
        }
    }

    /// One entry's counters.
    pub fn entry_stats(&self, name: &str) -> Option<StoreEntryStats> {
        let g = self.inner.lock().unwrap();
        g.entries.get(name).map(|e| Self::entry_to_stats(name, e))
    }

    /// Every entry's counters, sorted by name.
    pub fn per_entry(&self) -> Vec<StoreEntryStats> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<StoreEntryStats> =
            g.entries.iter().map(|(n, e)| Self::entry_to_stats(n, e)).collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    fn entry_to_stats(name: &str, e: &Entry) -> StoreEntryStats {
        StoreEntryStats {
            name: name.to_string(),
            resident: e.resident.is_some(),
            pinned: e.pinned,
            version: e.version,
            loads: e.loads,
            evictions: e.evictions,
            bytes: e.bytes,
            in_flight: e.in_flight,
        }
    }

    /// Build `name`'s model via its builder and charge it against the
    /// budget, evicting LRU victims as needed.  Caller holds the lock.
    fn make_resident(g: &mut Inner, name: &str) -> Result<(), StoreError> {
        let e = g.entries.get(name).ok_or_else(|| StoreError::Unknown(name.to_string()))?;
        let builder = e.builder.as_ref().ok_or_else(|| StoreError::Build {
            name: name.to_string(),
            reason: "entry is not resident and has no builder".to_string(),
        })?;
        let model = builder().map_err(|reason| StoreError::Build {
            name: name.to_string(),
            reason,
        })?;
        let bytes = model.resident_bytes();
        g.tick += 1;
        let tick = g.tick;
        let e = g.entries.get_mut(name).unwrap();
        e.resident = Some(model);
        e.bytes = bytes;
        e.loads += 1;
        e.last_used = tick;
        g.resident_bytes += bytes;
        g.total_loads += 1;
        if let Some(m) = &g.metrics {
            m.record_model_load(name);
        }
        Self::evict_to_fit(g, Some(name));
        Ok(())
    }

    /// Evict LRU victims until the budget fits or no victim remains.
    /// A victim must be resident, unpinned, idle (no dispatch holds),
    /// rebuildable (has a builder), and not `keep` (the entry that
    /// just loaded — evicting it immediately would thrash forever).
    /// Victim order is the minimum `(last_used, name)` — total and
    /// deterministic despite the `HashMap`, so the DES mirrors it.
    /// The budget is *modeled*: pins, dispatch holds, and oversized
    /// single models may legitimately exceed it.
    fn evict_to_fit(g: &mut Inner, keep: Option<&str>) {
        let Some(budget) = g.budget else { return };
        while g.resident_bytes > budget {
            let victim = g
                .entries
                .iter()
                .filter(|(n, e)| {
                    e.resident.is_some()
                        && !e.pinned
                        && e.in_flight == 0
                        && e.builder.is_some()
                        && keep != Some(n.as_str())
                })
                .min_by(|(an, ae), (bn, be)| {
                    ae.last_used.cmp(&be.last_used).then_with(|| an.cmp(bn))
                })
                .map(|(n, _)| n.clone());
            let Some(victim) = victim else { return };
            let e = g.entries.get_mut(&victim).unwrap();
            e.resident = None;
            e.evictions += 1;
            g.resident_bytes -= e.bytes;
            g.total_evictions += 1;
            if let Some(m) = &g.metrics {
                m.record_model_eviction(&victim);
            }
        }
    }
}

/// A dispatch hold: keeps one model `Arc` alive and its entry
/// un-evictable for the guard's lifetime.  Hot-swapping while guards
/// exist is safe — they finish on the version they captured.
pub struct DispatchGuard {
    store: Arc<ModelStore>,
    name: String,
    model: Arc<dyn Model>,
}

impl DispatchGuard {
    /// The model captured at dispatch time.
    pub fn model(&self) -> &Arc<dyn Model> {
        &self.model
    }

    /// The entry this guard holds.
    pub fn name(&self) -> &str {
        &self.name
    }
}

impl Drop for DispatchGuard {
    fn drop(&mut self) {
        self.store.end_dispatch(&self.name);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{LayerTiming, OpDesc};

    /// Weightless stub whose only interesting property is its byte
    /// charge; forwards echo the first frame so swap tests can tell
    /// versions apart by behavior if they want to.
    struct Stub {
        bytes: usize,
        tag: f32,
    }

    impl Model for Stub {
        fn input_len(&self) -> usize {
            1
        }
        fn output_len(&self) -> usize {
            1
        }
        fn forward_timed(&self, _frames: &[f32]) -> (Vec<f32>, Vec<LayerTiming>) {
            (vec![self.tag], Vec::new())
        }
        fn forward_batch(&self, frames: &[&[f32]]) -> Vec<(Vec<f32>, Vec<LayerTiming>)> {
            frames.iter().map(|_| (vec![self.tag], Vec::new())).collect()
        }
        fn route_ops(&self, _group: usize) -> Vec<OpDesc> {
            Vec::new()
        }
        fn resident_bytes(&self) -> usize {
            self.bytes
        }
        fn describe(&self) -> String {
            format!("stub[{} bytes]", self.bytes)
        }
    }

    fn stub(bytes: usize, tag: f32) -> Arc<dyn Model> {
        Arc::new(Stub { bytes, tag })
    }

    fn lazy(bytes: usize, tag: f32) -> ModelBuilder {
        Box::new(move || Ok(stub(bytes, tag)))
    }

    #[test]
    fn register_rejects_duplicates_and_admits_warm() {
        let store = ModelStore::new(None);
        store.register("a", stub(100, 1.0)).unwrap();
        let err = store.register("a", stub(100, 2.0)).unwrap_err();
        assert!(matches!(err, StoreError::AlreadyRegistered(n) if n == "a"));
        // the original instance survived the rejected re-registration
        let m = store.admit("a").unwrap();
        assert_eq!(m.forward_timed(&[0.0]).0, vec![1.0]);
        assert!(store.resident("a"));
        assert!(!store.resident("ghost"));
        assert!(matches!(store.admit("ghost"), Err(StoreError::Unknown(_))));
        assert_eq!(store.version("a"), Some(1));
    }

    #[test]
    fn cold_admission_sheds_once_then_hits_warm() {
        let store = ModelStore::new(None);
        store.register_lazy("m", 4 << 20, lazy(4 << 20, 1.0)).unwrap();
        assert!(!store.resident("m"));
        let err = store.admit("m").unwrap_err();
        let StoreError::Cold(cold) = err else { panic!("expected cold shed") };
        assert_eq!(cold.name, "m");
        assert_eq!(cold.bytes, 4 << 20);
        assert_eq!(cold.retry_after_us, cold_retry_us(4 << 20));
        assert!(cold.retry_after_us >= 1);
        // the shed itself performed the load: the retry is warm
        assert!(store.resident("m"));
        store.admit("m").unwrap();
        let s = store.entry_stats("m").unwrap();
        assert_eq!((s.loads, s.evictions), (1, 0));
    }

    #[test]
    fn lru_evicts_to_budget_deterministically() {
        // budget fits two 100-byte models
        let store = ModelStore::new(Some(200));
        for (name, tag) in [("a", 1.0), ("b", 2.0), ("c", 3.0)] {
            store.register_lazy(name, 100, lazy(100, tag)).unwrap();
        }
        let _ = store.admit("a"); // cold shed + load
        let _ = store.admit("b");
        store.admit("a").unwrap(); // touch a: b is now LRU
        let _ = store.admit("c"); // loads c, evicting b
        assert!(store.resident("a"));
        assert!(!store.resident("b"));
        assert!(store.resident("c"));
        let s = store.stats();
        assert_eq!(s.resident_bytes, 200);
        assert_eq!((s.loads, s.evictions), (3, 1));
        assert_eq!(s.resident_models, 2);
        // b reloads on demand and evicts the now-LRU a
        let _ = store.admit("b");
        assert!(!store.resident("a"));
        assert_eq!(store.entry_stats("b").unwrap().loads, 2);
        assert_eq!(store.entry_stats("a").unwrap().evictions, 1);
    }

    #[test]
    fn pinned_and_bare_entries_are_never_evicted() {
        let store = ModelStore::new(Some(150));
        // bare instance: no builder, can never be evicted
        store.register("bare", stub(100, 0.0)).unwrap();
        store.register_lazy("p", 100, lazy(100, 1.0)).unwrap();
        store.register_lazy("q", 100, lazy(100, 2.0)).unwrap();
        store.pin("p").unwrap(); // eager load, over budget already
        assert!(store.resident("p"));
        let _ = store.admit("q"); // loads q; only q itself is evictable
        // q was just loaded (kept), bare/p are protected: budget is
        // legitimately exceeded
        assert!(store.resident("bare") && store.resident("p") && store.resident("q"));
        // the next load finds q idle and unpinned: it goes
        store.register_lazy("r", 100, lazy(100, 3.0)).unwrap();
        let _ = store.admit("r");
        assert!(!store.resident("q"));
        assert!(store.resident("bare") && store.resident("p") && store.resident("r"));
        assert!(matches!(store.pin("ghost"), Err(StoreError::Unknown(_))));
    }

    #[test]
    fn dispatch_guard_blocks_eviction_and_reloads_transparently() {
        let store = Arc::new(ModelStore::new(Some(100)));
        store.register_lazy("a", 100, lazy(100, 1.0)).unwrap();
        store.register_lazy("b", 100, lazy(100, 2.0)).unwrap();
        let _ = store.admit("a");
        let guard = store.begin_dispatch("a").unwrap();
        assert_eq!(store.entry_stats("a").unwrap().in_flight, 1);
        // loading b wants a's bytes, but the hold protects a
        let _ = store.admit("b");
        assert!(store.resident("a") && store.resident("b"));
        drop(guard);
        assert_eq!(store.entry_stats("a").unwrap().in_flight, 0);
        // the drop re-ran eviction: LRU a went back under budget
        assert!(!store.resident("a"));
        assert!(store.resident("b"));
        // dispatch of an evicted-but-admitted model reloads, no shed
        let g2 = store.begin_dispatch("a").unwrap();
        assert_eq!(g2.model().forward_timed(&[0.0]).0, vec![1.0]);
        assert_eq!(store.entry_stats("a").unwrap().loads, 2);
    }

    #[test]
    fn swap_bumps_version_and_in_flight_finishes_on_old_weights() {
        let store = Arc::new(ModelStore::new(None));
        store.register("m", stub(100, 1.0)).unwrap();
        let guard = store.begin_dispatch("m").unwrap();
        let v2 = store.swap("m", stub(120, 2.0), Some(lazy(120, 2.0))).unwrap();
        assert_eq!(v2, 2);
        assert_eq!(store.version("m"), Some(2));
        // the guard still runs version 1
        assert_eq!(guard.model().forward_timed(&[0.0]).0, vec![1.0]);
        // new admissions get version 2
        assert_eq!(store.admit("m").unwrap().forward_timed(&[0.0]).0, vec![2.0]);
        drop(guard);
        let s = store.entry_stats("m").unwrap();
        assert_eq!((s.version, s.loads, s.bytes), (2, 2, 120));
        assert_eq!(store.stats().resident_bytes, 120);
        // swapping an unknown name is a typed error
        assert!(matches!(
            store.swap("ghost", stub(1, 0.0), None),
            Err(StoreError::Unknown(_))
        ));
    }

    #[test]
    fn swap_installs_builder_so_evictions_rebuild_the_new_version() {
        let store = ModelStore::new(Some(100));
        store.register_lazy("m", 60, lazy(60, 1.0)).unwrap();
        store.register_lazy("other", 60, lazy(60, 9.0)).unwrap();
        let _ = store.admit("m");
        store.swap("m", stub(60, 2.0), Some(lazy(60, 2.0))).unwrap();
        let _ = store.admit("other"); // evicts m (LRU)
        assert!(!store.resident("m"));
        // the reload builds v2 weights, version counter unchanged
        let err = store.admit("m").unwrap_err();
        assert!(matches!(err, StoreError::Cold(_)));
        assert_eq!(store.admit("m").unwrap().forward_timed(&[0.0]).0, vec![2.0]);
        assert_eq!(store.version("m"), Some(2));
    }

    #[test]
    fn metrics_mirror_store_activity() {
        let metrics = Arc::new(Metrics::default());
        let store = ModelStore::new(Some(100));
        store.attach_metrics(Arc::clone(&metrics));
        store.register_lazy("a", 100, lazy(100, 1.0)).unwrap();
        store.register_lazy("b", 100, lazy(100, 2.0)).unwrap();
        let _ = store.admit("a"); // load a
        let _ = store.admit("b"); // load b, evict a
        store.swap("b", stub(100, 3.0), None).unwrap(); // load + swap
        let (loads, evictions, swaps) = metrics.model_store_counts();
        assert_eq!((loads, evictions, swaps), (3, 1, 1));
        let s = store.stats();
        assert_eq!((s.loads, s.evictions), (loads, evictions));
        let a = metrics.model_counters("a").unwrap();
        assert_eq!((a.loads, a.evictions, a.version), (1, 1, 1));
        let b = metrics.model_counters("b").unwrap();
        assert_eq!((b.loads, b.evictions, b.version), (2, 0, 2));
    }

    #[test]
    fn per_entry_listing_is_sorted_and_complete() {
        let store = ModelStore::new(None);
        store.register("z", stub(10, 0.0)).unwrap();
        store.register_lazy("a", 20, lazy(20, 0.0)).unwrap();
        let rows = store.per_entry();
        assert_eq!(
            rows.iter().map(|r| r.name.as_str()).collect::<Vec<_>>(),
            ["a", "z"]
        );
        assert!(!rows[0].resident && rows[1].resident);
        assert_eq!(rows[0].bytes, 20);
        let failing = ModelStore::new(None);
        failing
            .register_lazy("bad", 1, Box::new(|| Err("disk on fire".to_string())))
            .unwrap();
        let err = failing.fetch("bad").unwrap_err();
        assert!(matches!(err, StoreError::Build { reason, .. } if reason.contains("disk")));
    }
}

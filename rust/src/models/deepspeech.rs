//! The DeepSpeech-like network (paper Fig. 9) as a Rust layer graph with
//! per-layer method assignment — the paper's split: FullPack on the
//! single-batch LSTM GEMVs, Ruy-W8A8 on the batch-16 FC GEMMs (§4.6).
//!
//! Weights are synthetic (DESIGN.md substitution table: end-to-end
//! timing depends on shapes and the GEMV/GEMM split, not weight values)
//! and generated deterministically from a seed so Rust and Python twins
//! agree on shapes.
//!
//! Kernel selection is entirely plan-driven (DESIGN.md §3): every layer
//! holds a `kernels::Plan` built from the §4.6 paper rule (or an
//! explicit registry name via [`DeepSpeech::with_lstm_kernel`]); no
//! kernel function is named here.

use super::xorshift_vals;
use crate::coordinator::request::OpDesc;
use crate::kernels::{
    KernelError, LayerShape, Plan, PlanBuilder, PlanScratch, SelectPolicy, Weights,
};
use crate::pack::{BitWidth, Variant};
use crate::quant::{requantize, requantize_rows, requantize_vec};

/// Shape configuration (defaults = Mozilla DeepSpeech v0.9).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeepSpeechConfig {
    pub n_input: usize,
    pub n_hidden: usize,
    pub n_output: usize,
    /// LSTM unroll length == FC batch (paper: 16)
    pub time_steps: usize,
}

impl DeepSpeechConfig {
    pub const FULL: DeepSpeechConfig =
        DeepSpeechConfig { n_input: 494, n_hidden: 2048, n_output: 32, time_steps: 16 };

    /// Tiny config matching `python/compile/model.py::TINY`.
    pub const TINY: DeepSpeechConfig =
        DeepSpeechConfig { n_input: 64, n_hidden: 128, n_output: 32, time_steps: 4 };

    pub fn gate_dim(&self) -> usize {
        4 * self.n_hidden
    }
}

/// What kind of compute a layer performs — drives the router's
/// GEMV-vs-GEMM path choice (paper §4.6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// batch-16 FullyConnected (GEMM; Ruy-W8A8 path)
    FcBatch,
    /// single-batch LSTM step GEMVs (FullPack path)
    LstmStep,
}

/// One layer of the Fig. 9 graph.  The name is owned (not `&'static`)
/// so layer descriptions can also be built at runtime — e.g. from a
/// model manifest (`runtime::manifest::parse_model_graph`).
#[derive(Debug)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub z: usize,
    pub k: usize,
}

/// The assembled model: quantized weights packed per the chosen variant
/// for the LSTM, W8A8 for the FC stack, with one execution plan per
/// layer shape.
pub struct DeepSpeech {
    pub config: DeepSpeechConfig,
    pub variant: Variant,
    pub layers: Vec<Layer>,
    /// FC weights, always W8A8 (paper routes GEMM to Ruy)
    pub fc_weights: Vec<Weights>,
    pub fc_biases: Vec<Vec<f32>>,
    /// optional per-row (per-output-channel) weight scales per FC layer
    /// — the kind `quant::quantize_per_row` produces.  `None` (the
    /// default) keeps the per-tensor `s_w`; `Some` routes that layer's
    /// requantization through `quant::requantize_rows`
    /// ([`DeepSpeech::with_fc_row_scales`]).
    fc_row_scales: Vec<Option<Vec<f32>>>,
    /// one plan per FC layer (batched → the Ruy path under `PaperRule`)
    fc_plans: Vec<Plan>,
    /// LSTM gate weights `[wx, wh]`, in the LSTM plan's kernel layout
    pub lstm_wx: Weights,
    pub lstm_wh: Weights,
    /// shared plan for both gate GEMVs (same `4H × H` shape)
    lstm_plan: Plan,
    pub lstm_bias: Vec<f32>,
    pub s_x: f32,
    pub s_h: f32,
    pub s_w: f32,
    /// intra-op row-parallelism for the LSTM gate GEMVs (1 = serial;
    /// results are bit-identical either way — `kernels::parallel`)
    pub intra_op_threads: usize,
    seed: u64,
}

impl DeepSpeech {
    /// Build with synthetic weights.  `variant` applies to the LSTM
    /// GEMVs; FC layers are W8A8 as in the paper's end-to-end setup.
    pub fn new(config: DeepSpeechConfig, variant: Variant, seed: u64) -> Self {
        let h = config.n_hidden;
        let layer = |name: &str, kind, z, k| Layer { name: name.to_string(), kind, z, k };
        let layers = vec![
            layer("fc1", LayerKind::FcBatch, h, config.n_input),
            layer("fc2", LayerKind::FcBatch, h, h),
            layer("fc3", LayerKind::FcBatch, h, h),
            layer("lstm", LayerKind::LstmStep, config.gate_dim(), 2 * h),
            layer("fc5", LayerKind::FcBatch, h, h),
            layer("fc6", LayerKind::FcBatch, config.n_output, h),
        ];
        let w8a8 = Variant::new(BitWidth::B8, BitWidth::B8);
        let mut fc_weights = Vec::new();
        let mut fc_biases = Vec::new();
        let mut fc_plans = Vec::new();
        for (i, l) in layers.iter().enumerate() {
            if l.kind == LayerKind::FcBatch {
                // batch = time_steps → PaperRule selects the Ruy path
                let plan = PlanBuilder::new(
                    LayerShape { z: l.z, k: l.k, batch: config.time_steps },
                    w8a8,
                )
                .build()
                .expect("fc plan");
                let w = xorshift_vals(BitWidth::B8, l.z * l.k, seed + i as u64);
                fc_weights.push(plan.prepare_weights(&w).expect("fc weights"));
                fc_biases.push(vec![0.01; l.z]);
                fc_plans.push(plan);
            }
        }
        // single-batch gate GEMVs → PaperRule selects FullPack for
        // sub-byte variants, Ruy for w8a8 (the paper's §4.6 split)
        let lstm_plan = PlanBuilder::new(
            LayerShape { z: config.gate_dim(), k: h, batch: 1 },
            variant,
        )
        .build()
        .expect("lstm plan");
        let lstm_wx = lstm_plan
            .prepare_weights(&xorshift_vals(variant.w, config.gate_dim() * h, seed + 100))
            .expect("lstm wx");
        let lstm_wh = lstm_plan
            .prepare_weights(&xorshift_vals(variant.w, config.gate_dim() * h, seed + 101))
            .expect("lstm wh");
        let mut lstm_bias = vec![0.0f32; config.gate_dim()];
        lstm_bias[h..2 * h].fill(1.0); // forget-gate bias 1
        let (_, ahi) = variant.a.value_range();
        let fc_row_scales = vec![None; fc_weights.len()];
        DeepSpeech {
            intra_op_threads: 1,
            config,
            variant,
            layers,
            fc_weights,
            fc_biases,
            fc_row_scales,
            fc_plans,
            lstm_wx,
            lstm_wh,
            lstm_plan,
            lstm_bias,
            s_x: 0.05,
            s_h: if ahi > 0 { 1.0 / ahi as f32 } else { 1.0 },
            s_w: 0.02,
            seed,
        }
    }

    /// Re-bind the LSTM gate GEMVs to an explicit registry kernel
    /// (CLI `--kernel`): rebuilds the plan and re-packs the gate
    /// weights into the new kernel's layout.
    pub fn with_lstm_kernel(mut self, name: &str) -> Result<DeepSpeech, KernelError> {
        let h = self.config.n_hidden;
        let plan = PlanBuilder::new(
            LayerShape { z: self.config.gate_dim(), k: h, batch: 1 },
            self.variant,
        )
        .policy(SelectPolicy::Explicit(name.to_string()))
        .build()?;
        self.lstm_wx = plan
            .prepare_weights(&xorshift_vals(self.variant.w, self.config.gate_dim() * h, self.seed + 100))?;
        self.lstm_wh = plan
            .prepare_weights(&xorshift_vals(self.variant.w, self.config.gate_dim() * h, self.seed + 101))?;
        self.lstm_plan = plan;
        Ok(self)
    }

    /// Registry name of the kernel serving the LSTM gate GEMVs.
    pub fn lstm_kernel_name(&self) -> &'static str {
        self.lstm_plan.kernel_name()
    }

    /// Attach per-row (per-output-channel) weight scales to FC layer
    /// `idx` — the scales `quant::quantize_per_row` produces.  That
    /// layer's requantization then goes through
    /// `quant::requantize_rows`; layers without scales keep the
    /// per-tensor `s_w` default.  `scales` must hold one entry per
    /// output row of the layer.
    pub fn with_fc_row_scales(
        mut self,
        idx: usize,
        scales: Vec<f32>,
    ) -> Result<DeepSpeech, KernelError> {
        let Some(w) = self.fc_weights.get(idx) else {
            return Err(KernelError::Shape(format!(
                "fc layer index {idx} out of range ({} fc layers)",
                self.fc_weights.len()
            )));
        };
        if scales.len() != w.rows() {
            return Err(KernelError::Shape(format!(
                "{} row scales for a {}-row fc layer",
                scales.len(),
                w.rows()
            )));
        }
        self.fc_row_scales[idx] = Some(scales);
        Ok(self)
    }

    /// Quantize an f32 vector to the variant's activation width.
    fn quant_act(&self, x: &[f32], scale: f32) -> Vec<i8> {
        let (lo, hi) = self.variant.a.value_range();
        x.iter()
            .map(|&v| (v / scale).round().clamp(lo as f32, hi as f32) as i8)
            .collect()
    }

    /// One LSTM step over the plan-selected kernel (the FullPack hot
    /// path).  `x_q` is the quantized input, `h_q` the quantized
    /// previous hidden state (both of logical depth `n_hidden`; the
    /// plan's scratch pads/packs them), `c` the f32 cell.  Returns
    /// `(h_f32, c_next)`.
    pub fn lstm_step(
        &self,
        x_q: &[i8],
        h_q: &[i8],
        c: &[f32],
        scratch: &mut LstmScratch,
    ) -> (Vec<f32>, Vec<f32>) {
        let hdim = self.config.n_hidden;
        let gd = self.config.gate_dim();

        let threads = self.intra_op_threads.max(1);
        scratch.acc_x.resize(gd, 0);
        scratch.acc_h.resize(gd, 0);
        // per-request scratch: concurrent requests sharing this model
        // never contend on (or reallocate) the plan's internal buffers
        self.lstm_plan
            .execute_in(&self.lstm_wx, x_q, &mut scratch.acc_x, threads, &mut scratch.pack)
            .expect("lstm gemv");
        self.lstm_plan
            .execute_in(&self.lstm_wh, h_q, &mut scratch.acc_h, threads, &mut scratch.pack)
            .expect("lstm gemv");

        let gates_x = requantize_vec(&scratch.acc_x, self.s_w, self.s_x, &self.lstm_bias);
        let sig = |v: f32| 1.0 / (1.0 + (-v).exp());
        let mut h_new = vec![0.0f32; hdim];
        let mut c_new = vec![0.0f32; hdim];
        for j in 0..hdim {
            let g_h = |lane: usize| scratch.acc_h[lane] as f32 * (self.s_w * self.s_h);
            let i = sig(gates_x[j] + g_h(j));
            let f = sig(gates_x[hdim + j] + g_h(hdim + j));
            let g = (gates_x[2 * hdim + j] + g_h(2 * hdim + j)).tanh();
            let o = sig(gates_x[3 * hdim + j] + g_h(3 * hdim + j));
            c_new[j] = f * c[j] + i * g;
            h_new[j] = o * c_new[j].tanh();
        }
        (h_new, c_new)
    }

    /// Full forward over `frames` (time_steps × n_input, row-major f32):
    /// FC stack (batch GEMM) → LSTM scan (per-step GEMVs) → FC stack.
    /// Returns (logits, per-layer elapsed nanoseconds) — the per-layer
    /// breakdown is exactly what Fig. 1 / Fig. 10 plot.  Layer labels
    /// are owned strings (runtime-built models need non-static names).
    pub fn forward_timed(&self, frames: &[f32]) -> (Vec<f32>, Vec<(String, u128)>) {
        self.forward_batch(&[frames]).pop().expect("one request in, one result out")
    }

    /// Batched forward over `n` independent requests — the serving
    /// engine's multi-request dispatch (DESIGN.md §9): all requests'
    /// frames are stacked into `n · time_steps` columns so each FC
    /// layer executes as **one** batched GEMM call, amortizing the
    /// weight pass across the whole flush; the recurrent LSTM scans
    /// stay per-request single-batch GEMVs (the FullPack path — a
    /// recurrence cannot batch across time).  Per-request results are
    /// bit-identical to `n` separate [`DeepSpeech::forward_timed`]
    /// calls because batched GEMM is column-independent integer math
    /// (pinned by `rust/tests/gemm_differential.rs`).
    ///
    /// Returns one `(logits, layer_times)` pair per request; the layer
    /// times are the shared group-level measurements.
    pub fn forward_batch(&self, frames: &[&[f32]]) -> Vec<(Vec<f32>, Vec<(String, u128)>)> {
        let cfg = self.config;
        let t = cfg.time_steps;
        let n = frames.len();
        if n == 0 {
            return Vec::new();
        }
        for f in frames {
            assert_eq!(f.len(), t * cfg.n_input, "bad frame window");
        }
        let cols = n * t;
        let mut times: Vec<(String, u128)> = Vec::new();
        let s_act = 0.05f32;

        // FC front-end: one GEMM over all `cols` columns (W8A8 — the
        // plan's GEMM backend)
        let mut cur: Vec<f32> = Vec::with_capacity(cols * cfg.n_input);
        for f in frames {
            cur.extend_from_slice(f);
        }
        let mut dim = cfg.n_input;
        let mut fc_idx = 0;
        for name in ["fc1", "fc2", "fc3"] {
            let start = std::time::Instant::now();
            cur = self.fc_forward(fc_idx, &cur, cols, dim, s_act, true);
            dim = self.fc_weights[fc_idx].rows();
            times.push((name.to_string(), start.elapsed().as_nanos()));
            fc_idx += 1;
        }

        // LSTM scans — per-request single-batch steps (FullPack path)
        let start = std::time::Instant::now();
        let hdim = cfg.n_hidden;
        let mut hs = vec![0.0f32; cols * hdim];
        let mut scratch = LstmScratch::default();
        for r in 0..n {
            let mut h_q = vec![0i8; hdim];
            let mut c = vec![0.0f32; hdim];
            for step in 0..t {
                let row = (r * t + step) * hdim;
                let x = &cur[row..row + hdim];
                let x_q = self.quant_act(x, self.s_x);
                let (h_f, c_n) = self.lstm_step(&x_q, &h_q, &c, &mut scratch);
                h_q = self.quant_act(&h_f, self.s_h);
                c = c_n;
                hs[row..row + hdim].copy_from_slice(&h_f);
            }
        }
        times.push(("lstm".to_string(), start.elapsed().as_nanos()));

        // FC back-end: batched over all columns again
        let mut out = hs;
        let mut dim2 = hdim;
        for name in ["fc5", "fc6"] {
            let start = std::time::Instant::now();
            let relu = name == "fc5";
            out = self.fc_forward(fc_idx, &out, cols, dim2, s_act, relu);
            dim2 = self.fc_weights[fc_idx].rows();
            times.push((name.to_string(), start.elapsed().as_nanos()));
            fc_idx += 1;
        }
        let per = t * cfg.n_output;
        (0..n).map(|r| (out[r * per..(r + 1) * per].to_vec(), times.clone())).collect()
    }

    fn fc_forward(
        &self,
        idx: usize,
        x: &[f32],
        batch: usize,
        k: usize,
        s_act: f32,
        relu: bool,
    ) -> Vec<f32> {
        let w = &self.fc_weights[idx];
        let z = w.rows();
        debug_assert_eq!(w.k(), k);
        // quantize activations to int8
        let xq: Vec<i8> = x
            .iter()
            .map(|&v| (v / s_act).round().clamp(-128.0, 127.0) as i8)
            .collect();
        let mut acc = vec![0i32; batch * z];
        self.fc_plans[idx].execute_batch(w, &xq, batch, &mut acc).expect("fc gemm");
        let bias = &self.fc_biases[idx];
        // per-row scales (quantize_per_row) when the layer carries
        // them; the per-tensor s_w default otherwise
        let mut out = match &self.fc_row_scales[idx] {
            // batch-major multi-column acc is requantize_rows' native shape
            Some(s_rows) => requantize_rows(&acc, s_rows, s_act, bias),
            None => {
                // single allocation, fused per-column pass
                let mut o = vec![0.0f32; batch * z];
                for (ocol, acol) in o.chunks_exact_mut(z).zip(acc.chunks_exact(z)) {
                    for ((y, &a), &bi) in ocol.iter_mut().zip(acol).zip(bias) {
                        *y = requantize(a, self.s_w, s_act, bi);
                    }
                }
                o
            }
        };
        if relu {
            for v in &mut out {
                *v = v.clamp(0.0, 20.0);
            }
        }
        out
    }

    /// Total weight footprint in bytes (capacity metric).
    pub fn weight_footprint(&self) -> usize {
        self.fc_weights.iter().map(|w| w.footprint()).sum::<usize>()
            + self.lstm_wx.footprint()
            + self.lstm_wh.footprint()
    }
}

/// Reusable buffers for the LSTM hot loop (no allocation per step).
#[derive(Default)]
pub struct LstmScratch {
    acc_x: Vec<i32>,
    acc_h: Vec<i32>,
    /// activation pad/pack scratch handed to `Plan::execute_in`
    pack: PlanScratch,
}

impl super::Model for DeepSpeech {
    fn input_len(&self) -> usize {
        self.config.time_steps * self.config.n_input
    }

    fn output_len(&self) -> usize {
        self.config.time_steps * self.config.n_output
    }

    fn forward_timed(&self, frames: &[f32]) -> (Vec<f32>, Vec<(String, u128)>) {
        DeepSpeech::forward_timed(self, frames)
    }

    fn forward_batch(&self, frames: &[&[f32]]) -> Vec<(Vec<f32>, Vec<(String, u128)>)> {
        DeepSpeech::forward_batch(self, frames)
    }

    fn route_ops(&self, group: usize) -> Vec<OpDesc> {
        // FC layers hold W8A8 weights regardless of the model variant
        // (the paper's protocol, hard-built in DeepSpeech::new) —
        // describe them as what they actually execute, so routing stats
        // can never advertise a backend the model's own plans did not
        // run.  The FC stack flushes as one `group · time_steps`-column
        // GEMM; each request's LSTM scan stays a single-batch GEMV
        // stream.
        let w8a8 = Variant::new(BitWidth::B8, BitWidth::B8);
        let mut ops = Vec::new();
        for layer in &self.layers {
            match layer.kind {
                LayerKind::FcBatch => ops.push(OpDesc {
                    batch: group * self.config.time_steps,
                    z: layer.z,
                    k: layer.k,
                    variant: w8a8,
                }),
                LayerKind::LstmStep => {
                    let op =
                        OpDesc { batch: 1, z: layer.z, k: layer.k, variant: self.variant };
                    ops.extend(std::iter::repeat(op).take(group));
                }
            }
        }
        ops
    }

    fn describe(&self) -> String {
        format!(
            "deepspeech {} (input {}, hidden {}, T {}, lstm kernel {})",
            self.variant,
            self.config.n_input,
            self.config.n_hidden,
            self.config.time_steps,
            self.lstm_kernel_name()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes_all_variants() {
        let cfg = DeepSpeechConfig::TINY;
        let frames = vec![0.1f32; cfg.time_steps * cfg.n_input];
        for v in Variant::PAPER_VARIANTS {
            let m = DeepSpeech::new(cfg, v, 7);
            let (out, times) = m.forward_timed(&frames);
            assert_eq!(out.len(), cfg.time_steps * cfg.n_output, "{v}");
            assert!(out.iter().all(|x| x.is_finite()), "{v}");
            assert_eq!(times.len(), 6);
            assert_eq!(times[3].0, "lstm");
        }
    }

    #[test]
    fn deterministic() {
        let cfg = DeepSpeechConfig::TINY;
        let frames: Vec<f32> = (0..cfg.time_steps * cfg.n_input)
            .map(|i| (i as f32 * 0.01).sin())
            .collect();
        let v = Variant::parse("w4a8").unwrap();
        let a = DeepSpeech::new(cfg, v, 7).forward_timed(&frames).0;
        let b = DeepSpeech::new(cfg, v, 7).forward_timed(&frames).0;
        assert_eq!(a, b);
    }

    #[test]
    fn explicit_lstm_kernel_is_bit_identical() {
        // same math, different backend layout: the naive Alg. 1 kernel
        // must reproduce the FullPack logits exactly
        let cfg = DeepSpeechConfig::TINY;
        let frames: Vec<f32> = (0..cfg.time_steps * cfg.n_input)
            .map(|i| (i as f32 * 0.01).sin())
            .collect();
        let v = Variant::parse("w4a8").unwrap();
        let m = DeepSpeech::new(cfg, v, 7);
        assert_eq!(m.lstm_kernel_name(), "fullpack-w4a8");
        let base = m.forward_timed(&frames).0;
        let naive = DeepSpeech::new(cfg, v, 7).with_lstm_kernel("naive-w4a8").unwrap();
        assert_eq!(naive.lstm_kernel_name(), "naive-w4a8");
        assert_eq!(naive.forward_timed(&frames).0, base);
        // a kernel that cannot run the variant is a build-time error
        assert!(DeepSpeech::new(cfg, v, 7).with_lstm_kernel("ulppack-w2a2").is_err());
    }

    #[test]
    fn forward_batch_is_bit_identical_to_per_request() {
        // the engine's multi-request GEMM dispatch cannot change
        // results — only amortize weight passes
        let cfg = DeepSpeechConfig::TINY;
        for vname in ["w4a8", "w2a2", "w8a8"] {
            let v = Variant::parse(vname).unwrap();
            let m = DeepSpeech::new(cfg, v, 13);
            let reqs: Vec<Vec<f32>> = (0..3)
                .map(|r| {
                    (0..cfg.time_steps * cfg.n_input)
                        .map(|i| ((i + r * 37) as f32 * 0.011).sin())
                        .collect()
                })
                .collect();
            let refs: Vec<&[f32]> = reqs.iter().map(|f| f.as_slice()).collect();
            let batched = m.forward_batch(&refs);
            assert_eq!(batched.len(), 3);
            for (r, f) in reqs.iter().enumerate() {
                let single = m.forward_timed(f).0;
                assert_eq!(batched[r].0, single, "{vname} request {r}");
                assert_eq!(batched[r].1.len(), 6);
            }
        }
        // the empty flush is a no-op
        let m = DeepSpeech::new(cfg, Variant::parse("w4a8").unwrap(), 13);
        assert!(m.forward_batch(&[]).is_empty());
    }

    #[test]
    fn per_row_fc_scales_behind_per_tensor_default() {
        let cfg = DeepSpeechConfig::TINY;
        let frames: Vec<f32> = (0..cfg.time_steps * cfg.n_input)
            .map(|i| (i as f32 * 0.013).sin())
            .collect();
        let v = Variant::parse("w4a8").unwrap();
        let base = DeepSpeech::new(cfg, v, 9).forward_timed(&frames).0;
        // uniform per-row scales equal to s_w are the per-tensor path
        // in disguise: bit-identical logits
        let m = DeepSpeech::new(cfg, v, 9);
        let uniform: Vec<f32> = vec![m.s_w; cfg.n_hidden];
        let m = m.with_fc_row_scales(0, uniform).unwrap();
        assert_eq!(m.forward_timed(&frames).0, base);
        // inflating fc1's row scales perturbs the logits (the per-row
        // path is actually live), still finite
        let m2 = DeepSpeech::new(cfg, v, 9);
        let scales = vec![m2.s_w * 4.0; cfg.n_hidden];
        let out = m2.with_fc_row_scales(0, scales).unwrap().forward_timed(&frames).0;
        assert_ne!(out, base);
        assert!(out.iter().all(|x| x.is_finite()));
        // shape errors are loud
        assert!(DeepSpeech::new(cfg, v, 9).with_fc_row_scales(0, vec![1.0; 3]).is_err());
        assert!(DeepSpeech::new(cfg, v, 9).with_fc_row_scales(99, vec![1.0]).is_err());
    }

    #[test]
    fn footprint_shrinks_with_bits() {
        let cfg = DeepSpeechConfig::TINY;
        let f8 = DeepSpeech::new(cfg, Variant::parse("w8a8").unwrap(), 1).weight_footprint();
        let f4 = DeepSpeech::new(cfg, Variant::parse("w4a4").unwrap(), 1).weight_footprint();
        let f1 = DeepSpeech::new(cfg, Variant::parse("w1a1").unwrap(), 1).weight_footprint();
        assert!(f4 < f8 && f1 < f4);
    }

    #[test]
    fn lstm_step_matches_scalar_reference() {
        // cross-check the packed LSTM gates against a direct i32 GEMV
        let cfg = DeepSpeechConfig::TINY;
        let v = Variant::parse("w4a8").unwrap();
        let m = DeepSpeech::new(cfg, v, 3);
        let kp = m.lstm_wx.k_padded();
        let x_q = vec![1i8; kp];
        let h_q = vec![0i8; kp];
        let c = vec![0.0f32; cfg.n_hidden];
        let mut scratch = LstmScratch::default();
        let (h, c2) = m.lstm_step(&x_q, &h_q, &c, &mut scratch);
        // oracle for gate 0 lane 0
        let wx = m.lstm_wx.as_packed().unwrap().unpack_all();
        let acc: i32 = wx[..kp].iter().map(|&w| w as i32).sum();
        let gate0 = acc as f32 * (m.s_w * m.s_x) + m.lstm_bias[0];
        let sig = |v: f32| 1.0 / (1.0 + (-v).exp());
        let hdim = cfg.n_hidden;
        let accf = |r: usize| -> f32 {
            wx[r * kp..(r + 1) * kp].iter().map(|&w| w as i32).sum::<i32>() as f32
                * (m.s_w * m.s_x)
                + m.lstm_bias[r]
        };
        let c_expect = sig(accf(hdim)) * 0.0 + sig(gate0) * accf(2 * hdim).tanh();
        let h_expect = sig(accf(3 * hdim)) * c_expect.tanh();
        assert!((c2[0] - c_expect).abs() < 1e-4);
        assert!((h[0] - h_expect).abs() < 1e-4);
    }
}

//! The DeepSpeech-like network (paper Fig. 9) as a Rust layer graph with
//! per-layer method assignment — the paper's split: FullPack on the
//! single-batch LSTM GEMVs, Ruy-W8A8 on the batch-16 FC GEMMs (§4.6).
//!
//! Weights are synthetic (DESIGN.md substitution table: end-to-end
//! timing depends on shapes and the GEMV/GEMM split, not weight values)
//! and generated deterministically from a seed so Rust and Python twins
//! agree on shapes.

use crate::kernels::{self, ActVec};
use crate::pack::{BitWidth, PackedMatrix, Variant};
use crate::quant::requantize_vec;

/// Shape configuration (defaults = Mozilla DeepSpeech v0.9).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeepSpeechConfig {
    pub n_input: usize,
    pub n_hidden: usize,
    pub n_output: usize,
    /// LSTM unroll length == FC batch (paper: 16)
    pub time_steps: usize,
}

impl DeepSpeechConfig {
    pub const FULL: DeepSpeechConfig =
        DeepSpeechConfig { n_input: 494, n_hidden: 2048, n_output: 32, time_steps: 16 };

    /// Tiny config matching `python/compile/model.py::TINY`.
    pub const TINY: DeepSpeechConfig =
        DeepSpeechConfig { n_input: 64, n_hidden: 128, n_output: 32, time_steps: 4 };

    pub fn gate_dim(&self) -> usize {
        4 * self.n_hidden
    }
}

/// What kind of compute a layer performs — drives the router's
/// GEMV-vs-GEMM path choice (paper §4.6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// batch-16 FullyConnected (GEMM; Ruy-W8A8 path)
    FcBatch,
    /// single-batch LSTM step GEMVs (FullPack path)
    LstmStep,
}

/// One layer of the Fig. 9 graph.
#[derive(Debug)]
pub struct Layer {
    pub name: &'static str,
    pub kind: LayerKind,
    pub z: usize,
    pub k: usize,
}

/// The assembled model: quantized weights packed per the chosen variant
/// for the LSTM, W8A8 for the FC stack.
pub struct DeepSpeech {
    pub config: DeepSpeechConfig,
    pub variant: Variant,
    pub layers: Vec<Layer>,
    /// FC weights, always W8A8 (paper routes GEMM to Ruy)
    pub fc_weights: Vec<PackedMatrix>,
    pub fc_biases: Vec<Vec<f32>>,
    /// LSTM gate weights `[wx, wh]`, packed per `variant.w`
    pub lstm_wx: PackedMatrix,
    pub lstm_wh: PackedMatrix,
    pub lstm_bias: Vec<f32>,
    pub s_x: f32,
    pub s_h: f32,
    pub s_w: f32,
    /// intra-op row-parallelism for the LSTM gate GEMVs (1 = serial;
    /// results are bit-identical either way — `kernels::parallel`)
    pub intra_op_threads: usize,
}

fn xorshift_vals(bits: BitWidth, n: usize, seed: u64) -> Vec<i8> {
    let (lo, hi) = bits.value_range();
    let span = (hi as i16 - lo as i16 + 1) as u64;
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    (0..n)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (lo as i16 + (s % span) as i16) as i8
        })
        .collect()
}

impl DeepSpeech {
    /// Build with synthetic weights.  `variant` applies to the LSTM
    /// GEMVs; FC layers are W8A8 as in the paper's end-to-end setup.
    pub fn new(config: DeepSpeechConfig, variant: Variant, seed: u64) -> Self {
        let h = config.n_hidden;
        let layers = vec![
            Layer { name: "fc1", kind: LayerKind::FcBatch, z: h, k: config.n_input },
            Layer { name: "fc2", kind: LayerKind::FcBatch, z: h, k: h },
            Layer { name: "fc3", kind: LayerKind::FcBatch, z: h, k: h },
            Layer { name: "lstm", kind: LayerKind::LstmStep, z: config.gate_dim(), k: 2 * h },
            Layer { name: "fc5", kind: LayerKind::FcBatch, z: h, k: h },
            Layer { name: "fc6", kind: LayerKind::FcBatch, z: config.n_output, k: h },
        ];
        let mut fc_weights = Vec::new();
        let mut fc_biases = Vec::new();
        for (i, l) in layers.iter().enumerate() {
            if l.kind == LayerKind::FcBatch {
                let w = xorshift_vals(BitWidth::B8, l.z * l.k, seed + i as u64);
                fc_weights.push(PackedMatrix::from_i8(&w, l.z, l.k, BitWidth::B8).unwrap());
                fc_biases.push(vec![0.01; l.z]);
            }
        }
        let kp = variant.padded_depth(h);
        let mk = |s| {
            let mut w = xorshift_vals(variant.w, config.gate_dim() * h, s);
            if kp != h {
                // zero-pad each row to the group-aligned depth
                let mut padded = vec![0i8; config.gate_dim() * kp];
                for r in 0..config.gate_dim() {
                    padded[r * kp..r * kp + h].copy_from_slice(&w[r * h..(r + 1) * h]);
                }
                w = padded;
            }
            PackedMatrix::from_i8(&w, config.gate_dim(), kp, variant.w).unwrap()
        };
        let lstm_wx = mk(seed + 100);
        let lstm_wh = mk(seed + 101);
        let mut lstm_bias = vec![0.0f32; config.gate_dim()];
        lstm_bias[h..2 * h].fill(1.0); // forget-gate bias 1
        let (_, ahi) = variant.a.value_range();
        DeepSpeech {
            intra_op_threads: 1,
            config,
            variant,
            layers,
            fc_weights,
            fc_biases,
            lstm_wx,
            lstm_wh,
            lstm_bias,
            s_x: 0.05,
            s_h: if ahi > 0 { 1.0 / ahi as f32 } else { 1.0 },
            s_w: 0.02,
        }
    }

    /// Quantize an f32 vector to the variant's activation width.
    fn quant_act(&self, x: &[f32], scale: f32) -> Vec<i8> {
        let (lo, hi) = self.variant.a.value_range();
        x.iter()
            .map(|&v| (v / scale).round().clamp(lo as f32, hi as f32) as i8)
            .collect()
    }

    /// One LSTM step over the native kernels (the FullPack hot path).
    /// `x` is the quantized input (padded to the gate matrices' depth),
    /// `h_q` the quantized previous hidden state, `c` the f32 cell.
    /// Returns `(h_f32, c_next)`.
    pub fn lstm_step(
        &self,
        x_q: &[i8],
        h_q: &[i8],
        c: &[f32],
        scratch: &mut LstmScratch,
    ) -> (Vec<f32>, Vec<f32>) {
        let hdim = self.config.n_hidden;
        let gd = self.config.gate_dim();
        let kp = self.lstm_wx.k_padded();
        debug_assert_eq!(x_q.len(), kp);
        debug_assert_eq!(h_q.len(), kp);

        let threads = self.intra_op_threads.max(1);
        let run = |w: &PackedMatrix, a: &[i8], out: &mut [i32], buf: &mut Vec<u8>| {
            if self.variant.a.is_sub_byte() {
                buf.clear();
                buf.extend(crate::pack::pack_unchecked(a, self.variant.a));
                let act = ActVec::Packed { bytes: buf, bits: self.variant.a };
                kernels::parallel::gemv_parallel(w, act, out, threads).expect("lstm gemv");
            } else {
                kernels::parallel::gemv_parallel(w, ActVec::I8(a), out, threads)
                    .expect("lstm gemv");
            }
        };
        scratch.acc_x.resize(gd, 0);
        scratch.acc_h.resize(gd, 0);
        run(&self.lstm_wx, x_q, &mut scratch.acc_x, &mut scratch.pack_buf);
        run(&self.lstm_wh, h_q, &mut scratch.acc_h, &mut scratch.pack_buf);

        let gates_x = requantize_vec(&scratch.acc_x, self.s_w, self.s_x, &self.lstm_bias);
        let sig = |v: f32| 1.0 / (1.0 + (-v).exp());
        let mut h_new = vec![0.0f32; hdim];
        let mut c_new = vec![0.0f32; hdim];
        for j in 0..hdim {
            let g_h = |lane: usize| scratch.acc_h[lane] as f32 * (self.s_w * self.s_h);
            let i = sig(gates_x[j] + g_h(j));
            let f = sig(gates_x[hdim + j] + g_h(hdim + j));
            let g = (gates_x[2 * hdim + j] + g_h(2 * hdim + j)).tanh();
            let o = sig(gates_x[3 * hdim + j] + g_h(3 * hdim + j));
            c_new[j] = f * c[j] + i * g;
            h_new[j] = o * c_new[j].tanh();
        }
        (h_new, c_new)
    }

    /// Full forward over `frames` (time_steps × n_input, row-major f32):
    /// FC stack (batch GEMM) → LSTM scan (per-step GEMVs) → FC stack.
    /// Returns (logits, per-layer elapsed nanoseconds) — the per-layer
    /// breakdown is exactly what Fig. 1 / Fig. 10 plot.
    pub fn forward_timed(&self, frames: &[f32]) -> (Vec<f32>, Vec<(&'static str, u128)>) {
        let cfg = self.config;
        let t = cfg.time_steps;
        assert_eq!(frames.len(), t * cfg.n_input);
        let mut times = Vec::new();
        let s_act = 0.05f32;

        // FC front-end (batch GEMM, W8A8 — Ruy path)
        let mut cur: Vec<f32> = frames.to_vec();
        let mut dim = cfg.n_input;
        let mut fc_idx = 0;
        for name in ["fc1", "fc2", "fc3"] {
            let start = std::time::Instant::now();
            cur = self.fc_forward(fc_idx, &cur, t, dim, s_act, true);
            dim = self.fc_weights[fc_idx].rows();
            times.push((name, start.elapsed().as_nanos()));
            fc_idx += 1;
        }

        // LSTM scan — single-batch steps (FullPack path)
        let start = std::time::Instant::now();
        let hdim = cfg.n_hidden;
        let kp = self.lstm_wx.k_padded();
        let mut h_q = vec![0i8; kp];
        let mut c = vec![0.0f32; hdim];
        let mut hs = vec![0.0f32; t * hdim];
        let mut scratch = LstmScratch::default();
        for step in 0..t {
            let x = &cur[step * hdim..(step + 1) * hdim];
            let mut x_q = self.quant_act(x, self.s_x);
            x_q.resize(kp, 0);
            let (h_f, c_n) = self.lstm_step(&x_q, &h_q, &c, &mut scratch);
            let mut hq = self.quant_act(&h_f, self.s_h);
            hq.resize(kp, 0);
            h_q = hq;
            c = c_n;
            hs[step * hdim..(step + 1) * hdim].copy_from_slice(&h_f);
        }
        times.push(("lstm", start.elapsed().as_nanos()));

        // FC back-end
        let mut out = hs;
        let mut dim2 = hdim;
        for name in ["fc5", "fc6"] {
            let start = std::time::Instant::now();
            let relu = name == "fc5";
            out = self.fc_forward(fc_idx, &out, t, dim2, s_act, relu);
            dim2 = self.fc_weights[fc_idx].rows();
            times.push((name, start.elapsed().as_nanos()));
            fc_idx += 1;
        }
        (out, times)
    }

    fn fc_forward(
        &self,
        idx: usize,
        x: &[f32],
        batch: usize,
        k: usize,
        s_act: f32,
        relu: bool,
    ) -> Vec<f32> {
        let w = &self.fc_weights[idx];
        let z = w.rows();
        debug_assert_eq!(w.k(), k);
        // quantize activations to int8
        let xq: Vec<i8> = x
            .iter()
            .map(|&v| (v / s_act).round().clamp(-128.0, 127.0) as i8)
            .collect();
        let mut acc = vec![0i32; batch * z];
        crate::kernels::baseline::gemm_ruy_i8(w, &xq, batch, &mut acc);
        let s = s_act * self.s_w;
        let bias = &self.fc_biases[idx];
        let mut out = vec![0.0f32; batch * z];
        for b in 0..batch {
            for j in 0..z {
                let v = acc[b * z + j] as f32 * s + bias[j];
                out[b * z + j] = if relu { v.clamp(0.0, 20.0) } else { v };
            }
        }
        out
    }

    /// Total weight footprint in bytes (capacity metric).
    pub fn weight_footprint(&self) -> usize {
        self.fc_weights.iter().map(|w| w.footprint()).sum::<usize>()
            + self.lstm_wx.footprint()
            + self.lstm_wh.footprint()
    }
}

/// Reusable buffers for the LSTM hot loop (no allocation per step).
#[derive(Default)]
pub struct LstmScratch {
    acc_x: Vec<i32>,
    acc_h: Vec<i32>,
    pack_buf: Vec<u8>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes_all_variants() {
        let cfg = DeepSpeechConfig::TINY;
        let frames = vec![0.1f32; cfg.time_steps * cfg.n_input];
        for v in Variant::PAPER_VARIANTS {
            let m = DeepSpeech::new(cfg, v, 7);
            let (out, times) = m.forward_timed(&frames);
            assert_eq!(out.len(), cfg.time_steps * cfg.n_output, "{v}");
            assert!(out.iter().all(|x| x.is_finite()), "{v}");
            assert_eq!(times.len(), 6);
            assert_eq!(times[3].0, "lstm");
        }
    }

    #[test]
    fn deterministic() {
        let cfg = DeepSpeechConfig::TINY;
        let frames: Vec<f32> = (0..cfg.time_steps * cfg.n_input)
            .map(|i| (i as f32 * 0.01).sin())
            .collect();
        let v = Variant::parse("w4a8").unwrap();
        let a = DeepSpeech::new(cfg, v, 7).forward_timed(&frames).0;
        let b = DeepSpeech::new(cfg, v, 7).forward_timed(&frames).0;
        assert_eq!(a, b);
    }

    #[test]
    fn footprint_shrinks_with_bits() {
        let cfg = DeepSpeechConfig::TINY;
        let f8 = DeepSpeech::new(cfg, Variant::parse("w8a8").unwrap(), 1).weight_footprint();
        let f4 = DeepSpeech::new(cfg, Variant::parse("w4a4").unwrap(), 1).weight_footprint();
        let f1 = DeepSpeech::new(cfg, Variant::parse("w1a1").unwrap(), 1).weight_footprint();
        assert!(f4 < f8 && f1 < f4);
    }

    #[test]
    fn lstm_step_matches_scalar_reference() {
        // cross-check the packed LSTM gates against a direct i32 GEMV
        let cfg = DeepSpeechConfig::TINY;
        let v = Variant::parse("w4a8").unwrap();
        let m = DeepSpeech::new(cfg, v, 3);
        let kp = m.lstm_wx.k_padded();
        let x_q = vec![1i8; kp];
        let h_q = vec![0i8; kp];
        let c = vec![0.0f32; cfg.n_hidden];
        let mut scratch = LstmScratch::default();
        let (h, c2) = m.lstm_step(&x_q, &h_q, &c, &mut scratch);
        // oracle for gate 0 lane 0
        let wx = m.lstm_wx.unpack_all();
        let acc: i32 = wx[..kp].iter().map(|&w| w as i32).sum();
        let gate0 = acc as f32 * (m.s_w * m.s_x) + m.lstm_bias[0];
        let sig = |v: f32| 1.0 / (1.0 + (-v).exp());
        let hdim = cfg.n_hidden;
        let accf = |r: usize| -> f32 {
            wx[r * kp..(r + 1) * kp].iter().map(|&w| w as i32).sum::<i32>() as f32
                * (m.s_w * m.s_x)
                + m.lstm_bias[r]
        };
        let c_expect = sig(accf(hdim)) * 0.0 + sig(gate0) * accf(2 * hdim).tanh();
        let h_expect = sig(accf(3 * hdim)) * c_expect.tanh();
        assert!((c2[0] - c_expect).abs() < 1e-4);
        assert!((h[0] - h_expect).abs() < 1e-4);
    }
}

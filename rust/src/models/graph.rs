//! `ModelGraph` — the model-layer IR (DESIGN.md §10): a typed, ordered
//! layer graph that [`crate::models::CompiledModel`] lowers onto the
//! existing per-layer `kernels::Plan` machinery.
//!
//! The IR exists so that "a new workload" is a graph constructor (or a
//! runtime-parsed manifest, `crate::runtime::manifest::parse_model_graph`)
//! instead of another hand-written model struct: every node declares
//! *what* it computes ([`Op`]), its shape, which quantization variant
//! its weights take ([`NodeVariant`]), and how it participates in
//! batching ([`BatchRole`] — the paper's §4.6 GEMV-vs-GEMM split made
//! explicit per node).  Node names are owned `String`s so graphs can be
//! assembled at runtime from manifests, not just from `&'static`
//! constructors.
//!
//! Weights are synthetic and deterministic (the DESIGN.md substitution
//! table): each node carries a `seed_offset` folded into the graph seed
//! by the same xorshift generator the legacy `DeepSpeech` model used, so
//! `CompiledModel` over [`crate::models::zoo::deepspeech_graph`] is
//! bit-identical to the legacy struct (pinned by
//! `rust/tests/model_graph.rs`).

#![warn(missing_docs)]

use crate::kernels::KernelError;
use crate::pack::Variant;

/// How a node participates in the engine's batching (paper §4.6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchRole {
    /// all columns of a flush execute as one batched call (the FC
    /// stack: one `GemmKernel::gemm` over `n·time_steps` columns)
    Batched,
    /// recurrent scan: per-request, per-step single-column GEMVs (the
    /// FullPack path — a recurrence cannot batch across time)
    Scan,
    /// weightless elementwise op over the whole activation stream
    Elementwise,
}

/// What one node computes.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// `z × k` fully-connected layer over every column, with an
    /// optionally fused `clamp(0, 20)` ReLU and a constant per-row bias.
    FullyConnected {
        /// fuse the legacy `clamp(0, 20)` ReLU after requantization
        relu: bool,
        /// constant bias added to every output row
        bias: f32,
    },
    /// LSTM cell scanned over `time_steps`: `z = 4·hidden` gate rows,
    /// `k` input depth, plus a `z × hidden` recurrent matrix.  Carries
    /// the legacy forget-gate-one bias.
    LstmCell,
    /// GRU cell scanned over `time_steps`: `z = 3·hidden` gate rows
    /// (reset, update, candidate), `k` input depth, plus a `z × hidden`
    /// recurrent matrix.  Zero bias.
    GruCell,
    /// standalone elementwise `clamp(0, max)` over the stream.
    Relu {
        /// upper clamp bound (the legacy fused ReLU uses 20.0)
        max: f32,
    },
}

impl Op {
    /// The node's batching role (paper §4.6 split, per node).
    pub fn role(&self) -> BatchRole {
        match self {
            Op::FullyConnected { .. } => BatchRole::Batched,
            Op::LstmCell | Op::GruCell => BatchRole::Scan,
            Op::Relu { .. } => BatchRole::Elementwise,
        }
    }

    /// Short op label (`fc`, `lstm`, `gru`, `relu`).
    pub fn label(&self) -> &'static str {
        match self {
            Op::FullyConnected { .. } => "fc",
            Op::LstmCell => "lstm",
            Op::GruCell => "gru",
            Op::Relu { .. } => "relu",
        }
    }
}

/// Which quantization variant a node's weights take.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeVariant {
    /// the graph-level variant (the model's sub-byte knob)
    Model,
    /// a pinned variant, e.g. the paper's W8A8 FC stack regardless of
    /// the model variant (§4.6 protocol)
    Fixed(Variant),
}

impl NodeVariant {
    /// Resolve against the graph-level variant.
    pub fn resolve(self, model: Variant) -> Variant {
        match self {
            NodeVariant::Model => model,
            NodeVariant::Fixed(v) => v,
        }
    }
}

/// One node of the layer graph.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    /// owned layer name (timing labels, metrics, manifests)
    pub name: String,
    /// what the node computes
    pub op: Op,
    /// output rows of the node's (input) weight matrix; gate dimension
    /// for cells (`4·hidden` LSTM, `3·hidden` GRU); stream width for
    /// weightless ops
    pub z: usize,
    /// input depth (the previous node's output width); equal to `z`
    /// for weightless ops
    pub k: usize,
    /// quantization of this node's weights/activations
    pub variant: NodeVariant,
    /// xorshift seed offset for synthetic weight generation (cells use
    /// `offset` for the input matrix and `offset + 1` for the
    /// recurrent one)
    pub seed_offset: u64,
}

impl Node {
    /// Hidden state width for cell nodes (`None` for non-recurrent ops).
    pub fn hidden(&self) -> Option<usize> {
        match self.op {
            Op::LstmCell => Some(self.z / 4),
            Op::GruCell => Some(self.z / 3),
            _ => None,
        }
    }

    /// Output stream width of this node.
    pub fn out_dim(&self) -> usize {
        match self.op {
            Op::FullyConnected { .. } => self.z,
            Op::LstmCell | Op::GruCell => self.hidden().unwrap_or(0),
            Op::Relu { .. } => self.z,
        }
    }
}

/// The model IR: an ordered layer graph plus the graph-level
/// quantization variant, shapes and scale constants the executor needs.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelGraph {
    /// model name (registry key, metrics label)
    pub name: String,
    /// graph-level quantization variant ([`NodeVariant::Model`] nodes)
    pub variant: Variant,
    /// per-frame input width
    pub input_dim: usize,
    /// columns per request (LSTM unroll length == FC batch; 1 for
    /// feed-forward classifiers)
    pub time_steps: usize,
    /// deterministic weight-generation seed
    pub seed: u64,
    /// per-tensor weight scale (legacy default 0.02)
    pub s_w: f32,
    /// activation scale (legacy default 0.05)
    pub s_act: f32,
    /// the ordered layer nodes
    pub nodes: Vec<Node>,
}

impl ModelGraph {
    /// Start an empty graph with the legacy scale defaults.
    pub fn new(
        name: impl Into<String>,
        variant: Variant,
        input_dim: usize,
        time_steps: usize,
        seed: u64,
    ) -> ModelGraph {
        ModelGraph {
            name: name.into(),
            variant,
            input_dim,
            time_steps: time_steps.max(1),
            seed,
            s_w: 0.02,
            s_act: 0.05,
            nodes: Vec::new(),
        }
    }

    /// Stream width entering the next appended node.
    pub fn cur_dim(&self) -> usize {
        self.nodes.last().map_or(self.input_dim, Node::out_dim)
    }

    /// Stream width leaving the last node (per column).
    pub fn output_dim(&self) -> usize {
        self.cur_dim()
    }

    /// f32 values per request at the input (`time_steps · input_dim`).
    pub fn input_len(&self) -> usize {
        self.time_steps * self.input_dim
    }

    /// f32 values per request at the output (`time_steps · output_dim`).
    pub fn output_len(&self) -> usize {
        self.time_steps * self.output_dim()
    }

    fn next_fc_offset(&self) -> u64 {
        self.nodes.len() as u64
    }

    fn next_cell_offset(&self) -> u64 {
        // the legacy DeepSpeech constructor seeded its (single) cell at
        // seed+100/seed+101; additional cells stack above that
        let cells = self
            .nodes
            .iter()
            .filter(|n| n.op.role() == BatchRole::Scan)
            .count() as u64;
        100 + 2 * cells
    }

    /// Append a fully-connected node on the graph-level variant.
    pub fn fc(self, name: impl Into<String>, z: usize, relu: bool) -> ModelGraph {
        self.fc_node(name, z, relu, NodeVariant::Model)
    }

    /// Append a fully-connected node with a pinned variant (the paper's
    /// W8A8 FC stack).
    pub fn fc_fixed(
        self,
        name: impl Into<String>,
        z: usize,
        relu: bool,
        v: Variant,
    ) -> ModelGraph {
        self.fc_node(name, z, relu, NodeVariant::Fixed(v))
    }

    fn fc_node(
        mut self,
        name: impl Into<String>,
        z: usize,
        relu: bool,
        variant: NodeVariant,
    ) -> ModelGraph {
        let k = self.cur_dim();
        let seed_offset = self.next_fc_offset();
        self.nodes.push(Node {
            name: name.into(),
            op: Op::FullyConnected { relu, bias: 0.01 },
            z,
            k,
            variant,
            seed_offset,
        });
        self
    }

    /// Append an LSTM cell of the given hidden width.
    pub fn lstm(mut self, name: impl Into<String>, hidden: usize) -> ModelGraph {
        let k = self.cur_dim();
        let seed_offset = self.next_cell_offset();
        self.nodes.push(Node {
            name: name.into(),
            op: Op::LstmCell,
            z: 4 * hidden,
            k,
            variant: NodeVariant::Model,
            seed_offset,
        });
        self
    }

    /// Append a GRU cell of the given hidden width.
    pub fn gru(mut self, name: impl Into<String>, hidden: usize) -> ModelGraph {
        let k = self.cur_dim();
        let seed_offset = self.next_cell_offset();
        self.nodes.push(Node {
            name: name.into(),
            op: Op::GruCell,
            z: 3 * hidden,
            k,
            variant: NodeVariant::Model,
            seed_offset,
        });
        self
    }

    /// Append a standalone elementwise `clamp(0, max)` node.
    pub fn relu(mut self, name: impl Into<String>, max: f32) -> ModelGraph {
        let d = self.cur_dim();
        self.nodes.push(Node {
            name: name.into(),
            op: Op::Relu { max },
            z: d,
            k: d,
            variant: NodeVariant::Model,
            seed_offset: 0,
        });
        self
    }

    /// Does any FC node quantize on the graph-level (sub-byte) variant?
    /// (Decides whether a whole-model FullPack comparison also swaps
    /// the FC method, or keeps the paper's Ruy FC protocol.)
    pub fn has_model_variant_fc(&self) -> bool {
        self.nodes.iter().any(|n| {
            matches!(n.op, Op::FullyConnected { .. }) && n.variant == NodeVariant::Model
        })
    }

    /// Structural validation: positive shapes, chained dimensions,
    /// divisible gate widths, unique names, at least one node.
    pub fn validate(&self) -> Result<(), KernelError> {
        let err = |m: String| Err(KernelError::Shape(m));
        if self.nodes.is_empty() {
            return err(format!("model graph {:?} has no nodes", self.name));
        }
        if self.input_dim == 0 {
            return err(format!("model graph {:?} has input_dim 0", self.name));
        }
        let mut dim = self.input_dim;
        let mut seen = std::collections::HashSet::new();
        for n in &self.nodes {
            if !seen.insert(n.name.as_str()) {
                return err(format!("duplicate node name {:?}", n.name));
            }
            if n.z == 0 || n.k == 0 {
                return err(format!("node {:?} has a zero dimension", n.name));
            }
            if n.k != dim {
                return err(format!(
                    "node {:?} expects depth {} but the stream is {dim} wide",
                    n.name, n.k
                ));
            }
            match n.op {
                Op::LstmCell if n.z % 4 != 0 => {
                    return err(format!("LSTM node {:?}: z={} not divisible by 4", n.name, n.z))
                }
                Op::GruCell if n.z % 3 != 0 => {
                    return err(format!("GRU node {:?}: z={} not divisible by 3", n.name, n.z))
                }
                Op::Relu { max } if !(max > 0.0) => {
                    return err(format!("relu node {:?}: non-positive max {max}", n.name))
                }
                _ => {}
            }
            dim = n.out_dim();
        }
        Ok(())
    }

    /// One-line human description.
    pub fn describe(&self) -> String {
        format!(
            "{} {} (input {}, T {}, {} nodes -> {})",
            self.name,
            self.variant,
            self.input_dim,
            self.time_steps,
            self.nodes.len(),
            self.output_dim()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &str) -> Variant {
        Variant::parse(s).unwrap()
    }

    #[test]
    fn builder_chains_dims_and_offsets() {
        let g = ModelGraph::new("m", v("w4a8"), 64, 4, 7)
            .fc("fc1", 128, true)
            .lstm("cell", 128)
            .fc("out", 10, false);
        assert_eq!(g.nodes.len(), 3);
        assert_eq!(g.nodes[0].k, 64);
        assert_eq!(g.nodes[1].z, 512);
        assert_eq!(g.nodes[1].k, 128);
        assert_eq!(g.nodes[1].hidden(), Some(128));
        assert_eq!(g.nodes[2].k, 128);
        assert_eq!(g.output_dim(), 10);
        assert_eq!(g.input_len(), 4 * 64);
        assert_eq!(g.output_len(), 4 * 10);
        // fc offsets = node index, first cell at 100 (legacy seeds)
        assert_eq!(g.nodes[0].seed_offset, 0);
        assert_eq!(g.nodes[1].seed_offset, 100);
        assert_eq!(g.nodes[2].seed_offset, 2);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn roles_per_node() {
        let g = ModelGraph::new("m", v("w4a8"), 8, 1, 7)
            .fc("a", 8, false)
            .relu("r", 20.0)
            .gru("g", 4);
        assert_eq!(g.nodes[0].op.role(), BatchRole::Batched);
        assert_eq!(g.nodes[1].op.role(), BatchRole::Elementwise);
        assert_eq!(g.nodes[2].op.role(), BatchRole::Scan);
        assert_eq!(g.nodes[2].z, 12);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn validation_rejects_bad_graphs() {
        let empty = ModelGraph::new("m", v("w4a8"), 8, 1, 7);
        assert!(empty.validate().is_err());
        // broken chain: hand-built node with the wrong depth
        let mut g = ModelGraph::new("m", v("w4a8"), 8, 1, 7).fc("a", 16, false);
        g.nodes.push(Node {
            name: "b".into(),
            op: Op::FullyConnected { relu: false, bias: 0.0 },
            z: 4,
            k: 99,
            variant: NodeVariant::Model,
            seed_offset: 1,
        });
        assert!(g.validate().is_err());
        // duplicate names
        let g = ModelGraph::new("m", v("w4a8"), 8, 1, 7).fc("a", 8, false).fc("a", 8, false);
        assert!(g.validate().is_err());
        // non-divisible gate width
        let mut g = ModelGraph::new("m", v("w4a8"), 8, 1, 7);
        g.nodes.push(Node {
            name: "l".into(),
            op: Op::LstmCell,
            z: 10,
            k: 8,
            variant: NodeVariant::Model,
            seed_offset: 100,
        });
        assert!(g.validate().is_err());
    }

    #[test]
    fn fixed_vs_model_variant_resolution() {
        let w8 = v("w8a8");
        let g = ModelGraph::new("m", v("w2a8"), 8, 2, 7)
            .fc_fixed("fc", 8, false, w8)
            .fc("sub", 8, false);
        assert_eq!(g.nodes[0].variant.resolve(g.variant), w8);
        assert_eq!(g.nodes[1].variant.resolve(g.variant), v("w2a8"));
        assert!(g.has_model_variant_fc());
        let g2 = ModelGraph::new("m", v("w2a8"), 8, 2, 7).fc_fixed("fc", 8, false, w8);
        assert!(!g2.has_model_variant_fc());
    }
}

//! Symmetric quantization f32 ↔ signed b-bit, per-tensor and per-row.
//!
//! This is the substrate the paper assumes ("prior art has demonstrated
//! negligible accuracy drop in sub-byte quantization", §1): it produces
//! the integer operands the FullPack kernels consume and the scales the
//! requantization pipeline applies to the int32 accumulators.

use crate::pack::BitWidth;

/// A quantized tensor: int8-held values (range limited by `bits`) plus a
/// symmetric scale such that `f32 ≈ q * scale`.
#[derive(Debug, Clone)]
pub struct Quantized {
    pub values: Vec<i8>,
    pub scale: f32,
    pub bits: BitWidth,
}

/// Symmetric per-tensor quantization: `scale = max|x| / qmax`,
/// `q = clamp(round(x / scale))`.
///
/// For `B1` the domain is {-1, 0} (the two's-complement 1-bit range the
/// FullPack ASR sign-extension realizes): negative values map to -1,
/// non-negative to 0, with `scale = max|x|`.
pub fn quantize(x: &[f32], bits: BitWidth) -> Quantized {
    let max_abs = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
    if bits == BitWidth::B1 {
        let scale = if max_abs > 0.0 { max_abs } else { 1.0 };
        let values = x.iter().map(|&v| if v < 0.0 { -1i8 } else { 0i8 }).collect();
        return Quantized { values, scale, bits };
    }
    let (lo, hi) = bits.value_range();
    let qmax = hi as f32;
    let scale = if max_abs > 0.0 { max_abs / qmax } else { 1.0 };
    let values = x
        .iter()
        .map(|&v| (v / scale).round().clamp(lo as f32, hi as f32) as i8)
        .collect();
    Quantized { values, scale, bits }
}

/// Quantize a row-major matrix with one scale per row (per-channel
/// weight quantization, the standard for FC layers).
pub fn quantize_per_row(w: &[f32], rows: usize, k: usize, bits: BitWidth) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(w.len(), rows * k);
    let mut values = Vec::with_capacity(rows * k);
    let mut scales = Vec::with_capacity(rows);
    for r in 0..rows {
        let q = quantize(&w[r * k..(r + 1) * k], bits);
        values.extend(q.values);
        scales.push(q.scale);
    }
    (values, scales)
}

/// Dequantize int8-held values back to f32.
pub fn dequantize(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| v as f32 * scale).collect()
}

/// Requantize an int32 GEMV accumulator to f32: `acc * (s_w * s_a) + bias`.
#[inline]
pub fn requantize(acc: i32, s_w: f32, s_a: f32, bias: f32) -> f32 {
    acc as f32 * (s_w * s_a) + bias
}

/// Apply [`requantize`] across a whole output vector.
pub fn requantize_vec(acc: &[i32], s_w: f32, s_a: f32, bias: &[f32]) -> Vec<f32> {
    debug_assert_eq!(acc.len(), bias.len());
    acc.iter()
        .zip(bias)
        .map(|(&a, &b)| requantize(a, s_w, s_a, b))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let x: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.13).collect();
        for bits in [BitWidth::B8, BitWidth::B4, BitWidth::B2] {
            let q = quantize(&x, bits);
            let deq = dequantize(&q.values, q.scale);
            let max_err = x
                .iter()
                .zip(&deq)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            // symmetric quantizer error <= scale/2 (clamp only at |max|)
            assert!(max_err <= q.scale * 0.5 + 1e-6, "{bits:?}: {max_err}");
        }
    }

    #[test]
    fn values_in_range() {
        let x: Vec<f32> = (0..64).map(|i| (i as f32) - 32.0).collect();
        for bits in [BitWidth::B8, BitWidth::B4, BitWidth::B2, BitWidth::B1] {
            let q = quantize(&x, bits);
            let (lo, hi) = bits.value_range();
            assert!(q.values.iter().all(|&v| v >= lo && v <= hi));
        }
    }

    #[test]
    fn one_bit_sign_semantics() {
        let q = quantize(&[-3.0, -0.1, 0.0, 2.0], BitWidth::B1);
        assert_eq!(q.values, vec![-1, -1, 0, 0]);
    }

    #[test]
    fn zero_input_unit_scale() {
        let q = quantize(&[0.0; 8], BitWidth::B4);
        assert_eq!(q.scale, 1.0);
        assert!(q.values.iter().all(|&v| v == 0));
    }

    #[test]
    fn per_row_scales_independent() {
        let w = [1.0f32, -1.0, 100.0, -100.0];
        let (vals, scales) = quantize_per_row(&w, 2, 2, BitWidth::B4);
        assert_eq!(vals.len(), 4);
        assert!(scales[1] > scales[0] * 50.0);
    }

    #[test]
    fn requantize_identity() {
        assert_eq!(requantize(10, 0.5, 2.0, 1.0), 11.0);
        let out = requantize_vec(&[1, 2], 1.0, 1.0, &[0.5, 0.5]);
        assert_eq!(out, vec![1.5, 2.5]);
    }
}

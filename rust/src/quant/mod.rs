//! Symmetric quantization f32 ↔ signed b-bit, per-tensor and per-row.
//!
//! This is the substrate the paper assumes ("prior art has demonstrated
//! negligible accuracy drop in sub-byte quantization", §1): it produces
//! the integer operands the FullPack kernels consume and the scales the
//! requantization pipeline applies to the int32 accumulators.

use crate::pack::BitWidth;

/// A quantized tensor: int8-held values (range limited by `bits`) plus a
/// symmetric scale such that `f32 ≈ q * scale`.
#[derive(Debug, Clone)]
pub struct Quantized {
    pub values: Vec<i8>,
    pub scale: f32,
    pub bits: BitWidth,
}

/// Symmetric per-tensor quantization: `scale = max|x| / qmax`,
/// `q = clamp(round(x / scale))`.
///
/// For `B1` the domain is {-1, 0} (the two's-complement 1-bit range the
/// FullPack ASR sign-extension realizes): negative values map to -1,
/// non-negative to 0, with `scale = max|x|`.
pub fn quantize(x: &[f32], bits: BitWidth) -> Quantized {
    let max_abs = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
    if bits == BitWidth::B1 {
        let scale = if max_abs > 0.0 { max_abs } else { 1.0 };
        let values = x.iter().map(|&v| if v < 0.0 { -1i8 } else { 0i8 }).collect();
        return Quantized { values, scale, bits };
    }
    let (lo, hi) = bits.value_range();
    let qmax = hi as f32;
    let scale = if max_abs > 0.0 { max_abs / qmax } else { 1.0 };
    let values = x
        .iter()
        .map(|&v| (v / scale).round().clamp(lo as f32, hi as f32) as i8)
        .collect();
    Quantized { values, scale, bits }
}

/// Quantize a row-major matrix with one scale per row (per-channel
/// weight quantization, the standard for FC layers).
pub fn quantize_per_row(w: &[f32], rows: usize, k: usize, bits: BitWidth) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(w.len(), rows * k);
    let mut values = Vec::with_capacity(rows * k);
    let mut scales = Vec::with_capacity(rows);
    for r in 0..rows {
        let q = quantize(&w[r * k..(r + 1) * k], bits);
        values.extend(q.values);
        scales.push(q.scale);
    }
    (values, scales)
}

/// Dequantize int8-held values back to f32.
pub fn dequantize(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| v as f32 * scale).collect()
}

/// Requantize an int32 GEMV accumulator to f32: `acc * (s_w * s_a) + bias`.
#[inline]
pub fn requantize(acc: i32, s_w: f32, s_a: f32, bias: f32) -> f32 {
    acc as f32 * (s_w * s_a) + bias
}

/// Apply [`requantize`] across a whole output vector.
pub fn requantize_vec(acc: &[i32], s_w: f32, s_a: f32, bias: &[f32]) -> Vec<f32> {
    // hard assert (like `requantize_rows`): a short bias would
    // otherwise silently truncate the output through the zip below
    assert_eq!(acc.len(), bias.len(), "bias len {} != acc len {}", bias.len(), acc.len());
    acc.iter()
        .zip(bias)
        .map(|(&a, &b)| requantize(a, s_w, s_a, b))
        .collect()
}

/// Per-row (per-output-channel) requantization: row `r` of the
/// accumulator uses its own weight scale — the scales
/// [`quantize_per_row`] produces, which [`requantize_vec`]'s single
/// `s_w` cannot apply.
///
/// `acc` holds one output column (`acc.len() == s_w_rows.len()`) or a
/// batch-major stack of columns (`acc.len() == batch · rows`, column
/// `c` at `acc[c·rows..(c+1)·rows]` — the layout `GemmKernel::gemm`
/// writes); `bias` is per row and added to every column.
pub fn requantize_rows(acc: &[i32], s_w_rows: &[f32], s_a: f32, bias: &[f32]) -> Vec<f32> {
    let rows = s_w_rows.len();
    assert!(rows > 0, "need at least one row scale");
    assert!(
        acc.len() % rows == 0,
        "acc len {} is not a whole number of {rows}-row columns",
        acc.len()
    );
    // hard assert: a short bias would otherwise silently truncate
    // every column through the zip below
    assert_eq!(bias.len(), rows, "bias len {} != rows {rows}", bias.len());
    acc.chunks_exact(rows)
        .flat_map(|col| {
            col.iter()
                .zip(s_w_rows)
                .zip(bias)
                .map(|((&a, &s_w), &b)| requantize(a, s_w, s_a, b))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let x: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.13).collect();
        for bits in [BitWidth::B8, BitWidth::B4, BitWidth::B2] {
            let q = quantize(&x, bits);
            let deq = dequantize(&q.values, q.scale);
            let max_err = x
                .iter()
                .zip(&deq)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            // symmetric quantizer error <= scale/2 (clamp only at |max|)
            assert!(max_err <= q.scale * 0.5 + 1e-6, "{bits:?}: {max_err}");
        }
    }

    #[test]
    fn values_in_range() {
        let x: Vec<f32> = (0..64).map(|i| (i as f32) - 32.0).collect();
        for bits in [BitWidth::B8, BitWidth::B4, BitWidth::B2, BitWidth::B1] {
            let q = quantize(&x, bits);
            let (lo, hi) = bits.value_range();
            assert!(q.values.iter().all(|&v| v >= lo && v <= hi));
        }
    }

    #[test]
    fn one_bit_sign_semantics() {
        let q = quantize(&[-3.0, -0.1, 0.0, 2.0], BitWidth::B1);
        assert_eq!(q.values, vec![-1, -1, 0, 0]);
    }

    #[test]
    fn zero_input_unit_scale() {
        let q = quantize(&[0.0; 8], BitWidth::B4);
        assert_eq!(q.scale, 1.0);
        assert!(q.values.iter().all(|&v| v == 0));
    }

    #[test]
    fn per_row_scales_independent() {
        let w = [1.0f32, -1.0, 100.0, -100.0];
        let (vals, scales) = quantize_per_row(&w, 2, 2, BitWidth::B4);
        assert_eq!(vals.len(), 4);
        assert!(scales[1] > scales[0] * 50.0);
    }

    #[test]
    fn requantize_identity() {
        assert_eq!(requantize(10, 0.5, 2.0, 1.0), 11.0);
        let out = requantize_vec(&[1, 2], 1.0, 1.0, &[0.5, 0.5]);
        assert_eq!(out, vec![1.5, 2.5]);
    }

    #[test]
    fn requantize_rows_applies_each_rows_scale() {
        // one column: row r scaled by its own s_w
        let out = requantize_rows(&[10, 10, 10], &[0.1, 1.0, 10.0], 2.0, &[0.0, 0.5, 0.0]);
        assert_eq!(out, vec![2.0, 20.5, 200.0]);
        // uniform row scales degenerate to the per-tensor path exactly
        let acc = [3, -7, 40];
        let bias = [0.25, -1.0, 2.0];
        assert_eq!(
            requantize_rows(&acc, &[0.3; 3], 0.7, &bias),
            requantize_vec(&acc, 0.3, 0.7, &bias)
        );
    }

    #[test]
    fn requantize_rows_batch_major_columns() {
        // two columns, batch-major (the GemmKernel output layout):
        // bias and row scales repeat per column
        let acc = [1, 2, 10, 20];
        let out = requantize_rows(&acc, &[1.0, 0.5], 1.0, &[0.0, 1.0]);
        assert_eq!(out, vec![1.0, 2.0, 10.0, 11.0]);
    }

    #[test]
    #[should_panic(expected = "bias len")]
    fn requantize_vec_rejects_short_bias() {
        // regression: the guard was a debug_assert, so release builds
        // silently truncated the output vector to the bias length
        let _ = requantize_vec(&[1, 2, 3], 1.0, 1.0, &[0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "whole number")]
    fn requantize_rows_rejects_ragged_columns() {
        let _ = requantize_rows(&[1, 2, 3], &[1.0, 1.0], 1.0, &[0.0, 0.0]);
    }

    #[test]
    fn per_row_pipeline_recovers_f32_gemv() {
        // quantize_per_row -> integer GEMV -> requantize_rows tracks the
        // f32 product within the quantizer's error bound; a single
        // per-tensor scale cannot (rows differ by 100x)
        let (rows, k) = (3usize, 16usize);
        let mut w = vec![0f32; rows * k];
        for r in 0..rows {
            let mag = [0.01f32, 1.0, 100.0][r];
            for c in 0..k {
                w[r * k + c] = mag * ((c as f32 * 0.37).sin());
            }
        }
        let a: Vec<f32> = (0..k).map(|i| (i as f32 * 0.21).cos()).collect();
        let qa = quantize(&a, BitWidth::B8);
        let (qw, s_rows) = quantize_per_row(&w, rows, k, BitWidth::B4);
        let acc: Vec<i32> = (0..rows)
            .map(|r| {
                qw[r * k..(r + 1) * k]
                    .iter()
                    .zip(&qa.values)
                    .map(|(&wv, &av)| wv as i32 * av as i32)
                    .sum()
            })
            .collect();
        let got = requantize_rows(&acc, &s_rows, qa.scale, &[0.0; 3]);
        for r in 0..rows {
            let expect: f32 = w[r * k..(r + 1) * k].iter().zip(&a).map(|(x, y)| x * y).sum();
            let tol = 0.2 * expect.abs().max(s_rows[r] * k as f32);
            assert!((got[r] - expect).abs() < tol, "row {r}: {} vs {expect}", got[r]);
        }
    }
}

//! Host tensors crossing the PJRT boundary, with Literal marshalling.

use super::manifest::{DType, TensorSpec};
use crate::util::error::{anyhow, bail, Result};

/// A host-side tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: TensorData,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    S8(Vec<i8>),
    U8(Vec<u8>),
    S32(Vec<i32>),
    F32(Vec<f32>),
}

impl Tensor {
    pub fn s8(data: Vec<i8>, shape: Vec<usize>) -> Tensor {
        Tensor { data: TensorData::S8(data), shape }
    }

    pub fn u8(data: Vec<u8>, shape: Vec<usize>) -> Tensor {
        Tensor { data: TensorData::U8(data), shape }
    }

    pub fn s32(data: Vec<i32>, shape: Vec<usize>) -> Tensor {
        Tensor { data: TensorData::S32(data), shape }
    }

    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> Tensor {
        Tensor { data: TensorData::F32(data), shape }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(vec![v], vec![])
    }

    pub fn dtype(&self) -> DType {
        match &self.data {
            TensorData::S8(_) => DType::S8,
            TensorData::U8(_) => DType::U8,
            TensorData::S32(_) => DType::S32,
            TensorData::F32(_) => DType::F32,
        }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            TensorData::S8(v) => v.len(),
            TensorData::U8(v) => v.len(),
            TensorData::S32(v) => v.len(),
            TensorData::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, got {:?}", dt(other)),
        }
    }

    pub fn as_s32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::S32(v) => Ok(v),
            other => bail!("expected s32 tensor, got {:?}", dt(other)),
        }
    }

    pub fn as_s8(&self) -> Result<&[i8]> {
        match &self.data {
            TensorData::S8(v) => Ok(v),
            other => bail!("expected s8 tensor, got {:?}", dt(other)),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match &self.data {
            TensorData::U8(v) => Ok(v),
            other => bail!("expected u8 tensor, got {:?}", dt(other)),
        }
    }

    /// Validate against a manifest spec.
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.dtype() != spec.dtype {
            bail!("dtype {} != manifest {}", self.dtype().name(), spec.dtype.name());
        }
        if self.shape != spec.shape {
            bail!("shape {:?} != manifest {:?}", self.shape, spec.shape);
        }
        if self.len() != spec.elems() {
            bail!("element count {} != shape product {}", self.len(), spec.elems());
        }
        Ok(())
    }

    /// To an XLA literal with the tensor's dims.  Built from raw bytes
    /// (the crate's `NativeType` path has no i8/u8 support).
    pub fn to_literal(&self) -> xla::Literal {
        let (ty, bytes): (xla::ElementType, &[u8]) = match &self.data {
            TensorData::S8(v) => (xla::ElementType::S8, unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len())
            }),
            TensorData::U8(v) => (xla::ElementType::U8, v.as_slice()),
            TensorData::S32(v) => (xla::ElementType::S32, unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            }),
            TensorData::F32(v) => (xla::ElementType::F32, unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            }),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, &self.shape, bytes)
            .expect("literal from host bytes")
    }

    /// From an XLA literal; `spec` (when available) provides the shape
    /// (literals flatten fine with `to_vec`).
    pub fn from_literal(lit: &xla::Literal, spec: Option<&TensorSpec>) -> Result<Tensor> {
        let ty = lit.ty().map_err(|e| anyhow!("literal dtype: {e}"))?;
        let shape = match spec {
            Some(s) => s.shape.clone(),
            None => vec![lit.element_count()],
        };
        Ok(match ty {
            xla::ElementType::S8 => {
                Tensor::s8(lit.to_vec::<i8>().map_err(|e| anyhow!("{e}"))?, shape)
            }
            xla::ElementType::U8 => {
                Tensor::u8(lit.to_vec::<u8>().map_err(|e| anyhow!("{e}"))?, shape)
            }
            xla::ElementType::S32 => {
                Tensor::s32(lit.to_vec::<i32>().map_err(|e| anyhow!("{e}"))?, shape)
            }
            xla::ElementType::F32 => {
                Tensor::f32(lit.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?, shape)
            }
            other => bail!("unsupported literal dtype {other:?}"),
        })
    }
}

fn dt(d: &TensorData) -> &'static str {
    match d {
        TensorData::S8(_) => "s8",
        TensorData::U8(_) => "u8",
        TensorData::S32(_) => "s32",
        TensorData::F32(_) => "f32",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_accessors() {
        let t = Tensor::s8(vec![1, -2], vec![2]);
        assert_eq!(t.dtype(), DType::S8);
        assert_eq!(t.len(), 2);
        assert_eq!(t.as_s8().unwrap(), &[1, -2]);
        assert!(t.as_f32().is_err());
        assert!(!t.is_empty());
    }

    #[test]
    fn check_against_spec() {
        let spec = TensorSpec { name: "w".into(), dtype: DType::U8, shape: vec![2, 3] };
        let ok = Tensor::u8(vec![0; 6], vec![2, 3]);
        assert!(ok.check(&spec).is_ok());
        let bad_dtype = Tensor::s8(vec![0; 6], vec![2, 3]);
        assert!(bad_dtype.check(&spec).is_err());
        let bad_shape = Tensor::u8(vec![0; 6], vec![3, 2]);
        assert!(bad_shape.check(&spec).is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        let lit = t.to_literal();
        assert_eq!(lit.element_count(), 4);
        let spec = TensorSpec { name: "x".into(), dtype: DType::F32, shape: vec![2, 2] };
        let back = Tensor::from_literal(&lit, Some(&spec)).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_integers() {
        for t in [
            Tensor::s8(vec![-8, 7, 0], vec![3]),
            Tensor::u8(vec![0, 255, 16], vec![3]),
            Tensor::s32(vec![i32::MIN, 0, i32::MAX], vec![3]),
        ] {
            let back = Tensor::from_literal(&t.to_literal(), None).unwrap();
            assert_eq!(back.data, t.data);
        }
    }

    #[test]
    fn scalar_literal() {
        let t = Tensor::scalar_f32(0.5);
        let lit = t.to_literal();
        assert_eq!(lit.element_count(), 1);
    }
}

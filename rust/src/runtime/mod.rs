//! AOT artifact runtime.  The dependency-free half — the
//! [`manifest`] parser, including the `ModelGraph`-from-manifest path
//! ([`manifest::parse_model_graph`]) — is always built; the PJRT
//! executor below (load `artifacts/*.hlo.txt`, AOT-lowered by
//! `python/compile/aot.py`, compile once on the XLA CPU client, and
//! execute from the L3 hot path) needs the heavyweight `xla` bindings
//! and is gated behind the `pjrt` feature.
//!
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos — see /opt/xla-example/README.md); the
//! text parser reassigns instruction ids and round-trips cleanly.
//!
//! PJRT objects are not `Send`, so [`Runtime`] is single-threaded; the
//! serving engine talks to it through [`handle::RuntimeHandle`], a
//! channel-backed executor thread (`spawn`), which is also the natural
//! device-thread isolation for a serving system.

#[cfg(feature = "pjrt")]
pub mod handle;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod tensor;

#[cfg(feature = "pjrt")]
pub use handle::{spawn, RuntimeHandle};
pub use manifest::{ArtifactMeta, DType, Manifest, TensorSpec};
#[cfg(feature = "pjrt")]
pub use tensor::Tensor;

#[cfg(feature = "pjrt")]
use crate::util::error::{anyhow, bail, Context, Result};
#[cfg(feature = "pjrt")]
use std::cell::RefCell;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::{Path, PathBuf};
#[cfg(feature = "pjrt")]
use std::rc::Rc;

/// Single-threaded PJRT runtime: manifest + lazily compiled executables.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Load the manifest from an artifacts directory (does not compile
    /// anything yet).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Runtime { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with host tensors, validating shapes/dtypes
    /// against the manifest.  Returns the output tensors (the lowered
    /// modules always return a tuple — `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
        if inputs.len() != meta.inputs.len() {
            bail!(
                "{name}: expected {} inputs ({}), got {}",
                meta.inputs.len(),
                meta.inputs.iter().map(|i| i.name.as_str()).collect::<Vec<_>>().join(", "),
                inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(&meta.inputs) {
            t.check(spec).with_context(|| format!("{name}: input {:?}", spec.name))?;
        }
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs.iter().map(Tensor::to_literal).collect();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e}"))?;
        let parts = out.to_tuple().map_err(|e| anyhow!("untupling {name}: {e}"))?;
        let mut tensors = Vec::with_capacity(parts.len());
        for (i, lit) in parts.into_iter().enumerate() {
            let spec = meta.outputs.get(i);
            tensors.push(Tensor::from_literal(&lit, spec)?);
        }
        Ok(tensors)
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn load_manifest_and_compile_one() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let rt = Runtime::load(&dir).unwrap();
        assert!(rt.manifest().artifacts.len() >= 30);
        assert_eq!(rt.platform().to_lowercase(), "cpu");
        let exe = rt.executable("gemv_w8a8_256x256").unwrap();
        drop(exe);
        // second fetch hits the cache
        let _ = rt.executable("gemv_w8a8_256x256").unwrap();
        assert_eq!(rt.cache.borrow().len(), 1);
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let rt = Runtime::load(&dir).unwrap();
        assert!(rt.executable("nope").is_err());
        assert!(rt.execute("nope", &[]).is_err());
    }
}

//! Channel-backed executor thread for the (non-`Send`) PJRT runtime.
//!
//! The serving engine's workers hold a cloneable [`RuntimeHandle`];
//! execution requests are serialized onto the device thread — the same
//! isolation a production engine uses for an accelerator context.

use super::{Runtime, Tensor};
use crate::util::error::{anyhow, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

enum Job {
    Execute {
        artifact: String,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<Result<Vec<Tensor>>>,
    },
    Warmup {
        artifact: String,
        reply: mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

/// Cloneable, thread-safe handle to the runtime executor thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Arc<Mutex<mpsc::Sender<Job>>>,
}

impl RuntimeHandle {
    /// Execute an artifact synchronously (the call blocks until the
    /// device thread replies).
    pub fn execute(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::Execute { artifact: artifact.to_string(), inputs, reply })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread dropped reply"))?
    }

    /// Pre-compile an artifact (populate the executable cache).
    pub fn warmup(&self, artifact: &str) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::Warmup { artifact: artifact.to_string(), reply })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread dropped reply"))?
    }

    /// Ask the executor thread to exit (best effort).
    pub fn shutdown(&self) {
        let _ = self.tx.lock().unwrap().send(Job::Shutdown);
    }
}

/// Spawn the executor thread.  Loads the manifest on the device thread;
/// returns the handle plus manifest metadata for the caller.
pub fn spawn(artifacts_dir: impl Into<PathBuf>) -> Result<(RuntimeHandle, super::Manifest)> {
    let dir = artifacts_dir.into();
    let (tx, rx) = mpsc::channel::<Job>();
    let (boot_tx, boot_rx) = mpsc::channel::<Result<super::Manifest>>();
    std::thread::Builder::new()
        .name("pjrt-executor".into())
        .spawn(move || {
            let rt = match Runtime::load(&dir) {
                Ok(rt) => {
                    let _ = boot_tx.send(Ok(rt.manifest().clone()));
                    rt
                }
                Err(e) => {
                    let _ = boot_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(job) = rx.recv() {
                match job {
                    Job::Execute { artifact, inputs, reply } => {
                        let _ = reply.send(rt.execute(&artifact, &inputs));
                    }
                    Job::Warmup { artifact, reply } => {
                        let _ = reply.send(rt.executable(&artifact).map(|_| ()));
                    }
                    Job::Shutdown => break,
                }
            }
        })
        .map_err(|e| anyhow!("spawning pjrt-executor: {e}"))?;
    let manifest = boot_rx
        .recv()
        .map_err(|_| anyhow!("runtime thread died during boot"))??;
    Ok((RuntimeHandle { tx: Arc::new(Mutex::new(tx)) }, manifest))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn handle_is_send_and_clone() {
        fn assert_send<T: Send + Clone>() {}
        assert_send::<RuntimeHandle>();
    }

    #[test]
    fn boot_failure_reported() {
        assert!(spawn("/definitely/not/a/dir").is_err());
    }

    #[test]
    fn execute_via_handle_from_another_thread() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let (h, manifest) = spawn(dir).unwrap();
        assert!(manifest.get("gemv_w8a8_256x256").is_some());
        let h2 = h.clone();
        let t = std::thread::spawn(move || {
            let w = Tensor::s8(vec![1i8; 256 * 256], vec![256, 256]);
            let a = Tensor::s8(vec![1i8; 256], vec![256]);
            h2.execute("gemv_w8a8_256x256", vec![w, a])
        });
        let out = t.join().unwrap().unwrap();
        assert_eq!(out[0].as_s32().unwrap(), vec![256i32; 256].as_slice());
        h.shutdown();
    }
}

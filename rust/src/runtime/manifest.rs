//! Artifact manifest (`artifacts/manifest.json`) — written by
//! `python/compile/aot.py`, read here with the in-repo JSON parser —
//! plus the **model manifest** path ([`parse_model_graph`]): a JSON
//! layer-graph description parsed straight into a
//! [`crate::models::ModelGraph`], so serving topologies can be declared
//! at runtime instead of compiled in (the reason layer names are owned
//! strings).
//!
//! Model manifest schema (depths chain automatically from `input_dim`):
//!
//! ```json
//! {"model": "custom-kws", "variant": "w2a8", "input_dim": 40,
//!  "time_steps": 4, "seed": 7,
//!  "layers": [
//!    {"name": "fc1", "op": "fc", "z": 128, "relu": true, "variant": "w8a8"},
//!    {"name": "gru", "op": "gru", "hidden": 64},
//!    {"name": "act", "op": "relu", "max": 20},
//!    {"name": "out", "op": "fc", "z": 12}
//!  ]}
//! ```
//!
//! An `fc` layer without a `"variant"` key quantizes on the model-level
//! variant (the sub-byte knob); `"relu"` defaults to false.

use crate::models::{ModelGraph, ModelSize, ModelRegistry};
use crate::pack::Variant;
use crate::util::json::Json;
use crate::util::error::{anyhow, bail, Result};
use std::collections::HashMap;

/// Element dtype of a tensor crossing the AOT boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    S8,
    U8,
    S32,
    F32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "s8" => DType::S8,
            "u8" => DType::U8,
            "s32" => DType::S32,
            "f32" => DType::F32,
            other => bail!("unknown dtype {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::S8 => "s8",
            DType::U8 => "u8",
            DType::S32 => "s32",
            DType::F32 => "f32",
        }
    }
}

/// Shape+dtype of one artifact input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub variant: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// free-form integer metadata (z, k, row_tile, hidden, ...)
    pub meta: HashMap<String, i64>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: usize,
    pub vl: usize,
    pub artifacts: Vec<ArtifactMeta>,
    index: HashMap<String, usize>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let version = j.get("version").and_then(Json::as_usize).unwrap_or(0);
        let vl = j.get("vl").and_then(Json::as_usize).unwrap_or(16);
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts[]"))?
        {
            artifacts.push(parse_artifact(a)?);
        }
        let index = artifacts
            .iter()
            .enumerate()
            .map(|(i, a)| (a.name.clone(), i))
            .collect();
        Ok(Manifest { version, vl, artifacts, index })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.index.get(name).map(|&i| &self.artifacts[i])
    }

    /// All artifacts of a given kind (e.g. `"gemv"`, `"lstm_step"`).
    pub fn of_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a ArtifactMeta> {
        self.artifacts.iter().filter(move |a| a.kind == kind)
    }
}

fn parse_specs(j: Option<&Json>, what: &str) -> Result<Vec<TensorSpec>> {
    let arr = j
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("artifact missing {what}[]"))?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, s) in arr.iter().enumerate() {
        let dtype = DType::parse(
            s.get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{what}[{i}] missing dtype"))?,
        )?;
        let shape = s
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{what}[{i}] missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let name = s
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or(&format!("{what}{i}"))
            .to_string();
        out.push(TensorSpec { name, dtype, shape });
    }
    Ok(out)
}

fn parse_artifact(a: &Json) -> Result<ArtifactMeta> {
    let gets = |k: &str| -> Result<String> {
        Ok(a.get(k)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("artifact missing {k}"))?
            .to_string())
    };
    let mut meta = HashMap::new();
    if let Some(Json::Obj(m)) = a.get("meta") {
        for (k, v) in m {
            if let Some(n) = v.as_f64() {
                meta.insert(k.clone(), n as i64);
            }
        }
    }
    Ok(ArtifactMeta {
        name: gets("name")?,
        file: gets("file")?,
        kind: gets("kind")?,
        variant: gets("variant")?,
        inputs: parse_specs(a.get("inputs"), "inputs")?,
        outputs: parse_specs(a.get("outputs"), "outputs")?,
        meta,
    })
}

/// Parse a model manifest (see the module docs for the schema) into a
/// validated [`ModelGraph`].  `"model"` may also name a zoo graph (no
/// `"layers"` key): the registry constructor is used with the
/// manifest's variant/size/seed — one schema covers both "pick a zoo
/// model" and "declare a custom topology".
pub fn parse_model_graph(text: &str) -> Result<ModelGraph> {
    let j = Json::parse(text).map_err(|e| anyhow!("model manifest JSON: {e}"))?;
    let name = j
        .get("model")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("model manifest missing \"model\""))?
        .to_string();
    let variant = Variant::parse(j.get("variant").and_then(Json::as_str).unwrap_or("w4a8"))
        .map_err(|e| anyhow!("model manifest variant: {e}"))?;
    let seed = j.get("seed").and_then(Json::as_usize).unwrap_or(7) as u64;

    let Some(layers_json) = j.get("layers") else {
        // no "layers" key at all: resolve through the zoo registry.
        // Shape keys only make sense with explicit layers — rejecting
        // them here beats silently serving a preset the user did not
        // describe
        for key in ["input_dim", "time_steps"] {
            if j.get(key).is_some() {
                bail!(
                    "model manifest: {key:?} only applies to explicit \"layers\" \
                     manifests (zoo graphs fix their own shapes)"
                );
            }
        }
        let size_str = j.get("size").and_then(Json::as_str).unwrap_or("full");
        let size = ModelSize::parse(size_str)
            .ok_or_else(|| anyhow!("model manifest size {size_str:?} (expected full|tiny)"))?;
        return ModelRegistry::global()
            .build(&name, size, variant, seed)
            .map_err(|e| anyhow!("model manifest: {e}"));
    };
    // a present-but-malformed "layers" is an error, never a silent
    // fallback onto a built-in zoo graph
    let layers = layers_json
        .as_arr()
        .ok_or_else(|| anyhow!("model manifest: \"layers\" must be an array"))?;

    let input_dim = j
        .get("input_dim")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("model manifest missing input_dim"))?;
    let time_steps = j.get("time_steps").and_then(Json::as_usize).unwrap_or(1);
    let mut g = ModelGraph::new(name, variant, input_dim, time_steps, seed);
    for (i, l) in layers.iter().enumerate() {
        let lname = l
            .get("name")
            .and_then(Json::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| format!("layer{i}"));
        let op = l
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("layers[{i}] missing op"))?;
        g = match op {
            "fc" => {
                let z = l
                    .get("z")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("layers[{i}]: fc needs z"))?;
                let relu = matches!(l.get("relu"), Some(Json::Bool(true)));
                match l.get("variant").and_then(Json::as_str) {
                    Some(v) => {
                        let v = Variant::parse(v)
                            .map_err(|e| anyhow!("layers[{i}] variant: {e}"))?;
                        g.fc_fixed(lname, z, relu, v)
                    }
                    None => g.fc(lname, z, relu),
                }
            }
            "lstm" | "gru" => {
                let hidden = l
                    .get("hidden")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("layers[{i}]: {op} needs hidden"))?;
                if op == "lstm" {
                    g.lstm(lname, hidden)
                } else {
                    g.gru(lname, hidden)
                }
            }
            "relu" => {
                let max = l.get("max").and_then(Json::as_f64).unwrap_or(20.0) as f32;
                g.relu(lname, max)
            }
            other => bail!("layers[{i}]: unknown op {other:?} (fc|lstm|gru|relu)"),
        };
    }
    g.validate().map_err(|e| anyhow!("model manifest: {e}"))?;
    Ok(g)
}

/// Read and [`parse_model_graph`] a model manifest file.
pub fn load_model_graph(path: impl AsRef<std::path::Path>) -> Result<ModelGraph> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading model manifest {path:?}: {e}"))?;
    parse_model_graph(&text)
}

/// Compile a model manifest and atomically hot-swap it into a serving
/// engine under the manifest's `"model"` name (DESIGN.md §14): new
/// admissions see the new weights immediately, in-flight batches sealed
/// on the old version drain on the old weights their dispatch guards
/// hold, and the per-model version counter bumps.  The installed
/// rebuild closure recompiles *this* manifest's graph, so an eviction
/// after the swap restores the swapped-in version, never the
/// registration-time one.  Returns the new version number.
pub fn swap_model_from_manifest(
    engine: &crate::coordinator::Engine,
    path: impl AsRef<std::path::Path>,
) -> Result<u64> {
    let graph = load_model_graph(path)?;
    let name = graph.name.clone();
    let model = crate::models::CompiledModel::compile(graph.clone())
        .map_err(|e| anyhow!("swap {name:?}: {e}"))?;
    let builder: crate::models::ModelBuilder = Box::new(move || {
        crate::models::CompiledModel::compile(graph.clone())
            .map(|m| std::sync::Arc::new(m) as std::sync::Arc<dyn crate::models::Model>)
            .map_err(|e| e.to_string())
    });
    engine
        .swap_model(&name, model, Some(builder))
        .map_err(|e| anyhow!("swap {name:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "vl": 16,
      "artifacts": [
        {"name": "gemv_w4a8_256x256", "file": "gemv_w4a8_256x256.hlo.txt",
         "kind": "gemv", "variant": "w4a8",
         "meta": {"z": 256, "k": 256, "row_tile": 8},
         "inputs": [
           {"name": "weights", "dtype": "u8", "shape": [256, 128]},
           {"name": "activations", "dtype": "s8", "shape": [256]}],
         "outputs": [{"dtype": "s32", "shape": [256]}]}
      ]}"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        assert_eq!(m.vl, 16);
        let a = m.get("gemv_w4a8_256x256").unwrap();
        assert_eq!(a.kind, "gemv");
        assert_eq!(a.meta["z"], 256);
        assert_eq!(a.inputs[0].dtype, DType::U8);
        assert_eq!(a.inputs[0].shape, vec![256, 128]);
        assert_eq!(a.inputs[0].elems(), 256 * 128);
        assert_eq!(a.outputs[0].dtype, DType::S32);
        assert_eq!(m.of_kind("gemv").count(), 1);
        assert!(m.get("missing").is_none());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Manifest::parse(&text).unwrap();
            assert!(m.artifacts.len() >= 30);
            // all nine paper variants have a 256x256 gemv artifact
            for v in crate::pack::Variant::PAPER_VARIANTS {
                assert!(m.get(&format!("gemv_{}_256x256", v.name())).is_some(), "{v}");
            }
        }
    }

    #[test]
    fn model_manifest_builds_a_custom_graph() {
        let g = parse_model_graph(
            r#"{"model": "custom-kws", "variant": "w2a8", "input_dim": 40,
                "time_steps": 4, "seed": 9,
                "layers": [
                  {"name": "fc1", "op": "fc", "z": 48, "relu": true, "variant": "w8a8"},
                  {"name": "gru", "op": "gru", "hidden": 16},
                  {"name": "act", "op": "relu", "max": 10},
                  {"name": "out", "op": "fc", "z": 12}
                ]}"#,
        )
        .unwrap();
        assert_eq!(g.name, "custom-kws");
        assert_eq!(g.variant, crate::pack::Variant::parse("w2a8").unwrap());
        assert_eq!(g.nodes.len(), 4);
        assert_eq!(g.nodes[1].z, 48); // 3 * hidden
        assert_eq!(g.nodes[1].k, 48); // chained from fc1
        assert_eq!(g.output_len(), 4 * 12);
        // runtime-built graphs execute through the compiler
        let m = crate::models::CompiledModel::compile(g).unwrap();
        let frames = vec![0.1f32; 4 * 40];
        let (out, times) = m.forward_timed(&frames);
        assert_eq!(out.len(), 4 * 12);
        assert!(out.iter().all(|v| v.is_finite()));
        assert_eq!(times.len(), 4);
        assert_eq!(times[1].0, "gru");
    }

    #[test]
    fn model_manifest_resolves_zoo_names() {
        let g = parse_model_graph(
            r#"{"model": "mlp", "variant": "w4a8", "size": "tiny", "seed": 3}"#,
        )
        .unwrap();
        assert_eq!(g.name, "mlp");
        assert_eq!(g.time_steps, 1);
        assert_eq!(g.seed, 3);
    }

    #[test]
    fn model_manifest_rejects_bad_inputs() {
        assert!(parse_model_graph("nope").is_err());
        assert!(parse_model_graph(r#"{"layers": []}"#).is_err()); // no model
        assert!(parse_model_graph(r#"{"model": "ghost-zoo-entry"}"#).is_err());
        // a present-but-malformed "layers" must error, never silently
        // fall back to the zoo graph of the same name
        assert!(parse_model_graph(
            r#"{"model": "mlp", "input_dim": 8, "layers": {"op": "fc", "z": 8}}"#
        )
        .is_err());
        // shape keys on a zoo-name manifest must error, not be ignored
        assert!(parse_model_graph(r#"{"model": "mlp", "input_dim": 99}"#).is_err());
        assert!(parse_model_graph(r#"{"model": "mlp", "time_steps": 9}"#).is_err());
        // custom layers need input_dim
        assert!(parse_model_graph(
            r#"{"model": "m", "layers": [{"op": "fc", "z": 8}]}"#
        )
        .is_err());
        // unknown op
        assert!(parse_model_graph(
            r#"{"model": "m", "input_dim": 8,
                "layers": [{"op": "conv", "z": 8}]}"#
        )
        .is_err());
        // structurally invalid graphs are rejected by validate()
        assert!(parse_model_graph(
            r#"{"model": "m", "input_dim": 8, "layers": []}"#
        )
        .is_err());
        assert!(load_model_graph("/no/such/manifest.json").is_err());
    }

    #[test]
    fn dtype_roundtrip() {
        for d in [DType::S8, DType::U8, DType::S32, DType::F32] {
            assert_eq!(DType::parse(d.name()).unwrap(), d);
        }
        assert!(DType::parse("f64").is_err());
    }
}

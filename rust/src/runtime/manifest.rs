//! Artifact manifest (`artifacts/manifest.json`) — written by
//! `python/compile/aot.py`, read here with the in-repo JSON parser.

use crate::util::json::Json;
use crate::util::error::{anyhow, bail, Result};
use std::collections::HashMap;

/// Element dtype of a tensor crossing the AOT boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    S8,
    U8,
    S32,
    F32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "s8" => DType::S8,
            "u8" => DType::U8,
            "s32" => DType::S32,
            "f32" => DType::F32,
            other => bail!("unknown dtype {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::S8 => "s8",
            DType::U8 => "u8",
            DType::S32 => "s32",
            DType::F32 => "f32",
        }
    }
}

/// Shape+dtype of one artifact input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub variant: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// free-form integer metadata (z, k, row_tile, hidden, ...)
    pub meta: HashMap<String, i64>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: usize,
    pub vl: usize,
    pub artifacts: Vec<ArtifactMeta>,
    index: HashMap<String, usize>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let version = j.get("version").and_then(Json::as_usize).unwrap_or(0);
        let vl = j.get("vl").and_then(Json::as_usize).unwrap_or(16);
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts[]"))?
        {
            artifacts.push(parse_artifact(a)?);
        }
        let index = artifacts
            .iter()
            .enumerate()
            .map(|(i, a)| (a.name.clone(), i))
            .collect();
        Ok(Manifest { version, vl, artifacts, index })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.index.get(name).map(|&i| &self.artifacts[i])
    }

    /// All artifacts of a given kind (e.g. `"gemv"`, `"lstm_step"`).
    pub fn of_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a ArtifactMeta> {
        self.artifacts.iter().filter(move |a| a.kind == kind)
    }
}

fn parse_specs(j: Option<&Json>, what: &str) -> Result<Vec<TensorSpec>> {
    let arr = j
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("artifact missing {what}[]"))?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, s) in arr.iter().enumerate() {
        let dtype = DType::parse(
            s.get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{what}[{i}] missing dtype"))?,
        )?;
        let shape = s
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{what}[{i}] missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let name = s
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or(&format!("{what}{i}"))
            .to_string();
        out.push(TensorSpec { name, dtype, shape });
    }
    Ok(out)
}

fn parse_artifact(a: &Json) -> Result<ArtifactMeta> {
    let gets = |k: &str| -> Result<String> {
        Ok(a.get(k)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("artifact missing {k}"))?
            .to_string())
    };
    let mut meta = HashMap::new();
    if let Some(Json::Obj(m)) = a.get("meta") {
        for (k, v) in m {
            if let Some(n) = v.as_f64() {
                meta.insert(k.clone(), n as i64);
            }
        }
    }
    Ok(ArtifactMeta {
        name: gets("name")?,
        file: gets("file")?,
        kind: gets("kind")?,
        variant: gets("variant")?,
        inputs: parse_specs(a.get("inputs"), "inputs")?,
        outputs: parse_specs(a.get("outputs"), "outputs")?,
        meta,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "vl": 16,
      "artifacts": [
        {"name": "gemv_w4a8_256x256", "file": "gemv_w4a8_256x256.hlo.txt",
         "kind": "gemv", "variant": "w4a8",
         "meta": {"z": 256, "k": 256, "row_tile": 8},
         "inputs": [
           {"name": "weights", "dtype": "u8", "shape": [256, 128]},
           {"name": "activations", "dtype": "s8", "shape": [256]}],
         "outputs": [{"dtype": "s32", "shape": [256]}]}
      ]}"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        assert_eq!(m.vl, 16);
        let a = m.get("gemv_w4a8_256x256").unwrap();
        assert_eq!(a.kind, "gemv");
        assert_eq!(a.meta["z"], 256);
        assert_eq!(a.inputs[0].dtype, DType::U8);
        assert_eq!(a.inputs[0].shape, vec![256, 128]);
        assert_eq!(a.inputs[0].elems(), 256 * 128);
        assert_eq!(a.outputs[0].dtype, DType::S32);
        assert_eq!(m.of_kind("gemv").count(), 1);
        assert!(m.get("missing").is_none());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Manifest::parse(&text).unwrap();
            assert!(m.artifacts.len() >= 30);
            // all nine paper variants have a 256x256 gemv artifact
            for v in crate::pack::Variant::PAPER_VARIANTS {
                assert!(m.get(&format!("gemv_{}_256x256", v.name())).is_some(), "{v}");
            }
        }
    }

    #[test]
    fn dtype_roundtrip() {
        for d in [DType::S8, DType::U8, DType::S32, DType::F32] {
            assert_eq!(DType::parse(d.name()).unwrap(), d);
        }
        assert!(DType::parse("f64").is_err());
    }
}

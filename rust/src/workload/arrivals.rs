//! Arrival-plan layer: expand a [`WorkloadMix`] into per-client
//! request plans.
//!
//! A plan is computed **before** the run starts, from the mix seed
//! alone — the live loadgen and the virtual-clock simulator replay the
//! *same* plan, which is what makes their traces comparable and makes
//! every run of a mix reproducible.  Client `c` draws from SplitMix64
//! stream `c` of `mix.seed`, so plans are independent of client count
//! ordering and of thread scheduling.

use super::mix::{ArrivalProcess, WorkloadMix};
use crate::util::rng::SplitMix64;

/// One request in a plan: which model it addresses and how much of the
/// model's fixed input window carries signal.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlannedRequest {
    /// index into `mix.models`
    pub model: usize,
    /// sequence fill in `(0, 1]`
    pub fill: f64,
}

/// One arrival event: wait `gap_ns`, then submit all `requests`
/// back-to-back.  For open-loop processes the gap is measured from the
/// previous *arrival*; for the closed loop it is think time measured
/// from the previous burst's *completion*.
#[derive(Debug, Clone, PartialEq)]
pub struct PlannedBurst {
    /// nanoseconds to wait before this burst (see above for the epoch)
    pub gap_ns: u64,
    /// requests submitted at this arrival
    pub requests: Vec<PlannedRequest>,
}

/// Per-burst inter-arrival gaps for one client, as an iterator-ish
/// stateful sampler (split out so the gap math is testable alone).
struct GapSampler {
    arrival: ArrivalProcess,
    clients: u64,
    /// bursts emitted so far
    count: u64,
    /// bursty: position inside the current on-window, ns
    phase_ns: f64,
}

impl GapSampler {
    fn new(mix: &WorkloadMix) -> GapSampler {
        GapSampler {
            arrival: mix.arrival,
            clients: mix.clients as u64,
            count: 0,
            phase_ns: 0.0,
        }
    }

    fn next_gap_ns(&mut self, client: usize, rng: &mut SplitMix64) -> u64 {
        let first = self.count == 0;
        self.count += 1;
        match self.arrival {
            ArrivalProcess::OpenPoisson { rate_rps } => {
                // each of `clients` streams carries 1/clients of the
                // aggregate rate: per-client mean gap = clients / rate
                let mean_ns = 1e9 * self.clients as f64 / rate_rps;
                rng.exp(mean_ns) as u64
            }
            ArrivalProcess::Deterministic { interval_us } => {
                let interval_ns = interval_us * 1_000;
                if first {
                    client as u64 * interval_ns
                } else {
                    interval_ns * self.clients
                }
            }
            ArrivalProcess::ClosedLoop { think_us } => {
                if first {
                    0
                } else {
                    think_us * 1_000
                }
            }
            ArrivalProcess::BurstyOnOff { on_us, off_us, rate_rps } => {
                // Poisson during on-windows only: draw the on-time gap,
                // then add one off-window per on-window boundary the
                // gap crosses (folding the silent periods in)
                let mean_ns = 1e9 * self.clients as f64 / rate_rps;
                let on_ns = (on_us * 1_000) as f64;
                let off_ns = (off_us * 1_000) as f64;
                let raw = rng.exp(mean_ns);
                let crossings = ((self.phase_ns + raw) / on_ns).floor();
                self.phase_ns = (self.phase_ns + raw) % on_ns;
                (raw + crossings * off_ns) as u64
            }
        }
    }
}

/// Expand the plan for one client of a mix: bursts with inter-arrival
/// gaps, each holding per-request model choices and sequence fills.
/// Deterministic in `(mix.seed, client)`; the per-request draw order
/// (model, then fill) is part of the format.
pub fn client_plan(mix: &WorkloadMix, client: usize) -> Vec<PlannedBurst> {
    let mut rng = SplitMix64::stream(mix.seed, client as u64);
    let weights: Vec<f64> = mix.models.iter().map(|m| m.weight).collect();
    let mut gaps = GapSampler::new(mix);
    let mut bursts = Vec::new();
    let mut remaining = mix.requests_per_client;
    while remaining > 0 {
        let gap_ns = gaps.next_gap_ns(client, &mut rng);
        let want = (mix.burst.sample(&mut rng).round() as usize).max(1);
        let n = want.min(remaining);
        remaining -= n;
        let requests = (0..n)
            .map(|_| PlannedRequest {
                model: rng.pick_weighted(&weights),
                fill: mix.seq_fill.sample(&mut rng),
            })
            .collect();
        bursts.push(PlannedBurst { gap_ns, requests });
    }
    bursts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::mix::{Dist, MixSpace};

    fn base_mix() -> WorkloadMix {
        let mut m = MixSpace::default_space().sample(11, 0);
        m.clients = 2;
        m.requests_per_client = 20;
        m
    }

    fn total(plan: &[PlannedBurst]) -> usize {
        plan.iter().map(|b| b.requests.len()).sum()
    }

    #[test]
    fn plans_are_deterministic_and_complete() {
        let mix = base_mix();
        for client in 0..mix.clients {
            let a = client_plan(&mix, client);
            let b = client_plan(&mix, client);
            assert_eq!(a, b);
            assert_eq!(total(&a), mix.requests_per_client);
            for burst in &a {
                assert!(!burst.requests.is_empty());
                for r in &burst.requests {
                    assert!(r.model < mix.models.len());
                    assert!(r.fill > 0.0 && r.fill <= 1.0);
                }
            }
        }
        // distinct clients draw from distinct streams
        assert_ne!(client_plan(&mix, 0), client_plan(&mix, 1));
    }

    #[test]
    fn deterministic_arrivals_stagger_clients() {
        let mut mix = base_mix();
        mix.clients = 3;
        mix.arrival = ArrivalProcess::Deterministic { interval_us: 500 };
        mix.burst = Dist::Const(1.0);
        for client in 0..3 {
            let plan = client_plan(&mix, client);
            // first gap offsets the client; later gaps keep the
            // aggregate stream at one request per interval
            assert_eq!(plan[0].gap_ns, client as u64 * 500_000);
            for b in &plan[1..] {
                assert_eq!(b.gap_ns, 3 * 500_000);
            }
        }
    }

    #[test]
    fn closed_loop_thinks_between_bursts() {
        let mut mix = base_mix();
        mix.arrival = ArrivalProcess::ClosedLoop { think_us: 250 };
        let plan = client_plan(&mix, 0);
        assert_eq!(plan[0].gap_ns, 0);
        for b in &plan[1..] {
            assert_eq!(b.gap_ns, 250_000);
        }
    }

    #[test]
    fn poisson_gaps_average_near_mean() {
        let mut mix = base_mix();
        mix.clients = 1;
        mix.requests_per_client = 4000;
        mix.arrival = ArrivalProcess::OpenPoisson { rate_rps: 1000.0 };
        mix.burst = Dist::Const(1.0);
        let plan = client_plan(&mix, 0);
        let mean = plan.iter().map(|b| b.gap_ns as f64).sum::<f64>() / plan.len() as f64;
        // per-client mean gap = clients/rate = 1ms
        assert!((mean - 1_000_000.0).abs() < 100_000.0, "mean {mean}");
    }

    #[test]
    fn bursty_gaps_fold_in_off_windows() {
        let mut mix = base_mix();
        mix.clients = 1;
        mix.requests_per_client = 2000;
        mix.arrival =
            ArrivalProcess::BurstyOnOff { on_us: 1_000, off_us: 4_000, rate_rps: 10_000.0 };
        mix.burst = Dist::Const(1.0);
        let plan = client_plan(&mix, 0);
        // mean on-time gap is 0.1ms -> ~10 arrivals per 1ms on-window;
        // each on-window boundary adds a 4ms off-window, so the overall
        // mean gap must sit well above the pure-Poisson mean...
        let mean = plan.iter().map(|b| b.gap_ns as f64).sum::<f64>() / plan.len() as f64;
        assert!(mean > 150_000.0, "mean {mean}");
        // ...and arrivals-per-on-window ~ on_ns/mean_ns = 10, so mean ~
        // (0.1ms on-gap + 0.4ms amortized off) = 0.5ms
        assert!((mean - 500_000.0).abs() < 100_000.0, "mean {mean}");
        // some gaps are pure on-window gaps (no boundary crossed)
        assert!(plan.iter().any(|b| b.gap_ns < 1_000_000));
        // and some fold in at least one full off-window
        assert!(plan.iter().any(|b| b.gap_ns >= 4_000_000));
    }

    #[test]
    fn model_choice_follows_weights() {
        let mut mix = base_mix();
        mix.clients = 1;
        mix.requests_per_client = 6000;
        mix.models.truncate(1);
        let spec = mix.models[0].spec.clone();
        mix.models[0].weight = 3.0;
        mix.models.push(super::super::mix::MixModel {
            spec: crate::coordinator::ModelSpec { name: "other".to_string(), ..spec },
            weight: 1.0,
        });
        let plan = client_plan(&mix, 0);
        let hits = plan
            .iter()
            .flat_map(|b| &b.requests)
            .filter(|r| r.model == 0)
            .count();
        let frac = hits as f64 / mix.requests_per_client as f64;
        assert!((frac - 0.75).abs() < 0.03, "frac {frac}");
    }

    #[test]
    fn burst_sizes_respect_dist_and_clamp() {
        let mut mix = base_mix();
        mix.requests_per_client = 7;
        mix.burst = Dist::Const(3.0);
        let plan = client_plan(&mix, 0);
        let sizes: Vec<usize> = plan.iter().map(|b| b.requests.len()).collect();
        // 7 requests in bursts of 3: 3, 3, then a clamped 1
        assert_eq!(sizes, vec![3, 3, 1]);
    }
}

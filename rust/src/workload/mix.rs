//! Workload-mix spec layer: [`WorkloadMix`] (one concrete scenario as
//! a JSON file) and [`MixSpace`] (per-axis ranges a seeded sampler
//! draws mixes from).
//!
//! Serialization is hand-rolled over `util::json` (serde is
//! unavailable offline — DESIGN.md §7) with **deterministic key order
//! and float formatting**, so `gen-mixes --seed S` writes byte-identical
//! files on every run — the invariant `rust/tests/workload_harness.rs`
//! pins.  Engine knobs and roster entries reuse the exact
//! `serve --config` schema (`coordinator::config`).

use crate::coordinator::config::{
    engine_from_json, engine_to_json, model_spec_from_json, model_spec_to_json,
};
use crate::coordinator::{EngineConfig, ModelSpec};
use crate::util::error::{anyhow, bail, Result};
use crate::util::json::Json;
use crate::util::rng::SplitMix64;

/// Deterministic float formatting for mix files: Rust's shortest
/// round-trip `Display` — stable across runs and platforms for the
/// same bit pattern.
fn fmt_f64(x: f64) -> String {
    format!("{x}")
}

/// Round to `d` decimals (sampled axes are quantized so mix files stay
/// readable and byte-stable).
fn round_to(x: f64, d: u32) -> f64 {
    let p = 10f64.powi(d as i32);
    (x * p).round() / p
}

/// A scalar distribution a plan samples per burst/request.
#[derive(Debug, Clone, PartialEq)]
pub enum Dist {
    /// always the same value
    Const(f64),
    /// uniform in `[lo, hi]`
    Uniform {
        /// lower bound (inclusive)
        lo: f64,
        /// upper bound (inclusive)
        hi: f64,
    },
    /// weighted choice over `(value, weight)` options
    Choice(Vec<(f64, f64)>),
}

impl Dist {
    /// Draw one value.
    pub fn sample(&self, rng: &mut SplitMix64) -> f64 {
        match self {
            Dist::Const(v) => *v,
            Dist::Uniform { lo, hi } => rng.f64_in(*lo, *hi),
            Dist::Choice(opts) => {
                let weights: Vec<f64> = opts.iter().map(|(_, w)| *w).collect();
                opts[rng.pick_weighted(&weights)].0
            }
        }
    }

    /// Smallest value the distribution can produce.
    pub fn min_value(&self) -> f64 {
        match self {
            Dist::Const(v) => *v,
            Dist::Uniform { lo, .. } => *lo,
            Dist::Choice(opts) => {
                opts.iter().map(|(v, _)| *v).fold(f64::INFINITY, f64::min)
            }
        }
    }

    /// Largest value the distribution can produce.
    pub fn max_value(&self) -> f64 {
        match self {
            Dist::Const(v) => *v,
            Dist::Uniform { hi, .. } => *hi,
            Dist::Choice(opts) => {
                opts.iter().map(|(v, _)| *v).fold(f64::NEG_INFINITY, f64::max)
            }
        }
    }

    /// Parse from the mix-file schema (`ctx` labels errors).
    pub fn parse(j: &Json, ctx: &str) -> Result<Dist> {
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("{ctx}: missing dist kind"))?;
        match kind {
            "const" => {
                let v = j
                    .get("value")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("{ctx}: const dist missing value"))?;
                Ok(Dist::Const(v))
            }
            "uniform" => {
                let lo = j
                    .get("lo")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("{ctx}: uniform dist missing lo"))?;
                let hi = j
                    .get("hi")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("{ctx}: uniform dist missing hi"))?;
                if hi < lo {
                    bail!("{ctx}: uniform dist hi {hi} < lo {lo}");
                }
                Ok(Dist::Uniform { lo, hi })
            }
            "choice" => {
                let opts = j
                    .get("options")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("{ctx}: choice dist missing options"))?;
                if opts.is_empty() {
                    bail!("{ctx}: choice dist has no options");
                }
                let mut out = Vec::with_capacity(opts.len());
                for (i, o) in opts.iter().enumerate() {
                    let v = o
                        .get("value")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("{ctx}: options[{i}] missing value"))?;
                    let w = o.get("weight").and_then(Json::as_f64).unwrap_or(1.0);
                    if !(w > 0.0) {
                        bail!("{ctx}: options[{i}] non-positive weight {w}");
                    }
                    out.push((v, w));
                }
                Ok(Dist::Choice(out))
            }
            other => bail!("{ctx}: unknown dist kind {other:?} (expected const|uniform|choice)"),
        }
    }

    /// Serialize to the schema [`Dist::parse`] reads (deterministic).
    pub fn to_json(&self) -> String {
        match self {
            Dist::Const(v) => format!("{{\"kind\": \"const\", \"value\": {}}}", fmt_f64(*v)),
            Dist::Uniform { lo, hi } => format!(
                "{{\"kind\": \"uniform\", \"lo\": {}, \"hi\": {}}}",
                fmt_f64(*lo),
                fmt_f64(*hi)
            ),
            Dist::Choice(opts) => {
                let items: Vec<String> = opts
                    .iter()
                    .map(|(v, w)| {
                        format!("{{\"value\": {}, \"weight\": {}}}", fmt_f64(*v), fmt_f64(*w))
                    })
                    .collect();
                format!("{{\"kind\": \"choice\", \"options\": [{}]}}", items.join(", "))
            }
        }
    }
}

/// How requests arrive (the load-shape axis of a mix).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// open loop: aggregate Poisson arrivals at `rate_rps` (split
    /// evenly across clients); submission never waits for replies
    OpenPoisson {
        /// aggregate request rate (requests/second across all clients)
        rate_rps: f64,
    },
    /// open loop: fixed aggregate inter-arrival gap, clients staggered
    Deterministic {
        /// aggregate inter-arrival interval in microseconds
        interval_us: u64,
    },
    /// closed loop: each client waits for its replies, thinks, repeats
    ClosedLoop {
        /// per-client think time between bursts, microseconds
        think_us: u64,
    },
    /// open loop: Poisson at `rate_rps` during on-windows, silence
    /// during off-windows (burst storms — the tail-latency stressor)
    BurstyOnOff {
        /// on-window length, microseconds
        on_us: u64,
        /// off-window length, microseconds
        off_us: u64,
        /// aggregate rate during on-windows (requests/second)
        rate_rps: f64,
    },
}

impl ArrivalProcess {
    /// Schema kind tag (`poisson`/`deterministic`/`closed-loop`/`bursty`).
    pub fn kind(&self) -> &'static str {
        match self {
            ArrivalProcess::OpenPoisson { .. } => "poisson",
            ArrivalProcess::Deterministic { .. } => "deterministic",
            ArrivalProcess::ClosedLoop { .. } => "closed-loop",
            ArrivalProcess::BurstyOnOff { .. } => "bursty",
        }
    }

    /// Is submission decoupled from replies?
    pub fn is_open_loop(&self) -> bool {
        !matches!(self, ArrivalProcess::ClosedLoop { .. })
    }

    /// One-line human description.
    pub fn describe(&self) -> String {
        match self {
            ArrivalProcess::OpenPoisson { rate_rps } => format!("poisson {rate_rps} rps"),
            ArrivalProcess::Deterministic { interval_us } => {
                format!("deterministic {interval_us}us")
            }
            ArrivalProcess::ClosedLoop { think_us } => format!("closed-loop think {think_us}us"),
            ArrivalProcess::BurstyOnOff { on_us, off_us, rate_rps } => {
                format!("bursty {rate_rps} rps on {on_us}us / off {off_us}us")
            }
        }
    }

    /// Parse from the mix-file schema.
    pub fn parse(j: &Json) -> Result<ArrivalProcess> {
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("arrival: missing kind"))?;
        let f64_at = |key: &str| -> Result<f64> {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("arrival {kind}: missing {key}"))
        };
        let us_at = |key: &str| -> Result<u64> {
            let v = f64_at(key)?;
            if !(v >= 0.0) {
                bail!("arrival {kind}: negative {key}");
            }
            Ok(v as u64)
        };
        let a = match kind {
            "poisson" => ArrivalProcess::OpenPoisson { rate_rps: f64_at("rate_rps")? },
            "deterministic" => {
                ArrivalProcess::Deterministic { interval_us: us_at("interval_us")? }
            }
            "closed-loop" => ArrivalProcess::ClosedLoop { think_us: us_at("think_us")? },
            "bursty" => ArrivalProcess::BurstyOnOff {
                on_us: us_at("on_us")?,
                off_us: us_at("off_us")?,
                rate_rps: f64_at("rate_rps")?,
            },
            other => bail!(
                "arrival: unknown kind {other:?} (expected poisson|deterministic|closed-loop|bursty)"
            ),
        };
        match a {
            ArrivalProcess::OpenPoisson { rate_rps }
            | ArrivalProcess::BurstyOnOff { rate_rps, .. }
                if !(rate_rps > 0.0) =>
            {
                bail!("arrival {kind}: rate_rps must be positive (got {rate_rps})")
            }
            ArrivalProcess::BurstyOnOff { on_us, .. } if on_us == 0 => {
                bail!("arrival bursty: on_us must be positive")
            }
            ArrivalProcess::Deterministic { interval_us } if interval_us == 0 => {
                bail!("arrival deterministic: interval_us must be positive")
            }
            _ => {}
        }
        Ok(a)
    }

    /// Serialize to the schema [`ArrivalProcess::parse`] reads.
    pub fn to_json(&self) -> String {
        match self {
            ArrivalProcess::OpenPoisson { rate_rps } => format!(
                "{{\"kind\": \"poisson\", \"rate_rps\": {}}}",
                fmt_f64(*rate_rps)
            ),
            ArrivalProcess::Deterministic { interval_us } => format!(
                "{{\"kind\": \"deterministic\", \"interval_us\": {interval_us}}}"
            ),
            ArrivalProcess::ClosedLoop { think_us } => {
                format!("{{\"kind\": \"closed-loop\", \"think_us\": {think_us}}}")
            }
            ArrivalProcess::BurstyOnOff { on_us, off_us, rate_rps } => format!(
                "{{\"kind\": \"bursty\", \"on_us\": {on_us}, \"off_us\": {off_us}, \"rate_rps\": {}}}",
                fmt_f64(*rate_rps)
            ),
        }
    }
}

/// One model in a mix's composition: a roster entry (the exact
/// `serve --config` schema) plus its traffic weight.
#[derive(Debug, Clone, PartialEq)]
pub struct MixModel {
    /// roster entry (name, zoo graph, variant, size, weight seed)
    pub spec: ModelSpec,
    /// relative traffic share (need not be normalized)
    pub weight: f64,
}

/// One concrete workload scenario — the declarative unit the loadgen
/// replays and `gen-mixes` samples.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadMix {
    /// mix name (file stem, report label)
    pub name: String,
    /// seed for every random draw the mix's replay makes (plans,
    /// per-request model choice, fills)
    pub seed: u64,
    /// concurrent load-generating clients
    pub clients: usize,
    /// requests each client issues over the run
    pub requests_per_client: usize,
    /// how requests arrive
    pub arrival: ArrivalProcess,
    /// requests per arrival event (burst size; batch-size axis)
    pub burst: Dist,
    /// fraction of the model's fixed input window carrying signal
    /// (padded-utterance semantics — engine input shapes stay valid)
    pub seq_fill: Dist,
    /// model composition with traffic weights (≥1 entry)
    pub models: Vec<MixModel>,
    /// engine under test (same schema as `serve --config`)
    pub engine: EngineConfig,
}

impl WorkloadMix {
    /// Total requests the mix issues.
    pub fn total_requests(&self) -> usize {
        self.clients * self.requests_per_client
    }

    /// Parse a mix document; every malformed field is a typed error.
    pub fn parse(text: &str) -> Result<WorkloadMix> {
        let j = Json::parse(text).map_err(|e| anyhow!("mix JSON: {e}"))?;
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("mix: missing name"))?
            .to_string();
        let seed = j
            .get("seed")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("mix {name:?}: missing seed"))? as u64;
        let clients = j
            .get("clients")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("mix {name:?}: missing clients"))?;
        let requests_per_client = j
            .get("requests_per_client")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("mix {name:?}: missing requests_per_client"))?;
        let arrival = ArrivalProcess::parse(
            j.get("arrival").ok_or_else(|| anyhow!("mix {name:?}: missing arrival"))?,
        )?;
        let burst = match j.get("burst") {
            Some(b) => Dist::parse(b, "burst")?,
            None => Dist::Const(1.0),
        };
        let seq_fill = match j.get("seq_fill") {
            Some(s) => Dist::parse(s, "seq_fill")?,
            None => Dist::Const(1.0),
        };
        let marr = j
            .get("models")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("mix {name:?}: missing models"))?;
        let mut models = Vec::with_capacity(marr.len());
        for (i, m) in marr.iter().enumerate() {
            let spec = model_spec_from_json(m, i)?;
            let weight = m.get("weight").and_then(Json::as_f64).unwrap_or(1.0);
            models.push(MixModel { spec, weight });
        }
        let engine = engine_from_json(j.get("engine").unwrap_or(&Json::Null));
        let mix = WorkloadMix {
            name,
            seed,
            clients,
            requests_per_client,
            arrival,
            burst,
            seq_fill,
            models,
            engine,
        };
        mix.validate()?;
        Ok(mix)
    }

    /// Read and [`WorkloadMix::parse`] a mix file.
    pub fn load(path: &str) -> Result<WorkloadMix> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading mix {path:?}: {e}"))?;
        Self::parse(&text)
    }

    /// Semantic validation beyond field presence.
    pub fn validate(&self) -> Result<()> {
        let name = &self.name;
        if self.clients == 0 {
            bail!("mix {name:?}: clients must be >= 1");
        }
        if self.requests_per_client == 0 {
            bail!("mix {name:?}: requests_per_client must be >= 1");
        }
        if self.models.is_empty() {
            bail!("mix {name:?}: models must be non-empty");
        }
        let mut seen = std::collections::HashSet::new();
        for m in &self.models {
            if !seen.insert(m.spec.name.as_str()) {
                bail!("mix {name:?}: duplicate model name {:?}", m.spec.name);
            }
            if !(m.weight > 0.0) {
                bail!("mix {name:?}: model {:?} weight must be positive", m.spec.name);
            }
        }
        if self.burst.min_value() < 1.0 {
            bail!("mix {name:?}: burst sizes must be >= 1");
        }
        if self.seq_fill.min_value() <= 0.0 || self.seq_fill.max_value() > 1.0 {
            bail!("mix {name:?}: seq_fill must lie in (0, 1]");
        }
        Ok(())
    }

    /// Serialize to the mix-file schema (deterministic key order and
    /// float formatting — byte-stable for a given mix).
    pub fn to_json(&self) -> String {
        let models: Vec<String> = self
            .models
            .iter()
            .map(|m| {
                // splice the weight into the roster-entry object
                let spec = model_spec_to_json(&m.spec);
                format!(
                    "{}, \"weight\": {}}}",
                    &spec[..spec.len() - 1],
                    fmt_f64(m.weight)
                )
            })
            .collect();
        format!(
            "{{\n  \"name\": \"{}\",\n  \"seed\": {},\n  \"clients\": {},\n  \
             \"requests_per_client\": {},\n  \"arrival\": {},\n  \"burst\": {},\n  \
             \"seq_fill\": {},\n  \"models\": [\n    {}\n  ],\n  \"engine\": {}\n}}\n",
            self.name,
            self.seed,
            self.clients,
            self.requests_per_client,
            self.arrival.to_json(),
            self.burst.to_json(),
            self.seq_fill.to_json(),
            models.join(",\n    "),
            engine_to_json(&self.engine),
        )
    }

    /// Write [`WorkloadMix::to_json`] to `path`.
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json())
            .map_err(|e| anyhow!("writing mix {path:?}: {e}"))
    }
}

/// Per-axis ranges a sweep samples concrete mixes from (the
/// declarative input of `fullpack workload gen-mixes|sweep`).
#[derive(Debug, Clone)]
pub struct MixSpace {
    /// client-count range (inclusive)
    pub clients: (usize, usize),
    /// requests-per-client range (inclusive)
    pub requests_per_client: (usize, usize),
    /// arrival kinds to sample among (schema tags)
    pub arrivals: Vec<String>,
    /// aggregate Poisson/bursty rate range, log-uniform (rps)
    pub rate_rps: (f64, f64),
    /// deterministic inter-arrival range (µs)
    pub interval_us: (u64, u64),
    /// closed-loop think-time range (µs)
    pub think_us: (u64, u64),
    /// bursty on-window range (µs)
    pub on_us: (u64, u64),
    /// bursty off-window range (µs)
    pub off_us: (u64, u64),
    /// largest burst size a sampled burst dist may produce
    pub burst_max: usize,
    /// sequence-fill range (fraction of the input window)
    pub seq_fill: (f64, f64),
    /// models-per-mix range (inclusive; clamped to the zoo size)
    pub models_per_mix: (usize, usize),
    /// Zipf popularity exponent range: when the sampled `s > 0`, the
    /// drawn per-model traffic weights are reshaped to `1 / rank^s`
    /// (roster order = popularity rank), concentrating traffic on a
    /// head of hot models — the model-churn axis that exercises the
    /// store's residency budget (cold sheds, LRU rotation).  The
    /// default `(0, 0)` disables the axis and leaves sampled weights
    /// untouched, so pre-store seeds resample byte-identically.
    pub zipf_s: (f64, f64),
    /// roster entries mixes draw their composition from
    pub zoo: Vec<ModelSpec>,
    /// engine under test for every sampled mix
    pub engine: EngineConfig,
}

impl MixSpace {
    /// The built-in CI-friendly space: tiny zoo models, small client
    /// counts, every arrival kind reachable.
    pub fn default_space() -> MixSpace {
        let spec = |name: &str, model: &str, variant: &str| ModelSpec {
            name: name.to_string(),
            model: model.to_string(),
            variant: crate::pack::Variant::parse(variant).unwrap(),
            size: crate::models::ModelSize::Tiny,
            seed: 7,
            pin: false,
        };
        MixSpace {
            clients: (1, 3),
            requests_per_client: (4, 10),
            arrivals: vec![
                "poisson".to_string(),
                "deterministic".to_string(),
                "closed-loop".to_string(),
                "bursty".to_string(),
            ],
            rate_rps: (50.0, 400.0),
            interval_us: (500, 5_000),
            think_us: (200, 2_000),
            on_us: (2_000, 10_000),
            off_us: (1_000, 5_000),
            burst_max: 4,
            seq_fill: (0.5, 1.0),
            models_per_mix: (1, 3),
            zipf_s: (0.0, 0.0),
            zoo: vec![
                spec("deepspeech-tiny", "deepspeech", "w4a8"),
                spec("kws-tiny", "keyword-spotter", "w2a8"),
                spec("mlp-tiny", "mlp", "w4a8"),
            ],
            engine: EngineConfig::default(),
        }
    }

    /// Parse a space document: every key optional, defaulting to
    /// [`MixSpace::default_space`].
    pub fn parse(text: &str) -> Result<MixSpace> {
        let j = Json::parse(text).map_err(|e| anyhow!("space JSON: {e}"))?;
        let mut s = MixSpace::default_space();
        let usize_pair = |key: &str, cur: (usize, usize)| -> Result<(usize, usize)> {
            match j.get(key) {
                None => Ok(cur),
                Some(v) => {
                    let a = v.as_arr().ok_or_else(|| anyhow!("space {key}: expected [lo, hi]"))?;
                    if a.len() != 2 {
                        bail!("space {key}: expected [lo, hi]");
                    }
                    let lo = a[0].as_usize().ok_or_else(|| anyhow!("space {key}: bad lo"))?;
                    let hi = a[1].as_usize().ok_or_else(|| anyhow!("space {key}: bad hi"))?;
                    if hi < lo {
                        bail!("space {key}: hi < lo");
                    }
                    Ok((lo, hi))
                }
            }
        };
        let f64_pair = |key: &str, cur: (f64, f64)| -> Result<(f64, f64)> {
            match j.get(key) {
                None => Ok(cur),
                Some(v) => {
                    let a = v.as_arr().ok_or_else(|| anyhow!("space {key}: expected [lo, hi]"))?;
                    if a.len() != 2 {
                        bail!("space {key}: expected [lo, hi]");
                    }
                    let lo = a[0].as_f64().ok_or_else(|| anyhow!("space {key}: bad lo"))?;
                    let hi = a[1].as_f64().ok_or_else(|| anyhow!("space {key}: bad hi"))?;
                    if hi < lo {
                        bail!("space {key}: hi < lo");
                    }
                    Ok((lo, hi))
                }
            }
        };
        s.clients = usize_pair("clients", s.clients)?;
        s.requests_per_client = usize_pair("requests_per_client", s.requests_per_client)?;
        if let Some(a) = j.get("arrivals") {
            let arr = a.as_arr().ok_or_else(|| anyhow!("space arrivals: expected an array"))?;
            let mut kinds = Vec::new();
            for k in arr {
                let k = k
                    .as_str()
                    .ok_or_else(|| anyhow!("space arrivals: expected kind strings"))?;
                if !matches!(k, "poisson" | "deterministic" | "closed-loop" | "bursty") {
                    bail!("space arrivals: unknown kind {k:?}");
                }
                kinds.push(k.to_string());
            }
            if kinds.is_empty() {
                bail!("space arrivals: must be non-empty");
            }
            s.arrivals = kinds;
        }
        s.rate_rps = f64_pair("rate_rps", s.rate_rps)?;
        if !(s.rate_rps.0 > 0.0) {
            bail!("space rate_rps: lo must be positive");
        }
        let u64_pair = |key: &str, cur: (u64, u64)| -> Result<(u64, u64)> {
            let p = usize_pair(key, (cur.0 as usize, cur.1 as usize))?;
            Ok((p.0 as u64, p.1 as u64))
        };
        s.interval_us = u64_pair("interval_us", s.interval_us)?;
        s.think_us = u64_pair("think_us", s.think_us)?;
        s.on_us = u64_pair("on_us", s.on_us)?;
        s.off_us = u64_pair("off_us", s.off_us)?;
        if let Some(b) = j.get("burst_max") {
            s.burst_max = b.as_usize().ok_or_else(|| anyhow!("space burst_max: bad number"))?;
        }
        s.seq_fill = f64_pair("seq_fill", s.seq_fill)?;
        if !(s.seq_fill.0 > 0.0) || s.seq_fill.1 > 1.0 {
            bail!("space seq_fill: range must lie in (0, 1]");
        }
        s.models_per_mix = usize_pair("models_per_mix", s.models_per_mix)?;
        s.zipf_s = f64_pair("zipf_s", s.zipf_s)?;
        if s.zipf_s.0 < 0.0 {
            bail!("space zipf_s: lo must be >= 0");
        }
        if let Some(arr) = j.get("zoo").and_then(Json::as_arr) {
            let mut zoo = Vec::with_capacity(arr.len());
            for (i, m) in arr.iter().enumerate() {
                zoo.push(model_spec_from_json(m, i)?);
            }
            if zoo.is_empty() {
                bail!("space zoo: must be non-empty");
            }
            s.zoo = zoo;
        }
        if let Some(e) = j.get("engine") {
            s.engine = engine_from_json(e);
        }
        if s.models_per_mix.0 == 0 {
            bail!("space models_per_mix: lo must be >= 1");
        }
        Ok(s)
    }

    /// Read and [`MixSpace::parse`] a space file.
    pub fn load(path: &str) -> Result<MixSpace> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading space {path:?}: {e}"))?;
        Self::parse(&text)
    }

    /// Sample mix `index` of a sweep seeded with `seed`: stream
    /// `index` of the seed drives every draw, so any mix of a sweep is
    /// reproducible in isolation and the whole sweep is byte-identical
    /// across runs.  The axis order below is part of the format — it
    /// must not change, or existing seeds resample differently.
    pub fn sample(&self, seed: u64, index: usize) -> WorkloadMix {
        let mut r = SplitMix64::stream(seed, index as u64);
        // folded to 53 bits: mix files carry the seed as a JSON number,
        // and only integers up to 2^53 survive the f64-backed number
        // representation byte-exactly through a save -> load roundtrip
        let mix_seed = r.next_u64() >> 11;
        let clients = r.usize_in(self.clients.0, self.clients.1);
        let requests_per_client =
            r.usize_in(self.requests_per_client.0, self.requests_per_client.1);
        let kind = &self.arrivals[r.usize_in(0, self.arrivals.len() - 1)];
        let arrival = match kind.as_str() {
            "poisson" => ArrivalProcess::OpenPoisson {
                rate_rps: round_to(r.f64_log_in(self.rate_rps.0, self.rate_rps.1), 1),
            },
            "deterministic" => ArrivalProcess::Deterministic {
                interval_us: r.usize_in(self.interval_us.0 as usize, self.interval_us.1 as usize)
                    as u64,
            },
            "closed-loop" => ArrivalProcess::ClosedLoop {
                think_us: r.usize_in(self.think_us.0 as usize, self.think_us.1 as usize) as u64,
            },
            _ => ArrivalProcess::BurstyOnOff {
                on_us: r.usize_in(self.on_us.0 as usize, self.on_us.1 as usize) as u64,
                off_us: r.usize_in(self.off_us.0 as usize, self.off_us.1 as usize) as u64,
                rate_rps: round_to(r.f64_log_in(self.rate_rps.0, self.rate_rps.1), 1),
            },
        };
        let burst = if self.burst_max <= 1 || r.f64_unit() < 0.5 {
            Dist::Const(1.0)
        } else {
            Dist::Uniform { lo: 1.0, hi: self.burst_max as f64 }
        };
        let fill_a = round_to(r.f64_in(self.seq_fill.0, self.seq_fill.1), 2);
        let fill_b = round_to(r.f64_in(self.seq_fill.0, self.seq_fill.1), 2);
        let (lo, hi) = (fill_a.min(fill_b), fill_a.max(fill_b));
        let seq_fill = if lo == hi { Dist::Const(lo) } else { Dist::Uniform { lo, hi } };
        let want = r.usize_in(
            self.models_per_mix.0.min(self.zoo.len()),
            self.models_per_mix.1.min(self.zoo.len()),
        );
        // partial Fisher-Yates: the first `want` slots are a uniform
        // subset in a deterministic order
        let mut idx: Vec<usize> = (0..self.zoo.len()).collect();
        for i in 0..want {
            let j = r.usize_in(i, idx.len() - 1);
            idx.swap(i, j);
        }
        let mut models: Vec<MixModel> = idx[..want]
            .iter()
            .map(|&zi| MixModel {
                spec: self.zoo[zi].clone(),
                weight: round_to(r.f64_in(0.5, 2.0), 2),
            })
            .collect();
        // Zipf popularity axis (appended after every pre-existing draw
        // so disabled spaces resample byte-identically): reshape the
        // traffic weights to 1/rank^s in sampled roster order, giving
        // the head models the traffic and the tail the cold starts.
        if self.zipf_s.1 > 0.0 {
            let s = round_to(r.f64_in(self.zipf_s.0, self.zipf_s.1), 2);
            if s > 0.0 {
                for (rank, m) in models.iter_mut().enumerate() {
                    m.weight = round_to(1.0 / ((rank + 1) as f64).powf(s), 4).max(0.0001);
                }
            }
        }
        WorkloadMix {
            name: format!("mix_{index:03}"),
            seed: mix_seed,
            clients,
            requests_per_client,
            arrival,
            burst,
            seq_fill,
            models,
            engine: self.engine,
        }
    }

    /// Sample `count` mixes (`mix_000` … `mix_{count-1}`).
    pub fn sample_all(&self, seed: u64, count: usize) -> Vec<WorkloadMix> {
        (0..count).map(|i| self.sample(seed, i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bursty_mix_text() -> &'static str {
        r#"{
          "name": "storm",
          "seed": 99,
          "clients": 2,
          "requests_per_client": 6,
          "arrival": {"kind": "bursty", "on_us": 3000, "off_us": 2000, "rate_rps": 200.0},
          "burst": {"kind": "uniform", "lo": 1, "hi": 3},
          "seq_fill": {"kind": "const", "value": 0.8},
          "models": [
            {"name": "ds", "model": "deepspeech", "variant": "w4a8", "size": "tiny", "seed": 7, "weight": 1.5},
            {"name": "kws", "model": "keyword-spotter", "variant": "w2a8", "size": "tiny", "seed": 7, "weight": 0.5}
          ],
          "engine": {"workers": 2, "batcher": {"max_batch": 4, "max_wait_ms": 1, "max_queue": 64}}
        }"#
    }

    #[test]
    fn mix_parses_and_roundtrips() {
        let mix = WorkloadMix::parse(bursty_mix_text()).unwrap();
        assert_eq!(mix.name, "storm");
        assert_eq!(mix.total_requests(), 12);
        assert_eq!(mix.arrival.kind(), "bursty");
        assert!(mix.arrival.is_open_loop());
        assert_eq!(mix.models.len(), 2);
        assert_eq!(mix.models[0].weight, 1.5);
        // the legacy "batcher" key still reaches the scheduler config
        assert_eq!(mix.engine.sched.max_batch, 4);
        assert_eq!(mix.engine.sched.slo, crate::coordinator::SchedulerConfig::default().slo);
        // serialize -> parse -> identical structure (to_json re-emits
        // the modern "scheduler" key; the parse prefers it)
        let text = mix.to_json();
        let back = WorkloadMix::parse(&text).unwrap();
        assert_eq!(back, mix);
        // serialization is byte-stable
        assert_eq!(back.to_json(), text);
    }

    #[test]
    fn malformed_mixes_rejected_with_typed_errors() {
        let cases: Vec<(&str, &str)> = vec![
            ("not json", "mix JSON"),
            (r#"{"seed": 1}"#, "missing name"),
            (r#"{"name": "m"}"#, "missing seed"),
            (r#"{"name": "m", "seed": 1}"#, "missing clients"),
            (
                r#"{"name": "m", "seed": 1, "clients": 1, "requests_per_client": 1}"#,
                "missing arrival",
            ),
            (
                r#"{"name": "m", "seed": 1, "clients": 1, "requests_per_client": 1,
                   "arrival": {"kind": "warp"}, "models": []}"#,
                "unknown kind",
            ),
            (
                r#"{"name": "m", "seed": 1, "clients": 1, "requests_per_client": 1,
                   "arrival": {"kind": "poisson"}, "models": []}"#,
                "missing rate_rps",
            ),
            (
                r#"{"name": "m", "seed": 1, "clients": 1, "requests_per_client": 1,
                   "arrival": {"kind": "poisson", "rate_rps": 100}}"#,
                "missing models",
            ),
            (
                r#"{"name": "m", "seed": 1, "clients": 1, "requests_per_client": 1,
                   "arrival": {"kind": "poisson", "rate_rps": 100}, "models": []}"#,
                "models must be non-empty",
            ),
            (
                r#"{"name": "m", "seed": 1, "clients": 0, "requests_per_client": 1,
                   "arrival": {"kind": "poisson", "rate_rps": 100},
                   "models": [{"name": "ds", "size": "tiny"}]}"#,
                "clients must be >= 1",
            ),
            (
                r#"{"name": "m", "seed": 1, "clients": 1, "requests_per_client": 1,
                   "arrival": {"kind": "poisson", "rate_rps": 100},
                   "models": [{"name": "ds", "size": "tiny", "weight": 0}]}"#,
                "weight must be positive",
            ),
            (
                r#"{"name": "m", "seed": 1, "clients": 1, "requests_per_client": 1,
                   "arrival": {"kind": "poisson", "rate_rps": 100},
                   "models": [{"name": "ds", "size": "tiny"}, {"name": "ds", "size": "tiny"}]}"#,
                "duplicate model name",
            ),
            (
                r#"{"name": "m", "seed": 1, "clients": 1, "requests_per_client": 1,
                   "arrival": {"kind": "poisson", "rate_rps": 100},
                   "burst": {"kind": "const", "value": 0},
                   "models": [{"name": "ds", "size": "tiny"}]}"#,
                "burst sizes must be >= 1",
            ),
            (
                r#"{"name": "m", "seed": 1, "clients": 1, "requests_per_client": 1,
                   "arrival": {"kind": "poisson", "rate_rps": 100},
                   "seq_fill": {"kind": "uniform", "lo": 0.5, "hi": 1.5},
                   "models": [{"name": "ds", "size": "tiny"}]}"#,
                "seq_fill must lie in (0, 1]",
            ),
            (
                r#"{"name": "m", "seed": 1, "clients": 1, "requests_per_client": 1,
                   "arrival": {"kind": "bursty", "on_us": 0, "off_us": 10, "rate_rps": 5},
                   "models": [{"name": "ds", "size": "tiny"}]}"#,
                "on_us must be positive",
            ),
            (
                r#"{"name": "m", "seed": 1, "clients": 1, "requests_per_client": 1,
                   "arrival": {"kind": "poisson", "rate_rps": 100},
                   "burst": {"kind": "choice", "options": []},
                   "models": [{"name": "ds", "size": "tiny"}]}"#,
                "no options",
            ),
        ];
        for (text, needle) in cases {
            let err = WorkloadMix::parse(text).expect_err(needle).to_string();
            assert!(err.contains(needle), "expected {needle:?} in {err:?}");
        }
    }

    #[test]
    fn dists_sample_within_bounds() {
        let mut r = SplitMix64::new(5);
        let u = Dist::Uniform { lo: 1.0, hi: 4.0 };
        let c = Dist::Choice(vec![(2.0, 1.0), (8.0, 3.0)]);
        for _ in 0..500 {
            let v = u.sample(&mut r);
            assert!((1.0..=4.0).contains(&v));
            let w = c.sample(&mut r);
            assert!(w == 2.0 || w == 8.0);
        }
        assert_eq!(Dist::Const(3.0).sample(&mut r), 3.0);
        assert_eq!(u.min_value(), 1.0);
        assert_eq!(u.max_value(), 4.0);
        assert_eq!(c.min_value(), 2.0);
        assert_eq!(c.max_value(), 8.0);
    }

    #[test]
    fn sampler_is_deterministic_and_in_range() {
        let space = MixSpace::default_space();
        let a = space.sample_all(7, 5);
        let b = space.sample_all(7, 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
            assert_eq!(x.to_json(), y.to_json());
        }
        // a different seed changes at least one sampled mix
        let c = space.sample_all(8, 5);
        assert!(a.iter().zip(&c).any(|(x, y)| x != y));
        for (i, m) in a.iter().enumerate() {
            assert_eq!(m.name, format!("mix_{i:03}"));
            assert!((space.clients.0..=space.clients.1).contains(&m.clients));
            assert!(
                (space.requests_per_client.0..=space.requests_per_client.1)
                    .contains(&m.requests_per_client)
            );
            assert!(!m.models.is_empty() && m.models.len() <= space.zoo.len());
            m.validate().unwrap();
            // sampled mixes survive a serialize/parse roundtrip
            assert_eq!(&WorkloadMix::parse(&m.to_json()).unwrap(), m);
        }
    }

    #[test]
    fn space_parse_overrides_and_rejects() {
        let s = MixSpace::parse(
            r#"{"clients": [2, 2], "arrivals": ["bursty"], "burst_max": 2,
                "zoo": [{"name": "only", "model": "mlp", "size": "tiny"}]}"#,
        )
        .unwrap();
        assert_eq!(s.clients, (2, 2));
        assert_eq!(s.arrivals, vec!["bursty".to_string()]);
        assert_eq!(s.zoo.len(), 1);
        let m = s.sample(3, 0);
        assert_eq!(m.clients, 2);
        assert_eq!(m.arrival.kind(), "bursty");
        assert_eq!(m.models[0].spec.name, "only");

        assert!(MixSpace::parse("oops").is_err());
        assert!(MixSpace::parse(r#"{"clients": [3, 1]}"#).is_err());
        assert!(MixSpace::parse(r#"{"arrivals": ["warp"]}"#).is_err());
        assert!(MixSpace::parse(r#"{"arrivals": []}"#).is_err());
        assert!(MixSpace::parse(r#"{"seq_fill": [0.0, 1.0]}"#).is_err());
        assert!(MixSpace::parse(r#"{"models_per_mix": [0, 1]}"#).is_err());
        assert!(MixSpace::parse(r#"{"zipf_s": [-0.5, 1.0]}"#).is_err());
    }

    #[test]
    fn zipf_axis_reshapes_weights_and_disabled_space_is_unchanged() {
        // disabled axis: the default space must sample exactly as it
        // did before the axis existed (the zipf draw only happens when
        // the range is enabled, and it trails every other draw)
        let plain = MixSpace::default_space();
        assert_eq!(plain.zipf_s, (0.0, 0.0));
        let baseline = plain.sample_all(7, 4);
        for m in &baseline {
            for mm in &m.models {
                assert!((0.5..=2.0).contains(&mm.weight), "{}", mm.weight);
            }
        }

        // enabled axis parses, samples deterministically, and yields
        // strictly non-increasing 1/rank^s weights over the roster
        let zs = MixSpace::parse(
            r#"{"models_per_mix": [3, 3], "zipf_s": [1.0, 1.2]}"#,
        )
        .unwrap();
        assert_eq!(zs.zipf_s, (1.0, 1.2));
        let a = zs.sample_all(7, 4);
        assert_eq!(a, zs.sample_all(7, 4));
        for m in &a {
            assert_eq!(m.models.len(), 3);
            for w in m.models.windows(2) {
                assert!(w[0].weight > w[1].weight, "zipf weights must decay");
            }
            assert_eq!(m.models[0].weight, 1.0); // rank 1 is always 1/1^s
            assert!(m.models.iter().all(|mm| mm.weight > 0.0));
            m.validate().unwrap();
            // reshaped weights survive a serialize/parse roundtrip
            assert_eq!(&WorkloadMix::parse(&m.to_json()).unwrap(), m);
        }

        // everything drawn before the zipf axis is untouched by it:
        // same seed, same space apart from zipf -> identical arrivals,
        // clients, and roster selection
        let zs_off = MixSpace::parse(r#"{"models_per_mix": [3, 3]}"#).unwrap();
        let b = zs_off.sample_all(7, 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.clients, y.clients);
            assert_eq!(x.arrival, y.arrival);
            let xs: Vec<_> = x.models.iter().map(|m| &m.spec.name).collect();
            let ys: Vec<_> = y.models.iter().map(|m| &m.spec.name).collect();
            assert_eq!(xs, ys);
        }
    }
}
